//! Low-precision wire formats — the paper's "Reducing communication volume".
//!
//! Three wire dtypes: f32 (4 B/elem), bf16 (2 B/elem, truncation-rounded),
//! and int8 with one f32 absmax scale per [`QBLOCK`]-element block
//! (≈1.016 B/elem). Reduction is ALWAYS performed in f32 after decoding —
//! the paper's correctness requirement ("natively support low precision
//! communication, for guaranteeing correctness"): precision is lost only
//! on the wire, never in the accumulator.
//!
//! The int8 scheme mirrors the L1 Pallas kernel
//! (`python/compile/kernels/quantize.py`) bit-for-bit so a gradient
//! quantized on either side of the stack decodes identically.

use super::ReduceOp;
use crate::util::bf16::{bf16_bits_to_f32, f32_to_bf16_bits};

/// Elements per int8 quantization block (one f32 scale per block).
/// Must match `python/compile/kernels/ref.py::QBLOCK`.
pub const QBLOCK: usize = 256;

/// Wire element encoding for collective payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireDtype {
    #[default]
    F32,
    Bf16,
    /// Per-block absmax int8; `QBLOCK` elements share one f32 scale.
    Int8Block,
}

impl WireDtype {
    /// Wire bytes for `n` elements.
    pub fn wire_bytes(&self, n: usize) -> usize {
        match self {
            WireDtype::F32 => 4 * n,
            WireDtype::Bf16 => 2 * n,
            WireDtype::Int8Block => n + 4 * n.div_ceil(QBLOCK),
        }
    }

    /// Volume reduction factor vs f32.
    pub fn compression(&self, n: usize) -> f64 {
        (4 * n) as f64 / self.wire_bytes(n) as f64
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "f32" | "fp32" => Some(WireDtype::F32),
            "bf16" => Some(WireDtype::Bf16),
            "int8" | "i8" => Some(WireDtype::Int8Block),
            _ => None,
        }
    }
}

impl std::fmt::Display for WireDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            WireDtype::F32 => "f32",
            WireDtype::Bf16 => "bf16",
            WireDtype::Int8Block => "int8",
        })
    }
}

/// Encode `src` into wire bytes.
pub fn encode(src: &[f32], dtype: WireDtype) -> Vec<u8> {
    match dtype {
        WireDtype::F32 => {
            // Hot path (§Perf): one memcpy. f32 is IEEE-754 and the wire
            // format is little-endian; on the LE targets we support this
            // is a byte-identical reinterpretation.
            let mut out = vec![0u8; 4 * src.len()];
            // SAFETY: u8 has no alignment requirements; lengths match.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    src.as_ptr() as *const u8,
                    out.as_mut_ptr(),
                    4 * src.len(),
                );
            }
            out
        }
        WireDtype::Bf16 => {
            let mut out = Vec::with_capacity(2 * src.len());
            for v in src {
                out.extend_from_slice(&f32_to_bf16_bits(*v).to_le_bytes());
            }
            out
        }
        WireDtype::Int8Block => {
            let nblk = src.len().div_ceil(QBLOCK);
            let mut out = vec![0u8; 4 * nblk + src.len()];
            let (scale_bytes, payload) = out.split_at_mut(4 * nblk);
            for (bi, blk) in src.chunks(QBLOCK).enumerate() {
                let absmax = blk.iter().fold(0f32, |a, v| a.max(v.abs()));
                let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
                scale_bytes[4 * bi..4 * bi + 4].copy_from_slice(&scale.to_le_bytes());
                let inv = 1.0 / scale; // mul beats div in the inner loop
                let base = bi * QBLOCK;
                for (j, v) in blk.iter().enumerate() {
                    let q = (v * inv).round().clamp(-127.0, 127.0) as i8;
                    payload[base + j] = q as u8;
                }
            }
            out
        }
    }
}

/// Decode wire bytes to f32 (allocating).
pub fn decode(bytes: &[u8], n: usize, dtype: WireDtype) -> Vec<f32> {
    let mut out = vec![0f32; n];
    decode_into(bytes, &mut out, dtype, None);
    out
}

/// Decode wire bytes into `dst`, optionally reducing with `op` (None →
/// overwrite). This is the single hot decode path the executor uses.
pub fn decode_into(bytes: &[u8], dst: &mut [f32], dtype: WireDtype, op: Option<ReduceOp>) {
    let n = dst.len();
    assert_eq!(bytes.len(), dtype.wire_bytes(n), "wire size mismatch");
    match dtype {
        WireDtype::F32 => match op {
            // Overwrite: single memcpy (see encode).
            None => unsafe {
                std::ptr::copy_nonoverlapping(
                    bytes.as_ptr(),
                    dst.as_mut_ptr() as *mut u8,
                    4 * n,
                );
            },
            Some(ReduceOp::Sum) => {
                // Autovectorizable sum-reduce over exact 4-byte chunks.
                for (d, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
                    *d += f32::from_le_bytes(c.try_into().unwrap());
                }
            }
            Some(o) => {
                for (d, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
                    *d = o.apply(*d, f32::from_le_bytes(c.try_into().unwrap()));
                }
            }
        },
        WireDtype::Bf16 => {
            for (i, d) in dst.iter_mut().enumerate() {
                let v = bf16_bits_to_f32(u16::from_le_bytes(
                    bytes[2 * i..2 * i + 2].try_into().unwrap(),
                ));
                *d = match op {
                    Some(o) => o.apply(*d, v),
                    None => v,
                };
            }
        }
        WireDtype::Int8Block => {
            let nblk = n.div_ceil(QBLOCK);
            let (scale_bytes, q) = bytes.split_at(4 * nblk);
            // Block-wise: hoist the scale load out of the inner loop.
            for (blk, (dblk, qblk)) in dst.chunks_mut(QBLOCK).zip(q.chunks(QBLOCK)).enumerate() {
                let s = f32::from_le_bytes(
                    scale_bytes[4 * blk..4 * blk + 4].try_into().unwrap(),
                );
                match op {
                    None => {
                        for (d, qi) in dblk.iter_mut().zip(qblk) {
                            *d = (*qi as i8) as f32 * s;
                        }
                    }
                    Some(ReduceOp::Sum) => {
                        for (d, qi) in dblk.iter_mut().zip(qblk) {
                            *d += (*qi as i8) as f32 * s;
                        }
                    }
                    Some(o) => {
                        for (d, qi) in dblk.iter_mut().zip(qblk) {
                            *d = o.apply(*d, (*qi as i8) as f32 * s);
                        }
                    }
                }
            }
        }
    }
}

/// Worst-case absolute round-trip error for a slice under a wire dtype
/// (used by tests and by the trainer's quantization guard).
pub fn max_roundtrip_error(src: &[f32], dtype: WireDtype) -> f32 {
    match dtype {
        WireDtype::F32 => 0.0,
        WireDtype::Bf16 => src
            .iter()
            .map(|v| (crate::util::bf16::bf16_roundtrip(*v) - v).abs())
            .fold(0.0, f32::max),
        WireDtype::Int8Block => src
            .chunks(QBLOCK)
            .map(|blk| {
                let absmax = blk.iter().fold(0f32, |a, v| a.max(v.abs()));
                absmax / 127.0 * 0.5 + f32::EPSILON * absmax
            })
            .fold(0.0, f32::max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 2654435761) % 1000) as f32 / 250.0 - 2.0).collect()
    }

    #[test]
    fn f32_roundtrip_exact() {
        let x = data(1000);
        let deq = decode(&encode(&x, WireDtype::F32), 1000, WireDtype::F32);
        assert_eq!(x, deq);
    }

    #[test]
    fn bf16_roundtrip_error_bounded() {
        let x = data(1000);
        let deq = decode(&encode(&x, WireDtype::Bf16), 1000, WireDtype::Bf16);
        for (a, b) in x.iter().zip(&deq) {
            // bf16 has 8 mantissa bits -> rel err <= 2^-8.
            assert!((a - b).abs() <= a.abs() / 128.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn int8_roundtrip_error_bounded() {
        let x = data(QBLOCK * 3 + 17); // non-multiple tail block
        let deq = decode(&encode(&x, WireDtype::Int8Block), x.len(), WireDtype::Int8Block);
        let bound = max_roundtrip_error(&x, WireDtype::Int8Block);
        for (i, (a, b)) in x.iter().zip(&deq).enumerate() {
            assert!((a - b).abs() <= bound + 1e-6, "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn int8_wire_size_and_compression() {
        let n = 4096;
        assert_eq!(WireDtype::Int8Block.wire_bytes(n), n + 4 * (n / QBLOCK));
        assert!(WireDtype::Int8Block.compression(n) > 3.9);
        assert_eq!(WireDtype::Bf16.compression(n), 2.0);
        assert_eq!(WireDtype::F32.compression(n), 1.0);
    }

    #[test]
    fn decode_with_sum_reduces() {
        let x = data(512);
        let wire = encode(&x, WireDtype::F32);
        let mut acc = x.clone();
        decode_into(&wire, &mut acc, WireDtype::F32, Some(ReduceOp::Sum));
        for (a, b) in acc.iter().zip(&x) {
            assert_eq!(*a, 2.0 * b);
        }
    }

    #[test]
    fn zero_block_is_stable() {
        let x = vec![0f32; QBLOCK * 2];
        let deq = decode(&encode(&x, WireDtype::Int8Block), x.len(), WireDtype::Int8Block);
        assert_eq!(x, deq);
    }

    #[test]
    fn max_and_min_ops() {
        assert_eq!(ReduceOp::Max.apply(1.0, 2.0), 2.0);
        assert_eq!(ReduceOp::Min.apply(1.0, 2.0), 1.0);
        assert_eq!(ReduceOp::Sum.apply(1.0, 2.0), 3.0);
    }
}

//! Tiny flag parser (offline replacement for `clap`): `--key value` /
//! `--key=value` / boolean `--flag`, with positional args and typed
//! accessors carrying defaults.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit arg list (no argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map_or(false, |n| !n.starts_with("--"))
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Copy without one flag (used when a list-valued flag shadows a
    /// scalar one, e.g. `--nodes 1,2,4` for a sweep).
    pub fn without(&self, key: &str) -> Args {
        let mut a = self.clone();
        a.flags.remove(key);
        a
    }

    /// Copy with a flag overridden.
    pub fn with(&self, key: &str, value: &str) -> Args {
        let mut a = self.clone();
        a.flags.insert(key.to_string(), value.to_string());
        a
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list of usizes (e.g. `--nodes 1,2,4,8`).
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{key}: bad int {s:?}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_forms() {
        let a = parse("train --preset small --nodes=8 --verbose --lr 0.05");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("preset"), Some("small"));
        assert_eq!(a.usize_or("nodes", 1), 8);
        assert!(a.bool("verbose"));
        assert!((a.f64_or("lr", 0.0) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("simulate");
        assert_eq!(a.str_or("topo", "eth10g"), "eth10g");
        assert_eq!(a.usize_or("nodes", 16), 16);
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn lists() {
        let a = parse("--nodes 1,2,4,256");
        assert_eq!(a.usize_list_or("nodes", &[]), vec![1, 2, 4, 256]);
        let b = parse("");
        assert_eq!(b.usize_list_or("nodes", &[64]), vec![64]);
    }

    #[test]
    fn boolean_flag_before_positional() {
        // `--flag positional` consumes the positional as a value: callers
        // must use `--flag=true`; documented quirk, asserted here.
        let a = parse("--dry run");
        assert_eq!(a.get("dry"), Some("run"));
    }
}

//! The framework role: a per-layer fwd/bwd training iteration timeline
//! driving MLSL communication over the discrete-event fabric.
//!
//! Three communication modes reproduce the paper's comparison points:
//!
//! * [`CommMode::MlslAsync`] — MLSL: dedicated comm cores give
//!   asynchronous progress (overlap), gradients carry per-layer
//!   priorities, urgent ops preempt bulk ones at the NIC.
//! * [`CommMode::MpiNonBlocking`] — plain MPI non-blocking collectives:
//!   same issue order but NO async progress (the wire only moves while
//!   the host is inside the library, i.e. while the node is NOT
//!   computing) and no priorities. This is what the paper means by "MPI
//!   interface and implementations do not support prioritizing such
//!   messages".
//! * [`CommMode::BulkSync`] — out-of-box Horovod-MPI: one bulk gradient
//!   exchange after the whole backward pass, fully exposed.
//!
//! Nodes are symmetric (same model, same batch) so they proceed in
//! lockstep; collectives are posted when every member has reached the
//! issue point (exact under symmetry).

pub mod report;

pub use report::Report;

use std::collections::HashMap;

use crate::collectives::program::{build, CollectiveKind};
use crate::collectives::simexec::SimCollectives;
use crate::collectives::{PriorityPolicy, WireDtype};
use crate::fabric::topology::{NodeSpec, Topology};
use crate::fabric::{NetSim, SimEvent};
use crate::metrics::Timeline;
use crate::mlsl::Distribution;
use crate::models::ModelDesc;
use crate::tuner::SelectionPolicy;
use crate::{Ns, Priority, Rank};

/// Communication runtime mode (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommMode {
    MlslAsync { comm_cores: usize },
    MpiNonBlocking,
    BulkSync,
}

impl CommMode {
    pub fn by_name(name: &str) -> Option<CommMode> {
        match name {
            "mlsl" => Some(CommMode::MlslAsync { comm_cores: 2 }),
            "mpi" => Some(CommMode::MpiNonBlocking),
            "bulk" | "horovod-oob" => Some(CommMode::BulkSync),
            _ => None,
        }
    }
}

/// Simulated-training configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub model: ModelDesc,
    pub topo: Topology,
    pub node: NodeSpec,
    pub dist: Distribution,
    /// Per-node mini-batch.
    pub batch: usize,
    pub mode: CommMode,
    pub policy: PriorityPolicy,
    /// Who picks collective algorithms: the analytic model (default) or a
    /// measured tuning table (`--tuning-table`).
    pub selection: SelectionPolicy,
    pub wire: WireDtype,
    /// Measured iterations (one extra warmup iteration is always run).
    pub iterations: usize,
    pub record_timeline: bool,
    /// Per-(node, layer, iteration) compute jitter: relative std-dev of a
    /// deterministic log-normal-ish perturbation. Real clusters have
    /// stragglers (OS noise, memory layout, thermal); every
    /// allreduce synchronizes the stragglers away from ideal, which is
    /// the dominant sub-100% term in weak scaling at large node counts.
    /// 0.0 = perfectly balanced (unit tests); the Fig. 2 bench uses 0.03.
    pub jitter: f64,
}

impl EngineConfig {
    pub fn new(model: ModelDesc, topo: Topology, p: usize) -> Self {
        Self {
            model,
            topo,
            node: NodeSpec::skylake_6148(),
            dist: Distribution::data_parallel(p),
            batch: 32,
            mode: CommMode::MlslAsync { comm_cores: 2 },
            policy: PriorityPolicy::ByLayer,
            selection: SelectionPolicy::Analytic,
            wire: WireDtype::F32,
            iterations: 3,
            record_timeline: false,
            jitter: 0.0,
        }
    }

    fn comm_cores(&self) -> usize {
        match self.mode {
            CommMode::MlslAsync { comm_cores } => comm_cores,
            _ => 0,
        }
    }

    fn gated(&self) -> bool {
        matches!(self.mode, CommMode::MpiNonBlocking)
    }

    /// Pure compute ns per iteration per node. Sums the SAME per-layer
    /// quantized durations the engine schedules, so `iter_ns −
    /// compute_ns_per_iter()` is exactly the exposed communication.
    /// Per-node compute is independent of the group size: a group of g
    /// nodes jointly processes g·batch samples (see analytic::compute_flops).
    pub fn compute_ns_per_iter(&self) -> Ns {
        let cc = self.comm_cores();
        self.model
            .layers
            .iter()
            .map(|l| {
                let fwd = self.node.compute_ns(l.fwd_flops * self.batch as f64, cc).max(1);
                let bwd = self.node.compute_ns(l.bwd_flops() * self.batch as f64, cc).max(1);
                fwd + bwd
            })
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Per-node schedule state machine
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum NodePhase {
    /// Waiting for layer `l`'s dependencies before its forward compute.
    FwdWait(usize),
    FwdCompute(usize),
    /// Waiting on the within-group activation allgather after fwd(l).
    FwdAct(usize),
    BwdCompute(usize),
    /// Waiting on the within-group activation-grad exchange after bwd(l).
    BwdAct(usize),
    /// BulkSync: waiting for the post-backward gradient exchange.
    BulkWait,
    Done,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum CommKind {
    Grad { layer: usize },
    FwdAct { layer: usize },
    BwdAct { layer: usize },
}

struct CommMeta {
    kind: CommKind,
    /// Nodes that still have to reach the issue point.
    waiting: Vec<Rank>,
    members: Vec<Rank>,
    elems: usize,
    priority: Priority,
    /// Members whose completion has not been observed yet; the meta is
    /// garbage-collected when this reaches zero.
    remaining: usize,
}

struct NodeState {
    phase: NodePhase,
    iter: usize,
    /// Gradient allreduce completed (this iteration's set), per layer.
    grad_done: Vec<bool>,
    /// Outstanding gradient ops (BulkSync wait / paranoia check).
    grads_outstanding: usize,
    /// fwd(0) compute start times, one per iteration (incl. warmup).
    iter_starts: Vec<Ns>,
    compute_busy_ns: Ns,
}

/// Opaque compute tag encoding (phase, layer).
fn tag_of(phase: NodePhase) -> u64 {
    match phase {
        NodePhase::FwdCompute(l) => 1 << 32 | l as u64,
        NodePhase::BwdCompute(l) => 2 << 32 | l as u64,
        _ => unreachable!("only computes carry tags"),
    }
}

/// The simulated training run.
pub struct Engine {
    cfg: EngineConfig,
    sim: NetSim,
    colls: SimCollectives,
    nodes: Vec<NodeState>,
    metas: HashMap<u64, CommMeta>,
    /// (kind, issue-iteration) → coll id, so joiners find pending ops.
    open: HashMap<(CommKind, usize, usize), u64>, // (kind, iter, comm_group_key)
    next_id: u64,
    pub timeline: Timeline,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Self {
        let p = cfg.dist.world();
        let nl = cfg.model.layers.len();
        let sim = NetSim::new(cfg.topo.clone(), p);
        let nodes = (0..p)
            .map(|_| NodeState {
                phase: NodePhase::FwdWait(0),
                iter: 0,
                grad_done: vec![true; nl], // iteration 0 has no prior grads
                grads_outstanding: 0,
                iter_starts: Vec::new(),
                compute_busy_ns: 0,
            })
            .collect();
        Self {
            cfg,
            sim,
            colls: SimCollectives::new(),
            nodes,
            metas: HashMap::new(),
            open: HashMap::new(),
            next_id: 1,
            timeline: Timeline::new(),
        }
    }

    /// Run the configured number of iterations; produce the report.
    pub fn run(mut self) -> Report {
        self.run_to_completion()
    }

    /// [`Engine::run`] on a borrowed engine (tests inspect post-run
    /// bookkeeping, e.g. that `metas` was garbage-collected).
    fn run_to_completion(&mut self) -> Report {
        let p = self.cfg.dist.world();
        let total_iters = self.cfg.iterations + 1; // + warmup
        for n in 0..p {
            self.try_advance(n);
        }
        // Event loop. One scratch completion buffer serves the whole
        // run — on_event_into appends into it instead of allocating a
        // fresh Vec per delivered message (this loop is the L3 hot path).
        let mut completions: Vec<crate::collectives::simexec::Completion> = Vec::new();
        while self.nodes.iter().any(|n| n.phase != NodePhase::Done) {
            let Some(ev) = self.sim.next() else {
                panic!(
                    "simulation deadlock: phases={:?}",
                    self.nodes.iter().map(|n| (n.iter, n.phase)).collect::<Vec<_>>()
                );
            };
            match ev {
                SimEvent::ComputeDone { node, tag, at } => {
                    self.on_compute_done(node, tag, at, total_iters);
                }
                ev => {
                    completions.clear();
                    self.colls.on_event_into(&mut self.sim, &ev, &mut completions);
                    for c in completions.drain(..) {
                        self.on_comm_done(c.coll_id, c.rank);
                    }
                }
            }
        }
        // Drain trailing collectives (the last iteration's gradient
        // exchanges) so traffic accounting is policy-independent.
        while self.colls.in_flight() > 0 {
            let Some(ev) = self.sim.next() else { break };
            completions.clear();
            self.colls.on_event_into(&mut self.sim, &ev, &mut completions);
            for c in completions.drain(..) {
                self.on_comm_done(c.coll_id, c.rank);
            }
        }
        let timeline = std::mem::replace(&mut self.timeline, Timeline::new());
        let iter_starts: Vec<Vec<Ns>> =
            self.nodes.iter().map(|n| n.iter_starts.clone()).collect();
        report::build_report(&self.cfg, &self.sim, &iter_starts, timeline)
    }

    // -- state machine ------------------------------------------------------

    fn layer_count(&self) -> usize {
        self.cfg.model.layers.len()
    }

    /// Compute duration of layer `l` in direction fwd/bwd for one node,
    /// with the node/iteration-specific straggler perturbation.
    fn compute_ns_for(&self, n: Rank, iter: usize, l: usize, fwd: bool) -> Ns {
        let layer = &self.cfg.model.layers[l];
        let flops = if fwd { layer.fwd_flops } else { layer.bwd_flops() };
        let flops = flops * self.cfg.batch as f64;
        let base = self.cfg.node.compute_ns(flops, self.cfg.comm_cores()).max(1);
        if self.cfg.jitter <= 0.0 {
            return base;
        }
        // Deterministic per-(node, iter) normal perturbation. Straggler
        // noise is CORRELATED within an iteration (OS jitter, turbo,
        // memory placement last milliseconds, not microseconds), so the
        // draw is per node-iteration and applied to every layer in it —
        // per-layer-independent noise would average out over the ~160
        // layers and understate the synchronization cost.
        let seed = (n as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((iter as u64) << 24);
        let _ = l;
        let z = crate::util::prng::Prng::seed(seed).normal();
        let factor = (1.0 + self.cfg.jitter * z).max(0.5);
        ((base as f64 * factor).round() as Ns).max(1)
    }

    /// Try to move node `n` forward through waits; start computes.
    fn try_advance(&mut self, n: Rank) {
        loop {
            match self.nodes[n].phase {
                NodePhase::FwdWait(l) => {
                    if l >= self.layer_count() {
                        // Forward done; begin backward.
                        self.nodes[n].phase = NodePhase::BwdCompute(self.layer_count() - 1);
                        continue;
                    }
                    if !self.nodes[n].grad_done[l] {
                        return; // blocked on last iteration's gradient
                    }
                    if l == 0 {
                        let now = self.sim.now();
                        self.nodes[n].iter_starts.push(now);
                    }
                    self.nodes[n].phase = NodePhase::FwdCompute(l);
                    self.start_compute(n, NodePhase::FwdCompute(l));
                    return;
                }
                NodePhase::BwdCompute(l) => {
                    self.start_compute(n, NodePhase::BwdCompute(l));
                    return;
                }
                NodePhase::FwdAct(_) | NodePhase::BwdAct(_) | NodePhase::BulkWait => return,
                NodePhase::FwdCompute(_) => return, // compute in flight
                NodePhase::Done => return,
            }
        }
    }

    fn start_compute(&mut self, n: Rank, phase: NodePhase) {
        let (l, fwd) = match phase {
            NodePhase::FwdCompute(l) => (l, true),
            NodePhase::BwdCompute(l) => (l, false),
            _ => unreachable!(),
        };
        let dur = self.compute_ns_for(n, self.nodes[n].iter, l, fwd);
        self.nodes[n].compute_busy_ns += dur;
        if self.cfg.gated() {
            self.sim.set_comm_gated(n, true);
        }
        if self.cfg.record_timeline && n == 0 {
            let now = self.sim.now();
            let dir = if fwd { "f" } else { "b" };
            self.timeline.record(n, now, now + dur, "compute", &format!("{dir}{l}"));
        }
        self.sim.compute(n, dur, tag_of(phase));
    }

    fn on_compute_done(&mut self, n: Rank, tag: u64, _at: Ns, total_iters: usize) {
        if self.cfg.gated() {
            self.sim.set_comm_gated(n, false);
        }
        let l = (tag & 0xFFFF_FFFF) as usize;
        let is_fwd = tag >> 32 == 1;
        if is_fwd {
            debug_assert_eq!(self.nodes[n].phase, NodePhase::FwdCompute(l));
            // Within-group activation exchange (hybrid/model parallel).
            if self.issue_act(n, l, true) {
                self.nodes[n].phase = NodePhase::FwdAct(l);
            } else {
                self.nodes[n].phase = NodePhase::FwdWait(l + 1);
                self.try_advance(n);
            }
        } else {
            debug_assert_eq!(self.nodes[n].phase, NodePhase::BwdCompute(l));
            // Gradient exchange for this layer.
            if self.cfg.model.layers[l].has_weights() && self.cfg.dist.num_groups() > 1 {
                match self.cfg.mode {
                    CommMode::BulkSync => {} // deferred to end of backward
                    _ => self.issue_grad(n, l),
                }
            }
            if self.issue_act(n, l, false) {
                self.nodes[n].phase = NodePhase::BwdAct(l);
            } else {
                self.after_bwd_step(n, l, total_iters);
            }
        }
    }

    fn after_bwd_step(&mut self, n: Rank, l: usize, total_iters: usize) {
        if l > 0 {
            self.nodes[n].phase = NodePhase::BwdCompute(l - 1);
            self.try_advance(n);
            return;
        }
        // Backward finished.
        if matches!(self.cfg.mode, CommMode::BulkSync) && self.cfg.dist.num_groups() > 1 {
            // Issue ALL gradients now, FIFO, flat priority (Horovod-oob).
            let layers: Vec<usize> = (0..self.layer_count())
                .rev() // completion order of backprop
                .filter(|l| self.cfg.model.layers[*l].has_weights())
                .collect();
            for l in layers {
                self.issue_grad(n, l);
            }
            if self.nodes[n].grads_outstanding > 0 {
                self.nodes[n].phase = NodePhase::BulkWait;
                return;
            }
        }
        self.finish_iteration(n, total_iters);
    }

    fn finish_iteration(&mut self, n: Rank, total_iters: usize) {
        let node = &mut self.nodes[n];
        node.iter += 1;
        if node.iter >= total_iters {
            node.phase = NodePhase::Done;
            return;
        }
        node.phase = NodePhase::FwdWait(0);
        self.try_advance(n);
    }

    // -- communication issue points ------------------------------------------

    /// Issue (or join) the gradient allreduce for layer `l`. Non-blocking:
    /// completion flips `grad_done[l]` consumed by the NEXT iteration's
    /// forward pass.
    fn issue_grad(&mut self, n: Rank, l: usize) {
        let iter = self.nodes[n].iter;
        self.nodes[n].grad_done[l] = false;
        self.nodes[n].grads_outstanding += 1;
        let members = self.cfg.dist.data_peers(n);
        let group_key = self.cfg.dist.rank_in_group(n);
        let elems = self.cfg.model.layers[l].weight_elems.div_ceil(self.cfg.dist.group_size());
        let priority = match self.cfg.mode {
            CommMode::MlslAsync { .. } => {
                self.cfg.policy.assign(l, self.layer_count())
            }
            _ => 128,
        };
        self.join_or_post(CommKind::Grad { layer: l }, iter, group_key, n, members, elems, priority);
    }

    /// Issue (or join) the within-group activation exchange after layer
    /// `l`; returns false when none is needed.
    fn issue_act(&mut self, n: Rank, l: usize, fwd: bool) -> bool {
        let g = self.cfg.dist.group_size();
        if g <= 1 || self.cfg.model.layers[l].out_act_elems == 0 {
            return false;
        }
        let iter = self.nodes[n].iter;
        let members = self.cfg.dist.group_members(n);
        let group_key = self.cfg.dist.group_of(n);
        // The group jointly holds g·batch samples of activations; the ring
        // allgather makes every member hold the group batch.
        let elems = self.cfg.model.layers[l].out_act_elems * self.cfg.batch * g;
        let kind = if fwd { CommKind::FwdAct { layer: l } } else { CommKind::BwdAct { layer: l } };
        // "activation communication must be prioritized": class 0.
        self.join_or_post(kind, iter, group_key, n, members, elems, 0);
        true
    }

    /// Join a pending collective or create it; post to the fabric once the
    /// last member joins.
    #[allow(clippy::too_many_arguments)]
    fn join_or_post(
        &mut self,
        kind: CommKind,
        iter: usize,
        group_key: usize,
        n: Rank,
        members: Vec<Rank>,
        elems: usize,
        priority: Priority,
    ) {
        if members.len() <= 1 {
            // Degenerate communicator: instantly complete.
            self.complete_comm_for(kind, n);
            return;
        }
        let key = (kind, iter, group_key);
        let id = *self.open.entry(key).or_insert_with(|| {
            let id = self.next_id;
            self.next_id += 1;
            self.metas.insert(
                id,
                CommMeta {
                    kind,
                    waiting: members.clone(),
                    members: members.clone(),
                    elems,
                    priority,
                    remaining: members.len(),
                },
            );
            id
        });
        let meta = self.metas.get_mut(&id).expect("meta exists");
        meta.waiting.retain(|r| *r != n);
        if meta.waiting.is_empty() {
            self.open.remove(&key);
            let members = meta.members.clone();
            let (elems, priority, kind) = (meta.elems, meta.priority, meta.kind);
            let pm = members.len();
            let ckind = match kind {
                CommKind::Grad { .. } => CollectiveKind::Allreduce,
                _ => CollectiveKind::Allgather,
            };
            // Hierarchical programs (and tier-discounted pricing) assume
            // program-rank groups map onto physical tier groups, AT EVERY
            // LEVEL the algorithm exploits. Gate per level: the chooser
            // sees the topology truncated to the leading tiers the member
            // set either tiles exactly or fits wholly inside
            // (`chooser_tier_depth`) — a tier the members straddle
            // without tiling would let the cost model bill straddling
            // hops at an inner tier they never ride. Fully aligned sets
            // (e.g. the world under pure data parallelism) keep the whole
            // stack; strided hybrid communicators (aligned depth 0) get
            // the flat all-top choice. Either way, the configured
            // selection policy (analytic model or measured tuning table)
            // decides.
            let bytes = (4 * elems) as u64;
            let depth = self.cfg.topo.aligned_tier_depth(&members);
            let usable = self.cfg.topo.chooser_tier_depth(&members);
            let restricted;
            let choose_topo = if usable >= self.cfg.topo.tiers.len() {
                &self.cfg.topo
            } else {
                restricted = self.cfg.topo.restrict_tiers(usable);
                &restricted
            };
            let alg = match (ckind, depth > 0) {
                (CollectiveKind::Allreduce, true) => {
                    self.cfg.selection.choose_allreduce(choose_topo, pm, bytes)
                }
                (CollectiveKind::Allreduce, false) => {
                    self.cfg.selection.choose_flat_allreduce(&self.cfg.topo, pm, bytes)
                }
                (_, true) => self.cfg.selection.choose_allgather(choose_topo, pm, bytes),
                (_, false) => {
                    self.cfg.selection.choose_flat_allgather(&self.cfg.topo, pm, bytes)
                }
            };
            let programs = build(ckind, alg, pm, elems)
                .expect("selection policies only return buildable algorithms");
            if self.cfg.record_timeline && members.contains(&0) {
                let now = self.sim.now();
                let label = match kind {
                    CommKind::Grad { layer } => format!("g{layer}"),
                    CommKind::FwdAct { layer } => format!("a{layer}"),
                    CommKind::BwdAct { layer } => format!("x{layer}"),
                };
                self.timeline.record(0, now, now, "issue", &label);
            }
            let completions = self.colls.post_mapped(
                &mut self.sim,
                id,
                programs,
                members,
                self.cfg.wire,
                priority,
            );
            for c in completions {
                self.on_comm_done(c.coll_id, c.rank);
            }
        }
    }

    fn on_comm_done(&mut self, coll_id: u64, node: Rank) {
        let meta = self.metas.get_mut(&coll_id).expect("known collective");
        let kind = meta.kind;
        meta.remaining = meta.remaining.saturating_sub(1);
        if meta.remaining == 0 {
            // Every member completed (the collective left simexec): GC the
            // meta so `metas` stays bounded across iterations.
            self.metas.remove(&coll_id);
        }
        self.complete_comm_for(kind, node);
    }

    fn complete_comm_for(&mut self, kind: CommKind, node: Rank) {
        match kind {
            CommKind::Grad { layer } => {
                self.nodes[node].grad_done[layer] = true;
                self.nodes[node].grads_outstanding =
                    self.nodes[node].grads_outstanding.saturating_sub(1);
                match self.nodes[node].phase {
                    NodePhase::FwdWait(l) if l == layer => self.try_advance(node),
                    NodePhase::BulkWait if self.nodes[node].grads_outstanding == 0 => {
                        let total = self.total_iters();
                        self.finish_iteration(node, total);
                    }
                    _ => {}
                }
            }
            CommKind::FwdAct { layer } => {
                debug_assert_eq!(self.nodes[node].phase, NodePhase::FwdAct(layer));
                self.nodes[node].phase = NodePhase::FwdWait(layer + 1);
                self.try_advance(node);
            }
            CommKind::BwdAct { layer } => {
                debug_assert_eq!(self.nodes[node].phase, NodePhase::BwdAct(layer));
                let total = self.total_iters();
                self.after_bwd_step(node, layer, total);
            }
        }
    }

    fn total_iters(&self) -> usize {
        self.cfg.iterations + 1
    }
}

/// Convenience: configure + run.
pub fn simulate(cfg: EngineConfig) -> Report {
    Engine::new(cfg).run()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(model: &str, p: usize, mode: CommMode) -> EngineConfig {
        let mut c = EngineConfig::new(
            ModelDesc::by_name(model).unwrap(),
            Topology::omnipath_100g(),
            p,
        );
        c.mode = mode;
        c
    }

    #[test]
    fn single_node_has_no_comm() {
        let r = simulate(cfg("resnet50", 1, CommMode::BulkSync));
        assert_eq!(r.exposed_comm_ns, 0);
        assert!(r.iter_ns > 0);
    }

    #[test]
    fn iteration_time_close_to_compute_on_fast_fabric() {
        let r = simulate(cfg("resnet50", 8, CommMode::MlslAsync { comm_cores: 2 }));
        // Omnipath + overlap: exposed comm well under 20% of compute.
        assert!(
            (r.exposed_comm_ns as f64) < 0.25 * r.compute_ns as f64,
            "exposed={} compute={}",
            r.exposed_comm_ns,
            r.compute_ns
        );
    }

    #[test]
    fn bulk_sync_exposes_all_comm() {
        let m = simulate(cfg("resnet50", 8, CommMode::MlslAsync { comm_cores: 2 }));
        let b = simulate(cfg("resnet50", 8, CommMode::BulkSync));
        assert!(
            b.exposed_comm_ns > 2 * m.exposed_comm_ns.max(1),
            "bulk={} mlsl={}",
            b.exposed_comm_ns,
            m.exposed_comm_ns
        );
        assert!(b.iter_ns > m.iter_ns);
    }

    #[test]
    fn mpi_slower_than_mlsl_on_ethernet() {
        let mut a = cfg("resnet50", 8, CommMode::MlslAsync { comm_cores: 2 });
        a.topo = Topology::eth_10g();
        let mut b = cfg("resnet50", 8, CommMode::MpiNonBlocking);
        b.topo = Topology::eth_10g();
        let ra = simulate(a);
        let rb = simulate(b);
        assert!(rb.iter_ns > ra.iter_ns, "mpi={} mlsl={}", rb.iter_ns, ra.iter_ns);
    }

    #[test]
    fn priority_beats_fifo_on_ethernet() {
        let mut with = cfg("vgg16", 8, CommMode::MlslAsync { comm_cores: 2 });
        with.topo = Topology::eth_10g();
        with.policy = PriorityPolicy::ByLayer;
        let mut without = with.clone();
        without.policy = PriorityPolicy::None;
        let rw = simulate(with);
        let ro = simulate(without);
        assert!(
            rw.exposed_comm_ns < ro.exposed_comm_ns,
            "bylayer={} fifo={}",
            rw.exposed_comm_ns,
            ro.exposed_comm_ns
        );
    }

    #[test]
    fn hybrid_runs_with_same_per_node_compute() {
        let mut c = cfg("vgg16", 8, CommMode::MlslAsync { comm_cores: 2 });
        c.dist = Distribution::new(8, 4);
        c.iterations = 2;
        let r = simulate(c);
        assert!(r.iter_ns > 0);
        // The group jointly processes g·batch samples: per-node compute is
        // unchanged vs pure data parallelism.
        let d = cfg("vgg16", 8, CommMode::MlslAsync { comm_cores: 2 });
        let rd = simulate(d);
        assert_eq!(r.compute_ns, rd.compute_ns);
        // But its iteration carries activation exchanges too.
        assert!(r.iter_ns >= rd.compute_ns);
    }

    #[test]
    fn weak_scaling_efficiency_definition() {
        let r1 = simulate(cfg("resnet50", 1, CommMode::MlslAsync { comm_cores: 2 }));
        let r64 = simulate(cfg("resnet50", 64, CommMode::MlslAsync { comm_cores: 2 }));
        let eff = r1.iter_ns as f64 / r64.iter_ns as f64;
        assert!(eff > 0.5 && eff <= 1.001, "{eff}");
    }

    #[test]
    fn two_tier_topology_reduces_comm_exposure() {
        // Same 16 ranks, bulk-sync (fully exposed comm). Re-describing the
        // fabric as 2 ranks/node keeps every inter-node parameter identical
        // but lets intra-node hops ride shared memory and the selector use
        // hierarchical allreduce — the iteration must get faster.
        let mut flat = cfg("resnet50", 16, CommMode::BulkSync);
        flat.topo = Topology::eth_10g();
        let mut smp = cfg("resnet50", 16, CommMode::BulkSync);
        smp.topo = Topology::eth_10g_smp(2);
        let rf = simulate(flat);
        let rs = simulate(smp);
        assert!(
            rs.iter_ns < rf.iter_ns,
            "smp={} flat={}",
            rs.iter_ns,
            rf.iter_ns
        );
    }

    #[test]
    fn three_level_topology_runs_and_beats_flat() {
        // 16 ranks described as 2/node × 4 nodes/rack (rack = 8): the
        // engine must gate hierarchical on alignment at every level and
        // still beat the flat description of the same NIC.
        let mut flat = cfg("resnet50", 16, CommMode::BulkSync);
        flat.topo = Topology::eth_10g();
        flat.iterations = 1;
        let mut tiered = cfg("resnet50", 16, CommMode::BulkSync);
        tiered.topo = Topology::by_name("eth10g-x2r4").unwrap();
        // Undo the rack preset's spine oversubscription so the comparison
        // isolates the hierarchy (same top physics as the flat preset).
        tiered.topo.link_gbps = flat.topo.link_gbps;
        tiered.topo.latency_ns = flat.topo.latency_ns;
        tiered.iterations = 1;
        let rf = simulate(flat);
        let rt = simulate(tiered);
        assert!(rt.iter_ns < rf.iter_ns, "tiered={} flat={}", rt.iter_ns, rf.iter_ns);
    }

    #[test]
    fn hybrid_on_three_level_topology_gates_per_level() {
        // Hybrid groups of 4 on a rack-of-8 fabric: in-group members are
        // node-aligned but too short for the rack tier, while the strided
        // cross-group communicators must take the flat path — the
        // per-level gate has to sort all of this out and complete.
        let mut c = cfg("vgg16", 16, CommMode::MlslAsync { comm_cores: 2 });
        c.topo = Topology::by_name("eth10g-x2r4").unwrap();
        c.dist = Distribution::new(16, 4);
        c.iterations = 1;
        let r = simulate(c);
        assert!(r.iter_ns > 0);
    }

    #[test]
    fn hybrid_on_smp_topology_completes() {
        // Strided data-parallel communicators are not node-aligned: the
        // engine must fall back to flat algorithms and still run.
        let mut c = cfg("vgg16", 8, CommMode::MlslAsync { comm_cores: 2 });
        c.topo = Topology::eth_10g_smp(2);
        c.dist = Distribution::new(8, 4);
        c.iterations = 2;
        let r = simulate(c);
        assert!(r.iter_ns > 0);
    }

    #[test]
    fn comm_metas_are_garbage_collected() {
        // Before the GC fix, `metas` grew by one entry per collective for
        // the whole run; now every completed collective drops its meta.
        let mut c = cfg("resnet50", 4, CommMode::MlslAsync { comm_cores: 2 });
        c.iterations = 3;
        let mut e = Engine::new(c);
        let r = e.run_to_completion();
        assert!(r.iter_ns > 0);
        assert!(e.metas.is_empty(), "{} metas leaked", e.metas.len());
        assert!(e.open.is_empty(), "{} open entries leaked", e.open.len());
    }

    #[test]
    fn tuned_selection_policy_runs_and_moves_same_traffic() {
        // Same run under the analytic and a measured-table policy: the
        // algorithms may differ, but the simulation completes and the
        // tuned run is a valid training iteration.
        let topo = Topology::eth_10g_smp(2);
        let mut analytic = cfg("resnet50", 8, CommMode::BulkSync);
        analytic.topo = topo.clone();
        analytic.iterations = 1;
        let mut tuned = analytic.clone();
        let mut spec = crate::tuner::ProbeSpec::quick();
        spec.max_ranks = 8;
        let table = crate::tuner::tune(&topo, &spec);
        tuned.selection = SelectionPolicy::TunedWithFallback(table);
        let ra = simulate(analytic);
        let rt = simulate(tuned);
        assert!(rt.iter_ns > 0);
        // Ring / halving-doubling / hierarchical allreduce all move the
        // same per-node volume; only rdoubling differs, and it only wins
        // tiny layers — total traffic stays within a few percent.
        let ratio = rt.bytes_per_node as f64 / ra.bytes_per_node.max(1) as f64;
        assert!(
            (0.8..1.25).contains(&ratio),
            "tuned={} analytic={}",
            rt.bytes_per_node,
            ra.bytes_per_node
        );
    }

    #[test]
    fn int8_wire_reduces_exposed_comm() {
        let mut f32c = cfg("vgg16", 8, CommMode::BulkSync);
        f32c.topo = Topology::eth_10g();
        let mut i8c = f32c.clone();
        i8c.wire = WireDtype::Int8Block;
        let rf = simulate(f32c);
        let ri = simulate(i8c);
        assert!(
            (rf.exposed_comm_ns as f64 / ri.exposed_comm_ns as f64) > 3.0,
            "f32={} int8={}",
            rf.exposed_comm_ns,
            ri.exposed_comm_ns
        );
    }
}

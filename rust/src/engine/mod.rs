//! The framework role: a per-layer fwd/bwd training iteration timeline
//! driving MLSL communication over the discrete-event fabric.
//!
//! Three communication modes reproduce the paper's comparison points:
//!
//! * [`CommMode::MlslAsync`] — MLSL: dedicated comm cores give
//!   asynchronous progress (overlap), gradients carry per-layer
//!   priorities, urgent ops preempt bulk ones at the NIC.
//! * [`CommMode::MpiNonBlocking`] — plain MPI non-blocking collectives:
//!   same issue order but NO async progress (the wire only moves while
//!   the host is inside the library, i.e. while the node is NOT
//!   computing) and no priorities. This is what the paper means by "MPI
//!   interface and implementations do not support prioritizing such
//!   messages".
//! * [`CommMode::BulkSync`] — out-of-box Horovod-MPI: one bulk gradient
//!   exchange after the whole backward pass, fully exposed.
//!
//! Nodes are symmetric (same model, same batch) so they proceed in
//! lockstep; collectives are posted when every member has reached the
//! issue point (exact under symmetry).
//!
//! # Why this loop is serial even under `--sim-threads`
//!
//! The iteration loop posts a collective at the instant its *last*
//! member reaches the issue point, and churn quiesce/release does the
//! same — one rank's event triggers sends on every rank with **zero**
//! simulated latency. Conservative-lookahead partitioning
//! ([`crate::collectives::parexec`]) requires strictly positive
//! lookahead on every cross-partition dependency, so these barriers
//! cannot be windowed without optimistic rollback. The engine therefore
//! always runs its exact serial event loop;
//! [`EngineConfig::sim_threads`] instead accelerates the barrier-free
//! simulation paths underneath (standalone collective timing and tuner
//! grid probing). The full argument is in `docs/ARCHITECTURE.md`
//! §"Partitioned mode".

pub mod report;
pub mod tenants;

pub use report::Report;
pub use tenants::{simulate_tenants, TenantSpec, TenantsReport};

use std::collections::HashMap;

use crate::collectives::program::{build, survivors, CollectiveKind};
use crate::collectives::simexec::SimCollectives;
use crate::collectives::{Algorithm, PriorityPolicy, WireDtype};
use crate::fabric::topology::{NodeSpec, Topology};
use crate::fabric::{BgPlan, ChaosPlan, NetSim, SimEvent, StragglerPlan, TENANT_TAG_SHIFT};
use crate::metrics::Timeline;
use crate::mlsl::Distribution;
use crate::trace::TraceEvent;
use crate::models::ModelDesc;
use crate::tuner::{Contention, SelectionPolicy};
use crate::{Ns, Priority, Rank};

/// Program-cache key: (kind, algorithm, wire, member count, elems).
type ProgKey = (CollectiveKind, Algorithm, WireDtype, usize, usize);

/// Communication runtime mode (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommMode {
    MlslAsync { comm_cores: usize },
    MpiNonBlocking,
    BulkSync,
}

impl CommMode {
    pub fn by_name(name: &str) -> Option<CommMode> {
        match name {
            "mlsl" => Some(CommMode::MlslAsync { comm_cores: 2 }),
            "mpi" => Some(CommMode::MpiNonBlocking),
            "bulk" | "horovod-oob" => Some(CommMode::BulkSync),
            _ => None,
        }
    }
}

/// One elastic-membership change, applied at an iteration boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnOp {
    /// The rank leaves the run. Survivors KEEP their rank ids and data
    /// partitions; subsequent communicators simply span fewer members.
    Leave(Rank),
    /// A previously-left rank rejoins at the boundary.
    Join(Rank),
}

/// A churn op plus the iteration after whose completion it applies
/// (0 = the warmup iteration).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChurnEvent {
    pub after_iter: usize,
    pub op: ChurnOp,
}

/// An ordered schedule of membership changes. The engine quiesces at the
/// first iteration boundary past each event (every active node parked,
/// no collective in flight, no partially-joined op), applies every event
/// due at that boundary, then releases the survivors — so membership
/// only ever changes between iterations, never mid-collective.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ChurnPlan {
    pub events: Vec<ChurnEvent>,
}

impl ChurnPlan {
    /// Parse the CLI grammar: `leave:<rank>@<iter>[,join:<rank>@<iter>...]`
    /// — e.g. `leave:3@1,join:3@3`. Events are sorted by iteration
    /// (stable, so same-boundary events keep their written order).
    pub fn parse(spec: &str) -> Result<ChurnPlan, String> {
        let mut events = Vec::new();
        for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let part = part.trim();
            let (op_name, rest) = part.split_once(':').ok_or_else(|| {
                format!("{part:?}: expected leave:<rank>@<iter> or join:<rank>@<iter>")
            })?;
            let (rank_s, iter_s) = rest
                .split_once('@')
                .ok_or_else(|| format!("{part:?}: missing @<iter>"))?;
            let rank: Rank = rank_s.parse().map_err(|_| format!("{part:?}: bad rank {rank_s:?}"))?;
            let after_iter: usize =
                iter_s.parse().map_err(|_| format!("{part:?}: bad iteration {iter_s:?}"))?;
            let op = match op_name {
                "leave" => ChurnOp::Leave(rank),
                "join" => ChurnOp::Join(rank),
                other => return Err(format!("{part:?}: unknown op {other:?} (leave|join)")),
            };
            events.push(ChurnEvent { after_iter, op });
        }
        if events.is_empty() {
            return Err("empty churn spec".into());
        }
        events.sort_by_key(|e| e.after_iter);
        Ok(ChurnPlan { events })
    }

    /// Replay the schedule against a `p`-rank world and reject anything
    /// the engine would have to panic on: out-of-range ranks, leaving a
    /// rank twice, joining a rank that never left, or leaving everyone.
    pub fn validate(&self, p: usize) -> Result<(), String> {
        let mut active = vec![true; p];
        for e in &self.events {
            let (r, what) = match e.op {
                ChurnOp::Leave(r) => (r, "leave"),
                ChurnOp::Join(r) => (r, "join"),
            };
            if r >= p {
                return Err(format!("{what}:{r}@{}: rank {r} out of range (p={p})", e.after_iter));
            }
            match e.op {
                ChurnOp::Leave(r) => {
                    if !active[r] {
                        return Err(format!("leave:{r}@{}: rank {r} already left", e.after_iter));
                    }
                    active[r] = false;
                }
                ChurnOp::Join(r) => {
                    if active[r] {
                        return Err(format!("join:{r}@{}: rank {r} never left", e.after_iter));
                    }
                    active[r] = true;
                }
            }
            if active.iter().all(|a| !a) {
                return Err(format!("after leave @{}: no survivors", e.after_iter));
            }
        }
        Ok(())
    }
}

/// Simulated-training configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub model: ModelDesc,
    pub topo: Topology,
    pub node: NodeSpec,
    pub dist: Distribution,
    /// Per-node mini-batch.
    pub batch: usize,
    pub mode: CommMode,
    pub policy: PriorityPolicy,
    /// Who picks collective algorithms: the analytic model (default) or a
    /// measured tuning table (`--tuning-table`).
    pub selection: SelectionPolicy,
    /// Fixed wire precision applied to every collective (`--wire-dtype
    /// fp32|bf16|int8`). Ignored for gradient allreduces when
    /// [`EngineConfig::wire_auto`] is set.
    pub wire: WireDtype,
    /// `--wire-dtype auto`: per-collective (algorithm × wire-precision)
    /// selection. Gradient allreduces pick the cheapest candidate over
    /// the full precision menu (quantize cost priced at the worst chaos
    /// compute slowdown — a slowed endpoint stretches its encode the
    /// same way it stretches any compute); activation exchanges always
    /// travel fp32, since only sum-reductions carry error-feedback
    /// protection (see `collectives/quant.rs`).
    pub wire_auto: bool,
    /// Measured iterations (one extra warmup iteration is always run).
    pub iterations: usize,
    /// Render [`Report::timeline`] (the node-0 ASCII Gantt). Implies
    /// span tracing: the timeline is derived from the trace store
    /// ([`Timeline::from_trace`]), not recorded separately.
    pub record_timeline: bool,
    /// Record the full span trace into [`Report::trace`]
    /// (`simulate --trace <out.json>` / `mlsl trace`). Off = the
    /// simulator's zero-overhead disabled path.
    pub trace: bool,
    /// Elastic membership: ranks leaving/joining at iteration boundaries
    /// (`--churn`). None = fixed membership.
    pub churn: Option<ChurnPlan>,
    /// Seeded fault injection installed into the fabric (`--chaos`):
    /// link flaps, dead NIC rails, node slowdowns. None = healthy run.
    pub chaos: Option<ChaosPlan>,
    /// Persistent per-node compute slowdown factors (`--straggler`) —
    /// unlike chaos's transient windows these hold for the whole run.
    /// None = all nodes healthy.
    pub straggler: Option<StragglerPlan>,
    /// Seeded deterministic background traffic injected into the fabric
    /// (`--background`): foreign flows that contend for egress but are
    /// invisible to the collectives layer. None = quiet fabric.
    pub background: Option<BgPlan>,
    /// Error-feedback residual tolerance driving adaptive precision
    /// backoff under `--wire-dtype auto`: when a gradient layer's
    /// projected EF residual bound would cross this value, the layer's
    /// wire menu is floored to the next-safer precision for subsequent
    /// iterations (one-shot warning + `quant.backoff` counter).
    pub ef_tolerance: f64,
    /// Per-(node, layer, iteration) compute jitter: relative std-dev of a
    /// deterministic log-normal-ish perturbation. Real clusters have
    /// stragglers (OS noise, memory layout, thermal); every
    /// allreduce synchronizes the stragglers away from ideal, which is
    /// the dominant sub-100% term in weak scaling at large node counts.
    /// 0.0 = perfectly balanced (unit tests); the Fig. 2 bench uses 0.03.
    pub jitter: f64,
    /// Worker threads for *partitioned* fabric simulation
    /// (`--sim-threads`, default 1 = the exact serial path). The engine's
    /// own iteration loop is always serial — `join_or_post` releases a
    /// collective at the instant its last member arrives and churn
    /// quiesce/release points couple every rank with zero latency, which
    /// conservative lookahead cannot window (see
    /// [`crate::collectives::parexec`] and `docs/ARCHITECTURE.md`). The
    /// thread count instead accelerates the barrier-free simulation
    /// paths: standalone collective timing
    /// ([`crate::collectives::parexec::time_collective_partitioned`])
    /// and tuning-grid probing ([`crate::tuner::probe::tune_threaded`]).
    pub sim_threads: usize,
}

impl EngineConfig {
    pub fn new(model: ModelDesc, topo: Topology, p: usize) -> Self {
        Self {
            model,
            topo,
            node: NodeSpec::skylake_6148(),
            dist: Distribution::data_parallel(p),
            batch: 32,
            mode: CommMode::MlslAsync { comm_cores: 2 },
            policy: PriorityPolicy::ByLayer,
            selection: SelectionPolicy::Analytic,
            wire: WireDtype::F32,
            wire_auto: false,
            iterations: 3,
            record_timeline: false,
            trace: false,
            churn: None,
            chaos: None,
            straggler: None,
            background: None,
            ef_tolerance: 0.05,
            jitter: 0.0,
            sim_threads: 1,
        }
    }

    fn comm_cores(&self) -> usize {
        match self.mode {
            CommMode::MlslAsync { comm_cores } => comm_cores,
            _ => 0,
        }
    }

    fn gated(&self) -> bool {
        matches!(self.mode, CommMode::MpiNonBlocking)
    }

    /// Worst per-node chaos compute slowdown (1000 = healthy run). The
    /// wire chooser prices (de)quantization at this rate: selection is
    /// made once per communicator, so it has to hold for the slowest
    /// endpoint that might sit on the critical path.
    pub fn max_chaos_slowdown_milli(&self) -> u64 {
        self.chaos
            .as_ref()
            .and_then(|c| c.slowdown_milli.iter().copied().max())
            .unwrap_or(1000)
            .max(1000)
    }

    /// Worst combined per-node compute slowdown: the worst chaos window
    /// compounded with the worst persistent straggler factor (both 1000
    /// = healthy). This is what the wire chooser prices quantization at.
    pub fn max_slowdown_milli(&self) -> u64 {
        let s = self.straggler.as_ref().map_or(1000, |s| s.max_milli()).max(1000);
        self.max_chaos_slowdown_milli() * s / 1000
    }

    /// Standalone collective timing under this config's fabric:
    /// `sim_threads == 1` runs the exact serial executor, anything more
    /// routes through the partitioned parallel executor
    /// ([`crate::collectives::parexec::time_collective_partitioned`],
    /// exact-equivalent by its lockstep tests — threads change
    /// wall-clock, never the answer). This is the `--sim-threads`
    /// surface for one-shot timing questions; the training loop itself
    /// stays serial (see the module docs).
    pub fn time_standalone_collective(
        &self,
        p: usize,
        programs: Vec<crate::collectives::program::Program>,
        wire: WireDtype,
        priority: Priority,
    ) -> Ns {
        if self.sim_threads > 1 {
            crate::collectives::parexec::time_collective_partitioned(
                &self.topo,
                p,
                programs,
                wire,
                priority,
                self.sim_threads,
            )
        } else {
            crate::collectives::simexec::time_collective(
                &mut NetSim::new(self.topo.clone(), p),
                programs,
                wire,
                priority,
            )
        }
    }

    /// Pure compute ns per iteration per node. Sums the SAME per-layer
    /// quantized durations the engine schedules, so `iter_ns −
    /// compute_ns_per_iter()` is exactly the exposed communication.
    /// Per-node compute is independent of the group size: a group of g
    /// nodes jointly processes g·batch samples (see analytic::compute_flops).
    pub fn compute_ns_per_iter(&self) -> Ns {
        let cc = self.comm_cores();
        self.model
            .layers
            .iter()
            .map(|l| {
                let fwd = self.node.compute_ns(l.fwd_flops * self.batch as f64, cc).max(1);
                let bwd = self.node.compute_ns(l.bwd_flops() * self.batch as f64, cc).max(1);
                fwd + bwd
            })
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Per-node schedule state machine
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum NodePhase {
    /// Waiting for layer `l`'s dependencies before its forward compute.
    FwdWait(usize),
    FwdCompute(usize),
    /// Waiting on the within-group activation allgather after fwd(l).
    FwdAct(usize),
    BwdCompute(usize),
    /// Waiting on the within-group activation-grad exchange after bwd(l).
    BwdAct(usize),
    /// BulkSync: waiting for the post-backward gradient exchange.
    BulkWait,
    /// Parked at an iteration boundary while elastic churn quiesces the
    /// cluster (see [`ChurnPlan`]); released once the membership change
    /// is applied.
    Hold,
    Done,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum CommKind {
    Grad { layer: usize },
    FwdAct { layer: usize },
    BwdAct { layer: usize },
}

struct CommMeta {
    kind: CommKind,
    /// Nodes that still have to reach the issue point.
    waiting: Vec<Rank>,
    members: Vec<Rank>,
    elems: usize,
    priority: Priority,
    /// Members whose completion has not been observed yet; the meta is
    /// garbage-collected when this reaches zero.
    remaining: usize,
}

struct NodeState {
    phase: NodePhase,
    iter: usize,
    /// Gradient allreduce completed (this iteration's set), per layer.
    grad_done: Vec<bool>,
    /// Outstanding gradient ops (BulkSync wait / paranoia check).
    grads_outstanding: usize,
    /// fwd(0) compute start times, one per iteration (incl. warmup).
    iter_starts: Vec<Ns>,
    compute_busy_ns: Ns,
}

/// Opaque compute tag encoding (tenant, phase, layer): the tenant rides
/// bits 48.., the phase discriminant bits 32..48, the layer the low 32.
/// (Message tags use a DIFFERENT tenant encoding — collective-id bit
/// [`TENANT_TAG_SHIFT`] — because compute tags never cross a wire; the
/// multi-tenant driver routes `ComputeDone` by `tag >> 48` alone.)
fn tag_of(tenant: usize, phase: NodePhase) -> u64 {
    let base = match phase {
        NodePhase::FwdCompute(l) => 1 << 32 | l as u64,
        NodePhase::BwdCompute(l) => 2 << 32 | l as u64,
        _ => unreachable!("only computes carry tags"),
    };
    (tenant as u64) << 48 | base
}

/// Inverse of [`tag_of`] for the node-0 Gantt: `f{l}` / `b{l}` labels
/// for traced compute spans ([`Timeline::from_trace`]); other nodes'
/// spans stay trace-only so the render matches the pre-trace output.
pub fn compute_label(node: Rank, tag: u64) -> Option<String> {
    if node != 0 {
        return None;
    }
    let l = tag & 0xFFFF_FFFF;
    match (tag >> 32) & 0xFFFF {
        1 => Some(format!("f{l}")),
        2 => Some(format!("b{l}")),
        _ => None,
    }
}

/// One training job's complete driver state — everything the simulated
/// run owns EXCEPT the fabric. The single-job [`Engine`] pairs one
/// `Job` with its own [`NetSim`]; the multi-tenant driver
/// ([`tenants::simulate_tenants`]) runs several `Job`s over one shared
/// fabric, which is why every method borrows `sim` instead of owning
/// it.
pub(crate) struct Job {
    cfg: EngineConfig,
    /// Accounting slot in the shared fabric (0 for the single-job
    /// engine). Collective ids carry it at [`TENANT_TAG_SHIFT`];
    /// compute tags at bit 48 (see [`tag_of`]).
    tenant: usize,
    /// Fabric rank of this job's local rank 0: 0 for colocated tenancy
    /// (jobs share nodes and contend for egress), `tenant · p` for
    /// disjoint tenancy (bitwise-isolated rank blocks).
    base: Rank,
    colls: SimCollectives,
    nodes: Vec<NodeState>,
    metas: HashMap<u64, CommMeta>,
    /// (kind, issue-iteration) → coll id, so joiners find pending ops.
    open: HashMap<(CommKind, usize, usize), u64>, // (kind, iter, comm_group_key)
    next_id: u64,
    /// Elastic membership: is rank i currently part of the run? All true
    /// until a [`ChurnOp::Leave`] applies; communicators only ever span
    /// active ranks (survivors keep their ids — no renumbering).
    active: Vec<bool>,
    /// Next unapplied event of `cfg.churn`.
    churn_idx: usize,
    /// Memoized (algorithm, wire) decisions per (kind, member set,
    /// per-rank elems). The member set is part of the key, so a churn
    /// rebuild naturally misses and re-selects for the survivor set —
    /// stale entries are never consulted. The final component is the
    /// wire-menu length offered at selection time, so a precision
    /// backoff (which shrinks a layer's menu) naturally misses and
    /// re-selects instead of replaying the pre-backoff pick.
    sel_cache: HashMap<(CollectiveKind, Vec<Rank>, usize, usize), (Algorithm, WireDtype)>,
    /// Built programs keyed by (kind, algorithm, WIRE, member count,
    /// elems). Programs repeat every iteration (same layers, same
    /// communicators), so steady state is pure reuse. The wire dtype is
    /// part of the key even though program structure is
    /// wire-independent: auto selection may flip precision at the
    /// crossover as churn changes the member count, and an entry must
    /// never be reused under a different precision label than it was
    /// selected for (the pair travels together into `post_mapped`).
    prog_cache: HashMap<ProgKey, Vec<crate::collectives::program::Program>>,
    /// Error-feedback residual bound per ORIGINAL rank id, in units of
    /// the gradient magnitude: after a compressed allreduce,
    /// `r ← δ·(1 + r)` with δ the wire's relative quantization error —
    /// the telescoping EF-SGD recurrence, converging to δ/(1−δ). Keyed
    /// by original id (never renumbered), so the state survives churn:
    /// a rank that leaves and rejoins resumes its own residual.
    ef_bound: Vec<f64>,
    /// Per-LAYER EF residual bound (symmetric across the lockstep
    /// members, so one scalar per gradient bucket suffices). Feeds the
    /// adaptive precision backoff against [`EngineConfig::ef_tolerance`].
    ef_layer: Vec<f64>,
    /// Per-layer wire-menu floor under `--wire-dtype auto`: the layer's
    /// candidate menu is `WireDtype::ALL[..ALL.len() - floor]`, so a
    /// backed-off bucket can never re-pick the precision that tripped
    /// its residual bound.
    wire_floor: Vec<usize>,
    /// One-shot latch for the backoff warning.
    backoff_warned: bool,
    /// Observed-load correction applied to selection (multi-tenant
    /// driver, `--contention-aware`). None = trust the quiet tables.
    contention: Option<Contention>,
    /// Human-readable record of applied membership changes.
    pub churn_log: Vec<String>,
    /// Earliest observed fwd(0) start per iteration index (cluster-level),
    /// feeding [`Report::per_iter_ns`].
    first_starts: Vec<Ns>,
}

impl Job {
    pub(crate) fn new(cfg: EngineConfig, tenant: usize, base: Rank) -> Self {
        let p = cfg.dist.world();
        let nl = cfg.model.layers.len();
        let nodes = (0..p)
            .map(|_| NodeState {
                phase: NodePhase::FwdWait(0),
                iter: 0,
                grad_done: vec![true; nl], // iteration 0 has no prior grads
                grads_outstanding: 0,
                iter_starts: Vec::new(),
                compute_busy_ns: 0,
            })
            .collect();
        Self {
            cfg,
            tenant,
            base,
            colls: SimCollectives::new(),
            nodes,
            metas: HashMap::new(),
            open: HashMap::new(),
            // Disjoint per-tenant collective-id spaces: tenant 0 counts
            // from 1 exactly like the pre-tenant engine, so single-job
            // runs stay bitwise identical.
            next_id: 1 + ((tenant as u64) << TENANT_TAG_SHIFT),
            active: vec![true; p],
            churn_idx: 0,
            sel_cache: HashMap::new(),
            prog_cache: HashMap::new(),
            ef_bound: vec![0.0; p],
            ef_layer: vec![0.0; nl],
            wire_floor: vec![0; nl],
            backoff_warned: false,
            contention: None,
            churn_log: Vec::new(),
            first_starts: Vec::new(),
        }
    }

    /// Every node finished its configured iterations.
    fn done(&self) -> bool {
        self.nodes.iter().all(|n| n.phase == NodePhase::Done)
    }

    /// Slowest node's iteration index (the job's lockstep progress).
    fn min_iter(&self) -> usize {
        self.nodes.iter().map(|n| n.iter).min().unwrap_or(0)
    }

    /// Install (or replace) the observed-load correction; memoized
    /// selections are dropped so every communicator re-ranks under it.
    fn set_contention(&mut self, c: Contention) {
        self.sel_cache.clear();
        self.contention = Some(c);
    }

    /// Feed one fabric event through this job's collective executor and
    /// its completion handlers. Deliveries tagged for other tenants (or
    /// background flows) miss `colls`' id table and are ignored — the
    /// multi-tenant driver routes by tag anyway, this is the backstop.
    fn on_sim_event(
        &mut self,
        sim: &mut NetSim,
        ev: &SimEvent,
        completions: &mut Vec<crate::collectives::simexec::Completion>,
    ) {
        completions.clear();
        self.colls.on_event_into(sim, ev, completions);
        for i in 0..completions.len() {
            let (cid, rank) = (completions[i].coll_id, completions[i].rank);
            self.on_comm_done(sim, cid, rank);
        }
    }

    // -- state machine ------------------------------------------------------

    fn layer_count(&self) -> usize {
        self.cfg.model.layers.len()
    }

    /// Compute duration of layer `l` in direction fwd/bwd for one node,
    /// with the node/iteration-specific straggler perturbation.
    fn compute_ns_for(&self, n: Rank, iter: usize, l: usize, fwd: bool) -> Ns {
        let layer = &self.cfg.model.layers[l];
        let flops = if fwd { layer.fwd_flops } else { layer.bwd_flops() };
        let flops = flops * self.cfg.batch as f64;
        let base = self.cfg.node.compute_ns(flops, self.cfg.comm_cores()).max(1);
        if self.cfg.jitter <= 0.0 {
            return base;
        }
        // Deterministic per-(node, iter) normal perturbation. Straggler
        // noise is CORRELATED within an iteration (OS jitter, turbo,
        // memory placement last milliseconds, not microseconds), so the
        // draw is per node-iteration and applied to every layer in it —
        // per-layer-independent noise would average out over the ~160
        // layers and understate the synchronization cost.
        let seed = (n as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((iter as u64) << 24);
        let _ = l;
        let z = crate::util::prng::Prng::seed(seed).normal();
        let factor = (1.0 + self.cfg.jitter * z).max(0.5);
        ((base as f64 * factor).round() as Ns).max(1)
    }

    /// Try to move node `n` forward through waits; start computes.
    fn try_advance(&mut self, sim: &mut NetSim, n: Rank) {
        loop {
            match self.nodes[n].phase {
                NodePhase::FwdWait(l) => {
                    if l >= self.layer_count() {
                        // Forward done; begin backward.
                        self.nodes[n].phase = NodePhase::BwdCompute(self.layer_count() - 1);
                        continue;
                    }
                    if !self.nodes[n].grad_done[l] {
                        return; // blocked on last iteration's gradient
                    }
                    if l == 0 {
                        let now = sim.now();
                        self.nodes[n].iter_starts.push(now);
                        // Cluster-level first start of this iteration
                        // index (sim time is monotonic, so the first
                        // recorder IS the earliest).
                        let iter = self.nodes[n].iter;
                        while self.first_starts.len() <= iter {
                            self.first_starts.push(Ns::MAX);
                        }
                        self.first_starts[iter] = self.first_starts[iter].min(now);
                    }
                    self.nodes[n].phase = NodePhase::FwdCompute(l);
                    self.start_compute(sim, n, NodePhase::FwdCompute(l));
                    return;
                }
                NodePhase::BwdCompute(l) => {
                    self.start_compute(sim, n, NodePhase::BwdCompute(l));
                    return;
                }
                NodePhase::FwdAct(_) | NodePhase::BwdAct(_) | NodePhase::BulkWait => return,
                NodePhase::FwdCompute(_) => return, // compute in flight
                NodePhase::Hold | NodePhase::Done => return,
            }
        }
    }

    fn start_compute(&mut self, sim: &mut NetSim, n: Rank, phase: NodePhase) {
        let (l, fwd) = match phase {
            NodePhase::FwdCompute(l) => (l, true),
            NodePhase::BwdCompute(l) => (l, false),
            _ => unreachable!(),
        };
        let dur = self.compute_ns_for(n, self.nodes[n].iter, l, fwd);
        self.nodes[n].compute_busy_ns += dur;
        if self.cfg.gated() {
            sim.set_comm_gated(self.base + n, true);
        }
        // No timeline recording here: the traced compute span (see
        // [`NetSim::compute`]) is the single source the Gantt renders.
        sim.compute(self.base + n, dur, tag_of(self.tenant, phase));
    }

    /// Handle a compute completion. `n` is the JOB-LOCAL rank (the
    /// caller subtracts `base`); `tag` still carries the tenant bits.
    fn on_compute_done(&mut self, sim: &mut NetSim, n: Rank, tag: u64, _at: Ns, total_iters: usize) {
        if self.cfg.gated() {
            sim.set_comm_gated(self.base + n, false);
        }
        let l = (tag & 0xFFFF_FFFF) as usize;
        let is_fwd = (tag >> 32) & 0xFFFF == 1;
        if is_fwd {
            debug_assert_eq!(self.nodes[n].phase, NodePhase::FwdCompute(l));
            // Within-group activation exchange (hybrid/model parallel).
            if self.issue_act(sim, n, l, true) {
                self.nodes[n].phase = NodePhase::FwdAct(l);
            } else {
                self.nodes[n].phase = NodePhase::FwdWait(l + 1);
                self.try_advance(sim, n);
            }
        } else {
            debug_assert_eq!(self.nodes[n].phase, NodePhase::BwdCompute(l));
            // Gradient exchange for this layer.
            if self.cfg.model.layers[l].has_weights() && self.cfg.dist.num_groups() > 1 {
                match self.cfg.mode {
                    CommMode::BulkSync => {} // deferred to end of backward
                    _ => self.issue_grad(sim, n, l),
                }
            }
            if self.issue_act(sim, n, l, false) {
                self.nodes[n].phase = NodePhase::BwdAct(l);
            } else {
                self.after_bwd_step(sim, n, l, total_iters);
            }
        }
    }

    fn after_bwd_step(&mut self, sim: &mut NetSim, n: Rank, l: usize, total_iters: usize) {
        if l > 0 {
            self.nodes[n].phase = NodePhase::BwdCompute(l - 1);
            self.try_advance(sim, n);
            return;
        }
        // Backward finished.
        if matches!(self.cfg.mode, CommMode::BulkSync) && self.cfg.dist.num_groups() > 1 {
            // Issue ALL gradients now, FIFO, flat priority (Horovod-oob).
            let layers: Vec<usize> = (0..self.layer_count())
                .rev() // completion order of backprop
                .filter(|l| self.cfg.model.layers[*l].has_weights())
                .collect();
            for l in layers {
                self.issue_grad(sim, n, l);
            }
            if self.nodes[n].grads_outstanding > 0 {
                self.nodes[n].phase = NodePhase::BulkWait;
                return;
            }
        }
        self.finish_iteration(sim, n, total_iters);
    }

    fn finish_iteration(&mut self, sim: &mut NetSim, n: Rank, total_iters: usize) {
        self.nodes[n].iter += 1;
        // Elastic churn: park at the first boundary past the next
        // unapplied event; the change applies once the whole cluster is
        // quiesced there.
        let must_hold = self.cfg.churn.as_ref().is_some_and(|c| {
            c.events
                .get(self.churn_idx)
                .is_some_and(|e| self.nodes[n].iter > e.after_iter)
        });
        if must_hold {
            self.nodes[n].phase = NodePhase::Hold;
            self.maybe_apply_churn(sim, total_iters);
            return;
        }
        if self.nodes[n].iter >= total_iters {
            self.nodes[n].phase = NodePhase::Done;
            return;
        }
        self.nodes[n].phase = NodePhase::FwdWait(0);
        self.try_advance(sim, n);
    }

    /// Apply every churn event due at the current boundary once the
    /// cluster is quiesced: every active node parked (Hold or Done) past
    /// the event's iteration, nothing in flight, nothing half-joined.
    /// Then release the held survivors (and any joiners) into the next
    /// iteration. Safe to call eagerly — it is a no-op until quiesced.
    fn maybe_apply_churn(&mut self, sim: &mut NetSim, total_iters: usize) {
        let nl = self.layer_count();
        let mut applied = false;
        loop {
            let Some(ev) = self
                .cfg
                .churn
                .as_ref()
                .and_then(|c| c.events.get(self.churn_idx))
                .copied()
            else {
                break;
            };
            let quiesced = self
                .nodes
                .iter()
                .enumerate()
                .all(|(i, nd)| {
                    !self.active[i]
                        || (matches!(nd.phase, NodePhase::Hold | NodePhase::Done)
                            && nd.iter > ev.after_iter)
                })
                && self.colls.in_flight() == 0
                && self.open.is_empty();
            if !quiesced {
                break;
            }
            match ev.op {
                ChurnOp::Leave(r) => {
                    assert!(self.active[r], "churn: rank {r} left twice");
                    self.active[r] = false;
                    self.nodes[r].phase = NodePhase::Done;
                }
                ChurnOp::Join(r) => {
                    assert!(!self.active[r], "churn: rank {r} joined while active");
                    self.active[r] = true;
                    // The joiner re-enters at the boundary iteration with
                    // no prior gradients outstanding; it is released with
                    // the survivors below.
                    self.nodes[r].iter = ev.after_iter + 1;
                    self.nodes[r].grad_done = vec![true; nl];
                    self.nodes[r].grads_outstanding = 0;
                    self.nodes[r].phase = NodePhase::Hold;
                }
            }
            let survivors = self.active.iter().filter(|a| **a).count();
            let (what, r) = match ev.op {
                ChurnOp::Leave(r) => ("leave", r),
                ChurnOp::Join(r) => ("join", r),
            };
            self.churn_log.push(format!(
                "{what} rank {r} after iter {} ({survivors} active)",
                ev.after_iter
            ));
            self.churn_idx += 1;
            applied = true;
        }
        if !applied {
            return;
        }
        for i in 0..self.nodes.len() {
            if self.active[i] && self.nodes[i].phase == NodePhase::Hold {
                if self.nodes[i].iter >= total_iters {
                    self.nodes[i].phase = NodePhase::Done;
                } else {
                    self.nodes[i].phase = NodePhase::FwdWait(0);
                    self.try_advance(sim, i);
                }
            }
        }
    }

    // -- communication issue points ------------------------------------------

    /// Issue (or join) the gradient allreduce for layer `l`. Non-blocking:
    /// completion flips `grad_done[l]` consumed by the NEXT iteration's
    /// forward pass.
    fn issue_grad(&mut self, sim: &mut NetSim, n: Rank, l: usize) {
        let iter = self.nodes[n].iter;
        self.nodes[n].grad_done[l] = false;
        self.nodes[n].grads_outstanding += 1;
        // Elastic churn: the communicator spans the SURVIVING data peers
        // only, keeping their original rank ids (no renumbering).
        let members = survivors(self.cfg.dist.data_peers(n), |r| self.active[r]);
        let group_key = self.cfg.dist.rank_in_group(n);
        let elems = self.cfg.model.layers[l].weight_elems.div_ceil(self.cfg.dist.group_size());
        let priority = match self.cfg.mode {
            CommMode::MlslAsync { .. } => {
                self.cfg.policy.assign(l, self.layer_count())
            }
            _ => 128,
        };
        self.join_or_post(sim, CommKind::Grad { layer: l }, iter, group_key, n, members, elems, priority);
    }

    /// Issue (or join) the within-group activation exchange after layer
    /// `l`; returns false when none is needed.
    fn issue_act(&mut self, sim: &mut NetSim, n: Rank, l: usize, fwd: bool) -> bool {
        let g = self.cfg.dist.group_size();
        if g <= 1 || self.cfg.model.layers[l].out_act_elems == 0 {
            return false;
        }
        let iter = self.nodes[n].iter;
        let members = survivors(self.cfg.dist.group_members(n), |r| self.active[r]);
        let group_key = self.cfg.dist.group_of(n);
        // The group jointly holds g·batch samples of activations; the ring
        // allgather makes every member hold the group batch.
        let elems = self.cfg.model.layers[l].out_act_elems * self.cfg.batch * g;
        let kind = if fwd { CommKind::FwdAct { layer: l } } else { CommKind::BwdAct { layer: l } };
        // "activation communication must be prioritized": class 0.
        self.join_or_post(sim, kind, iter, group_key, n, members, elems, 0);
        true
    }

    /// Join a pending collective or create it; post to the fabric once the
    /// last member joins. `members` are job-local ranks; the fabric sees
    /// them shifted by `base`.
    #[allow(clippy::too_many_arguments)]
    fn join_or_post(
        &mut self,
        sim: &mut NetSim,
        kind: CommKind,
        iter: usize,
        group_key: usize,
        n: Rank,
        members: Vec<Rank>,
        elems: usize,
        priority: Priority,
    ) {
        if members.len() <= 1 {
            // Degenerate communicator: instantly complete.
            self.complete_comm_for(sim, kind, n);
            return;
        }
        let key = (kind, iter, group_key);
        let id = *self.open.entry(key).or_insert_with(|| {
            let id = self.next_id;
            self.next_id += 1;
            self.metas.insert(
                id,
                CommMeta {
                    kind,
                    waiting: members.clone(),
                    members: members.clone(),
                    elems,
                    priority,
                    remaining: members.len(),
                },
            );
            id
        });
        let meta = self.metas.get_mut(&id).expect("meta exists");
        meta.waiting.retain(|r| *r != n);
        if meta.waiting.is_empty() {
            self.open.remove(&key);
            let members = meta.members.clone();
            let (elems, priority, kind) = (meta.elems, meta.priority, meta.kind);
            let pm = members.len();
            let ckind = match kind {
                CommKind::Grad { .. } => CollectiveKind::Allreduce,
                _ => CollectiveKind::Allgather,
            };
            // The member-set-aware chooser applies the per-level
            // alignment gate (tier truncation for partially-aligned
            // sets, the flat path for strided or post-churn
            // non-contiguous survivor sets) before consulting the
            // configured policy — see
            // [`SelectionPolicy::choose_for_members`]. Decisions are
            // memoized per (kind, member set, elems): the same layer's
            // communicator repeats every iteration.
            let bytes = (4 * elems) as u64;
            // The fabric-rank view of the communicator: identical to the
            // local view for the single-job engine (base 0), shifted for
            // disjoint-tenancy jobs — tier alignment is a property of
            // where the ranks actually sit on the fabric.
            let gmembers: Vec<Rank> =
                members.iter().map(|r| r + self.base).collect();
            // Adaptive precision backoff floors a gradient layer's wire
            // menu once its EF residual bound nears the tolerance.
            let menu: &[WireDtype] = match kind {
                CommKind::Grad { layer } if self.cfg.wire_auto => {
                    &WireDtype::ALL[..WireDtype::ALL.len() - self.wire_floor[layer]]
                }
                _ => &WireDtype::ALL,
            };
            let sel_key = (ckind, members.clone(), elems, menu.len());
            let (alg, wire) = match self.sel_cache.get(&sel_key) {
                Some(&cached) => cached,
                None => {
                    let picked = if self.cfg.wire_auto {
                        self.cfg.selection.choose_for_members_wire_contended(
                            &self.cfg.topo,
                            &gmembers,
                            ckind,
                            bytes,
                            menu,
                            self.cfg.max_slowdown_milli(),
                            self.contention.as_ref(),
                        )
                    } else if self.contention.is_some() {
                        // Fixed wire: contention re-ranks the algorithm
                        // only, the precision stays what the user asked.
                        let (alg, _) = self.cfg.selection.choose_for_members_wire_contended(
                            &self.cfg.topo,
                            &gmembers,
                            ckind,
                            bytes,
                            &[self.cfg.wire],
                            self.cfg.max_slowdown_milli(),
                            self.contention.as_ref(),
                        );
                        (alg, self.cfg.wire)
                    } else {
                        (
                            self.cfg.selection.choose_for_members(
                                &self.cfg.topo,
                                &gmembers,
                                ckind,
                                bytes,
                            ),
                            self.cfg.wire,
                        )
                    };
                    self.sel_cache.insert(sel_key, picked);
                    picked
                }
            };
            let programs = self
                .prog_cache
                .entry((ckind, alg, wire, pm, elems))
                .or_insert_with(|| {
                    build(ckind, alg, pm, elems)
                        .expect("selection policies only return buildable algorithms")
                })
                .clone();
            if ckind == CollectiveKind::Allreduce && wire != WireDtype::F32 {
                // EF-SGD residual recurrence: each member folds its
                // quantization error into the next send, so the bound
                // telescopes instead of accumulating linearly.
                let delta = wire.rel_error();
                for &r in &members {
                    self.ef_bound[r] = delta * (1.0 + self.ef_bound[r]);
                }
                if let CommKind::Grad { layer } = kind {
                    let bound = delta * (1.0 + self.ef_layer[layer]);
                    self.ef_layer[layer] = bound;
                    self.maybe_backoff(layer, wire, bound);
                }
            }
            if self.tenant == 0 && sim.trace_enabled() && members.contains(&0) {
                let at = sim.now();
                let label = match kind {
                    CommKind::Grad { layer } => format!("g{layer}"),
                    CommKind::FwdAct { layer } => format!("a{layer}"),
                    CommKind::BwdAct { layer } => format!("x{layer}"),
                };
                sim.trace_push(TraceEvent::Mark {
                    node: 0,
                    at,
                    track: "issue".into(),
                    label,
                });
            }
            let completions = self.colls.post_mapped(
                sim,
                id,
                programs,
                gmembers,
                wire,
                priority,
            );
            for c in completions {
                self.on_comm_done(sim, c.coll_id, c.rank);
            }
        }
    }

    /// Adaptive precision backoff: if the NEXT compressed exchange at
    /// `wire` would push `layer`'s EF residual bound past the configured
    /// tolerance, floor the layer's auto menu below `wire` so subsequent
    /// iterations re-select from the safer precisions only.
    fn maybe_backoff(&mut self, layer: usize, wire: WireDtype, bound: f64) {
        if !self.cfg.wire_auto
            || wire.rel_error() * (1.0 + bound) <= self.cfg.ef_tolerance
        {
            return;
        }
        let Some(idx) = WireDtype::ALL.iter().position(|w| *w == wire) else {
            return;
        };
        let floor = WireDtype::ALL.len() - idx; // menu shrinks to ALL[..idx]
        if idx == 0 || self.wire_floor[layer] >= floor {
            return; // f32 cannot back off further / already floored
        }
        self.wire_floor[layer] = floor;
        crate::metrics::registry::inc("quant.backoff");
        if !self.backoff_warned {
            self.backoff_warned = true;
            crate::util::warn(format!(
                "quantization backoff: layer {layer} EF residual bound {bound:.5} \
                 near tolerance {:.5} — wire menu floored below {wire:?}",
                self.cfg.ef_tolerance
            ));
        }
    }

    /// Handle one rank's collective completion. `rank` is the FABRIC
    /// rank simexec reports; job-local bookkeeping subtracts `base`.
    fn on_comm_done(&mut self, sim: &mut NetSim, coll_id: u64, rank: Rank) {
        let node = rank - self.base;
        let meta = self.metas.get_mut(&coll_id).expect("known collective");
        let kind = meta.kind;
        meta.remaining = meta.remaining.saturating_sub(1);
        if meta.remaining == 0 {
            // Every member completed (the collective left simexec): GC the
            // meta so `metas` stays bounded across iterations.
            self.metas.remove(&coll_id);
        }
        self.complete_comm_for(sim, kind, node);
        // A completion may have been the last thing churn was quiescing
        // on (held nodes' trailing gradient exchanges draining).
        if self
            .cfg
            .churn
            .as_ref()
            .is_some_and(|c| self.churn_idx < c.events.len())
        {
            let total = self.total_iters();
            self.maybe_apply_churn(sim, total);
        }
    }

    fn complete_comm_for(&mut self, sim: &mut NetSim, kind: CommKind, node: Rank) {
        match kind {
            CommKind::Grad { layer } => {
                self.nodes[node].grad_done[layer] = true;
                self.nodes[node].grads_outstanding =
                    self.nodes[node].grads_outstanding.saturating_sub(1);
                match self.nodes[node].phase {
                    NodePhase::FwdWait(l) if l == layer => self.try_advance(sim, node),
                    NodePhase::BulkWait if self.nodes[node].grads_outstanding == 0 => {
                        let total = self.total_iters();
                        self.finish_iteration(sim, node, total);
                    }
                    _ => {}
                }
            }
            CommKind::FwdAct { layer } => {
                debug_assert_eq!(self.nodes[node].phase, NodePhase::FwdAct(layer));
                self.nodes[node].phase = NodePhase::FwdWait(layer + 1);
                self.try_advance(sim, node);
            }
            CommKind::BwdAct { layer } => {
                debug_assert_eq!(self.nodes[node].phase, NodePhase::BwdAct(layer));
                let total = self.total_iters();
                self.after_bwd_step(sim, node, layer, total);
            }
        }
    }

    fn total_iters(&self) -> usize {
        self.cfg.iterations + 1
    }

    /// Per-rank error-feedback residual bound (original rank ids; see
    /// the field docs). Zero for a rank that never sent a compressed
    /// gradient; otherwise strictly below δ/(1−δ) for its wire's δ.
    pub fn ef_residual_bound(&self) -> &[f64] {
        &self.ef_bound
    }

    /// Currently-active ranks (the elastic-membership view; all ranks
    /// until a leave applies).
    pub fn active_ranks(&self) -> Vec<Rank> {
        self.active
            .iter()
            .enumerate()
            .filter(|(_, a)| **a)
            .map(|(i, _)| i)
            .collect()
    }

    /// Sum over iteration indices of the spread between the first and
    /// last node to reach that iteration's fwd(0) — the synchronization
    /// cost a straggler induces at every lockstep boundary.
    fn boundary_spread_ns(&self) -> Ns {
        let longest = self.nodes.iter().map(|n| n.iter_starts.len()).max().unwrap_or(0);
        let mut total = 0;
        for i in 0..longest {
            let starts = self.nodes.iter().filter_map(|n| n.iter_starts.get(i).copied());
            let (mut lo, mut hi, mut any) = (Ns::MAX, 0, false);
            for s in starts {
                lo = lo.min(s);
                hi = hi.max(s);
                any = true;
            }
            if any {
                total += hi - lo;
            }
        }
        total
    }
}

/// The simulated training run: one [`Job`] driving its own fabric.
pub struct Engine {
    sim: NetSim,
    job: Job,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Self {
        let p = cfg.dist.world();
        let mut sim = NetSim::new(cfg.topo.clone(), p);
        if let Some(plan) = cfg.chaos.clone() {
            sim.set_chaos(plan);
        }
        if let Some(plan) = cfg.straggler.clone() {
            sim.set_stragglers(plan);
        }
        if let Some(plan) = cfg.background.clone() {
            sim.set_background(plan);
        }
        // The Gantt renderer is a view over the trace store, so asking
        // for the timeline turns tracing on too (still zero impact on
        // the event stream — see `fabric/sim.rs`).
        sim.set_trace(cfg.trace || cfg.record_timeline);
        Engine { sim, job: Job::new(cfg, 0, 0) }
    }

    /// Run the configured number of iterations; produce the report.
    pub fn run(mut self) -> Report {
        self.run_to_completion()
    }

    /// [`Engine::run`] on a borrowed engine (tests inspect post-run
    /// bookkeeping, e.g. that `metas` was garbage-collected).
    fn run_to_completion(&mut self) -> Report {
        let p = self.job.cfg.dist.world();
        let total_iters = self.job.cfg.iterations + 1; // + warmup
        for n in 0..p {
            self.job.try_advance(&mut self.sim, n);
        }
        // Event loop. One scratch completion buffer serves the whole
        // run — on_event_into appends into it instead of allocating a
        // fresh Vec per delivered message (this loop is the L3 hot path).
        let mut completions: Vec<crate::collectives::simexec::Completion> = Vec::new();
        while !self.job.done() {
            let Some(ev) = self.sim.next() else {
                panic!(
                    "simulation deadlock: phases={:?}",
                    self.job.nodes.iter().map(|n| (n.iter, n.phase)).collect::<Vec<_>>()
                );
            };
            match ev {
                SimEvent::ComputeDone { node, tag, at } => {
                    self.job.on_compute_done(&mut self.sim, node, tag, at, total_iters);
                }
                ev => self.job.on_sim_event(&mut self.sim, &ev, &mut completions),
            }
        }
        // Drain trailing collectives (the last iteration's gradient
        // exchanges) so traffic accounting is policy-independent.
        while self.job.colls.in_flight() > 0 {
            let Some(ev) = self.sim.next() else { break };
            self.job.on_sim_event(&mut self.sim, &ev, &mut completions);
        }
        let trace = self.sim.take_trace().map(|t| t.normalized());
        let timeline = trace
            .as_ref()
            .map(|t| Timeline::from_trace(t, compute_label))
            .unwrap_or_default();
        let iter_starts: Vec<Vec<Ns>> =
            self.job.nodes.iter().map(|n| n.iter_starts.clone()).collect();
        report::build_report(
            &self.job.cfg,
            &self.sim,
            &iter_starts,
            &self.job.first_starts,
            self.job.churn_log.clone(),
            timeline,
            trace,
        )
    }

    /// Per-rank error-feedback residual bound (see [`Job::ef_residual_bound`]).
    pub fn ef_residual_bound(&self) -> &[f64] {
        self.job.ef_residual_bound()
    }

    /// Currently-active ranks (the elastic-membership view).
    pub fn active_ranks(&self) -> Vec<Rank> {
        self.job.active_ranks()
    }
}

/// Convenience: configure + run.
pub fn simulate(cfg: EngineConfig) -> Report {
    Engine::new(cfg).run()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(model: &str, p: usize, mode: CommMode) -> EngineConfig {
        let mut c = EngineConfig::new(
            ModelDesc::by_name(model).unwrap(),
            Topology::omnipath_100g(),
            p,
        );
        c.mode = mode;
        c
    }

    #[test]
    fn single_node_has_no_comm() {
        let r = simulate(cfg("resnet50", 1, CommMode::BulkSync));
        assert_eq!(r.exposed_comm_ns, 0);
        assert!(r.iter_ns > 0);
    }

    #[test]
    fn iteration_time_close_to_compute_on_fast_fabric() {
        let r = simulate(cfg("resnet50", 8, CommMode::MlslAsync { comm_cores: 2 }));
        // Omnipath + overlap: exposed comm well under 20% of compute.
        assert!(
            (r.exposed_comm_ns as f64) < 0.25 * r.compute_ns as f64,
            "exposed={} compute={}",
            r.exposed_comm_ns,
            r.compute_ns
        );
    }

    #[test]
    fn bulk_sync_exposes_all_comm() {
        let m = simulate(cfg("resnet50", 8, CommMode::MlslAsync { comm_cores: 2 }));
        let b = simulate(cfg("resnet50", 8, CommMode::BulkSync));
        assert!(
            b.exposed_comm_ns > 2 * m.exposed_comm_ns.max(1),
            "bulk={} mlsl={}",
            b.exposed_comm_ns,
            m.exposed_comm_ns
        );
        assert!(b.iter_ns > m.iter_ns);
    }

    #[test]
    fn mpi_slower_than_mlsl_on_ethernet() {
        let mut a = cfg("resnet50", 8, CommMode::MlslAsync { comm_cores: 2 });
        a.topo = Topology::eth_10g();
        let mut b = cfg("resnet50", 8, CommMode::MpiNonBlocking);
        b.topo = Topology::eth_10g();
        let ra = simulate(a);
        let rb = simulate(b);
        assert!(rb.iter_ns > ra.iter_ns, "mpi={} mlsl={}", rb.iter_ns, ra.iter_ns);
    }

    #[test]
    fn priority_beats_fifo_on_ethernet() {
        let mut with = cfg("vgg16", 8, CommMode::MlslAsync { comm_cores: 2 });
        with.topo = Topology::eth_10g();
        with.policy = PriorityPolicy::ByLayer;
        let mut without = with.clone();
        without.policy = PriorityPolicy::None;
        let rw = simulate(with);
        let ro = simulate(without);
        assert!(
            rw.exposed_comm_ns < ro.exposed_comm_ns,
            "bylayer={} fifo={}",
            rw.exposed_comm_ns,
            ro.exposed_comm_ns
        );
    }

    #[test]
    fn hybrid_runs_with_same_per_node_compute() {
        let mut c = cfg("vgg16", 8, CommMode::MlslAsync { comm_cores: 2 });
        c.dist = Distribution::new(8, 4);
        c.iterations = 2;
        let r = simulate(c);
        assert!(r.iter_ns > 0);
        // The group jointly processes g·batch samples: per-node compute is
        // unchanged vs pure data parallelism.
        let d = cfg("vgg16", 8, CommMode::MlslAsync { comm_cores: 2 });
        let rd = simulate(d);
        assert_eq!(r.compute_ns, rd.compute_ns);
        // But its iteration carries activation exchanges too.
        assert!(r.iter_ns >= rd.compute_ns);
    }

    #[test]
    fn weak_scaling_efficiency_definition() {
        let r1 = simulate(cfg("resnet50", 1, CommMode::MlslAsync { comm_cores: 2 }));
        let r64 = simulate(cfg("resnet50", 64, CommMode::MlslAsync { comm_cores: 2 }));
        let eff = r1.iter_ns as f64 / r64.iter_ns as f64;
        assert!(eff > 0.5 && eff <= 1.001, "{eff}");
    }

    #[test]
    fn two_tier_topology_reduces_comm_exposure() {
        // Same 16 ranks, bulk-sync (fully exposed comm). Re-describing the
        // fabric as 2 ranks/node keeps every inter-node parameter identical
        // but lets intra-node hops ride shared memory and the selector use
        // hierarchical allreduce — the iteration must get faster.
        let mut flat = cfg("resnet50", 16, CommMode::BulkSync);
        flat.topo = Topology::eth_10g();
        let mut smp = cfg("resnet50", 16, CommMode::BulkSync);
        smp.topo = Topology::eth_10g_smp(2);
        let rf = simulate(flat);
        let rs = simulate(smp);
        assert!(
            rs.iter_ns < rf.iter_ns,
            "smp={} flat={}",
            rs.iter_ns,
            rf.iter_ns
        );
    }

    #[test]
    fn three_level_topology_runs_and_beats_flat() {
        // 16 ranks described as 2/node × 4 nodes/rack (rack = 8): the
        // engine must gate hierarchical on alignment at every level and
        // still beat the flat description of the same NIC.
        let mut flat = cfg("resnet50", 16, CommMode::BulkSync);
        flat.topo = Topology::eth_10g();
        flat.iterations = 1;
        let mut tiered = cfg("resnet50", 16, CommMode::BulkSync);
        tiered.topo = Topology::by_name("eth10g-x2r4").unwrap();
        // Undo the rack preset's spine oversubscription so the comparison
        // isolates the hierarchy (same top physics as the flat preset).
        tiered.topo.link_gbps = flat.topo.link_gbps;
        tiered.topo.latency_ns = flat.topo.latency_ns;
        tiered.iterations = 1;
        let rf = simulate(flat);
        let rt = simulate(tiered);
        assert!(rt.iter_ns < rf.iter_ns, "tiered={} flat={}", rt.iter_ns, rf.iter_ns);
    }

    #[test]
    fn hybrid_on_three_level_topology_gates_per_level() {
        // Hybrid groups of 4 on a rack-of-8 fabric: in-group members are
        // node-aligned but too short for the rack tier, while the strided
        // cross-group communicators must take the flat path — the
        // per-level gate has to sort all of this out and complete.
        let mut c = cfg("vgg16", 16, CommMode::MlslAsync { comm_cores: 2 });
        c.topo = Topology::by_name("eth10g-x2r4").unwrap();
        c.dist = Distribution::new(16, 4);
        c.iterations = 1;
        let r = simulate(c);
        assert!(r.iter_ns > 0);
    }

    #[test]
    fn hybrid_on_smp_topology_completes() {
        // Strided data-parallel communicators are not node-aligned: the
        // engine must fall back to flat algorithms and still run.
        let mut c = cfg("vgg16", 8, CommMode::MlslAsync { comm_cores: 2 });
        c.topo = Topology::eth_10g_smp(2);
        c.dist = Distribution::new(8, 4);
        c.iterations = 2;
        let r = simulate(c);
        assert!(r.iter_ns > 0);
    }

    #[test]
    fn comm_metas_are_garbage_collected() {
        // Before the GC fix, `metas` grew by one entry per collective for
        // the whole run; now every completed collective drops its meta.
        let mut c = cfg("resnet50", 4, CommMode::MlslAsync { comm_cores: 2 });
        c.iterations = 3;
        let mut e = Engine::new(c);
        let r = e.run_to_completion();
        assert!(r.iter_ns > 0);
        assert!(e.job.metas.is_empty(), "{} metas leaked", e.job.metas.len());
        assert!(e.job.open.is_empty(), "{} open entries leaked", e.job.open.len());
    }

    #[test]
    fn tuned_selection_policy_runs_and_moves_same_traffic() {
        // Same run under the analytic and a measured-table policy: the
        // algorithms may differ, but the simulation completes and the
        // tuned run is a valid training iteration.
        let topo = Topology::eth_10g_smp(2);
        let mut analytic = cfg("resnet50", 8, CommMode::BulkSync);
        analytic.topo = topo.clone();
        analytic.iterations = 1;
        let mut tuned = analytic.clone();
        let mut spec = crate::tuner::ProbeSpec::quick();
        spec.max_ranks = 8;
        let table = crate::tuner::tune(&topo, &spec);
        tuned.selection = SelectionPolicy::TunedWithFallback(table);
        let ra = simulate(analytic);
        let rt = simulate(tuned);
        assert!(rt.iter_ns > 0);
        // Ring / halving-doubling / hierarchical allreduce all move the
        // same per-node volume; only rdoubling differs, and it only wins
        // tiny layers — total traffic stays within a few percent.
        let ratio = rt.bytes_per_node as f64 / ra.bytes_per_node.max(1) as f64;
        assert!(
            (0.8..1.25).contains(&ratio),
            "tuned={} analytic={}",
            rt.bytes_per_node,
            ra.bytes_per_node
        );
    }

    #[test]
    fn churn_spec_parses_and_validates() {
        let plan = ChurnPlan::parse("leave:3@1,join:3@3,leave:0@2").unwrap();
        assert_eq!(plan.events.len(), 3);
        // Sorted by boundary iteration, written order kept within one.
        assert_eq!(plan.events[0], ChurnEvent { after_iter: 1, op: ChurnOp::Leave(3) });
        assert_eq!(plan.events[1], ChurnEvent { after_iter: 2, op: ChurnOp::Leave(0) });
        assert_eq!(plan.events[2], ChurnEvent { after_iter: 3, op: ChurnOp::Join(3) });
        assert!(plan.validate(4).is_ok());
        assert!(plan.validate(3).is_err(), "rank 3 out of range at p=3");
        for bad in [
            "", "leave:3", "leave:3@", "nuke:3@1", "leave:x@1", "leave:1@y",
            "leave:1@1,leave:1@2",        // left twice
            "join:2@1",                   // never left
            "leave:0@1,leave:1@1",        // no survivors at p=2
        ] {
            let err = ChurnPlan::parse(bad).and_then(|p| p.validate(2));
            assert!(err.is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn churn_leave_shrinks_membership_and_completes() {
        let mut c = cfg("resnet50", 4, CommMode::MlslAsync { comm_cores: 2 });
        c.iterations = 3;
        c.churn = Some(ChurnPlan::parse("leave:3@1").unwrap());
        let mut e = Engine::new(c);
        let r = e.run_to_completion();
        assert!(r.iter_ns > 0);
        assert_eq!(e.active_ranks(), vec![0, 1, 2]);
        assert_eq!(r.churn_log.len(), 1);
        assert!(r.churn_log[0].contains("leave rank 3"), "{:?}", r.churn_log);
        // Quiesce leaves no dangling bookkeeping behind.
        assert!(e.job.metas.is_empty());
        assert!(e.job.open.is_empty());
        // The leaver ran iterations 0 and 1 only.
        assert_eq!(e.job.nodes[3].iter_starts.len(), 2);
        assert_eq!(e.job.nodes[0].iter_starts.len(), 4);
    }

    #[test]
    fn churn_join_rejoins_a_left_rank() {
        let mut c = cfg("resnet50", 4, CommMode::BulkSync);
        c.iterations = 4;
        c.churn = Some(ChurnPlan::parse("leave:2@1,join:2@2").unwrap());
        let mut e = Engine::new(c);
        let r = e.run_to_completion();
        assert!(r.iter_ns > 0);
        assert_eq!(e.active_ranks(), vec![0, 1, 2, 3]);
        assert_eq!(r.churn_log.len(), 2);
        // Rank 2 sat out exactly one iteration (iter 2): starts for
        // iters 0, 1, 3, 4 only.
        assert_eq!(e.job.nodes[2].iter_starts.len(), 4);
        assert_eq!(e.job.nodes[0].iter_starts.len(), 5);
    }

    #[test]
    fn churn_to_single_survivor_still_completes() {
        let mut c = cfg("resnet50", 2, CommMode::BulkSync);
        c.iterations = 2;
        c.churn = Some(ChurnPlan::parse("leave:1@0").unwrap());
        let mut e = Engine::new(c);
        let r = e.run_to_completion();
        assert!(r.iter_ns > 0);
        assert_eq!(e.active_ranks(), vec![0]);
        assert_eq!(r.churn_log.len(), 1);
    }

    #[test]
    fn per_iter_spans_cover_every_boundary() {
        let mut c = cfg("resnet50", 4, CommMode::BulkSync);
        c.iterations = 3;
        let r = simulate(c);
        // 4 iterations (warmup + 3) → 3 boundary-to-boundary spans.
        assert_eq!(r.per_iter_ns.len(), 3);
        assert!(r.per_iter_ns.iter().all(|&d| d > 0));
    }

    #[test]
    fn traced_run_derives_timeline_and_keeps_the_clock() {
        let mut plain = cfg("resnet50", 4, CommMode::MlslAsync { comm_cores: 2 });
        plain.iterations = 1;
        let mut traced = plain.clone();
        traced.record_timeline = true;
        let rp = simulate(plain);
        let rt = simulate(traced);
        assert_eq!(rp.iter_ns, rt.iter_ns, "tracing must not move the clock");
        assert_eq!(rp.bytes_per_node, rt.bytes_per_node);
        assert!(rp.trace.is_none());
        assert!(rp.timeline.spans.is_empty());
        let tr = rt.trace.as_ref().unwrap();
        assert!(tr.span_count() > 0);
        // The Gantt derives node-0 rows exactly like the old recorder:
        // f/b compute spans plus instant issue marks.
        assert!(rt
            .timeline
            .spans
            .iter()
            .any(|s| s.label == "f0" && s.track == "compute"));
        assert!(rt.timeline.spans.iter().any(|s| s.label == "b0"));
        assert!(rt.timeline.spans.iter().any(|s| s.track == "issue"));
        assert!(rt.timeline.spans.iter().all(|s| s.node == 0));
        let gantt = rt.timeline.ascii_gantt(60);
        assert!(gantt.contains("node0"), "{gantt}");
    }

    #[test]
    fn chaos_runs_are_deterministic_and_slower_than_healthy() {
        use crate::fabric::ChaosPlan;
        let topo = Topology::by_name("eth10g-x2e2").unwrap();
        let mk = |chaos: Option<ChaosPlan>| {
            let mut c = cfg("resnet50", 8, CommMode::BulkSync);
            c.topo = topo.clone();
            c.iterations = 2;
            c.chaos = chaos;
            c
        };
        let healthy = simulate(mk(None));
        let horizon = healthy.iter_ns.saturating_mul(4).max(1_000_000);
        let plan = ChaosPlan::generate(42, &topo, 8, horizon);
        let a = simulate(mk(Some(plan.clone())));
        let b = simulate(mk(Some(plan)));
        // Same seed ⇒ identical run, down to every counter.
        assert_eq!(a.iter_ns, b.iter_ns);
        assert_eq!(a.bytes_per_node, b.bytes_per_node);
        assert_eq!(a.chaos, b.chaos);
        // Faults moved the clock, never the traffic.
        assert_eq!(a.bytes_per_node, healthy.bytes_per_node);
        assert!(a.iter_ns >= healthy.iter_ns, "chaos={} healthy={}", a.iter_ns, healthy.iter_ns);
    }

    #[test]
    fn chaos_and_churn_compose() {
        use crate::fabric::ChaosPlan;
        let topo = Topology::by_name("eth10g-x2e2").unwrap();
        let mut c = cfg("resnet50", 8, CommMode::MlslAsync { comm_cores: 2 });
        c.topo = topo.clone();
        c.iterations = 3;
        c.chaos = Some(ChaosPlan::generate(7, &topo, 8, 100_000_000));
        c.churn = Some(ChurnPlan::parse("leave:5@1").unwrap());
        let mut e = Engine::new(c);
        let r = e.run_to_completion();
        assert!(r.iter_ns > 0);
        assert_eq!(e.active_ranks().len(), 7);
        assert!(e.job.metas.is_empty());
    }

    #[test]
    fn wire_auto_compresses_bulk_gradients_on_ethernet() {
        // vgg16's fc layers are deep in bandwidth-bound territory on
        // 10G ethernet: auto precision must pick a compressed wire for
        // them and beat the all-f32 run, without being told a dtype.
        let mut f32c = cfg("vgg16", 8, CommMode::BulkSync);
        f32c.topo = Topology::eth_10g();
        let mut auto = f32c.clone();
        auto.wire_auto = true;
        let rf = simulate(f32c);
        let mut e = Engine::new(auto);
        let ra = e.run_to_completion();
        assert!(
            (rf.exposed_comm_ns as f64 / ra.exposed_comm_ns as f64) > 1.5,
            "f32={} auto={}",
            rf.exposed_comm_ns,
            ra.exposed_comm_ns
        );
        // Every rank sent compressed gradients, so every rank carries a
        // residual bound — positive, below the δ/(1−δ) fixed point of
        // the loosest wire, and symmetric across the lockstep cluster.
        let bounds = e.ef_residual_bound().to_vec();
        let worst_delta = WireDtype::Int8Block.rel_error();
        let cap = worst_delta / (1.0 - worst_delta) + 1e-12;
        for (r, b) in bounds.iter().enumerate() {
            assert!(*b > 0.0 && *b <= cap, "rank {r}: bound {b} vs cap {cap}");
        }
        assert!(bounds.windows(2).all(|w| w[0] == w[1]), "{bounds:?}");
    }

    #[test]
    fn ef_residual_state_survives_churn_without_renumbering() {
        // Rank 2 leaves after iter 1 and rejoins after iter 2. Its
        // error-feedback residual is keyed by its ORIGINAL id, so it
        // resumes the bound it left with instead of restarting at zero
        // — while the ranks that stayed keep compounding theirs.
        let mut c = cfg("vgg16", 4, CommMode::BulkSync);
        c.topo = Topology::eth_10g();
        c.wire = WireDtype::Int8Block;
        c.iterations = 4;
        c.churn = Some(ChurnPlan::parse("leave:2@1,join:2@2").unwrap());
        let mut e = Engine::new(c);
        let r = e.run_to_completion();
        assert!(r.iter_ns > 0);
        let bounds = e.ef_residual_bound();
        let delta = WireDtype::Int8Block.rel_error();
        let cap = delta / (1.0 - delta) + 1e-12;
        for (rk, b) in bounds.iter().enumerate() {
            assert!(*b > 0.0 && *b <= cap, "rank {rk}: bound {b}");
        }
        // The recurrence r ← δ(1+r) is monotone in the iteration count:
        // the rank that sat out one iteration is strictly behind the
        // ranks that never left, but strictly past a fresh joiner.
        assert!(bounds[2] < bounds[0], "{bounds:?}");
        assert!(bounds[2] > delta, "{bounds:?}");
    }

    #[test]
    fn program_and_selection_caches_reach_steady_state() {
        // Collectives repeat every iteration over the same member sets
        // and sizes: a longer run must not grow either cache beyond
        // what the first full iteration established.
        let mk = |iters: usize| {
            let mut c = cfg("resnet50", 4, CommMode::MlslAsync { comm_cores: 2 });
            c.iterations = iters;
            c
        };
        let mut e1 = Engine::new(mk(1));
        e1.run_to_completion();
        let mut e3 = Engine::new(mk(3));
        e3.run_to_completion();
        assert!(!e1.job.prog_cache.is_empty());
        assert_eq!(e1.job.prog_cache.len(), e3.job.prog_cache.len());
        assert_eq!(e1.job.sel_cache.len(), e3.job.sel_cache.len());
    }

    #[test]
    fn standalone_timing_routes_through_the_partitioned_executor() {
        // sim_threads > 1 sends one-shot collective timing through
        // parexec; conservative lookahead is exact, so the answer must
        // be bit-identical to the serial executor's.
        use crate::collectives::program::allreduce_ring;
        let p = 8;
        let n = 1 << 16;
        let mut c = cfg("resnet50", p, CommMode::BulkSync);
        c.topo = Topology::eth_10g();
        c.sim_threads = 2;
        let par = c.time_standalone_collective(p, allreduce_ring(p, n), WireDtype::F32, 1);
        let mut serial_cfg = c.clone();
        serial_cfg.sim_threads = 1;
        let serial =
            serial_cfg.time_standalone_collective(p, allreduce_ring(p, n), WireDtype::F32, 1);
        assert_eq!(par, serial);
        assert!(par > 0);
    }

    #[test]
    fn chaos_slowdown_feeds_the_wire_pricer() {
        use crate::fabric::ChaosPlan;
        let mut c = cfg("resnet50", 4, CommMode::BulkSync);
        assert_eq!(c.max_chaos_slowdown_milli(), 1000, "healthy default");
        let mut plan = ChaosPlan::quiet(1, 4);
        plan.slowdown_milli = vec![1000, 2100, 1000, 1300];
        c.chaos = Some(plan);
        assert_eq!(c.max_chaos_slowdown_milli(), 2100);
    }

    #[test]
    fn int8_wire_reduces_exposed_comm() {
        let mut f32c = cfg("vgg16", 8, CommMode::BulkSync);
        f32c.topo = Topology::eth_10g();
        let mut i8c = f32c.clone();
        i8c.wire = WireDtype::Int8Block;
        let rf = simulate(f32c);
        let ri = simulate(i8c);
        assert!(
            (rf.exposed_comm_ns as f64 / ri.exposed_comm_ns as f64) > 3.0,
            "f32={} int8={}",
            rf.exposed_comm_ns,
            ri.exposed_comm_ns
        );
    }
}

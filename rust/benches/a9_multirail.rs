//! **Ablation A9**: multi-rail NIC striping — rail-striped chunk
//! programs on `e<l>` fabrics.
//!
//! Real Cloud/HPC nodes aggregate 2–4 NIC rails; a single-endpoint
//! communication path leaves most of the injection bandwidth idle
//! (ROADMAP "Multi-rail NICs"). `fabric::sim` now gives every node one
//! egress server per rail and `NetSim::send` stripes a transfer's whole
//! chunks across them with the pure assignment `(chunk + src) % rails`.
//! The observable contract this bench ASSERTS:
//!
//! * bandwidth-bound allreduce (1 MiB per-step segments = 4 chunks on
//!   eth10g) at p >= 64 speeds up near-linearly: >= 1.8x at 2 rails,
//!   >= 3.2x at 4 rails;
//! * latency-bound sizes are untouched (within 2 percent — in fact the
//!   striping is byte-identical there: sub-chunk messages ride ONE rail
//!   and pay one overhead);
//! * the analytic rail-aware cost model tracks the simulator on striped
//!   fabrics, and tuned selection measured on a striped fabric picks the
//!   per-cell winners there;
//! * tuner fingerprint v3 rejects single-rail tables on striped fabrics:
//!   `TunedWithFallback` answers with the analytic choice instead of a
//!   wrong table pick.
//!
//! Run: `cargo bench --bench a9_multirail`

use mlsl::collectives::program::{build, CollectiveKind};
use mlsl::collectives::selector::{choose_algorithm, predict_allreduce_ns};
use mlsl::collectives::simexec::time_collective;
use mlsl::collectives::{Algorithm, WireDtype};
use mlsl::fabric::topology::Topology;
use mlsl::fabric::NetSim;
use mlsl::metrics::print_table;
use mlsl::tuner::table::fingerprint;
use mlsl::tuner::{tune, ProbeSpec, SelectionPolicy};
use mlsl::util::stats::fmt_bytes;

fn simulate(topo: &Topology, alg: Algorithm, p: usize, bytes: u64) -> u64 {
    let n = (bytes / 4).max(1) as usize;
    let programs =
        build(CollectiveKind::Allreduce, alg, p, n).expect("bench algorithms are buildable");
    time_collective(&mut NetSim::new(topo.clone(), p), programs, WireDtype::F32, 1)
}

fn main() {
    let base = Topology::eth_10g(); // 256 KiB chunks
    let e2 = base.clone().with_rails(2).unwrap();
    let e4 = base.clone().with_rails(4).unwrap();

    // -- near-linear rail speedup for bandwidth-bound allreduce ---------
    let mut rows = Vec::new();
    for p in [64usize, 128] {
        // 1 MiB per-rank segment => 4 whole chunks per ring step: enough
        // chunks in flight to occupy all 4 rails at every rank count.
        let bw_bytes = (p as u64) << 20;
        let t1 = simulate(&base, Algorithm::Ring, p, bw_bytes);
        let t2 = simulate(&e2, Algorithm::Ring, p, bw_bytes);
        let t4 = simulate(&e4, Algorithm::Ring, p, bw_bytes);
        let s2 = t1 as f64 / t2.max(1) as f64;
        let s4 = t1 as f64 / t4.max(1) as f64;
        assert!(s2 >= 1.8, "p={p}: 2-rail speedup {s2:.2} < 1.8 (t1={t1} t2={t2})");
        assert!(s4 >= 3.2, "p={p}: 4-rail speedup {s4:.2} < 3.2 (t1={t1} t4={t4})");
        rows.push(vec![
            p.to_string(),
            fmt_bytes(bw_bytes),
            format!("{:.3}", t1 as f64 / 1e6),
            format!("{s2:.2}x"),
            format!("{s4:.2}x"),
        ]);

        // Latency-bound sizes: zero regression (+-2%). Every message is
        // under one chunk, so striping must not engage at all.
        for small in [4u64 << 10, 64 << 10] {
            let algs: &[Algorithm] = if p.is_power_of_two() {
                &[Algorithm::Ring, Algorithm::RecursiveDoubling]
            } else {
                &[Algorithm::Ring]
            };
            for &alg in algs {
                let l1 = simulate(&base, alg, p, small);
                for (rails, striped) in [(2u32, &e2), (4, &e4)] {
                    let lr = simulate(striped, alg, p, small);
                    let drift = (lr as f64 / l1.max(1) as f64 - 1.0).abs();
                    assert!(
                        drift <= 0.02,
                        "p={p} {alg} {small}B at {rails} rails: {lr} vs {l1}"
                    );
                }
            }
        }
    }
    print_table(
        "A9: ring allreduce rail speedup, eth10g (1 MiB/rank, 256 KiB chunks)",
        &["ranks", "size", "1-rail ms", "2-rail speedup", "4-rail speedup"],
        &rows,
    );

    // -- analytic self-consistency on striped fabrics -------------------
    // The rail-aware alpha-beta model must track the simulator within
    // the same slack the single-rail model is held to.
    for (topo, label) in [(&e2, "e2"), (&e4, "e4")] {
        let p = 64usize;
        let bytes = 64u64 << 20;
        let measured = simulate(topo, Algorithm::Ring, p, bytes);
        let predicted = predict_allreduce_ns(topo, Algorithm::Ring, p, bytes);
        let ratio = measured as f64 / predicted.max(1) as f64;
        assert!(
            (0.8..1.25).contains(&ratio),
            "{label}: measured={measured} predicted={predicted}"
        );
        // Shape stays consistent: fewest rounds small, bandwidth-optimal
        // large.
        assert_eq!(choose_algorithm(topo, 64, 1024), Algorithm::RecursiveDoubling, "{label}");
        let large = choose_algorithm(topo, 64, 256 << 20);
        assert!(
            matches!(large, Algorithm::Ring | Algorithm::HalvingDoubling),
            "{label}: {large:?}"
        );
    }

    // -- tuned selection on a striped fabric ----------------------------
    let mut spec = ProbeSpec::quick();
    spec.max_ranks = 8;
    let striped_table = tune(&e2, &spec);
    assert!(striped_table.matches(&e2));
    let tuned = SelectionPolicy::TunedWithFallback(striped_table.clone());
    for cell in striped_table.cells(CollectiveKind::Allreduce) {
        let pick = tuned.choose_allreduce(&e2, cell.ranks, cell.bytes);
        assert_eq!(
            pick,
            cell.best().expect("measured cell").0,
            "tuned pick p={} bytes={}",
            cell.ranks,
            cell.bytes
        );
    }

    // -- fingerprint v3: single-rail tables are rejected ----------------
    let single_table = tune(&base, &spec);
    assert_ne!(fingerprint(&base), fingerprint(&e2), "v3 hashes rail counts");
    assert!(!single_table.matches(&e2), "single-rail table must not match striped fabric");
    let fallback = SelectionPolicy::TunedWithFallback(single_table);
    for p in [4usize, 8] {
        for bytes in [1u64 << 10, 1 << 20, 4 << 20] {
            assert_eq!(
                fallback.choose_allreduce(&e2, p, bytes),
                choose_algorithm(&e2, p, bytes),
                "fingerprint mismatch must fall back to the analytic pick (p={p})"
            );
        }
    }

    println!("\nexpected shape: striping splits each >=2-chunk transfer across rails, so the");
    println!("ring's per-step wire time divides by the rail count while alpha (overhead +");
    println!("latency, ~34 us on eth10g) is paid once — speedup 1.9x / 3.6x at 2 / 4 rails");
    println!("for 1 MiB segments, converging to the rail count as segments grow. Sub-chunk");
    println!("messages never stripe: latency-bound timings are byte-identical. Tuned");
    println!("selection probed on the striped fabric picks its measured winners; a");
    println!("single-rail table is rejected by the v3 fingerprint. OK");
}

//! Size-adaptive algorithm selection — the paper's "implements performance
//! critical data path operations in an optimal manner".
//!
//! The choice is driven by the alpha-beta cost model on the actual fabric:
//!
//! * ring allreduce:            2(P−1)·(α + γ + (n/P)/B)
//! * recursive doubling:        log₂P·(α + γ + n/B)
//! * halving-doubling:          2·log₂P·(α + γ) + 2(P−1)/P·n/B
//!
//! Small n → latency term dominates → recursive doubling (fewest rounds).
//! Large n → bandwidth term dominates → ring / halving-doubling.

use super::Algorithm;
use crate::fabric::topology::Topology;
use crate::Ns;

/// Predicted wall time of an allreduce of `bytes` over `p` ranks.
pub fn predict_allreduce_ns(topo: &Topology, alg: Algorithm, p: usize, bytes: u64) -> Ns {
    if p <= 1 {
        return 0;
    }
    let alpha = (topo.latency_ns + topo.per_msg_overhead_ns) as f64;
    let n = bytes as f64;
    let bw = super::super::fabric::gbps_to_bytes_per_ns(topo.link_gbps);
    let pf = p as f64;
    let lg = (p as f64).log2().ceil();
    let t = match alg {
        Algorithm::Ring => 2.0 * (pf - 1.0) * (alpha + n / pf / bw),
        Algorithm::RecursiveDoubling => lg * (alpha + n / bw),
        Algorithm::HalvingDoubling => 2.0 * lg * alpha + 2.0 * (pf - 1.0) / pf * n / bw,
        Algorithm::Auto => {
            let best = choose_algorithm(topo, p, bytes);
            return predict_allreduce_ns(topo, best, p, bytes);
        }
    };
    t.ceil() as Ns
}

/// Pick the cheapest supported algorithm for this (fabric, p, bytes).
pub fn choose_algorithm(topo: &Topology, p: usize, bytes: u64) -> Algorithm {
    if p <= 1 {
        return Algorithm::Ring;
    }
    let mut candidates = vec![Algorithm::Ring];
    if p.is_power_of_two() {
        candidates.push(Algorithm::RecursiveDoubling);
        candidates.push(Algorithm::HalvingDoubling);
    }
    *candidates
        .iter()
        .min_by_key(|a| predict_allreduce_ns(topo, **a, p, bytes))
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_messages_pick_fewest_rounds() {
        let topo = Topology::eth_10g();
        // 4 KB over 64 ranks: latency-bound -> recursive doubling.
        assert_eq!(choose_algorithm(&topo, 64, 4 * 1024), Algorithm::RecursiveDoubling);
    }

    #[test]
    fn large_messages_pick_bandwidth_optimal() {
        let topo = Topology::eth_10g();
        let alg = choose_algorithm(&topo, 64, 256 << 20);
        assert!(
            matches!(alg, Algorithm::Ring | Algorithm::HalvingDoubling),
            "{alg:?}"
        );
    }

    #[test]
    fn non_pow2_always_ring() {
        let topo = Topology::omnipath_100g();
        assert_eq!(choose_algorithm(&topo, 6, 1024), Algorithm::Ring);
        assert_eq!(choose_algorithm(&topo, 100, 1 << 20), Algorithm::Ring);
    }

    #[test]
    fn prediction_monotone_in_size() {
        let topo = Topology::omnipath_100g();
        for alg in [Algorithm::Ring, Algorithm::RecursiveDoubling, Algorithm::HalvingDoubling] {
            let a = predict_allreduce_ns(&topo, alg, 16, 1 << 10);
            let b = predict_allreduce_ns(&topo, alg, 16, 1 << 24);
            assert!(b > a, "{alg:?}");
        }
    }

    #[test]
    fn single_rank_is_free() {
        let topo = Topology::eth_10g();
        assert_eq!(predict_allreduce_ns(&topo, Algorithm::Auto, 1, 1 << 20), 0);
    }

    #[test]
    fn crossover_exists() {
        // Sweeping sizes must switch algorithms somewhere (the A4 bench
        // regenerates the full crossover table).
        let topo = Topology::eth_10g();
        let small = choose_algorithm(&topo, 32, 1024);
        let large = choose_algorithm(&topo, 32, 64 << 20);
        assert_ne!(small, large);
    }
}

//! Node-group hybrid parallelism — the paper's `Distribution` object.
//!
//! "nodes within a group employ model parallelism and data parallelism is
//! used across groups. One could consider data and model parallelism as
//! two extreme design points of hybrid parallelism with node group size
//! being one and all nodes respectively."

use crate::Rank;

/// Partition of `world` ranks into `num_groups() = world/group_size`
/// model-parallel groups; data parallelism runs across groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Distribution {
    world: usize,
    group_size: usize,
}

impl Distribution {
    /// `group_size` must divide `world`.
    pub fn new(world: usize, group_size: usize) -> Self {
        assert!(world >= 1);
        assert!(group_size >= 1 && group_size <= world, "group {group_size} vs world {world}");
        assert_eq!(world % group_size, 0, "group size must divide world");
        Self { world, group_size }
    }

    /// Pure data parallelism (groups of one).
    pub fn data_parallel(world: usize) -> Self {
        Self::new(world, 1)
    }

    /// Pure model parallelism (one group of all).
    pub fn model_parallel(world: usize) -> Self {
        Self::new(world, world)
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn group_size(&self) -> usize {
        self.group_size
    }

    pub fn num_groups(&self) -> usize {
        self.world / self.group_size
    }

    pub fn is_pure_data(&self) -> bool {
        self.group_size == 1
    }

    pub fn is_pure_model(&self) -> bool {
        self.group_size == self.world
    }

    /// Group index of `rank` (ranks are grouped contiguously).
    pub fn group_of(&self, rank: Rank) -> usize {
        assert!(rank < self.world);
        rank / self.group_size
    }

    /// Position of `rank` inside its group (the model-parallel rank).
    pub fn rank_in_group(&self, rank: Rank) -> usize {
        rank % self.group_size
    }

    /// Members of `rank`'s model-parallel group, in group order.
    pub fn group_members(&self, rank: Rank) -> Vec<Rank> {
        let g = self.group_of(rank);
        (0..self.group_size).map(|i| g * self.group_size + i).collect()
    }

    /// The data-parallel communicator of `rank`: same in-group position
    /// across all groups (this is who the weight-shard allreduce spans).
    pub fn data_peers(&self, rank: Rank) -> Vec<Rank> {
        let pos = self.rank_in_group(rank);
        (0..self.num_groups()).map(|g| g * self.group_size + pos).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extremes() {
        let d = Distribution::data_parallel(8);
        assert!(d.is_pure_data());
        assert_eq!(d.num_groups(), 8);
        assert_eq!(d.group_members(5), vec![5]);
        assert_eq!(d.data_peers(5), (0..8).collect::<Vec<_>>());

        let m = Distribution::model_parallel(8);
        assert!(m.is_pure_model());
        assert_eq!(m.num_groups(), 1);
        assert_eq!(m.group_members(3), (0..8).collect::<Vec<_>>());
        assert_eq!(m.data_peers(3), vec![3]);
    }

    #[test]
    fn hybrid_grouping() {
        let h = Distribution::new(8, 4);
        assert_eq!(h.num_groups(), 2);
        assert_eq!(h.group_of(0), 0);
        assert_eq!(h.group_of(5), 1);
        assert_eq!(h.group_members(5), vec![4, 5, 6, 7]);
        assert_eq!(h.rank_in_group(5), 1);
        assert_eq!(h.data_peers(5), vec![1, 5]);
    }

    #[test]
    fn peers_partition_world() {
        let h = Distribution::new(12, 3);
        // Every rank appears in exactly one group and one data-peer set
        // per position.
        let mut seen = vec![0; 12];
        for g in 0..h.num_groups() {
            for r in h.group_members(g * 3) {
                seen[r] += 1;
            }
        }
        assert!(seen.iter().all(|c| *c == 1));
    }

    #[test]
    #[should_panic(expected = "divide")]
    fn rejects_nondividing_group() {
        Distribution::new(10, 4);
    }
}

//! Minimal property-testing harness (offline replacement for `proptest`).
//!
//! Runs a property over many PRNG-generated cases with linear input
//! shrinking on failure (halve sizes until the property passes again,
//! report the smallest failing case). Used by the randomized invariant
//! tests in `rust/tests/prop_*.rs`.

use super::prng::Prng;

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 256, seed: 0xC0FFEE }
    }
}

/// Run `prop` over `cases` random inputs produced by `gen`.
/// On failure, retries with progressively "smaller" seeds derived from the
/// failing case index and panics with the case number + seed so the exact
/// failure reproduces with `reproduce(seed, case)`.
pub fn run<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Prng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let mut rng = Prng::seed(cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (case {case}, seed {:#x}):\n  input: {input:?}\n  error: {msg}",
                cfg.seed
            );
        }
    }
}

/// Reconstruct the PRNG for a reported failing case.
pub fn reproduce(seed: u64, case: usize) -> Prng {
    Prng::seed(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        run(
            Config { cases: 50, seed: 1 },
            |r| r.below(100),
            |v| if *v < 100 { Ok(()) } else { Err("impossible".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        run(
            Config { cases: 50, seed: 1 },
            |r| r.below(100),
            |v| if *v < 30 { Ok(()) } else { Err(format!("{v} too big")) },
        );
    }

    #[test]
    fn reproduce_matches_run() {
        let mut captured = Vec::new();
        run(
            Config { cases: 3, seed: 77 },
            |r| r.next_u64(),
            |v| {
                captured.push(*v);
                Ok(())
            },
        );
        for (case, want) in captured.iter().enumerate() {
            let mut rng = reproduce(77, case);
            assert_eq!(rng.next_u64(), *want);
        }
    }
}

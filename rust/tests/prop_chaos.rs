//! Property tests for chaos-mode fault injection: faults bend the
//! CLOCK, never the data — and everything is a pure function of the
//! seed.
//!
//! Three invariant families:
//!
//! * **seed determinism** — the same `ChaosPlan` seed derives the same
//!   plan, and two simulators running it over the same traffic emit
//!   byte-identical event streams down to every fault counter;
//! * **no corrupt payloads** — a collective executed under link flaps,
//!   rail deaths and slowdowns completes and delivers exactly the
//!   healthy run's multiset of logical messages (same sources,
//!   destinations and byte counts — faults may only delay them);
//! * **work conservation across rail death** — when a rail dies
//!   mid-transfer its queued pieces migrate to surviving rails and the
//!   summed per-rail busy time still accounts for the whole transfer,
//!   matching the healthy single-rail run within per-piece rounding.

use mlsl::collectives::program::{build, CollectiveKind};
use mlsl::collectives::simexec::SimCollectives;
use mlsl::collectives::{Algorithm as A, WireDtype};
use mlsl::fabric::topology::Topology;
use mlsl::fabric::{ChaosPlan, MsgDesc, NetSim, RailDeath, SimEvent};
use mlsl::util::proptest::{run as prop_run, Config};

/// Flat multi-rail test fabric (8 Gbps = 1 B/ns per rail, 512-byte
/// chunks) — the same physics as prop_rails, so striping engages.
fn flat_topo(rails: u32, gamma: u64) -> Topology {
    Topology::flat("chaostest", 8.0, 1_000, gamma, 512)
        .with_rails(rails)
        .unwrap()
}

#[test]
fn prop_same_seed_same_plan_same_event_stream() {
    let topo = Topology::by_name("eth10g-x2e2").unwrap();
    let p = 8;
    prop_run(
        Config { cases: 60, seed: 81 },
        |r| {
            let seed = r.below(u64::MAX);
            let horizon = 10_000 + r.below(10_000_000);
            let k = 1 + r.usize_below(8);
            let msgs: Vec<MsgDesc> = (0..k)
                .map(|i| {
                    let src = r.usize_below(p);
                    let dst = (src + 1 + r.usize_below(p - 1)) % p;
                    MsgDesc {
                        src,
                        dst,
                        bytes: 1 + r.below(64 << 10),
                        priority: r.below(4) as u8,
                        tag: i as u64,
                    }
                })
                .collect();
            (seed, horizon, msgs)
        },
        |(seed, horizon, msgs)| {
            // Plan derivation is a pure function of its arguments.
            let plan = ChaosPlan::generate(*seed, &topo, p, *horizon);
            if plan != ChaosPlan::generate(*seed, &topo, p, *horizon) {
                return Err(format!("seed {seed}: plan derivation not deterministic"));
            }
            // Two independent simulators under the same plan and traffic:
            // byte-identical event streams, identical fault accounting.
            let run = |plan: ChaosPlan| {
                let mut sim = NetSim::new(topo.clone(), p);
                sim.set_chaos(plan);
                for m in msgs {
                    sim.send(m.clone());
                }
                (sim.drain(), sim.chaos_stats)
            };
            let (ev_a, stats_a) = run(plan.clone());
            let (ev_b, stats_b) = run(plan);
            if ev_a != ev_b {
                return Err(format!("seed {seed}: event streams diverged"));
            }
            if stats_a != stats_b {
                return Err(format!(
                    "seed {seed}: fault counters diverged ({stats_a:?} vs {stats_b:?})"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_faulted_collectives_deliver_uncorrupted_payloads() {
    prop_run(
        Config { cases: 60, seed: 82 },
        |r| {
            let p = 2 + r.usize_below(7); // 2..9
            let n = 1 + r.usize_below(2_000);
            let seed = r.below(u64::MAX);
            let alg = if p.is_power_of_two() && r.below(2) == 0 {
                A::RecursiveDoubling
            } else {
                A::Ring
            };
            let kind = if r.below(2) == 0 {
                CollectiveKind::Allreduce
            } else {
                CollectiveKind::Allgather
            };
            (p, n, seed, kind, alg)
        },
        |&(p, n, seed, kind, alg)| {
            type Delivered = Vec<(usize, usize, u64)>;
            let topo = flat_topo(4, 100);
            let progs = build(kind, alg, p, n).map_err(|e| e.to_string())?;
            let run = |chaos: Option<ChaosPlan>| -> Result<(Delivered, u64), String> {
                let mut sim = NetSim::new(topo.clone(), p);
                if let Some(plan) = chaos {
                    sim.set_chaos(plan);
                }
                let mut exec = SimCollectives::new();
                let mut completions = exec.post(&mut sim, 1, progs.clone(), WireDtype::F32, 1);
                let mut delivered = Vec::new();
                while exec.in_flight() > 0 {
                    let ev = sim
                        .next()
                        .ok_or_else(|| format!("{kind:?}/{alg} p={p}: deadlock under faults"))?;
                    if let SimEvent::MsgDelivered { msg, .. } = &ev {
                        delivered.push((msg.src, msg.dst, msg.bytes));
                    }
                    exec.on_event_into(&mut sim, &ev, &mut completions);
                }
                if completions.len() != p {
                    return Err(format!(
                        "{kind:?}/{alg} p={p}: {} of {p} ranks completed",
                        completions.len()
                    ));
                }
                delivered.sort_unstable();
                Ok((delivered, sim.stats.bytes_sent))
            };
            let (healthy, healthy_bytes) = run(None)?;
            // A horizon spanning the healthy run so the faults actually
            // overlap the collective's lifetime.
            let plan = ChaosPlan::generate(seed, &topo, p, 200_000);
            let (faulted, faulted_bytes) = run(Some(plan))?;
            if faulted != healthy {
                return Err(format!(
                    "{kind:?}/{alg} p={p} seed={seed}: faulted run delivered a \
                     different logical-message multiset"
                ));
            }
            if faulted_bytes != healthy_bytes {
                return Err(format!(
                    "{kind:?}/{alg} p={p} seed={seed}: faulted run moved \
                     {faulted_bytes} bytes, healthy moved {healthy_bytes}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rail_death_mid_transfer_conserves_work() {
    prop_run(
        Config { cases: 100, seed: 83 },
        |r| {
            // At least one whole chunk so striping engages; gamma = 0 so
            // busy time is pure wire work.
            let bytes = 512 + r.below(40_000);
            let rails = [2u32, 4][r.usize_below(2)];
            let rail = r.below(rails as u64) as u32;
            let at = r.below(bytes); // 1 B/ns: somewhere inside the transfer
            (bytes, rails, rail, at)
        },
        |&(bytes, rails, rail, at)| {
            // Healthy single-rail reference.
            let mut s1 = NetSim::new(flat_topo(1, 0), 2);
            s1.send(MsgDesc { src: 0, dst: 1, bytes, priority: 1, tag: 1 });
            s1.drain();
            let single = s1.nic_busy_ns(0);
            // Striped run with one rail dying mid-transfer.
            let mut sr = NetSim::new(flat_topo(rails, 0), 2);
            sr.set_chaos(ChaosPlan {
                seed: 0,
                flaps: Vec::new(),
                rail_deaths: vec![RailDeath { node: 0, rail, at }],
                slowdown_milli: vec![1000; 2],
            });
            sr.send(MsgDesc { src: 0, dst: 1, bytes, priority: 1, tag: 1 });
            let events = sr.drain();
            if !events
                .iter()
                .any(|e| matches!(e, SimEvent::MsgDelivered { msg, .. } if msg.bytes == bytes))
            {
                return Err(format!("bytes={bytes} rails={rails}: message never delivered"));
            }
            if !sr.rail_dead(0, rail as usize) {
                return Err(format!("rail {rail} still alive after its death event"));
            }
            if sr.alive_rails(0) != rails as usize - 1 {
                return Err(format!("expected {} surviving rails", rails - 1));
            }
            let summed: u64 = (0..sr.num_rails()).map(|i| sr.rail_busy_ns(0, i)).sum();
            if summed != sr.nic_busy_ns(0) {
                return Err("nic_busy_ns must be the per-rail sum".into());
            }
            // Work conservation: the dying rail's queued pieces migrate
            // with their remaining wire time intact; each of the <= rails
            // pieces rounds at most 1 ns.
            if summed.abs_diff(single) > rails as u64 {
                return Err(format!(
                    "bytes={bytes} rails={rails} death@{at}: summed per-rail \
                     busy {summed} vs single-rail {single}"
                ));
            }
            Ok(())
        },
    );
}

//! Collectives: algorithms, wire formats, priorities, selection.
//!
//! A collective is compiled into one *chunk program per rank*
//! ([`program`]): an ordered list of steps, each an optional send and an
//! optional receive(+reduce) over an element range. The same programs are
//! executed two ways:
//!
//! * **really** — [`exec`] moves actual bytes over the in-process
//!   [`crate::fabric::shm`] fabric (the training path), with low-precision
//!   wire formats from [`quant`];
//! * **symbolically** — [`verify`] checks algebraic correctness (every
//!   rank ends with every rank's contribution exactly once), which is the
//!   proptest invariant; and the [`crate::engine`] *times* them against
//!   the discrete-event fabric.
//!
//! Algorithm choice ([`selector`]) follows the paper's "implements
//! performance critical data path operations in an optimal manner":
//! latency-optimal recursive doubling for small payloads,
//! bandwidth-optimal ring for large ones, halving-doubling in between —
//! for allgather too (ring vs block-doubling). The closed forms here are
//! the *analytic* arm of [`crate::tuner::SelectionPolicy`]; the tuned arm
//! replaces them with crossovers measured by running these same programs
//! through [`simexec`] on the live topology.
//!
//! ## Two-tier (hierarchical) collectives
//!
//! On multi-rank-per-node fabrics ([`crate::fabric::topology::Topology`]
//! with `ranks_per_node > 1`) a flat algorithm pays inter-node alpha for
//! almost every step. [`Algorithm::Hierarchical`] instead composes three
//! phases in one chunk program per rank:
//!
//! 1. **intra-node reduce** — binomial tree onto each node's leader rank
//!    over the fast shared-memory tier;
//! 2. **inter-node allreduce** — the existing ring / halving-doubling
//!    among the leaders only (one rank per node on the wire);
//! 3. **intra-node broadcast** — binomial tree from the leader.
//!
//! The step count on the slow tier drops from `O(p)` to `O(p /
//! ranks_per_node)`; the selector prices both tiers with the two-tier
//! alpha–beta model and picks hierarchical vs. flat per message size.

pub mod exec;
pub mod priority;
pub mod program;
pub mod quant;
pub mod selector;
pub mod simexec;
pub mod verify;

pub use priority::PriorityPolicy;
pub use program::{CollectiveKind, Program, Range, RecvStep, SendStep, Step};
pub use quant::WireDtype;
pub use selector::choose_algorithm;

/// Reduction operator applied element-wise during reducing receives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
    Min,
}

impl ReduceOp {
    #[inline]
    pub fn apply(&self, a: f32, b: f32) -> f32 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Max => a.max(b),
            ReduceOp::Min => a.min(b),
        }
    }
}

/// Collective algorithm family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Pipeline ring: bandwidth-optimal, 2(P−1) steps of n/P elements.
    Ring,
    /// Recursive doubling on the full buffer: log₂P steps of n elements —
    /// latency-optimal for small messages. P must be a power of two.
    RecursiveDoubling,
    /// Rabenseifner reduce-scatter-halving + allgather-doubling:
    /// bandwidth-optimal with log₂P steps. P must be a power of two.
    HalvingDoubling,
    /// Two-level hierarchical allreduce for multi-rank-per-node fabrics:
    /// intra-node binomial reduce to a leader, flat allreduce among the
    /// leaders over the inter-node tier, intra-node broadcast back.
    /// `ranks_per_node` must divide P (contiguous node grouping).
    Hierarchical { ranks_per_node: usize },
    /// Let the library pick per message size / rank count (the default).
    Auto,
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Algorithm::Ring => "ring",
            Algorithm::RecursiveDoubling => "rdoubling",
            Algorithm::HalvingDoubling => "halving",
            Algorithm::Hierarchical { .. } => "hier",
            Algorithm::Auto => "auto",
        };
        f.write_str(s)
    }
}

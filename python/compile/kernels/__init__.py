"""Pallas kernels (L1) + pure-jnp oracles for the mlsl-rs model stack.

Every kernel is lowered with interpret=True (CPU-PJRT executable HLO);
see DESIGN.md §Hardware-Adaptation for the TPU mapping rationale.
"""

from . import ref  # noqa: F401
from .attn import attention  # noqa: F401
from .layernorm import layernorm  # noqa: F401
from .matmul import matmul_bias_act  # noqa: F401
from .quantize import dequantize_int8, quantize_int8  # noqa: F401
from .ref import QBLOCK  # noqa: F401
from .sgd import sgd_momentum  # noqa: F401

//! **Claim C1**: message prioritization gives a 1.8×–2.2× reduction in
//! exposed communication time for ResNet-50 / VGG-16 / GoogLeNet on
//! Skylake + 10 Gbps Ethernet.
//!
//! The benefit of prioritization depends on how much of the communication
//! is hideable: when compute fully hides comm both policies expose ~0;
//! when comm utterly dominates, ordering cannot reduce the total. The
//! paper's systems sat in the partial-overlap regime. This bench sweeps
//! the per-node batch (which moves the compute window) and reports the
//! exposed-comm ratio FIFO/ByLayer per model, flagging the partial-overlap
//! operating points.
//!
//! Run: `cargo bench --bench c1_prioritization`

mod common;

use common::{cfg, ms};
use mlsl::collectives::PriorityPolicy;
use mlsl::engine::{simulate, CommMode};
use mlsl::fabric::topology::Topology;
use mlsl::metrics::print_table;

fn main() {
    let models = ["resnet50", "vgg16", "googlenet"];
    let batches = [4usize, 8, 12, 16, 24, 32, 48, 64];
    let p = 16;

    for model in models {
        let mut rows = Vec::new();
        let mut band_hits = Vec::new();
        for b in batches {
            let mut with = cfg(model, Topology::eth_10g(), p, b,
                               CommMode::MlslAsync { comm_cores: 2 });
            with.policy = PriorityPolicy::ByLayer;
            let rw = simulate(with);
            let mut without = cfg(model, Topology::eth_10g(), p, b,
                                  CommMode::MlslAsync { comm_cores: 2 });
            without.policy = PriorityPolicy::None;
            let ro = simulate(without);
            let ratio = ro.exposed_comm_ns as f64 / rw.exposed_comm_ns.max(1) as f64;
            let overlap_regime = {
                let frac = ro.exposed_comm_ns as f64 / ro.iter_ns as f64;
                (0.02..0.6).contains(&frac)
            };
            if overlap_regime && ratio > 1.2 {
                band_hits.push((b, ratio));
            }
            rows.push(vec![
                b.to_string(),
                ms(rw.exposed_comm_ns),
                ms(ro.exposed_comm_ns),
                format!("{ratio:.2}x"),
                if overlap_regime { "partial-overlap".into() } else { "".to_string() },
            ]);
        }
        print_table(
            &format!("C1: {model}, {p} nodes, 10GbE — exposed comm, ByLayer vs FIFO"),
            &["batch/node", "priority ms", "fifo ms", "reduction", "regime"],
            &rows,
        );
        if let Some((b, r)) = band_hits
            .iter()
            .min_by(|a, c| (a.1 - 2.0).abs().partial_cmp(&(c.1 - 2.0).abs()).unwrap())
        {
            println!("  headline point: batch {b}/node -> {r:.2}x reduction (paper band: 1.8-2.2x)");
        }
    }
    println!("\npaper: 1.8x-2.2x exposed-communication reduction on these three topologies.");
}

//! Minimal in-tree replacement for the `anyhow` crate.
//!
//! This image builds offline (no crates.io access), so the subset of the
//! anyhow API the workspace actually uses is implemented here: [`Error`],
//! [`Result`], the [`Context`] extension trait (on both `Result` and
//! `Option`), and the [`anyhow!`] / [`ensure!`] / [`bail!`] macros.
//!
//! Like the real crate, [`Error`] deliberately does NOT implement
//! `std::error::Error` — that is what allows the blanket
//! `From<E: std::error::Error>` conversion to coexist with the identity
//! `From<Error>` impl that `?` needs.

use std::fmt;

/// An error message with a stack of human-readable context frames.
pub struct Error {
    msg: String,
    /// Context frames, innermost first (as attached).
    context: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), context: Vec::new() }
    }

    fn wrap<C: fmt::Display>(mut self, c: C) -> Self {
        self.context.push(c.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Outermost context first, root cause last.
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting the error to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach human-readable context to errors (and missing `Option` values).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::Error::msg(format!($($t)*))
    };
}

/// Return early with a formatted [`Error`] when `cond` is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($t)*)));
        }
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::Error::msg(format!($($t)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let v: u32 = s.parse().context("not an integer")?;
        ensure!(v < 100, "{v} out of range");
        Ok(v)
    }

    #[test]
    fn context_chains_display_outermost_first() {
        let e = parse("x").unwrap_err();
        assert_eq!(format!("{e}"), "not an integer: invalid digit found in string");
    }

    #[test]
    fn ensure_and_ok_paths() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("200").is_err());
    }

    #[test]
    fn option_context_and_macro() {
        let none: Option<u32> = None;
        assert!(none.context("missing").is_err());
        let e: Error = anyhow!("bad {}", 7);
        assert_eq!(format!("{e:?}"), "bad 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io().is_err());
    }
}

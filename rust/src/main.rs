//! `mlsl` — the launcher.
//!
//! Subcommands:
//! * `info      --model resnet50` — layer table + compute/comm analysis
//! * `simulate  --model resnet50 --nodes 64 --topo opa --mode mlsl` —
//!   simulated distributed training, prints the iteration report
//!   (`--tuning-table t.json` selects algorithms from measurements)
//! * `scaling   --model resnet50 --nodes 1,2,4,...` — efficiency table
//! * `tune      --topo eth10g-x2 --out t.json` — measure a collective
//!   tuning table on a topology (every candidate algorithm across a
//!   log-spaced rank-count × message-size grid; `--quick` for a tiny CI
//!   grid) and print the measured crossovers
//! * `topo      eth10g-x8r16e2` — dump the parsed tier stack of a preset
//!   (per-tier group size, gbps, latency, overhead, shm flag, rails), so
//!   suffix-grammar mistakes are inspectable without reading simulator
//!   output
//! * `trace     eth10g-x2 --ranks 16 --out t.json` — traced ring
//!   allreduce on a preset: serial vs partitioned merged-trace identity
//!   check, critical-path decomposition, windowed utilization, metrics
//!   counters, optional Chrome trace-event export (`docs/TRACING.md`)
//! * `train     --artifacts artifacts/small --ranks 2 --steps 100` — the
//!   REAL data-parallel trainer over PJRT + prioritized collectives

use anyhow::{anyhow, Context, Result};

use mlsl::analytic::{best_parallelism, ratio, Parallelism};
use mlsl::collectives::{PriorityPolicy, WireDtype};
use mlsl::config::engine_config;
use mlsl::engine::simulate;
use mlsl::fabric::topology::Topology;
use mlsl::metrics::print_table;
use mlsl::models::ModelDesc;
use mlsl::trainer::{train, TrainerConfig};
use mlsl::tuner::{probe, ProbeSpec};
use mlsl::util::cli::Args;
use mlsl::util::stats::{fmt_bytes, fmt_ns};

fn main() -> Result<()> {
    let args = Args::parse();
    match args.positional.first().map(|s| s.as_str()) {
        Some("info") => cmd_info(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("scaling") => cmd_scaling(&args),
        Some("tune") => cmd_tune(&args),
        Some("topo") => cmd_topo(&args),
        Some("trace") => cmd_trace(&args),
        Some("train") => cmd_train(&args),
        Some("chaos") => cmd_chaos(&args),
        other => {
            eprintln!("usage: mlsl <info|simulate|scaling|tune|topo|trace|train|chaos> [--flags]");
            eprintln!(
                "  tune: --topo <preset> [--ranks-per-node r] [--rails l] \
                 [--max-ranks n] [--quick] [--sim-threads t] [--out table.json] \
                 — candidates span (algorithm x wire-precision); with --out the \
                 summary prints where each precision starts winning"
            );
            eprintln!(
                "  wire precision: --wire-dtype auto|fp32|bf16|int8 on \
                 simulate/scaling (auto = per-collective selection with \
                 error-feedback bookkeeping; docs/ARCHITECTURE.md)"
            );
            eprintln!("  topo: <preset> — dump the parsed tier stack (debug aid)");
            eprintln!(
                "  trace: <preset> [--ranks p] [--bytes b] [--sim-threads t] \
                 [--out chrome.json] — traced collective run: merged-trace \
                 identity check, critical path, utilization (docs/TRACING.md)"
            );
            eprintln!(
                "  simulate --trace[=chrome.json] records spans (critical path \
                 + optional Chrome trace-event export; docs/TRACING.md)"
            );
            eprintln!("  simulate/scaling take --tuning-table <t.json> (measured selection)");
            eprintln!(
                "  topology presets: eth10g | eth25g | omnipath100g (opa), with the \
                 suffix grammar <base>[-x<r>[r<k>][e<l>]]:"
            );
            eprintln!(
                "    -x<r>   r ranks/node on a shared-memory tier (eth10g-x2, opa-x4)"
            );
            eprintln!(
                "    r<k>    k nodes/rack behind a 4:1-oversubscribed spine \
                 (eth10g-x8r16 = 8 ranks/node x 16 nodes/rack)"
            );
            eprintln!(
                "    e<l>    l NIC egress rails per node; chunk programs stripe \
                 across them (eth10g-x8r16e2, flat multi-rail = eth10g-x1e4)"
            );
            eprintln!(
                "    full grammar, per-preset tier parameters and worked \
                 examples: docs/PRESETS.md"
            );
            eprintln!(
                "  parallel simulation: --sim-threads <t> partitions the \
                 discrete-event fabric into t shards stepped by t worker \
                 threads (byte-identical results; docs/ARCHITECTURE.md)"
            );
            eprintln!(
                "  fault injection: --chaos <seed> installs a seeded fault plan \
                 (link flaps, dead rails, slowdowns; same seed = same faults)"
            );
            eprintln!(
                "  elastic membership: --churn op:rank@iter[,op:rank@iter...] \
                 with op in leave|join (e.g. --churn leave:3@1,join:3@2)"
            );
            eprintln!(
                "  multi-tenant fabric: --tenants <n>[:disjoint] runs n \
                 concurrent jobs over one fabric (colocated by default; \
                 :disjoint gives each job its own rank block) and prints \
                 per-tenant reports + a fairness: line (Jain's index)"
            );
            eprintln!(
                "  background traffic: --background <seed> installs a seeded \
                 noisy-neighbor flow schedule (same seed = same flows; bends \
                 timing only, never training payloads)"
            );
            eprintln!(
                "  stragglers: --straggler node:factor[,node:factor...] (or \
                 all:factor) pins persistent per-node compute slowdowns, e.g. \
                 --straggler 3:2.0 — unlike --chaos windows they never expire"
            );
            eprintln!(
                "  contention-aware selection: --contention-aware re-ranks \
                 collective picks from observed per-tier utilization after \
                 one loaded iteration; --ef-tolerance <f> floors compressed \
                 wire dtypes once the error-feedback residual bound nears f"
            );
            eprintln!(
                "  chaos: --seed s [--churn spec] [simulate flags] — seeded \
                 chaos run, replayed twice (determinism check) + post-churn \
                 collective verification"
            );
            if let Some(o) = other {
                Err(anyhow!("unknown command {o:?}"))
            } else {
                Ok(())
            }
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let name = args.str_or("model", "resnet50");
    let model =
        ModelDesc::by_name(&name).ok_or_else(|| anyhow!("unknown model {name:?}"))?;
    let batch = args.usize_or("batch", model.default_batch);
    let p = args.usize_or("nodes", 64);

    println!(
        "model {name}: {} layers, {} parameters ({}), fwd {:.2} GFLOP/sample",
        model.layers.len(),
        model.total_weight_elems(),
        fmt_bytes(model.total_weight_bytes()),
        model.fwd_flops_per_sample() / 1e9,
    );

    let mut rows = Vec::new();
    for (i, l) in model.weighted_layers() {
        let r = ratio(l, Parallelism::Data, p, batch);
        let best = best_parallelism(l, p, batch);
        rows.push(vec![
            i.to_string(),
            l.name.clone(),
            format!("{:?}", l.kind),
            fmt_bytes(l.weight_bytes()),
            format!("{:.1}", l.fwd_flops / 1e6),
            format!("{r:.0}"),
            format!("{best:?}"),
        ]);
    }
    print_table(
        &format!("{name}: per-layer analysis (p={p}, batch={batch})"),
        &["#", "layer", "kind", "grad bytes", "fwd MFLOP", "flops/byte (data)", "best partition"],
        &rows,
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = engine_config(args)?;
    // Multi-tenant path: `--tenants <n>[:disjoint]` runs n concurrent
    // copies of this job over ONE shared fabric; `--contention-aware`
    // re-ranks algorithm selection from observed per-tier utilization
    // (works with one tenant too — background traffic alone is enough
    // to shift picks). Either flag routes through the tenants driver.
    let tenants = args
        .get("tenants")
        .map(mlsl::engine::TenantSpec::parse)
        .transpose()
        .map_err(|e| anyhow!(e))?;
    let contention_aware = args.bool("contention-aware");
    if tenants.is_some() || contention_aware {
        let spec = tenants
            .unwrap_or(mlsl::engine::TenantSpec { jobs: 1, disjoint: false });
        return cmd_simulate_tenants(&cfg, spec, contention_aware);
    }
    let desc = format!(
        "{} on {} nodes ({}, {:?}, group={}, batch={}/node, wire={})",
        cfg.model.name,
        cfg.dist.world(),
        cfg.topo.name,
        cfg.mode,
        cfg.dist.group_size(),
        cfg.batch,
        cfg.wire,
    );
    let timeline = cfg.record_timeline;
    let rails = cfg.topo.rails as usize;
    let r = simulate(cfg);
    println!("simulated: {desc}");
    println!("  iteration        {}", fmt_ns(r.iter_ns));
    println!("  compute          {}", fmt_ns(r.compute_ns));
    println!("  exposed comm     {}", fmt_ns(r.exposed_comm_ns));
    println!("  throughput       {:.1} samples/s", r.throughput_samples_per_s);
    println!("  bytes/node/run   {}", fmt_bytes(r.bytes_per_node));
    println!("  NIC preemptions  {}", r.preemptions);
    // Surfaced straggler factors (chaos × persistent): these used to be
    // write-only config — a slowed run was undiagnosable from the report.
    if r.straggler_max_milli != 1000 {
        println!(
            "  straggler        max {:.2}x, mean {:.2}x per-node compute slowdown",
            r.straggler_max_milli as f64 / 1000.0,
            r.straggler_mean_milli as f64 / 1000.0,
        );
    }
    for line in &r.churn_log {
        println!("  churn            {line}");
    }
    if r.chaos != mlsl::fabric::ChaosStats::default() {
        println!(
            "  chaos            {} zero-bw window(s), {} latency spike(s), \
             {} rail death(s) ({} transfer(s) rerouted), {} slowdown(s)",
            r.chaos.zero_bw_windows,
            r.chaos.latency_spikes,
            r.chaos.rails_killed,
            r.chaos.transfers_rerouted,
            r.chaos.slowdowns_applied,
        );
    }
    if let Some(trace) = &r.trace {
        println!("spans: {}", trace.span_count());
        // Critical path of the last collective to finish — under a
        // steady-state schedule that is the one gating the iteration.
        if let Some(cp) = last_rank_done(trace)
            .and_then(|coll| mlsl::trace::critical::critical_path(trace, coll))
        {
            print!("{}", cp.render(args.usize_or("top", 5)));
        }
        // `--trace out.json` (any non-boolean value) also dumps a Chrome
        // trace-event file loadable in Perfetto / chrome://tracing.
        if let Some(path) = args.get("trace").filter(|v| !matches!(*v, "true" | "1" | "yes")) {
            mlsl::trace::chrome::write_file(trace, rails, std::path::Path::new(path))
                .with_context(|| format!("write {path}"))?;
            println!("wrote {path}: Chrome trace-event JSON ({} spans)", trace.span_count());
        }
    }
    if timeline {
        println!("{}", r.timeline.ascii_gantt(100));
    }
    Ok(())
}

/// Multi-tenant simulate: N concurrent jobs time-sharing one fabric,
/// with optional background traffic, stragglers and contention-aware
/// selection. Prints one `tenant <t>:` line per job plus the
/// grep-stable `fairness:` summary — both are CI smoke targets.
fn cmd_simulate_tenants(
    cfg: &mlsl::engine::EngineConfig,
    spec: mlsl::engine::TenantSpec,
    contention_aware: bool,
) -> Result<()> {
    let tr = mlsl::engine::simulate_tenants(cfg, &spec, contention_aware);
    println!(
        "simulated: {} tenant(s) of {} on {} node(s) each ({}, {:?}, {}{})",
        spec.jobs,
        cfg.model.name,
        cfg.dist.world(),
        cfg.topo.name,
        cfg.mode,
        if spec.disjoint { "disjoint rank blocks" } else { "colocated" },
        if contention_aware { ", contention-aware selection" } else { "" },
    );
    for (t, r) in tr.reports.iter().enumerate() {
        println!(
            "tenant {t}: iter {}, exposed comm {}, {}/node, straggler spread {}",
            fmt_ns(r.iter_ns),
            fmt_ns(r.exposed_comm_ns),
            fmt_bytes(r.bytes_per_node),
            fmt_ns(tr.straggler_spread_ns[t]),
        );
        if r.straggler_max_milli != 1000 {
            println!(
                "  straggler factors: max {:.2}x, mean {:.2}x (chaos × persistent)",
                r.straggler_max_milli as f64 / 1000.0,
                r.straggler_mean_milli as f64 / 1000.0,
            );
        }
        for line in &r.churn_log {
            println!("  churn            {line}");
        }
    }
    println!("{}", tr.fairness_line());
    Ok(())
}

/// The collective whose last `RankDone` lands latest in `trace` (the
/// run's finishing collective), if any rank-done records exist.
fn last_rank_done(trace: &mlsl::trace::Trace) -> Option<u64> {
    trace
        .events
        .iter()
        .filter_map(|e| match e {
            mlsl::trace::TraceEvent::RankDone { coll_id, at, .. } => Some((*at, *coll_id)),
            _ => None,
        })
        .max()
        .map(|(_, coll)| coll)
}

fn cmd_scaling(args: &Args) -> Result<()> {
    let nodes = args.usize_list_or("nodes", &[1, 2, 4, 8, 16, 32, 64, 128, 256]);
    let mut rows = Vec::new();
    let mut single_iter: Option<u64> = None;
    for p in nodes {
        let sub = args.with("nodes", &p.to_string());
        let mut cfg = engine_config(&sub)?;
        let group = cfg.dist.group_size().min(p).max(1);
        cfg.dist = if p % group == 0 {
            mlsl::mlsl::Distribution::new(p, group)
        } else {
            mlsl::mlsl::Distribution::data_parallel(p)
        };
        let r = simulate(cfg);
        let t1 = *single_iter.get_or_insert(r.iter_ns);
        rows.push(vec![
            p.to_string(),
            fmt_ns(r.iter_ns),
            fmt_ns(r.exposed_comm_ns),
            format!("{:.1}%", 100.0 * t1 as f64 / r.iter_ns as f64),
            format!("{:.0}", r.throughput_samples_per_s),
        ]);
    }
    print_table(
        "weak scaling",
        &["nodes", "iter", "exposed comm", "efficiency", "samples/s"],
        &rows,
    );
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let topo_name = args.str_or("topo", "omnipath100g");
    let mut topo = Topology::by_name(&topo_name)
        .ok_or_else(|| anyhow!("unknown topology {topo_name:?}"))?;
    if let Some(r) = args.get("ranks-per-node") {
        let r: usize = r.parse().context("--ranks-per-node")?;
        topo = topo.with_ranks_per_node(r).map_err(|e| anyhow!("--ranks-per-node: {e}"))?;
    }
    if let Some(l) = args.get("rails") {
        let l: u32 = l.parse().context("--rails")?;
        topo = topo.with_rails(l).map_err(|e| anyhow!("--rails: {e}"))?;
    }
    let mut spec = if args.bool("quick") { ProbeSpec::quick() } else { ProbeSpec::full() };
    spec.max_ranks = args.usize_or("max-ranks", spec.max_ranks);
    if spec.max_ranks < 2 {
        return Err(anyhow!("--max-ranks must be >= 2"));
    }
    let threads = args.usize_or("sim-threads", 1);
    if threads == 0 {
        return Err(anyhow!("--sim-threads must be >= 1"));
    }
    eprintln!(
        "tuning {}: ranks {:?}, {} sizes in [{}, {}]{}",
        topo.name,
        spec.rank_grid_for(&topo),
        spec.size_grid_for(&topo).len(),
        fmt_bytes(spec.min_bytes),
        fmt_bytes(spec.max_bytes),
        if threads > 1 { format!(", {threads} probe threads") } else { String::new() },
    );
    // Grid cells are independent measurements, so the threaded probe
    // emits a byte-identical table (see tuner::probe::tune_threaded).
    let table = if threads > 1 {
        probe::tune_threaded(&topo, &spec, threads)
    } else {
        probe::tune_with_progress(&topo, &spec, |done, total| {
            if done % 25 == 0 || done == total {
                eprintln!("  probed {done}/{total} cells");
            }
        })
    };

    // Measured crossover summary: per (kind, rank row), where the winner
    // changes along the size axis. Only with --out: without the flag,
    // stdout IS the JSON table (pipeable straight into --tuning-table)
    // and must stay pure.
    if args.get("out").is_some() {
        for kind in probe::TUNED_KINDS {
            let key = mlsl::tuner::table::kind_key(kind).expect("tuned kinds have keys");
            let mut rows = Vec::new();
            for p in table.rank_rows(kind) {
                let small = table
                    .cells(kind)
                    .iter()
                    .find(|c| c.ranks == p)
                    .and_then(|c| c.best_cand())
                    .map(|(c, _)| mlsl::tuner::table::cand_key(c))
                    .unwrap_or_default();
                let xs = table.crossovers_cand(kind, p);
                let desc = if xs.is_empty() {
                    "none (single winner)".to_string()
                } else {
                    xs.iter()
                        .map(|(b, from, to)| {
                            format!(
                                "{}→{} @ {}",
                                mlsl::tuner::table::cand_key(*from),
                                mlsl::tuner::table::cand_key(*to),
                                fmt_bytes(*b)
                            )
                        })
                        .collect::<Vec<_>>()
                        .join(", ")
                };
                rows.push(vec![p.to_string(), small, desc]);
            }
            print_table(
                &format!("measured crossovers: {key} on {}", topo.name),
                &["ranks", "small-msg winner", "crossovers"],
                &rows,
            );
        }
        // Measured precision crossovers: per rank row, the smallest
        // probed size where a compressed wire's best candidate beats
        // every fp32 candidate. `precision crossover:` is a CI grep
        // target (the tune smoke in .github/workflows/ci.yml).
        let kind = mlsl::collectives::program::CollectiveKind::Allreduce;
        let wire_best = |c: &mlsl::tuner::table::MeasuredCell, w: WireDtype| {
            c.timings
                .iter()
                .filter(|((_, cw), _)| *cw == w)
                .map(|(_, t)| *t)
                .min()
        };
        for p in table.rank_rows(kind) {
            let mut parts = Vec::new();
            for w in [WireDtype::Bf16, WireDtype::Int8Block] {
                let first_win = table
                    .cells(kind)
                    .iter()
                    .filter(|c| c.ranks == p)
                    .find(|c| {
                        matches!(
                            (wire_best(c, w), wire_best(c, WireDtype::F32)),
                            (Some(cw), Some(cf)) if cw < cf
                        )
                    })
                    .map(|c| c.bytes);
                parts.push(match first_win {
                    Some(b) => format!("{w} wins from {}", fmt_bytes(b)),
                    None => format!("{w} never wins"),
                });
            }
            println!(
                "precision crossover: allreduce p={p} on {}: {}",
                topo.name,
                parts.join(", ")
            );
        }
    }

    let json = table.to_json_string();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &json).with_context(|| format!("write {path}"))?;
            println!(
                "wrote {path}: {} cells for {} (fingerprint {})",
                table.cell_count(),
                table.topo_name,
                table.fingerprint,
            );
        }
        None => println!("{json}"),
    }
    Ok(())
}

/// Dump the parsed tier stack of a topology preset — the debug surface
/// for the `<base>[-x<r>[r<k>][e<l>]]` suffix grammar: what grouping,
/// physics and rail counts a name actually resolved to.
fn cmd_topo(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .cloned()
        .or_else(|| args.get("topo").map(String::from))
        .ok_or_else(|| anyhow!("usage: mlsl topo <preset> (e.g. mlsl topo eth10g-x8r16e2)"))?;
    let topo = Topology::by_name(&name)
        .ok_or_else(|| anyhow!("unknown topology {name:?} (malformed suffix?)"))?;
    println!(
        "{}: {} level(s), {} rank(s)/node, chunk {}",
        topo.name,
        topo.num_levels(),
        topo.ranks_per_node(),
        fmt_bytes(topo.chunk_bytes),
    );
    let mut rows = Vec::new();
    for level in 0..topo.num_levels() {
        let (kind, group) = match topo.tiers.get(level) {
            Some(t) => (if t.shm { "shm" } else { "nic" }, t.ranks.to_string()),
            None => ("top", "world".to_string()),
        };
        rows.push(vec![
            level.to_string(),
            kind.to_string(),
            group,
            format!("{}", topo.gbps_at(level)),
            fmt_ns(topo.latency_at(level)),
            fmt_ns(topo.overhead_at(level)),
            topo.rails_at(level).to_string(),
        ]);
    }
    print_table(
        &format!("parsed tier stack: {} (innermost first)", topo.name),
        &["level", "kind", "group", "gbps", "latency", "overhead", "rails"],
        &rows,
    );
    println!("fingerprint: {}", mlsl::tuner::table::fingerprint(&topo));
    Ok(())
}

/// Traced collective drill: run one ring allreduce twice — serial and
/// partitioned (`--sim-threads` shards, default 2) — with span tracing
/// on, require the merged per-shard buffers to be byte-identical to the
/// serial trace (the layer's core invariant), then print the analyzers:
/// span count, critical-path decomposition, windowed utilization and
/// the process-wide metrics counters. `--out chrome.json` dumps a
/// Chrome trace-event file loadable in Perfetto. The `spans:`,
/// `trace merge ok:` and `critical path:` lines are CI grep targets
/// (docs/TRACING.md).
fn cmd_trace(args: &Args) -> Result<()> {
    use mlsl::collectives::parexec::{run_collective, run_collective_serial, FleetConfig};
    use mlsl::collectives::program::allreduce_ring;

    let name = args
        .positional
        .get(1)
        .cloned()
        .or_else(|| args.get("topo").map(String::from))
        .unwrap_or_else(|| "eth10g".to_string());
    let mut topo = Topology::by_name(&name)
        .ok_or_else(|| anyhow!("unknown topology {name:?} (malformed suffix?)"))?;
    if let Some(r) = args.get("ranks-per-node") {
        let r: usize = r.parse().context("--ranks-per-node")?;
        topo = topo.with_ranks_per_node(r).map_err(|e| anyhow!("--ranks-per-node: {e}"))?;
    }
    if let Some(l) = args.get("rails") {
        let l: u32 = l.parse().context("--rails")?;
        topo = topo.with_rails(l).map_err(|e| anyhow!("--rails: {e}"))?;
    }
    let p = args.usize_or("ranks", 16);
    if p < 2 {
        return Err(anyhow!("--ranks must be >= 2"));
    }
    let bytes = args.usize_or("bytes", 1 << 20);
    let n = (bytes / 4).max(1); // f32 wire: 4 bytes/element
    let threads = args.usize_or("sim-threads", 2).max(1);

    let serial = run_collective_serial(
        &topo,
        p,
        allreduce_ring(p, n),
        WireDtype::F32,
        1,
        None,
        false,
        true,
    );
    let trace = serial.trace.expect("tracing was enabled");
    println!(
        "trace: ring allreduce, p={p}, {} on {} ({} rail(s), finish {})",
        fmt_bytes(4 * n as u64),
        topo.name,
        topo.rails,
        fmt_ns(serial.finish_ns),
    );
    println!("spans: {}", trace.span_count());

    let fleet = FleetConfig {
        shards: threads,
        threads,
        chaos: None,
        record_deliveries: false,
        trace: true,
    };
    let par = run_collective(&topo, p, allreduce_ring(p, n), WireDtype::F32, 1, &fleet);
    if par.trace.as_ref() != Some(&trace) {
        return Err(anyhow!(
            "trace merge violated: {} shard(s) merged to {} span(s), serial has {}",
            threads,
            par.trace.map(|t| t.span_count()).unwrap_or(0),
            trace.span_count(),
        ));
    }
    println!(
        "trace merge ok: {threads} shard(s) x {threads} thread(s) reproduce the serial trace"
    );

    if let Some(cp) =
        last_rank_done(&trace).and_then(|coll| mlsl::trace::critical::critical_path(&trace, coll))
    {
        print!("{}", cp.render(args.usize_or("top", 5)));
    }
    // Utilization time series; default window gives ~16 rows per run.
    let window = args.usize_or("window-ns", 0) as u64;
    let window = if window > 0 { window } else { (trace.end_time() / 16).max(1) };
    let util = mlsl::trace::Utilization::compute(&trace, p, topo.rails as usize, window);
    print!("{}", util.render());
    let counters = mlsl::metrics::registry::snapshot();
    if !counters.is_empty() {
        println!("counters:");
        for (k, v) in &counters {
            println!("  {k} {v}");
        }
    }
    if let Some(path) = args.get("out") {
        mlsl::trace::chrome::write_file(&trace, topo.rails as usize, std::path::Path::new(path))
            .with_context(|| format!("write {path}"))?;
        println!("wrote {path}: Chrome trace-event JSON");
    }
    Ok(())
}

/// Seeded chaos drill: install a `--chaos` fault plan (plus a `--churn`
/// membership change — one node leaving by default), run the SAME
/// simulation twice and require byte-identical results (the determinism
/// guarantee: every fault is a pure function of the seed), then check
/// the post-churn collectives bitwise against the symbolic executor.
/// The final `recovery ok:` line is the CI grep target.
fn cmd_chaos(args: &Args) -> Result<()> {
    let seed = args.usize_or("seed", 42) as u64;
    let mut sub = args.with("chaos", &seed.to_string());
    let world = engine_config(&sub)?.dist.world();
    if sub.get("churn").is_none() {
        // Default drill: the highest rank leaves right after iteration 1.
        if world < 2 {
            return Err(anyhow!("chaos drill needs --nodes >= 2 (someone must leave)"));
        }
        sub = sub.with("churn", &format!("leave:{}@1", world - 1));
    }
    let cfg = engine_config(&sub)?;
    let plan = cfg.chaos.clone().expect("--chaos installs a plan");
    let slowdowns = plan.slowdown_milli.iter().filter(|m| **m != 1000).count();
    println!(
        "chaos plan (seed {seed}) on {} at p={world}: {} link flap(s), \
         {} rail death(s), {} node slowdown(s)",
        cfg.topo.name,
        plan.flaps.len(),
        plan.rail_deaths.len(),
        slowdowns,
    );

    let a = simulate(cfg.clone());
    let b = simulate(cfg.clone());
    if a.iter_ns != b.iter_ns || a.bytes_per_node != b.bytes_per_node || a.chaos != b.chaos {
        return Err(anyhow!(
            "determinism violated: two runs with seed {seed} disagree \
             (iter {} vs {}, bytes {} vs {})",
            a.iter_ns,
            b.iter_ns,
            a.bytes_per_node,
            b.bytes_per_node
        ));
    }
    println!(
        "determinism ok: two seeded runs agree (iter {}, {}/node, \
         {} fault event(s) applied)",
        fmt_ns(a.iter_ns),
        fmt_bytes(a.bytes_per_node),
        a.chaos.zero_bw_windows
            + a.chaos.latency_spikes
            + a.chaos.rails_killed
            + a.chaos.slowdowns_applied,
    );
    for line in &a.churn_log {
        println!("churn: {line}");
    }

    // Post-churn membership: replay the validated plan.
    let mut active = vec![true; world];
    if let Some(churn) = &cfg.churn {
        for e in &churn.events {
            match e.op {
                mlsl::engine::ChurnOp::Leave(r) => active[r] = false,
                mlsl::engine::ChurnOp::Join(r) => active[r] = true,
            }
        }
    }
    let survivors: Vec<usize> = (0..world).filter(|r| active[*r]).collect();
    let p_after = survivors.len();
    // Bitwise verification of the collectives the survivors will run,
    // at the shrunken rank count, through the symbolic executor.
    use mlsl::collectives::program::CollectiveKind as K;
    use mlsl::collectives::Algorithm;
    let n = 4096;
    for (kind, label) in [
        (K::Allreduce, "allreduce"),
        (K::Allgather, "allgather"),
        (K::Broadcast { root: 0 }, "broadcast"),
    ] {
        let alg = match kind {
            K::Allreduce => cfg.selection.choose_for_members(
                &cfg.topo,
                &survivors,
                K::Allreduce,
                (4 * n) as u64,
            ),
            _ => Algorithm::Ring,
        };
        mlsl::collectives::verify::verify(kind, alg, p_after, n)
            .map_err(|e| anyhow!("post-churn {label} ({alg}) at p={p_after}: {e}"))?;
        println!("verified: post-churn {label} ({alg}) bitwise-correct at p={p_after}");
    }
    println!(
        "recovery ok: {p_after}/{world} rank(s) survive, iter {} under {} \
         rerouted transfer(s) and {} preemption(s)",
        fmt_ns(a.iter_ns),
        a.chaos.transfers_rerouted,
        a.preemptions,
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = TrainerConfig::new(args.str_or("artifacts", "artifacts/small"));
    cfg.ranks = args.usize_or("ranks", 2);
    cfg.steps = args.usize_or("steps", 50);
    cfg.log_every = args.usize_or("log-every", 10);
    cfg.seed = args.usize_or("seed", 42) as u64;
    cfg.wire = WireDtype::by_name(&args.str_or("wire", "f32"))
        .ok_or_else(|| anyhow!("bad --wire"))?;
    cfg.policy = PriorityPolicy::by_name(&args.str_or("policy", "bylayer"))
        .ok_or_else(|| anyhow!("bad --policy"))?;
    let res = train(&cfg)?;
    println!(
        "trained {} ({} param tensors) for {} steps on {} ranks",
        res.preset,
        res.n_params,
        res.losses.len(),
        cfg.ranks
    );
    println!(
        "loss: first {:.4} -> last {:.4}",
        res.losses.first().unwrap_or(&f32::NAN),
        res.losses.last().unwrap_or(&f32::NAN)
    );
    let mean_ms = mlsl::util::stats::mean(&res.step_ms);
    let mean_comm = mlsl::util::stats::mean(&res.comm_wait_ms);
    println!("step time: {mean_ms:.1} ms (comm wait {mean_comm:.1} ms)");
    if let Some(out) = args.get("loss-csv") {
        let rows: Vec<Vec<String>> = res
            .losses
            .iter()
            .enumerate()
            .map(|(i, l)| vec![i.to_string(), l.to_string(), format!("{:.2}", res.step_ms[i])])
            .collect();
        mlsl::metrics::write_csv(std::path::Path::new(out), &["step", "loss", "ms"], &rows)?;
        println!("wrote {out}");
    }
    Ok(())
}

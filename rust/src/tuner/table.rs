//! Persisted tuning tables: measured (collective, rank count, message
//! size) → per-candidate timings, keyed by a topology fingerprint.
//!
//! A *candidate* is an (algorithm × wire precision) pair ([`Cand`]):
//! `ring@int8` and `ring` (bare = fp32) are separate measured columns of
//! the same cell, so the measured fp32→bf16→int8 crossovers live in the
//! table alongside the algorithm crossovers.
//!
//! A [`TuningTable`] is produced by [`crate::tuner::probe`] and consumed
//! by [`crate::tuner::SelectionPolicy`]. A lookup snaps the rank count to
//! the nearest measured row (log distance, ties to the smaller row), then
//! log-interpolates each candidate's time between the two bracketing size
//! cells (clamped at the grid edges) and picks the cheapest candidate
//! that is LEGAL at the actual rank count — a row measured at p = 8 may
//! prefer recursive doubling, which does not exist at p = 6. Tables
//! serialize via [`crate::util::json`] so a grid probed once on a
//! topology is reused by the engine, benches and examples.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::collectives::program::CollectiveKind;
use crate::collectives::{Algorithm, WireDtype};
use crate::fabric::topology::Topology;
use crate::util::json::Json;
use crate::Ns;

/// One tuning candidate: an algorithm at a wire precision.
pub type Cand = (Algorithm, WireDtype);

/// Process-wide count of lookups whose rank count fell OUTSIDE the
/// probed grid (below the smallest or above the largest measured row)
/// and were clamped to the edge row. Post-churn rank counts routinely
/// land here; the count lets tests and operators detect that a table is
/// being stretched instead of silently trusting extrapolated picks.
static OUT_OF_GRID: AtomicU64 = AtomicU64::new(0);
static OUT_OF_GRID_WARNED: AtomicBool = AtomicBool::new(false);

/// Lookups clamped to a grid-edge row so far (process-wide, monotonic).
pub fn out_of_grid_count() -> u64 {
    OUT_OF_GRID.load(Ordering::Relaxed)
}

/// Stable identity of the fabric a table was measured on: every parameter
/// that influences simulated timings (NOT the display name — renaming a
/// preset must not invalidate its measurements). Hashes the FULL tier
/// stack — all levels' group sizes and physics — so a table probed on a
/// two-tier fabric never silently applies to a three-tier one, and (v3)
/// every level's RAIL count — rail striping moves the measured
/// latency/bandwidth crossovers, so a table probed single-rail must
/// never silently apply to a striped fabric. `v4` hashes NOTHING new —
/// the bump exists because v4 tables carry (algorithm × precision)
/// candidate keys (`ring@int8`) that pre-precision consumers would
/// misread, so old and new tables must never silently cross-apply. The
/// pre-precision `v3`, pre-rail `v2` and pre-tier-stack `v1` formats can
/// never match and fall back cleanly.
pub fn fingerprint(t: &Topology) -> String {
    let mut s = format!(
        "v4|g{}|l{}|o{}|c{}|e{}",
        t.link_gbps, t.latency_ns, t.per_msg_overhead_ns, t.chunk_bytes, t.rails,
    );
    for tier in &t.tiers {
        s.push_str(&format!(
            "|t{}:g{}:l{}:o{}:m{}:e{}",
            tier.ranks,
            tier.gbps,
            tier.latency_ns,
            tier.per_msg_overhead_ns,
            tier.shm as u8,
            tier.rails,
        ));
    }
    s
}

/// Table key of a tunable collective kind. Rooted collectives and barrier
/// are not tuned (root-dependent / trivial payload).
pub fn kind_key(kind: CollectiveKind) -> Option<&'static str> {
    match kind {
        CollectiveKind::Allreduce => Some("allreduce"),
        CollectiveKind::Allgather => Some("allgather"),
        _ => None,
    }
}

/// Stable serialization key of an algorithm (`Display` collapses the
/// hierarchical group stack, which the table must preserve):
/// `"hier:8"` for the two-tier case, `"hier:8x128"` for deeper stacks
/// (innermost first — [`crate::collectives::GroupStack`]'s `Display`).
pub fn alg_key(alg: Algorithm) -> String {
    match alg {
        Algorithm::Hierarchical { groups } => format!("hier:{groups}"),
        other => other.to_string(),
    }
}

/// Inverse of [`alg_key`]. Structurally invalid group stacks (bad
/// nesting, too deep) are rejected, not folded.
pub fn parse_alg_key(s: &str) -> Option<Algorithm> {
    match s {
        "ring" => Some(Algorithm::Ring),
        "rdoubling" => Some(Algorithm::RecursiveDoubling),
        "halving" => Some(Algorithm::HalvingDoubling),
        _ => {
            let body = s.strip_prefix("hier:")?;
            let groups: Option<Vec<usize>> =
                body.split('x').map(|g| g.parse().ok()).collect();
            Algorithm::try_hier(&groups?)
        }
    }
}

/// Stable serialization key of an (algorithm × precision) candidate:
/// [`alg_key`] with a `@bf16` / `@int8` suffix; fp32 stays bare
/// (`"ring"` ≡ `"ring@fp32"`), so pre-precision keys read back as the
/// f32 columns they always were. Examples: `ring@int8`,
/// `hier:8x128@bf16`.
pub fn cand_key(cand: Cand) -> String {
    let (alg, wire) = cand;
    match wire {
        WireDtype::F32 => alg_key(alg),
        other => format!("{}@{other}", alg_key(alg)),
    }
}

/// Inverse of [`cand_key`]. Accepts `@fp32`/`@f32` spelled out too.
pub fn parse_cand_key(s: &str) -> Option<Cand> {
    match s.rsplit_once('@') {
        None => Some((parse_alg_key(s)?, WireDtype::F32)),
        Some((alg, wire)) => Some((parse_alg_key(alg)?, WireDtype::by_name(wire)?)),
    }
}

/// One measured grid cell: every candidate's simulated time at (ranks,
/// bytes).
#[derive(Debug, Clone, PartialEq)]
pub struct MeasuredCell {
    pub ranks: usize,
    pub bytes: u64,
    /// ((algorithm, wire), measured ns), canonically sorted by
    /// [`cand_key`] so tie-breaks and JSON round-trips are deterministic.
    pub timings: Vec<(Cand, Ns)>,
}

impl MeasuredCell {
    /// fp32-only constructor (the pre-precision surface — existing
    /// benches and tests build algorithm-keyed cells through this).
    pub fn new(ranks: usize, bytes: u64, timings: Vec<(Algorithm, Ns)>) -> Self {
        Self::new_cand(
            ranks,
            bytes,
            timings.into_iter().map(|(a, t)| ((a, WireDtype::F32), t)).collect(),
        )
    }

    pub fn new_cand(ranks: usize, bytes: u64, mut timings: Vec<(Cand, Ns)>) -> Self {
        timings.sort_by(|a, b| cand_key(a.0).cmp(&cand_key(b.0)));
        Self { ranks, bytes, timings }
    }

    /// Measured time of `alg` at fp32 (the pre-precision query).
    pub fn time_of(&self, alg: Algorithm) -> Option<Ns> {
        self.time_of_cand((alg, WireDtype::F32))
    }

    pub fn time_of_cand(&self, cand: Cand) -> Option<Ns> {
        self.timings.iter().find(|(c, _)| *c == cand).map(|(_, t)| *t)
    }

    /// Measured-best algorithm AT fp32 (ties break on canonical key
    /// order) — the algorithm-crossover view; see [`Self::best_cand`]
    /// for the full (algorithm × precision) winner.
    pub fn best(&self) -> Option<(Algorithm, Ns)> {
        self.timings
            .iter()
            .filter(|((_, w), _)| *w == WireDtype::F32)
            .map(|((a, _), t)| (*a, *t))
            .min_by_key(|(_, t)| *t)
    }

    /// Measured-best candidate over every (algorithm × precision)
    /// column (ties break on canonical key order).
    pub fn best_cand(&self) -> Option<(Cand, Ns)> {
        self.timings.iter().copied().min_by_key(|(_, t)| *t)
    }
}

/// Measured tuning table for one topology.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TuningTable {
    pub topo_name: String,
    pub fingerprint: String,
    /// kind key → cells, kept sorted by (ranks, bytes).
    pub kinds: BTreeMap<String, Vec<MeasuredCell>>,
}

impl TuningTable {
    pub fn for_topology(topo: &Topology) -> Self {
        Self {
            topo_name: topo.name.clone(),
            fingerprint: fingerprint(topo),
            kinds: BTreeMap::new(),
        }
    }

    /// Was this table measured on (a fabric physically identical to)
    /// `topo`?
    pub fn matches(&self, topo: &Topology) -> bool {
        self.fingerprint == fingerprint(topo)
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.values().all(|v| v.is_empty())
    }

    /// Total measured cells across all kinds.
    pub fn cell_count(&self) -> usize {
        self.kinds.values().map(|v| v.len()).sum()
    }

    /// Insert (or replace) a measured cell, keeping the row sorted.
    pub fn insert(&mut self, kind: CollectiveKind, cell: MeasuredCell) {
        let Some(key) = kind_key(kind) else { return };
        let cells = self.kinds.entry(key.to_string()).or_default();
        match cells.binary_search_by(|c| (c.ranks, c.bytes).cmp(&(cell.ranks, cell.bytes))) {
            Ok(i) => cells[i] = cell,
            Err(i) => cells.insert(i, cell),
        }
    }

    pub fn cells(&self, kind: CollectiveKind) -> &[MeasuredCell] {
        kind_key(kind)
            .and_then(|k| self.kinds.get(k))
            .map_or(&[], |v| v.as_slice())
    }

    /// Distinct measured rank counts for `kind`, ascending.
    pub fn rank_rows(&self, kind: CollectiveKind) -> Vec<usize> {
        let mut out: Vec<usize> = self.cells(kind).iter().map(|c| c.ranks).collect();
        out.dedup();
        out
    }

    /// The measured rank-count row a lookup at `p` snaps to: nearest in
    /// log space inside the probed grid (ties to the smaller row), the
    /// edge row when `p` falls OUTSIDE the grid entirely. Out-of-grid
    /// queries used to ride the nearest-distance scan silently — an
    /// elastic shrink below the smallest probed row (or a query above
    /// the largest) would apply that row's measurements as if they were
    /// local, with nothing telling the operator the table never covered
    /// this rank count. The clamp is now explicit, counted
    /// ([`out_of_grid_count`]) and warned about once per process.
    pub fn snapped_row(&self, kind: CollectiveKind, p: usize) -> Option<usize> {
        let cells = self.cells(kind);
        if cells.is_empty() || p == 0 {
            return None;
        }
        let min = cells.iter().map(|c| c.ranks).min().expect("non-empty");
        let max = cells.iter().map(|c| c.ranks).max().expect("non-empty");
        if p < min || p > max {
            let clamped = if p < min { min } else { max };
            OUT_OF_GRID.fetch_add(1, Ordering::Relaxed);
            crate::metrics::registry::inc("tuner.out_of_grid_clamps");
            if !OUT_OF_GRID_WARNED.swap(true, Ordering::Relaxed) {
                crate::util::warn::warn(format!(
                    "tuning table for {} has no row at p={p} \
                     (probed grid spans {min}..={max}); clamping to the \
                     p={clamped} row — consider re-tuning after large \
                     membership changes",
                    self.topo_name
                ));
            }
            return Some(clamped);
        }
        let dist = |r: usize| ((r as f64).ln() - (p as f64).ln()).abs();
        let mut best: Option<usize> = None;
        for c in cells {
            match best {
                None => best = Some(c.ranks),
                Some(b) if dist(c.ranks) < dist(b) => best = Some(c.ranks),
                _ => {}
            }
        }
        best
    }

    /// Size-sorted cells of [`Self::snapped_row`]'s pick.
    fn nearest_row(&self, kind: CollectiveKind, p: usize) -> Option<Vec<&MeasuredCell>> {
        let row_p = self.snapped_row(kind, p)?;
        Some(self.cells(kind).iter().filter(|c| c.ranks == row_p).collect())
    }

    /// Per-candidate times at (p, bytes): nearest rank row, then
    /// log-interpolated between the bracketing size cells (clamped at the
    /// grid edges). At an exactly-measured grid point this returns the
    /// cell's timings verbatim.
    pub fn interpolated_cand(
        &self,
        kind: CollectiveKind,
        p: usize,
        bytes: u64,
    ) -> Option<Vec<(Cand, f64)>> {
        let row = self.nearest_row(kind, p)?;
        let verbatim = |c: &MeasuredCell| -> Vec<(Cand, f64)> {
            c.timings.iter().map(|(a, t)| (*a, *t as f64)).collect()
        };
        let first = *row.first()?;
        if bytes <= first.bytes {
            return Some(verbatim(first));
        }
        let last = *row.last().expect("non-empty row");
        if bytes >= last.bytes {
            return Some(verbatim(last));
        }
        // First cell with bytes >= query; `bytes > first.bytes` above
        // guarantees hi >= 1.
        let hi = row.partition_point(|c| c.bytes < bytes);
        let (lo_cell, hi_cell) = (row[hi - 1], row[hi]);
        let f = ((bytes as f64).ln() - (lo_cell.bytes as f64).ln())
            / ((hi_cell.bytes as f64).ln() - (lo_cell.bytes as f64).ln());
        let out: Vec<(Cand, f64)> = lo_cell
            .timings
            .iter()
            .filter_map(|(cand, t0)| {
                hi_cell
                    .time_of_cand(*cand)
                    .map(|t1| (*cand, *t0 as f64 * (1.0 - f) + t1 as f64 * f))
            })
            .collect();
        if out.is_empty() {
            None
        } else {
            Some(out)
        }
    }

    /// [`Self::interpolated_cand`] restricted to the fp32 columns — the
    /// pre-precision query surface the algorithm-only policy path uses.
    pub fn interpolated(
        &self,
        kind: CollectiveKind,
        p: usize,
        bytes: u64,
    ) -> Option<Vec<(Algorithm, f64)>> {
        let out: Vec<(Algorithm, f64)> = self
            .interpolated_cand(kind, p, bytes)?
            .into_iter()
            .filter(|((_, w), _)| *w == WireDtype::F32)
            .map(|((a, _), t)| (a, t))
            .collect();
        if out.is_empty() {
            None
        } else {
            Some(out)
        }
    }

    /// Tuned pick at fp32: the cheapest interpolated algorithm passing
    /// `legal` (None when nothing measured here is legal at the actual
    /// `p` — the policy then falls back to the analytic chooser).
    pub fn lookup(
        &self,
        kind: CollectiveKind,
        p: usize,
        bytes: u64,
        legal: &dyn Fn(Algorithm) -> bool,
    ) -> Option<Algorithm> {
        self.interpolated(kind, p, bytes)?
            .into_iter()
            .filter(|(a, _)| legal(*a))
            .min_by(|x, y| x.1.partial_cmp(&y.1).expect("measured times are finite"))
            .map(|(a, _)| a)
    }

    /// Tuned pick over the full (algorithm × precision) grid: the
    /// cheapest interpolated candidate passing `legal` (which gates both
    /// algorithm legality at the actual `p` AND the wire-precision menu
    /// — a `--wire-dtype int8` run filters to int8 columns).
    pub fn lookup_cand(
        &self,
        kind: CollectiveKind,
        p: usize,
        bytes: u64,
        legal: &dyn Fn(Cand) -> bool,
    ) -> Option<Cand> {
        self.interpolated_cand(kind, p, bytes)?
            .into_iter()
            .filter(|(c, _)| legal(*c))
            .min_by(|x, y| x.1.partial_cmp(&y.1).expect("measured times are finite"))
            .map(|(c, _)| c)
    }

    /// Interpolated time of `alg` at fp32 at (p, bytes), if measured.
    pub fn time_ns(
        &self,
        kind: CollectiveKind,
        p: usize,
        bytes: u64,
        alg: Algorithm,
    ) -> Option<Ns> {
        self.time_ns_cand(kind, p, bytes, (alg, WireDtype::F32))
    }

    /// Interpolated time of a candidate at (p, bytes), if measured.
    pub fn time_ns_cand(
        &self,
        kind: CollectiveKind,
        p: usize,
        bytes: u64,
        cand: Cand,
    ) -> Option<Ns> {
        self.interpolated_cand(kind, p, bytes)?
            .into_iter()
            .find(|(c, _)| *c == cand)
            .map(|(_, t)| t.ceil() as Ns)
    }

    /// Winner-change points along the size axis of one measured rank row
    /// AT fp32: (bytes where the new winner takes over, previous winner,
    /// new winner). This is the measured analogue of the analytic
    /// model's latency/bandwidth crossover; see [`Self::crossovers_cand`]
    /// for the (algorithm × precision) winners including the measured
    /// fp32→bf16→int8 compression crossovers.
    pub fn crossovers(
        &self,
        kind: CollectiveKind,
        ranks: usize,
    ) -> Vec<(u64, Algorithm, Algorithm)> {
        let mut out = Vec::new();
        let mut prev: Option<Algorithm> = None;
        for c in self.cells(kind).iter().filter(|c| c.ranks == ranks) {
            let Some((w, _)) = c.best() else { continue };
            if let Some(p0) = prev {
                if p0 != w {
                    out.push((c.bytes, p0, w));
                }
            }
            prev = Some(w);
        }
        out
    }

    /// [`Self::crossovers`] over the full candidate grid: where the
    /// measured (algorithm × precision) winner changes along the size
    /// axis — in particular the sizes where bf16 and int8 start beating
    /// fp32 once wire-byte savings outweigh the (de)quantize cost.
    pub fn crossovers_cand(
        &self,
        kind: CollectiveKind,
        ranks: usize,
    ) -> Vec<(u64, Cand, Cand)> {
        let mut out = Vec::new();
        let mut prev: Option<Cand> = None;
        for c in self.cells(kind).iter().filter(|c| c.ranks == ranks) {
            let Some((w, _)) = c.best_cand() else { continue };
            if let Some(p0) = prev {
                if p0 != w {
                    out.push((c.bytes, p0, w));
                }
            }
            prev = Some(w);
        }
        out
    }

    // -- serialization -------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut kinds = BTreeMap::new();
        for (key, cells) in &self.kinds {
            let arr = cells
                .iter()
                .map(|c| {
                    let mut m = BTreeMap::new();
                    m.insert("ranks".to_string(), Json::Num(c.ranks as f64));
                    m.insert("bytes".to_string(), Json::Num(c.bytes as f64));
                    let timings: BTreeMap<String, Json> = c
                        .timings
                        .iter()
                        .map(|(cand, t)| (cand_key(*cand), Json::Num(*t as f64)))
                        .collect();
                    m.insert("timings".to_string(), Json::Obj(timings));
                    Json::Obj(m)
                })
                .collect();
            kinds.insert(key.clone(), Json::Arr(arr));
        }
        let mut root = BTreeMap::new();
        root.insert("version".to_string(), Json::Num(1.0));
        root.insert("topo".to_string(), Json::Str(self.topo_name.clone()));
        root.insert("fingerprint".to_string(), Json::Str(self.fingerprint.clone()));
        root.insert("kinds".to_string(), Json::Obj(kinds));
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> Result<TuningTable, String> {
        let version = j.at(&["version"]).as_usize().ok_or("missing version")?;
        if version != 1 {
            return Err(format!("unsupported tuning-table version {version}"));
        }
        let topo_name = j.at(&["topo"]).as_str().ok_or("missing topo")?.to_string();
        let fp = j.at(&["fingerprint"]).as_str().ok_or("missing fingerprint")?.to_string();
        let mut table =
            TuningTable { topo_name, fingerprint: fp, kinds: BTreeMap::new() };
        let Json::Obj(kinds) = j.at(&["kinds"]) else {
            return Err("missing kinds".into());
        };
        for (key, arr) in kinds {
            let kind = match key.as_str() {
                "allreduce" => CollectiveKind::Allreduce,
                "allgather" => CollectiveKind::Allgather,
                other => return Err(format!("unknown collective kind {other:?}")),
            };
            let cells = arr.as_arr().ok_or("kind cells must be an array")?;
            for c in cells {
                let ranks = c.at(&["ranks"]).as_usize().ok_or("cell missing ranks")?;
                if ranks == 0 {
                    return Err("cell with 0 ranks".into());
                }
                let bytes_f = c.at(&["bytes"]).as_f64().ok_or("cell missing bytes")?;
                // bytes >= 1 keeps ln(bytes) finite for interpolation;
                // NaN is rejected too (`as u64` would fold it to 0 and
                // crash lookups much later, mid-simulation).
                if bytes_f.is_nan() || bytes_f < 1.0 {
                    return Err(format!("cell with invalid bytes {bytes_f}"));
                }
                let bytes = bytes_f as u64;
                let Json::Obj(timings) = c.at(&["timings"]) else {
                    return Err("cell missing timings".into());
                };
                let mut ts = Vec::new();
                for (ck, tv) in timings {
                    let cand =
                        parse_cand_key(ck).ok_or_else(|| format!("bad candidate key {ck:?}"))?;
                    let t = tv.as_f64().ok_or("timing must be a number")? as Ns;
                    ts.push((cand, t));
                }
                table.insert(kind, MeasuredCell::new_cand(ranks, bytes, ts));
            }
        }
        Ok(table)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    pub fn parse(text: &str) -> Result<TuningTable, String> {
        Self::from_json(&Json::parse(text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Algorithm as A;
    use CollectiveKind as K;

    fn cell(p: usize, bytes: u64, ts: &[(A, Ns)]) -> MeasuredCell {
        MeasuredCell::new(p, bytes, ts.to_vec())
    }

    fn sample() -> TuningTable {
        let mut t = TuningTable::for_topology(&Topology::eth_10g());
        let rd = A::RecursiveDoubling;
        t.insert(K::Allreduce, cell(8, 1 << 10, &[(A::Ring, 700), (rd, 100)]));
        t.insert(K::Allreduce, cell(8, 1 << 20, &[(A::Ring, 1_000), (rd, 3_000)]));
        t.insert(K::Allreduce, cell(8, 1 << 24, &[(A::Ring, 9_000), (rd, 40_000)]));
        t.insert(K::Allreduce, cell(6, 1 << 20, &[(A::Ring, 2_000)]));
        t
    }

    #[test]
    fn lookup_snaps_interpolates_and_clamps() {
        let t = sample();
        let any = |_: Algorithm| true;
        // Exact cells.
        assert_eq!(t.lookup(K::Allreduce, 8, 1 << 10, &any), Some(A::RecursiveDoubling));
        assert_eq!(t.lookup(K::Allreduce, 8, 1 << 20, &any), Some(A::Ring));
        // Below/above the grid clamps to the edge cells.
        assert_eq!(t.lookup(K::Allreduce, 8, 16, &any), Some(A::RecursiveDoubling));
        assert_eq!(t.lookup(K::Allreduce, 8, 1 << 30, &any), Some(A::Ring));
        // Between cells: log-interpolated times still order correctly.
        assert_eq!(t.lookup(K::Allreduce, 8, 1 << 22, &any), Some(A::Ring));
        // Nearest rank row: p=7 (ln-closer to 8 than to 6) uses the p=8 row.
        assert_eq!(t.lookup(K::Allreduce, 7, 1 << 10, &any), Some(A::RecursiveDoubling));
        // …but the legality filter rejects rdoubling at p=7.
        let legal7 = |a: Algorithm| a != A::RecursiveDoubling;
        assert_eq!(t.lookup(K::Allreduce, 7, 1 << 10, &legal7), Some(A::Ring));
        // Unmeasured kind → None.
        assert_eq!(t.lookup(K::Allgather, 8, 1 << 10, &any), None);
    }

    #[test]
    fn out_of_grid_rank_counts_clamp_to_edge_rows_and_are_counted() {
        let t = sample(); // measured rows: p = 6 and p = 8
        let any = |_: Algorithm| true;
        let before = out_of_grid_count();
        // Below the grid: clamp to the smallest row, not a silent
        // nearest-distance extrapolation.
        assert_eq!(t.snapped_row(K::Allreduce, 2), Some(6));
        // Above it: clamp to the largest.
        assert_eq!(t.snapped_row(K::Allreduce, 100), Some(8));
        // (>= not ==: the counter is process-wide and other tests run in
        // parallel.)
        assert!(out_of_grid_count() >= before + 2);
        // Clamped lookups still answer, from the edge row's cells.
        assert_eq!(t.lookup(K::Allreduce, 2, 1 << 20, &any), Some(A::Ring));
        // In-grid queries keep the log-nearest snap (7 → 8).
        assert_eq!(t.snapped_row(K::Allreduce, 7), Some(8));
        assert_eq!(t.snapped_row(K::Allreduce, 0), None);
        assert_eq!(t.snapped_row(K::Allgather, 4), None);
    }

    #[test]
    fn interpolation_is_log_weighted() {
        let t = sample();
        // Halfway in log space between 2^10 and 2^20 is 2^15.
        let times = t.interpolated(K::Allreduce, 8, 1 << 15).unwrap();
        let ring = times.iter().find(|(a, _)| *a == A::Ring).unwrap().1;
        assert!((ring - 850.0).abs() < 1.0, "{ring}");
        let ns = t.time_ns(K::Allreduce, 8, 1 << 15, A::Ring).unwrap();
        assert_eq!(ns, 850);
    }

    #[test]
    fn crossover_extraction_reports_switch_points() {
        let t = sample();
        let xs = t.crossovers(K::Allreduce, 8);
        assert_eq!(xs, vec![(1 << 20, A::RecursiveDoubling, A::Ring)]);
        assert!(t.crossovers(K::Allreduce, 6).is_empty());
        assert_eq!(t.rank_rows(K::Allreduce), vec![6, 8]);
    }

    #[test]
    fn fingerprints_track_physics_not_names() {
        let a = Topology::eth_10g();
        let mut renamed = a.clone();
        renamed.name = "something-else".into();
        assert_eq!(fingerprint(&a), fingerprint(&renamed));
        assert_ne!(fingerprint(&a), fingerprint(&Topology::omnipath_100g()));
        assert_ne!(fingerprint(&a), fingerprint(&Topology::eth_10g_smp(2)));
        let t = sample();
        assert!(t.matches(&renamed));
        assert!(!t.matches(&Topology::eth_25g()));
    }

    #[test]
    fn fingerprints_hash_the_full_tier_stack() {
        // Same node tier, different (or absent) rack tier: a two-tier
        // table must never silently apply to a three-tier fabric.
        let two = Topology::by_name("eth10g-x8").unwrap();
        let three = Topology::by_name("eth10g-x8r16").unwrap();
        let three_other = Topology::by_name("eth10g-x8r4").unwrap();
        assert_ne!(fingerprint(&two), fingerprint(&three));
        assert_ne!(fingerprint(&three), fingerprint(&three_other));
        // Same stack, different tier physics: distinct.
        let mut warped = three.clone();
        warped.tiers[1].gbps *= 2.0;
        assert_ne!(fingerprint(&three), fingerprint(&warped));
        let mut chan = three.clone();
        chan.tiers[0].shm = false;
        assert_ne!(fingerprint(&three), fingerprint(&chan));
        // A table measured on the two-tier fabric is ignored on the
        // three-tier one (the PR 3 fingerprint-mismatch fallback).
        let table = TuningTable::for_topology(&two);
        assert!(table.matches(&two));
        assert!(!table.matches(&three));
    }

    #[test]
    fn fingerprints_hash_rail_counts() {
        // v3: a single-rail table must never silently apply to a striped
        // fabric (striping moves the measured crossovers).
        let single = Topology::by_name("eth10g-x2").unwrap();
        let striped = Topology::by_name("eth10g-x2e2").unwrap();
        let wider = Topology::by_name("eth10g-x2e4").unwrap();
        assert!(fingerprint(&single).starts_with("v4|"));
        assert_ne!(fingerprint(&single), fingerprint(&striped));
        assert_ne!(fingerprint(&striped), fingerprint(&wider));
        // Flat fabrics hash their top-tier rails too.
        assert_ne!(
            fingerprint(&Topology::eth_10g()),
            fingerprint(&Topology::by_name("eth10g-x1e2").unwrap())
        );
        let table = TuningTable::for_topology(&single);
        assert!(table.matches(&single));
        assert!(!table.matches(&striped), "single-rail table on striped fabric");
        let striped_table = TuningTable::for_topology(&striped);
        assert!(!striped_table.matches(&single), "and vice versa");
    }

    #[test]
    fn json_roundtrip_and_rejects_garbage() {
        let t = sample();
        let s = t.to_json_string();
        let back = TuningTable::parse(&s).unwrap();
        assert_eq!(t, back);
        assert_eq!(back.to_json_string(), s);
        assert!(TuningTable::parse("not json").is_err());
        assert!(TuningTable::parse("{}").is_err());
        assert!(TuningTable::parse(r#"{"version":2,"topo":"x","fingerprint":"y","kinds":{}}"#)
            .is_err());
        // Degenerate cells are rejected at load, not at lookup time.
        for bad_bytes in ["0", "-4", "null"] {
            let doc = format!(
                r#"{{"version":1,"topo":"x","fingerprint":"y","kinds":{{"allreduce":
                   [{{"ranks":4,"bytes":{bad_bytes},"timings":{{"ring":10}}}}]}}}}"#
            );
            assert!(TuningTable::parse(&doc).is_err(), "bytes={bad_bytes}");
        }
    }

    #[test]
    fn cand_keys_roundtrip_and_fp32_stays_bare() {
        use WireDtype as W;
        for cand in [
            (A::Ring, W::F32),
            (A::Ring, W::Bf16),
            (A::Ring, W::Int8Block),
            (A::RecursiveDoubling, W::Int8Block),
            (A::hier(&[8, 128]), W::Bf16),
        ] {
            assert_eq!(parse_cand_key(&cand_key(cand)), Some(cand), "{cand:?}");
        }
        // The grammar from the module doc, verbatim.
        assert_eq!(cand_key((A::Ring, W::Int8Block)), "ring@int8");
        assert_eq!(cand_key((A::hier(&[8, 128]), W::Bf16)), "hier:8x128@bf16");
        // fp32 serializes bare — pre-precision tables' keys ARE the f32
        // columns, no migration needed.
        assert_eq!(cand_key((A::Ring, W::F32)), "ring");
        assert_eq!(parse_cand_key("ring"), Some((A::Ring, W::F32)));
        assert_eq!(parse_cand_key("ring@fp32"), Some((A::Ring, W::F32)));
        assert_eq!(parse_cand_key("ring@nope"), None);
        assert_eq!(parse_cand_key("nope@int8"), None);
    }

    #[test]
    fn precision_columns_have_their_own_winners_and_crossovers() {
        use WireDtype as W;
        let mut t = TuningTable::for_topology(&Topology::eth_10g());
        // Latency-bound cell: f32 wins (no quantize setup to pay).
        t.insert(
            K::Allreduce,
            MeasuredCell::new_cand(
                8,
                1 << 10,
                vec![
                    ((A::Ring, W::F32), 100),
                    ((A::Ring, W::Bf16), 140),
                    ((A::Ring, W::Int8Block), 200),
                ],
            ),
        );
        // Bandwidth-bound cell: int8 wins.
        t.insert(
            K::Allreduce,
            MeasuredCell::new_cand(
                8,
                1 << 24,
                vec![
                    ((A::Ring, W::F32), 8_000),
                    ((A::Ring, W::Bf16), 4_500),
                    ((A::Ring, W::Int8Block), 2_600),
                ],
            ),
        );
        let any = |_: Cand| true;
        assert_eq!(t.lookup_cand(K::Allreduce, 8, 1 << 10, &any), Some((A::Ring, W::F32)));
        assert_eq!(
            t.lookup_cand(K::Allreduce, 8, 1 << 24, &any),
            Some((A::Ring, W::Int8Block))
        );
        // A fixed-precision menu filters the columns.
        let bf16_only = |(_, w): Cand| w == W::Bf16;
        assert_eq!(
            t.lookup_cand(K::Allreduce, 8, 1 << 10, &bf16_only),
            Some((A::Ring, W::Bf16))
        );
        // The algorithm-only surface still sees pure-f32 columns…
        assert_eq!(t.lookup(K::Allreduce, 8, 1 << 24, &|_| true), Some(A::Ring));
        assert_eq!(t.crossovers(K::Allreduce, 8), vec![]);
        // …while the candidate crossovers report the compression switch.
        assert_eq!(
            t.crossovers_cand(K::Allreduce, 8),
            vec![(1 << 24, (A::Ring, W::F32), (A::Ring, W::Int8Block))]
        );
        // And the whole thing round-trips through @-suffixed JSON keys.
        let back = TuningTable::parse(&t.to_json_string()).unwrap();
        assert_eq!(t, back);
        assert!(t.to_json_string().contains("ring@int8"));
    }

    #[test]
    fn alg_keys_roundtrip_including_hierarchical() {
        for alg in [
            A::Ring,
            A::RecursiveDoubling,
            A::HalvingDoubling,
            A::hier(&[4]),
            A::hier(&[2, 8]),
            A::hier(&[2, 8, 64]),
        ] {
            assert_eq!(parse_alg_key(&alg_key(alg)), Some(alg), "{alg:?}");
        }
        // The two-tier PR 3 format is still parsed.
        assert_eq!(parse_alg_key("hier:4"), Some(A::hier(&[4])));
        assert_eq!(parse_alg_key("hier:8x128"), Some(A::hier(&[8, 128])));
        assert_eq!(parse_alg_key("nope"), None);
        assert_eq!(parse_alg_key("hier:x"), None);
        assert_eq!(parse_alg_key("hier:"), None);
        assert_eq!(parse_alg_key("hier:3x7"), None, "broken nesting is rejected");
        assert_eq!(parse_alg_key("hier:0"), None);
    }

    #[test]
    fn insert_replaces_existing_cells() {
        let mut t = sample();
        let before = t.cell_count();
        t.insert(K::Allreduce, cell(8, 1 << 10, &[(A::Ring, 1)]));
        assert_eq!(t.cell_count(), before);
        let replaced = t
            .cells(K::Allreduce)
            .iter()
            .find(|c| c.ranks == 8 && c.bytes == 1 << 10)
            .unwrap();
        assert_eq!(replaced.timings, vec![((A::Ring, WireDtype::F32), 1)]);
        // Untunable kinds are ignored.
        t.insert(K::Barrier, cell(8, 1, &[(A::Ring, 1)]));
        assert_eq!(t.cell_count(), before);
    }
}

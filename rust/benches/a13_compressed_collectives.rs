//! **Ablation A13**: compressed collectives as a SELECTION dimension —
//! the (algorithm × wire-precision) grid on `eth10g-x8r16` (8 ranks/node
//! × 16 nodes, p = 128), extending A3's fixed-wire sweep to the tuner's
//! candidate grid.
//!
//! A3 showed what each wire dtype costs on a fixed ring; since the `v4`
//! tables, precision is a candidate axis the selector weighs per
//! (p, bytes) cell against the modeled endpoint (de)quantize charge
//! (`selector::quant_chain_ns`). The observable contract this bench
//! ASSERTS:
//!
//! * **bulk wins big** — at 16 MiB/rank the best int8 candidate beats
//!   the best fp32 candidate by >= 1.8x (wire bytes shrink ~3.9x; the
//!   quantize charge gives some of it back, never all of it);
//! * **latency-bound stays fp32, byte-identically** — at 256 B the
//!   measured grid's best candidate is an fp32 wire (the per-hop
//!   quantize floor exceeds the few-hundred-byte wire saving), and for
//!   every candidate algorithm the f32 column IS the pre-compression
//!   measurement bit-for-bit (`measure_cand_ns(.., F32) == measure_ns`);
//! * **tuned pick == measured best across the crossover** — a table
//!   probed on the size ladder (including the analytic compression
//!   crossover sizes) answers every probed cell with that cell's legal
//!   argmin over (algorithm × wire), and `crossovers_cand` reports a
//!   precision handover from an fp32 candidate to a compressed one as
//!   sizes grow.
//!
//! Emits `BENCH_compressed_collectives.json` (repo root).
//!
//! Run: `cargo bench --bench a13_compressed_collectives`

use mlsl::collectives::program::CollectiveKind;
use mlsl::collectives::selector::compression_crossover_sizes;
use mlsl::collectives::WireDtype;
use mlsl::fabric::topology::Topology;
use mlsl::metrics::print_table;
use mlsl::tuner::table::{cand_key, MeasuredCell};
use mlsl::tuner::{probe, Cand, SelectionPolicy, TuningTable};

const P: usize = 128;
const BULK: u64 = 16 << 20; // 16 MiB/rank
const TINY: u64 = 256; // latency-bound

fn main() {
    let topo = Topology::by_name("eth10g-x8r16").expect("preset exists");
    let kind = CollectiveKind::Allreduce;
    let algs = probe::probe_candidates(&topo, kind, P);
    assert!(algs.len() >= 3, "grid needs flat and hierarchical candidates: {algs:?}");

    // -- measure the ladder ---------------------------------------------
    // Generic log steps plus the analytic compression crossovers, so the
    // table brackets the precision handover instead of straddling it.
    let mut sizes = vec![TINY, 16 << 10, 256 << 10, 4 << 20, BULK];
    sizes.extend(compression_crossover_sizes(&topo, P));
    sizes.sort_unstable();
    sizes.dedup();

    let mut table = TuningTable::for_topology(&topo);
    // (bytes, best f32, best bf16, best int8, overall best candidate)
    let mut per_size: Vec<(u64, u64, u64, u64, Cand)> = Vec::new();
    for &bytes in &sizes {
        let mut timings: Vec<(Cand, u64)> = Vec::new();
        for &a in &algs {
            for &w in &WireDtype::ALL {
                timings.push(((a, w), probe::measure_cand_ns(&topo, kind, a, P, bytes, w)));
            }
        }
        let wire_best = |w: WireDtype| {
            timings.iter().filter(|((_, cw), _)| *cw == w).map(|(_, t)| *t).min().unwrap()
        };
        let (best, _) =
            *timings.iter().min_by_key(|(_, t)| *t).expect("non-empty candidate grid");
        per_size.push((
            bytes,
            wire_best(WireDtype::F32),
            wire_best(WireDtype::Bf16),
            wire_best(WireDtype::Int8Block),
            best,
        ));
        table.insert(kind, MeasuredCell::new_cand(P, bytes, timings));
    }

    let mut rows = Vec::new();
    for &(bytes, f, b, i, best) in &per_size {
        rows.push(vec![
            format!("{bytes}"),
            format!("{:.3}", f as f64 / 1e6),
            format!("{:.3}", b as f64 / 1e6),
            format!("{:.3}", i as f64 / 1e6),
            cand_key(best),
            format!("{:.2}x", f as f64 / i as f64),
        ]);
    }
    print_table(
        &format!("A13: (algorithm x wire) allreduce grid at p={P}, eth10g-x8r16"),
        &["bytes/rank", "best f32 ms", "best bf16 ms", "best int8 ms", "winner", "f32/int8"],
        &rows,
    );

    // -- bulk: int8 >= 1.8x over fp32 at 16 MiB/rank --------------------
    let &(_, bulk_f32, _, bulk_int8, bulk_best) =
        per_size.iter().find(|(b, ..)| *b == BULK).unwrap();
    let speedup = bulk_f32 as f64 / bulk_int8 as f64;
    assert!(
        speedup >= 1.8,
        "int8 must win bulk by >= 1.8x: best f32 {bulk_f32} ns vs best int8 {bulk_int8} ns \
         ({speedup:.2}x)"
    );
    assert_eq!(bulk_best.1, WireDtype::Int8Block, "bulk winner must ride the int8 wire");

    // -- latency-bound: fp32 wins, and its column is the pre-compression
    //    measurement byte-for-byte --------------------------------------
    let &(_, _, _, _, tiny_best) = per_size.iter().find(|(b, ..)| *b == TINY).unwrap();
    assert_eq!(
        tiny_best.1,
        WireDtype::F32,
        "at {TINY} B the quantize floor must keep the pick on the f32 wire: {}",
        cand_key(tiny_best)
    );
    for &a in &algs {
        let compressed_path = probe::measure_cand_ns(&topo, kind, a, P, TINY, WireDtype::F32);
        let legacy_path = probe::measure_ns(&topo, kind, a, P, TINY);
        assert_eq!(
            compressed_path, legacy_path,
            "f32 through the candidate grid must be the pre-compression measurement \
             bit-for-bit ({a})"
        );
    }

    // -- tuned pick == measured best across the crossover ---------------
    let policy = SelectionPolicy::Tuned(table.clone());
    for cell in table.cells(kind) {
        let (pick_cand, _) = cell.best_cand().expect("measured cell");
        let tuned = policy.choose_allreduce_wire(&topo, P, cell.bytes, &WireDtype::ALL, 1000);
        assert_eq!(
            tuned,
            pick_cand,
            "tuned pick at {} B must be the cell's measured argmin ({} vs {})",
            cell.bytes,
            cand_key(tuned),
            cand_key(pick_cand)
        );
    }
    // ...and the table reports the precision handover: some crossover as
    // sizes grow moves from an fp32 wire onto a compressed one.
    let crossings = table.crossovers_cand(kind, P);
    let handover = crossings
        .iter()
        .find(|(_, from, to)| from.1 == WireDtype::F32 && to.1 != WireDtype::F32);
    let (at, from, to) = handover.unwrap_or_else(|| {
        panic!("no fp32 -> compressed handover in {crossings:?}")
    });
    println!(
        "\nprecision handover: {} -> {} at {at} bytes/rank (p={P})",
        cand_key(*from),
        cand_key(*to)
    );

    // -- emit BENCH_compressed_collectives.json at the repo root --------
    let mut json = String::from("{\n  \"bench\": \"a13_compressed_collectives\",\n");
    json.push_str(&format!(
        "  \"topology\": \"{}\", \"ranks\": {P},\n  \"bulk_bytes\": {BULK}, \
         \"bulk_speedup_int8\": {speedup:.2},\n",
        topo.name
    ));
    json.push_str(&format!(
        "  \"handover\": {{\"bytes\": {at}, \"from\": \"{}\", \"to\": \"{}\"}},\n",
        cand_key(*from),
        cand_key(*to)
    ));
    json.push_str("  \"cells\": [\n");
    for (i, &(bytes, f, b, n8, best)) in per_size.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"bytes\": {bytes}, \"best_f32_ns\": {f}, \"best_bf16_ns\": {b}, \
             \"best_int8_ns\": {n8}, \"winner\": \"{}\"}}{}\n",
            cand_key(best),
            if i + 1 < per_size.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_compressed_collectives.json");
    std::fs::write(out, &json).expect("write BENCH_compressed_collectives.json");
    println!("wrote {out}");

    println!("\nexpected shape: at 256 B every hop pays the quantize floor for a few-hundred-");
    println!("byte saving, so fp32 candidates keep winning and their measurements are the");
    println!("pre-compression path bit-for-bit. As sizes grow the wire term dominates and");
    println!("the grid hands over to bf16 then int8 — by 16 MiB/rank the best int8 candidate");
    println!("clears 1.8x over the best fp32 one even after the (de)quantize charge. The");
    println!("tuned policy answers every probed cell with its measured argmin, so the");
    println!("handover the table reports is the handover the engine rides. OK");
}

//! Multi-tenant driver: N independent training jobs time-sharing ONE
//! discrete-event fabric.
//!
//! The paper's whole premise is synchronous SGD on *shared* Cloud/HPC
//! fabrics; arXiv 1609.06870 shows contention and stragglers — not peak
//! bandwidth — cap real scaling. This module is where that pressure is
//! applied: [`simulate_tenants`] runs `n` copies of a training job over
//! one [`NetSim`], optionally with seeded background traffic
//! ([`crate::fabric::BgPlan`]) and persistent per-node stragglers
//! ([`crate::fabric::StragglerPlan`]) installed, then reports per-tenant
//! results plus fairness metrics (per-tenant egress share, Jain's
//! index, straggler-induced boundary spread).
//!
//! # Tenancy models
//!
//! * **Colocated** (`--tenants <n>`): all jobs run on the SAME `p`
//!   fabric nodes. Egress contention is per-source-NIC, so colocated
//!   jobs genuinely fight for the strict-priority rails — this is the
//!   "noisy neighbor on my own box" regime.
//! * **Disjoint** (`--tenants <n>:disjoint`): job `t` owns the
//!   contiguous fabric rank block `[t·p, (t+1)·p)`. Jobs never share a
//!   NIC, so their event streams are bitwise independent — the
//!   isolation property `prop_tenant.rs` asserts.
//!
//! # Determinism contract
//!
//! Identical to chaos ([`crate::fabric::ChaosPlan`]): one seed/spec ⇒
//! byte-identical event streams. Background traffic and stragglers bend
//! *timing* only — the delivered training-message multiset is
//! unchanged, and `--tenants 1` with a quiet fabric reproduces the
//! single-job engine bitwise (tenant 0's collective ids and compute
//! tags are numerically identical to the pre-tenant encoding).
//!
//! # Contention-aware selection
//!
//! With `contention_aware`, the driver lets every job finish one full
//! iteration under load, snapshots the span trace, computes per-tier
//! utilization ([`Utilization`]), and installs the resulting
//! [`Contention`] correction into each job's selection path — tuned
//! picks re-rank against observed effective bandwidth instead of
//! trusting the quiet-fabric table (see
//! [`crate::tuner::SelectionPolicy::choose_for_members_wire_contended`]).
//!
//! One caveat: [`CommMode::MpiNonBlocking`](super::CommMode) gates a
//! node's comm while it computes via a per-NODE flag, so two colocated
//! jobs toggling the same node's gate interleave their windows — timing
//! bends slightly, correctness does not. Use mlsl/bulk modes for
//! colocated fairness measurements.

use super::report::{build_report_with, Report};
use super::{compute_label, EngineConfig, Job};
use crate::fabric::{tenant_of_tag, NetSim, SimEvent, BG_TAG};
use crate::metrics::{jain, Timeline};
use crate::trace::Utilization;
use crate::tuner::Contention;
use crate::Ns;

/// Parsed `--tenants` spec: `<n>` (colocated) or `<n>:disjoint`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantSpec {
    pub jobs: usize,
    pub disjoint: bool,
}

impl TenantSpec {
    pub fn parse(spec: &str) -> Result<TenantSpec, String> {
        let (n_s, disjoint) = match spec.split_once(':') {
            Some((n_s, "disjoint")) => (n_s, true),
            Some((_, other)) => {
                return Err(format!("--tenants {spec:?}: unknown placement {other:?} (disjoint)"))
            }
            None => (spec, false),
        };
        let jobs: usize =
            n_s.parse().map_err(|_| format!("--tenants {spec:?}: bad job count {n_s:?}"))?;
        if jobs == 0 {
            return Err("--tenants: need at least one job".into());
        }
        Ok(TenantSpec { jobs, disjoint })
    }
}

/// Result of a multi-tenant run: one [`Report`] per job plus the
/// cross-tenant fairness view.
#[derive(Debug, Clone)]
pub struct TenantsReport {
    /// Per-tenant training reports. `bytes_per_node` in each is that
    /// tenant's OWN traffic; `preemptions` stays fabric-global.
    pub reports: Vec<Report>,
    /// Bytes each tenant's collectives pushed onto the fabric.
    pub tenant_bytes: Vec<u64>,
    /// Bytes the background injector pushed.
    pub bg_bytes: u64,
    /// Egress-wire busy share per tenant, background last — fractions
    /// of total busy ns (all zeros if the fabric never went busy).
    pub egress_share: Vec<f64>,
    /// Jain's fairness index over the training tenants' egress busy ns
    /// (background excluded): 1.0 = perfectly fair, 1/n = one tenant
    /// starved the rest.
    pub jain: f64,
    /// Per-tenant straggler-induced exposed time: the summed spread
    /// between the first and last node reaching each iteration
    /// boundary. Zero on a balanced healthy run.
    pub straggler_spread_ns: Vec<Ns>,
}

impl TenantsReport {
    /// Grep-stable one-line fairness summary (CI asserts on the
    /// `fairness:` prefix — keep it).
    pub fn fairness_line(&self) -> String {
        let shares: Vec<String> =
            self.egress_share.iter().map(|s| format!("{s:.3}")).collect();
        format!(
            "fairness: jain={:.3} egress_share=[{}] bg_bytes={}",
            self.jain,
            shares.join(","),
            self.bg_bytes
        )
    }
}

/// Drive `spec.jobs` copies of the `cfg` training job over one shared
/// fabric. `cfg.background` / `cfg.straggler` / `cfg.chaos` install
/// into that shared fabric; `contention_aware` turns on the observed
/// effective-bandwidth correction for every job's selection.
pub fn simulate_tenants(
    cfg: &EngineConfig,
    spec: &TenantSpec,
    contention_aware: bool,
) -> TenantsReport {
    let n = spec.jobs;
    let p_job = cfg.dist.world();
    let sim_p = if spec.disjoint { n * p_job } else { p_job };
    let mut sim = NetSim::new(cfg.topo.clone(), sim_p);
    if let Some(plan) = cfg.chaos.clone() {
        sim.set_chaos(plan);
    }
    if let Some(plan) = cfg.straggler.clone() {
        sim.set_stragglers(plan);
    }
    if let Some(plan) = cfg.background.clone() {
        sim.set_background(plan);
    }
    sim.set_tenants(n);
    // The utilization probe reads the span trace, so contention
    // awareness implies tracing (same zero-event-impact contract).
    sim.set_trace(cfg.trace || cfg.record_timeline || contention_aware);
    let mut jobs: Vec<Job> = (0..n)
        .map(|t| Job::new(cfg.clone(), t, if spec.disjoint { t * p_job } else { 0 }))
        .collect();
    let total_iters = cfg.iterations + 1; // + warmup
    for job in &mut jobs {
        for r in 0..p_job {
            job.try_advance(&mut sim, r);
        }
    }
    let mut completions: Vec<crate::collectives::simexec::Completion> = Vec::new();
    let mut contention_pending = contention_aware;
    while jobs.iter().any(|j| !j.done()) {
        let Some(ev) = sim.next() else {
            panic!(
                "multi-tenant simulation deadlock: iters={:?}",
                jobs.iter().map(|j| j.min_iter()).collect::<Vec<_>>()
            );
        };
        match ev {
            SimEvent::ComputeDone { node, tag, at } => {
                // Compute tags carry the tenant at bit 48 (`tag_of`).
                let t = ((tag >> 48) as usize).min(n - 1);
                let base = jobs[t].base;
                jobs[t].on_compute_done(&mut sim, node - base, tag, at, total_iters);
            }
            ev @ SimEvent::MsgDelivered { .. } => {
                let SimEvent::MsgDelivered { msg, .. } = &ev else { unreachable!() };
                if msg.tag & BG_TAG != 0 {
                    continue; // background flows contend for wires only
                }
                let t = tenant_of_tag(msg.tag, n);
                jobs[t].on_sim_event(&mut sim, &ev, &mut completions);
            }
        }
        // Once every job has one full iteration of load behind it, the
        // trace holds a representative busy profile: measure per-tier
        // utilization and re-rank every job's selections under it.
        if contention_pending && jobs.iter().all(|j| j.min_iter() >= 1) {
            contention_pending = false;
            if let Some(tr) = sim.trace_snapshot() {
                let u = Utilization::compute(
                    &tr,
                    sim_p,
                    cfg.topo.rails.max(1) as usize,
                    sim.now().max(1),
                );
                let c = Contention::from_utilization(&u, &cfg.topo);
                if !c.is_quiet() {
                    for job in &mut jobs {
                        job.set_contention(c.clone());
                    }
                }
            }
        }
    }
    // Drain trailing collectives (last iteration's gradient exchanges)
    // so per-tenant traffic accounting is complete.
    while jobs.iter().any(|j| j.colls.in_flight() > 0) {
        let Some(ev) = sim.next() else { break };
        if let SimEvent::MsgDelivered { msg, .. } = &ev {
            if msg.tag & BG_TAG != 0 {
                continue;
            }
            let t = tenant_of_tag(msg.tag, n);
            jobs[t].on_sim_event(&mut sim, &ev, &mut completions);
        }
    }
    let mut trace = sim.take_trace().map(|t| t.normalized());
    let mut timeline =
        trace.as_ref().map(|t| Some(Timeline::from_trace(t, compute_label))).unwrap_or_default();
    let tenant_bytes: Vec<u64> =
        (0..n).map(|t| sim.stats.tenant_bytes.get(t).copied().unwrap_or(0)).collect();
    let bg_bytes = sim.stats.tenant_bytes.get(n).copied().unwrap_or(0);
    let busy: Vec<f64> =
        (0..=n).map(|t| sim.stats.tenant_busy_ns.get(t).copied().unwrap_or(0) as f64).collect();
    let total_busy: f64 = busy.iter().sum();
    let egress_share: Vec<f64> = busy
        .iter()
        .map(|b| if total_busy > 0.0 { b / total_busy } else { 0.0 })
        .collect();
    let fairness = jain(&busy[..n]);
    let straggler_spread_ns: Vec<Ns> = jobs.iter().map(|j| j.boundary_spread_ns()).collect();
    let reports: Vec<Report> = jobs
        .iter()
        .enumerate()
        .map(|(t, j)| {
            let iter_starts: Vec<Vec<Ns>> =
                j.nodes.iter().map(|nd| nd.iter_starts.clone()).collect();
            build_report_with(
                &j.cfg,
                &sim,
                &iter_starts,
                &j.first_starts,
                j.churn_log.clone(),
                // The node-0 Gantt and full trace describe the shared
                // fabric; tenant 0's report carries them.
                timeline.take().unwrap_or_default(),
                trace.take(),
                Some(tenant_bytes[t]),
            )
        })
        .collect();
    TenantsReport {
        reports,
        tenant_bytes,
        bg_bytes,
        egress_share,
        jain: fairness,
        straggler_spread_ns,
    }
}

#[cfg(test)]
mod tests {
    use super::super::{simulate, CommMode, EngineConfig};
    use super::*;
    use crate::fabric::{BgPlan, StragglerPlan, Topology};
    use crate::models::ModelDesc;

    fn cfg(p: usize) -> EngineConfig {
        let mut c = EngineConfig::new(
            ModelDesc::by_name("resnet50").unwrap(),
            Topology::eth_10g(),
            p,
        );
        c.mode = CommMode::BulkSync;
        c.iterations = 2;
        c
    }

    #[test]
    fn tenant_spec_parses_and_validates() {
        assert_eq!(TenantSpec::parse("2").unwrap(), TenantSpec { jobs: 2, disjoint: false });
        assert_eq!(
            TenantSpec::parse("3:disjoint").unwrap(),
            TenantSpec { jobs: 3, disjoint: true }
        );
        for bad in ["", "0", "x", "2:weird", ":disjoint"] {
            assert!(TenantSpec::parse(bad).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn single_tenant_reproduces_the_plain_engine_bitwise() {
        let c = cfg(4);
        let single = simulate(c.clone());
        let multi =
            simulate_tenants(&c, &TenantSpec { jobs: 1, disjoint: false }, false);
        let r = &multi.reports[0];
        assert_eq!(r.iter_ns, single.iter_ns);
        assert_eq!(r.bytes_per_node, single.bytes_per_node);
        assert_eq!(r.per_iter_ns, single.per_iter_ns);
        assert_eq!(r.exposed_comm_ns, single.exposed_comm_ns);
        assert_eq!(multi.tenant_bytes[0], single.bytes_per_node * 4);
        assert_eq!(multi.bg_bytes, 0);
    }

    #[test]
    fn colocated_tenants_contend_for_shared_egress() {
        let c = cfg(4);
        let single = simulate(c.clone());
        let multi =
            simulate_tenants(&c, &TenantSpec { jobs: 2, disjoint: false }, false);
        assert_eq!(multi.reports.len(), 2);
        // Two jobs on the same NICs: each one's iteration stretches.
        for r in &multi.reports {
            assert!(
                r.iter_ns > single.iter_ns,
                "tenant={} single={}",
                r.iter_ns,
                single.iter_ns
            );
        }
        // Symmetric jobs split the wire near-evenly.
        assert!(multi.jain > 0.9, "jain={}", multi.jain);
        assert!(multi.fairness_line().starts_with("fairness: jain="));
        // Every byte is accounted to exactly one tenant.
        assert_eq!(multi.tenant_bytes[0], multi.tenant_bytes[1]);
    }

    #[test]
    fn disjoint_tenants_are_timing_isolated() {
        // Disjoint rank blocks never share a source NIC: each job runs
        // exactly the single-job timeline, bit for bit.
        let c = cfg(4);
        let single = simulate(c.clone());
        let multi =
            simulate_tenants(&c, &TenantSpec { jobs: 2, disjoint: true }, false);
        for r in &multi.reports {
            assert_eq!(r.iter_ns, single.iter_ns);
            assert_eq!(r.bytes_per_node, single.bytes_per_node);
            assert_eq!(r.per_iter_ns, single.per_iter_ns);
        }
        assert_eq!(multi.tenant_bytes[0], multi.tenant_bytes[1]);
    }

    #[test]
    fn background_traffic_bends_timing_but_not_volume() {
        let mut noisy = cfg(4);
        let quiet_run =
            simulate_tenants(&noisy, &TenantSpec { jobs: 1, disjoint: false }, false);
        noisy.background = Some(BgPlan::generate(11, &noisy.topo, 4, 50_000_000));
        let noisy_run =
            simulate_tenants(&noisy, &TenantSpec { jobs: 1, disjoint: false }, false);
        assert!(noisy_run.bg_bytes > 0);
        assert_eq!(
            noisy_run.reports[0].bytes_per_node, quiet_run.reports[0].bytes_per_node,
            "background must never change training traffic"
        );
        assert!(
            noisy_run.reports[0].iter_ns >= quiet_run.reports[0].iter_ns,
            "noisy={} quiet={}",
            noisy_run.reports[0].iter_ns,
            quiet_run.reports[0].iter_ns
        );
        // Same seed ⇒ byte-identical rerun.
        let again =
            simulate_tenants(&noisy, &TenantSpec { jobs: 1, disjoint: false }, false);
        assert_eq!(again.reports[0].iter_ns, noisy_run.reports[0].iter_ns);
        assert_eq!(again.bg_bytes, noisy_run.bg_bytes);
    }

    #[test]
    fn stragglers_surface_in_the_report_and_stretch_iterations() {
        let healthy = simulate(cfg(4));
        assert_eq!(healthy.straggler_max_milli, 1000);
        let mut c = cfg(4);
        c.straggler = Some(StragglerPlan::parse("1:2.0", 4).unwrap());
        let slow = simulate(c);
        assert_eq!(slow.straggler_max_milli, 2000);
        assert_eq!(slow.straggler_mean_milli, 1250);
        assert!(
            slow.iter_ns > healthy.iter_ns,
            "straggled={} healthy={}",
            slow.iter_ns,
            healthy.iter_ns
        );
        // Lockstep sync bounds the damage at the straggler's own factor.
        assert!(
            slow.iter_ns <= healthy.iter_ns * 21 / 10,
            "no cascade: straggled={} healthy={}",
            slow.iter_ns,
            healthy.iter_ns
        );
    }
}

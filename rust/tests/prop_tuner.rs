//! Tuner subsystem invariants: every algorithm a tuned policy returns is
//! buildable (randomized over p ∈ 2..33 across fabric presets), tables
//! survive a JSON round-trip, a fingerprint mismatch falls back to the
//! analytic model, and on exact grid cells the tuned pick tracks the
//! measured best within the acceptance bound.

use mlsl::collectives::program::{self, CollectiveKind};
use mlsl::fabric::topology::Topology;
use mlsl::tuner::{probe, table::TuningTable, ProbeSpec, SelectionPolicy};
use mlsl::util::proptest::{run as prop_run, Config};

fn quick_table(topo: &Topology) -> TuningTable {
    let mut spec = ProbeSpec::quick();
    spec.max_ranks = 16;
    probe::tune(topo, &spec)
}

#[test]
fn prop_tuned_policy_only_returns_buildable_algorithms() {
    // The nearest measured row may prefer an algorithm that is illegal at
    // the queried rank count (rdoubling at p=6 from the p=8 row,
    // hierarchical where the node size does not divide p): the policy's
    // legality filter must keep `program::build` from ever erroring.
    let setups: Vec<(Topology, SelectionPolicy, SelectionPolicy)> = [
        Topology::eth_10g(),
        Topology::eth_10g_smp(2),
        Topology::omnipath_100g_smp(4),
    ]
    .into_iter()
    .map(|t| {
        let table = quick_table(&t);
        (
            t,
            SelectionPolicy::Tuned(table.clone()),
            SelectionPolicy::TunedWithFallback(table),
        )
    })
    .collect();
    prop_run(
        Config { cases: 300, seed: 41 },
        |r| {
            (
                r.usize_below(setups.len()),
                2 + r.usize_below(31), // p in 2..33
                1 + r.usize_below(1 << 22),
            )
        },
        |&(ti, p, n)| {
            let (topo, tuned, fallback) = &setups[ti];
            let bytes = (4 * n) as u64;
            for policy in [tuned, fallback, &SelectionPolicy::Analytic] {
                let ar = policy.choose_allreduce(topo, p, bytes);
                program::build(CollectiveKind::Allreduce, ar, p, n)
                    .map_err(|e| format!("[{}] allreduce {ar} p={p}: {e}", policy.name()))?;
                let flat = policy.choose_flat_allreduce(topo, p, bytes);
                program::build(CollectiveKind::Allreduce, flat, p, n)
                    .map_err(|e| format!("[{}] flat allreduce {flat} p={p}: {e}", policy.name()))?;
                let ag = policy.choose_allgather(topo, p, bytes);
                program::build(CollectiveKind::Allgather, ag, p, n)
                    .map_err(|e| format!("[{}] allgather {ag} p={p}: {e}", policy.name()))?;
                let fag = policy.choose_flat_allgather(topo, p, bytes);
                program::build(CollectiveKind::Allgather, fag, p, n)
                    .map_err(|e| format!("[{}] flat allgather {fag} p={p}: {e}", policy.name()))?;
            }
            Ok(())
        },
    );
}

#[test]
fn tuning_table_json_roundtrips_exactly() {
    let topo = Topology::eth_10g_smp(2);
    let table = quick_table(&topo);
    assert!(!table.is_empty());
    let text = table.to_json_string();
    let back = TuningTable::parse(&text).unwrap();
    assert_eq!(table, back);
    // A second trip is byte-identical (canonical cell + timing order).
    assert_eq!(back.to_json_string(), text);
}

#[test]
fn fingerprint_mismatch_falls_back_to_analytic() {
    use mlsl::collectives::Algorithm;
    use mlsl::tuner::table::MeasuredCell;
    // Hand-build a table (fingerprinted for 10GbE) that claims ring wins
    // a latency-bound cell where the analytic model must pick rdoubling —
    // so which policy answered is observable.
    let mut table = TuningTable::for_topology(&Topology::eth_10g());
    table.insert(
        CollectiveKind::Allreduce,
        MeasuredCell::new(
            16,
            1 << 10,
            vec![(Algorithm::Ring, 10), (Algorithm::RecursiveDoubling, 99_999)],
        ),
    );
    let live = Topology::omnipath_100g();
    assert!(!table.matches(&live));
    let analytic_pick = SelectionPolicy::Analytic.choose_allreduce(&live, 16, 1 << 10);
    assert_eq!(analytic_pick, Algorithm::RecursiveDoubling);
    // TunedWithFallback on a mismatched fingerprint ignores the table
    // wholesale…
    let fallback = SelectionPolicy::TunedWithFallback(table.clone());
    assert_eq!(fallback.choose_allreduce(&live, 16, 1 << 10), analytic_pick);
    // …while strict Tuned trusts it regardless — proving the equality
    // above is the fingerprint check, not coincidence.
    let strict = SelectionPolicy::Tuned(table.clone());
    assert_eq!(strict.choose_allreduce(&live, 16, 1 << 10), Algorithm::Ring);
    // And the same fallback policy DOES consult the table on the fabric
    // it was measured for (even under a preset rename: the fingerprint
    // tracks physics, not names).
    let mut renamed = Topology::eth_10g();
    renamed.name = "renamed".into();
    assert!(table.matches(&renamed));
    let fb2 = SelectionPolicy::TunedWithFallback(table);
    assert_eq!(fb2.choose_allreduce(&renamed, 16, 1 << 10), Algorithm::Ring);
}

#[test]
fn tuned_policy_tracks_measured_best_on_grid_cells() {
    // The acceptance bound of the a7 bench, at test scale: on every grid
    // cell the tuned pick matches the measured best in >= 90% of cells
    // and is never > 5% slower.
    for topo in [Topology::eth_10g(), Topology::eth_10g_smp(2)] {
        let table = quick_table(&topo);
        let policy = SelectionPolicy::TunedWithFallback(table.clone());
        let (mut total, mut matched) = (0usize, 0usize);
        for kind in probe::TUNED_KINDS {
            for cell in table.cells(kind) {
                let (best, best_ns) = cell.best().unwrap();
                let pick = match kind {
                    CollectiveKind::Allreduce => {
                        policy.choose_allreduce(&topo, cell.ranks, cell.bytes)
                    }
                    _ => policy.choose_allgather(&topo, cell.ranks, cell.bytes),
                };
                let pick_ns = cell.time_of(pick).unwrap();
                assert!(
                    pick_ns as f64 <= 1.05 * best_ns as f64,
                    "{} {kind:?} p={} bytes={}: pick {pick} ({pick_ns}ns) vs \
                     best {best} ({best_ns}ns)",
                    topo.name,
                    cell.ranks,
                    cell.bytes,
                );
                total += 1;
                if pick == best {
                    matched += 1;
                }
            }
        }
        assert!(total > 0);
        assert!(matched * 10 >= total * 9, "{}: {matched}/{total} matched", topo.name);
    }
}

#[test]
fn tuned_policy_is_near_optimal_off_grid_at_the_extremes() {
    // The grid-cell replay above is satisfied by construction (the pick
    // IS the argmin of the scored measurements); this is the off-grid
    // check. Beyond the grid edges the winner's regime is unambiguous
    // (latency-bound below the smallest cell, bandwidth-bound above the
    // largest), so the clamped lookup must pick an algorithm whose
    // FRESHLY measured time at the off-grid point stays within 10% of
    // the freshly measured best there. (The tighter 5% interpolation
    // bound between cells is exercised by the a7 bench's holdout replay.)
    let topo = Topology::eth_10g();
    let spec = ProbeSpec::quick();
    let table = probe::tune(&topo, &spec);
    let policy = SelectionPolicy::TunedWithFallback(table.clone());
    for kind in probe::TUNED_KINDS {
        for p in table.rank_rows(kind) {
            for bytes in [spec.min_bytes / 2, spec.max_bytes * 2] {
                let pick = match kind {
                    CollectiveKind::Allreduce => policy.choose_allreduce(&topo, p, bytes),
                    _ => policy.choose_allgather(&topo, p, bytes),
                };
                let fresh: Vec<(mlsl::collectives::Algorithm, u64)> =
                    probe::probe_candidates(&topo, kind, p)
                        .into_iter()
                        .map(|a| (a, probe::measure_ns(&topo, kind, a, p, bytes)))
                        .collect();
                let best = fresh.iter().map(|(_, t)| *t).min().unwrap();
                let pick_ns = fresh
                    .iter()
                    .find(|(a, _)| *a == pick)
                    .map(|(_, t)| *t)
                    .expect("pick comes from the candidate set");
                assert!(
                    pick_ns as f64 <= 1.10 * best as f64,
                    "{kind:?} p={p} bytes={bytes}: off-grid pick {pick} \
                     ({pick_ns}ns) vs fresh best ({best}ns)"
                );
            }
        }
    }
}

#[test]
fn post_churn_rank_counts_outside_the_grid_clamp_instead_of_extrapolating() {
    use mlsl::collectives::Algorithm;
    use mlsl::tuner::out_of_grid_count;
    use mlsl::tuner::policy::allreduce_legal;
    use mlsl::tuner::table::MeasuredCell;
    // Regression: the nearest-row lookup used to ride its log-distance
    // scan for ANY p — an elastic shrink below the smallest probed row
    // (or growth above the largest) silently applied a far-away row's
    // measurements. Now the clamp is explicit, counted and warned about.
    let mut table = TuningTable::for_topology(&Topology::eth_10g());
    for p in [8usize, 32] {
        table.insert(
            CollectiveKind::Allreduce,
            MeasuredCell::new(
                p,
                1 << 20,
                vec![
                    (Algorithm::Ring, 1_000 * p as u64),
                    (Algorithm::RecursiveDoubling, 900 * p as u64),
                ],
            ),
        );
    }
    let before = out_of_grid_count();
    // Post-churn shrink below the smallest probed row: clamp to p=8.
    assert_eq!(table.snapped_row(CollectiveKind::Allreduce, 3), Some(8));
    // Growth above the largest probed row: clamp to p=32.
    assert_eq!(table.snapped_row(CollectiveKind::Allreduce, 100), Some(32));
    // Both clamps are visible on the process-wide counter (>= because
    // tests run in parallel).
    assert!(out_of_grid_count() >= before + 2);
    // The clamped row's preference (rdoubling) is still filtered by
    // legality at the ACTUAL rank count — rdoubling does not exist at
    // p=3, so the pick degrades to ring rather than an unbuildable alg.
    let legal3 = |a: Algorithm| allreduce_legal(a, 3);
    assert_eq!(
        table.lookup(CollectiveKind::Allreduce, 3, 1 << 20, &legal3),
        Some(Algorithm::Ring)
    );
    // In-grid queries keep the log-nearest snap, no clamp involved:
    // ln-distance puts 12 nearer 8, 20 nearer 32.
    assert_eq!(table.snapped_row(CollectiveKind::Allreduce, 12), Some(8));
    assert_eq!(table.snapped_row(CollectiveKind::Allreduce, 20), Some(32));
    // A tuned policy riding the clamped row never errors in build —
    // the same guarantee the randomized legality sweep above checks.
    let policy = SelectionPolicy::Tuned(table);
    for p in [2usize, 3, 5, 64, 100] {
        let pick = policy.choose_allreduce(&Topology::eth_10g(), p, 1 << 20);
        program::build(CollectiveKind::Allreduce, pick, p, 64).unwrap();
    }
}

#[test]
fn tune_then_load_drives_the_engine_end_to_end() {
    // The CLI path, without the CLI: probe a table, serialize it, load it
    // through the config layer, run a simulated iteration under it.
    use mlsl::engine::{simulate, CommMode, EngineConfig};
    use mlsl::models::ModelDesc;
    let topo = Topology::eth_10g_smp(2);
    let mut spec = ProbeSpec::quick();
    spec.max_ranks = 8;
    let table = probe::tune(&topo, &spec);
    let reloaded = TuningTable::parse(&table.to_json_string()).unwrap();
    let mut cfg = EngineConfig::new(ModelDesc::by_name("resnet50").unwrap(), topo, 8);
    cfg.mode = CommMode::BulkSync;
    cfg.iterations = 1;
    cfg.selection = SelectionPolicy::TunedWithFallback(reloaded);
    let r = simulate(cfg);
    assert!(r.iter_ns > 0);
    assert!(r.bytes_per_node > 0);
}

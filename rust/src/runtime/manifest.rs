//! AOT manifest loading (`artifacts/<preset>/manifest.json`), emitted by
//! `python/compile/aot.py`. The manifest fixes the parameter ORDER — the
//! contract between the JAX lowering and the Rust trainer (and the source
//! of gradient allreduce priorities).

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One parameter tensor's spec.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
    /// Model layer index (0 = embeddings).
    pub layer: usize,
    /// Position in the forward pass == allreduce priority class.
    pub fwd_order: usize,
}

/// Input/output name lists of one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactIo {
    pub file: PathBuf,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub n_param_elements: usize,
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    pub params: Vec<ParamSpec>,
    pub grad_step: ArtifactIo,
    pub apply_update: ArtifactIo,
    pub train_step: Option<ArtifactIo>,
    pub eval_loss: ArtifactIo,
    pub tokens_shape: Vec<usize>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `artifacts/<preset>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", path.display()))?;

        let params = j
            .at(&["params"])
            .as_arr()
            .context("params array")?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.at(&["name"]).as_str().context("param name")?.to_string(),
                    shape: p
                        .at(&["shape"])
                        .as_arr()
                        .context("param shape")?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    size: p.at(&["size"]).as_usize().context("param size")?,
                    layer: p.at(&["layer"]).as_usize().context("param layer")?,
                    fwd_order: p.at(&["fwd_order"]).as_usize().context("fwd_order")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let io = |key: &str| -> Result<ArtifactIo> {
            let a = j.at(&["artifacts", key]);
            Ok(ArtifactIo {
                file: dir.join(a.at(&["file"]).as_str().context("artifact file")?),
                inputs: a
                    .at(&["inputs"])
                    .as_arr()
                    .context("inputs")?
                    .iter()
                    .filter_map(|s| s.as_str().map(String::from))
                    .collect(),
                outputs: a
                    .at(&["outputs"])
                    .as_arr()
                    .context("outputs")?
                    .iter()
                    .filter_map(|s| s.as_str().map(String::from))
                    .collect(),
            })
        };

        Ok(Manifest {
            preset: j.at(&["preset"]).as_str().context("preset")?.to_string(),
            vocab: j.at(&["model", "vocab"]).as_usize().context("vocab")?,
            d_model: j.at(&["model", "d_model"]).as_usize().context("d_model")?,
            n_layers: j.at(&["model", "n_layers"]).as_usize().context("n_layers")?,
            seq_len: j.at(&["model", "seq_len"]).as_usize().context("seq_len")?,
            batch: j.at(&["model", "batch"]).as_usize().context("batch")?,
            n_param_elements: j
                .at(&["model", "n_param_elements"])
                .as_usize()
                .context("n_param_elements")?,
            lr: j.at(&["hparams", "lr"]).as_f64().context("lr")?,
            momentum: j.at(&["hparams", "momentum"]).as_f64().context("momentum")?,
            weight_decay: j
                .at(&["hparams", "weight_decay"])
                .as_f64()
                .context("weight_decay")?,
            params,
            grad_step: io("grad_step")?,
            apply_update: io("apply_update")?,
            train_step: if j.at(&["artifacts", "train_step"]).is_null() {
                None
            } else {
                Some(io("train_step")?)
            },
            eval_loss: io("eval_loss")?,
            tokens_shape: j
                .at(&["tokens_shape"])
                .as_arr()
                .context("tokens_shape")?
                .iter()
                .filter_map(|d| d.as_usize())
                .collect(),
            dir: dir.to_path_buf(),
        })
    }

    /// Consistency checks (sizes, orders, files present).
    pub fn validate(&self) -> Result<()> {
        let total: usize = self.params.iter().map(|p| p.size).sum();
        if total != self.n_param_elements {
            return Err(anyhow!("param sizes sum {total} != {}", self.n_param_elements));
        }
        for (i, p) in self.params.iter().enumerate() {
            if p.fwd_order != i {
                return Err(anyhow!("param {i} fwd_order {} out of order", p.fwd_order));
            }
            let prod: usize = p.shape.iter().product();
            if prod.max(1) != p.size.max(1) {
                return Err(anyhow!("param {} shape/size mismatch", p.name));
            }
        }
        for io in [&self.grad_step, &self.apply_update, &self.eval_loss] {
            if !io.file.exists() {
                return Err(anyhow!("missing artifact {}", io.file.display()));
            }
        }
        // grad_step outputs: loss + grad per param, in order.
        if self.grad_step.outputs.len() != self.params.len() + 1 {
            return Err(anyhow!("grad_step output arity"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir(preset: &str) -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(preset);
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_tiny_manifest_if_built() {
        let Some(dir) = artifacts_dir("tiny") else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.params[0].name, "tok_emb");
        assert_eq!(m.params.last().unwrap().name, "w_out");
        m.validate().unwrap();
        // Priorities: fwd_order strictly increasing == index.
        for (i, p) in m.params.iter().enumerate() {
            assert_eq!(p.fwd_order, i);
        }
    }

    #[test]
    fn rejects_bad_json() {
        let dir = std::env::temp_dir().join("mlsl_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}

//! Property tests for elastic membership: after any churn sequence the
//! surviving communicator is just a smaller communicator — same
//! collective semantics, same bitwise results, and nobody's identity
//! moves.
//!
//! Three invariant families:
//!
//! * **post-rebuild collectives are bitwise-correct** — for random
//!   leave/join/leave sequences at p ∈ 2..33, the programs
//!   `rebuild_for_survivors` compiles at the shrunken rank count pass
//!   the symbolic executor's exact payload check for allreduce,
//!   allgather and broadcast;
//! * **survivors keep their data without renumbering** — the rebuild's
//!   fabric-rank map IS the survivor list, in original id order, for
//!   contiguous and strided member sets alike;
//! * **the engine completes under random churn** — random leave (and
//!   optional rejoin) schedules across flat, tiered and multi-rail
//!   presets finish every configured iteration with clean bookkeeping.

use mlsl::collectives::program::{rebuild_for_survivors, survivors, CollectiveKind};
use mlsl::collectives::verify::{check, init_bufs, run as sym_run};
use mlsl::collectives::Algorithm as A;
use mlsl::engine::{simulate, ChurnPlan, CommMode, EngineConfig};
use mlsl::fabric::topology::Topology;
use mlsl::models::ModelDesc;
use mlsl::util::proptest::{run as prop_run, Config};

#[test]
fn prop_post_churn_collectives_bitwise_correct() {
    prop_run(
        Config { cases: 150, seed: 71 },
        |r| {
            let p = 2 + r.usize_below(31); // p in 2..33
            let n = 1 + r.usize_below(1_000);
            // A churn history folded down to its final membership: each
            // rank may leave, then some leavers rejoin (leave/join/leave
            // sequences only ever matter through the final active set).
            let mut alive: Vec<bool> = (0..p).map(|_| r.below(3) > 0).collect();
            for a in alive.iter_mut() {
                if !*a && r.below(4) == 0 {
                    *a = true; // rejoin
                }
            }
            alive[r.usize_below(p)] = true; // never leave everyone
            (p, n, alive)
        },
        |(p, n, alive)| {
            let (p, n) = (*p, *n);
            let members: Vec<usize> = (0..p).collect();
            let surv = survivors(members.clone(), |r| alive[r]);
            let want: Vec<usize> = (0..p).filter(|r| alive[*r]).collect();
            if surv != want {
                return Err(format!("survivor ids renumbered: {surv:?} vs {want:?}"));
            }
            let p2 = surv.len();
            let mut cases = vec![
                (CollectiveKind::Allreduce, A::Ring),
                (CollectiveKind::Allgather, A::Ring),
                (CollectiveKind::Broadcast { root: 0 }, A::Ring),
            ];
            if p2.is_power_of_two() && p2 >= 2 {
                cases.push((CollectiveKind::Allreduce, A::RecursiveDoubling));
            }
            for (kind, alg) in cases {
                let (progs, map) = rebuild_for_survivors(kind, alg, &members, |r| alive[r], n)
                    .map_err(|e| format!("{kind:?}/{alg} at p'={p2}: {e}"))?;
                if map != surv {
                    return Err(format!(
                        "{kind:?}: rebuild map {map:?} is not the survivor list {surv:?}"
                    ));
                }
                if progs.len() != p2 {
                    return Err(format!("{kind:?}: {} programs for {p2} survivors", progs.len()));
                }
                // Bitwise check through the symbolic executor: program
                // rank i's payload carries survivor map[i]'s identity.
                let finals = sym_run(&progs, init_bufs(kind, p2, n))
                    .map_err(|e| format!("{kind:?}/{alg} p'={p2}: {e}"))?;
                check(kind, p2, n, &finals)
                    .map_err(|e| format!("{kind:?}/{alg} p'={p2}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn survivors_preserve_ids_and_order_for_strided_members() {
    // Strided (hybrid-parallel) member lists shrink the same way:
    // filtering, never renumbering — and the helper is order-preserving
    // even when ids are non-monotonic.
    let strided = vec![1usize, 5, 9, 13];
    assert_eq!(survivors(strided.clone(), |r| r != 9), vec![1, 5, 13]);
    assert_eq!(survivors(strided.clone(), |_| true), strided);
    assert_eq!(survivors(strided, |_| false), Vec::<usize>::new());
    let shuffled = vec![7usize, 2, 11, 4];
    assert_eq!(survivors(shuffled, |r| r != 2), vec![7, 11, 4]);
}

#[test]
fn prop_engine_completes_under_random_churn() {
    let presets = ["eth10g", "eth10g-x2", "eth10g-x2e2"];
    prop_run(
        Config { cases: 24, seed: 72 },
        |r| {
            let preset = r.usize_below(presets.len());
            let p = 2 + r.usize_below(7); // 2..9 nodes
            let leaver = r.usize_below(p);
            let boundary = r.usize_below(2); // after warmup or iter 1
            let rejoin = r.below(2) == 0;
            let bulk = r.below(2) == 0;
            (preset, p, leaver, boundary, rejoin, bulk)
        },
        |&(preset, p, leaver, boundary, rejoin, bulk)| {
            let mut spec = format!("leave:{leaver}@{boundary}");
            if rejoin {
                spec.push_str(&format!(",join:{leaver}@{}", boundary + 1));
            }
            let plan = ChurnPlan::parse(&spec).map_err(|e| format!("{spec}: {e}"))?;
            plan.validate(p).map_err(|e| format!("{spec} at p={p}: {e}"))?;
            let topo = Topology::by_name(presets[preset]).expect("preset exists");
            let mut cfg = EngineConfig::new(
                ModelDesc::by_name("resnet50").expect("model exists"),
                topo,
                p,
            );
            cfg.iterations = 2;
            cfg.mode = if bulk {
                CommMode::BulkSync
            } else {
                CommMode::MlslAsync { comm_cores: 2 }
            };
            cfg.churn = Some(plan);
            let r = simulate(cfg);
            if r.iter_ns == 0 {
                return Err(format!("{spec}: zero iteration time"));
            }
            let applied = if rejoin { 2 } else { 1 };
            if r.churn_log.len() != applied {
                return Err(format!(
                    "{spec} on {}: {} churn events applied, expected {applied} \
                     ({:?})",
                    presets[preset],
                    r.churn_log.len(),
                    r.churn_log
                ));
            }
            if r.per_iter_ns.is_empty() || r.per_iter_ns.iter().any(|&d| d == 0) {
                return Err(format!("{spec}: degenerate per-iteration spans {:?}", r.per_iter_ns));
            }
            Ok(())
        },
    );
}

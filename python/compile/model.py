"""L2: Transformer LM forward/backward in JAX, calling the L1 Pallas kernels.

Architecture: pre-LN decoder-only transformer (tok+pos embedding, N blocks
of [LN -> MHA -> residual, LN -> MLP(gelu) -> residual], final LN, untied
output projection), next-token cross-entropy.

Every compute hot-spot goes through a Pallas kernel wrapped in
`jax.custom_vjp`: the forward is the fused kernel, the backward is the
jax-derived VJP of the pure-jnp oracle (rematerialization — the forward is
recomputed in the backward, trading FLOPs for not staging residuals; noted
in DESIGN.md §Perf). This keeps the kernels differentiable without writing
hand-rolled backward kernels, while the AOT artifact still contains the
fused forward HLO.

Parameters are an *ordered* flat list — the order IS the forward order and
is what the Rust side uses for the paper's message prioritization (first
layer's weight gradients are the most urgent: they are needed first in the
next forward pass).
"""

import functools
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref
from .presets import ModelConfig

# ---------------------------------------------------------------------------
# custom_vjp wrappers: Pallas forward, oracle-VJP backward
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _mm_act(x, w, b, activation):
    return kernels.matmul_bias_act(x, w, b, activation)


def _mm_act_fwd(x, w, b, activation):
    return kernels.matmul_bias_act(x, w, b, activation), (x, w, b)


def _mm_act_bwd(activation, res, ct):
    x, w, b = res
    _, vjp = jax.vjp(lambda x_, w_, b_: ref.matmul_bias_act(x_, w_, b_, activation),
                     x, w, b)
    return vjp(ct)


_mm_act.defvjp(_mm_act_fwd, _mm_act_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _attn(q, k, v, causal):
    return kernels.attention(q, k, v, causal)


def _attn_fwd(q, k, v, causal):
    return kernels.attention(q, k, v, causal), (q, k, v)


def _attn_bwd(causal, res, ct):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention(q_, k_, v_, causal), q, k, v)
    return vjp(ct)


_attn.defvjp(_attn_fwd, _attn_bwd)


@jax.custom_vjp
def _ln(x, g, b):
    return kernels.layernorm(x, g, b)


def _ln_fwd(x, g, b):
    return kernels.layernorm(x, g, b), (x, g, b)


def _ln_bwd(res, ct):
    x, g, b = res
    _, vjp = jax.vjp(ref.layernorm, x, g, b)
    return vjp(ct)


_ln.defvjp(_ln_fwd, _ln_bwd)


# ---------------------------------------------------------------------------
# Parameter bookkeeping
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> List[Dict]:
    """Ordered parameter manifest.

    Each entry: name, shape, layer (0 = embeddings = most-urgent gradient,
    per the paper's first-layer prioritization), fwd_order (position in the
    forward pass; doubles as the allreduce priority class on the Rust side).
    """
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    specs: List[Dict] = []

    def add(name, shape, layer):
        specs.append({
            "name": name,
            "shape": list(shape),
            "size": int(math.prod(shape)) if shape else 1,
            "layer": layer,
            "fwd_order": len(specs),
        })

    add("tok_emb", (v, d), 0)
    add("pos_emb", (s, d), 0)
    for i in range(cfg.n_layers):
        li = 1 + i
        add(f"blk{i}.ln1_g", (d,), li)
        add(f"blk{i}.ln1_b", (d,), li)
        add(f"blk{i}.wq", (d, d), li)
        add(f"blk{i}.wk", (d, d), li)
        add(f"blk{i}.wv", (d, d), li)
        add(f"blk{i}.wo", (d, d), li)
        add(f"blk{i}.ln2_g", (d,), li)
        add(f"blk{i}.ln2_b", (d,), li)
        add(f"blk{i}.w1", (d, f), li)
        add(f"blk{i}.b1", (f,), li)
        add(f"blk{i}.w2", (f, d), li)
        add(f"blk{i}.b2", (d,), li)
    lf = 1 + cfg.n_layers
    add("lnf_g", (d,), lf)
    add("lnf_b", (d,), lf)
    add("w_out", (d, v), lf)
    return specs


def init_params(cfg: ModelConfig, key) -> List[jnp.ndarray]:
    """GPT-2-style init, returned in param_specs order."""
    specs = param_specs(cfg)
    params = []
    for spec in specs:
        key, sub = jax.random.split(key)
        shape = tuple(spec["shape"])
        name = spec["name"]
        if name.endswith(("_g",)):
            p = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_b", ".b1", ".b2")):
            p = jnp.zeros(shape, jnp.float32)
        elif name.endswith((".wo", ".w2")):  # residual-branch outputs, scaled
            std = 0.02 / (2 * cfg.n_layers) ** 0.5
            p = std * jax.random.normal(sub, shape, jnp.float32)
        else:
            p = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        params.append(p)
    return params


def _as_dict(cfg: ModelConfig, flat: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    return {s["name"]: p for s, p in zip(param_specs(cfg), flat)}


# ---------------------------------------------------------------------------
# Forward + loss
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, flat_params: List[jnp.ndarray], tokens) -> jnp.ndarray:
    """Logits for input tokens. tokens: (B, S) int32 -> (B, S, V) f32."""
    p = _as_dict(cfg, flat_params)
    b, s = tokens.shape
    d, h = cfg.d_model, cfg.n_heads
    hd = cfg.head_dim
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :s, :]
    zero_b = jnp.zeros((d,), jnp.float32)
    for i in range(cfg.n_layers):
        # --- attention sublayer
        xn = _ln(x, p[f"blk{i}.ln1_g"], p[f"blk{i}.ln1_b"])
        xn2 = xn.reshape(b * s, d)
        q = _mm_act(xn2, p[f"blk{i}.wq"], zero_b, "none")
        k = _mm_act(xn2, p[f"blk{i}.wk"], zero_b, "none")
        v = _mm_act(xn2, p[f"blk{i}.wv"], zero_b, "none")
        split = lambda t: t.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        att = _attn(split(q), split(k), split(v), True)
        att = att.transpose(0, 2, 1, 3).reshape(b * s, d)
        proj = _mm_act(att, p[f"blk{i}.wo"], zero_b, "none")
        x = x + proj.reshape(b, s, d)
        # --- MLP sublayer
        xn = _ln(x, p[f"blk{i}.ln2_g"], p[f"blk{i}.ln2_b"]).reshape(b * s, d)
        hidden = _mm_act(xn, p[f"blk{i}.w1"], p[f"blk{i}.b1"], "gelu")
        out = _mm_act(hidden, p[f"blk{i}.w2"], p[f"blk{i}.b2"], "none")
        x = x + out.reshape(b, s, d)
    x = _ln(x, p["lnf_g"], p["lnf_b"]).reshape(b * s, d)
    logits = _mm_act(x, p["w_out"], jnp.zeros((cfg.vocab,), jnp.float32), "none")
    return logits.reshape(b, s, cfg.vocab)


def loss_fn(cfg: ModelConfig, flat_params: List[jnp.ndarray], tokens) -> jnp.ndarray:
    """Mean next-token cross-entropy. tokens: (B, S+1) int32."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, flat_params, inputs).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Training-step entry points (these are what aot.py lowers)
# ---------------------------------------------------------------------------


def grad_step(cfg: ModelConfig, *args) -> Tuple:
    """(params..., tokens) -> (loss, grads...).

    The data-parallel decomposition point: each Rust rank runs this, the
    Rust collectives allreduce the grads, then apply_update runs.
    """
    n = len(param_specs(cfg))
    flat_params, tokens = list(args[:n]), args[n]
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(cfg, ps, tokens))(flat_params)
    return (loss, *grads)


def apply_update(cfg: ModelConfig, lr: float, mu: float, wd: float, *args) -> Tuple:
    """(params..., moms..., grads...) -> (params'..., moms'...)."""
    n = len(param_specs(cfg))
    params, moms, grads = args[:n], args[n:2 * n], args[2 * n:3 * n]
    new_p, new_m = [], []
    for w, m, g in zip(params, moms, grads):
        wn, mn = kernels.sgd_momentum(w, m, g, lr=lr, mu=mu, wd=wd)
        new_p.append(wn)
        new_m.append(mn)
    return (*new_p, *new_m)


def train_step(cfg: ModelConfig, lr: float, mu: float, wd: float, *args) -> Tuple:
    """Single-rank fused step: (params..., moms..., tokens) -> (params'..., moms'..., loss)."""
    n = len(param_specs(cfg))
    params, moms, tokens = list(args[:n]), args[n:2 * n], args[2 * n]
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(cfg, ps, tokens))(params)
    out = apply_update(cfg, lr, mu, wd, *params, *moms, *grads)
    return (*out, loss)


def eval_loss(cfg: ModelConfig, *args) -> Tuple:
    """(params..., tokens) -> (loss,)."""
    n = len(param_specs(cfg))
    return (loss_fn(cfg, list(args[:n]), args[n]),)

//! Minimal JSON parser + writer (offline replacement for `serde_json`),
//! sufficient for the AOT manifests and metrics emission.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated. Numbers parse as f64 (the manifests only carry
//! integers that fit exactly).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chain; returns Null for missing keys.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- writer ---------------------------------------------------------

    #[allow(clippy::inherent_to_string)] // deliberate: no Display audience
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("bad array sep {other:?} at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("bad object sep {other:?} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "preset": "tiny",
            "model": {"vocab": 512, "d_model": 64},
            "params": [
                {"name": "tok_emb", "shape": [512, 64], "layer": 0},
                {"name": "w_out", "shape": [64, 512], "layer": 3}
            ],
            "train_step": null,
            "ok": true,
            "lr": 3e-2
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.at(&["preset"]).as_str(), Some("tiny"));
        assert_eq!(j.at(&["model", "vocab"]).as_usize(), Some(512));
        let params = j.at(&["params"]).as_arr().unwrap();
        assert_eq!(params.len(), 2);
        assert_eq!(params[1].at(&["name"]).as_str(), Some("w_out"));
        assert!(j.at(&["train_step"]).is_null());
        assert_eq!(j.at(&["ok"]).as_bool(), Some(true));
        assert!((j.at(&["lr"]).as_f64().unwrap() - 0.03).abs() < 1e-12);
    }

    #[test]
    fn roundtrips() {
        let doc = r#"{"a":[1,2.5,"x\ny",null,true],"b":{"c":-7}}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("00x").is_err());
        assert!(Json::parse(r#"{"a":1} trailing"#).is_err());
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }
}

"""Hypothesis sweeps over kernel shapes/dtypes vs the pure-jnp oracles."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _arr(rng, shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


@settings(**SETTINGS)
@given(m=st.integers(1, 48), k=st.integers(1, 48), n=st.integers(1, 48),
       act=st.sampled_from(["none", "gelu", "relu"]), seed=st.integers(0, 2**31))
def test_matmul_any_shape(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, (m, k)), _arr(rng, (k, n)), _arr(rng, (n,))
    got = kernels.matmul_bias_act(x, w, b, act)
    want = ref.matmul_bias_act(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(b=st.integers(1, 3), h=st.integers(1, 4), s=st.integers(1, 48),
       d=st.integers(1, 32), causal=st.booleans(), seed=st.integers(0, 2**31))
def test_attention_any_shape(b, h, s, d, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (_arr(rng, (b, h, s, d)) for _ in range(3))
    got = kernels.attention(q, k, v, causal)
    want = ref.attention(q, k, v, causal)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(nblk=st.integers(1, 128), scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**31))
def test_quantize_any_size(nblk, scale, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (nblk * ref.QBLOCK,), scale)
    q_got, s_got = kernels.quantize_int8(x)
    q_want, s_want = ref.quantize_int8(x)
    # Values that land exactly on a rounding tie can differ by 1 LSB
    # between the tiled kernel and the oracle (f32 division association);
    # require agreement within one quantum on a vanishing fraction.
    qg = np.asarray(q_got, np.int32)
    qw = np.asarray(q_want, np.int32)
    diff = np.abs(qg - qw)
    assert diff.max() <= 1, diff.max()
    assert (diff > 0).mean() < 1e-3, (diff > 0).mean()
    np.testing.assert_allclose(s_got, s_want, rtol=1e-6)
    # Round-trip error bound holds for every block.
    deq = kernels.dequantize_int8(q_got, s_got)
    blocks = np.asarray(x).reshape(-1, ref.QBLOCK)
    step = np.abs(blocks).max(axis=1) / 127.0
    err = np.abs(np.asarray(deq).reshape(-1, ref.QBLOCK) - blocks)
    assert (err <= 0.5 * step[:, None] + 1e-6 * max(scale, 1.0)).all()


@settings(**SETTINGS)
@given(n=st.integers(1, 9000), lr=st.floats(1e-4, 1.0), mu=st.floats(0.0, 0.99),
       wd=st.floats(0.0, 1e-2), seed=st.integers(0, 2**31))
def test_sgd_any_size(n, lr, mu, wd, seed):
    rng = np.random.default_rng(seed)
    w, m, g = _arr(rng, (n,)), _arr(rng, (n,)), _arr(rng, (n,))
    wn, mn = kernels.sgd_momentum(w, m, g, lr=lr, mu=mu, wd=wd)
    we, me = ref.sgd_momentum(w, m, g, lr, mu, wd)
    np.testing.assert_allclose(wn, we, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(mn, me, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(rows=st.integers(1, 64), d=st.integers(1, 256), seed=st.integers(0, 2**31))
def test_layernorm_any_shape(rows, d, seed):
    rng = np.random.default_rng(seed)
    x, g, b = _arr(rng, (rows, d)), _arr(rng, (d,)), _arr(rng, (d,))
    got = kernels.layernorm(x, g, b)
    want = ref.layernorm(x, g, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

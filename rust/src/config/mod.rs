//! Run configuration: CLI args + config files → typed experiment setups.
//!
//! Config files use a flat `key = value` format (`#` comments); CLI flags
//! override file values. See `configs/` in the repo root for examples.
//!
//! ## Topology preset suffix grammar
//!
//! `--topo` resolves `<base>[-x<r>[r<k>][e<l>]]` through
//! [`Topology::by_name`]:
//!
//! * `<base>` — a flat preset: `eth10g`, `eth25g`, `omnipath100g`/`opa`;
//! * `-x<r>` — `r` ranks share each node over a shared-memory tier
//!   (`eth10g-x2`, `opa-x4`); `--ranks-per-node r` is the flag
//!   equivalent and overrides a preset's suffix;
//! * `r<k>` — `k` nodes per rack behind an oversubscribed spine
//!   (`eth10g-x8r16` = 8 ranks/node × 16 nodes/rack = rack tier of 128
//!   ranks): in-rack hops keep the base NIC rate at half the latency,
//!   cross-rack hops pay 4× less bandwidth and 2× latency;
//! * `e<l>` — every node drives `l` independent NIC egress rails
//!   (`eth10g-x8r16e2`; a flat multi-rail fabric is `eth10g-x1e4`):
//!   bandwidth-bound transfers stripe whole chunks across the rails for
//!   up to `l`× injection bandwidth, latency-bound messages ride one
//!   rail and pay one overhead; `--rails l` is the flag equivalent and
//!   overrides a preset's suffix.
//!
//! Malformed suffixes (`-x0`, `-x2r1`, `-x2e0`) are configuration
//! errors, not panics.
//!
//! The full grammar with every base preset's tier parameters and worked
//! examples (e.g. `eth10g-x8r16e2`) is documented in `docs/PRESETS.md`;
//! `mlsl` with no subcommand prints the short form.
//!
//! ## Simulator threading
//!
//! `--sim-threads <n>` (default 1) partitions the discrete-event fabric
//! into `n` node-contiguous shards driven by `n` worker threads under
//! conservative-lookahead windows ([`crate::collectives::parexec`]).
//! `1` is today's exact serial path; any `n` produces byte-identical
//! results for the single-collective timing workloads it accelerates
//! (standalone collective timing and `mlsl tune` grid probing — the
//! engine's iteration loop itself stays serial, see
//! `docs/ARCHITECTURE.md`).
//!
//! ## Chaos and churn grammar
//!
//! * `--chaos <seed>` — install a seeded fault-injection plan
//!   ([`crate::fabric::ChaosPlan::generate`]) covering the whole run:
//!   tier-level latency spikes, temporary zero-bandwidth windows, dead
//!   NIC rails and per-node compute slowdowns. The plan is a pure
//!   function of `(seed, topology, world size, horizon)` — the same
//!   seed on the same config replays the exact same faults, event for
//!   event (the determinism guarantee `mlsl chaos` checks).
//! * `--churn <spec>` — membership changes between engine iterations:
//!   `op:rank@iter[,op:rank@iter...]` where `op` is `leave` or `join`,
//!   `rank` is a fabric rank id and `iter` the completed iteration the
//!   change applies after (`0` = right after warmup). Example:
//!   `--churn leave:3@1,join:3@2`. Survivors keep their rank ids and
//!   their data; specs that would double-leave, rejoin a present rank,
//!   reference an out-of-range rank or empty the cluster are
//!   configuration errors.

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use crate::collectives::{PriorityPolicy, WireDtype};
use crate::engine::{CommMode, EngineConfig};
use crate::fabric::topology::{NodeSpec, Topology};
use crate::mlsl::Distribution;
use crate::models::ModelDesc;
use crate::util::cli::Args;

/// Flat key=value config file.
#[derive(Debug, Default, Clone)]
pub struct FileConfig {
    map: BTreeMap<String, String>,
}

impl FileConfig {
    pub fn parse(text: &str) -> Result<FileConfig> {
        let mut map = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(FileConfig { map })
    }

    pub fn load(path: &Path) -> Result<FileConfig> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }
}

/// Resolve a simulation EngineConfig from (optional config file) + flags.
pub fn engine_config(args: &Args) -> Result<EngineConfig> {
    let file = match args.get("config") {
        Some(p) => FileConfig::load(Path::new(p))?,
        None => FileConfig::default(),
    };
    let get = |key: &str, default: &str| -> String {
        args.get(key)
            .map(String::from)
            .or_else(|| file.get(key).map(String::from))
            .unwrap_or_else(|| default.to_string())
    };

    let model_name = get("model", "resnet50");
    let model = ModelDesc::by_name(&model_name)
        .ok_or_else(|| anyhow!("unknown model {model_name:?}"))?;
    let topo_name = get("topo", "omnipath100g");
    let mut topo =
        Topology::by_name(&topo_name).ok_or_else(|| anyhow!("unknown topology {topo_name:?}"))?;
    // Tiered-fabric override: `--ranks-per-node 2` (or an `-x2` preset
    // suffix) marks ranks as co-located in groups on shared-memory nodes;
    // an existing rack tier (`r<k>` suffix) is preserved, rescaled to the
    // same nodes-per-rack count. Invalid values surface as config errors
    // (with_ranks_per_node validates, it no longer asserts).
    let rpn: usize = get("ranks-per-node", &topo.ranks_per_node().to_string())
        .parse()
        .context("--ranks-per-node")?;
    topo = topo.with_ranks_per_node(rpn).map_err(|e| anyhow!("--ranks-per-node: {e}"))?;
    // Multi-rail override: `--rails l` (or an `e<l>` preset suffix) gives
    // every node `l` independent NIC egress rails; chunk programs stripe
    // bandwidth-bound transfers across them (see fabric::sim).
    let rails: u32 = get("rails", &topo.rails.to_string()).parse().context("--rails")?;
    if rails != topo.rails {
        topo = topo.with_rails(rails).map_err(|e| anyhow!("--rails: {e}"))?;
    }
    let node_name = get("node", "skylake");
    let node =
        NodeSpec::by_name(&node_name).ok_or_else(|| anyhow!("unknown node {node_name:?}"))?;
    let nodes: usize = get("nodes", "16").parse().context("--nodes")?;
    let group: usize = get("group", "1").parse().context("--group")?;
    let batch: usize = get("batch", &model.default_batch.to_string()).parse().context("--batch")?;
    let mode_name = get("mode", "mlsl");
    let mut mode =
        CommMode::by_name(&mode_name).ok_or_else(|| anyhow!("unknown mode {mode_name:?}"))?;
    if let CommMode::MlslAsync { .. } = mode {
        let cc: usize = get("comm-cores", "2").parse().context("--comm-cores")?;
        mode = CommMode::MlslAsync { comm_cores: cc };
    }
    let policy_name = get("policy", "bylayer");
    let policy = PriorityPolicy::by_name(&policy_name)
        .ok_or_else(|| anyhow!("unknown policy {policy_name:?}"))?;
    // Wire precision: `--wire-dtype auto|fp32|bf16|int8` (the canonical
    // flag; `--wire` stays as the original alias for a fixed dtype).
    // `auto` turns every gradient allreduce into an (algorithm ×
    // wire-precision) selection — see `EngineConfig::wire_auto`.
    let wire_name = args
        .get("wire-dtype")
        .map(String::from)
        .or_else(|| file.get("wire-dtype").map(String::from))
        .unwrap_or_else(|| get("wire", "f32"));
    let (wire, wire_auto) = if wire_name == "auto" {
        (WireDtype::F32, true)
    } else {
        let w = WireDtype::by_name(&wire_name)
            .ok_or_else(|| anyhow!("unknown wire dtype {wire_name:?} (auto|fp32|bf16|int8)"))?;
        (w, false)
    };
    let iterations: usize = get("iterations", "3").parse().context("--iterations")?;
    let sim_threads: usize = get("sim-threads", "1").parse().context("--sim-threads")?;
    if sim_threads == 0 {
        return Err(anyhow!("--sim-threads must be >= 1"));
    }

    let mut cfg = EngineConfig::new(model, topo, nodes);
    cfg.node = node;
    cfg.dist = Distribution::new(nodes, group);
    cfg.batch = batch;
    cfg.mode = mode;
    cfg.policy = policy;
    cfg.wire = wire;
    cfg.wire_auto = wire_auto;
    cfg.iterations = iterations;
    cfg.record_timeline = args.bool("timeline");
    // Span tracing: `--trace` (bare, or `--trace out.json` — `mlsl
    // simulate` treats a non-boolean value as a Chrome-trace output
    // path, see `docs/TRACING.md`). The config only carries the switch;
    // path handling stays in the CLI.
    cfg.trace = args.get("trace").or_else(|| file.get("trace")).is_some();
    cfg.jitter = get("jitter", "0.0").parse().context("--jitter")?;
    cfg.sim_threads = sim_threads;
    // Elastic membership: `--churn leave:3@1,join:3@2` (see the module
    // doc's grammar section). Validated against the world size here so a
    // bad spec dies as a config error, not mid-simulation.
    if let Some(spec) = args.get("churn").or_else(|| file.get("churn")) {
        let plan =
            crate::engine::ChurnPlan::parse(spec).map_err(|e| anyhow!("--churn: {e}"))?;
        plan.validate(cfg.dist.world()).map_err(|e| anyhow!("--churn: {e}"))?;
        cfg.churn = Some(plan);
    }
    // Fault injection: `--chaos <seed>` derives the full schedule from
    // the seed, the topology, the world size and a horizon sized to the
    // configured run (compute time × iterations, with headroom for the
    // communication the schedule will expose) — deterministic in all
    // four, which is what makes chaos runs replayable.
    if let Some(seed) = args.get("chaos").or_else(|| file.get("chaos")) {
        let seed: u64 = seed.parse().context("--chaos")?;
        let horizon = cfg
            .compute_ns_per_iter()
            .saturating_mul((cfg.iterations as u64 + 1) * 2)
            .max(1_000_000);
        cfg.chaos =
            Some(crate::fabric::ChaosPlan::generate(seed, &cfg.topo, cfg.dist.world(), horizon));
    }
    // Persistent stragglers: `--straggler node:factor[,node:factor…]`
    // (`all:factor` pins every node). Unlike `--chaos` slowdown windows
    // these never expire; they compose multiplicatively with chaos.
    // Validated against the world size here, same as `--churn`.
    if let Some(spec) = args.get("straggler").or_else(|| file.get("straggler")) {
        let plan = crate::fabric::StragglerPlan::parse(spec, cfg.dist.world())
            .map_err(|e| anyhow!("--straggler: {e}"))?;
        if !plan.is_quiet() {
            cfg.straggler = Some(plan);
        }
    }
    // Background traffic: `--background <seed>` installs a seeded
    // noisy-neighbor plan ([`crate::fabric::BgPlan::generate`]) over the
    // same horizon the chaos planner uses — deterministic in (seed,
    // topology, world, horizon), so noisy runs replay exactly.
    if let Some(seed) = args.get("background").or_else(|| file.get("background")) {
        let seed: u64 = seed.parse().context("--background")?;
        let horizon = cfg
            .compute_ns_per_iter()
            .saturating_mul((cfg.iterations as u64 + 1) * 2)
            .max(1_000_000);
        cfg.background =
            Some(crate::fabric::BgPlan::generate(seed, &cfg.topo, cfg.dist.world(), horizon));
    }
    // Adaptive precision backoff threshold: with `--wire-dtype auto`,
    // a layer whose error-feedback residual bound approaches this is
    // floored back to wider wire dtypes (see `EngineConfig::ef_tolerance`).
    let ef_tol: f64 = get("ef-tolerance", "0.05").parse().context("--ef-tolerance")?;
    if !(0.0..=1.0).contains(&ef_tol) {
        return Err(anyhow!("--ef-tolerance must lie in [0, 1], got {ef_tol}"));
    }
    cfg.ef_tolerance = ef_tol;
    // Measured collective selection: `--tuning-table <path>` loads a table
    // produced by `mlsl tune` and installs it with analytic fallback (a
    // table whose fingerprint does not match this topology is ignored at
    // query time). Without the flag, the analytic model stays the default.
    if let Some(path) = args.get("tuning-table").or_else(|| file.get("tuning-table")) {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read tuning table {path}"))?;
        let table = crate::tuner::TuningTable::parse(&text)
            .map_err(|e| anyhow!("parse tuning table {path}: {e}"))?;
        // Surface the fingerprint-mismatch fallback at install time (one
        // place for every subcommand) instead of silently running
        // analytic: a table probed on a different fabric — e.g.
        // single-rail vs striped, where the v3 fingerprint differs —
        // must be visibly rejected.
        if !table.matches(&cfg.topo) {
            crate::util::warn::warn(format!(
                "tuning table {path} fingerprint does not match {} — analytic fallback",
                cfg.topo.name
            ));
        }
        cfg.selection = crate::tuner::SelectionPolicy::TunedWithFallback(table);
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn defaults_build() {
        let cfg = engine_config(&args("")).unwrap();
        assert_eq!(cfg.model.name, "resnet50");
        assert_eq!(cfg.dist.world(), 16);
    }

    #[test]
    fn flags_override() {
        let cfg =
            engine_config(&args("--model vgg16 --nodes 8 --group 4 --mode bulk --wire int8"))
                .unwrap();
        assert_eq!(cfg.model.name, "vgg16");
        assert_eq!(cfg.dist.group_size(), 4);
        assert_eq!(cfg.mode, CommMode::BulkSync);
        assert_eq!(cfg.wire, WireDtype::Int8Block);
    }

    #[test]
    fn wire_dtype_flag_covers_fixed_and_auto() {
        // Default: fixed f32, no auto selection.
        let cfg = engine_config(&args("")).unwrap();
        assert_eq!(cfg.wire, WireDtype::F32);
        assert!(!cfg.wire_auto);
        // Fixed dtypes through the canonical flag.
        let cfg = engine_config(&args("--wire-dtype bf16")).unwrap();
        assert_eq!(cfg.wire, WireDtype::Bf16);
        assert!(!cfg.wire_auto);
        // auto → per-collective selection, fixed dtype stays f32.
        let cfg = engine_config(&args("--wire-dtype auto")).unwrap();
        assert_eq!(cfg.wire, WireDtype::F32);
        assert!(cfg.wire_auto);
        // The canonical flag wins over the legacy alias.
        let cfg = engine_config(&args("--wire-dtype int8 --wire f32")).unwrap();
        assert_eq!(cfg.wire, WireDtype::Int8Block);
        // `--wire auto` is NOT accepted through the alias: auto is a
        // selection mode, not a dtype.
        assert!(engine_config(&args("--wire auto")).is_err());
        assert!(engine_config(&args("--wire-dtype nope")).is_err());
    }

    #[test]
    fn file_config_parses_and_cli_wins() {
        let dir = std::env::temp_dir().join("mlsl_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.conf");
        std::fs::write(&p, "model = googlenet # comment\nnodes = 4\n\n# full-line comment\nmode = mpi\n").unwrap();
        let cfg =
            engine_config(&args(&format!("--config {} --nodes 32", p.display()))).unwrap();
        assert_eq!(cfg.model.name, "googlenet");
        assert_eq!(cfg.dist.world(), 32); // CLI overrides file
        assert_eq!(cfg.mode, CommMode::MpiNonBlocking);
    }

    #[test]
    fn bad_values_error() {
        assert!(engine_config(&args("--model nope")).is_err());
        assert!(engine_config(&args("--topo nope")).is_err());
        assert!(engine_config(&args("--mode nope")).is_err());
        assert!(engine_config(&args("--ranks-per-node 0")).is_err());
        assert!(engine_config(&args("--ranks-per-node two")).is_err());
    }

    #[test]
    fn chaos_and_churn_flags_thread_through() {
        // No flags → no plans installed.
        let cfg = engine_config(&args("")).unwrap();
        assert!(cfg.chaos.is_none());
        assert!(cfg.churn.is_none());
        // Same seed + config → identical plan (the determinism guarantee
        // starts at config resolution).
        let a = engine_config(&args("--topo eth10g-x2e2 --nodes 8 --chaos 42")).unwrap();
        let b = engine_config(&args("--topo eth10g-x2e2 --nodes 8 --chaos 42")).unwrap();
        assert_eq!(a.chaos, b.chaos);
        assert!(a.chaos.is_some());
        // Different seed → different plan.
        let c = engine_config(&args("--topo eth10g-x2e2 --nodes 8 --chaos 43")).unwrap();
        assert_ne!(a.chaos, c.chaos);
        // Churn parses, validates against the world size and sorts.
        let cfg = engine_config(&args("--nodes 4 --churn leave:3@1,join:3@2")).unwrap();
        let plan = cfg.churn.unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(plan.events[0].after_iter, 1);
        // Bad specs are config errors, not panics mid-run.
        assert!(engine_config(&args("--nodes 4 --churn leave:9@1")).is_err());
        assert!(engine_config(&args("--nodes 4 --churn join:0@1")).is_err());
        assert!(engine_config(&args("--nodes 4 --churn nonsense")).is_err());
        assert!(engine_config(&args("--nodes 1 --churn leave:0@1")).is_err());
        assert!(engine_config(&args("--chaos notanumber")).is_err());
    }

    #[test]
    fn straggler_background_and_tolerance_flags_thread_through() {
        let cfg = engine_config(&args("")).unwrap();
        assert!(cfg.straggler.is_none());
        assert!(cfg.background.is_none());
        assert_eq!(cfg.ef_tolerance, 0.05);
        // Stragglers parse and validate against the world size.
        let cfg = engine_config(&args("--nodes 4 --straggler 1:2.0,3:1.5")).unwrap();
        let plan = cfg.straggler.unwrap();
        assert_eq!(plan.factor_milli, vec![1000, 2000, 1000, 1500]);
        // An all-healthy spec installs nothing (stays on the quiet path).
        assert!(engine_config(&args("--nodes 4 --straggler all:1.0"))
            .unwrap()
            .straggler
            .is_none());
        assert!(engine_config(&args("--nodes 4 --straggler 9:2.0")).is_err());
        assert!(engine_config(&args("--nodes 4 --straggler 0:200.0")).is_err());
        assert!(engine_config(&args("--nodes 4 --straggler nonsense")).is_err());
        // Background plans are deterministic in the seed.
        let a = engine_config(&args("--nodes 8 --background 7")).unwrap();
        let b = engine_config(&args("--nodes 8 --background 7")).unwrap();
        assert_eq!(a.background, b.background);
        assert!(a.background.is_some());
        let c = engine_config(&args("--nodes 8 --background 8")).unwrap();
        assert_ne!(a.background, c.background);
        assert!(engine_config(&args("--background notanumber")).is_err());
        // EF tolerance parses and is range-checked.
        assert_eq!(
            engine_config(&args("--ef-tolerance 0.01")).unwrap().ef_tolerance,
            0.01
        );
        assert!(engine_config(&args("--ef-tolerance 1.5")).is_err());
        assert!(engine_config(&args("--ef-tolerance nope")).is_err());
    }

    #[test]
    fn trace_flag_threads_through() {
        assert!(!engine_config(&args("")).unwrap().trace);
        assert!(engine_config(&args("--trace=true")).unwrap().trace);
        // A path value also turns tracing on (simulate exports to it).
        assert!(engine_config(&args("--trace out.json")).unwrap().trace);
    }

    #[test]
    fn sim_threads_parses_and_defaults_to_serial() {
        assert_eq!(engine_config(&args("")).unwrap().sim_threads, 1);
        assert_eq!(engine_config(&args("--sim-threads 4")).unwrap().sim_threads, 4);
        assert!(engine_config(&args("--sim-threads 0")).is_err());
        assert!(engine_config(&args("--sim-threads four")).is_err());
    }

    #[test]
    fn tuning_table_flag_installs_tuned_policy() {
        use crate::tuner::{SelectionPolicy, TuningTable};
        // No flag → analytic stays the default.
        let cfg = engine_config(&args("")).unwrap();
        assert_eq!(cfg.selection, SelectionPolicy::Analytic);
        // A (tiny) table on disk → tuned with fallback.
        let topo = Topology::by_name("eth10g").unwrap();
        let mut table = TuningTable::for_topology(&topo);
        table.insert(
            crate::collectives::CollectiveKind::Allreduce,
            crate::tuner::table::MeasuredCell::new(
                4,
                1024,
                vec![(crate::collectives::Algorithm::Ring, 5_000)],
            ),
        );
        let dir = std::env::temp_dir().join("mlsl_tuning_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("table.json");
        std::fs::write(&p, table.to_json_string()).unwrap();
        let cfg = engine_config(&args(&format!(
            "--topo eth10g --tuning-table {}",
            p.display()
        )))
        .unwrap();
        assert_eq!(cfg.selection.name(), "tuned+fallback");
        assert_eq!(cfg.selection, SelectionPolicy::TunedWithFallback(table));
        // Unreadable / malformed tables are hard errors, not silence.
        assert!(engine_config(&args("--tuning-table /nonexistent/t.json")).is_err());
        let bad = dir.join("bad.json");
        std::fs::write(&bad, "{not json").unwrap();
        assert!(engine_config(&args(&format!("--tuning-table {}", bad.display()))).is_err());
    }

    #[test]
    fn tiered_topology_flags() {
        // Preset suffix form.
        let cfg = engine_config(&args("--topo eth10g-x2")).unwrap();
        assert_eq!(cfg.topo.ranks_per_node(), 2);
        assert_eq!(cfg.topo.name, "eth10g-x2");
        // Explicit flag form overrides the preset's grouping.
        let cfg = engine_config(&args("--topo opa --ranks-per-node 4")).unwrap();
        assert_eq!(cfg.topo.ranks_per_node(), 4);
        assert_eq!(cfg.topo.name, "omnipath100g-x4");
        // Default stays flat.
        let cfg = engine_config(&args("")).unwrap();
        assert_eq!(cfg.topo.ranks_per_node(), 1);
        assert!(!cfg.topo.is_hierarchical());
    }

    #[test]
    fn rail_flags_and_suffixes_thread_through() {
        // Preset suffix form.
        let cfg = engine_config(&args("--topo eth10g-x2e2")).unwrap();
        assert_eq!(cfg.topo.name, "eth10g-x2e2");
        assert_eq!(cfg.topo.rails, 2);
        // Explicit flag form overrides the preset's rail count.
        let cfg = engine_config(&args("--topo eth10g-x2e2 --rails 4")).unwrap();
        assert_eq!(cfg.topo.name, "eth10g-x2e4");
        assert_eq!(cfg.topo.rails, 4);
        // Flag on a flat preset.
        let cfg = engine_config(&args("--topo opa --rails 2")).unwrap();
        assert_eq!(cfg.topo.name, "omnipath100g-x1e2");
        assert_eq!(cfg.topo.rails, 2);
        // Rails survive a ranks-per-node override (rescale preserves
        // rail counts).
        let cfg = engine_config(&args("--topo eth10g-x8r16e2 --ranks-per-node 2")).unwrap();
        assert_eq!(cfg.topo.name, "eth10g-x2r16e2");
        assert_eq!(cfg.topo.rails, 2);
        // Default stays single-rail.
        let cfg = engine_config(&args("")).unwrap();
        assert_eq!(cfg.topo.rails, 1);
        // Malformed values are clean config errors — including absurd
        // rail counts (capped, so the sim never allocates for them).
        assert!(engine_config(&args("--rails 0")).is_err());
        assert!(engine_config(&args("--rails two")).is_err());
        assert!(engine_config(&args("--rails 999999999")).is_err());
        assert!(engine_config(&args("--topo eth10g-x2e0")).is_err());
        assert!(engine_config(&args("--topo eth10g-x2e999999999")).is_err());
    }

    #[test]
    fn rack_suffix_resolves_and_survives_rpn_override() {
        // 3-level preset suffix: 8 ranks/node, 16 nodes/rack.
        let cfg = engine_config(&args("--topo eth10g-x8r16")).unwrap();
        assert_eq!(cfg.topo.name, "eth10g-x8r16");
        assert_eq!(cfg.topo.level_sizes(), vec![8, 128]);
        // Overriding the node size keeps the rack (same nodes-per-rack).
        let cfg =
            engine_config(&args("--topo eth10g-x8r16 --ranks-per-node 2")).unwrap();
        assert_eq!(cfg.topo.name, "eth10g-x2r16");
        assert_eq!(cfg.topo.level_sizes(), vec![2, 32]);
        // Malformed suffixes are clean config errors, not panics.
        assert!(engine_config(&args("--topo eth10g-x0r16")).is_err());
        assert!(engine_config(&args("--topo eth10g-x2r1")).is_err());
        assert!(engine_config(&args("--topo eth10g-x2r16 --ranks-per-node 0")).is_err());
    }
}

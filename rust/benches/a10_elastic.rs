//! **Ablation A10**: elastic membership — shrink-by-one-node recovery
//! cost and tuning-table reuse across churn.
//!
//! Cloud training jobs lose and regain nodes; the paper's premise is
//! that communication machinery should absorb that without a fresh
//! setup pass. ROADMAP "Elastic membership and fault scenarios": ranks
//! leave/join between iterations, communicators rebuild for the
//! survivors without renumbering anybody's data, and the
//! fingerprint-keyed tuning table keeps answering through its
//! nearest-row lookup instead of forcing a re-probe. The observable
//! contract this bench ASSERTS, at p = 128 on `eth10g-x8r16e2`
//! (8 ranks/node, 16 nodes, 2 NIC rails):
//!
//! * losing one whole node (ranks 120..128 leave at the same boundary)
//!   costs at most 2 healthy-iteration times: every per-iteration span
//!   of the churned run — including the one that absorbs quiesce +
//!   rebuild — stays under `2 * healthy.iter_ns`;
//! * the table probed at p = 128 is REUSED at p' = 120: the tuned pick
//!   is the argmin of the legal measurements in the snapped p = 128
//!   row (table reuse, not re-probe), `TunedWithFallback` agrees with
//!   strict `Tuned` (so the fingerprint still matches the shrunken
//!   world — no analytic fallback), and the pick's freshly measured
//!   time at p' = 120 is within 10% of the fresh best there;
//! * a shrink below the smallest probed row does NOT silently ride the
//!   log-distance scan: it clamps to the edge row and trips the
//!   out-of-grid counter.
//!
//! Run: `cargo bench --bench a10_elastic`

use mlsl::collectives::program::CollectiveKind;
use mlsl::collectives::Algorithm;
use mlsl::engine::{simulate, ChurnPlan, CommMode, EngineConfig};
use mlsl::fabric::topology::Topology;
use mlsl::metrics::print_table;
use mlsl::models::ModelDesc;
use mlsl::tuner::policy::allreduce_legal;
use mlsl::tuner::table::MeasuredCell;
use mlsl::tuner::{out_of_grid_count, probe, SelectionPolicy, TuningTable};

const P: usize = 128;
const P_AFTER: usize = 120; // one whole 8-rank node gone

fn main() {
    let topo = Topology::by_name("eth10g-x8r16e2").expect("preset exists");

    // -- a tuning table probed on the HEALTHY world ---------------------
    // Rank rows 32 and 128 bracket the post-churn count; every timing is
    // a real simulator measurement so "measured best" means something.
    let hier8 = Algorithm::try_hier(&[8]).unwrap();
    let hier8x128 = Algorithm::try_hier(&[8, 128]).unwrap();
    let mut table = TuningTable::for_topology(&topo);
    for p in [32usize, P] {
        let mut algs = vec![Algorithm::Ring, Algorithm::RecursiveDoubling, hier8];
        if p == P {
            algs.push(hier8x128);
        }
        for bytes in [1u64 << 10, 16 << 20] {
            let timings: Vec<(Algorithm, u64)> = algs
                .iter()
                .map(|&a| (a, probe::measure_ns(&topo, CollectiveKind::Allreduce, a, p, bytes)))
                .collect();
            table.insert(CollectiveKind::Allreduce, MeasuredCell::new(p, bytes, timings));
        }
    }
    // The fingerprint hashes fabric physics (tiers, rates, rails) — not
    // the rank count — so the table survives the shrink verbatim.
    assert!(table.matches(&topo), "pre-churn table must match its own fabric");

    // -- shrink-by-one-node recovery cost -------------------------------
    let model = ModelDesc::by_name("vgg16").expect("model exists");
    let policy = SelectionPolicy::TunedWithFallback(table.clone());
    let mut healthy_cfg = EngineConfig::new(model.clone(), topo.clone(), P);
    healthy_cfg.iterations = 3;
    healthy_cfg.mode = CommMode::BulkSync;
    healthy_cfg.selection = policy.clone();
    let healthy = simulate(healthy_cfg);
    assert!(healthy.iter_ns > 0);

    let spec: Vec<String> = (P_AFTER..P).map(|r| format!("leave:{r}@1")).collect();
    let plan = ChurnPlan::parse(&spec.join(",")).expect("well-formed churn spec");
    plan.validate(P).expect("spec is valid at p=128");
    let mut churn_cfg = EngineConfig::new(model, topo.clone(), P);
    churn_cfg.iterations = 3;
    churn_cfg.mode = CommMode::BulkSync;
    churn_cfg.selection = policy.clone();
    churn_cfg.churn = Some(plan);
    let churned = simulate(churn_cfg);
    assert_eq!(
        churned.churn_log.len(),
        P - P_AFTER,
        "all {} leaves must apply: {:?}",
        P - P_AFTER,
        churned.churn_log
    );
    assert!(!churned.per_iter_ns.is_empty());
    let bound = 2 * healthy.iter_ns;
    let worst = *churned.per_iter_ns.iter().max().unwrap();
    for (i, &span) in churned.per_iter_ns.iter().enumerate() {
        assert!(
            span <= bound,
            "iteration {i} of the churned run took {span} ns — recovery must \
             cost <= 2 healthy iterations ({bound} ns; healthy {})",
            healthy.iter_ns
        );
    }
    let mut rows = vec![
        vec![
            "healthy".into(),
            P.to_string(),
            format!("{:.3}", healthy.iter_ns as f64 / 1e6),
            "-".into(),
        ],
        vec![
            "node 15 leaves @1".into(),
            P_AFTER.to_string(),
            format!("{:.3}", worst as f64 / 1e6),
            format!("{:.2}x", worst as f64 / healthy.iter_ns.max(1) as f64),
        ],
    ];

    // -- table reuse at p' = 120: nearest row, no re-probe --------------
    let bytes = 16u64 << 20;
    assert_eq!(
        table.snapped_row(CollectiveKind::Allreduce, P_AFTER),
        Some(P),
        "p'=120 must snap to the measured p=128 row"
    );
    let legal = |a: Algorithm| allreduce_legal(a, P_AFTER);
    let pick = table
        .lookup(CollectiveKind::Allreduce, P_AFTER, bytes, &legal)
        .expect("snapped row answers");
    // The pick IS the stored row's legal argmin — rdoubling (120 is not a
    // power of two) and hier 8x128 (128 does not divide 120) fall away.
    let row_best = {
        let cells = table.cells(CollectiveKind::Allreduce);
        let cell = cells
            .iter()
            .find(|c| c.ranks == P && c.bytes == bytes)
            .expect("measured cell");
        cell.timings
            .iter()
            .filter(|((a, _), _)| legal(*a))
            .min_by_key(|(_, ns)| *ns)
            .map(|((a, _), _)| *a)
            .expect("some legal algorithm")
    };
    assert_eq!(pick, row_best, "tuned pick must be the snapped row's legal argmin");
    assert!(
        !allreduce_legal(Algorithm::RecursiveDoubling, P_AFTER)
            && !allreduce_legal(hier8x128, P_AFTER),
        "the interesting candidates really are illegal at p'=120"
    );
    // No fingerprint-mismatch fallback: the fallback policy answers from
    // the same table, and both agree.
    let strict = SelectionPolicy::Tuned(table.clone());
    assert_eq!(
        strict.choose_allreduce(&topo, P_AFTER, bytes),
        policy.choose_allreduce(&topo, P_AFTER, bytes),
        "TunedWithFallback must still be consulting the table after the shrink"
    );
    // And the reused row is a good answer: the pick's fresh measurement
    // at p'=120 is within 10% of the fresh best there.
    let fresh: Vec<(Algorithm, u64)> = [Algorithm::Ring, hier8]
        .into_iter()
        .map(|a| (a, probe::measure_ns(&topo, CollectiveKind::Allreduce, a, P_AFTER, bytes)))
        .collect();
    let fresh_best = fresh.iter().map(|(_, t)| *t).min().unwrap();
    let pick_fresh = fresh
        .iter()
        .find(|(a, _)| *a == pick)
        .map(|(_, t)| *t)
        .expect("pick is a legal candidate");
    assert!(
        pick_fresh as f64 <= 1.10 * fresh_best as f64,
        "reused pick {pick} measures {pick_fresh} ns at p'=120 vs fresh best {fresh_best} ns"
    );
    rows.push(vec![
        format!("tuned pick @ p'={P_AFTER}"),
        format!("row {P}"),
        format!("{:.3}", pick_fresh as f64 / 1e6),
        format!("{pick}"),
    ]);

    // -- shrinking below the grid clamps (and is counted) ---------------
    let before = out_of_grid_count();
    assert_eq!(
        table.snapped_row(CollectiveKind::Allreduce, 16),
        Some(32),
        "below-grid shrink clamps to the smallest probed row"
    );
    assert!(out_of_grid_count() >= before + 1, "the clamp must be visible on the counter");

    print_table(
        "A10: one-node shrink at p=128, eth10g-x8r16e2 (vgg16, bulk-sync)",
        &["scenario", "ranks", "worst iter ms", "note"],
        &rows,
    );
    println!("\nexpected shape: the churn boundary quiesces in-flight collectives, drops the");
    println!("departed node and rebuilds programs for the 120 survivors in place — the");
    println!("recovery iteration stays under 2 healthy iterations and later iterations run");
    println!("slightly faster (less data to move). Selection keeps riding the p=128 table");
    println!("row via the nearest-row snap with the legality filter stripping rdoubling and");
    println!("8x128 at p'=120; only a shrink below the probed grid clamps and warns. OK");
}

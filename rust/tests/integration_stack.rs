//! Cross-module integration tests: Session → engine → simulator shapes;
//! engine-vs-analytic cross-validation; communicator stress; trainer
//! (real PJRT) smoke — the layers composed in pairs and end-to-end.

use mlsl::analytic;
use mlsl::collectives::{PriorityPolicy, WireDtype};
use mlsl::engine::{simulate, CommMode, EngineConfig};
use mlsl::fabric::topology::{NodeSpec, Topology};
use mlsl::mlsl::{Communicator, Distribution, Session};
use mlsl::models::ModelDesc;

fn cfg(model: &str, p: usize) -> EngineConfig {
    EngineConfig::new(ModelDesc::by_name(model).unwrap(), Topology::omnipath_100g(), p)
}

// ---------------------------------------------------------------------------
// engine ↔ analytic cross-validation
// ---------------------------------------------------------------------------

#[test]
fn engine_matches_analytic_on_bulk_sync() {
    // With no overlap (bulk-sync) the analytic prediction decomposes as
    // compute + serialized allreduces; sim and closed-form must agree on
    // ORDER (within 2x — the closed form ignores pipeline effects).
    let model = ModelDesc::by_name("resnet50").unwrap();
    let topo = Topology::eth_10g();
    let node = NodeSpec::skylake_6148();
    let p = 8;
    let batch = 32;

    let mut c = cfg("resnet50", p);
    c.topo = topo.clone();
    c.mode = CommMode::BulkSync;
    c.batch = batch;
    let r = simulate(c);

    let mut comm_ns = 0u64;
    for (_, layer) in model.weighted_layers() {
        comm_ns += mlsl::collectives::selector::predict_allreduce_ns(
            &topo,
            mlsl::collectives::Algorithm::Auto,
            p,
            layer.weight_bytes(),
        );
    }
    let compute_ns = node.compute_ns(model.step_flops(batch), 2);
    let predicted = compute_ns + comm_ns;
    let ratio = r.iter_ns as f64 / predicted as f64;
    assert!((0.5..2.0).contains(&ratio), "sim {} vs analytic {}", r.iter_ns, predicted);
}

#[test]
fn efficiency_ordering_across_fabrics() {
    // Same workload: omnipath must beat 25GbE must beat 10GbE.
    let mut effs = Vec::new();
    for topo in [Topology::omnipath_100g(), Topology::eth_25g(), Topology::eth_10g()] {
        let mut c1 = cfg("resnet50", 1);
        c1.topo = topo.clone();
        c1.batch = 16;
        let r1 = simulate(c1);
        let mut c = cfg("resnet50", 16);
        c.topo = topo;
        c.batch = 16;
        let r = simulate(c);
        effs.push(r1.iter_ns as f64 / r.iter_ns as f64);
    }
    assert!(effs[0] >= effs[1] && effs[1] >= effs[2], "{effs:?}");
}

// ---------------------------------------------------------------------------
// Session → engine consistency
// ---------------------------------------------------------------------------

#[test]
fn session_comm_count_matches_engine_traffic() {
    // The number of gradient allreduces the Session derives equals the
    // number of distinct gradient collectives the engine runs.
    let model = ModelDesc::by_name("googlenet").unwrap();
    let weighted = model.weighted_layers().count();
    let mut s = Session::new(Distribution::data_parallel(4));
    s.add_model(&model);
    let derived = s.iteration_comms(32).len();
    assert_eq!(derived, weighted);

    // Engine: bytes on the wire per iteration per node ≈ 2*(p-1)/p*W.
    let mut c = cfg("googlenet", 4);
    c.iterations = 2;
    c.jitter = 0.0;
    let r = simulate(c);
    let w = model.total_weight_bytes() as f64;
    let per_iter = r.bytes_per_node as f64 / 3.0; // warmup + 2 measured
    let ideal = 2.0 * 3.0 / 4.0 * w;
    let ratio = per_iter / ideal;
    assert!((0.8..1.3).contains(&ratio), "bytes/iter {per_iter:.3e} vs ideal {ideal:.3e}");
}

#[test]
fn hybrid_reduces_gradient_traffic_for_fc_models() {
    let mut data = cfg("alexnet", 8);
    data.topo = Topology::eth_10g();
    data.mode = CommMode::BulkSync;
    data.batch = 8;
    let rd = simulate(data);

    let mut hybrid = cfg("alexnet", 8);
    hybrid.topo = Topology::eth_10g();
    hybrid.mode = CommMode::BulkSync;
    hybrid.batch = 8;
    hybrid.dist = Distribution::new(8, 4);
    let rh = simulate(hybrid);

    // 4-way model sharding cuts the fc gradient allreduce 4x; activation
    // traffic is tiny at batch 8. Exposed comm must drop.
    assert!(
        rh.exposed_comm_ns < rd.exposed_comm_ns,
        "hybrid {} vs data {}",
        rh.exposed_comm_ns,
        rd.exposed_comm_ns
    );
}

// ---------------------------------------------------------------------------
// Communicator stress
// ---------------------------------------------------------------------------

#[test]
fn communicator_many_small_ops_stress() {
    let p = 4;
    let comms = Communicator::world(p);
    let handles: Vec<_> = comms
        .into_iter()
        .map(|c| {
            std::thread::spawn(move || {
                let mut acc = 0.0f32;
                for i in 0..200u32 {
                    let prio = (i % 7) as u8;
                    let h = c.allreduce_async(
                        vec![1.0; 16 + (i as usize % 64)],
                        mlsl::collectives::Algorithm::Auto,
                        if i % 3 == 0 { WireDtype::Bf16 } else { WireDtype::F32 },
                        prio,
                    );
                    acc += h.wait()[0];
                }
                acc
            })
        })
        .collect();
    for h in handles {
        let acc = h.join().unwrap();
        assert_eq!(acc, 200.0 * 4.0);
    }
}

#[test]
fn priority_policies_change_sim_behaviour_not_results() {
    // Same config, different priority policy: timing differs (on a slow
    // fabric), but the amount of data moved is identical.
    let mk = |policy| {
        let mut c = cfg("vgg16", 8);
        c.topo = Topology::eth_10g();
        c.policy = policy;
        c.batch = 16;
        c.iterations = 2;
        simulate(c)
    };
    let a = mk(PriorityPolicy::ByLayer);
    let b = mk(PriorityPolicy::None);
    assert_eq!(a.bytes_per_node, b.bytes_per_node, "traffic volume must not depend on policy");
    assert!(a.iter_ns <= b.iter_ns, "priorities must not hurt");
}

#[test]
fn reverse_priority_is_pessimal() {
    let mk = |policy| {
        let mut c = cfg("vgg16", 8);
        c.topo = Topology::eth_10g();
        c.policy = policy;
        c.batch = 16;
        c.iterations = 2;
        simulate(c).exposed_comm_ns
    };
    let by_layer = mk(PriorityPolicy::ByLayer);
    let reverse = mk(PriorityPolicy::ReverseLayer);
    assert!(by_layer < reverse, "bylayer {by_layer} vs reverse {reverse}");
}

// ---------------------------------------------------------------------------
// Real-stack smoke (needs `make artifacts`)
// ---------------------------------------------------------------------------

fn tiny_artifacts() -> Option<std::path::PathBuf> {
    let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    d.join("manifest.json").exists().then_some(d)
}

#[test]
fn trainer_single_vs_dual_rank_losses_match_at_step0() {
    // Step-0 loss is data-dependent only through the batch; with the same
    // seed the 1-rank and 2-rank runs see the same rank-0 shard, and the
    // 2-rank loss is the mean over both shards — all finite and near
    // ln(vocab) at init.
    let Some(dir) = tiny_artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut cfg1 = mlsl::trainer::TrainerConfig::new(&dir);
    cfg1.ranks = 1;
    cfg1.steps = 2;
    cfg1.log_every = 0;
    let r1 = mlsl::trainer::train(&cfg1).unwrap();
    let mut cfg2 = mlsl::trainer::TrainerConfig::new(&dir);
    cfg2.ranks = 2;
    cfg2.steps = 2;
    cfg2.log_every = 0;
    let r2 = mlsl::trainer::train(&cfg2).unwrap();
    for l in r1.losses.iter().chain(&r2.losses) {
        assert!(l.is_finite());
        assert!((3.0..8.0).contains(l), "{l}");
    }
}

#[test]
fn trainer_fifo_policy_also_converges() {
    let Some(dir) = tiny_artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut cfg = mlsl::trainer::TrainerConfig::new(&dir);
    cfg.ranks = 2;
    cfg.steps = 8;
    cfg.policy = PriorityPolicy::None;
    cfg.log_every = 0;
    let res = mlsl::trainer::train(&cfg).unwrap();
    assert!(res.losses.last().unwrap() < res.losses.first().unwrap());
}

//! The REAL data-parallel trainer: Rust ranks executing AOT-compiled
//! JAX+Pallas train steps via PJRT, exchanging gradients through this
//! library's prioritized collectives. Python never runs here.
//!
//! Per rank and step:
//! 1. `grad_step` executable: (params…, tokens) → (loss, grads…)
//! 2. gradients allreduced — issued in REVERSE forward order (the order
//!    backprop produces them) with priority = forward order, over the
//!    in-process fabric through each rank's comm core;
//! 3. gradients averaged, `apply_update`: (params…, moms…, grads…) →
//!    (params'…, moms'…).
//!
//! Rank 0 initializes parameters (GPT-2-style, mirroring
//! `python/compile/model.py::init_params`) and broadcasts them, so every
//! rank starts bit-identical — asserted by a replica-consistency check.

pub mod data;

use anyhow::{Context, Result};
use std::path::Path;
use std::sync::mpsc::channel;
use std::thread;

use crate::collectives::{Algorithm, PriorityPolicy, WireDtype};
use crate::fabric::shm;
use crate::mlsl::Communicator;
use crate::runtime::{Input, Manifest, Runtime};
use crate::trainer::data::TokenGen;
use crate::util::prng::Prng;

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    /// `artifacts/<preset>` directory.
    pub artifacts: std::path::PathBuf,
    pub ranks: usize,
    pub steps: usize,
    pub wire: WireDtype,
    pub policy: PriorityPolicy,
    pub seed: u64,
    pub log_every: usize,
}

impl TrainerConfig {
    pub fn new<P: AsRef<Path>>(artifacts: P) -> Self {
        Self {
            artifacts: artifacts.as_ref().to_path_buf(),
            ranks: 2,
            steps: 20,
            wire: WireDtype::F32,
            policy: PriorityPolicy::ByLayer,
            seed: 42,
            log_every: 10,
        }
    }
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    /// Mean loss across ranks, one entry per step.
    pub losses: Vec<f32>,
    /// Wall-clock per step, ms.
    pub step_ms: Vec<f64>,
    /// Time spent inside allreduce wait, ms per step (rank 0).
    pub comm_wait_ms: Vec<f64>,
    pub preset: String,
    pub n_params: usize,
}

/// GPT-2-style init mirroring python/compile/model.py::init_params.
fn init_param(spec: &crate::runtime::ParamSpec, n_layers: usize, rng: &mut Prng) -> Vec<f32> {
    let n = spec.size;
    let name = &spec.name;
    if name.ends_with("_g") {
        vec![1.0; n]
    } else if name.ends_with("_b") || name.ends_with(".b1") || name.ends_with(".b2") {
        vec![0.0; n]
    } else {
        let std = if name.ends_with(".wo") || name.ends_with(".w2") {
            0.02 / (2.0 * n_layers as f64).sqrt()
        } else {
            0.02
        };
        (0..n).map(|_| (rng.normal() * std) as f32).collect()
    }
}

/// Run data-parallel training; returns the loss curve.
pub fn train(cfg: &TrainerConfig) -> Result<TrainResult> {
    let manifest = Manifest::load(&cfg.artifacts)?;
    manifest.validate()?;
    let p = cfg.ranks;
    let n_params = manifest.params.len();

    let endpoints = shm::fabric(p);
    let (res_tx, res_rx) = channel();

    let mut joins = Vec::new();
    for ep in endpoints {
        let rank = ep.rank;
        let manifest = manifest.clone();
        let cfg = cfg.clone();
        let res_tx = res_tx.clone();
        joins.push(
            thread::Builder::new()
                .name(format!("mlsl-rank-{rank}"))
                .spawn(move || -> Result<()> {
                    let out = rank_main(rank, ep, &manifest, &cfg)?;
                    res_tx.send((rank, out)).ok();
                    Ok(())
                })
                .context("spawn rank")?,
        );
    }
    drop(res_tx);

    let mut per_rank: Vec<Option<RankOutput>> = (0..p).map(|_| None).collect();
    for (rank, out) in res_rx {
        per_rank[rank] = Some(out);
    }
    for j in joins {
        j.join().expect("rank panicked")?;
    }

    let outs: Vec<RankOutput> = per_rank.into_iter().map(|o| o.expect("rank result")).collect();
    // Replica consistency: every rank must have IDENTICAL losses (they all
    // apply the same averaged gradients to the same initial params).
    for r in 1..p {
        for (s, (a, b)) in outs[0].losses.iter().zip(&outs[r].losses).enumerate() {
            anyhow::ensure!(
                (a - b).abs() <= 1e-4 * a.abs().max(1.0),
                "replica divergence at step {s}: rank0={a} rank{r}={b}"
            );
        }
    }

    Ok(TrainResult {
        losses: outs[0].losses.clone(),
        step_ms: outs[0].step_ms.clone(),
        comm_wait_ms: outs[0].comm_wait_ms.clone(),
        preset: manifest.preset.clone(),
        n_params,
    })
}

struct RankOutput {
    losses: Vec<f32>,
    step_ms: Vec<f64>,
    comm_wait_ms: Vec<f64>,
}

fn rank_main(
    rank: usize,
    ep: shm::ShmEndpoint,
    manifest: &Manifest,
    cfg: &TrainerConfig,
) -> Result<RankOutput> {
    let p = cfg.ranks;
    let comm = Communicator::from_endpoint(ep, p);
    let rt = Runtime::cpu()?;
    let grad_exe = rt.load_hlo(&manifest.grad_step.file)?;
    let update_exe = rt.load_hlo(&manifest.apply_update.file)?;

    // ---- parameter init + broadcast (rank 0 is the source of truth) ----
    let mut rng = Prng::seed(cfg.seed);
    let mut params: Vec<Vec<f32>> = manifest
        .params
        .iter()
        .map(|s| {
            if rank == 0 {
                init_param(s, manifest.n_layers, &mut rng)
            } else {
                vec![0.0; s.size]
            }
        })
        .collect();
    for buf in params.iter_mut() {
        let got = comm.broadcast(std::mem::take(buf), 0);
        *buf = got;
    }
    let mut moms: Vec<Vec<f32>> = manifest.params.iter().map(|s| vec![0.0; s.size]).collect();

    // ---- training loop ----
    let mut gen = TokenGen::new(manifest.vocab, cfg.seed ^ (0xD00D + rank as u64));
    let tokens_shape = manifest.tokens_shape.clone();
    let inv_p = 1.0 / p as f32;
    let mut losses = Vec::with_capacity(cfg.steps);
    let mut step_ms = Vec::with_capacity(cfg.steps);
    let mut comm_wait_ms = Vec::with_capacity(cfg.steps);

    for step in 0..cfg.steps {
        let t0 = std::time::Instant::now();
        let tokens = gen.batch(tokens_shape[0], tokens_shape[1]);

        // 1. grad_step
        let mut inputs: Vec<Input> = params
            .iter()
            .zip(&manifest.params)
            .map(|(d, s)| Input::f32(d.clone(), &s.shape))
            .collect();
        inputs.push(Input::i32(tokens, &tokens_shape));
        let mut outs = grad_exe.run(&inputs)?;
        let loss_local = outs[0][0];
        let grads_raw: Vec<Vec<f32>> = outs.drain(1..).collect();

        // 2. prioritized allreduce: issue in REVERSE forward order (the
        //    order backprop would emit them), priority by policy → the
        //    comm cores complete the FIRST layers first.
        let t_comm = std::time::Instant::now();
        let mut handles: Vec<(usize, crate::progress::Handle)> = Vec::with_capacity(n_grads(&grads_raw));
        let mut grads: Vec<Option<Vec<f32>>> = grads_raw.into_iter().map(Some).collect();
        for idx in (0..grads.len()).rev() {
            let buf = grads[idx].take().expect("grad present");
            let prio = cfg.policy.assign(manifest.params[idx].fwd_order, manifest.params.len());
            let h = comm.allreduce_async(buf, Algorithm::Auto, cfg.wire, prio);
            handles.push((idx, h));
        }
        // Consume completions in FORWARD order — the order the next
        // forward pass needs them (what prioritization optimizes for).
        handles.sort_by_key(|(idx, _)| *idx);
        for (idx, h) in handles {
            let mut g = h.wait();
            for v in g.iter_mut() {
                *v *= inv_p;
            }
            grads[idx] = Some(g);
        }
        let comm_elapsed = t_comm.elapsed().as_secs_f64() * 1e3;

        // 3. Loss allreduce (tiny, urgent).
        let loss_sum = comm.allreduce(vec![loss_local])[0];
        let loss = loss_sum * inv_p;

        // 4. apply_update
        let mut upd_inputs: Vec<Input> = Vec::with_capacity(3 * grads.len());
        for (d, s) in params.iter().zip(&manifest.params) {
            upd_inputs.push(Input::f32(d.clone(), &s.shape));
        }
        for (d, s) in moms.iter().zip(&manifest.params) {
            upd_inputs.push(Input::f32(d.clone(), &s.shape));
        }
        for (g, s) in grads.iter().zip(&manifest.params) {
            upd_inputs.push(Input::f32(g.clone().expect("reduced"), &s.shape));
        }
        let mut new_state = update_exe.run(&upd_inputs)?;
        let new_moms: Vec<Vec<f32>> = new_state.drain(grads.len()..).collect();
        params = new_state;
        moms = new_moms;

        losses.push(loss);
        step_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        comm_wait_ms.push(comm_elapsed);
        if rank == 0 && cfg.log_every > 0 && step % cfg.log_every == 0 {
            eprintln!(
                "step {step:4}  loss {loss:.4}  ({:.0} ms, comm {:.1} ms)",
                step_ms.last().unwrap(),
                comm_elapsed
            );
        }
    }

    comm.shutdown();
    Ok(RankOutput { losses, step_ms, comm_wait_ms })
}

fn n_grads(g: &[Vec<f32>]) -> usize {
    g.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_artifacts() -> Option<std::path::PathBuf> {
        let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .join("tiny");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn two_rank_training_reduces_loss() {
        let Some(dir) = tiny_artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let mut cfg = TrainerConfig::new(dir);
        cfg.ranks = 2;
        cfg.steps = 12;
        cfg.log_every = 0;
        let res = train(&cfg).unwrap();
        assert_eq!(res.losses.len(), 12);
        let first = res.losses[0];
        let last = *res.losses.last().unwrap();
        // tiny vocab=512: initial loss ~ ln(512) ≈ 6.24; must drop.
        assert!(first > 5.0, "{first}");
        assert!(last < first - 0.2, "no learning: {first} -> {last}");
    }

    #[test]
    fn int8_wire_still_learns() {
        let Some(dir) = tiny_artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let mut cfg = TrainerConfig::new(dir);
        cfg.ranks = 2;
        cfg.steps = 10;
        cfg.wire = WireDtype::Int8Block;
        cfg.log_every = 0;
        let res = train(&cfg).unwrap();
        let first = res.losses[0];
        let last = *res.losses.last().unwrap();
        assert!(last < first - 0.1, "quantized training diverged: {first} -> {last}");
    }
}

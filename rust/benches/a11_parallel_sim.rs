//! **Ablation A11**: the partitioned parallel simulator
//! (`collectives::parexec`) — conservative-lookahead windows over
//! node-sharded `NetSim`s (`--sim-threads`).
//!
//! The observable contract this bench ASSERTS:
//!
//! * **exactness** — a partitioned run reproduces the serial simulator
//!   byte-for-byte: full delivered-message/completion equality for real
//!   ring programs at p = 256, and identical finish times / message
//!   counts for the O(p)-state pattern workloads at p = 1024 — before
//!   any timing is taken;
//! * **speedup** — the full p = 4096 ring allreduce (33.5 M messages)
//!   runs >= 2x faster with 4 worker threads than serial (asserted only
//!   when the host actually has >= 4 cores);
//! * **scale** — p = 65,536 workloads (full recursive doubling, and a
//!   128-round ring window, honestly labeled) complete in wall-clock
//!   seconds; a full 131,070-round ring at that scale is ~8.6e9
//!   messages, which no event-driven simulator does in seconds, so the
//!   bench prints the linear extrapolation instead of pretending.
//!
//! Emits `BENCH_parallel_sim.json` (repo root) with serial vs.
//! partitioned wall-clock per case; the representative numbers are
//! recorded in `docs/ARCHITECTURE.md` §"Simulator performance".
//!
//! Run: `cargo bench --bench a11_parallel_sim`

use std::time::Instant;

use mlsl::collectives::parexec::{
    run_collective, run_collective_serial, run_pattern, FleetConfig, ParOutcome, Pattern,
    PatternSpec,
};
use mlsl::collectives::program::allreduce_ring;
use mlsl::collectives::WireDtype;
use mlsl::fabric::topology::Topology;
use mlsl::metrics::print_table;

const THREADS: usize = 4;

fn topo() -> Topology {
    Topology::eth_10g() // 10 Gbit/s, 30 us alpha: lookahead = 30 us
}

fn time_pattern(spec: &PatternSpec, cfg: &FleetConfig) -> (f64, ParOutcome) {
    let t0 = Instant::now();
    let out = run_pattern(&topo(), spec, cfg);
    (t0.elapsed().as_secs_f64() * 1e3, out)
}

struct Case {
    label: &'static str,
    spec: PatternSpec,
    serial_ms: f64,
    par_ms: f64,
}

fn main() {
    let host_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // -- exactness first: nothing below is worth timing if this fails --
    // Real chunk programs, byte-level equality at p = 256.
    let t = topo();
    let (p, n) = (256usize, 64 << 10);
    let serial =
        run_collective_serial(&t, p, allreduce_ring(p, n), WireDtype::F32, 1, None, true, false);
    for (shards, threads) in [(2usize, 1usize), (4, 4)] {
        let cfg =
            FleetConfig { shards, threads, chaos: None, record_deliveries: true, trace: false };
        let par = run_collective(&t, p, allreduce_ring(p, n), WireDtype::F32, 1, &cfg);
        assert_eq!(par.delivered, serial.delivered, "shards={shards}");
        assert_eq!(par.completions, serial.completions, "shards={shards}");
        assert_eq!(par.finish_ns, serial.finish_ns, "shards={shards}");
        assert_eq!(par.final_clock, serial.final_clock, "shards={shards}");
    }
    // Pattern workload equality at p = 1024 (the scale path).
    let eq_spec = PatternSpec::ring_allreduce(1024, 64 << 10);
    let base = run_pattern(
        &t,
        &eq_spec,
        &FleetConfig { shards: 1, threads: 1, chaos: None, record_deliveries: false, trace: false },
    );
    let fleet = run_pattern(&t, &eq_spec, &FleetConfig::threaded(THREADS));
    assert_eq!(fleet.finish_ns, base.finish_ns, "p=1024 ring finish");
    assert_eq!(fleet.stats.msgs_sent, base.stats.msgs_sent);
    assert_eq!(fleet.stats.bytes_sent, base.stats.bytes_sent);
    println!("equivalence: serial == partitioned at p=256 (programs) and p=1024 (pattern)");

    // -- the measured ladder -------------------------------------------
    let mut cases = vec![
        Case {
            label: "ring allreduce (full)",
            spec: PatternSpec::ring_allreduce(1024, 64 << 10),
            serial_ms: 0.0,
            par_ms: 0.0,
        },
        Case {
            label: "ring allreduce (full)",
            spec: PatternSpec::ring_allreduce(4096, 64 << 10),
            serial_ms: 0.0,
            par_ms: 0.0,
        },
        Case {
            label: "recursive doubling (full)",
            spec: PatternSpec::rdoubling_allreduce(16384, 1 << 20),
            serial_ms: 0.0,
            par_ms: 0.0,
        },
        Case {
            label: "recursive doubling (full)",
            spec: PatternSpec::rdoubling_allreduce(65536, 1 << 20),
            serial_ms: 0.0,
            par_ms: 0.0,
        },
        Case {
            label: "ring window (128 rounds)",
            spec: PatternSpec {
                pattern: Pattern::Ring,
                p: 65536,
                msg_bytes: 64 << 10,
                rounds: 128,
                priority: 1,
            },
            serial_ms: 0.0,
            par_ms: 0.0,
        },
    ];
    let serial_cfg =
        FleetConfig { shards: 1, threads: 1, chaos: None, record_deliveries: false, trace: false };
    let par_cfg = FleetConfig::threaded(THREADS);
    for c in &mut cases {
        let (s_ms, s_out) = time_pattern(&c.spec, &serial_cfg);
        let (p_ms, p_out) = time_pattern(&c.spec, &par_cfg);
        assert_eq!(p_out.finish_ns, s_out.finish_ns, "{} p={}", c.label, c.spec.p);
        assert_eq!(p_out.stats.msgs_sent, s_out.stats.msgs_sent);
        c.serial_ms = s_ms;
        c.par_ms = p_ms;
    }

    let mut rows = Vec::new();
    for c in &cases {
        rows.push(vec![
            format!("{} p={}", c.label, c.spec.p),
            c.spec.total_msgs().to_string(),
            format!("{:.0}", c.serial_ms),
            format!("{:.0}", c.par_ms),
            format!("{:.2}x", c.serial_ms / c.par_ms.max(1e-9)),
        ]);
    }
    print_table(
        &format!("A11: serial vs {THREADS}-thread partitioned simulation, eth10g"),
        &["workload", "msgs", "serial ms", "partitioned ms", "speedup"],
        &rows,
    );

    // -- asserts on the ladder ------------------------------------------
    // p = 65,536 completes in wall-clock seconds, partitioned.
    for c in &cases {
        if c.spec.p == 65536 {
            assert!(
                c.par_ms < 60_000.0,
                "{} p=65536 took {:.0} ms partitioned — not 'seconds'",
                c.label,
                c.par_ms
            );
        }
    }
    // >= 2x at p = 4096 with 4 workers — only meaningful on a >= 4-core
    // host (CI runners qualify; a 2-core laptop prints SKIP).
    let big_ring = &cases[1];
    let speedup = big_ring.serial_ms / big_ring.par_ms.max(1e-9);
    if host_cores >= THREADS {
        assert!(
            speedup >= 2.0,
            "p=4096 ring: {THREADS}-thread speedup {speedup:.2}x < 2x \
             (serial {:.0} ms, partitioned {:.0} ms, {host_cores} cores)",
            big_ring.serial_ms,
            big_ring.par_ms
        );
    } else {
        println!("SKIP speedup assert: host has {host_cores} cores (< {THREADS})");
    }

    // Honest extrapolation for the full ring at p = 65,536: steady-state
    // ring throughput is round-invariant, so scale the 128-round window.
    let window = &cases[4];
    let full_rounds = 2 * (65536 - 1) as f64;
    let scale = full_rounds / window.spec.rounds as f64;
    println!(
        "\nfull p=65536 ring ({:.2e} msgs) extrapolates to ~{:.0} min serial, ~{:.0} min \
         at {THREADS} threads",
        full_rounds * 65536.0,
        window.serial_ms * scale / 60_000.0,
        window.par_ms * scale / 60_000.0,
    );

    // -- emit BENCH_parallel_sim.json at the repo root ------------------
    let mut json = String::from("{\n  \"bench\": \"a11_parallel_sim\",\n");
    json.push_str(&format!("  \"threads\": {THREADS},\n  \"host_cores\": {host_cores},\n"));
    json.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        let pat = match c.spec.pattern {
            Pattern::Ring => "ring",
            Pattern::RecursiveDoubling => "rdoubling",
        };
        json.push_str(&format!(
            "    {{\"workload\": \"{}\", \"pattern\": \"{pat}\", \"p\": {}, \
             \"rounds\": {}, \"msgs\": {}, \"serial_ms\": {:.1}, \
             \"partitioned_ms\": {:.1}, \"speedup\": {:.2}}}{}\n",
            c.label,
            c.spec.p,
            c.spec.rounds,
            c.spec.total_msgs(),
            c.serial_ms,
            c.par_ms,
            c.serial_ms / c.par_ms.max(1e-9),
            if i + 1 < cases.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_parallel_sim.json");
    std::fs::write(out, &json).expect("write BENCH_parallel_sim.json");
    println!("wrote {out}");

    println!("\nexpected shape: ring traffic is neighbor-local, so contiguous node shards");
    println!("keep almost every message shard-local and the speedup approaches the worker");
    println!("count; recursive doubling's late rounds all cross shards, so coordinator");
    println!("mail-routing caps its speedup — still ahead of serial at the p where it");
    println!("matters. Exactness is asserted before timing: the partitioned clock is an");
    println!("implementation detail, never a different answer. OK");
}

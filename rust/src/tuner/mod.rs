//! Measurement-driven collective selection — the autotuner.
//!
//! The analytic selector ([`crate::collectives::selector`]) predicts
//! algorithm crossovers from a closed-form two-tier alpha-beta model.
//! Das et al. (arXiv:1602.06709) and You et al. (arXiv:1708.02983) both
//! show those crossover points shift substantially with real fabric
//! latency/bandwidth ratios — measured tables beat closed forms once
//! topologies get real. We already own a cycle-accurate measuring
//! instrument (`simexec` over `NetSim`); this subsystem turns it into an
//! autotuner:
//!
//! * [`probe`] times every candidate algorithm for each tunable
//!   [`crate::collectives::CollectiveKind`] across a log-spaced
//!   (rank count × message size) grid by executing real chunk programs
//!   through the discrete-event fabric on the live topology — every
//!   cell on its own private fabric, so `--sim-threads n` stripes the
//!   grid across `n` workers ([`probe::tune_threaded`]) and still emits
//!   a byte-identical table (see `docs/ARCHITECTURE.md`);
//! * [`table`] persists the measurements as a [`TuningTable`] keyed by a
//!   topology *fingerprint*, with per-cell winners, crossover extraction
//!   and nearest-cell + log-interpolated lookup, serialized via
//!   [`crate::util::json`] (the `tune` CLI subcommand emits one, and
//!   `--tuning-table <path>` loads it back);
//! * [`policy`] exposes [`SelectionPolicy`] — `Analytic` (the default),
//!   `Tuned` and `TunedWithFallback` — threaded through the engine, the
//!   analytic design-space model and the CLI, so every algorithm choice
//!   goes through one switchable decision point.
//!
//! Every later topology feature calibrates against this bridge from
//! "model says" to "measurement says": the N-level tier stack (PR 4)
//! already does — the fingerprint hashes every tier's size and physics
//! (a two-tier table can never silently apply to a three-tier fabric),
//! the probe's rank grid covers tier-shaped rows, and multi-level
//! hierarchical candidates are measured like any other. Multi-rail NICs
//! ride the same path: the `v3` fingerprint hashes every level's rail
//! count (a table probed single-rail never silently applies to a
//! striped fabric — `TunedWithFallback` falls back to the analytic
//! model on mismatch), and the probe's size grid gains a rail dimension
//! (`ProbeSpec::size_grid_for` adds the whole-chunk stripe-transition
//! sizes where striping moves the measured crossovers).

pub mod policy;
pub mod probe;
pub mod table;

pub use policy::SelectionPolicy;
pub use probe::{tune, tune_threaded, ProbeSpec};
pub use table::{out_of_grid_count, TuningTable};

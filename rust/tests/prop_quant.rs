//! Compressed-collective invariants: encode/decode round-trips stay
//! within each wire dtype's declared error bound, error-feedback
//! accumulation drives the long-run quantization error of a repeated
//! allreduce below the one-shot error, and every (algorithm ×
//! wire-precision) pick a selection policy can emit is buildable
//! (randomized over p ∈ 2..33 across fabric presets).

use mlsl::collectives::program::{self, CollectiveKind};
use mlsl::collectives::quant::{
    decode, encode, max_roundtrip_error, EfState, WireDtype,
};
use mlsl::fabric::topology::Topology;
use mlsl::tuner::{probe, ProbeSpec, SelectionPolicy};
use mlsl::util::proptest::{run as prop_run, Config};

fn random_grad(r: &mut mlsl::util::prng::Prng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| r.range_f32(-scale, scale)).collect()
}

#[test]
fn prop_roundtrip_error_within_the_dtype_bound() {
    // decode(encode(x)) must stay within max_roundtrip_error(x) — the
    // same bound the trainer's quantization guard and the engine's
    // error-feedback bookkeeping are derived from — and the wire size
    // must match the dtype's advertised bytes-per-element exactly.
    prop_run(
        Config { cases: 200, seed: 0x9A17 },
        |r| {
            let n = 1 + r.usize_below(1500);
            let scale = 0.01 + 100.0 * r.f64() as f32;
            (random_grad(r, n, scale), r.usize_below(3))
        },
        |(x, wi)| {
            let wire = WireDtype::ALL[*wi];
            let bytes = encode(x, wire);
            if bytes.len() != wire.wire_bytes(x.len()) {
                return Err(format!(
                    "{wire}: wire size {} != advertised {}",
                    bytes.len(),
                    wire.wire_bytes(x.len())
                ));
            }
            let back = decode(&bytes, x.len(), wire);
            let bound = max_roundtrip_error(x, wire) * (1.0 + 1e-5) + f32::EPSILON;
            for (i, (a, b)) in x.iter().zip(&back).enumerate() {
                let err = (a - b).abs();
                if err > bound {
                    return Err(format!("{wire} elem {i}: |{a} - {b}| = {err} > {bound}"));
                }
            }
            if wire == WireDtype::F32 && x != &back {
                return Err("f32 round-trip must be exact".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_error_feedback_beats_one_shot_quantization() {
    // Repeatedly allreducing a FIXED gradient with error feedback: each
    // round sends quantize(g + residual) and banks what the format
    // dropped, so the sent values telescope — after K rounds the total
    // contributed error is just the final residual, and the per-round
    // error |r_K|/K falls well below the one-shot quantization error.
    // Meanwhile the residual itself stays bounded (≈ δ/(1−δ) scaled),
    // never drifting: 2× the one-shot error covers it.
    prop_run(
        Config { cases: 60, seed: 0xEF5D },
        |r| {
            let n = 1 + r.usize_below(1024);
            let scale = 0.05 + 20.0 * r.f64() as f32;
            (random_grad(r, n, scale), 1 + r.usize_below(2))
        },
        |(g, wi)| {
            let wire = WireDtype::ALL[*wi]; // Bf16 or Int8Block
            let delta = wire.rel_error() as f32;
            let absmax = g.iter().fold(0f32, |a, v| a.max(v.abs()));
            if absmax <= 0.0 {
                return Ok(()); // degenerate all-zero draw
            }
            // Dtype-level one-shot error bound; the measured one-shot
            // error must sit under it (sanity for the bound itself).
            let one_shot_bound = delta * absmax * (1.0 + 1e-5) + f32::EPSILON * absmax;
            if max_roundtrip_error(g, wire) > 2.0 * one_shot_bound {
                return Err(format!(
                    "{wire}: one-shot error {} escaped its δ·|g|∞ bound {one_shot_bound}",
                    max_roundtrip_error(g, wire)
                ));
            }
            const K: usize = 32;
            let mut ef = EfState::new(g.len());
            let mut worst_residual = 0f32;
            for _ in 0..K {
                let _wire_bytes = ef.encode_with_feedback(g, wire);
                worst_residual = worst_residual.max(ef.residual_linf());
            }
            // Bounded, K-independent residual: |r| ≤ δ(|g| + |r|) per
            // element (per block for int8) gives the δ/(1−δ) fixed
            // point; 4δ·|g|∞ covers it with rounding headroom.
            let cap = 4.0 * delta * absmax + 4.0 * f32::EPSILON * absmax;
            if worst_residual > cap {
                return Err(format!(
                    "{wire}: residual {worst_residual} escaped the {cap} bound"
                ));
            }
            // Telescoping: K sends contribute K·g − r_K, so the whole
            // run's error is one bounded residual — amortized per round
            // it falls K× below the one-shot error bound.
            let amortized = ef.residual_linf() / K as f32;
            if amortized >= one_shot_bound {
                return Err(format!(
                    "{wire}: amortized error {amortized} not below one-shot bound {one_shot_bound}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_wire_precision_picks_are_always_buildable() {
    // Whatever (algorithm, wire) pair a policy answers — analytic
    // crossover model or measured table, contiguous world or flat
    // strided communicator — the algorithm must build at the queried
    // rank count. The wire dimension must never smuggle in a candidate
    // the legality filters would have rejected.
    let setups: Vec<(Topology, Vec<SelectionPolicy>)> = [
        Topology::eth_10g(),
        Topology::eth_10g_smp(2),
        Topology::omnipath_100g_smp(4),
    ]
    .into_iter()
    .map(|t| {
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 16;
        let table = probe::tune(&t, &spec);
        let policies = vec![
            SelectionPolicy::Analytic,
            SelectionPolicy::Tuned(table.clone()),
            SelectionPolicy::TunedWithFallback(table),
        ];
        (t, policies)
    })
    .collect();
    prop_run(
        Config { cases: 200, seed: 0x5E1E },
        |r| {
            (
                r.usize_below(3),
                2 + r.usize_below(31), // p in 2..33
                1 + r.usize_below(1 << 22),
                r.usize_below(3), // menu: full / int8-only / bf16-only
            )
        },
        |&(ti, p, n, mi)| {
            let (topo, policies) = &setups[ti];
            let bytes = (4 * n) as u64;
            let menus: [&[WireDtype]; 3] = [
                &WireDtype::ALL,
                &[WireDtype::Int8Block],
                &[WireDtype::Bf16],
            ];
            let menu = menus[mi];
            let members: Vec<usize> = (0..p).collect();
            for policy in policies {
                let (alg, wire) = policy.choose_allreduce_wire(topo, p, bytes, menu, 1000);
                if !menu.contains(&wire) {
                    return Err(format!("[{}] wire {wire} not on the menu", policy.name()));
                }
                program::build(CollectiveKind::Allreduce, alg, p, n)
                    .map_err(|e| format!("[{}] {alg}@{wire} p={p}: {e}", policy.name()))?;
                let (flat, fwire) =
                    policy.choose_flat_allreduce_wire(topo, p, bytes, menu, 1000);
                if !menu.contains(&fwire) {
                    return Err(format!("[{}] flat wire {fwire} off-menu", policy.name()));
                }
                program::build(CollectiveKind::Allreduce, flat, p, n)
                    .map_err(|e| format!("[{}] flat {flat}@{fwire} p={p}: {e}", policy.name()))?;
                let (malg, mwire) = policy.choose_for_members_wire(
                    topo,
                    &members,
                    CollectiveKind::Allreduce,
                    bytes,
                    menu,
                    1000,
                );
                if !menu.contains(&mwire) {
                    return Err(format!("[{}] member wire {mwire} off-menu", policy.name()));
                }
                program::build(CollectiveKind::Allreduce, malg, p, n)
                    .map_err(|e| format!("[{}] members {malg}@{mwire} p={p}: {e}", policy.name()))?;
                // The wire-aware predictor must answer something finite
                // for every pick it can make.
                let t = policy.predict_allreduce_ns_wire(topo, p, bytes, menu, 1000);
                if t == 0 || t >= u64::MAX / 8 {
                    return Err(format!("[{}] absurd prediction {t}", policy.name()));
                }
            }
            Ok(())
        },
    );
}

//! Collective algorithms compiled to per-rank chunk programs.
//!
//! A [`Program`] is executed strictly in step order by a rank; messages
//! between a (src, dst) pair within one collective are FIFO, so matching
//! needs only the collective id. Send data is read from the buffer at the
//! moment the step executes — algorithms below are constructed so that at
//! that moment the range already carries every contribution it must.

use crate::Rank;

/// Contiguous element range (not bytes — the executor scales by dtype).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Range {
    pub off: usize,
    pub len: usize,
}

impl Range {
    pub fn new(off: usize, len: usize) -> Self {
        Self { off, len }
    }
    pub fn end(&self) -> usize {
        self.off + self.len
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SendStep {
    pub to: Rank,
    pub range: Range,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecvStep {
    pub from: Rank,
    pub range: Range,
    /// true → reduce into the buffer; false → overwrite.
    pub reduce: bool,
}

/// One program step: the send and recv (if both present) are logically
/// concurrent; the step completes when both have.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Step {
    pub send: Option<SendStep>,
    pub recv: Option<RecvStep>,
}

/// Per-rank program for one collective instance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Program {
    pub rank: Rank,
    pub steps: Vec<Step>,
}

/// What the collective computes (drives program generation + verification).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    Allreduce,
    ReduceScatter,
    Allgather,
    Broadcast { root: Rank },
    Reduce { root: Rank },
    Barrier,
}

/// Split `n` elements into `p` balanced segments; returns offsets of len
/// p+1 (segment i = [seg[i], seg[i+1])). Exact for any n, p.
pub fn segments(n: usize, p: usize) -> Vec<usize> {
    (0..=p).map(|i| i * n / p).collect()
}

fn seg_range(seg: &[usize], i: usize) -> Range {
    Range::new(seg[i], seg[i + 1] - seg[i])
}

/// Range covering segments [lo, hi).
fn seg_span(seg: &[usize], lo: usize, hi: usize) -> Range {
    Range::new(seg[lo], seg[hi] - seg[lo])
}

// ---------------------------------------------------------------------------
// Ring algorithms
// ---------------------------------------------------------------------------

/// Ring reduce-scatter: after p−1 steps rank r owns the fully-reduced
/// segment (r+1) mod p.
pub fn reduce_scatter_ring(p: usize, n: usize) -> Vec<Program> {
    assert!(p >= 1);
    let seg = segments(n, p);
    (0..p)
        .map(|r| {
            let steps = (0..p.saturating_sub(1))
                .map(|s| Step {
                    send: Some(SendStep {
                        to: (r + 1) % p,
                        range: seg_range(&seg, (r + p - s) % p),
                    }),
                    recv: Some(RecvStep {
                        from: (r + p - 1) % p,
                        range: seg_range(&seg, (r + p - 1 - s) % p),
                        reduce: true,
                    }),
                })
                .collect();
            Program { rank: r, steps }
        })
        .collect()
}

/// Ring allgather: rank r starts owning segment `own(r)` and ends with all.
/// `owner_shift` selects which segment each rank starts with (the ring
/// allreduce composition needs shift=1: rank r owns seg (r+1) mod p).
pub fn allgather_ring_shifted(p: usize, n: usize, owner_shift: usize) -> Vec<Program> {
    assert!(p >= 1);
    let seg = segments(n, p);
    (0..p)
        .map(|r| {
            let steps = (0..p.saturating_sub(1))
                .map(|s| Step {
                    send: Some(SendStep {
                        to: (r + 1) % p,
                        range: seg_range(&seg, (r + owner_shift + p - s) % p),
                    }),
                    recv: Some(RecvStep {
                        from: (r + p - 1) % p,
                        range: seg_range(&seg, (r + owner_shift + p - 1 - s) % p),
                        reduce: false,
                    }),
                })
                .collect();
            Program { rank: r, steps }
        })
        .collect()
}

/// Ring allgather with the natural ownership (rank r owns segment r).
pub fn allgather_ring(p: usize, n: usize) -> Vec<Program> {
    allgather_ring_shifted(p, n, 0)
}

/// Recursive-doubling allgather: rank r starts owning segment r; the
/// round at partner distance d exchanges the currently-held d-segment
/// block, doubling it. Same total volume as the ring (n·(p−1)/p elements
/// per rank) in only log₂ p rounds. P must be a power of two.
pub fn allgather_rdoubling(p: usize, n: usize) -> Vec<Program> {
    assert_pow2(p);
    let seg = segments(n, p);
    (0..p)
        .map(|r| {
            let mut steps = Vec::new();
            let mut d = 1;
            while d < p {
                let partner = r ^ d;
                // Entering this round, a rank holds the aligned d-segment
                // block containing its own segment; the partner holds the
                // sibling block.
                let lo = (r / d) * d;
                let plo = (partner / d) * d;
                steps.push(Step {
                    send: Some(SendStep { to: partner, range: seg_span(&seg, lo, lo + d) }),
                    recv: Some(RecvStep {
                        from: partner,
                        range: seg_span(&seg, plo, plo + d),
                        reduce: false,
                    }),
                });
                d <<= 1;
            }
            Program { rank: r, steps }
        })
        .collect()
}

/// Ring allreduce = ring reduce-scatter ∘ ring allgather. Bandwidth cost
/// 2·(p−1)/p · n elements per rank: optimal.
pub fn allreduce_ring(p: usize, n: usize) -> Vec<Program> {
    let rs = reduce_scatter_ring(p, n);
    let ag = allgather_ring_shifted(p, n, 1);
    rs.into_iter()
        .zip(ag)
        .map(|(mut a, b)| {
            a.steps.extend(b.steps);
            a
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Recursive doubling / halving-doubling (power-of-two rank counts)
// ---------------------------------------------------------------------------

fn assert_pow2(p: usize) {
    assert!(p.is_power_of_two(), "algorithm requires power-of-two ranks, got {p}");
}

/// Recursive-doubling allreduce: log₂p exchanges of the FULL buffer.
/// Latency-optimal (fewest rounds) — the small-message choice.
pub fn allreduce_rdoubling(p: usize, n: usize) -> Vec<Program> {
    assert_pow2(p);
    let full = Range::new(0, n);
    (0..p)
        .map(|r| {
            let mut steps = Vec::new();
            let mut d = 1;
            while d < p {
                let partner = r ^ d;
                steps.push(Step {
                    send: Some(SendStep { to: partner, range: full }),
                    recv: Some(RecvStep { from: partner, range: full, reduce: true }),
                });
                d <<= 1;
            }
            Program { rank: r, steps }
        })
        .collect()
}

/// Rabenseifner allreduce: reduce-scatter by recursive *halving* then
/// allgather by recursive *doubling*. Bandwidth-optimal with only
/// 2·log₂p rounds.
pub fn allreduce_halving_doubling(p: usize, n: usize) -> Vec<Program> {
    assert_pow2(p);
    let seg = segments(n, p);
    (0..p)
        .map(|r| {
            let mut steps = Vec::new();
            // Reduce-scatter phase: block = segment window [lo, hi).
            let (mut lo, mut hi) = (0usize, p);
            let mut d = p / 2;
            while d >= 1 {
                let partner = r ^ d;
                let mid = (lo + hi) / 2;
                let (keep, give) = if r & d == 0 {
                    ((lo, mid), (mid, hi))
                } else {
                    ((mid, hi), (lo, mid))
                };
                steps.push(Step {
                    send: Some(SendStep { to: partner, range: seg_span(&seg, give.0, give.1) }),
                    recv: Some(RecvStep {
                        from: partner,
                        range: seg_span(&seg, keep.0, keep.1),
                        reduce: true,
                    }),
                });
                lo = keep.0;
                hi = keep.1;
                d >>= 1;
            }
            // Allgather phase: mirror, doubling the block back up.
            let mut d = 1;
            while d < p {
                let partner = r ^ d;
                // Partner's block is the sibling of ours at this level.
                let width = hi - lo;
                let (plo, phi) = if (lo / width) % 2 == 0 {
                    (hi, hi + width)
                } else {
                    (lo - width, lo)
                };
                steps.push(Step {
                    send: Some(SendStep { to: partner, range: seg_span(&seg, lo, hi) }),
                    recv: Some(RecvStep {
                        from: partner,
                        range: seg_span(&seg, plo, phi),
                        reduce: false,
                    }),
                });
                lo = lo.min(plo);
                hi = hi.max(phi);
                d <<= 1;
            }
            Program { rank: r, steps }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Binomial trees
// ---------------------------------------------------------------------------

/// Binomial-tree broadcast of the full buffer from `root`.
pub fn broadcast_binomial(p: usize, n: usize, root: Rank) -> Vec<Program> {
    let full = Range::new(0, n);
    (0..p)
        .map(|r| {
            let relative = (r + p - root) % p;
            let mut steps = Vec::new();
            // Receive once, from relative's lowest set bit "parent".
            let mut mask = 1;
            while mask < p {
                if relative & mask != 0 {
                    let src = (r + p - mask) % p;
                    steps.push(Step {
                        send: None,
                        recv: Some(RecvStep { from: src, range: full, reduce: false }),
                    });
                    break;
                }
                mask <<= 1;
            }
            // Then fan out to children at descending masks.
            let mut m = mask >> 1;
            while m > 0 {
                if relative + m < p {
                    let dst = (r + m) % p;
                    steps.push(Step {
                        send: Some(SendStep { to: dst, range: full }),
                        recv: None,
                    });
                }
                m >>= 1;
            }
            Program { rank: r, steps }
        })
        .collect()
}

/// Binomial-tree reduce of the full buffer to `root`.
pub fn reduce_binomial(p: usize, n: usize, root: Rank) -> Vec<Program> {
    let full = Range::new(0, n);
    (0..p)
        .map(|r| {
            let relative = (r + p - root) % p;
            let mut steps = Vec::new();
            let mut mask = 1;
            // Mirror of broadcast: receive from children (ascending masks)
            // while our bit is clear, then send once to the parent.
            while mask < p {
                if relative & mask == 0 {
                    if relative + mask < p {
                        let src = (r + mask) % p;
                        steps.push(Step {
                            send: None,
                            recv: Some(RecvStep { from: src, range: full, reduce: true }),
                        });
                    }
                } else {
                    let dst = (r + p - mask) % p;
                    steps.push(Step {
                        send: Some(SendStep { to: dst, range: full }),
                        recv: None,
                    });
                    break;
                }
                mask <<= 1;
            }
            Program { rank: r, steps }
        })
        .collect()
}

/// Barrier: recursive-doubling exchange of a 1-element token.
pub fn barrier(p: usize) -> Vec<Program> {
    if p.is_power_of_two() {
        allreduce_rdoubling(p, 1)
    } else {
        allreduce_ring(p, p.max(1))
    }
}

// ---------------------------------------------------------------------------
// Hierarchical (N-level) composition
// ---------------------------------------------------------------------------

/// Re-label program ranks through `map` (program rank i runs as rank
/// `map[i]`); send/recv peers are rewritten accordingly. Used to lift
/// group-local and leader-only phase programs into the global rank space.
fn remap_ranks(progs: Vec<Program>, map: &[Rank]) -> Vec<Program> {
    progs
        .into_iter()
        .map(|mut prog| {
            prog.rank = map[prog.rank];
            for step in &mut prog.steps {
                if let Some(s) = &mut step.send {
                    s.to = map[s.to];
                }
                if let Some(r) = &mut step.recv {
                    r.from = map[r.from];
                }
            }
            prog
        })
        .collect()
}

/// Lift one group-local program into global rank space: local rank `l`
/// of group `block` runs as global rank `block * g + l`.
fn lift_block(prog: Program, block: usize, g: usize) -> Program {
    let map: Vec<Rank> = (0..g).map(|l| block * g + l).collect();
    remap_ranks(vec![prog], &map).pop().expect("one program in, one out")
}

/// Assert the preconditions shared by every recursive hierarchical
/// builder: nested group sizes (innermost first), each >= 1, dividing the
/// next, the outermost dividing `p`.
fn assert_groups(p: usize, groups: &[usize]) {
    assert!(p >= 1);
    let mut prev = 1usize;
    for &g in groups {
        assert!(g >= 1 && g % prev == 0, "group sizes must nest: {groups:?}");
        prev = g;
    }
    assert_eq!(p % prev, 0, "outermost group must divide p: {groups:?} vs {p}");
}

/// `rest` rescaled into the leader index space after peeling a group of
/// `g` (leader i of the peeled level ↔ global rank i·g).
fn scale_groups(rest: &[usize], g: usize) -> Vec<usize> {
    rest.iter().map(|s| s / g).collect()
}

/// N-level hierarchical allreduce over nested `groups` (innermost first;
/// see [`assert_groups`] for the preconditions), recursing over the tier
/// stack:
///
/// 1. binomial reduce of the full buffer onto each innermost group's
///    leader (the group's first rank),
/// 2. recurse over the leaders with the remaining (rescaled) groups —
///    bottoming out in a flat `inner` allreduce (ring / halving-doubling
///    / recursive doubling) among the outermost leaders,
/// 3. binomial broadcast from the leader back through the group.
///
/// The phases need no barrier between them: every phase-k step of a rank
/// is ordered after its phase-(k−1) steps, and cross-phase messages
/// between the same (src, dst) pair stay FIFO, which is all the matching
/// layer requires. With `groups == &[]` (or all-1s) this is byte-
/// identical to the flat `inner` algorithm; with one group it is the
/// classic two-tier [`allreduce_hierarchical`]. An `inner` of recursive
/// doubling / halving-doubling needs a power-of-two outermost leader
/// count ([`build`] picks a valid inner via [`hierarchical_inner`]).
pub fn allreduce_hierarchical_levels(
    p: usize,
    n: usize,
    groups: &[usize],
    inner: super::Algorithm,
) -> Vec<Program> {
    assert_groups(p, groups);
    let Some((&g, rest)) = groups.split_first() else {
        return match inner {
            super::Algorithm::RecursiveDoubling => allreduce_rdoubling(p, n),
            super::Algorithm::HalvingDoubling => allreduce_halving_doubling(p, n),
            _ => allreduce_ring(p, n),
        };
    };
    let blocks = p / g;
    // Phase programs in group-local rank space (leader = local rank 0).
    let reduce = reduce_binomial(g, n, 0);
    let bcast = broadcast_binomial(g, n, 0);
    // The levels above, among this level's leaders (leader b ↔ rank b·g).
    let sub = allreduce_hierarchical_levels(blocks, n, &scale_groups(rest, g), inner);
    let leader_map: Vec<Rank> = (0..blocks).map(|b| b * g).collect();
    (0..p)
        .map(|r| {
            let block = r / g;
            let local = r % g;
            let mut steps = lift_block(reduce[local].clone(), block, g).steps;
            if local == 0 {
                steps.extend(
                    remap_ranks(vec![sub[block].clone()], &leader_map)
                        .pop()
                        .expect("one program in, one out")
                        .steps,
                );
            }
            steps.extend(lift_block(bcast[local].clone(), block, g).steps);
            Program { rank: r, steps }
        })
        .collect()
}

/// Two-level hierarchical allreduce for fabrics with `ranks_per_node`
/// co-located ranks per node (contiguous grouping, leader = first rank of
/// each node) — the single-group case of
/// [`allreduce_hierarchical_levels`], kept as the named two-tier entry
/// point.
pub fn allreduce_hierarchical(
    p: usize,
    n: usize,
    ranks_per_node: usize,
    inner: super::Algorithm,
) -> Vec<Program> {
    allreduce_hierarchical_levels(p, n, &[ranks_per_node], inner)
}

/// Inner (top-phase) allreduce [`build`] emits for hierarchical
/// composition at a given outermost-leader count: the bandwidth-optimal
/// flat choice legal there. The selector's cost model prices hierarchical
/// with this SAME rule — change them together, via this one function.
pub fn hierarchical_inner(nodes: usize) -> super::Algorithm {
    if nodes.is_power_of_two() {
        super::Algorithm::HalvingDoubling
    } else {
        super::Algorithm::Ring
    }
}

/// Top-phase allgather [`build`] emits for hierarchical allgather:
/// block-doubling when the leader count admits it, ring otherwise. Same
/// change-together contract as [`hierarchical_inner`].
pub fn hierarchical_ag_inner(nodes: usize) -> super::Algorithm {
    if nodes.is_power_of_two() {
        super::Algorithm::RecursiveDoubling
    } else {
        super::Algorithm::Ring
    }
}

/// Ring reduce-scatter with NATURAL ownership: rank r ends owning the
/// fully-reduced segment r. The ring algorithm inherently finishes with
/// program i owning segment (i+1) mod p; because the ring is
/// rotation-symmetric and every rank starts with the same "own data
/// everywhere" shape, relabeling program i onto rank (i+1) mod p yields
/// natural ownership with identical steps and volume.
pub fn reduce_scatter_natural(p: usize, n: usize) -> Vec<Program> {
    let map: Vec<Rank> = (0..p).map(|i| (i + 1) % p).collect();
    let mut progs = remap_ranks(reduce_scatter_ring(p, n), &map);
    progs.sort_by_key(|pr| pr.rank);
    progs
}

/// N-level hierarchical reduce-scatter over nested `groups` (innermost
/// first). Semantics: NATURAL ownership — rank r ends owning the
/// fully-reduced segment r of [`segments`]`(n, p)` (unlike the flat
/// [`reduce_scatter_ring`], whose ring pipeline leaves rank r with
/// segment (r+1) mod p; a ring-shifted layout cannot nest across tiers,
/// so the hierarchical family standardizes on natural ownership).
///
/// Recursion: binomial reduce of the full buffer onto each innermost
/// group's leader; reduce-scatter among the leaders (each leader ends
/// with its group's whole segment span — segment boundaries at every
/// level nest exactly because [`segments`] cuts at i·n/p); then each
/// leader scatters the per-rank segments to its group members.
pub fn reduce_scatter_hierarchical(p: usize, n: usize, groups: &[usize]) -> Vec<Program> {
    assert_groups(p, groups);
    let Some((&g, rest)) = groups.split_first() else {
        return reduce_scatter_natural(p, n);
    };
    let blocks = p / g;
    let seg = segments(n, p);
    let reduce = reduce_binomial(g, n, 0);
    let sub = reduce_scatter_hierarchical(blocks, n, &scale_groups(rest, g));
    let leader_map: Vec<Rank> = (0..blocks).map(|b| b * g).collect();
    (0..p)
        .map(|r| {
            let block = r / g;
            let local = r % g;
            let mut steps = lift_block(reduce[local].clone(), block, g).steps;
            if local == 0 {
                steps.extend(
                    remap_ranks(vec![sub[block].clone()], &leader_map)
                        .pop()
                        .expect("one program in, one out")
                        .steps,
                );
                // Scatter: member l's final segment is block·g + l.
                for l in 1..g {
                    steps.push(Step {
                        send: Some(SendStep {
                            to: block * g + l,
                            range: seg_range(&seg, block * g + l),
                        }),
                        recv: None,
                    });
                }
            } else {
                // The received segment is fully reduced (it already
                // carries this rank's own contribution): overwrite.
                steps.push(Step {
                    send: None,
                    recv: Some(RecvStep {
                        from: block * g,
                        range: seg_range(&seg, r),
                        reduce: false,
                    }),
                });
            }
            Program { rank: r, steps }
        })
        .collect()
}

/// N-level hierarchical allgather over nested `groups` (innermost
/// first). Input/output match the flat builders: rank r starts owning
/// segment r (natural ownership) and ends with the whole buffer.
///
/// Recursion: each member sends its segment to the group leader (the
/// leader then owns the group's whole segment span — boundaries nest);
/// the leaders allgather among themselves; each leader broadcasts the
/// full buffer back through its group (a member's own segment is
/// overwritten with the identical data — the full-buffer tree is cheaper
/// in steps than per-segment scatters on the fast tiers).
pub fn allgather_hierarchical(p: usize, n: usize, groups: &[usize]) -> Vec<Program> {
    assert_groups(p, groups);
    let Some((&g, rest)) = groups.split_first() else {
        return match hierarchical_ag_inner(p) {
            super::Algorithm::RecursiveDoubling => allgather_rdoubling(p, n),
            _ => allgather_ring(p, n),
        };
    };
    let blocks = p / g;
    let seg = segments(n, p);
    let bcast = broadcast_binomial(g, n, 0);
    let sub = allgather_hierarchical(blocks, n, &scale_groups(rest, g));
    let leader_map: Vec<Rank> = (0..blocks).map(|b| b * g).collect();
    (0..p)
        .map(|r| {
            let block = r / g;
            let local = r % g;
            let mut steps = Vec::new();
            if local == 0 {
                // Gather the members' segments (FIFO per pair; one
                // message per member).
                for l in 1..g {
                    steps.push(Step {
                        send: None,
                        recv: Some(RecvStep {
                            from: block * g + l,
                            range: seg_range(&seg, block * g + l),
                            reduce: false,
                        }),
                    });
                }
                steps.extend(
                    remap_ranks(vec![sub[block].clone()], &leader_map)
                        .pop()
                        .expect("one program in, one out")
                        .steps,
                );
            } else {
                steps.push(Step {
                    send: Some(SendStep { to: block * g, range: seg_range(&seg, r) }),
                    recv: None,
                });
            }
            steps.extend(lift_block(bcast[local].clone(), block, g).steps);
            Program { rank: r, steps }
        })
        .collect()
}

/// N-level hierarchical broadcast from ANY root via leader relay. At
/// each level, if the (sub-)root is not its group's leader it first
/// relays the full buffer to that leader (one extra hop on that level's
/// links); the leaders then broadcast among themselves rooted at the
/// root's leader, and finally every leader runs a binomial broadcast
/// through its own group. A non-leader root receives one redundant copy
/// of data it already holds (harmless overwrite) — the price of keeping
/// every phase a plain binomial tree. Total volume: n·(p−1) plus n per
/// level at which the (sub-)root is not a leader.
pub fn broadcast_hierarchical(p: usize, n: usize, root: Rank, groups: &[usize]) -> Vec<Program> {
    assert_groups(p, groups);
    assert!(root < p, "root {root} out of range for p={p}");
    let Some((&g, rest)) = groups.split_first() else {
        return broadcast_binomial(p, n, root);
    };
    let blocks = p / g;
    let full = Range::new(0, n);
    let root_block = root / g;
    let root_local = root % g;
    let bcast = broadcast_binomial(g, n, 0);
    let sub = broadcast_hierarchical(blocks, n, root_block, &scale_groups(rest, g));
    let leader_map: Vec<Rank> = (0..blocks).map(|b| b * g).collect();
    (0..p)
        .map(|r| {
            let block = r / g;
            let local = r % g;
            let mut steps = Vec::new();
            // Leader relay: the root hands the buffer to its group's
            // leader so the leader phase can start from a leader.
            if root_local != 0 && block == root_block {
                if r == root {
                    steps.push(Step {
                        send: Some(SendStep { to: root_block * g, range: full }),
                        recv: None,
                    });
                } else if local == 0 {
                    steps.push(Step {
                        send: None,
                        recv: Some(RecvStep { from: root, range: full, reduce: false }),
                    });
                }
            }
            if local == 0 {
                steps.extend(
                    remap_ranks(vec![sub[block].clone()], &leader_map)
                        .pop()
                        .expect("one program in, one out")
                        .steps,
                );
            }
            steps.extend(lift_block(bcast[local].clone(), block, g).steps);
            Program { rank: r, steps }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Validated entry point
// ---------------------------------------------------------------------------

/// Why a (kind, algorithm, p) request cannot be compiled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BuildError {
    /// Zero ranks: there is no communicator to build for.
    NoRanks,
    /// Recursive doubling / halving-doubling require a power-of-two rank
    /// count.
    NonPowerOfTwoRanks { alg: super::Algorithm, p: usize },
    /// Hierarchical requires the outermost group size to divide `p`
    /// (nesting divisibility inside the stack is enforced by
    /// [`super::GroupStack`] at construction).
    InvalidTierGrouping { p: usize, groups: super::GroupStack },
    /// `Algorithm::Auto` must be resolved by the selector before building.
    UnresolvedAuto,
}

impl std::fmt::Display for BuildError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BuildError::NoRanks => write!(f, "cannot build a collective over 0 ranks"),
            BuildError::NonPowerOfTwoRanks { alg, p } => {
                write!(f, "{alg} requires a power-of-two rank count, got {p}")
            }
            BuildError::InvalidTierGrouping { p, groups } => write!(
                f,
                "hierarchical needs its outermost group dividing p: got p={p}, \
                 groups={groups}"
            ),
            BuildError::UnresolvedAuto => {
                write!(f, "Algorithm::Auto must be resolved via the selector before build")
            }
        }
    }
}

impl std::error::Error for BuildError {}

/// Build programs for (kind, algorithm). Returns a structured
/// [`BuildError`] when the algorithm's rank-count precondition is violated
/// (the selector never produces such combinations, but callers composing
/// algorithms by hand get a diagnosable error instead of a panic).
///
/// Note one semantic wrinkle: flat reduce-scatter (`Ring` et al.) leaves
/// rank r owning segment (r+1) mod p (the ring pipeline's layout), while
/// `Hierarchical` reduce-scatter produces NATURAL ownership (rank r owns
/// segment r) — a ring-shifted layout cannot nest across tiers. See
/// [`reduce_scatter_hierarchical`].
pub fn build(
    kind: CollectiveKind,
    alg: super::Algorithm,
    p: usize,
    n: usize,
) -> Result<Vec<Program>, BuildError> {
    use super::Algorithm as A;
    use CollectiveKind as K;
    if p == 0 {
        return Err(BuildError::NoRanks);
    }
    // Hierarchical preconditions are kind-independent wherever a
    // hierarchical builder exists.
    if let A::Hierarchical { groups } = alg {
        if matches!(
            kind,
            K::Allreduce | K::ReduceScatter | K::Allgather | K::Broadcast { .. }
        ) && p % groups.outermost() != 0
        {
            return Err(BuildError::InvalidTierGrouping { p, groups });
        }
    }
    if kind == K::Allreduce {
        match alg {
            A::RecursiveDoubling | A::HalvingDoubling if !p.is_power_of_two() => {
                return Err(BuildError::NonPowerOfTwoRanks { alg, p });
            }
            A::Auto => return Err(BuildError::UnresolvedAuto),
            _ => {}
        }
    }
    if kind == K::Allgather && alg == A::RecursiveDoubling && !p.is_power_of_two() {
        return Err(BuildError::NonPowerOfTwoRanks { alg, p });
    }
    Ok(match (kind, alg) {
        (K::Allreduce, A::Ring) => allreduce_ring(p, n),
        (K::Allreduce, A::RecursiveDoubling) => allreduce_rdoubling(p, n),
        (K::Allreduce, A::HalvingDoubling) => allreduce_halving_doubling(p, n),
        (K::Allreduce, A::Hierarchical { groups }) => {
            let inner = hierarchical_inner(p / groups.outermost());
            allreduce_hierarchical_levels(p, n, &groups.to_vec(), inner)
        }
        (K::ReduceScatter, A::Hierarchical { groups }) => {
            reduce_scatter_hierarchical(p, n, &groups.to_vec())
        }
        (K::ReduceScatter, _) => reduce_scatter_ring(p, n),
        (K::Allgather, A::Hierarchical { groups }) => {
            allgather_hierarchical(p, n, &groups.to_vec())
        }
        (K::Allgather, A::RecursiveDoubling) => allgather_rdoubling(p, n),
        (K::Allgather, _) => allgather_ring(p, n),
        (K::Broadcast { root }, A::Hierarchical { groups }) => {
            broadcast_hierarchical(p, n, root, &groups.to_vec())
        }
        (K::Broadcast { root }, _) => broadcast_binomial(p, n, root),
        (K::Reduce { root }, _) => reduce_binomial(p, n, root),
        (K::Barrier, _) => barrier(p),
        (K::Allreduce, A::Auto) => unreachable!("rejected above"),
    })
}

/// Surviving-member view of a communicator after elastic churn: drops
/// inactive ranks while KEEPING the original fabric rank ids and their
/// relative order. Program ranks of a rebuilt collective are simply
/// positions in this list — nobody's payload identity is renumbered,
/// which is what lets survivors keep their data across a membership
/// change.
pub fn survivors(
    members: Vec<crate::Rank>,
    alive: impl Fn(crate::Rank) -> bool,
) -> Vec<crate::Rank> {
    members.into_iter().filter(|r| alive(*r)).collect()
}

/// Rebuild a collective for the post-churn survivor set: filters
/// `members` through `alive`, compiles `alg` at the shrunken rank count
/// and returns the programs together with the fabric rank map to post
/// them with (program rank i runs on fabric node `map[i]` — see
/// `SimCollectives::post_mapped`).
pub fn rebuild_for_survivors(
    kind: CollectiveKind,
    alg: super::Algorithm,
    members: &[crate::Rank],
    alive: impl Fn(crate::Rank) -> bool,
    n: usize,
) -> Result<(Vec<Program>, Vec<crate::Rank>), BuildError> {
    let map = survivors(members.to_vec(), alive);
    let programs = build(kind, alg, map.len(), n)?;
    Ok((programs, map))
}

/// Total bytes a single rank puts on the wire for this program.
pub fn rank_send_bytes(prog: &Program, elem_bytes: usize) -> u64 {
    prog.steps
        .iter()
        .filter_map(|s| s.send.as_ref())
        .map(|s| (s.range.len * elem_bytes) as u64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segments_are_exact_partition() {
        for n in [0usize, 1, 7, 64, 1000] {
            for p in [1usize, 2, 3, 5, 8] {
                let seg = segments(n, p);
                assert_eq!(seg[0], 0);
                assert_eq!(*seg.last().unwrap(), n);
                assert!(seg.windows(2).all(|w| w[0] <= w[1]));
            }
        }
    }

    #[test]
    fn ring_allreduce_step_count() {
        let p = 5;
        for prog in allreduce_ring(p, 100) {
            assert_eq!(prog.steps.len(), 2 * (p - 1));
        }
    }

    #[test]
    fn rdoubling_step_count() {
        for prog in allreduce_rdoubling(8, 64) {
            assert_eq!(prog.steps.len(), 3);
        }
    }

    #[test]
    fn halving_doubling_bandwidth_is_optimal() {
        // Per-rank wire bytes must be 2(p-1)/p * n elements (+0): same as ring.
        let (p, n) = (8, 1024);
        for prog in allreduce_halving_doubling(p, n) {
            let sent: usize = prog
                .steps
                .iter()
                .filter_map(|s| s.send.map(|x| x.range.len))
                .sum();
            assert_eq!(sent, 2 * (p - 1) * n / p);
        }
    }

    #[test]
    fn broadcast_root_never_receives() {
        for root in 0..6 {
            let progs = broadcast_binomial(6, 10, root);
            assert!(progs[root].steps.iter().all(|s| s.recv.is_none()));
            // Every non-root receives exactly once.
            for (r, prog) in progs.iter().enumerate() {
                if r != root {
                    assert_eq!(
                        prog.steps.iter().filter(|s| s.recv.is_some()).count(),
                        1,
                        "rank {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn reduce_root_never_sends() {
        for root in 0..6 {
            let progs = reduce_binomial(6, 10, root);
            assert!(progs[root].steps.iter().all(|s| s.send.is_none()));
            for (r, prog) in progs.iter().enumerate() {
                if r != root {
                    assert_eq!(
                        prog.steps.iter().filter(|s| s.send.is_some()).count(),
                        1,
                        "rank {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn single_rank_programs_are_empty() {
        assert!(allreduce_ring(1, 10)[0].steps.is_empty());
        assert!(broadcast_binomial(1, 10, 0)[0].steps.is_empty());
    }

    #[test]
    fn hierarchical_non_leaders_stay_off_the_inter_tier() {
        use crate::collectives::Algorithm as A;
        let (p, rpn, n) = (8, 2, 64);
        let progs = allreduce_hierarchical(p, n, rpn, A::Ring);
        for (r, prog) in progs.iter().enumerate() {
            assert_eq!(prog.rank, r);
            let node = r / rpn;
            let local = r % rpn;
            for step in &prog.steps {
                for peer in step
                    .send
                    .iter()
                    .map(|s| s.to)
                    .chain(step.recv.iter().map(|v| v.from))
                {
                    if local != 0 {
                        // Non-leaders only ever talk within their node.
                        assert_eq!(peer / rpn, node, "rank {r} peer {peer}");
                    }
                }
            }
            if local != 0 {
                // One send (reduce up) + one recv (broadcast down).
                assert_eq!(prog.steps.len(), 2, "rank {r}");
            }
        }
    }

    #[test]
    fn hierarchical_degenerates_to_inner_or_intra_only() {
        use crate::collectives::Algorithm as A;
        // ranks_per_node = 1: exactly the inner algorithm.
        let flat = allreduce_hierarchical(6, 30, 1, A::Ring);
        let ring = allreduce_ring(6, 30);
        for (a, b) in flat.iter().zip(&ring) {
            assert_eq!(a.steps, b.steps);
        }
        // ranks_per_node = p: one node, reduce + broadcast only.
        let single = allreduce_hierarchical(4, 30, 4, A::Ring);
        let reduce_steps: usize =
            reduce_binomial(4, 30, 0).iter().map(|pr| pr.steps.len()).sum();
        let bcast_steps: usize =
            broadcast_binomial(4, 30, 0).iter().map(|pr| pr.steps.len()).sum();
        let total: usize = single.iter().map(|pr| pr.steps.len()).sum();
        assert_eq!(total, reduce_steps + bcast_steps);
    }

    #[test]
    fn build_rejects_violated_preconditions_structurally() {
        use crate::collectives::Algorithm as A;
        use CollectiveKind as K;
        assert_eq!(
            build(K::Allreduce, A::RecursiveDoubling, 6, 10),
            Err(BuildError::NonPowerOfTwoRanks { alg: A::RecursiveDoubling, p: 6 })
        );
        assert_eq!(
            build(K::Allreduce, A::HalvingDoubling, 12, 10),
            Err(BuildError::NonPowerOfTwoRanks { alg: A::HalvingDoubling, p: 12 })
        );
        let g3 = crate::collectives::GroupStack::single(3).unwrap();
        assert_eq!(
            build(K::Allreduce, A::hier(&[3]), 8, 10),
            Err(BuildError::InvalidTierGrouping { p: 8, groups: g3 })
        );
        // A non-dividing OUTERMOST group is rejected for every kind with a
        // hierarchical builder.
        for kind in [
            K::ReduceScatter,
            K::Allgather,
            K::Broadcast { root: 0 },
        ] {
            assert_eq!(
                build(kind, A::hier(&[2, 6]), 8, 10),
                Err(BuildError::InvalidTierGrouping {
                    p: 8,
                    groups: crate::collectives::GroupStack::new(&[2, 6]).unwrap()
                }),
                "{kind:?}"
            );
        }
        assert_eq!(build(K::Allreduce, A::Auto, 8, 10), Err(BuildError::UnresolvedAuto));
        assert_eq!(build(K::Barrier, A::Ring, 0, 1), Err(BuildError::NoRanks));
        // Errors render a usable message.
        let msg = build(K::Allreduce, A::RecursiveDoubling, 6, 10).unwrap_err().to_string();
        assert!(msg.contains("power-of-two"), "{msg}");
        let msg = build(K::Allreduce, A::hier(&[3]), 8, 10).unwrap_err().to_string();
        assert!(msg.contains("groups=3"), "{msg}");
        // Valid requests still build.
        assert_eq!(build(K::Allreduce, A::Ring, 6, 10).unwrap().len(), 6);
        assert_eq!(build(K::Allreduce, A::hier(&[2]), 8, 10).unwrap().len(), 8);
        assert_eq!(build(K::Allreduce, A::hier(&[2, 4]), 8, 10).unwrap().len(), 8);
        assert_eq!(build(K::Allgather, A::hier(&[2, 4]), 16, 32).unwrap().len(), 16);
        assert_eq!(build(K::ReduceScatter, A::hier(&[3]), 9, 27).unwrap().len(), 9);
        assert_eq!(
            build(K::Broadcast { root: 5 }, A::hier(&[2, 6]), 12, 10).unwrap().len(),
            12
        );
    }

    /// Acceptance criterion: with a trivial tier stack the recursive
    /// builders emit BYTE-IDENTICAL programs to the flat algorithms.
    #[test]
    fn recursive_builders_degenerate_to_flat_byte_identical() {
        use crate::collectives::Algorithm as A;
        for (p, n) in [(6usize, 30usize), (8, 64), (1, 5)] {
            assert_eq!(
                allreduce_hierarchical_levels(p, n, &[], A::Ring),
                allreduce_ring(p, n)
            );
            assert_eq!(
                allgather_hierarchical(p, n, &[]),
                if p.is_power_of_two() { allgather_rdoubling(p, n) } else { allgather_ring(p, n) }
            );
            assert_eq!(reduce_scatter_hierarchical(p, n, &[]), reduce_scatter_natural(p, n));
            for root in 0..p {
                assert_eq!(
                    broadcast_hierarchical(p, n, root, &[]),
                    broadcast_binomial(p, n, root)
                );
            }
        }
        // All-1 group stacks degenerate the same way (every rank is a
        // leader at every level; the per-level trees are empty).
        assert_eq!(allreduce_hierarchical_levels(6, 30, &[1], A::Ring), allreduce_ring(6, 30));
        assert_eq!(allreduce_hierarchical_levels(6, 30, &[1, 1], A::Ring), allreduce_ring(6, 30));
    }

    /// Acceptance criterion: with TWO tiers the recursion is byte-
    /// identical to PR 1's three-phase composition (intra binomial reduce
    /// → lifted leader phase → intra binomial broadcast), restated here
    /// independently.
    #[test]
    fn two_tier_recursion_matches_legacy_composition() {
        use crate::collectives::Algorithm as A;
        for (p, rpn, n, inner) in
            [(8usize, 2usize, 64usize, A::HalvingDoubling), (12, 3, 40, A::Ring), (16, 4, 7, A::RecursiveDoubling)]
        {
            let nodes = p / rpn;
            let reduce = reduce_binomial(rpn, n, 0);
            let bcast = broadcast_binomial(rpn, n, 0);
            let leaders: Vec<Rank> = (0..nodes).map(|k| k * rpn).collect();
            let inter_progs = match inner {
                A::RecursiveDoubling => allreduce_rdoubling(nodes, n),
                A::HalvingDoubling => allreduce_halving_doubling(nodes, n),
                _ => allreduce_ring(nodes, n),
            };
            let inter = remap_ranks(inter_progs, &leaders);
            let legacy: Vec<Program> = (0..p)
                .map(|r| {
                    let node = r / rpn;
                    let local = r % rpn;
                    let node_map: Vec<Rank> = (0..rpn).map(|l| node * rpn + l).collect();
                    let mut steps =
                        remap_ranks(vec![reduce[local].clone()], &node_map).pop().unwrap().steps;
                    if local == 0 {
                        steps.extend(inter[node].steps.iter().copied());
                    }
                    steps.extend(
                        remap_ranks(vec![bcast[local].clone()], &node_map).pop().unwrap().steps,
                    );
                    Program { rank: r, steps }
                })
                .collect();
            assert_eq!(allreduce_hierarchical(p, n, rpn, inner), legacy, "p={p} rpn={rpn}");
            assert_eq!(
                allreduce_hierarchical_levels(p, n, &[rpn], inner),
                legacy,
                "levels p={p} rpn={rpn}"
            );
        }
    }

    #[test]
    fn three_level_non_leaders_never_touch_outer_tiers() {
        use crate::collectives::Algorithm as A;
        // 2 ranks/socket-ish group, 8/node-group, 32 ranks total.
        let (p, n) = (32usize, 48usize);
        let groups = [2usize, 8];
        let progs = allreduce_hierarchical_levels(p, n, &groups, A::Ring);
        for (r, prog) in progs.iter().enumerate() {
            assert_eq!(prog.rank, r);
            for step in &prog.steps {
                for peer in step
                    .send
                    .iter()
                    .map(|s| s.to)
                    .chain(step.recv.iter().map(|v| v.from))
                {
                    // A rank that is not a leader at level i must stay
                    // inside its level-i group.
                    for &g in &groups {
                        if r % g != 0 {
                            assert_eq!(peer / g, r / g, "rank {r} peer {peer} group {g}");
                        }
                    }
                }
            }
            if r % groups[0] != 0 {
                // Innermost non-leaders: one send up + one recv down.
                assert_eq!(prog.steps.len(), 2, "rank {r}");
            }
        }
    }
}

//! The library's single warning funnel.
//!
//! Every user-facing diagnostic that is *not* part of a subcommand's
//! payload goes through [`warn`], which writes one `warning: `-prefixed
//! line to **stderr**. The contract (documented in
//! `docs/ARCHITECTURE.md` §"Warning contract"):
//!
//! * stdout stays machine-consumable — `mlsl tune` without `--out`
//!   pipes a pure-JSON table, simulate reports stay parseable;
//! * warnings are grep-stable — CI asserts on the `analytic fallback`
//!   and out-of-grid messages, so call sites keep their key phrases;
//! * one-shot warnings (e.g. the tuning-table out-of-grid clamp in
//!   [`crate::tuner::table`]) implement their own latching and call
//!   [`warn`] at most once per process.

/// Emit `warning: {msg}` on stderr and bump the `util.warnings`
/// counter in [`crate::metrics::registry`], so tests and the `mlsl
/// trace` counter dump can assert warning counts without capturing
/// stderr.
pub fn warn(msg: impl AsRef<str>) {
    crate::metrics::registry::inc("util.warnings");
    eprintln!("{}", format_warning(msg.as_ref()));
}

/// The exact line [`warn`] prints (separated out so tests can pin the
/// format without capturing stderr).
pub fn format_warning(msg: &str) -> String {
    format!("warning: {msg}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_is_stable() {
        assert_eq!(format_warning("x — analytic fallback"), "warning: x — analytic fallback");
    }
}

//! Chrome trace-event JSON export: renders a recorded [`Trace`] into
//! the `traceEvents` format that Perfetto and `chrome://tracing` load.
//!
//! Track layout (docs/TRACING.md §Chrome-trace track layout): one
//! process per node (`pid` = rank) with fixed thread ids —
//!
//! | tid        | track                                  |
//! |------------|----------------------------------------|
//! | 0          | compute                                |
//! | 1          | shm egress channel                     |
//! | 2 + k      | NIC rail k egress                      |
//! | 2 + rails  | net (posted→delivered hop spans)       |
//! | 3 + rails  | marks (engine phases, collective issue)|
//!
//! Durations are complete events (`ph:"X"`, `ts`/`dur` in microseconds
//! as the format requires — nanosecond precision survives as fractional
//! microseconds); collective starts/finishes, chaos gates and rail
//! deaths are instants (`ph:"i"`). Span args carry bytes, priority,
//! tier and collective id so Perfetto queries can slice by them.

use std::collections::BTreeSet;
use std::path::Path;

use super::{Trace, TraceEvent, TrackChan};
use crate::util::json::Json;
use crate::Ns;

const TID_COMPUTE: u64 = 0;
const TID_SHM: u64 = 1;
const TID_RAIL0: u64 = 2;

fn us(ns: Ns) -> Json {
    Json::Num(ns as f64 / 1000.0)
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

fn complete(
    pid: usize,
    tid: u64,
    name: String,
    cat: &str,
    start: Ns,
    end: Ns,
    args: Vec<(&str, Json)>,
) -> Json {
    obj(vec![
        ("ph", Json::Str("X".into())),
        ("pid", num(pid as u64)),
        ("tid", num(tid)),
        ("name", Json::Str(name)),
        ("cat", Json::Str(cat.into())),
        ("ts", us(start)),
        ("dur", us(end.saturating_sub(start))),
        ("args", obj(args)),
    ])
}

fn instant(pid: usize, tid: u64, name: String, at: Ns, args: Vec<(&str, Json)>) -> Json {
    obj(vec![
        ("ph", Json::Str("i".into())),
        ("s", Json::Str("p".into())),
        ("pid", num(pid as u64)),
        ("tid", num(tid)),
        ("name", Json::Str(name)),
        ("ts", us(at)),
        ("args", obj(args)),
    ])
}

/// Render `trace` as a Chrome trace-event document for a `rails`-rail
/// fabric. Events are emitted in start-time order, so every track's
/// spans are time-monotonic.
pub fn export(trace: &Trace, rails: usize) -> Json {
    let rails = rails.max(1) as u64;
    let tid_net = TID_RAIL0 + rails;
    let tid_mark = tid_net + 1;
    let mut events: Vec<(Ns, Json)> = Vec::with_capacity(trace.events.len() + 16);
    let mut pids: BTreeSet<usize> = BTreeSet::new();
    for ev in &trace.events {
        match ev {
            TraceEvent::Compute(c) => {
                pids.insert(c.node);
                events.push((
                    c.start,
                    complete(
                        c.node,
                        TID_COMPUTE,
                        format!("compute t{}", c.tag),
                        "compute",
                        c.start,
                        c.end,
                        vec![("tag", num(c.tag))],
                    ),
                ));
            }
            TraceEvent::Busy(b) => {
                pids.insert(b.node);
                let (tid, name) = match b.chan {
                    TrackChan::Rail(r) => (TID_RAIL0 + r as u64, format!("egress p{}", b.class)),
                    TrackChan::Shm => (TID_SHM, "shm copy".to_string()),
                };
                events.push((
                    b.start,
                    complete(
                        b.node,
                        tid,
                        name,
                        "egress",
                        b.start,
                        b.end,
                        vec![("priority", num(b.class as u64))],
                    ),
                ));
            }
            TraceEvent::Hop(h) => {
                pids.insert(h.src);
                events.push((
                    h.posted_at,
                    complete(
                        h.src,
                        tid_net,
                        format!("->{} c{}", h.dst, h.tag),
                        "net",
                        h.posted_at,
                        h.deliver_at,
                        vec![
                            ("bytes", num(h.bytes)),
                            ("priority", num(h.priority as u64)),
                            ("tier", num(h.level as u64)),
                            ("coll", num(h.tag)),
                            ("dst", num(h.dst as u64)),
                            ("queue_ns", num(h.queue_ns())),
                            ("service_ns", num(h.service_ns)),
                            ("stall_ns", num(h.stall_ns())),
                            ("flight_ns", num(h.flight_ns())),
                            ("pieces", num(h.pieces as u64)),
                            ("lat_mult_milli", num(h.lat_mult_milli)),
                        ],
                    ),
                ));
            }
            TraceEvent::CollStart { coll_id, at, priority, ranks } => {
                events.push((
                    *at,
                    instant(
                        0,
                        tid_mark,
                        format!("coll {coll_id} start"),
                        *at,
                        vec![
                            ("coll", num(*coll_id)),
                            ("priority", num(*priority as u64)),
                            ("ranks", num(*ranks as u64)),
                        ],
                    ),
                ));
            }
            TraceEvent::RankDone { coll_id, rank, at } => {
                pids.insert(*rank);
                events.push((
                    *at,
                    instant(
                        *rank,
                        tid_mark,
                        format!("coll {coll_id} done"),
                        *at,
                        vec![("coll", num(*coll_id))],
                    ),
                ));
            }
            TraceEvent::ChaosGate { at, on } => {
                events.push((
                    *at,
                    instant(
                        0,
                        tid_mark,
                        format!("chaos gate {}", if *on { "open" } else { "close" }),
                        *at,
                        vec![("on", Json::Bool(*on))],
                    ),
                ));
            }
            TraceEvent::RailDie { at, node, rail } => {
                pids.insert(*node);
                events.push((
                    *at,
                    instant(
                        *node,
                        TID_RAIL0 + *rail as u64,
                        format!("rail {rail} dies"),
                        *at,
                        vec![("rail", num(*rail as u64))],
                    ),
                ));
            }
            TraceEvent::Mark { node, at, track, label } => {
                pids.insert(*node);
                events.push((
                    *at,
                    instant(
                        *node,
                        tid_mark,
                        format!("{track}:{label}"),
                        *at,
                        vec![("track", Json::Str(track.clone()))],
                    ),
                ));
            }
        }
    }
    events.sort_by_key(|(at, _)| *at);
    let mut out: Vec<Json> = Vec::with_capacity(events.len() + pids.len() * 4);
    // Thread-name metadata first, so viewers label the fixed tids.
    for &pid in &pids {
        let mut named: Vec<(u64, String)> = vec![
            (TID_COMPUTE, "compute".into()),
            (TID_SHM, "shm".into()),
            (tid_net, "net".into()),
            (tid_mark, "marks".into()),
        ];
        for r in 0..rails {
            named.push((TID_RAIL0 + r, format!("nic-rail-{r}")));
        }
        for (tid, name) in named {
            out.push(obj(vec![
                ("ph", Json::Str("M".into())),
                ("pid", num(pid as u64)),
                ("tid", num(tid)),
                ("name", Json::Str("thread_name".into())),
                ("args", obj(vec![("name", Json::Str(name))])),
            ]));
        }
    }
    out.extend(events.into_iter().map(|(_, e)| e));
    obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

/// Write the exported document to `path`.
pub fn write_file(trace: &Trace, rails: usize, path: &Path) -> std::io::Result<()> {
    std::fs::write(path, export(trace, rails).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{BusySpan, ComputeSpan, HopSpan};

    fn sample() -> Trace {
        Trace {
            events: vec![
                TraceEvent::Compute(ComputeSpan {
                    node: 0,
                    start: 0,
                    end: 500,
                    tag: 1,
                    cause: None,
                }),
                TraceEvent::Busy(BusySpan {
                    node: 0,
                    chan: TrackChan::Rail(1),
                    class: 2,
                    start: 500,
                    end: 900,
                }),
                TraceEvent::Hop(HopSpan {
                    src: 0,
                    dst: 1,
                    bytes: 4096,
                    priority: 2,
                    tag: 1,
                    level: 1,
                    posted_at: 500,
                    first_service_at: 500,
                    egress_done_at: 900,
                    deliver_at: 1400,
                    service_ns: 400,
                    pieces: 1,
                    lat_mult_milli: 1000,
                    cause: None,
                }),
                TraceEvent::CollStart { coll_id: 1, at: 0, priority: 2, ranks: 4 },
                TraceEvent::RankDone { coll_id: 1, rank: 1, at: 1400 },
            ],
        }
    }

    #[test]
    fn export_roundtrips_and_is_track_monotonic() {
        let doc = export(&sample(), 2);
        let text = doc.to_string();
        let parsed = Json::parse(&text).expect("exported JSON parses");
        let evs = parsed.at(&["traceEvents"]).as_arr().unwrap();
        assert!(!evs.is_empty());
        // Per-(pid,tid) complete-event start times are monotonic.
        let mut last: std::collections::HashMap<(u64, u64), f64> =
            std::collections::HashMap::new();
        let mut completes = 0;
        for e in evs {
            if e.at(&["ph"]).as_str() != Some("X") {
                continue;
            }
            completes += 1;
            let key = (
                e.at(&["pid"]).as_f64().unwrap() as u64,
                e.at(&["tid"]).as_f64().unwrap() as u64,
            );
            let ts = e.at(&["ts"]).as_f64().unwrap();
            let prev = last.insert(key, ts).unwrap_or(f64::MIN);
            assert!(ts >= prev, "track {key:?} went backwards");
            assert!(e.at(&["dur"]).as_f64().unwrap() >= 0.0);
        }
        assert_eq!(completes, 3);
        // The hop span carries its attribution args.
        let hop = evs
            .iter()
            .find(|e| e.at(&["cat"]).as_str() == Some("net"))
            .unwrap();
        assert_eq!(hop.at(&["args", "bytes"]).as_usize(), Some(4096));
        assert_eq!(hop.at(&["args", "tier"]).as_usize(), Some(1));
        assert_eq!(hop.at(&["args", "coll"]).as_usize(), Some(1));
        // Thread names exist for the rails.
        assert!(text.contains("nic-rail-1"));
        assert!(text.contains("thread_name"));
    }
}

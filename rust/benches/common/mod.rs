//! Shared helpers for the paper-table bench harnesses (harness = false).

use mlsl::engine::{simulate, CommMode, EngineConfig};
use mlsl::fabric::topology::Topology;
use mlsl::models::ModelDesc;

/// Milliseconds with 2 decimals.
#[allow(dead_code)]
pub fn ms(ns: u64) -> String {
    format!("{:.2}", ns as f64 / 1e6)
}

/// Ratio with 2 decimals ("x" suffix).
#[allow(dead_code)]
pub fn ratio(a: u64, b: u64) -> String {
    format!("{:.2}x", a as f64 / b.max(1) as f64)
}

/// Build a standard engine config.
#[allow(dead_code)]
pub fn cfg(model: &str, topo: Topology, nodes: usize, batch: usize, mode: CommMode) -> EngineConfig {
    let mut c = EngineConfig::new(ModelDesc::by_name(model).expect("model"), topo, nodes);
    c.batch = batch;
    c.mode = mode;
    c
}

/// Simulate and return (iter_ns, exposed_ns).
#[allow(dead_code)]
pub fn run(c: EngineConfig) -> (u64, u64) {
    let r = simulate(c);
    (r.iter_ns, r.exposed_comm_ns)
}

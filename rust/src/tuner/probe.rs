//! The measurement probe: times every candidate algorithm for each
//! tunable collective across a log-spaced (rank count × message size)
//! grid by executing real chunk programs through
//! [`crate::collectives::simexec`] on the live [`Topology`] — the same
//! cycle-accurate instrument the engine times training with, so measured
//! winners transfer directly to engine runs.
//!
//! Cells are independent (one private fabric each), so the grid is
//! embarrassingly parallel: [`tune_threaded`] stripes it across worker
//! threads and produces a byte-identical table (`--sim-threads`).

use crate::collectives::parexec::{run_pattern, FleetConfig, PatternSpec};
use crate::collectives::program::{build, CollectiveKind};
use crate::collectives::selector::{
    allgather_candidates, candidate_algorithms, compression_crossover_sizes, quant_chain_ns,
};
use crate::collectives::simexec::time_collective;
use crate::collectives::{Algorithm, WireDtype};
use crate::fabric::topology::Topology;
use crate::fabric::NetSim;
use crate::Ns;

use super::table::{Cand, MeasuredCell, TuningTable};

/// The collectives the probe measures.
pub const TUNED_KINDS: [CollectiveKind; 2] =
    [CollectiveKind::Allreduce, CollectiveKind::Allgather];

/// Rank rows above this are measured through the pattern driver
/// ([`crate::collectives::parexec::run_pattern`]) instead of full chunk
/// programs: at p in the thousands, building and executing per-rank
/// programs is prohibitive, while the O(p·rounds) pattern walk stays
/// cheap. Rows at or below the threshold keep the program-accurate path.
pub const PATTERN_ROW_MIN: usize = 512;

/// The datacenter-scale rank rows appended to the grid when `max_ranks`
/// reaches them — the first slice of tuning tables that carry measured
/// rows beyond a few hundred ranks (flat ring / recursive-doubling
/// candidates only; hierarchical shapes at that scale are future work).
pub const PATTERN_RANK_ROWS: [usize; 3] = [1024, 2048, 4096];

/// Is this rank row measured through the pattern driver?
pub fn pattern_row(p: usize) -> bool {
    p > PATTERN_ROW_MIN
}

const F32_ONLY: &[WireDtype] = &[WireDtype::F32];

/// Wire dtypes probed per collective kind: gradient allreduce measures
/// the full (algorithm × precision) menu; every other kind stays f32
/// (only reductions get error-feedback protection, so compression is
/// not offered elsewhere).
pub fn wire_menu(kind: CollectiveKind) -> &'static [WireDtype] {
    match kind {
        CollectiveKind::Allreduce => &WireDtype::ALL,
        _ => F32_ONLY,
    }
}

/// Grid description for a tuning run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProbeSpec {
    /// Largest rank count probed (rows: powers of two plus 3·2^k).
    pub max_ranks: usize,
    pub min_bytes: u64,
    pub max_bytes: u64,
    /// Log-spaced size points between min and max, inclusive.
    pub size_points: usize,
}

impl ProbeSpec {
    /// The full grid the `tune` subcommand measures by default.
    pub fn full() -> Self {
        Self { max_ranks: 64, min_bytes: 1 << 10, max_bytes: 64 << 20, size_points: 9 }
    }

    /// Tiny grid for CI smoke runs and tests.
    pub fn quick() -> Self {
        Self { max_ranks: 16, min_bytes: 1 << 10, max_bytes: 4 << 20, size_points: 4 }
    }

    /// Rank rows: powers of two plus 3·2^k (so ring-only non-power-of-two
    /// cells — and hierarchical cells with non-power-of-two leader counts
    /// — are measured too), clamped to `max_ranks`. The program-accurate
    /// rows stop at [`PATTERN_ROW_MIN`]; past it the grid jumps to the
    /// [`PATTERN_RANK_ROWS`] measured through the pattern driver.
    pub fn rank_grid(&self) -> Vec<usize> {
        let cap = self.max_ranks.min(PATTERN_ROW_MIN);
        let mut out = Vec::new();
        for start in [2usize, 6] {
            let mut p = start;
            while p <= cap {
                out.push(p);
                p *= 2;
            }
        }
        for p in PATTERN_RANK_ROWS {
            if p <= self.max_ranks {
                out.push(p);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// [`ProbeSpec::rank_grid`] extended with rows representative of the
    /// topology's tier shapes: for every tier size s, the multiples s,
    /// 2s, 3s and 4s (clamped to `max_ranks`). On a 3-level fabric this
    /// guarantees cells where the multi-level hierarchical candidates
    /// exist (p a strict multiple of the rack size), so the measured
    /// table actually covers 2- AND 3-level shapes instead of whatever
    /// the generic grid happens to hit.
    pub fn rank_grid_for(&self, topo: &Topology) -> Vec<usize> {
        let mut out = self.rank_grid();
        for s in topo.level_sizes() {
            for m in 1..=4usize {
                let p = s * m;
                if p >= 2 && p <= self.max_ranks.min(PATTERN_ROW_MIN) {
                    out.push(p);
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// [`ProbeSpec::size_grid`] extended with two topology-driven
    /// dimensions the generic log-spaced grid can miss:
    ///
    /// * the RAIL dimension — on a multi-rail fabric the striping
    ///   discount switches on in whole-chunk steps
    ///   ([`Topology::stripe_count`]), so the grid adds the
    ///   stripe-transition sizes `k · chunk_bytes` for k = 1..=max_rails;
    /// * the COMPRESSION crossovers — the analytic sizes where bf16/int8
    ///   first beat the f32 wire
    ///   ([`compression_crossover_sizes`], evaluated at both ends of the
    ///   rank span since the ring's per-hop segment scales with p), so
    ///   the measured table brackets every precision handover.
    pub fn size_grid_for(&self, topo: &Topology) -> Vec<u64> {
        let mut out = self.size_grid();
        let rails = topo.max_rails() as u64;
        if rails > 1 {
            for k in 1..=rails {
                let b = k * topo.chunk_bytes;
                if (self.min_bytes..=self.max_bytes).contains(&b) {
                    out.push(b);
                }
            }
        }
        let ranks = self.rank_grid();
        for p in [ranks.first(), ranks.last()].into_iter().flatten() {
            for b in compression_crossover_sizes(topo, (*p).min(PATTERN_ROW_MIN)) {
                if (self.min_bytes..=self.max_bytes).contains(&b) {
                    out.push(b);
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Log-spaced byte sizes from min to max inclusive (ascending).
    pub fn size_grid(&self) -> Vec<u64> {
        let k = self.size_points.max(2);
        let lo = self.min_bytes.max(4) as f64;
        let hi = (self.max_bytes.max(self.min_bytes.max(4))) as f64;
        let mut out: Vec<u64> = (0..k)
            .map(|i| {
                let f = i as f64 / (k - 1) as f64;
                (lo.ln() * (1.0 - f) + hi.ln() * f).exp().round() as u64
            })
            .collect();
        out.dedup();
        out
    }
}

/// Candidates the probe measures for (topo, kind, p) — exactly the sets
/// the analytic selector considers, so the tuned and analytic policies
/// choose from the same menu.
pub fn probe_candidates(topo: &Topology, kind: CollectiveKind, p: usize) -> Vec<Algorithm> {
    match kind {
        CollectiveKind::Allreduce => candidate_algorithms(topo, p),
        CollectiveKind::Allgather => allgather_candidates(topo, p),
        _ => vec![Algorithm::Ring],
    }
}

/// Time one collective on an otherwise idle simulated fabric (f32 wire).
pub fn measure_ns(
    topo: &Topology,
    kind: CollectiveKind,
    alg: Algorithm,
    p: usize,
    bytes: u64,
) -> Ns {
    measure_cand_ns(topo, kind, alg, p, bytes, WireDtype::F32)
}

/// Time one (algorithm, wire dtype) candidate: the chunk programs run
/// through the cycle-accurate simulator with `wire`-compressed payloads
/// (fewer bytes per hop), plus the modeled endpoint (de)quantize charge
/// ([`quant_chain_ns`]) the fabric simulator does not execute. f32 adds
/// nothing and is the pre-existing measurement bit-for-bit.
pub fn measure_cand_ns(
    topo: &Topology,
    kind: CollectiveKind,
    alg: Algorithm,
    p: usize,
    bytes: u64,
    wire: WireDtype,
) -> Ns {
    // Counted here — once per (cell, candidate) measurement — so the
    // serial and threaded grid walks bump `tuner.probes` identically.
    crate::metrics::registry::inc("tuner.probes");
    let n = (bytes / 4).max(1) as usize; // f32 elements
    let programs = build(kind, alg, p, n).expect("probe candidates are buildable");
    let mut sim = NetSim::new(topo.clone(), p);
    let wall = time_collective(&mut sim, programs, wire, 1);
    let quant = if kind == CollectiveKind::Allreduce {
        quant_chain_ns(alg, p, n, wire, 1000)
    } else {
        0
    };
    wall + quant
}

/// Time one flat allreduce through the PATTERN driver — the road to
/// rank counts in the thousands, where building per-rank chunk programs
/// is prohibitive. Same fabric, same per-hop wire-compressed bytes,
/// same endpoint quantize charge as [`measure_cand_ns`]. `None` for
/// algorithms the pattern driver cannot shape (everything but the ring
/// and, at power-of-two p, recursive doubling).
pub fn measure_pattern_ns(
    topo: &Topology,
    alg: Algorithm,
    p: usize,
    bytes: u64,
    wire: WireDtype,
) -> Option<Ns> {
    let n = (bytes / 4).max(1) as usize; // f32 elements
    let spec = match alg {
        Algorithm::Ring => {
            PatternSpec::ring_allreduce(p, wire.wire_bytes(n.div_ceil(p)) as u64)
        }
        Algorithm::RecursiveDoubling if p.is_power_of_two() => {
            PatternSpec::rdoubling_allreduce(p, wire.wire_bytes(n) as u64)
        }
        _ => return None,
    };
    crate::metrics::registry::inc("tuner.probes");
    let wall = run_pattern(topo, &spec, &FleetConfig::threaded(1)).finish_ns;
    Some(wall + quant_chain_ns(alg, p, n, wire, 1000))
}

/// The grid as an explicit cell list, in the serial insertion order both
/// walks share. Pattern rows exist only for allreduce — the pattern
/// driver has no allgather shape.
fn grid_cells(topo: &Topology, spec: &ProbeSpec) -> Vec<(CollectiveKind, usize, u64)> {
    let ranks = spec.rank_grid_for(topo);
    let sizes = spec.size_grid_for(topo);
    let mut cells = Vec::new();
    for kind in TUNED_KINDS {
        for &p in &ranks {
            if pattern_row(p) && kind != CollectiveKind::Allreduce {
                continue;
            }
            for &bytes in &sizes {
                cells.push((kind, p, bytes));
            }
        }
    }
    cells
}

/// Measure one grid cell: every candidate algorithm crossed with the
/// kind's wire menu (program-accurate below [`PATTERN_ROW_MIN`], the
/// pattern driver above it).
fn measure_cell(topo: &Topology, kind: CollectiveKind, p: usize, bytes: u64) -> MeasuredCell {
    let mut timings: Vec<(Cand, Ns)> = Vec::new();
    if pattern_row(p) {
        for alg in [Algorithm::Ring, Algorithm::RecursiveDoubling] {
            for &w in wire_menu(kind) {
                if let Some(t) = measure_pattern_ns(topo, alg, p, bytes, w) {
                    timings.push(((alg, w), t));
                }
            }
        }
    } else {
        for alg in probe_candidates(topo, kind, p) {
            for &w in wire_menu(kind) {
                timings.push(((alg, w), measure_cand_ns(topo, kind, alg, p, bytes, w)));
            }
        }
    }
    MeasuredCell::new_cand(p, bytes, timings)
}

/// Measure the whole grid, reporting `(done_cells, total_cells)` after
/// every cell.
pub fn tune_with_progress(
    topo: &Topology,
    spec: &ProbeSpec,
    mut progress: impl FnMut(usize, usize),
) -> TuningTable {
    let cells = grid_cells(topo, spec);
    let total = cells.len();
    let mut table = TuningTable::for_topology(topo);
    for (done, &(kind, p, bytes)) in cells.iter().enumerate() {
        table.insert(kind, measure_cell(topo, kind, p, bytes));
        progress(done + 1, total);
    }
    table
}

/// Measure the whole grid silently.
pub fn tune(topo: &Topology, spec: &ProbeSpec) -> TuningTable {
    tune_with_progress(topo, spec, |_, _| {})
}

/// Measure the whole grid with `threads` worker threads
/// (`mlsl tune --sim-threads n`).
///
/// Every grid cell is an independent measurement on its own private
/// [`NetSim`] ([`measure_ns`]), so the grid is striped across scoped
/// threads with no shared state at all. Results are inserted in the
/// serial grid order afterwards, so the produced table — including its
/// JSON serialization — is byte-identical to [`tune`]'s at any thread
/// count. `threads <= 1` takes the serial path unchanged.
pub fn tune_threaded(topo: &Topology, spec: &ProbeSpec, threads: usize) -> TuningTable {
    if threads <= 1 {
        return tune(topo, spec);
    }
    let cells = grid_cells(topo, spec);
    let nthreads = threads.min(cells.len()).max(1);
    let computed: Vec<Vec<(usize, MeasuredCell)>> = std::thread::scope(|scope| {
        let cells = &cells;
        let handles: Vec<_> = (0..nthreads)
            .map(|w| {
                scope.spawn(move || {
                    let mut out = Vec::new();
                    // Stripe, don't chunk: the expensive large-p cells sit
                    // at the end of the grid and would all land on the
                    // last worker otherwise.
                    let mut i = w;
                    while i < cells.len() {
                        let (kind, p, bytes) = cells[i];
                        out.push((i, measure_cell(topo, kind, p, bytes)));
                        i += nthreads;
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("probe worker panicked")).collect()
    });
    let mut flat: Vec<(usize, MeasuredCell)> = computed.into_iter().flatten().collect();
    flat.sort_by_key(|&(i, _)| i);
    let mut table = TuningTable::for_topology(topo);
    for (i, cell) in flat {
        table.insert(cells[i].0, cell);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_are_log_spaced_and_deduped() {
        let spec =
            ProbeSpec { max_ranks: 24, min_bytes: 1 << 10, max_bytes: 1 << 20, size_points: 3 };
        assert_eq!(spec.rank_grid(), vec![2, 4, 6, 8, 12, 16, 24]);
        assert_eq!(spec.size_grid(), vec![1 << 10, 1 << 15, 1 << 20]);
        // Degenerate range collapses to one point.
        let tiny = ProbeSpec { max_ranks: 2, min_bytes: 1024, max_bytes: 1024, size_points: 5 };
        assert_eq!(tiny.size_grid(), vec![1024]);
        assert_eq!(tiny.rank_grid(), vec![2]);
    }

    #[test]
    fn quick_probe_measures_every_candidate_per_cell() {
        let topo = Topology::eth_10g_smp(2);
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let table = tune(&topo, &spec);
        assert!(!table.is_empty());
        for kind in TUNED_KINDS {
            for cell in table.cells(kind) {
                let want = probe_candidates(&topo, kind, cell.ranks);
                assert_eq!(
                    cell.timings.len(),
                    want.len() * wire_menu(kind).len(),
                    "{kind:?} p={}",
                    cell.ranks
                );
                for alg in want {
                    let t = cell.time_of(alg).unwrap_or_else(|| {
                        panic!("{kind:?} p={} missing {alg:?}", cell.ranks)
                    });
                    assert!(t > 0, "{kind:?} p={} {alg:?}", cell.ranks);
                }
            }
        }
        assert!(table.matches(&topo));
    }

    #[test]
    fn tier_shaped_rank_rows_cover_multi_level_cells() {
        // On a 3-level fabric the probe grid must include rack-multiple
        // rows, and those cells must measure the 3-level candidate too.
        let topo = Topology::by_name("eth10g-x2r4").unwrap(); // node=2, rack=8
        let spec = ProbeSpec { max_ranks: 32, min_bytes: 1 << 10, max_bytes: 1 << 20, size_points: 2 };
        let grid = spec.rank_grid_for(&topo);
        for p in [8usize, 16, 24, 32] {
            assert!(grid.contains(&p), "{grid:?} missing {p}");
        }
        // Flat topologies keep the generic grid.
        assert_eq!(spec.rank_grid_for(&Topology::eth_10g()), spec.rank_grid());
        let table = tune(&topo, &spec);
        let three = crate::collectives::Algorithm::hier(&[2, 8]);
        let cell16 = table
            .cells(CollectiveKind::Allreduce)
            .iter()
            .find(|c| c.ranks == 16 && c.bytes == 1 << 10)
            .expect("rack-multiple row measured");
        assert!(cell16.time_of(three).is_some(), "{cell16:?}");
        // ...and the allgather grid measures its hierarchical candidate.
        let ag16 = table
            .cells(CollectiveKind::Allgather)
            .iter()
            .find(|c| c.ranks == 16 && c.bytes == 1 << 10)
            .unwrap();
        assert!(ag16.time_of(three).is_some(), "{ag16:?}");
    }

    #[test]
    fn size_grid_gains_a_rail_dimension_on_striped_fabrics() {
        use crate::collectives::selector::compression_crossover_sizes;
        let spec =
            ProbeSpec { max_ranks: 8, min_bytes: 1 << 10, max_bytes: 4 << 20, size_points: 3 };
        // On fast flat fabrics (no rails, no compression win) the grid is
        // exactly the generic one.
        assert_eq!(spec.size_grid_for(&Topology::omnipath_100g()), spec.size_grid());
        // On slow ethernet the extra points are exactly the compression
        // crossovers at the rank-span ends.
        let flat = Topology::eth_10g(); // chunk 256 KiB
        let grid_flat = spec.size_grid_for(&flat);
        for b in spec.size_grid() {
            assert!(grid_flat.contains(&b), "{grid_flat:?} missing generic {b}");
        }
        for extra in grid_flat.iter().filter(|b| !spec.size_grid().contains(b)) {
            let from_crossover = [2usize, 8].iter().any(|p| {
                compression_crossover_sizes(&flat, *p).contains(extra)
            });
            assert!(from_crossover, "unexplained grid point {extra}");
        }
        // Multi-rail fabrics add the stripe-transition sizes k·chunk.
        let e4 = flat.clone().with_rails(4).unwrap();
        let grid = spec.size_grid_for(&e4);
        for k in 1..=4u64 {
            assert!(grid.contains(&(k * e4.chunk_bytes)), "{grid:?} missing {k}·chunk");
        }
        assert!(grid.windows(2).all(|w| w[0] < w[1]), "sorted+deduped: {grid:?}");
        // Out-of-range transitions are clamped away.
        let tiny =
            ProbeSpec { max_ranks: 8, min_bytes: 40 << 20, max_bytes: 64 << 20, size_points: 3 };
        assert_eq!(tiny.size_grid_for(&e4), tiny.size_grid());
        // The probed table measures those cells like any other.
        let quick = ProbeSpec { max_ranks: 4, min_bytes: 1 << 10, max_bytes: 1 << 20, size_points: 2 };
        let e2 = flat.with_rails(2).unwrap();
        let table = tune(&e2, &quick);
        let cell = table
            .cells(CollectiveKind::Allreduce)
            .iter()
            .find(|c| c.ranks == 4 && c.bytes == 2 * e2.chunk_bytes)
            .expect("rail-transition cell measured");
        assert!(cell.best().is_some());
    }

    #[test]
    fn threaded_tune_matches_serial_byte_for_byte() {
        let topo = Topology::eth_10g_smp(2);
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let serial = tune(&topo, &spec);
        for threads in [2usize, 3] {
            let par = tune_threaded(&topo, &spec, threads);
            assert_eq!(par.to_json_string(), serial.to_json_string(), "threads={threads}");
        }
        // threads=1 is literally the serial path.
        assert_eq!(tune_threaded(&topo, &spec, 1).to_json_string(), serial.to_json_string());
    }

    #[test]
    fn probe_measurements_bump_the_metrics_registry() {
        let before = crate::metrics::registry::get("tuner.probes");
        measure_ns(&Topology::eth_10g(), CollectiveKind::Allreduce, Algorithm::Ring, 4, 4096);
        // >= not ==: sibling tests probing concurrently also bump it.
        assert!(crate::metrics::registry::get("tuner.probes") >= before + 1);
    }

    #[test]
    fn pattern_rows_extend_the_rank_grid_at_datacenter_scale() {
        // Below the threshold nothing changes…
        let small =
            ProbeSpec { max_ranks: 64, min_bytes: 1 << 10, max_bytes: 1 << 20, size_points: 2 };
        assert!(small.rank_grid().iter().all(|&p| !pattern_row(p)));
        // …above it the generic rows stop at PATTERN_ROW_MIN and the
        // pattern rows take over (no program-built rows in between).
        let big =
            ProbeSpec { max_ranks: 2048, min_bytes: 1 << 10, max_bytes: 1 << 20, size_points: 2 };
        let grid = big.rank_grid();
        assert!(grid.contains(&512) && grid.contains(&1024) && grid.contains(&2048), "{grid:?}");
        assert!(!grid.contains(&4096), "{grid:?}");
        assert!(grid.iter().all(|&p| p <= PATTERN_ROW_MIN || PATTERN_RANK_ROWS.contains(&p)));
        // Pattern rows never reach the allgather grid (no pattern shape).
        let topo = Topology::eth_10g();
        let cells = grid_cells(&topo, &big);
        assert!(cells.iter().any(|c| c.0 == CollectiveKind::Allreduce && pattern_row(c.1)));
        assert!(!cells.iter().any(|c| c.0 == CollectiveKind::Allgather && pattern_row(c.1)));
    }

    #[test]
    fn pattern_measurement_scales_to_thousands_of_ranks() {
        // Recursive doubling at p=1024 is 10 rounds — cheap to drive even
        // in debug builds — and must time every wire dtype, compressed
        // wires strictly cheaper at bandwidth-bound sizes.
        let topo = Topology::eth_10g();
        let bytes = 4u64 << 20;
        let rd = Algorithm::RecursiveDoubling;
        let f = measure_pattern_ns(&topo, rd, 1024, bytes, WireDtype::F32).unwrap();
        let i = measure_pattern_ns(&topo, rd, 1024, bytes, WireDtype::Int8Block).unwrap();
        assert!(i < f, "int8={i} f32={f}");
        // The driver has no shape for halving-doubling or hierarchy.
        assert!(measure_pattern_ns(&topo, Algorithm::HalvingDoubling, 1024, bytes, WireDtype::F32)
            .is_none());
        // Ring agrees with the program-accurate measurement at small p
        // (same rounds, same segment bytes — the pattern is the program).
        let ring_pat =
            measure_pattern_ns(&topo, Algorithm::Ring, 8, 1 << 20, WireDtype::F32).unwrap();
        let ring_prog = measure_ns(&topo, CollectiveKind::Allreduce, Algorithm::Ring, 8, 1 << 20);
        let ratio = ring_pat as f64 / ring_prog as f64;
        assert!((0.5..2.0).contains(&ratio), "pattern {ring_pat} vs program {ring_prog}");
    }

    #[test]
    fn allreduce_cells_carry_wire_columns_and_int8_wins_bulk() {
        let topo = Topology::eth_10g();
        let spec =
            ProbeSpec { max_ranks: 4, min_bytes: 1 << 10, max_bytes: 4 << 20, size_points: 2 };
        let table = tune(&topo, &spec);
        let cells = table.cells(CollectiveKind::Allreduce);
        let bulk = cells.iter().find(|c| c.ranks == 4 && c.bytes == 4 << 20).unwrap();
        // Full (algorithm × precision) menu measured…
        assert!(bulk.time_of_cand((Algorithm::Ring, WireDtype::Int8Block)).is_some());
        assert!(bulk.time_of_cand((Algorithm::Ring, WireDtype::Bf16)).is_some());
        // …the compressed wire wins the bandwidth-bound cell, while the
        // algorithm-only view still reports a pure-f32 winner.
        let ((_, wire), _) = bulk.best_cand().unwrap();
        assert_eq!(wire, WireDtype::Int8Block, "{bulk:?}");
        assert!(bulk.best().is_some());
        // Allgather cells stay f32-only.
        for cell in table.cells(CollectiveKind::Allgather) {
            assert!(cell.timings.iter().all(|((_, w), _)| *w == WireDtype::F32), "{cell:?}");
        }
    }

    #[test]
    fn measured_winners_track_latency_bandwidth_shape() {
        // On flat 10GbE the small-message winner must be a logarithmic-
        // round algorithm and the large-message winner bandwidth-optimal:
        // the measured table reproduces the paper's A4 shape.
        let topo = Topology::eth_10g();
        let spec = ProbeSpec { max_ranks: 16, min_bytes: 256, max_bytes: 64 << 20, size_points: 5 };
        let table = tune(&topo, &spec);
        let cells = table.cells(CollectiveKind::Allreduce);
        let small = cells.iter().find(|c| c.ranks == 16 && c.bytes == 256).unwrap();
        assert_eq!(small.best().unwrap().0, Algorithm::RecursiveDoubling);
        let large = cells.iter().find(|c| c.ranks == 16 && c.bytes == 64 << 20).unwrap();
        assert!(matches!(
            large.best().unwrap().0,
            Algorithm::Ring | Algorithm::HalvingDoubling
        ));
    }
}

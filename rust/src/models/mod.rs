//! Model zoo: per-layer compute/weight/activation tables.
//!
//! The engine and the analytic model consume these descriptors; they are
//! what stands in for the paper's Caffe prototxts. Weight counts and FLOP
//! totals are computed exactly from layer shapes and cross-checked against
//! the publicly known totals in tests (ResNet-50 ≈ 25.5M params, VGG-16 ≈
//! 138M, GoogLeNet ≈ 7M, AlexNet ≈ 61M).
//!
//! Conventions:
//! * FLOPs are multiply+add = 2 ops; per *sample* (multiply by batch).
//! * Backward ≈ 2× forward for weighted layers (dgrad + wgrad GEMMs).
//! * `fwd_order` of a layer is its index; gradient priority = fwd_order
//!   under `PriorityPolicy::ByLayer`.

pub mod alexnet;
pub mod googlenet;
pub mod resnet50;
pub mod transformer;
pub mod vgg16;

/// Layer category (drives parallelism choice in the DL Layer API).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Convolution: weights small vs activations — data parallel friendly.
    Conv,
    /// Fully connected: weights huge vs activations — model parallel friendly.
    Fc,
    /// Embedding lookup table.
    Embed,
    /// Attention projections (transformer QKVO).
    Attn,
    /// Normalization/bias-scale (tiny weights).
    Norm,
    /// Weightless (pooling, activation, softmax).
    Weightless,
}

/// One layer's accounting.
#[derive(Debug, Clone)]
pub struct LayerDesc {
    pub name: String,
    pub kind: LayerKind,
    /// Learnable parameter elements (f32).
    pub weight_elems: usize,
    /// Forward FLOPs per sample.
    pub fwd_flops: f64,
    /// Output activation elements per sample.
    pub out_act_elems: usize,
}

impl LayerDesc {
    pub fn weight_bytes(&self) -> u64 {
        4 * self.weight_elems as u64
    }

    /// Backward FLOPs per sample (dgrad + wgrad ≈ 2× fwd for weighted
    /// layers; ≈ 1× for weightless).
    pub fn bwd_flops(&self) -> f64 {
        if self.weight_elems > 0 {
            2.0 * self.fwd_flops
        } else {
            self.fwd_flops
        }
    }

    pub fn has_weights(&self) -> bool {
        self.weight_elems > 0
    }
}

/// A model = ordered layer list (forward order).
#[derive(Debug, Clone)]
pub struct ModelDesc {
    pub name: String,
    pub layers: Vec<LayerDesc>,
    /// Reference per-node mini-batch used by the paper-scale experiments.
    pub default_batch: usize,
}

impl ModelDesc {
    pub fn by_name(name: &str) -> Option<ModelDesc> {
        match name {
            "resnet50" => Some(resnet50::resnet50()),
            "vgg16" => Some(vgg16::vgg16()),
            "googlenet" => Some(googlenet::googlenet()),
            "alexnet" => Some(alexnet::alexnet()),
            "transformer" => Some(transformer::transformer_small()),
            _ => None,
        }
    }

    pub fn total_weight_elems(&self) -> usize {
        self.layers.iter().map(|l| l.weight_elems).sum()
    }

    pub fn total_weight_bytes(&self) -> u64 {
        4 * self.total_weight_elems() as u64
    }

    pub fn fwd_flops_per_sample(&self) -> f64 {
        self.layers.iter().map(|l| l.fwd_flops).sum()
    }

    pub fn bwd_flops_per_sample(&self) -> f64 {
        self.layers.iter().map(|l| l.bwd_flops()).sum()
    }

    pub fn step_flops(&self, batch: usize) -> f64 {
        (self.fwd_flops_per_sample() + self.bwd_flops_per_sample()) * batch as f64
    }

    /// Layers that produce weight gradients (the allreduce set).
    pub fn weighted_layers(&self) -> impl Iterator<Item = (usize, &LayerDesc)> {
        self.layers.iter().enumerate().filter(|(_, l)| l.has_weights())
    }
}

// ---------------------------------------------------------------------------
// Builder helpers shared by the per-model tables
// ---------------------------------------------------------------------------

/// Conv layer: k×k kernel, `cin`→`cout` channels, output `h`×`w`.
pub(crate) fn conv(name: &str, k: usize, cin: usize, cout: usize, h: usize, w: usize) -> LayerDesc {
    let weight_elems = k * k * cin * cout + cout; // + bias
    let fwd_flops = 2.0 * (k * k * cin * cout * h * w) as f64;
    LayerDesc {
        name: name.into(),
        kind: LayerKind::Conv,
        weight_elems,
        fwd_flops,
        out_act_elems: cout * h * w,
    }
}

/// Fully-connected layer `cin`→`cout`.
pub(crate) fn fc(name: &str, cin: usize, cout: usize) -> LayerDesc {
    LayerDesc {
        name: name.into(),
        kind: LayerKind::Fc,
        weight_elems: cin * cout + cout,
        fwd_flops: 2.0 * (cin * cout) as f64,
        out_act_elems: cout,
    }
}

/// Weightless layer (pool/relu/softmax) emitting `out_elems` activations.
pub(crate) fn pool(name: &str, out_elems: usize, flops: f64) -> LayerDesc {
    LayerDesc {
        name: name.into(),
        kind: LayerKind::Weightless,
        weight_elems: 0,
        fwd_flops: flops,
        out_act_elems: out_elems,
    }
}

/// BatchNorm over `c` channels at `h`×`w`.
pub(crate) fn bn(name: &str, c: usize, h: usize, w: usize) -> LayerDesc {
    LayerDesc {
        name: name.into(),
        kind: LayerKind::Norm,
        weight_elems: 2 * c,
        fwd_flops: 2.0 * (c * h * w) as f64,
        out_act_elems: c * h * w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_resolve() {
        for m in ["resnet50", "vgg16", "googlenet", "alexnet", "transformer"] {
            let model = ModelDesc::by_name(m).unwrap();
            assert!(!model.layers.is_empty(), "{m}");
            assert!(model.total_weight_elems() > 0, "{m}");
            assert!(model.fwd_flops_per_sample() > 0.0, "{m}");
        }
        assert!(ModelDesc::by_name("resnet152").is_none());
    }

    #[test]
    fn known_parameter_totals() {
        // Published totals (±3%: bias/bn bookkeeping differences).
        let checks = [
            ("resnet50", 25.5e6),
            ("vgg16", 138.3e6),
            ("googlenet", 7.0e6),
            ("alexnet", 61.0e6),
        ];
        for (name, want) in checks {
            let m = ModelDesc::by_name(name).unwrap();
            let got = m.total_weight_elems() as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.03, "{name}: {got:.3e} vs published {want:.3e}");
        }
    }

    #[test]
    fn known_flop_totals() {
        // Forward GFLOPs per sample (2*MACs), generous tolerance.
        let checks = [
            ("resnet50", 7.7e9),
            ("vgg16", 31.0e9),
            ("googlenet", 3.0e9),
            ("alexnet", 1.4e9),
        ];
        for (name, want) in checks {
            let m = ModelDesc::by_name(name).unwrap();
            let got = m.fwd_flops_per_sample();
            let rel = (got - want).abs() / want;
            assert!(rel < 0.15, "{name}: {got:.3e} vs expected {want:.3e}");
        }
    }

    #[test]
    fn vgg_gradient_distribution_is_fc_heavy() {
        // The paper's prioritization result is largest on VGG: its last
        // layers (fc) hold most of the weight bytes.
        let m = ModelDesc::by_name("vgg16").unwrap();
        let total = m.total_weight_bytes() as f64;
        let fc_bytes: u64 = m
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Fc)
            .map(|l| l.weight_bytes())
            .sum();
        assert!(fc_bytes as f64 / total > 0.85);
    }

    #[test]
    fn bwd_is_twice_fwd_for_weighted() {
        let l = fc("x", 100, 10);
        assert_eq!(l.bwd_flops(), 2.0 * l.fwd_flops);
        let p = pool("p", 10, 100.0);
        assert_eq!(p.bwd_flops(), p.fwd_flops);
    }
}

//! Selection policy: who decides which algorithm a collective runs —
//! the closed-form model ("model says") or a measured tuning table
//! ("measurement says").
//!
//! Every call site that previously hardcoded
//! [`selector::choose_algorithm`] / [`selector::choose_flat_algorithm`]
//! (the engine, the analytic design-space model, the CLI) now consults a
//! [`SelectionPolicy`]. The analytic policy reproduces the old behaviour
//! exactly; the tuned policies answer from a [`TuningTable`] and are
//! guaranteed to only ever return algorithms that
//! [`crate::collectives::program::build`] accepts at the queried rank
//! count (a legality filter runs before every table pick, because the
//! nearest measured row may prefer an algorithm that does not exist at
//! the actual p).

use crate::collectives::program::CollectiveKind;
use crate::collectives::selector;
use crate::collectives::Algorithm;
use crate::fabric::topology::Topology;
use crate::Ns;

use super::table::TuningTable;

/// Is `alg` buildable as an allreduce over `p` ranks? Deliberately the
/// BUILDER'S precondition, not the analytic candidate menu: a tuned
/// table may apply a measurement to any rank count the program compiles
/// at (e.g. hierarchical at p == ranks_per_node). Constant-time — this
/// runs per candidate on every tuned choose/predict — and kept in
/// lockstep with [`crate::collectives::program::build`] by the
/// `legality_matches_builder` test.
pub fn allreduce_legal(alg: Algorithm, p: usize) -> bool {
    match alg {
        Algorithm::Ring => true,
        Algorithm::RecursiveDoubling | Algorithm::HalvingDoubling => p.is_power_of_two(),
        Algorithm::Hierarchical { ranks_per_node } => {
            ranks_per_node >= 1 && p % ranks_per_node == 0
        }
        Algorithm::Auto => false,
    }
}

/// Is `alg` a real allgather program over `p` ranks? Only ring and
/// recursive doubling have allgather builders; every other algorithm
/// would silently compile to a ring, which a tuned table must not be
/// credited for. Lockstep with `build`: `legality_matches_builder`.
pub fn allgather_legal(alg: Algorithm, p: usize) -> bool {
    match alg {
        Algorithm::Ring => true,
        Algorithm::RecursiveDoubling => p.is_power_of_two(),
        _ => false,
    }
}

/// How call sites choose collective algorithms.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum SelectionPolicy {
    /// Closed-form two-tier alpha-beta model (the default: no table
    /// supplied).
    #[default]
    Analytic,
    /// Measured table, trusted unconditionally (nearest-cell semantics
    /// even when its fingerprint does not match the live topology);
    /// analytic only when the table has no legal candidate for a query.
    Tuned(TuningTable),
    /// Measured table, consulted ONLY while its fingerprint matches the
    /// live topology; any mismatch falls back to the analytic model
    /// wholesale. This is what `--tuning-table` installs.
    TunedWithFallback(TuningTable),
}

impl SelectionPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SelectionPolicy::Analytic => "analytic",
            SelectionPolicy::Tuned(_) => "tuned",
            SelectionPolicy::TunedWithFallback(_) => "tuned+fallback",
        }
    }

    /// The table to consult for `topo`, if this policy trusts one.
    fn table_for(&self, topo: &Topology) -> Option<&TuningTable> {
        match self {
            SelectionPolicy::Analytic => None,
            SelectionPolicy::Tuned(t) => Some(t),
            SelectionPolicy::TunedWithFallback(t) => {
                if t.matches(topo) {
                    Some(t)
                } else {
                    None
                }
            }
        }
    }

    /// Allreduce over a node-aligned (contiguous whole-node) communicator.
    pub fn choose_allreduce(&self, topo: &Topology, p: usize, bytes: u64) -> Algorithm {
        if p <= 1 {
            return Algorithm::Ring;
        }
        if let Some(t) = self.table_for(topo) {
            if let Some(alg) =
                t.lookup(CollectiveKind::Allreduce, p, bytes, &|a| allreduce_legal(a, p))
            {
                return alg;
            }
        }
        selector::choose_algorithm(topo, p, bytes)
    }

    /// Allreduce over a strided / non-node-aligned communicator. Tables
    /// are measured on contiguous communicators, where intra-node hops
    /// ride shared memory; a strided group gets no such discount, so the
    /// table only applies on flat fabrics (ranks_per_node == 1, where
    /// contiguity is irrelevant). Otherwise the all-inter analytic model
    /// decides — exactly what a mis-applied table would mispredict.
    pub fn choose_flat_allreduce(&self, topo: &Topology, p: usize, bytes: u64) -> Algorithm {
        if p <= 1 {
            return Algorithm::Ring;
        }
        if topo.ranks_per_node <= 1 {
            if let Some(t) = self.table_for(topo) {
                let legal = |a: Algorithm| {
                    !matches!(a, Algorithm::Hierarchical { .. }) && allreduce_legal(a, p)
                };
                if let Some(alg) = t.lookup(CollectiveKind::Allreduce, p, bytes, &legal) {
                    return alg;
                }
            }
        }
        selector::choose_flat_algorithm(topo, p, bytes)
    }

    /// Allgather over a node-aligned communicator (the engine's
    /// activation exchanges).
    pub fn choose_allgather(&self, topo: &Topology, p: usize, bytes: u64) -> Algorithm {
        if p <= 1 {
            return Algorithm::Ring;
        }
        if let Some(t) = self.table_for(topo) {
            if let Some(alg) =
                t.lookup(CollectiveKind::Allgather, p, bytes, &|a| allgather_legal(a, p))
            {
                return alg;
            }
        }
        selector::choose_allgather_algorithm(topo, p, bytes)
    }

    /// Allgather over a non-aligned communicator (see
    /// [`Self::choose_flat_allreduce`] for the gating rationale).
    pub fn choose_flat_allgather(&self, topo: &Topology, p: usize, bytes: u64) -> Algorithm {
        if p <= 1 {
            return Algorithm::Ring;
        }
        if topo.ranks_per_node <= 1 {
            if let Some(t) = self.table_for(topo) {
                if let Some(alg) =
                    t.lookup(CollectiveKind::Allgather, p, bytes, &|a| allgather_legal(a, p))
                {
                    return alg;
                }
            }
        }
        selector::choose_flat_allgather_algorithm(topo, p, bytes)
    }

    /// Predicted allreduce time under this policy: tuned policies answer
    /// from measured (log-interpolated) cells when they can, the analytic
    /// policy from the closed-form model — so design-space analyses built
    /// on this prediction calibrate to measurements once a table exists.
    pub fn predict_allreduce_ns(&self, topo: &Topology, p: usize, bytes: u64) -> Ns {
        if p <= 1 {
            return 0;
        }
        // One interpolation pass serves both the pick and its time (this
        // sits in the analytic design-space loops, per layer × group).
        if let Some(t) = self.table_for(topo) {
            let cheapest_legal = t
                .interpolated(CollectiveKind::Allreduce, p, bytes)
                .unwrap_or_default()
                .into_iter()
                .filter(|(a, _)| allreduce_legal(*a, p))
                .min_by(|x, y| x.1.partial_cmp(&y.1).expect("measured times are finite"));
            if let Some((_, ns)) = cheapest_legal {
                return ns.ceil() as Ns;
            }
        }
        let alg = selector::choose_algorithm(topo, p, bytes);
        selector::predict_allreduce_ns(topo, alg, p, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::probe::{tune, ProbeSpec};

    #[test]
    fn legality_matches_builder() {
        // The constant-time legality checks must agree with the builder's
        // own validation everywhere the policy can query them (p >= 1;
        // the policy short-circuits p <= 1 before filtering). For
        // allgather only ring/rdoubling count: `build` compiles anything
        // else to a ring fallback, which legality deliberately rejects.
        use crate::collectives::program::build;
        for p in 1..=64usize {
            let mut algs = vec![
                Algorithm::Ring,
                Algorithm::RecursiveDoubling,
                Algorithm::HalvingDoubling,
                Algorithm::Auto,
            ];
            for rpn in [0usize, 1, 2, 3, 4, 5, 8] {
                algs.push(Algorithm::Hierarchical { ranks_per_node: rpn });
            }
            for alg in algs {
                assert_eq!(
                    allreduce_legal(alg, p),
                    build(CollectiveKind::Allreduce, alg, p, 1).is_ok(),
                    "allreduce {alg:?} p={p}"
                );
            }
            for alg in [Algorithm::Ring, Algorithm::RecursiveDoubling] {
                assert_eq!(
                    allgather_legal(alg, p),
                    build(CollectiveKind::Allgather, alg, p, 1).is_ok(),
                    "allgather {alg:?} p={p}"
                );
            }
        }
    }

    #[test]
    fn analytic_policy_reproduces_selector_choices() {
        let policy = SelectionPolicy::default();
        assert_eq!(policy.name(), "analytic");
        for topo in [Topology::eth_10g(), Topology::eth_10g_smp(2)] {
            for p in [2usize, 6, 16, 64] {
                for bytes in [1u64 << 10, 1 << 20, 64 << 20] {
                    assert_eq!(
                        policy.choose_allreduce(&topo, p, bytes),
                        selector::choose_algorithm(&topo, p, bytes)
                    );
                    assert_eq!(
                        policy.choose_flat_allreduce(&topo, p, bytes),
                        selector::choose_flat_algorithm(&topo, p, bytes)
                    );
                    assert_eq!(
                        policy.choose_allgather(&topo, p, bytes),
                        selector::choose_allgather_algorithm(&topo, p, bytes)
                    );
                }
            }
        }
    }

    #[test]
    fn tuned_policy_answers_from_the_table_on_grid_cells() {
        let topo = Topology::eth_10g();
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let table = tune(&topo, &spec);
        let policy = SelectionPolicy::TunedWithFallback(table.clone());
        for kind in crate::tuner::probe::TUNED_KINDS {
            for cell in table.cells(kind) {
                let pick = match kind {
                    CollectiveKind::Allreduce => {
                        policy.choose_allreduce(&topo, cell.ranks, cell.bytes)
                    }
                    _ => policy.choose_allgather(&topo, cell.ranks, cell.bytes),
                };
                assert_eq!(pick, cell.best().unwrap().0, "{kind:?} p={}", cell.ranks);
            }
        }
    }

    #[test]
    fn strided_groups_on_smp_fabrics_stay_analytic() {
        let topo = Topology::eth_10g_smp(2);
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let policy = SelectionPolicy::Tuned(tune(&topo, &spec));
        for p in [4usize, 6, 8] {
            for bytes in [1u64 << 10, 1 << 20] {
                assert_eq!(
                    policy.choose_flat_allreduce(&topo, p, bytes),
                    selector::choose_flat_algorithm(&topo, p, bytes),
                    "p={p} bytes={bytes}"
                );
            }
        }
    }

    #[test]
    fn tuned_prediction_matches_measurement_on_grid_cells() {
        let topo = Topology::eth_10g();
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let table = tune(&topo, &spec);
        let policy = SelectionPolicy::Tuned(table.clone());
        for cell in table.cells(CollectiveKind::Allreduce) {
            let (_, best_ns) = cell.best().unwrap();
            assert_eq!(
                policy.predict_allreduce_ns(&topo, cell.ranks, cell.bytes),
                best_ns,
                "p={} bytes={}",
                cell.ranks,
                cell.bytes
            );
        }
    }
}

//! Fabric + node parameter presets for the paper's testbeds — an
//! **N-level tier hierarchy**.
//!
//! Real clusters are hierarchical well beyond two tiers: co-located ranks
//! share a socket or node (shared memory / QPI), nodes share a rack (ToR
//! switch, full NIC line rate), racks share an oversubscribed spine. A
//! [`Topology`] therefore carries an ordered stack of [`TierSpec`]s —
//! innermost first, each with its own group size, line rate, latency and
//! per-message overhead — plus the top-level fabric parameters
//! (`link_gbps` / `latency_ns` / `per_msg_overhead_ns`) that price every
//! hop not contained in any tier. Ranks are grouped contiguously at every
//! level (`group = rank / tier.ranks`), and every point-to-point cost
//! helper prices a hop at its **deepest common tier** — the innermost
//! level whose group contains both endpoints. An empty tier stack
//! collapses to the old flat single-tier model and every legacy helper
//! (`wire_ns`, `msg_ns`) keeps pricing the top tier.
//!
//! Preset names follow the suffix grammar `<base>[-x<r>[r<k>][e<l>]]`:
//! `-x<r>` puts `r` ranks on each shared-memory node (`eth10g-x2`,
//! `opa-x4`), the optional `r<k>` groups `k` nodes per rack behind an
//! oversubscribed spine (`eth10g-x8r16` = 8 ranks/node × 16 nodes/rack;
//! in-rack hops keep the NIC line rate while cross-rack hops pay
//! [`RACK_OVERSUBSCRIPTION`]× less bandwidth and 2× latency), and the
//! optional `e<l>` gives every node `l` independent NIC egress **rails**
//! (`eth10g-x8r16e2` = 2 rails/node; `eth10g-x1e4` = a flat fabric whose
//! nodes drive 4 rails). Each rail serializes at the per-rail line rate
//! with its own priority queue in [`crate::fabric::sim`]; chunk programs
//! stripe bandwidth-bound transfers across rails ([`Topology::stripe_count`])
//! while latency-bound small messages ride one rail and pay one overhead.
//! Suffixes round-trip through [`Topology::by_name`].
//!
//! Numbers are public-spec-derived, not measured on the authors' clusters;
//! EXPERIMENTS.md compares *shapes* (who wins, by what factor), which these
//! presets preserve (10GbE: high latency + low bandwidth → prioritization
//! matters most; Omnipath: low latency + high bandwidth → near-ideal
//! scaling with overlap; `-x<r>` smp variants: hierarchical collectives
//! win once the intra tier can absorb the first reduction level; `r<k>`
//! rack variants: a second reduction level pays off once the spine is the
//! bottleneck).

use crate::{Ns, Rank};

/// Shared-memory tier defaults (Skylake-class socket pair): ~75 GB/s
/// effective copy bandwidth, sub-µs latency, cheap doorbells.
const INTRA_GBPS: f64 = 600.0;
const INTRA_LATENCY_NS: Ns = 700;
const INTRA_OVERHEAD_NS: Ns = 150;

/// Spine oversubscription factor of the `r<k>` rack presets: cross-rack
/// traffic sees `link_gbps / RACK_OVERSUBSCRIPTION` effective bandwidth
/// (a classic 4:1 leaf-spine fabric).
pub const RACK_OVERSUBSCRIPTION: f64 = 4.0;

/// Most nested grouping levels a [`Topology`] may carry below the top
/// fabric (socket → node → rack → pod is 4). Keeps
/// [`crate::collectives::GroupStack`] — which mirrors tier prefixes —
/// `Copy`-able with a fixed-size backing array.
pub const MAX_TIERS: usize = 4;

/// Most NIC egress rails a node may drive. Real nodes aggregate 2–8;
/// the cap keeps an absurd `e<l>` suffix (or `--rails`) a clean
/// configuration error instead of letting [`crate::fabric::sim`]
/// allocate one egress server per claimed rail.
pub const MAX_RAILS: u32 = 64;

/// One level of the fabric hierarchy: `ranks` contiguous ranks form a
/// group wired with these link parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct TierSpec {
    /// Ranks per group at this level (absolute, contiguous grouping:
    /// `group = rank / ranks`). Must be >= 2, strictly increasing along
    /// the stack, and divide the next tier's size.
    pub ranks: usize,
    /// Line rate of a hop confined to this tier, Gbit/s.
    pub gbps: f64,
    /// In-flight message latency of this tier, ns.
    pub latency_ns: Ns,
    /// Per-message injection overhead of this tier, ns.
    pub per_msg_overhead_ns: Ns,
    /// Shared-memory tier: hops confined here bypass the NIC priority
    /// queue in [`crate::fabric::sim`] (they ride the per-rank shm
    /// channel — one free class, FIFO, no preemption). Shm tiers must
    /// form a prefix of the stack: nothing outside a NIC-crossing tier
    /// can be shared memory again.
    pub shm: bool,
    /// Independent egress rails a node drives for hops confined to this
    /// tier: each rail serializes at `gbps` with its own priority queue,
    /// so a transfer striped across `rails` chunks sees up to `rails`×
    /// the injection bandwidth. Must be >= 1; shm tiers have exactly 1
    /// (the per-rank copy channel is not a NIC endpoint).
    pub rails: u32,
}

impl TierSpec {
    /// A shared-memory tier of `ranks` co-located ranks with the default
    /// Skylake-class socket-pair parameters.
    pub fn shm_node(ranks: usize) -> Self {
        Self {
            ranks,
            gbps: INTRA_GBPS,
            latency_ns: INTRA_LATENCY_NS,
            per_msg_overhead_ns: INTRA_OVERHEAD_NS,
            shm: true,
            rails: 1,
        }
    }
}

/// Network fabric parameters: an N-level alpha–beta–gamma model. The
/// `link_*` fields describe the TOP tier (hops not contained in any
/// entry of `tiers`); `tiers` holds the nested inner levels.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub name: String,
    /// Top-tier egress line rate, Gbit/s (beta⁻¹ of the outermost level).
    pub link_gbps: f64,
    /// Top-tier end-to-end message latency, ns (alpha): propagation +
    /// switching.
    pub latency_ns: Ns,
    /// Top-tier per-message software/NIC injection overhead, ns (gamma).
    /// Paid on the egress wire before the first byte moves — this is what
    /// makes small messages latency-bound and motivates prioritization.
    pub per_msg_overhead_ns: Ns,
    /// Chunk size collectives use on this fabric, bytes. Preemption is
    /// chunk-granular, so this is also the preemption latency knob — and
    /// the rail-striping granularity: a transfer only occupies as many
    /// rails as it has whole chunks ([`Topology::stripe_count`]).
    pub chunk_bytes: u64,
    /// Independent NIC egress rails per node on the TOP tier (>= 1).
    /// Real Cloud/HPC nodes aggregate 2–4 NIC rails; driving them
    /// concurrently multiplies the injection bandwidth of
    /// bandwidth-bound collectives ([`crate::fabric::sim`] models one
    /// egress server per rail). The `e<l>` preset suffix sets this.
    pub rails: u32,
    /// Nested inner tiers, innermost first (empty = flat single-tier
    /// fabric). Invariants (see [`Topology::validate`]): at most
    /// [`MAX_TIERS`] entries; sizes >= 2, strictly increasing, each
    /// dividing the next; shm tiers form a prefix.
    pub tiers: Vec<TierSpec>,
}

impl Topology {
    /// A flat (single-tier) fabric from top-level link parameters.
    pub fn flat(name: &str, link_gbps: f64, latency_ns: Ns, per_msg_overhead_ns: Ns, chunk_bytes: u64) -> Self {
        Self {
            name: name.into(),
            link_gbps,
            latency_ns,
            per_msg_overhead_ns,
            chunk_bytes,
            rails: 1,
            tiers: Vec::new(),
        }
    }

    /// 10 Gbit/s Ethernet, TCP-class latency — the fabric of the paper's
    /// 1.8–2.2× prioritization result (C1).
    pub fn eth_10g() -> Self {
        Self::flat("eth10g", 10.0, 30_000 /* ~30 µs TCP stack */, 4_000, 256 * 1024)
    }

    /// Intel Omnipath-class 100 Gbit/s HPC fabric — Fig. 2's testbed.
    pub fn omnipath_100g() -> Self {
        Self::flat("omnipath100g", 100.0, 1_100 /* ~1.1 µs MPI pingpong */, 250, 1024 * 1024)
    }

    /// 25 GbE cloud fabric (intermediate point, used in ablations).
    pub fn eth_25g() -> Self {
        Self::flat("eth25g", 25.0, 15_000, 2_000, 512 * 1024)
    }

    /// Structural invariants of the tier stack. Construction through
    /// [`Topology::by_name`] / [`Topology::with_ranks_per_node`] /
    /// [`Topology::with_rack`] always yields a valid stack; hand-built
    /// topologies should call this before use.
    pub fn validate(&self) -> Result<(), String> {
        if self.tiers.len() > MAX_TIERS {
            return Err(format!(
                "at most {MAX_TIERS} inner tiers supported, got {}",
                self.tiers.len()
            ));
        }
        if self.rails == 0 || self.rails > MAX_RAILS {
            return Err(format!("top tier rails must be in 1..={MAX_RAILS}"));
        }
        let mut prev_ranks = 1usize;
        let mut seen_nic = false;
        for (i, t) in self.tiers.iter().enumerate() {
            if t.ranks < 2 {
                return Err(format!("tier {i}: group size must be >= 2, got {}", t.ranks));
            }
            if t.rails == 0 || t.rails > MAX_RAILS {
                return Err(format!("tier {i}: rails must be in 1..={MAX_RAILS}"));
            }
            if t.shm && t.rails != 1 {
                return Err(format!(
                    "tier {i}: shared-memory tiers have a single copy channel per \
                     rank, not NIC rails"
                ));
            }
            if t.ranks <= prev_ranks || t.ranks % prev_ranks != 0 {
                return Err(format!(
                    "tier {i}: group size {} must be a strictly larger multiple of the \
                     inner tier's {prev_ranks}",
                    t.ranks
                ));
            }
            if t.shm && seen_nic {
                return Err(format!(
                    "tier {i}: shared-memory tiers must form a prefix of the stack"
                ));
            }
            seen_nic |= !t.shm;
            prev_ranks = t.ranks;
        }
        Ok(())
    }

    /// Parse an smp/rack/rail preset suffix body (the part after `-x`):
    /// `<r>[r<k>][e<l>]`. Returns (ranks_per_node, nodes_per_rack,
    /// rails).
    fn parse_suffix(suffix: &str) -> Option<(usize, Option<usize>, Option<u32>)> {
        let (head, rails) = match suffix.split_once('e') {
            Some((h, e)) => (h, Some(e.parse().ok()?)),
            None => (suffix, None),
        };
        match head.split_once('r') {
            Some((r, k)) => {
                let (r, k) = (r.parse().ok()?, k.parse().ok()?);
                Some((r, Some(k), rails))
            }
            None => Some((head.parse().ok()?, None, rails)),
        }
    }

    /// Base preset name with any `-x<r>[r<k>]` suffix stripped.
    fn base_name(&self) -> String {
        match self.name.rsplit_once("-x") {
            Some((b, suffix)) if Self::parse_suffix(suffix).is_some() => b.to_string(),
            _ => self.name.clone(),
        }
    }

    /// Nodes per rack encoded in the current tier stack (rack size /
    /// node size), if a rack tier exists.
    fn nodes_per_rack(&self) -> Option<usize> {
        let rpn = self.ranks_per_node();
        self.tiers
            .iter()
            .find(|t| !t.shm)
            .map(|rack| rack.ranks / rpn.max(1))
    }

    /// Canonical preset name for the current tier stack:
    /// `<base>[-x<r>[r<k>][e<l>]]`, omitting the whole suffix when the
    /// topology is flat and single-rail. All suffix-applying builders
    /// regenerate the name through here so presets round-trip through
    /// [`Topology::by_name`] regardless of application order.
    fn suffixed_name(&self) -> String {
        let base = self.base_name();
        let r = self.ranks_per_node();
        let rack = self.nodes_per_rack().filter(|&k| k >= 2);
        let mut suffix = String::new();
        if r > 1 || rack.is_some() || self.rails > 1 {
            suffix = format!("-x{r}");
            if let Some(k) = rack {
                suffix.push_str(&format!("r{k}"));
            }
            if self.rails > 1 {
                suffix.push_str(&format!("e{}", self.rails));
            }
        }
        format!("{base}{suffix}")
    }

    /// Multi-rank-per-node variant of any preset: `r` ranks share each
    /// node's NIC-facing tiers and talk shared-memory within the node.
    /// An existing rack tier is preserved (its absolute size rescales to
    /// keep the same nodes-per-rack count), and so are its (and the top
    /// tier's) rail counts. The name gains an `-x<r>` suffix so presets
    /// resolve round-trip through [`Topology::by_name`]. `r == 0` is a
    /// configuration error (not a panic).
    pub fn with_ranks_per_node(mut self, r: usize) -> Result<Self, String> {
        if r == 0 {
            return Err("ranks_per_node must be >= 1".into());
        }
        let rack = self.nodes_per_rack();
        // Rebuild the node tier, preserving any custom node physics (the
        // outermost shm tier IS the node — matching `ranks_per_node`).
        let node_params = self
            .tiers
            .iter()
            .rev()
            .find(|t| t.shm)
            .cloned()
            .unwrap_or_else(|| TierSpec::shm_node(r));
        // Rack params carry their rail count through the rescale, exactly
        // like their physics (gbps/latency/overhead).
        let rack_params = self.tiers.iter().find(|t| !t.shm).cloned();
        self.tiers.clear();
        if r > 1 {
            self.tiers.push(TierSpec { ranks: r, ..node_params });
        }
        if let (Some(k), Some(params)) = (rack, rack_params) {
            if k >= 2 {
                self.tiers.push(TierSpec { ranks: r * k, ..params });
            }
        }
        self.name = self.suffixed_name();
        self.validate()?;
        Ok(self)
    }

    /// Add a rack tier grouping `nodes_per_rack` whole nodes behind an
    /// oversubscribed spine: in-rack hops keep the CURRENT top-tier
    /// parameters (full NIC line rate through the ToR switch, half the
    /// latency), while the new top tier — cross-rack traffic — pays
    /// [`RACK_OVERSUBSCRIPTION`]× less bandwidth and 2× latency. Errors
    /// if a rack tier is already present or `nodes_per_rack < 2`.
    pub fn with_rack(mut self, nodes_per_rack: usize) -> Result<Self, String> {
        if nodes_per_rack < 2 {
            return Err("nodes_per_rack must be >= 2".into());
        }
        if self.tiers.iter().any(|t| !t.shm) {
            return Err(format!("{} already has a rack tier", self.name));
        }
        let rpn = self.ranks_per_node();
        self.tiers.push(TierSpec {
            ranks: rpn * nodes_per_rack,
            gbps: self.link_gbps,
            latency_ns: self.latency_ns / 2,
            per_msg_overhead_ns: self.per_msg_overhead_ns,
            shm: false,
            // The rack tier rides the same physical NIC endpoints as the
            // spine: it inherits the node's rail count.
            rails: self.rails,
        });
        self.link_gbps /= RACK_OVERSUBSCRIPTION;
        self.latency_ns *= 2;
        self.name = self.suffixed_name();
        self.validate()?;
        Ok(self)
    }

    /// Multi-rail variant of any preset: every node drives `l`
    /// independent NIC egress rails on ALL NIC-crossing tiers (the rails
    /// are physical node endpoints shared by the in-rack and cross-rack
    /// paths; shared-memory tiers are untouched). The name gains an
    /// `e<l>` suffix (`eth10g-x2e2`; a flat preset becomes
    /// `eth10g-x1e4`) so presets round-trip through
    /// [`Topology::by_name`]. `l == 0` is a configuration error.
    pub fn with_rails(mut self, l: u32) -> Result<Self, String> {
        if l == 0 {
            return Err("rails must be >= 1".into());
        }
        self.rails = l;
        for t in &mut self.tiers {
            if !t.shm {
                t.rails = l;
            }
        }
        self.name = self.suffixed_name();
        self.validate()?;
        Ok(self)
    }

    /// The paper's Xeon/10GbE testbed at >1 rank per node.
    ///
    /// Panics on `ranks_per_node == 0` — a test/bench convenience; use
    /// [`Topology::with_ranks_per_node`] for fallible construction.
    pub fn eth_10g_smp(ranks_per_node: usize) -> Self {
        Self::eth_10g()
            .with_ranks_per_node(ranks_per_node)
            .expect("preset ranks_per_node must be >= 1")
    }

    /// The paper's Xeon/Omni-Path testbed at >1 rank per node. Panics on
    /// `ranks_per_node == 0` (see [`Topology::eth_10g_smp`]).
    pub fn omnipath_100g_smp(ranks_per_node: usize) -> Self {
        Self::omnipath_100g()
            .with_ranks_per_node(ranks_per_node)
            .expect("preset ranks_per_node must be >= 1")
    }

    /// Resolve a preset name; `-x<r>` suffixes select the smp variant
    /// (e.g. `eth10g-x2`, `opa-x4`), `r<k>` adds a rack tier of `k`
    /// nodes (e.g. `eth10g-x8r16`) and `e<l>` gives every node `l` NIC
    /// rails (e.g. `eth10g-x8r16e2`, `opa-x1e4`). Malformed suffixes
    /// (e.g. `-x0`, `e0`) resolve to `None`, which the CLI reports as a
    /// configuration error.
    pub fn by_name(name: &str) -> Option<Self> {
        if let Some((base, suffix)) = name.rsplit_once("-x") {
            if let Some((r, rack, rails)) = Self::parse_suffix(suffix) {
                let mut topo = Self::by_name(base)?.with_ranks_per_node(r).ok()?;
                if let Some(k) = rack {
                    topo = topo.with_rack(k).ok()?;
                }
                if let Some(l) = rails {
                    topo = topo.with_rails(l).ok()?;
                }
                return Some(topo);
            }
        }
        match name {
            "eth10g" => Some(Self::eth_10g()),
            "eth25g" => Some(Self::eth_25g()),
            "omnipath100g" | "opa" => Some(Self::omnipath_100g()),
            _ => None,
        }
    }

    // -- tier resolution ----------------------------------------------------

    /// Number of levels including the top fabric (= `tiers.len() + 1`).
    pub fn num_levels(&self) -> usize {
        self.tiers.len() + 1
    }

    /// Index of the top (outermost) level.
    pub fn top_level(&self) -> usize {
        self.tiers.len()
    }

    /// Group sizes of the inner tiers, innermost first.
    pub fn level_sizes(&self) -> Vec<usize> {
        self.tiers.iter().map(|t| t.ranks).collect()
    }

    /// Ranks co-located on one shared-memory node: the outermost shm
    /// tier's group size (1 on flat fabrics and pure-NIC hierarchies).
    pub fn ranks_per_node(&self) -> usize {
        self.tiers.iter().rev().find(|t| t.shm).map_or(1, |t| t.ranks)
    }

    /// Node index of `rank` under contiguous grouping.
    pub fn node_of(&self, rank: Rank) -> usize {
        rank / self.ranks_per_node().max(1)
    }

    /// Deepest common tier of an `(a, b)` hop: the innermost level whose
    /// group contains both ranks; `top_level()` when none does.
    pub fn level_of(&self, a: Rank, b: Rank) -> usize {
        self.tiers
            .iter()
            .position(|t| a / t.ranks == b / t.ranks)
            .unwrap_or_else(|| self.top_level())
    }

    /// Do `a` and `b` share a shared-memory node? True exactly when the
    /// hop's deepest common tier is an shm tier. (Never true on flat
    /// topologies.)
    pub fn same_node(&self, a: Rank, b: Rank) -> bool {
        self.tiers.get(self.level_of(a, b)).is_some_and(|t| t.shm)
    }

    /// Does this fabric have any inner tier to exploit?
    pub fn is_hierarchical(&self) -> bool {
        !self.tiers.is_empty()
    }

    /// Levels whose hops ride the NIC (everything except shared-memory
    /// tiers), innermost first and always including the top fabric.
    /// These are the levels link faults ([`crate::fabric::sim::ChaosPlan`])
    /// can live on: shm copies never cross a flappable wire.
    pub fn nic_levels(&self) -> Vec<usize> {
        (0..self.num_levels())
            .filter(|&l| !self.tiers.get(l).is_some_and(|t| t.shm))
            .collect()
    }

    /// Innermost level whose groups can contain an ALIGNED contiguous run
    /// of `g` ranks (tier size a multiple of `g`); `top_level()` when no
    /// inner tier can. Used to price in-group traffic on the correct tier.
    pub fn level_for_group(&self, g: usize) -> usize {
        self.tiers
            .iter()
            .position(|t| t.ranks >= g && t.ranks % g == 0)
            .unwrap_or_else(|| self.top_level())
    }

    /// How many leading tiers `members` decomposes into: the count of
    /// inner levels whose groups the member set tiles exactly (members
    /// contiguous ascending, first member group-aligned, length a
    /// multiple of the group size). 0 for strided / non-aligned /
    /// empty sets. Hierarchical collectives are valid over the first
    /// `aligned_tier_depth` levels only.
    pub fn aligned_tier_depth(&self, members: &[Rank]) -> usize {
        if members.is_empty() || members.windows(2).any(|w| w[1] != w[0] + 1) {
            return 0;
        }
        let len = members.len();
        self.tiers
            .iter()
            .take_while(|t| t.ranks <= len && len % t.ranks == 0 && members[0] % t.ranks == 0)
            .count()
    }

    /// Leading tiers the N-level COST MODEL may assume for `members`:
    /// each tier's groups must either be exactly tiled by the member set
    /// (hierarchical candidates stay legal there) or contain it whole
    /// (in-group pricing stays valid). A tier the members straddle
    /// without tiling — e.g. a node-aligned run crossing one rack
    /// boundary mid-rack — must be collapsed into the top level before
    /// pricing, or a "rack-sized" ring would be billed in-rack while its
    /// straddling hop crosses the spine every lockstep.
    pub fn chooser_tier_depth(&self, members: &[Rank]) -> usize {
        if members.is_empty() || members.windows(2).any(|w| w[1] != w[0] + 1) {
            return 0;
        }
        let len = members.len();
        let (first, last) = (members[0], members[len - 1]);
        self.tiers
            .iter()
            .take_while(|t| {
                let tiles = t.ranks <= len && len % t.ranks == 0 && first % t.ranks == 0;
                let contains = first / t.ranks == last / t.ranks;
                tiles || contains
            })
            .count()
    }

    /// This fabric truncated to its first `depth` inner tiers (outer
    /// tiers collapse into the top level). Used to restrict algorithm
    /// choice for communicators only partially aligned to the hierarchy;
    /// pricing outer-tier hops at the top level is conservative.
    pub fn restrict_tiers(&self, depth: usize) -> Self {
        let mut t = self.clone();
        t.tiers.truncate(depth);
        t
    }

    /// Tier sizes usable as hierarchical group stacks over a contiguous
    /// aligned communicator of `p` ranks: sizes > 1, < p, dividing p
    /// (ascending; nesting divisibility is inherited from the stack).
    pub fn hier_group_sizes_for(&self, p: usize) -> Vec<usize> {
        self.tiers
            .iter()
            .map(|t| t.ranks)
            .filter(|&s| s > 1 && s < p && p % s == 0)
            .collect()
    }

    /// True when `members` decompose into whole shared-memory nodes:
    /// consecutive runs of `ranks_per_node()` ranks, each starting at a
    /// node boundary — the nodes themselves need NOT be adjacent.
    /// (Legacy two-tier helper, semantics unchanged from PR 1;
    /// [`Topology::aligned_tier_depth`] is the N-level generalization
    /// the engine gates on, which additionally requires a contiguous
    /// run so outer tiers can be exploited.)
    pub fn ranks_node_aligned(&self, members: &[Rank]) -> bool {
        let rpn = self.ranks_per_node();
        rpn > 1
            && !members.is_empty()
            && members.len() % rpn == 0
            && members.chunks(rpn).all(|c| {
                c[0] % rpn == 0 && c.windows(2).all(|w| w[1] == w[0] + 1)
            })
    }

    /// Line rate of a level, Gbit/s.
    pub fn gbps_at(&self, level: usize) -> f64 {
        self.tiers.get(level).map_or(self.link_gbps, |t| t.gbps)
    }

    /// Message latency of a level, ns.
    pub fn latency_at(&self, level: usize) -> Ns {
        self.tiers.get(level).map_or(self.latency_ns, |t| t.latency_ns)
    }

    /// Per-message overhead of a level, ns.
    pub fn overhead_at(&self, level: usize) -> Ns {
        self.tiers.get(level).map_or(self.per_msg_overhead_ns, |t| t.per_msg_overhead_ns)
    }

    /// Egress rails available to a hop confined to `level`.
    pub fn rails_at(&self, level: usize) -> u32 {
        self.tiers.get(level).map_or(self.rails, |t| t.rails)
    }

    /// Most rails any level drives — the number of egress servers each
    /// node owns in [`crate::fabric::sim`] (rails are physical node
    /// endpoints; per-hop striping is capped by [`Topology::rails_at`]).
    pub fn max_rails(&self) -> u32 {
        self.tiers.iter().map(|t| t.rails).fold(self.rails, u32::max)
    }

    /// Conservative-lookahead bound for the partitioned simulator
    /// ([`crate::collectives::parexec`]): the minimum in-flight latency
    /// of any NIC tier. A node-partitioned fleet ([`crate::fabric::par`])
    /// never splits a shared-memory node across shards, so every
    /// cross-shard hop is a NIC-tier hop and spends at least this long
    /// in flight after leaving the source wire — which is what lets a
    /// shard safely execute all local events strictly before
    /// `min(shard clocks) + lookahead_ns()`. Chaos latency flaps only
    /// ever stretch latency ([`ChaosPlan::generate`] multipliers are
    /// ≥ 1×), so the bound survives fault injection; hand-built plans
    /// with shrinking multipliers must scale it down (the parexec
    /// coordinator does).
    ///
    /// [`ChaosPlan::generate`]: crate::fabric::sim::ChaosPlan::generate
    pub fn lookahead_ns(&self) -> Ns {
        self.nic_levels()
            .into_iter()
            .map(|l| self.latency_at(l))
            .min()
            .unwrap_or(self.latency_ns)
    }

    /// Rails a `bytes`-sized transfer at `level` actually occupies: the
    /// level's rail count, capped by the number of whole
    /// [`Topology::chunk_bytes`] chunks in flight. Latency-bound small
    /// messages (under one chunk) ride ONE rail and pay one overhead —
    /// striping discounts only the bandwidth term, never alpha. Pure
    /// (deterministic in its arguments), so simulator replay and the
    /// analytic model agree exactly.
    pub fn stripe_count(&self, level: usize, bytes: u64) -> u32 {
        let rails = self.rails_at(level) as u64;
        if rails <= 1 {
            return 1;
        }
        let chunks = (bytes / self.chunk_bytes.max(1)).max(1);
        rails.min(chunks) as u32
    }

    // -- hop costs ------------------------------------------------------------

    /// Pure wire time for `bytes` on the TOP tier (no latency/overhead).
    /// Legacy helper: flat topologies have only this tier.
    pub fn wire_ns(&self, bytes: u64) -> Ns {
        super::wire_ns(bytes, self.link_gbps)
    }

    /// Full cost of a single TOP-tier point-to-point message.
    pub fn msg_ns(&self, bytes: u64) -> Ns {
        self.per_msg_overhead_ns + self.wire_ns(bytes) + self.latency_ns
    }

    /// Full cost of a single point-to-point message at `level`.
    pub fn msg_ns_at(&self, level: usize, bytes: u64) -> Ns {
        self.overhead_at(level)
            + super::wire_ns(bytes, self.gbps_at(level))
            + self.latency_at(level)
    }

    /// Full cost of a single INNERMOST-tier message (the top tier on flat
    /// fabrics). Legacy two-tier helper.
    pub fn intra_msg_ns(&self, bytes: u64) -> Ns {
        self.msg_ns_at(0, bytes)
    }

    /// Wire time of `bytes` between two concrete ranks, priced at the
    /// hop's deepest common tier.
    pub fn wire_ns_between(&self, src: Rank, dst: Rank, bytes: u64) -> Ns {
        super::wire_ns(bytes, self.gbps_at(self.level_of(src, dst)))
    }

    /// Per-message overhead between two concrete ranks.
    pub fn overhead_between(&self, src: Rank, dst: Rank) -> Ns {
        self.overhead_at(self.level_of(src, dst))
    }

    /// In-flight latency between two concrete ranks.
    pub fn latency_between(&self, src: Rank, dst: Rank) -> Ns {
        self.latency_at(self.level_of(src, dst))
    }

    /// Full cost of a message between two concrete ranks.
    pub fn msg_ns_between(&self, src: Rank, dst: Rank, bytes: u64) -> Ns {
        self.msg_ns_at(self.level_of(src, dst), bytes)
    }

    /// Wall time of a single point-to-point message at `level` when its
    /// chunks stripe across the level's rails: the largest piece gates
    /// delivery, the pieces move concurrently, and the per-message
    /// overhead and latency are paid once (not divided — rails never
    /// discount alpha). Identical to [`Topology::msg_ns_at`] on
    /// single-rail fabrics and for sub-chunk messages.
    pub fn striped_msg_ns_at(&self, level: usize, bytes: u64) -> Ns {
        let k = self.stripe_count(level, bytes) as u64;
        let piece = bytes.div_ceil(k.max(1));
        self.overhead_at(level)
            + super::wire_ns(piece, self.gbps_at(level))
            + self.latency_at(level)
    }
}

/// Node compute model (Skylake-class by default).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub name: String,
    /// Peak single-precision FLOP/s of the whole socket pair.
    pub peak_flops: f64,
    /// Fraction of peak a tuned DL framework sustains (conv/gemm mix).
    pub dl_efficiency: f64,
    /// Physical cores (comm cores are stolen from these).
    pub cores: usize,
}

impl NodeSpec {
    /// 2× Intel Xeon Gold 6148 (Skylake, the paper's node): 2 × 20 cores ×
    /// 2 AVX-512 FMA units × 16 f32 lanes × 2 flop × 2.4 GHz ≈ 6.1 Tf/s.
    pub fn skylake_6148() -> Self {
        Self {
            name: "2xXeon6148".into(),
            peak_flops: 6.1e12,
            dl_efficiency: 0.55,
            cores: 40,
        }
    }

    /// Xeon Phi 7250 (the 9600-node Cori run cited by the paper).
    pub fn xeon_phi_7250() -> Self {
        Self {
            name: "XeonPhi7250".into(),
            peak_flops: 6.0e12,
            dl_efficiency: 0.35,
            cores: 68,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "skylake" | "2xXeon6148" => Some(Self::skylake_6148()),
            "knl" | "XeonPhi7250" => Some(Self::xeon_phi_7250()),
            _ => None,
        }
    }

    /// Sustained FLOP/s with `comm_cores` dedicated to driving the network
    /// (the paper: "dedicating one or more cores for driving the network").
    pub fn effective_flops(&self, comm_cores: usize) -> f64 {
        let compute_cores = self.cores.saturating_sub(comm_cores).max(1);
        self.peak_flops * self.dl_efficiency * compute_cores as f64 / self.cores as f64
    }

    /// Time to execute `flops` floating point ops, ns.
    pub fn compute_ns(&self, flops: f64, comm_cores: usize) -> Ns {
        (flops / self.effective_flops(comm_cores) * 1e9).ceil() as Ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_time_scales_linearly() {
        let t = Topology::eth_10g();
        // 10 Gbps = 1.25 B/ns -> 1 MiB takes 1048576/1.25 ≈ 838861 ns.
        assert_eq!(t.wire_ns(1_048_576), 838_861);
        assert!(t.wire_ns(2 * 1_048_576) >= 2 * t.wire_ns(1_048_576) - 1);
    }

    #[test]
    fn lookahead_is_the_min_nic_tier_latency() {
        // Flat fabric: the only NIC level is the top tier.
        let flat = Topology::flat("t", 8.0, 1_000, 100, 1 << 20);
        assert_eq!(flat.lookahead_ns(), 1_000);
        // Shm tier does not lower the bound (its hops never cross shards).
        let smp = Topology::eth_10g_smp(4);
        assert_eq!(smp.lookahead_ns(), smp.latency_at(smp.top_level()));
        // A faster in-rack NIC tier does.
        let racked = Topology::by_name("eth10g-x2r4").unwrap();
        let min_nic = racked
            .nic_levels()
            .into_iter()
            .map(|l| racked.latency_at(l))
            .min()
            .unwrap();
        assert_eq!(racked.lookahead_ns(), min_nic);
        assert!(racked.lookahead_ns() < racked.latency_at(racked.top_level()));
        assert!(racked.lookahead_ns() > 0);
    }

    #[test]
    fn omnipath_beats_ethernet() {
        let e = Topology::eth_10g();
        let o = Topology::omnipath_100g();
        assert!(o.msg_ns(1024) < e.msg_ns(1024));
        assert!(o.msg_ns(16 << 20) < e.msg_ns(16 << 20));
    }

    #[test]
    fn comm_cores_reduce_compute_rate() {
        let n = NodeSpec::skylake_6148();
        assert!(n.effective_flops(2) < n.effective_flops(0));
        // Stealing 2 of 40 cores costs 5%.
        let ratio = n.effective_flops(2) / n.effective_flops(0);
        assert!((ratio - 38.0 / 40.0).abs() < 1e-9);
    }

    #[test]
    fn presets_resolve_by_name() {
        assert!(Topology::by_name("eth10g").is_some());
        assert!(Topology::by_name("opa").is_some());
        assert!(Topology::by_name("nope").is_none());
        assert!(NodeSpec::by_name("skylake").is_some());
    }

    #[test]
    fn smp_presets_resolve_and_roundtrip() {
        let t = Topology::by_name("eth10g-x4").unwrap();
        assert_eq!(t.ranks_per_node(), 4);
        assert_eq!(t.name, "eth10g-x4");
        assert_eq!(Topology::by_name(&t.name).unwrap(), t);
        let o = Topology::omnipath_100g_smp(2);
        assert_eq!(o.name, "omnipath100g-x2");
        assert_eq!(Topology::by_name("opa-x2").unwrap().ranks_per_node(), 2);
        assert!(Topology::by_name("nope-x2").is_none());
        // Re-suffixing replaces, never stacks.
        let again = t.with_ranks_per_node(2).unwrap();
        assert_eq!(again.name, "eth10g-x2");
        assert_eq!(again.with_ranks_per_node(1).unwrap().name, "eth10g");
    }

    #[test]
    fn zero_ranks_per_node_is_an_error_not_a_panic() {
        assert!(Topology::eth_10g().with_ranks_per_node(0).is_err());
        assert!(Topology::by_name("eth10g-x0").is_none());
        assert!(Topology::by_name("eth10g-x0r4").is_none());
        assert!(Topology::by_name("eth10g-x2r0").is_none());
        assert!(Topology::by_name("eth10g-x2r1").is_none());
        assert!(Topology::eth_10g_smp(2).with_rack(1).is_err());
    }

    #[test]
    fn rack_presets_resolve_and_roundtrip() {
        let t = Topology::by_name("eth10g-x8r16").unwrap();
        assert_eq!(t.name, "eth10g-x8r16");
        assert_eq!(t.ranks_per_node(), 8);
        assert_eq!(t.level_sizes(), vec![8, 128]);
        assert!(t.tiers[0].shm && !t.tiers[1].shm);
        // In-rack hops keep the base NIC rate; cross-rack is
        // oversubscribed 4:1 with doubled latency.
        let base = Topology::eth_10g();
        assert_eq!(t.tiers[1].gbps, base.link_gbps);
        assert_eq!(t.link_gbps, base.link_gbps / RACK_OVERSUBSCRIPTION);
        assert_eq!(t.latency_ns, base.latency_ns * 2);
        assert_eq!(Topology::by_name(&t.name).unwrap(), t);
        // Re-suffixing the node size preserves the rack (nodes-per-rack
        // is kept, the absolute rack size rescales) without compounding
        // the spine oversubscription.
        let again = t.clone().with_ranks_per_node(4).unwrap();
        assert_eq!(again.name, "eth10g-x4r16");
        assert_eq!(again.level_sizes(), vec![4, 64]);
        assert_eq!(again.link_gbps, t.link_gbps);
        assert_eq!(Topology::by_name(&again.name).unwrap(), again);
        // A rack with 1 rank per node still resolves.
        let r = Topology::by_name("eth10g-x1r4").unwrap();
        assert_eq!(r.level_sizes(), vec![4]);
        assert!(!r.tiers[0].shm);
        assert_eq!(r.ranks_per_node(), 1);
        // Double-racking is rejected.
        assert!(t.with_rack(4).is_err());
    }

    #[test]
    fn rail_presets_resolve_and_roundtrip() {
        let t = Topology::by_name("eth10g-x8r16e2").unwrap();
        assert_eq!(t.name, "eth10g-x8r16e2");
        assert_eq!(t.rails, 2);
        assert_eq!(t.level_sizes(), vec![8, 128]);
        // Rails live on every NIC tier; the shm tier keeps its single
        // copy channel.
        assert_eq!(t.tiers[0].rails, 1);
        assert_eq!(t.tiers[1].rails, 2);
        assert_eq!(t.rails_at(0), 1);
        assert_eq!(t.rails_at(1), 2);
        assert_eq!(t.rails_at(t.top_level()), 2);
        assert_eq!(t.max_rails(), 2);
        assert_eq!(Topology::by_name(&t.name).unwrap(), t);
        // Flat multi-rail: `-x1e4`.
        let flat = Topology::by_name("eth10g-x1e4").unwrap();
        assert_eq!(flat.name, "eth10g-x1e4");
        assert_eq!(flat.rails, 4);
        assert!(flat.tiers.is_empty());
        assert_eq!(Topology::by_name(&flat.name).unwrap(), flat);
        assert_eq!(
            Topology::eth_10g().with_rails(4).unwrap().name,
            "eth10g-x1e4"
        );
        // e1 normalizes away (re-suffixing replaces, never stacks).
        assert_eq!(flat.with_rails(1).unwrap().name, "eth10g");
        // Builder order does not matter: rails-then-rack == rack-then-rails.
        let a = Topology::eth_10g()
            .with_ranks_per_node(2)
            .unwrap()
            .with_rails(2)
            .unwrap()
            .with_rack(4)
            .unwrap();
        let b = Topology::by_name("eth10g-x2r4e2").unwrap();
        assert_eq!(a, b);
        // Malformed rail suffixes are config errors, not panics — and
        // absurd rail counts are capped (the sim allocates one egress
        // server per rail; `e999999999` must not OOM).
        assert!(Topology::by_name("eth10g-x2e0").is_none());
        assert!(Topology::by_name("eth10g-x2e").is_none());
        assert!(Topology::by_name("eth10g-x2r4e0").is_none());
        assert!(Topology::by_name("eth10g-x2e999999999").is_none());
        assert!(Topology::eth_10g().with_rails(0).is_err());
        assert!(Topology::eth_10g().with_rails(MAX_RAILS + 1).is_err());
        assert!(Topology::eth_10g().with_rails(MAX_RAILS).is_ok());
    }

    /// Regression (preemptive bugfix): rescaling ranks-per-node must
    /// preserve rail counts the same way it preserves the rack tier —
    /// the rails describe the node's physical NIC endpoints, which a
    /// re-described grouping does not change.
    #[test]
    fn rescale_preserves_rail_counts() {
        let t = Topology::by_name("eth10g-x8r16e2").unwrap();
        let again = t.clone().with_ranks_per_node(4).unwrap();
        assert_eq!(again.name, "eth10g-x4r16e2");
        assert_eq!(again.level_sizes(), vec![4, 64]);
        assert_eq!(again.rails, 2);
        assert_eq!(again.tiers[1].rails, 2, "rack tier keeps its rails");
        assert_eq!(Topology::by_name(&again.name).unwrap(), again);
        // Without a rack tier too.
        let flat = Topology::by_name("eth10g-x4e2").unwrap();
        let re = flat.with_ranks_per_node(2).unwrap();
        assert_eq!(re.name, "eth10g-x2e2");
        assert_eq!(re.rails, 2);
        // Down to one rank per node the rails still survive.
        let one = Topology::by_name("eth10g-x4e2")
            .unwrap()
            .with_ranks_per_node(1)
            .unwrap();
        assert_eq!(one.name, "eth10g-x1e2");
        assert_eq!(one.rails, 2);
        assert_eq!(Topology::by_name(&one.name).unwrap(), one);
    }

    #[test]
    fn stripe_count_caps_by_rails_and_chunks() {
        let t = Topology::eth_10g().with_rails(4).unwrap(); // chunk 256 KiB
        let c = t.chunk_bytes;
        let top = t.top_level();
        // Sub-chunk messages ride one rail.
        assert_eq!(t.stripe_count(top, 1), 1);
        assert_eq!(t.stripe_count(top, c - 1), 1);
        // Whole chunks occupy one rail each, capped at the rail count.
        assert_eq!(t.stripe_count(top, c), 1);
        assert_eq!(t.stripe_count(top, 2 * c), 2);
        assert_eq!(t.stripe_count(top, 3 * c), 3);
        assert_eq!(t.stripe_count(top, 100 * c), 4);
        // Single-rail fabrics never stripe.
        assert_eq!(Topology::eth_10g().stripe_count(0, 100 * c), 1);
        // Shm tiers (rails 1) never stripe.
        let smp = Topology::eth_10g_smp(2).with_rails(4).unwrap();
        assert_eq!(smp.stripe_count(0, 100 * c), 1);
        assert_eq!(smp.stripe_count(smp.top_level(), 100 * c), 4);
    }

    #[test]
    fn striped_msg_divides_wire_not_alpha() {
        let t = Topology::eth_10g().with_rails(2).unwrap();
        let top = t.top_level();
        let b = 4 * t.chunk_bytes;
        let single = t.msg_ns_at(top, b);
        let striped = t.striped_msg_ns_at(top, b);
        let fixed = t.overhead_at(top) + t.latency_at(top);
        // Wire time halves; overhead + latency are paid once, undivided.
        assert_eq!(striped, fixed + t.wire_ns(b.div_ceil(2)));
        assert!(striped < single);
        // Sub-chunk and single-rail cases are identical to msg_ns_at.
        assert_eq!(t.striped_msg_ns_at(top, 100), t.msg_ns_at(top, 100));
        let flat = Topology::eth_10g();
        assert_eq!(flat.striped_msg_ns_at(0, b), flat.msg_ns_at(0, b));
    }

    #[test]
    fn tiers_resolve_by_node_grouping() {
        let t = Topology::eth_10g_smp(4);
        assert!(t.is_hierarchical());
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert!(t.same_node(1, 2));
        assert!(!t.same_node(3, 4));
        assert_eq!(t.level_of(0, 1), 0);
        assert_eq!(t.level_of(0, 4), t.top_level());
        // Flat fabrics never resolve to an inner tier.
        let flat = Topology::eth_10g();
        assert!(!flat.same_node(0, 0));
        assert_eq!(flat.level_of(0, 1), flat.top_level());
        assert_eq!(flat.num_levels(), 1);
    }

    #[test]
    fn three_level_hops_price_at_deepest_common_tier() {
        let t = Topology::by_name("eth10g-x2r4").unwrap(); // node=2, rack=8
        assert_eq!(t.num_levels(), 3);
        assert_eq!(t.level_of(0, 1), 0); // same node
        assert_eq!(t.level_of(0, 2), 1); // same rack, different node
        assert_eq!(t.level_of(0, 8), 2); // different rack
        assert!(t.same_node(0, 1));
        assert!(!t.same_node(0, 2));
        let b = 1 << 20;
        // Deeper tiers are strictly cheaper per hop.
        assert!(t.msg_ns_between(0, 1, b) < t.msg_ns_between(0, 2, b));
        assert!(t.msg_ns_between(0, 2, b) < t.msg_ns_between(0, 8, b));
        // In-rack = ToR params; cross-rack = oversubscribed spine.
        assert_eq!(t.msg_ns_between(0, 2, b), t.msg_ns_at(1, b));
        assert_eq!(t.msg_ns_between(0, 8, b), t.msg_ns(b));
    }

    #[test]
    fn intra_hops_are_much_cheaper() {
        let t = Topology::eth_10g_smp(2);
        let b = 1 << 20;
        assert!(t.msg_ns_between(0, 1, b) < t.msg_ns_between(1, 2, b) / 10);
        // Top-tier helpers agree with the legacy flat helpers.
        assert_eq!(t.msg_ns_between(1, 2, b), t.msg_ns(b));
        assert_eq!(t.msg_ns_between(0, 1, b), t.intra_msg_ns(b));
    }

    #[test]
    fn node_alignment_detection() {
        let t = Topology::eth_10g_smp(2);
        assert!(t.ranks_node_aligned(&[0, 1, 2, 3]));
        assert!(t.ranks_node_aligned(&[4, 5]));
        // Scattered WHOLE nodes still count (PR 1 semantics preserved).
        assert!(t.ranks_node_aligned(&[0, 1, 4, 5]));
        assert!(!t.ranks_node_aligned(&[1, 2])); // straddles nodes
        assert!(!t.ranks_node_aligned(&[0, 2, 4, 6])); // strided
        assert!(!t.ranks_node_aligned(&[0, 1, 2])); // partial node
        assert!(!t.ranks_node_aligned(&[]));
        assert!(!Topology::eth_10g().ranks_node_aligned(&[0, 1])); // flat
    }

    #[test]
    fn aligned_tier_depth_counts_decomposable_levels() {
        let t = Topology::by_name("eth10g-x2r4").unwrap(); // node=2, rack=8
        let world16: Vec<usize> = (0..16).collect();
        assert_eq!(t.aligned_tier_depth(&world16), 2);
        // One whole rack, starting at a rack boundary.
        let rack: Vec<usize> = (8..16).collect();
        assert_eq!(t.aligned_tier_depth(&rack), 2);
        // Node-aligned but rack-straddling contiguous run: depth 1.
        let run: Vec<usize> = (4..12).collect();
        assert_eq!(t.aligned_tier_depth(&run), 1);
        // Too short for the rack tier.
        assert_eq!(t.aligned_tier_depth(&[0, 1, 2, 3]), 1);
        // Strided or misaligned: depth 0.
        assert_eq!(t.aligned_tier_depth(&[0, 2, 4, 6]), 0);
        assert_eq!(t.aligned_tier_depth(&[1, 2]), 0);
        assert_eq!(t.aligned_tier_depth(&[]), 0);
        assert_eq!(Topology::eth_10g().aligned_tier_depth(&[0, 1]), 0);
        // Restriction truncates the stack for partially-aligned sets.
        let restricted = t.restrict_tiers(1);
        assert_eq!(restricted.level_sizes(), vec![2]);
        assert_eq!(restricted.link_gbps, t.link_gbps);
    }

    #[test]
    fn chooser_tier_depth_keeps_tiled_or_containing_tiers() {
        let t = Topology::by_name("eth10g-x2r4").unwrap(); // node=2, rack=8
        // Tiled at both levels.
        let world16: Vec<usize> = (0..16).collect();
        assert_eq!(t.chooser_tier_depth(&world16), 2);
        // Too short to tile the rack but contained in one: the rack tier
        // stays usable for pricing.
        assert_eq!(t.chooser_tier_depth(&[0, 1, 2, 3]), 2);
        assert_eq!(t.chooser_tier_depth(&[8, 9, 10, 11]), 2);
        // Node-aligned run STRADDLING a rack boundary without tiling it:
        // the rack tier must be collapsed (its groups are neither tiled
        // nor containing), even though the length happens to fit.
        let straddle: Vec<usize> = (6..12).collect();
        assert_eq!(t.chooser_tier_depth(&straddle), 1);
        // Whole racks starting on a boundary keep everything.
        let rack: Vec<usize> = (8..16).collect();
        assert_eq!(t.chooser_tier_depth(&rack), 2);
        // Strided / empty: nothing.
        assert_eq!(t.chooser_tier_depth(&[0, 2, 4, 6]), 0);
        assert_eq!(t.chooser_tier_depth(&[]), 0);
    }

    #[test]
    fn nic_levels_skip_shared_memory_tiers() {
        // Flat: only the top fabric.
        assert_eq!(Topology::eth_10g().nic_levels(), vec![0]);
        // smp: the shm node tier (level 0) is not flappable.
        assert_eq!(Topology::eth_10g_smp(4).nic_levels(), vec![1]);
        // node(shm) + rack(nic) + spine: levels 1 and 2.
        let t = Topology::by_name("eth10g-x2r4").unwrap();
        assert_eq!(t.nic_levels(), vec![1, 2]);
    }

    #[test]
    fn level_for_group_finds_containing_tier() {
        let t = Topology::by_name("eth10g-x4r8").unwrap(); // node=4, rack=32
        assert_eq!(t.level_for_group(2), 0); // 2 divides 4
        assert_eq!(t.level_for_group(4), 0);
        assert_eq!(t.level_for_group(8), 1); // 8 divides 32 but not 4
        assert_eq!(t.level_for_group(32), 1);
        assert_eq!(t.level_for_group(3), t.top_level()); // 3 divides no tier
        assert_eq!(t.level_for_group(64), t.top_level());
        assert_eq!(Topology::eth_10g().level_for_group(2), 0);
    }

    #[test]
    fn hier_group_sizes_respect_divisibility() {
        let t = Topology::by_name("eth10g-x8r16").unwrap(); // 8, 128
        assert_eq!(t.hier_group_sizes_for(256), vec![8, 128]);
        assert_eq!(t.hier_group_sizes_for(128), vec![8]); // rack == p: excluded
        assert_eq!(t.hier_group_sizes_for(64), vec![8]); // rack ∤ 64
        assert_eq!(t.hier_group_sizes_for(12), vec![]); // 8 ∤ 12
        assert_eq!(Topology::eth_10g().hier_group_sizes_for(64), vec![]);
    }

    #[test]
    fn validate_rejects_broken_stacks() {
        let mut t = Topology::eth_10g();
        assert!(t.validate().is_ok());
        t.tiers = vec![TierSpec::shm_node(1)];
        assert!(t.validate().is_err(), "size < 2");
        t.tiers = vec![TierSpec::shm_node(4), TierSpec::shm_node(6)];
        assert!(t.validate().is_err(), "6 not a multiple of 4");
        t.tiers = vec![TierSpec::shm_node(4), TierSpec::shm_node(4)];
        assert!(t.validate().is_err(), "not strictly increasing");
        t.tiers = vec![
            TierSpec { shm: false, ..TierSpec::shm_node(4) },
            TierSpec::shm_node(8),
        ];
        assert!(t.validate().is_err(), "shm outside a NIC tier");
        t.tiers = (0..5)
            .map(|i| TierSpec::shm_node(2usize.pow(i + 1)))
            .collect();
        assert!(t.validate().is_err(), "too many tiers");
        t.tiers = vec![TierSpec::shm_node(2), TierSpec::shm_node(8)];
        assert!(t.validate().is_ok());
        // Rail invariants: >= 1 everywhere, shm tiers exactly 1.
        t.tiers.clear();
        t.rails = 0;
        assert!(t.validate().is_err(), "top rails must be >= 1");
        t.rails = 2;
        t.tiers = vec![TierSpec { rails: 0, shm: false, ..TierSpec::shm_node(4) }];
        assert!(t.validate().is_err(), "tier rails must be >= 1");
        t.tiers = vec![TierSpec { rails: 2, ..TierSpec::shm_node(4) }];
        assert!(t.validate().is_err(), "shm tiers have no NIC rails");
        t.tiers = vec![TierSpec { rails: 2, shm: false, ..TierSpec::shm_node(4) }];
        assert!(t.validate().is_ok());
    }
}

//! The paper's two public interfaces (Fig. 1 of the paper):
//!
//! * the **Collectives API** — MPI-like, exposed here as
//!   [`Communicator`]: non-blocking allreduce/…/barrier over a rank's
//!   comm core, with priorities and wire dtypes;
//! * the **DL Layer API** — [`Session`] / [`Operation`] /
//!   [`Distribution`]: a framework registers its layers once and the
//!   library *derives* the communication each layer needs for the chosen
//!   parallelism (data / model / hybrid via node groups), "reducing the
//!   hassle of supporting these different scenarios within each framework
//!   explicitly".

pub mod communicator;
pub mod distribution;
pub mod session;

pub use communicator::Communicator;
pub use distribution::Distribution;
pub use session::{CommRequirement, CommScope, OpId, Operation, Phase, Session};

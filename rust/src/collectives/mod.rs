//! Collectives: algorithms, wire formats, priorities, selection.
//!
//! A collective is compiled into one *chunk program per rank*
//! ([`program`]): an ordered list of steps, each an optional send and an
//! optional receive(+reduce) over an element range. The same programs are
//! executed two ways:
//!
//! * **really** — [`exec`] moves actual bytes over the in-process
//!   [`crate::fabric::shm`] fabric (the training path), with low-precision
//!   wire formats from [`quant`];
//! * **symbolically** — [`verify`] checks algebraic correctness (every
//!   rank ends with every rank's contribution exactly once), which is the
//!   proptest invariant; and the [`crate::engine`] *times* them against
//!   the discrete-event fabric.
//!
//! Algorithm choice ([`selector`]) follows the paper's "implements
//! performance critical data path operations in an optimal manner":
//! latency-optimal recursive doubling for small payloads,
//! bandwidth-optimal ring for large ones, halving-doubling in between —
//! for allgather too (ring vs block-doubling). The closed forms here are
//! the *analytic* arm of [`crate::tuner::SelectionPolicy`]; the tuned arm
//! replaces them with crossovers measured by running these same programs
//! through [`simexec`] on the live topology. [`parexec`] runs the same
//! timing workloads over a *partitioned* fleet of simulator shards with
//! conservative-lookahead windows (`--sim-threads`), producing
//! byte-identical results to [`simexec`] while scaling to
//! datacenter-size rank counts — see `docs/ARCHITECTURE.md`.
//!
//! ## Hierarchical (N-level) collectives
//!
//! On tiered fabrics ([`crate::fabric::topology::Topology`] with a
//! non-empty tier stack) a flat algorithm pays the slowest tier's alpha
//! for almost every step. [`Algorithm::Hierarchical`] instead recurses
//! over the tier stack — a [`GroupStack`] of nested group sizes
//! (socket → node → rack …), innermost first:
//!
//! 1. **reduce up** — at each level, a binomial tree onto the group's
//!    leader rank over that level's (faster) links;
//! 2. **top phase** — the existing ring / halving-doubling among the
//!    outermost leaders only (one rank per outermost group on the
//!    slowest wire);
//! 3. **broadcast down** — the mirror binomial trees, outermost first.
//!
//! The step count on the slowest tier drops from `O(p)` to `O(p / g_k)`
//! where `g_k` is the outermost group size; the selector prices every
//! level with the N-level alpha–beta model and picks the best stack depth
//! per message size. Reduce-scatter, allgather and broadcast-from-any-
//! root (leader relay) have hierarchical builders too ([`program`]).

pub mod exec;
pub mod parexec;
pub mod priority;
pub mod program;
pub mod quant;
pub mod selector;
pub mod simexec;
pub mod verify;

pub use priority::PriorityPolicy;
pub use program::{CollectiveKind, Program, Range, RecvStep, SendStep, Step};
pub use quant::WireDtype;
pub use selector::choose_algorithm;

/// Reduction operator applied element-wise during reducing receives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
    Min,
}

impl ReduceOp {
    #[inline]
    pub fn apply(&self, a: f32, b: f32) -> f32 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Max => a.max(b),
            ReduceOp::Min => a.min(b),
        }
    }
}

/// Nested hierarchical group sizes, innermost first — the algorithm-side
/// mirror of a [`crate::fabric::topology::Topology`] tier-stack prefix.
/// Sizes are nondecreasing and each divides the next (so groups nest);
/// at most [`crate::fabric::topology::MAX_TIERS`] levels, which keeps the
/// type `Copy` (and [`Algorithm`] with it) on a fixed-size array.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GroupStack {
    len: u8,
    sizes: [u32; crate::fabric::topology::MAX_TIERS],
}

impl GroupStack {
    /// Validating constructor: 1..=MAX_TIERS sizes, each >= 1,
    /// nondecreasing, each dividing the next.
    pub fn new(groups: &[usize]) -> Option<Self> {
        if groups.is_empty() || groups.len() > crate::fabric::topology::MAX_TIERS {
            return None;
        }
        let mut sizes = [0u32; crate::fabric::topology::MAX_TIERS];
        let mut prev = 1usize;
        for (i, &g) in groups.iter().enumerate() {
            if g < 1 || g < prev || g % prev != 0 || g > u32::MAX as usize {
                return None;
            }
            sizes[i] = g as u32;
            prev = g;
        }
        Some(Self { len: groups.len() as u8, sizes })
    }

    /// Single-level stack (the two-tier `ranks_per_node` case).
    pub fn single(ranks_per_node: usize) -> Option<Self> {
        Self::new(&[ranks_per_node])
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Innermost (smallest) group size.
    pub fn innermost(&self) -> usize {
        self.sizes[0] as usize
    }

    /// Outermost (largest) group size — the leaders of these groups run
    /// the top phase.
    pub fn outermost(&self) -> usize {
        self.sizes[self.len() - 1] as usize
    }

    pub fn to_vec(&self) -> Vec<usize> {
        self.sizes[..self.len()].iter().map(|&s| s as usize).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.sizes[..self.len()].iter().map(|&s| s as usize)
    }
}

impl std::fmt::Display for GroupStack {
    /// `"8"` / `"8x128"` — sizes joined by `x`, innermost first.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, g) in self.iter().enumerate() {
            if i > 0 {
                f.write_str("x")?;
            }
            write!(f, "{g}")?;
        }
        Ok(())
    }
}

/// Collective algorithm family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Pipeline ring: bandwidth-optimal, 2(P−1) steps of n/P elements.
    Ring,
    /// Recursive doubling on the full buffer: log₂P steps of n elements —
    /// latency-optimal for small messages. P must be a power of two.
    RecursiveDoubling,
    /// Rabenseifner reduce-scatter-halving + allgather-doubling:
    /// bandwidth-optimal with log₂P steps. P must be a power of two.
    HalvingDoubling,
    /// N-level hierarchical composition over nested group sizes
    /// (innermost first): binomial reduce onto each group's leader going
    /// up, a flat phase among the outermost leaders, binomial broadcast
    /// coming down. The outermost group size must divide P (contiguous
    /// grouping); nesting divisibility is enforced by [`GroupStack`].
    Hierarchical { groups: GroupStack },
    /// Let the library pick per message size / rank count (the default).
    Auto,
}

impl Algorithm {
    /// Hierarchical over `groups` (innermost first); `None` when the
    /// stack is structurally invalid (see [`GroupStack::new`]).
    pub fn try_hier(groups: &[usize]) -> Option<Algorithm> {
        GroupStack::new(groups).map(|g| Algorithm::Hierarchical { groups: g })
    }

    /// [`Algorithm::try_hier`] that panics on an invalid stack — test and
    /// bench convenience.
    pub fn hier(groups: &[usize]) -> Algorithm {
        Self::try_hier(groups).expect("invalid hierarchical group stack")
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Algorithm::Ring => f.write_str("ring"),
            Algorithm::RecursiveDoubling => f.write_str("rdoubling"),
            Algorithm::HalvingDoubling => f.write_str("halving"),
            // "hier" for the classic two-tier case; deeper stacks show
            // their level count ("hier2" = two nested groups + top).
            Algorithm::Hierarchical { groups } if groups.len() == 1 => f.write_str("hier"),
            Algorithm::Hierarchical { groups } => write!(f, "hier{}", groups.len()),
            Algorithm::Auto => f.write_str("auto"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_stack_validates_nesting() {
        assert!(GroupStack::new(&[]).is_none());
        assert!(GroupStack::new(&[0]).is_none());
        assert!(GroupStack::new(&[2, 3]).is_none(), "3 not a multiple of 2");
        assert!(GroupStack::new(&[8, 4]).is_none(), "decreasing");
        assert!(GroupStack::new(&[2, 4, 8, 16, 32]).is_none(), "too deep");
        let g = GroupStack::new(&[2, 8, 8, 64]).unwrap();
        assert_eq!(g.to_vec(), vec![2, 8, 8, 64]);
        assert_eq!(g.innermost(), 2);
        assert_eq!(g.outermost(), 64);
        assert_eq!(g.len(), 4);
        assert_eq!(GroupStack::single(4).unwrap().to_vec(), vec![4]);
        assert!(GroupStack::single(0).is_none());
    }

    #[test]
    fn group_stack_and_algorithm_display() {
        assert_eq!(GroupStack::new(&[8, 128]).unwrap().to_string(), "8x128");
        assert_eq!(Algorithm::hier(&[4]).to_string(), "hier");
        assert_eq!(Algorithm::hier(&[8, 128]).to_string(), "hier2");
        assert_eq!(Algorithm::try_hier(&[3, 7]), None);
        assert_eq!(Algorithm::Ring.to_string(), "ring");
    }
}

//! Visualize message prioritization: an ASCII Gantt of node 0's compute
//! vs the fabric's exposed communication, with and without priorities,
//! on VGG-16 over 10GbE (the paper's C1 setting).
//!
//! With FIFO (no priorities) the huge fc6/fc7 gradients issued first
//! monopolize the wire and the first conv layers' gradients finish LAST —
//! stalling the next forward pass. With ByLayer priorities the NIC
//! preempts the bulk transfers and the forward pass starts sooner.
//!
//! Run: `cargo run --release --example priority_timeline`

use mlsl::collectives::PriorityPolicy;
use mlsl::engine::{simulate, CommMode, EngineConfig};
use mlsl::fabric::topology::Topology;
use mlsl::models::ModelDesc;
use mlsl::util::cli::Args;
use mlsl::util::stats::fmt_ns;

fn main() {
    let args = Args::parse();
    let model = ModelDesc::by_name(&args.str_or("model", "vgg16")).expect("--model");
    let p = args.usize_or("nodes", 8);

    for (label, policy) in [
        ("FIFO (MPI-like, no priorities)", PriorityPolicy::None),
        ("ByLayer (MLSL prioritization)", PriorityPolicy::ByLayer),
    ] {
        let mut cfg = EngineConfig::new(model.clone(), Topology::eth_10g(), p);
        cfg.mode = CommMode::MlslAsync { comm_cores: 2 };
        cfg.policy = policy;
        cfg.iterations = 2;
        cfg.record_timeline = true;
        let r = simulate(cfg);
        println!("\n=== {label} ===");
        println!(
            "iteration {}   exposed comm {}   NIC preemptions {}",
            fmt_ns(r.iter_ns),
            fmt_ns(r.exposed_comm_ns),
            r.preemptions
        );
        println!("{}", r.timeline.ascii_gantt(110));
        println!("legend: compute row = f<layer>/b<layer>; issue row marks g<layer> gradient issues");
    }
}

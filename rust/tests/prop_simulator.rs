//! Randomized property tests over the discrete-event fabric and the
//! priority/preemption machinery — the coordinator invariants.

use mlsl::fabric::topology::Topology;
use mlsl::fabric::{MsgDesc, NetSim, SimEvent};
use mlsl::util::proptest::{run, Config};

fn test_topo() -> Topology {
    // 8 Gbps = 1 byte/ns; flat (empty tier stack).
    Topology::flat("prop", 8.0, 500, 50, 1 << 20)
}

/// Random message workload.
fn gen_msgs(r: &mut mlsl::util::prng::Prng) -> (usize, Vec<MsgDesc>) {
    let p = 2 + r.usize_below(8);
    let k = 1 + r.usize_below(40);
    let msgs = (0..k)
        .map(|i| {
            let src = r.usize_below(p);
            let mut dst = r.usize_below(p);
            if dst == src {
                dst = (dst + 1) % p;
            }
            MsgDesc {
                src,
                dst,
                bytes: 1 + r.below(100_000),
                priority: r.below(4) as u8,
                tag: i as u64,
            }
        })
        .collect();
    (p, msgs)
}

#[test]
fn prop_all_messages_delivered_exactly_once() {
    run(
        Config { cases: 150, seed: 21 },
        gen_msgs,
        |(p, msgs)| {
            let mut sim = NetSim::new(test_topo(), *p);
            for m in msgs {
                sim.send(m.clone());
            }
            let mut seen = vec![false; msgs.len()];
            while let Some(ev) = sim.next() {
                if let SimEvent::MsgDelivered { msg, .. } = ev {
                    let i = msg.tag as usize;
                    if seen[i] {
                        return Err(format!("msg {i} delivered twice"));
                    }
                    seen[i] = true;
                }
            }
            if !seen.iter().all(|s| *s) {
                return Err("lost messages".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_deterministic_replay() {
    run(
        Config { cases: 60, seed: 22 },
        gen_msgs,
        |(p, msgs)| {
            let run_once = || {
                let mut sim = NetSim::new(test_topo(), *p);
                for m in msgs {
                    sim.send(m.clone());
                }
                sim.drain()
                    .into_iter()
                    .map(|e| format!("{e:?}"))
                    .collect::<Vec<_>>()
            };
            if run_once() != run_once() {
                return Err("nondeterministic".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_delivery_time_lower_bound() {
    // No message arrives earlier than overhead + wire + latency.
    run(
        Config { cases: 100, seed: 23 },
        gen_msgs,
        |(p, msgs)| {
            let topo = test_topo();
            let mut sim = NetSim::new(topo.clone(), *p);
            for m in msgs {
                sim.send(m.clone());
            }
            while let Some(ev) = sim.next() {
                if let SimEvent::MsgDelivered { msg, at } = ev {
                    let min = topo.msg_ns(msg.bytes);
                    if at < min {
                        return Err(format!("msg {} at {at} < minimum {min}", msg.tag));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_priority_order_within_same_source() {
    // From one source NIC: a strictly-higher-priority message posted at
    // t=0 together with lower-priority ones is delivered first.
    run(
        Config { cases: 100, seed: 24 },
        |r| {
            let bulk_count = 1 + r.usize_below(5);
            let sizes: Vec<u64> = (0..bulk_count).map(|_| 10_000 + r.below(100_000)).collect();
            (sizes, 100 + r.below(5_000))
        },
        |(bulk_sizes, urgent_bytes)| {
            let mut sim = NetSim::new(test_topo(), 3);
            for (i, b) in bulk_sizes.iter().enumerate() {
                sim.send(MsgDesc { src: 0, dst: 1, bytes: *b, priority: 5, tag: i as u64 });
            }
            sim.send(MsgDesc { src: 0, dst: 2, bytes: *urgent_bytes, priority: 0, tag: 999 });
            let mut order = Vec::new();
            while let Some(ev) = sim.next() {
                if let SimEvent::MsgDelivered { msg, .. } = ev {
                    order.push(msg.tag);
                }
            }
            if order.first() != Some(&999) {
                return Err(format!("urgent not first: {order:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_preemption_conserves_work() {
    // Total egress busy time must equal the sum of message costs
    // regardless of preemptions (work conservation).
    run(
        Config { cases: 80, seed: 25 },
        gen_msgs,
        |(p, msgs)| {
            let topo = test_topo();
            let mut sim = NetSim::new(topo.clone(), *p);
            for m in msgs {
                sim.send(m.clone());
            }
            sim.drain();
            let total_busy: f64 = (0..*p)
                .map(|n| sim.nic_utilization(n) * sim.now() as f64)
                .sum();
            let expected: f64 = msgs
                .iter()
                .map(|m| (topo.per_msg_overhead_ns + topo.wire_ns(m.bytes)) as f64)
                .sum();
            if (total_busy - expected).abs() > 1.0 + expected * 1e-9 {
                return Err(format!("busy {total_busy} vs cost {expected}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gating_never_loses_messages() {
    run(
        Config { cases: 60, seed: 26 },
        |r| {
            let (p, msgs) = gen_msgs(r);
            let toggles = 1 + r.usize_below(6);
            (p, msgs, toggles)
        },
        |(p, msgs, toggles)| {
            let mut sim = NetSim::new(test_topo(), *p);
            for m in msgs {
                sim.send(m.clone());
            }
            // Interleave gating toggles with event processing.
            let mut delivered = 0usize;
            for i in 0..*toggles {
                sim.set_comm_gated(i % *p, true);
                // Pump a few events (might be none if everything gated).
                for _ in 0..3 {
                    match sim.next() {
                        Some(SimEvent::MsgDelivered { .. }) => delivered += 1,
                        Some(_) => {}
                        None => break,
                    }
                }
                sim.set_comm_gated(i % *p, false);
            }
            while let Some(ev) = sim.next() {
                if matches!(ev, SimEvent::MsgDelivered { .. }) {
                    delivered += 1;
                }
            }
            if delivered != msgs.len() {
                return Err(format!("delivered {delivered} of {}", msgs.len()));
            }
            Ok(())
        },
    );
}

//! **Ablation A2**: large-batch training and the compute/communication
//! ratio.
//!
//! Paper (design §): "the compute to communication ratio is proportional
//! to the mini-batch size ... scaling will be negatively impacted as we
//! strong-scale the mini-batch and the mini-batch per node drops";
//! communication becomes latency-bound with little compute to hide it.
//!
//! Run: `cargo bench --bench a2_large_batch`

mod common;

use common::{cfg, ms};
use mlsl::analytic::{ratio, Parallelism};
use mlsl::engine::{simulate, CommMode};
use mlsl::fabric::topology::Topology;
use mlsl::metrics::print_table;
use mlsl::models::ModelDesc;

fn main() {
    let p = 64;
    let model = ModelDesc::by_name("resnet50").unwrap();
    // Aggregate compute-to-comm ratio over weighted layers (flops/byte).
    let agg_ratio = |batch: usize| -> f64 {
        let (mut fl, mut by) = (0.0f64, 0u64);
        for (_, l) in model.weighted_layers() {
            fl += mlsl::analytic::compute_flops(l, Parallelism::Data, batch);
            by += mlsl::analytic::comm_bytes(l, Parallelism::Data, p, batch);
        }
        fl / by as f64
    };

    let mut rows = Vec::new();
    let mut t_ideal_per_sample: Option<f64> = None;
    for batch in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut c = cfg("resnet50", Topology::omnipath_100g(), p, batch,
                        CommMode::MlslAsync { comm_cores: 2 });
        c.iterations = 3;
        let r = simulate(c);
        let per_sample = r.iter_ns as f64 / batch as f64;
        let ideal = *t_ideal_per_sample.get_or_insert_with(|| {
            // Ideal = pure compute per sample (no comm), from the 64-batch
            // compute model (per-sample compute is batch-independent).
            r.compute_ns as f64 / batch as f64
        });
        let eff = 100.0 * ideal / per_sample;
        let lr = ratio(
            model.weighted_layers().next().unwrap().1,
            Parallelism::Data,
            p,
            batch,
        );
        let _ = lr;
        rows.push(vec![
            batch.to_string(),
            format!("{:.0}", agg_ratio(batch)),
            ms(r.iter_ns),
            ms(r.exposed_comm_ns),
            format!("{eff:.1}%"),
        ]);
    }
    print_table(
        "A2: ResNet-50, 64 nodes, Omnipath — per-node batch sweep",
        &["batch/node", "flops-per-byte (data-par)", "iter ms", "exposed ms", "efficiency"],
        &rows,
    );
    println!("\nexpected shape: ratio grows linearly with batch; efficiency is poor at");
    println!("batch 1-2 (latency-bound comm, no compute to hide it) and approaches 100%");
    println!("at large per-node batch — the paper's motivation for large-batch training.");
}

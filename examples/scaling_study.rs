//! Scaling study: sweep node counts × fabrics × comm modes for any model
//! in the zoo and print the weak-scaling efficiency tables (a
//! generalization of the paper's Fig. 2 workflow).
//!
//! Run: `cargo run --release --example scaling_study -- [--model resnet50]
//!       [--nodes 1,2,4,...,256] [--batch 32]`

use mlsl::collectives::PriorityPolicy;
use mlsl::engine::{simulate, CommMode, EngineConfig};
use mlsl::fabric::topology::Topology;
use mlsl::metrics::print_table;
use mlsl::models::ModelDesc;
use mlsl::util::cli::Args;
use mlsl::util::stats::fmt_ns;

fn main() {
    let args = Args::parse();
    let model_name = args.str_or("model", "resnet50");
    let model = ModelDesc::by_name(&model_name).expect("--model");
    let nodes = args.usize_list_or("nodes", &[1, 2, 4, 8, 16, 32, 64, 128, 256]);
    let batch = args.usize_or("batch", model.default_batch);

    for topo in [Topology::omnipath_100g(), Topology::eth_10g()] {
        for (mode_name, mode) in [
            ("MLSL (overlap+priority)", CommMode::MlslAsync { comm_cores: 2 }),
            ("MPI non-blocking", CommMode::MpiNonBlocking),
            ("bulk-synchronous", CommMode::BulkSync),
        ] {
            let mut rows = Vec::new();
            let mut t1 = None;
            for &p in &nodes {
                let mut cfg = EngineConfig::new(model.clone(), topo.clone(), p);
                cfg.batch = batch;
                cfg.mode = mode;
                cfg.policy = PriorityPolicy::ByLayer;
                let r = simulate(cfg);
                let base = *t1.get_or_insert(r.iter_ns);
                rows.push(vec![
                    p.to_string(),
                    fmt_ns(r.iter_ns),
                    fmt_ns(r.exposed_comm_ns),
                    format!("{:.1}%", 100.0 * base as f64 / r.iter_ns as f64),
                    format!("{:.0}", r.throughput_samples_per_s),
                ]);
            }
            print_table(
                &format!("{model_name} / {} / {mode_name} (batch {batch}/node)", topo.name),
                &["nodes", "iter", "exposed comm", "efficiency", "samples/s"],
                &rows,
            );
        }
    }
}

//! **Claim C2**: the Horovod-interface + MLSL backend reaches >93%
//! scaling efficiency at 64 Xeon nodes, beating out-of-box Horovod-MPI.
//!
//! MLSL mode = async progress (comm cores) + priorities; the two MPI
//! baselines are non-blocking-MPI (no async progress: the wire only moves
//! inside library calls) and bulk-synchronous (one exposed exchange after
//! backprop — Horovod out-of-box without tuned tensor fusion).
//!
//! Run: `cargo bench --bench c2_horovod_tf`

mod common;

use common::{cfg, ms};
use mlsl::collectives::PriorityPolicy;
use mlsl::engine::{simulate, CommMode};
use mlsl::fabric::topology::Topology;
use mlsl::metrics::print_table;

fn main() {
    let p = 64;
    let modes: [(&str, CommMode); 3] = [
        ("Horovod+MLSL (async, priorities)", CommMode::MlslAsync { comm_cores: 2 }),
        ("Horovod+MPI (non-blocking)", CommMode::MpiNonBlocking),
        ("Horovod+MPI (bulk, out-of-box)", CommMode::BulkSync),
    ];
    let mut rows = Vec::new();
    for (name, mode) in modes {
        // T(1) reference must use the same mode (same comm-core tax).
        let mut c1 = cfg("resnet50", Topology::omnipath_100g(), 1, 32, mode);
        c1.policy = PriorityPolicy::ByLayer;
        c1.jitter = 0.03;
        let r1 = simulate(c1);
        let mut c = cfg("resnet50", Topology::omnipath_100g(), p, 32, mode);
        c.policy = PriorityPolicy::ByLayer;
        c.jitter = 0.03;
        c.iterations = 4;
        let r = simulate(c);
        let eff = 100.0 * r1.iter_ns as f64 / r.iter_ns as f64;
        rows.push(vec![
            name.to_string(),
            ms(r.iter_ns),
            ms(r.exposed_comm_ns),
            format!("{eff:.1}%"),
        ]);
    }
    print_table(
        "C2: ResNet-50, 64 nodes, Omnipath, TF/Horovod integration modes",
        &["backend", "iter ms", "exposed ms", "efficiency"],
        &rows,
    );
    println!("\npaper: >93% efficiency at 64 nodes with the MLSL backend; out-of-box");
    println!("Horovod-MPI noticeably lower. Expected: row 1 > 93%, rows 2-3 below it.");
}

"""Fused LayerNorm Pallas kernel: normalize + affine in one VMEM pass.

Grid tiles over rows; the feature axis D stays whole in VMEM (D is a lane
multiple for all presets), so mean/var are lane reductions.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEF_ROWS = 128


def _pick_rows(rows: int, r_total: int) -> int:
    r = min(rows, r_total)
    while r_total % r != 0:
        r -= 1
    return r


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (rows, D)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * g_ref[...].astype(jnp.float32)[None, :] + b_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "rows"))
def layernorm(x, gamma, beta, eps: float = 1e-5, rows: int = DEF_ROWS):
    """LayerNorm over the last axis. x: (..., D); gamma, beta: (D,)."""
    shape = x.shape
    d = shape[-1]
    xr = x.reshape(-1, d)
    r_total = xr.shape[0]
    rb = _pick_rows(rows, r_total)
    out = pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(r_total // rb,),
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_total, d), x.dtype),
        interpret=True,
    )(xr, gamma, beta)
    return out.reshape(shape)

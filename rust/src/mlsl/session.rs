//! The DL Layer API: `Session` + `Operation`.
//!
//! A framework registers each layer ONCE (name, weights, activations,
//! forward position). The session then answers, per layer: which
//! collectives must run, over which communicator scope, in which phase,
//! at what priority, and how large — for whatever [`Distribution`] was
//! chosen. The engine (simulated compute) and the trainer (real PJRT
//! compute) both consume exactly this interface, which is the paper's
//! point: one library, every framework.

use crate::collectives::program::CollectiveKind;
use crate::collectives::{PriorityPolicy, WireDtype};
use crate::models::{LayerKind, ModelDesc};
use crate::Priority;

use super::distribution::Distribution;

pub type OpId = usize;

/// A registered layer (the paper's `Operation` object).
#[derive(Debug, Clone)]
pub struct Operation {
    pub id: OpId,
    pub name: String,
    pub kind: LayerKind,
    /// Learnable elements (f32) — the gradient allreduce size.
    pub weight_elems: usize,
    /// Output activation elements per sample.
    pub act_elems: usize,
    /// Position in the forward pass (0 = first). Drives priority.
    pub fwd_order: usize,
}

/// Which ranks a required collective spans.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommScope {
    /// The data-parallel communicator (across groups; whole world when
    /// group size is 1).
    AcrossGroups,
    /// The model-parallel communicator (within this rank's group).
    WithinGroup,
}

/// When the collective is issued.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// After the layer's forward compute (activation exchange).
    Forward,
    /// After the layer's backward compute (gradient exchange).
    Backward,
}

/// One derived communication requirement.
#[derive(Debug, Clone)]
pub struct CommRequirement {
    pub op_id: OpId,
    pub kind: CollectiveKind,
    pub scope: CommScope,
    pub phase: Phase,
    /// Elements THIS rank contributes/receives.
    pub elems: usize,
    pub priority: Priority,
    /// Blocking requirements stall the pipeline (activation exchanges);
    /// non-blocking ones overlap (gradient allreduces).
    pub blocking: bool,
}

/// The session: distribution + registered operations + runtime knobs.
#[derive(Debug, Clone)]
pub struct Session {
    dist: Distribution,
    ops: Vec<Operation>,
    pub policy: PriorityPolicy,
    pub wire: WireDtype,
}

impl Session {
    pub fn new(dist: Distribution) -> Self {
        Self { dist, ops: Vec::new(), policy: PriorityPolicy::ByLayer, wire: WireDtype::F32 }
    }

    pub fn with_policy(mut self, policy: PriorityPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_wire(mut self, wire: WireDtype) -> Self {
        self.wire = wire;
        self
    }

    pub fn distribution(&self) -> Distribution {
        self.dist
    }

    /// Register a layer; returns its id. Layers must be added in forward
    /// order (enforced).
    pub fn add_operation(
        &mut self,
        name: &str,
        kind: LayerKind,
        weight_elems: usize,
        act_elems: usize,
    ) -> OpId {
        let id = self.ops.len();
        self.ops.push(Operation {
            id,
            name: name.to_string(),
            kind,
            weight_elems,
            act_elems,
            fwd_order: id,
        });
        id
    }

    /// Register every layer of a model descriptor.
    pub fn add_model(&mut self, model: &ModelDesc) -> Vec<OpId> {
        model
            .layers
            .iter()
            .map(|l| self.add_operation(&l.name, l.kind, l.weight_elems, l.out_act_elems))
            .collect()
    }

    pub fn operations(&self) -> &[Operation] {
        &self.ops
    }

    pub fn op(&self, id: OpId) -> &Operation {
        &self.ops[id]
    }

    /// Re-derive the distribution from the analytic model: the best
    /// uniform node-group size for `model` on this world and fabric (the
    /// paper's "choosing the right work partitioning strategy").
    pub fn auto_group(
        &mut self,
        model: &crate::models::ModelDesc,
        topo: &crate::fabric::topology::Topology,
        node: &crate::fabric::topology::NodeSpec,
        batch: usize,
    ) -> usize {
        let (g, _) = crate::analytic::best_group_size(model, topo, node, self.dist.world(), batch);
        self.dist = Distribution::new(self.dist.world(), g);
        g
    }

    /// Gradient priority for an operation under the session policy.
    pub fn gradient_priority(&self, id: OpId) -> Priority {
        self.policy.assign(self.ops[id].fwd_order, self.ops.len())
    }

    /// Derive the communication requirements of operation `id` for one
    /// iteration at `batch` samples per rank.
    pub fn required_comms(&self, id: OpId, batch: usize) -> Vec<CommRequirement> {
        let op = &self.ops[id];
        let g = self.dist.group_size();
        let groups = self.dist.num_groups();
        let mut out = Vec::new();

        // Weight-gradient allreduce across the data-parallel communicator.
        // Under hybrid, each rank owns a 1/g shard of the layer's weights.
        if op.weight_elems > 0 && groups > 1 {
            out.push(CommRequirement {
                op_id: id,
                kind: CollectiveKind::Allreduce,
                scope: CommScope::AcrossGroups,
                phase: Phase::Backward,
                elems: op.weight_elems.div_ceil(g),
                priority: self.gradient_priority(id),
                blocking: false,
            });
        }

        // Model parallelism: activations allgathered within the group in
        // the forward pass, activation-gradients exchanged backward.
        // Prioritized over everything ("activation communication must be
        // prioritized as they may block the next layer's compute").
        if g > 1 && op.act_elems > 0 {
            // The group jointly processes g·batch samples; each member
            // contributes its `batch` worth and gathers the rest.
            for phase in [Phase::Forward, Phase::Backward] {
                out.push(CommRequirement {
                    op_id: id,
                    kind: CollectiveKind::Allgather,
                    scope: CommScope::WithinGroup,
                    phase,
                    elems: op.act_elems * batch * g,
                    priority: 0,
                    blocking: true,
                });
            }
        }
        out
    }

    /// All requirements for a full iteration, in issue order: forward
    /// requirements by layer order, then backward in reverse layer order.
    pub fn iteration_comms(&self, batch: usize) -> Vec<CommRequirement> {
        let mut fwd = Vec::new();
        let mut bwd = Vec::new();
        for op in &self.ops {
            for req in self.required_comms(op.id, batch) {
                match req.phase {
                    Phase::Forward => fwd.push(req),
                    Phase::Backward => bwd.push(req),
                }
            }
        }
        bwd.reverse(); // backprop issues output-side first
        fwd.into_iter().chain(bwd).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelDesc;

    fn resnet_session(world: usize, group: usize) -> Session {
        let mut s = Session::new(Distribution::new(world, group));
        let m = ModelDesc::by_name("resnet50").unwrap();
        s.add_model(&m);
        s
    }

    #[test]
    fn data_parallel_derives_one_allreduce_per_weighted_layer() {
        let s = resnet_session(8, 1);
        let m = ModelDesc::by_name("resnet50").unwrap();
        let weighted = m.weighted_layers().count();
        let reqs = s.iteration_comms(32);
        assert_eq!(reqs.len(), weighted);
        assert!(reqs.iter().all(|r| r.kind == CollectiveKind::Allreduce
            && r.scope == CommScope::AcrossGroups
            && !r.blocking));
    }

    #[test]
    fn backward_comms_issue_in_reverse_layer_order() {
        let s = resnet_session(8, 1);
        let reqs = s.iteration_comms(32);
        // Issue order: LAST layer's gradient first (backprop order)...
        assert!(s.op(reqs[0].op_id).fwd_order > s.op(reqs.last().unwrap().op_id).fwd_order);
        // ...but the FIRST layer's gradient has the most urgent priority.
        let first_req = reqs.iter().min_by_key(|r| s.op(r.op_id).fwd_order).unwrap();
        assert!(reqs.iter().all(|r| first_req.priority <= r.priority));
    }

    #[test]
    fn hybrid_adds_activation_exchanges_and_shards_weights() {
        let s = resnet_session(8, 4);
        let reqs = s.iteration_comms(32);
        let ag: Vec<_> = reqs.iter().filter(|r| r.kind == CollectiveKind::Allgather).collect();
        let ar: Vec<_> = reqs.iter().filter(|r| r.kind == CollectiveKind::Allreduce).collect();
        assert!(!ag.is_empty());
        assert!(ag.iter().all(|r| r.blocking && r.priority == 0 && r.scope == CommScope::WithinGroup));
        // Weight shards are 1/4 of the full gradient.
        let m = ModelDesc::by_name("resnet50").unwrap();
        let (idx, l) = m.weighted_layers().next().unwrap();
        let req = ar.iter().find(|r| r.op_id == idx).unwrap();
        assert_eq!(req.elems, l.weight_elems.div_ceil(4));
    }

    #[test]
    fn pure_model_parallel_has_no_gradient_allreduce() {
        let s = resnet_session(8, 8);
        let reqs = s.iteration_comms(32);
        assert!(reqs.iter().all(|r| r.kind != CollectiveKind::Allreduce));
    }

    #[test]
    fn fifo_policy_flattens_priorities() {
        let mut s = resnet_session(8, 1);
        s.policy = PriorityPolicy::None;
        let reqs = s.iteration_comms(32);
        let p0 = reqs[0].priority;
        assert!(reqs.iter().all(|r| r.priority == p0));
    }
}

//! Selection policy: who decides which algorithm a collective runs —
//! the closed-form model ("model says") or a measured tuning table
//! ("measurement says").
//!
//! Every call site that previously hardcoded
//! [`selector::choose_algorithm`] / [`selector::choose_flat_algorithm`]
//! (the engine, the analytic design-space model, the CLI) now consults a
//! [`SelectionPolicy`]. The analytic policy reproduces the old behaviour
//! exactly; the tuned policies answer from a [`TuningTable`] and are
//! guaranteed to only ever return algorithms that
//! [`crate::collectives::program::build`] accepts at the queried rank
//! count (a legality filter runs before every table pick, because the
//! nearest measured row may prefer an algorithm that does not exist at
//! the actual p).

use crate::collectives::program::CollectiveKind;
use crate::collectives::selector;
use crate::collectives::{Algorithm, WireDtype};
use crate::fabric::topology::Topology;
use crate::trace::Utilization;
use crate::Ns;

use super::table::{Cand, TuningTable};

/// Is `alg` buildable as an allreduce over `p` ranks? Deliberately the
/// BUILDER'S precondition, not the analytic candidate menu: a tuned
/// table may apply a measurement to any rank count the program compiles
/// at (e.g. hierarchical at p == ranks_per_node). Constant-time — this
/// runs per candidate on every tuned choose/predict — and kept in
/// lockstep with [`crate::collectives::program::build`] by the
/// `legality_matches_builder` test.
pub fn allreduce_legal(alg: Algorithm, p: usize) -> bool {
    match alg {
        Algorithm::Ring => true,
        Algorithm::RecursiveDoubling | Algorithm::HalvingDoubling => p.is_power_of_two(),
        // Nesting divisibility is a GroupStack construction invariant;
        // only the outermost group vs p remains to check.
        Algorithm::Hierarchical { groups } => p % groups.outermost() == 0,
        Algorithm::Auto => false,
    }
}

/// Is `alg` a real allgather program over `p` ranks? Ring, recursive
/// doubling and hierarchical have allgather builders; every other
/// algorithm would silently compile to a ring, which a tuned table must
/// not be credited for. Lockstep with `build`: `legality_matches_builder`.
pub fn allgather_legal(alg: Algorithm, p: usize) -> bool {
    match alg {
        Algorithm::Ring => true,
        Algorithm::RecursiveDoubling => p.is_power_of_two(),
        Algorithm::Hierarchical { groups } => p % groups.outermost() == 0,
        _ => false,
    }
}

/// A hierarchical pick from a table must also FIT the live topology's
/// tier stack: every group size has to be one of its tier sizes. The
/// engine hands partially-aligned communicators a topology view
/// truncated to the tiers their members actually tile or fit inside
/// ([`Topology::chooser_tier_depth`]); a table row measured on the full
/// fabric may still prefer a deeper stack (divisibility alone cannot
/// tell), and applying it would run a "rack" phase across a rack
/// boundary the members straddle. Non-hierarchical picks fit anywhere.
fn fits_tiers(alg: Algorithm, topo: &Topology) -> bool {
    match alg {
        Algorithm::Hierarchical { groups } => {
            let sizes = topo.level_sizes();
            groups.iter().all(|g| sizes.contains(&g))
        }
        _ => true,
    }
}

/// Observed fabric congestion, per NIC level, in milli-units of
/// AVAILABLE egress fraction (1000 = quiet; 300 = 70% of the tier's
/// wires busy with other tenants' traffic). Built from the trace
/// layer's windowed utilization ([`Contention::from_utilization`]) and
/// consumed by the `_contended` choosers: a quiet-fabric tuning table is
/// measurably wrong next to a saturating neighbor, so tuned picks are
/// re-ranked by each candidate's *predicted degradation* on a derated
/// topology view instead of being trusted verbatim.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Contention {
    /// Available egress fraction per topology level, milli-units.
    /// Missing levels count as quiet (1000).
    pub avail_milli: Vec<u64>,
}

impl Contention {
    /// A quiet fabric (no correction — the choosers delegate bitwise).
    pub fn quiet() -> Self {
        Self::default()
    }

    /// No level observed under load?
    pub fn is_quiet(&self) -> bool {
        self.avail_milli.iter().all(|&a| a >= 1000)
    }

    /// Available fraction at `level` (milli-units), clamped to
    /// [50, 1000] — even a fully-saturated tier leaves the correction
    /// finite.
    pub fn avail_at(&self, level: usize) -> u64 {
        self.avail_milli.get(level).copied().unwrap_or(1000).clamp(50, 1000)
    }

    /// Mean observed per-level egress utilization over the whole
    /// windowed series, normalized by each level's aggregate wire
    /// capacity (`p × rails_at(level)`). This measures TOTAL load —
    /// including the observer's own traffic — which overstates the
    /// correction slightly; the derate clamp keeps that benign.
    pub fn from_utilization(u: &Utilization, topo: &Topology) -> Self {
        let levels = topo.num_levels();
        let mut busy: Vec<u128> = vec![0; levels];
        let mut span: u128 = 0;
        for w in &u.windows {
            span += (w.end - w.start) as u128;
            for (&level, &ns) in &w.by_level {
                if let Some(b) = busy.get_mut(level) {
                    *b += ns as u128;
                }
            }
        }
        let mut avail_milli = vec![1000u64; levels];
        if span > 0 && u.p > 0 {
            for (level, slot) in avail_milli.iter_mut().enumerate() {
                let wires = (u.p as u128) * topo.rails_at(level).max(1) as u128;
                let used = (busy[level] * 1000 / (span * wires)).min(950) as u64;
                *slot = 1000 - used;
            }
        }
        Self { avail_milli }
    }

    /// A topology view bent to the observed load: each NIC tier's
    /// bandwidth scales by its available fraction AND its per-message
    /// overhead inflates by the expected queueing delay
    /// `u/(1−u) × one chunk's quiet service time` (M/M/1-flavored).
    /// The overhead term is what makes the correction rank-aware: under
    /// saturating same-class neighbors every ROUND of a collective pays
    /// a queueing stall, penalizing round-heavy algorithms — a pure
    /// bandwidth derate would miss that and re-rank the wrong way.
    pub fn derate(&self, topo: &Topology) -> Topology {
        let mut t = topo.clone();
        for level in topo.nic_levels() {
            let avail = self.avail_at(level);
            if avail >= 1000 {
                continue;
            }
            let used = 1000 - avail;
            let gbps = topo.gbps_at(level) * avail as f64 / 1000.0;
            let service =
                topo.overhead_at(level) + crate::fabric::wire_ns(topo.chunk_bytes, topo.gbps_at(level));
            let stall = service.saturating_mul(used) / avail;
            if level < t.tiers.len() {
                t.tiers[level].gbps = gbps;
                t.tiers[level].per_msg_overhead_ns += stall;
            } else {
                t.link_gbps = gbps;
                t.per_msg_overhead_ns += stall;
            }
        }
        t
    }
}

/// How call sites choose collective algorithms.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum SelectionPolicy {
    /// Closed-form two-tier alpha-beta model (the default: no table
    /// supplied).
    #[default]
    Analytic,
    /// Measured table, trusted unconditionally (nearest-cell semantics
    /// even when its fingerprint does not match the live topology);
    /// analytic only when the table has no legal candidate for a query.
    Tuned(TuningTable),
    /// Measured table, consulted ONLY while its fingerprint matches the
    /// live topology; any mismatch falls back to the analytic model
    /// wholesale. This is what `--tuning-table` installs. Note the
    /// engine's partially-aligned communicators query through a
    /// TRUNCATED topology view ([`Topology::restrict_tiers`]) whose
    /// fingerprint never matches a table measured on the full fabric —
    /// they deliberately get the analytic model (the table's cells were
    /// measured on fully-aligned communicators and do not transfer).
    TunedWithFallback(TuningTable),
}

impl SelectionPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SelectionPolicy::Analytic => "analytic",
            SelectionPolicy::Tuned(_) => "tuned",
            SelectionPolicy::TunedWithFallback(_) => "tuned+fallback",
        }
    }

    /// The table to consult for `topo`, if this policy trusts one.
    fn table_for(&self, topo: &Topology) -> Option<&TuningTable> {
        match self {
            SelectionPolicy::Analytic => None,
            SelectionPolicy::Tuned(t) => Some(t),
            SelectionPolicy::TunedWithFallback(t) => {
                if t.matches(topo) {
                    Some(t)
                } else {
                    None
                }
            }
        }
    }

    /// Allreduce over a node-aligned (contiguous whole-node) communicator.
    pub fn choose_allreduce(&self, topo: &Topology, p: usize, bytes: u64) -> Algorithm {
        if p <= 1 {
            return Algorithm::Ring;
        }
        if let Some(t) = self.table_for(topo) {
            let legal = |a: Algorithm| fits_tiers(a, topo) && allreduce_legal(a, p);
            if let Some(alg) = t.lookup(CollectiveKind::Allreduce, p, bytes, &legal) {
                return alg;
            }
        }
        selector::choose_algorithm(topo, p, bytes)
    }

    /// Allreduce over a strided / non-aligned communicator. Tables are
    /// measured on contiguous communicators, where in-tier hops get tier
    /// discounts; a strided group gets none, so the table only applies on
    /// flat fabrics (empty tier stack, where contiguity is irrelevant).
    /// Otherwise the all-top analytic model decides — exactly what a
    /// mis-applied table would mispredict.
    pub fn choose_flat_allreduce(&self, topo: &Topology, p: usize, bytes: u64) -> Algorithm {
        if p <= 1 {
            return Algorithm::Ring;
        }
        if !topo.is_hierarchical() {
            if let Some(t) = self.table_for(topo) {
                let legal = |a: Algorithm| {
                    !matches!(a, Algorithm::Hierarchical { .. }) && allreduce_legal(a, p)
                };
                if let Some(alg) = t.lookup(CollectiveKind::Allreduce, p, bytes, &legal) {
                    return alg;
                }
            }
        }
        selector::choose_flat_algorithm(topo, p, bytes)
    }

    /// Allgather over a node-aligned communicator (the engine's
    /// activation exchanges).
    pub fn choose_allgather(&self, topo: &Topology, p: usize, bytes: u64) -> Algorithm {
        if p <= 1 {
            return Algorithm::Ring;
        }
        if let Some(t) = self.table_for(topo) {
            let legal = |a: Algorithm| fits_tiers(a, topo) && allgather_legal(a, p);
            if let Some(alg) = t.lookup(CollectiveKind::Allgather, p, bytes, &legal) {
                return alg;
            }
        }
        selector::choose_allgather_algorithm(topo, p, bytes)
    }

    /// Allgather over a non-aligned communicator (see
    /// [`Self::choose_flat_allreduce`] for the gating rationale).
    pub fn choose_flat_allgather(&self, topo: &Topology, p: usize, bytes: u64) -> Algorithm {
        if p <= 1 {
            return Algorithm::Ring;
        }
        if !topo.is_hierarchical() {
            if let Some(t) = self.table_for(topo) {
                let legal = |a: Algorithm| {
                    !matches!(a, Algorithm::Hierarchical { .. }) && allgather_legal(a, p)
                };
                if let Some(alg) = t.lookup(CollectiveKind::Allgather, p, bytes, &legal) {
                    return alg;
                }
            }
        }
        selector::choose_flat_allgather_algorithm(topo, p, bytes)
    }

    /// One-stop choice for an arbitrary member list (the engine's path,
    /// including post-churn survivor sets): node-aligned contiguous
    /// groups get the hierarchical chooser over a topology view
    /// truncated to the tiers the members actually tile
    /// ([`Topology::chooser_tier_depth`]); anything strided or
    /// non-contiguous — which elastic departures routinely produce —
    /// gets the flat chooser. Centralising this gate here means churned
    /// communicators and healthy ones choose through the same code.
    pub fn choose_for_members(
        &self,
        topo: &Topology,
        members: &[crate::Rank],
        kind: CollectiveKind,
        bytes: u64,
    ) -> Algorithm {
        let p = members.len();
        let depth = topo.aligned_tier_depth(members);
        let usable = topo.chooser_tier_depth(members);
        let restricted;
        let view = if usable >= topo.tiers.len() {
            topo
        } else {
            restricted = topo.restrict_tiers(usable);
            &restricted
        };
        match (kind, depth > 0) {
            (CollectiveKind::Allreduce, true) => self.choose_allreduce(view, p, bytes),
            (CollectiveKind::Allreduce, false) => self.choose_flat_allreduce(topo, p, bytes),
            (_, true) => self.choose_allgather(view, p, bytes),
            (_, false) => self.choose_flat_allgather(topo, p, bytes),
        }
    }

    /// Predicted allreduce time under this policy: tuned policies answer
    /// from measured (log-interpolated) cells when they can, the analytic
    /// policy from the closed-form model — so design-space analyses built
    /// on this prediction calibrate to measurements once a table exists.
    pub fn predict_allreduce_ns(&self, topo: &Topology, p: usize, bytes: u64) -> Ns {
        if p <= 1 {
            return 0;
        }
        // One interpolation pass serves both the pick and its time (this
        // sits in the analytic design-space loops, per layer × group).
        if let Some(t) = self.table_for(topo) {
            let cheapest_legal = t
                .interpolated(CollectiveKind::Allreduce, p, bytes)
                .unwrap_or_default()
                .into_iter()
                .filter(|(a, _)| fits_tiers(*a, topo) && allreduce_legal(*a, p))
                .min_by(|x, y| x.1.partial_cmp(&y.1).expect("measured times are finite"));
            if let Some((_, ns)) = cheapest_legal {
                return ns.ceil() as Ns;
            }
        }
        let alg = selector::choose_algorithm(topo, p, bytes);
        selector::predict_allreduce_ns(topo, alg, p, bytes)
    }

    // -----------------------------------------------------------------
    // Wire precision: (algorithm × wire dtype) choices
    // -----------------------------------------------------------------

    /// Allreduce over a node-aligned communicator, choosing from the
    /// (algorithm × wire dtype) grid. `wires` is the precision menu
    /// ([`WireDtype::ALL`] for `--wire-dtype auto`, a single element for
    /// a pinned precision); `slowdown_milli` is the worst endpoint chaos
    /// compute-slowdown the quantize charge must assume (1000 = healthy).
    /// Tuned policies answer from measured candidate columns; the
    /// analytic model decides otherwise. A `[F32]` menu reproduces
    /// [`Self::choose_allreduce`] exactly.
    pub fn choose_allreduce_wire(
        &self,
        topo: &Topology,
        p: usize,
        bytes: u64,
        wires: &[WireDtype],
        slowdown_milli: u64,
    ) -> (Algorithm, WireDtype) {
        if p <= 1 {
            return (Algorithm::Ring, wires.first().copied().unwrap_or_default());
        }
        if let Some(t) = self.table_for(topo) {
            let legal = |(a, w): Cand| {
                wires.contains(&w) && fits_tiers(a, topo) && allreduce_legal(a, p)
            };
            if let Some(cand) = t.lookup_cand(CollectiveKind::Allreduce, p, bytes, &legal) {
                return cand;
            }
        }
        selector::choose_algorithm_wire(topo, p, bytes, wires, slowdown_milli)
    }

    /// Allreduce over a strided / non-aligned communicator with the
    /// precision menu (table on flat fabrics only — see
    /// [`Self::choose_flat_allreduce`]).
    pub fn choose_flat_allreduce_wire(
        &self,
        topo: &Topology,
        p: usize,
        bytes: u64,
        wires: &[WireDtype],
        slowdown_milli: u64,
    ) -> (Algorithm, WireDtype) {
        if p <= 1 {
            return (Algorithm::Ring, wires.first().copied().unwrap_or_default());
        }
        if !topo.is_hierarchical() {
            if let Some(t) = self.table_for(topo) {
                let legal = |(a, w): Cand| {
                    wires.contains(&w)
                        && !matches!(a, Algorithm::Hierarchical { .. })
                        && allreduce_legal(a, p)
                };
                if let Some(cand) = t.lookup_cand(CollectiveKind::Allreduce, p, bytes, &legal) {
                    return cand;
                }
            }
        }
        selector::choose_flat_algorithm_wire(topo, p, bytes, wires, slowdown_milli)
    }

    /// [`Self::choose_for_members`] over the (algorithm × wire dtype)
    /// grid. Only reductions are error-feedback-protected, so only
    /// allreduce consults the precision menu; every other kind keeps its
    /// algorithm choice and the f32 wire.
    pub fn choose_for_members_wire(
        &self,
        topo: &Topology,
        members: &[crate::Rank],
        kind: CollectiveKind,
        bytes: u64,
        wires: &[WireDtype],
        slowdown_milli: u64,
    ) -> (Algorithm, WireDtype) {
        if kind != CollectiveKind::Allreduce {
            return (self.choose_for_members(topo, members, kind, bytes), WireDtype::F32);
        }
        let p = members.len();
        let depth = topo.aligned_tier_depth(members);
        let usable = topo.chooser_tier_depth(members);
        let restricted;
        let view = if usable >= topo.tiers.len() {
            topo
        } else {
            restricted = topo.restrict_tiers(usable);
            &restricted
        };
        if depth > 0 {
            self.choose_allreduce_wire(view, p, bytes, wires, slowdown_milli)
        } else {
            self.choose_flat_allreduce_wire(topo, p, bytes, wires, slowdown_milli)
        }
    }

    /// [`Self::choose_for_members_wire`] with an observed-contention
    /// correction. `None` (or a quiet [`Contention`]) delegates to the
    /// plain chooser BITWISE — single-tenant runs cannot drift. Under
    /// load, tuned policies re-rank their measured quiet-fabric cells by
    /// each candidate's analytically-predicted degradation on the
    /// derated topology (measured × derated/quiet ratio), and the
    /// analytic policy simply chooses on the derated fabric.
    #[allow(clippy::too_many_arguments)]
    pub fn choose_for_members_wire_contended(
        &self,
        topo: &Topology,
        members: &[crate::Rank],
        kind: CollectiveKind,
        bytes: u64,
        wires: &[WireDtype],
        slowdown_milli: u64,
        contention: Option<&Contention>,
    ) -> (Algorithm, WireDtype) {
        let Some(c) = contention.filter(|c| !c.is_quiet()) else {
            return self.choose_for_members_wire(topo, members, kind, bytes, wires, slowdown_milli);
        };
        if kind != CollectiveKind::Allreduce {
            return (self.choose_for_members(topo, members, kind, bytes), WireDtype::F32);
        }
        let p = members.len();
        if p <= 1 {
            return (Algorithm::Ring, wires.first().copied().unwrap_or_default());
        }
        let derated = c.derate(topo);
        let depth = topo.aligned_tier_depth(members);
        let usable = topo.chooser_tier_depth(members);
        // Quiet and derated views share the tier structure, so the
        // alignment gate resolves identically on both.
        let (restricted_q, restricted_d);
        let (qview, dview) = if usable >= topo.tiers.len() {
            (topo, &derated)
        } else {
            restricted_q = topo.restrict_tiers(usable);
            restricted_d = derated.restrict_tiers(usable);
            (&restricted_q, &restricted_d)
        };
        if depth > 0 {
            self.choose_allreduce_wire_contended(qview, dview, p, bytes, wires, slowdown_milli)
        } else {
            selector::choose_flat_algorithm_wire(&derated, p, bytes, wires, slowdown_milli)
        }
    }

    /// Aligned-communicator allreduce pick under contention: table cells
    /// (measured on the QUIET fabric) are re-ranked by the analytic
    /// quiet→derated time ratio of each candidate, so a measured winner
    /// whose advantage evaporates under per-round queueing stalls loses
    /// to a candidate that degrades less. Falls back to choosing
    /// analytically on the derated fabric when no table cell applies.
    fn choose_allreduce_wire_contended(
        &self,
        quiet: &Topology,
        derated: &Topology,
        p: usize,
        bytes: u64,
        wires: &[WireDtype],
        slowdown_milli: u64,
    ) -> (Algorithm, WireDtype) {
        if let Some(t) = self.table_for(quiet) {
            let reranked = t
                .interpolated_cand(CollectiveKind::Allreduce, p, bytes)
                .unwrap_or_default()
                .into_iter()
                .filter(|((a, w), _)| {
                    wires.contains(w) && fits_tiers(*a, quiet) && allreduce_legal(*a, p)
                })
                .map(|((a, w), measured)| {
                    let q = selector::predict_allreduce_ns_wire(quiet, a, p, bytes, w, slowdown_milli)
                        .max(1);
                    let d =
                        selector::predict_allreduce_ns_wire(derated, a, p, bytes, w, slowdown_milli);
                    ((a, w), measured * d as f64 / q as f64)
                })
                .min_by(|x, y| x.1.partial_cmp(&y.1).expect("predicted times are finite"));
            if let Some((cand, _)) = reranked {
                return cand;
            }
        }
        selector::choose_algorithm_wire(derated, p, bytes, wires, slowdown_milli)
    }

    /// Wire-precision-aware [`Self::predict_allreduce_ns`]: the predicted
    /// time of the best (algorithm, wire) pick offered by `wires`.
    pub fn predict_allreduce_ns_wire(
        &self,
        topo: &Topology,
        p: usize,
        bytes: u64,
        wires: &[WireDtype],
        slowdown_milli: u64,
    ) -> Ns {
        if p <= 1 {
            return 0;
        }
        if let Some(t) = self.table_for(topo) {
            let cheapest_legal = t
                .interpolated_cand(CollectiveKind::Allreduce, p, bytes)
                .unwrap_or_default()
                .into_iter()
                .filter(|((a, w), _)| {
                    wires.contains(w) && fits_tiers(*a, topo) && allreduce_legal(*a, p)
                })
                .min_by(|x, y| x.1.partial_cmp(&y.1).expect("measured times are finite"));
            if let Some((_, ns)) = cheapest_legal {
                return ns.ceil() as Ns;
            }
        }
        let (alg, wire) = selector::choose_algorithm_wire(topo, p, bytes, wires, slowdown_milli);
        selector::predict_allreduce_ns_wire(topo, alg, p, bytes, wire, slowdown_milli)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::probe::{tune, ProbeSpec};

    #[test]
    fn legality_matches_builder() {
        // The constant-time legality checks must agree with the builder's
        // own validation everywhere the policy can query them (p >= 1;
        // the policy short-circuits p <= 1 before filtering). For
        // allgather only ring/rdoubling count: `build` compiles anything
        // else to a ring fallback, which legality deliberately rejects.
        use crate::collectives::program::build;
        let stacks: [&[usize]; 10] =
            [&[1], &[2], &[3], &[4], &[5], &[8], &[2, 4], &[2, 8], &[3, 6], &[2, 4, 8]];
        for p in 1..=64usize {
            let mut algs = vec![
                Algorithm::Ring,
                Algorithm::RecursiveDoubling,
                Algorithm::HalvingDoubling,
                Algorithm::Auto,
            ];
            for stack in stacks {
                algs.push(Algorithm::hier(stack));
            }
            for alg in &algs {
                assert_eq!(
                    allreduce_legal(*alg, p),
                    build(CollectiveKind::Allreduce, *alg, p, 1).is_ok(),
                    "allreduce {alg:?} p={p}"
                );
            }
            for alg in algs.iter().filter(|a| **a != Algorithm::Auto) {
                // Auto compiles to a ring for allgather (not an error), so
                // the legality check deliberately excludes it.
                if *alg == Algorithm::HalvingDoubling {
                    continue; // same: silently compiles to a ring
                }
                assert_eq!(
                    allgather_legal(*alg, p),
                    build(CollectiveKind::Allgather, *alg, p, 1).is_ok(),
                    "allgather {alg:?} p={p}"
                );
            }
        }
    }

    #[test]
    fn analytic_policy_reproduces_selector_choices() {
        let policy = SelectionPolicy::default();
        assert_eq!(policy.name(), "analytic");
        for topo in [Topology::eth_10g(), Topology::eth_10g_smp(2)] {
            for p in [2usize, 6, 16, 64] {
                for bytes in [1u64 << 10, 1 << 20, 64 << 20] {
                    assert_eq!(
                        policy.choose_allreduce(&topo, p, bytes),
                        selector::choose_algorithm(&topo, p, bytes)
                    );
                    assert_eq!(
                        policy.choose_flat_allreduce(&topo, p, bytes),
                        selector::choose_flat_algorithm(&topo, p, bytes)
                    );
                    assert_eq!(
                        policy.choose_allgather(&topo, p, bytes),
                        selector::choose_allgather_algorithm(&topo, p, bytes)
                    );
                }
            }
        }
    }

    #[test]
    fn tuned_policy_answers_from_the_table_on_grid_cells() {
        let topo = Topology::eth_10g();
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let table = tune(&topo, &spec);
        let policy = SelectionPolicy::TunedWithFallback(table.clone());
        for kind in crate::tuner::probe::TUNED_KINDS {
            for cell in table.cells(kind) {
                let pick = match kind {
                    CollectiveKind::Allreduce => {
                        policy.choose_allreduce(&topo, cell.ranks, cell.bytes)
                    }
                    _ => policy.choose_allgather(&topo, cell.ranks, cell.bytes),
                };
                assert_eq!(pick, cell.best().unwrap().0, "{kind:?} p={}", cell.ranks);
            }
        }
    }

    #[test]
    fn table_picks_never_exceed_the_live_tier_stack() {
        use crate::tuner::table::MeasuredCell;
        // A strict Tuned table (trusted regardless of fingerprint) claims
        // the 3-level stack wins a cell. Queried through a topology view
        // that lacks the rack tier — what the engine hands rack-straddling
        // communicators — the pick must be filtered out, not applied.
        let full = Topology::by_name("eth10g-x2r4").unwrap();
        let three = Algorithm::hier(&[2, 8]);
        let mut table = crate::tuner::TuningTable::for_topology(&full);
        table.insert(
            CollectiveKind::Allreduce,
            MeasuredCell::new(16, 1 << 20, vec![(Algorithm::Ring, 99_999), (three, 10)]),
        );
        let policy = SelectionPolicy::Tuned(table);
        // On the full fabric the measured 3-level winner applies…
        assert_eq!(policy.choose_allreduce(&full, 16, 1 << 20), three);
        // …but on the node-only restricted view it must not: the members
        // behind that view straddle a rack boundary.
        let restricted = full.restrict_tiers(1);
        let pick = policy.choose_allreduce(&restricted, 16, 1 << 20);
        assert_ne!(pick, three, "{pick:?}");
    }

    #[test]
    fn strided_groups_on_smp_fabrics_stay_analytic() {
        let topo = Topology::eth_10g_smp(2);
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let policy = SelectionPolicy::Tuned(tune(&topo, &spec));
        for p in [4usize, 6, 8] {
            for bytes in [1u64 << 10, 1 << 20] {
                assert_eq!(
                    policy.choose_flat_allreduce(&topo, p, bytes),
                    selector::choose_flat_algorithm(&topo, p, bytes),
                    "p={p} bytes={bytes}"
                );
            }
        }
    }

    #[test]
    fn choose_for_members_gates_on_alignment() {
        let topo = Topology::by_name("eth10g-x2e2").unwrap();
        let policy = SelectionPolicy::default();
        let bytes = 1u64 << 20;
        // Whole-node contiguous members: hierarchical chooser on the
        // (here untruncated) tier view.
        let aligned: Vec<usize> = (0..8).collect();
        assert_eq!(
            policy.choose_for_members(&topo, &aligned, CollectiveKind::Allreduce, bytes),
            policy.choose_allreduce(&topo, 8, bytes)
        );
        assert_eq!(
            policy.choose_for_members(&topo, &aligned, CollectiveKind::Allgather, bytes),
            policy.choose_allgather(&topo, 8, bytes)
        );
        // A post-churn survivor set with a hole is non-contiguous: the
        // flat chooser decides (no tier discounts apply to it).
        let holed: Vec<usize> = vec![0, 1, 2, 4, 5, 6, 7];
        assert_eq!(topo.aligned_tier_depth(&holed), 0);
        assert_eq!(
            policy.choose_for_members(&topo, &holed, CollectiveKind::Allreduce, bytes),
            policy.choose_flat_allreduce(&topo, 7, bytes)
        );
    }

    #[test]
    fn wire_choices_reduce_to_plain_choices_on_an_f32_menu() {
        let topo = Topology::eth_10g_smp(2);
        let f32_only = [WireDtype::F32];
        let policy = SelectionPolicy::default();
        for p in [2usize, 6, 8, 16] {
            for bytes in [1u64 << 10, 1 << 20, 16 << 20] {
                assert_eq!(
                    policy.choose_allreduce_wire(&topo, p, bytes, &f32_only, 1000),
                    (policy.choose_allreduce(&topo, p, bytes), WireDtype::F32)
                );
                assert_eq!(
                    policy.choose_flat_allreduce_wire(&topo, p, bytes, &f32_only, 1000),
                    (policy.choose_flat_allreduce(&topo, p, bytes), WireDtype::F32)
                );
                assert_eq!(
                    policy.predict_allreduce_ns_wire(&topo, p, bytes, &f32_only, 1000),
                    policy.predict_allreduce_ns(&topo, p, bytes)
                );
            }
        }
    }

    #[test]
    fn tuned_wire_policy_answers_candidates_from_the_table() {
        let topo = Topology::eth_10g();
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let table = tune(&topo, &spec);
        let policy = SelectionPolicy::TunedWithFallback(table.clone());
        for cell in table.cells(CollectiveKind::Allreduce) {
            // Full menu: the pick is the cell's measured best candidate.
            let pick =
                policy.choose_allreduce_wire(&topo, cell.ranks, cell.bytes, &WireDtype::ALL, 1000);
            assert_eq!(pick, cell.best_cand().unwrap().0, "p={}", cell.ranks);
            // f32-pinned menu: the pick is the f32-restricted best — the
            // same answer the algorithm-only tuned policy gives.
            let f32_menu = [WireDtype::F32];
            let (alg, wire) =
                policy.choose_allreduce_wire(&topo, cell.ranks, cell.bytes, &f32_menu, 1000);
            assert_eq!(wire, WireDtype::F32);
            assert_eq!(alg, cell.best().unwrap().0, "p={}", cell.ranks);
        }
        // The bulk cells' tuned winner is compressed on 10GbE.
        let bulk = table
            .cells(CollectiveKind::Allreduce)
            .iter()
            .map(|c| policy.choose_allreduce_wire(&topo, c.ranks, c.bytes, &WireDtype::ALL, 1000))
            .any(|(_, w)| w != WireDtype::F32);
        assert!(bulk, "no compressed winner anywhere on the quick grid");
        // choose_for_members_wire keeps non-reductions on the f32 wire.
        let members: Vec<usize> = (0..8).collect();
        let (_, w) = policy.choose_for_members_wire(
            &topo,
            &members,
            CollectiveKind::Allgather,
            1 << 20,
            &WireDtype::ALL,
            1000,
        );
        assert_eq!(w, WireDtype::F32);
    }

    #[test]
    fn quiet_contention_delegates_to_the_plain_chooser_bitwise() {
        let topo = Topology::eth_10g_smp(2);
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let policy = SelectionPolicy::Tuned(tune(&topo, &spec));
        let members: Vec<usize> = (0..8).collect();
        for bytes in [1u64 << 10, 1 << 20, 16 << 20] {
            let plain =
                policy.choose_for_members_wire(&topo, &members, CollectiveKind::Allreduce, bytes, &WireDtype::ALL, 1000);
            for c in [None, Some(Contention::quiet())] {
                assert_eq!(
                    policy.choose_for_members_wire_contended(
                        &topo,
                        &members,
                        CollectiveKind::Allreduce,
                        bytes,
                        &WireDtype::ALL,
                        1000,
                        c.as_ref(),
                    ),
                    plain,
                    "bytes={bytes}"
                );
            }
        }
    }

    #[test]
    fn contention_derates_bandwidth_and_inflates_overhead() {
        let topo = Topology::eth_10g();
        let c = Contention { avail_milli: vec![250] }; // top tier 75% busy
        assert!(!c.is_quiet());
        let d = c.derate(&topo);
        assert!((d.link_gbps - topo.link_gbps * 0.25).abs() < 1e-9);
        assert!(d.per_msg_overhead_ns > topo.per_msg_overhead_ns, "queueing stall term");
        // A quiet contention leaves the topology untouched.
        let q = Contention::quiet().derate(&topo);
        assert_eq!(q.link_gbps, topo.link_gbps);
        assert_eq!(q.per_msg_overhead_ns, topo.per_msg_overhead_ns);
        // Saturation clamps: avail never below 5%.
        let full = Contention { avail_milli: vec![0] };
        assert_eq!(full.avail_at(0), 50);
        assert!(full.derate(&topo).link_gbps > 0.0);
    }

    #[test]
    fn contention_from_utilization_reads_per_level_busy_fractions() {
        use crate::trace::UtilWindow;
        let topo = Topology::eth_10g(); // flat: level 0, 1 rail
        // Hand-built series: one 1000 ns window, level 0 busy 800 of the
        // 1000 × p(=1) wire-ns capacity.
        let mut w = UtilWindow { start: 0, end: 1_000, rail_busy: vec![800], ..Default::default() };
        w.by_level.insert(0, 800);
        let u = Utilization { window_ns: 1_000, p: 1, rails: 1, windows: vec![w] };
        let c = Contention::from_utilization(&u, &topo);
        assert_eq!(c.avail_milli, vec![200]);
        assert!(!c.is_quiet());
        // An empty series is quiet.
        let empty = Utilization { window_ns: 1_000, p: 1, rails: 1, windows: vec![] };
        assert!(Contention::from_utilization(&empty, &topo).is_quiet());
    }

    #[test]
    fn analytic_contended_pick_equals_choosing_on_the_derated_fabric() {
        let policy = SelectionPolicy::default();
        let c = Contention { avail_milli: vec![100, 100, 100] };
        for topo in [Topology::eth_10g(), Topology::by_name("eth10g-x2").unwrap()] {
            let derated = c.derate(&topo);
            let members: Vec<usize> = (0..8).collect();
            for bytes in [1u64 << 12, 1 << 20, 16 << 20] {
                let contended = policy.choose_for_members_wire_contended(
                    &topo,
                    &members,
                    CollectiveKind::Allreduce,
                    bytes,
                    &WireDtype::ALL,
                    1000,
                    Some(&c),
                );
                let on_derated = policy.choose_for_members_wire(
                    &derated,
                    &members,
                    CollectiveKind::Allreduce,
                    bytes,
                    &WireDtype::ALL,
                    1000,
                );
                assert_eq!(contended, on_derated, "{} bytes={bytes}", topo.name);
            }
        }
    }

    #[test]
    fn contention_reranks_a_near_tied_table_toward_fewer_rounds() {
        use crate::tuner::table::MeasuredCell;
        // Quiet measurements: ring narrowly beats recursive doubling at
        // 1 MiB over p=8 on 10GbE. Under a 95%-busy spine every round
        // pays a queueing stall, and ring runs ~4.7× the rounds — the
        // re-ranked pick must flip to the round-light candidate.
        let topo = Topology::eth_10g();
        let mut table = crate::tuner::TuningTable::for_topology(&topo);
        table.insert(
            CollectiveKind::Allreduce,
            MeasuredCell::new(
                8,
                1 << 20,
                vec![(Algorithm::Ring, 100_000), (Algorithm::RecursiveDoubling, 110_000)],
            ),
        );
        let policy = SelectionPolicy::Tuned(table);
        let members: Vec<usize> = (0..8).collect();
        let quiet_pick = policy.choose_for_members_wire(
            &topo,
            &members,
            CollectiveKind::Allreduce,
            1 << 20,
            &[WireDtype::F32],
            1000,
        );
        assert_eq!(quiet_pick.0, Algorithm::Ring, "quiet table prefers ring");
        let c = Contention { avail_milli: vec![50] };
        let contended_pick = policy.choose_for_members_wire_contended(
            &topo,
            &members,
            CollectiveKind::Allreduce,
            1 << 20,
            &[WireDtype::F32],
            1000,
            Some(&c),
        );
        assert_eq!(
            contended_pick.0,
            Algorithm::RecursiveDoubling,
            "re-rank must favor the round-light algorithm under saturation"
        );
    }

    #[test]
    fn tuned_prediction_matches_measurement_on_grid_cells() {
        let topo = Topology::eth_10g();
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let table = tune(&topo, &spec);
        let policy = SelectionPolicy::Tuned(table.clone());
        for cell in table.cells(CollectiveKind::Allreduce) {
            let (_, best_ns) = cell.best().unwrap();
            assert_eq!(
                policy.predict_allreduce_ns(&topo, cell.ranks, cell.bytes),
                best_ns,
                "p={} bytes={}",
                cell.ranks,
                cell.bytes
            );
        }
    }
}

//! **Ablation A1**: hybrid parallelism node-group sweep.
//!
//! Paper (design §): "data and model parallelism [are] two extreme design
//! points of hybrid parallelism with node group size being one and all
//! nodes respectively". For fc-heavy models (VGG-16) at small batch the
//! optimum is an intermediate group size: groups shrink the enormous
//! weight-gradient allreduce (weights sharded 1/g, data-parallel width
//! P/g) at the cost of within-group activation exchanges.
//!
//! Run: `cargo bench --bench a1_hybrid_parallelism`

mod common;

use common::{cfg, ms};
use mlsl::engine::{simulate, CommMode};
use mlsl::fabric::topology::Topology;
use mlsl::metrics::print_table;
use mlsl::mlsl::Distribution;

fn main() {
    let p = 64;
    for (model, batch) in [("vgg16", 4usize), ("resnet50", 4), ("alexnet", 4)] {
        let mut rows = Vec::new();
        let mut best: Option<(usize, u64)> = None;
        for group in [1usize, 2, 4, 8, 16, 32, 64] {
            let mut c = cfg(model, Topology::eth_25g(), p, batch,
                            CommMode::MlslAsync { comm_cores: 2 });
            c.dist = Distribution::new(p, group);
            c.iterations = 2;
            let r = simulate(c);
            // Samples/s uses the GLOBAL batch = batch * num_groups, so
            // bigger groups process fewer samples per iteration — compare
            // throughput, not iteration time.
            let tput = r.throughput_samples_per_s;
            if best.map_or(true, |(_, t)| (tput as u64) > t) {
                best = Some((group, tput as u64));
            }
            rows.push(vec![
                group.to_string(),
                (p / group).to_string(),
                ms(r.iter_ns),
                ms(r.exposed_comm_ns),
                format!("{tput:.0}"),
            ]);
        }
        print_table(
            &format!("A1: {model}, {p} nodes, 25GbE, batch {batch}/node — node-group sweep"),
            &["group size", "data-parallel width", "iter ms", "exposed ms", "samples/s"],
            &rows,
        );
        if let Some((g, _)) = best {
            println!("  best group size for {model}: {g}");
        }
    }
    println!("\nexpected shape: fc-heavy models (vgg16, alexnet) peak at group > 1 at");
    println!("small batch; conv-dominated resnet50 prefers pure data parallelism (group 1).");
}

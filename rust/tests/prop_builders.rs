//! Property-test harness over EVERY program builder in
//! `collectives::program`, driven through the symbolic executor
//! (`collectives::verify`): randomized rank counts (p ∈ {2..17},
//! including non-powers-of-two wherever the builder supports them),
//! element counts, roots, owner shifts and node groupings.
//!
//! Two invariant families per builder:
//!
//! * **bitwise correctness** — the symbolic contribution matrices end
//!   exactly right (every rank's initial value counted exactly once where
//!   the collective's semantics demand it);
//! * **cost accounting** — per-rank step counts and TOTAL on-wire element
//!   counts match the analytic formulas exactly:
//!     ring / halving-doubling / hierarchical allreduce → 2n(p−1),
//!     recursive doubling → p·log₂p·n,
//!     reduce-scatter / allgather (ring) → n(p−1),
//!     binomial broadcast / reduce → n(p−1).
//!   (Hierarchical moving exactly the flat-ring volume — just relocated
//!   onto the intra-node tier — is itself the load-bearing claim.)

use mlsl::collectives::program::{self, CollectiveKind, Program};
use mlsl::collectives::verify::{check, init_bufs, run as sym_run, SymBuf};
use mlsl::collectives::Algorithm as A;
use mlsl::util::proptest::{run as prop_run, Config};

/// Total elements every rank together puts on the wire.
fn total_sent_elems(progs: &[Program]) -> usize {
    progs
        .iter()
        .flat_map(|p| &p.steps)
        .filter_map(|s| s.send.map(|x| x.range.len))
        .sum()
}

fn expect_eq(what: &str, got: usize, want: usize) -> Result<(), String> {
    if got != want {
        return Err(format!("{what}: got {got}, want {want}"));
    }
    Ok(())
}

#[test]
fn prop_ring_allreduce_correct_and_counted() {
    prop_run(
        Config { cases: 150, seed: 31 },
        |r| (2 + r.usize_below(16), 1 + r.usize_below(300)),
        |&(p, n)| {
            mlsl::collectives::verify::verify(CollectiveKind::Allreduce, A::Ring, p, n)?;
            let progs = program::allreduce_ring(p, n);
            for prog in &progs {
                expect_eq("ring steps", prog.steps.len(), 2 * (p - 1))?;
            }
            expect_eq("ring total elems", total_sent_elems(&progs), 2 * n * (p - 1))
        },
    );
}

#[test]
fn prop_reduce_scatter_and_allgather_correct_and_counted() {
    prop_run(
        Config { cases: 150, seed: 32 },
        |r| (2 + r.usize_below(16), 1 + r.usize_below(300), r.below(2) == 0),
        |&(p, n, scatter)| {
            let (kind, progs) = if scatter {
                (CollectiveKind::ReduceScatter, program::reduce_scatter_ring(p, n))
            } else {
                (CollectiveKind::Allgather, program::allgather_ring(p, n))
            };
            mlsl::collectives::verify::verify(kind, A::Ring, p, n)?;
            for prog in &progs {
                expect_eq("steps", prog.steps.len(), p - 1)?;
            }
            expect_eq("total elems", total_sent_elems(&progs), n * (p - 1))
        },
    );
}

#[test]
fn prop_allgather_owner_shifts_correct() {
    // allgather_ring_shifted(shift) starts rank r owning segment
    // (r+shift) % p; the custom init/check below encodes exactly that.
    prop_run(
        Config { cases: 150, seed: 33 },
        |r| {
            let p = 2 + r.usize_below(16);
            (p, 1 + r.usize_below(200), r.usize_below(p))
        },
        |&(p, n, shift)| {
            let progs = program::allgather_ring_shifted(p, n, shift);
            let seg = program::segments(n, p);
            let mut bufs: Vec<SymBuf> = vec![vec![vec![0u32; p]; n]; p];
            for (r, buf) in bufs.iter_mut().enumerate() {
                let own = (r + shift) % p;
                for e in &mut buf[seg[own]..seg[own + 1]] {
                    e[r] = 1;
                }
            }
            let finals = sym_run(&progs, bufs)?;
            for (r, buf) in finals.iter().enumerate() {
                for s in 0..p {
                    let owner = (s + p - shift % p) % p;
                    let mut want = vec![0u32; p];
                    want[owner] = 1;
                    for e in seg[s]..seg[s + 1] {
                        if buf[e] != want {
                            return Err(format!(
                                "rank {r} seg {s} elem {e}: {:?} want {want:?}",
                                buf[e]
                            ));
                        }
                    }
                }
            }
            expect_eq("total elems", total_sent_elems(&progs), n * (p - 1))
        },
    );
}

#[test]
fn prop_pow2_doubling_builders_correct_and_counted() {
    prop_run(
        Config { cases: 120, seed: 34 },
        |r| (1usize << (1 + r.usize_below(4)), 1 + r.usize_below(300), r.below(2) == 0),
        |&(p, n, rd)| {
            let lg = p.trailing_zeros() as usize;
            if rd {
                mlsl::collectives::verify::verify(
                    CollectiveKind::Allreduce,
                    A::RecursiveDoubling,
                    p,
                    n,
                )?;
                let progs = program::allreduce_rdoubling(p, n);
                for prog in &progs {
                    expect_eq("rdoubling steps", prog.steps.len(), lg)?;
                }
                expect_eq("rdoubling total elems", total_sent_elems(&progs), p * lg * n)
            } else {
                mlsl::collectives::verify::verify(
                    CollectiveKind::Allreduce,
                    A::HalvingDoubling,
                    p,
                    n,
                )?;
                let progs = program::allreduce_halving_doubling(p, n);
                for prog in &progs {
                    expect_eq("halving steps", prog.steps.len(), 2 * lg)?;
                }
                // Σ over ranks of 2(n − own_block) with own blocks exactly
                // partitioning n → 2n(p−1), for ANY n.
                expect_eq("halving total elems", total_sent_elems(&progs), 2 * n * (p - 1))
            }
        },
    );
}

#[test]
fn prop_binomial_trees_correct_and_counted() {
    prop_run(
        Config { cases: 150, seed: 35 },
        |r| {
            let p = 2 + r.usize_below(16);
            (p, 1 + r.usize_below(200), r.usize_below(p), r.below(2) == 0)
        },
        |&(p, n, root, bcast)| {
            let (kind, progs) = if bcast {
                (CollectiveKind::Broadcast { root }, program::broadcast_binomial(p, n, root))
            } else {
                (CollectiveKind::Reduce { root }, program::reduce_binomial(p, n, root))
            };
            mlsl::collectives::verify::verify(kind, A::Ring, p, n)?;
            // A binomial tree moves the full buffer down/up p−1 edges.
            expect_eq("binomial total elems", total_sent_elems(&progs), n * (p - 1))
        },
    );
}

#[test]
fn prop_barrier_completes_any_p() {
    prop_run(
        Config { cases: 60, seed: 36 },
        |r| 2 + r.usize_below(16),
        |&p| {
            let n = if p.is_power_of_two() { 1 } else { p };
            let progs = program::barrier(p);
            sym_run(&progs, init_bufs(CollectiveKind::Barrier, p, n)).map(|_| ())
        },
    );
}

#[test]
fn prop_hierarchical_correct_and_volume_matches_flat_ring() {
    prop_run(
        Config { cases: 150, seed: 37 },
        |r| {
            let p = 2 + r.usize_below(16);
            // Random divisor of p as the node size (1 and p included).
            let divisors: Vec<usize> = (1..=p).filter(|d| p % d == 0).collect();
            let rpn = divisors[r.usize_below(divisors.len())];
            let nodes = p / rpn;
            let inner = if nodes.is_power_of_two() {
                match r.below(3) {
                    0 => A::Ring,
                    1 => A::RecursiveDoubling,
                    _ => A::HalvingDoubling,
                }
            } else {
                A::Ring
            };
            (p, rpn, 1 + r.usize_below(200), inner)
        },
        |&(p, rpn, n, inner)| {
            let progs = program::allreduce_hierarchical(p, n, rpn, inner);
            let finals = sym_run(&progs, init_bufs(CollectiveKind::Allreduce, p, n))?;
            check(CollectiveKind::Allreduce, p, n, &finals)?;
            let nodes = p / rpn;
            // intra reduce + broadcast: 2n(p − nodes); inter allreduce:
            // ring/halving 2n(nodes−1), rdoubling nodes·log₂(nodes)·n.
            let inter = match inner {
                A::RecursiveDoubling => nodes * (nodes.trailing_zeros() as usize) * n,
                _ => 2 * n * (nodes - 1),
            };
            expect_eq(
                "hierarchical total elems",
                total_sent_elems(&progs),
                2 * n * (p - nodes) + inter,
            )
        },
    );
}

#[test]
fn prop_build_validates_instead_of_panicking() {
    use mlsl::collectives::program::BuildError;
    prop_run(
        Config { cases: 200, seed: 38 },
        |r| {
            let p = 1 + r.usize_below(17);
            let alg = match r.below(5) {
                0 => A::Ring,
                1 => A::RecursiveDoubling,
                2 => A::HalvingDoubling,
                3 => A::hier(&[1 + r.usize_below(6)]),
                _ => {
                    let g1 = 1 + r.usize_below(4);
                    let g2 = g1 * (1 + r.usize_below(4));
                    A::hier(&[g1, g2])
                }
            };
            (p, 1 + r.usize_below(50), alg)
        },
        |&(p, n, alg)| {
            let legal = match alg {
                A::RecursiveDoubling | A::HalvingDoubling => p.is_power_of_two(),
                A::Hierarchical { groups } => p % groups.outermost() == 0,
                _ => true,
            };
            match program::build(CollectiveKind::Allreduce, alg, p, n) {
                Ok(progs) => {
                    if !legal {
                        return Err(format!("{alg:?} p={p}: expected a BuildError"));
                    }
                    expect_eq("program count", progs.len(), p)
                }
                Err(BuildError::NonPowerOfTwoRanks { .. })
                | Err(BuildError::InvalidTierGrouping { .. }) => {
                    if legal {
                        return Err(format!("{alg:?} p={p}: spurious BuildError"));
                    }
                    Ok(())
                }
                Err(e) => Err(format!("{alg:?} p={p}: unexpected error {e}")),
            }
        },
    );
}

// ---------------------------------------------------------------------------
// N-level recursive builders (3-level socket/node/rack shapes, p <= 64)
// ---------------------------------------------------------------------------

/// Random nested shape: branch factors per level (socket, node, rack,
/// top), p = their product clamped to 64, groups = cumulative products
/// with branch 1 levels dropped. Mixed: non-pow2 branches included.
fn gen_shape(r: &mut mlsl::util::prng::Prng) -> (usize, Vec<usize>, usize) {
    let branches = [
        1 + r.usize_below(4), // socket
        1 + r.usize_below(4), // node
        1 + r.usize_below(3), // rack
        1 + r.usize_below(4), // top
    ];
    let mut p = 1usize;
    let mut groups = Vec::new();
    for (i, &b) in branches.iter().enumerate() {
        if p * b > 64 {
            break;
        }
        p *= b;
        if i < 3 && b > 1 {
            groups.push(p);
        }
    }
    // Drop a trailing group equal to p (a single top group is the
    // degenerate "everyone in one rack" case — keep it sometimes).
    if groups.last() == Some(&p) && r.below(2) == 0 {
        groups.pop();
    }
    if p < 2 {
        p = 2;
        groups.clear();
    }
    (p, groups, 1 + r.usize_below(200))
}

/// Hierarchy isolation: a rank that is not a leader of its level-g group
/// never communicates outside that group (so non-leaders never touch the
/// top tier, and level-i leaders never skip levels).
fn assert_tier_isolation(progs: &[Program], groups: &[usize]) -> Result<(), String> {
    for prog in progs {
        let r = prog.rank;
        for step in &prog.steps {
            for peer in step
                .send
                .iter()
                .map(|s| s.to)
                .chain(step.recv.iter().map(|v| v.from))
            {
                for &g in groups {
                    if r % g != 0 && peer / g != r / g {
                        return Err(format!(
                            "rank {r} (non-leader of its {g}-group) peers with {peer}"
                        ));
                    }
                }
            }
        }
    }
    Ok(())
}

fn scaled(rest: &[usize], g: usize) -> Vec<usize> {
    rest.iter().map(|s| s / g).collect()
}

/// Expected total on-wire elements, mirroring the builders' phase
/// structure exactly (full-buffer trees per level, flat top phase,
/// per-segment gathers/scatters with exact `segments` arithmetic).
fn ar_hier_volume(p: usize, n: usize, groups: &[usize], inner: A) -> usize {
    match groups.split_first() {
        None => match inner {
            A::RecursiveDoubling => p * (p.trailing_zeros() as usize) * n,
            _ => 2 * n * (p - 1),
        },
        Some((&g, rest)) => {
            let blocks = p / g;
            2 * n * (p - blocks) + ar_hier_volume(blocks, n, &scaled(rest, g), inner)
        }
    }
}

fn rs_hier_volume(p: usize, n: usize, groups: &[usize]) -> usize {
    match groups.split_first() {
        None => n * (p - 1),
        Some((&g, rest)) => {
            let seg = program::segments(n, p);
            let blocks = p / g;
            let reduce_up = n * (g - 1) * blocks;
            let scatter: usize =
                (0..p).filter(|r| r % g != 0).map(|r| seg[r + 1] - seg[r]).sum();
            reduce_up + scatter + rs_hier_volume(blocks, n, &scaled(rest, g))
        }
    }
}

fn ag_hier_volume(p: usize, n: usize, groups: &[usize]) -> usize {
    match groups.split_first() {
        None => n * (p - 1),
        Some((&g, rest)) => {
            let seg = program::segments(n, p);
            let blocks = p / g;
            let gather: usize =
                (0..p).filter(|r| r % g != 0).map(|r| seg[r + 1] - seg[r]).sum();
            let down = n * (g - 1) * blocks;
            gather + down + ag_hier_volume(blocks, n, &scaled(rest, g))
        }
    }
}

fn bcast_hier_volume(p: usize, n: usize, root: usize, groups: &[usize]) -> usize {
    match groups.split_first() {
        None => n * (p - 1),
        Some((&g, rest)) => {
            let blocks = p / g;
            let relay = if root % g != 0 { n } else { 0 };
            relay + n * (g - 1) * blocks + bcast_hier_volume(blocks, n, root / g, &scaled(rest, g))
        }
    }
}

#[test]
fn prop_multilevel_allreduce_correct_isolated_and_counted() {
    prop_run(
        Config { cases: 120, seed: 51 },
        |r| {
            let (p, groups, n) = gen_shape(r);
            let leaders = p / groups.last().copied().unwrap_or(1);
            let inner = if leaders.is_power_of_two() {
                match r.below(3) {
                    0 => A::Ring,
                    1 => A::RecursiveDoubling,
                    _ => A::HalvingDoubling,
                }
            } else {
                A::Ring
            };
            (p, groups, n, inner)
        },
        |(p, groups, n, inner)| {
            let (p, n) = (*p, *n);
            let progs = program::allreduce_hierarchical_levels(p, n, groups, *inner);
            let finals = sym_run(&progs, init_bufs(CollectiveKind::Allreduce, p, n))?;
            check(CollectiveKind::Allreduce, p, n, &finals)?;
            assert_tier_isolation(&progs, groups)?;
            expect_eq(
                "allreduce levels total elems",
                total_sent_elems(&progs),
                ar_hier_volume(p, n, groups, *inner),
            )
        },
    );
}

#[test]
fn prop_multilevel_reduce_scatter_correct_isolated_and_counted() {
    use mlsl::collectives::verify::check_reduce_scatter_layout;
    prop_run(
        Config { cases: 120, seed: 52 },
        gen_shape,
        |(p, groups, n)| {
            let (p, n) = (*p, *n);
            let progs = program::reduce_scatter_hierarchical(p, n, groups);
            let finals = sym_run(&progs, init_bufs(CollectiveKind::ReduceScatter, p, n))?;
            // Natural ownership: rank r owns fully-reduced segment r.
            check_reduce_scatter_layout(p, n, &finals, 0)?;
            assert_tier_isolation(&progs, groups)?;
            expect_eq(
                "hier reduce-scatter total elems",
                total_sent_elems(&progs),
                rs_hier_volume(p, n, groups),
            )
        },
    );
}

#[test]
fn prop_multilevel_allgather_correct_isolated_and_counted() {
    prop_run(
        Config { cases: 120, seed: 53 },
        gen_shape,
        |(p, groups, n)| {
            let (p, n) = (*p, *n);
            let progs = program::allgather_hierarchical(p, n, groups);
            let finals = sym_run(&progs, init_bufs(CollectiveKind::Allgather, p, n))?;
            check(CollectiveKind::Allgather, p, n, &finals)?;
            assert_tier_isolation(&progs, groups)?;
            expect_eq(
                "hier allgather total elems",
                total_sent_elems(&progs),
                ag_hier_volume(p, n, groups),
            )
        },
    );
}

#[test]
fn prop_multilevel_broadcast_any_root_correct_isolated_and_counted() {
    prop_run(
        Config { cases: 150, seed: 54 },
        |r| {
            let (p, groups, n) = gen_shape(r);
            let root = r.usize_below(p);
            (p, groups, n, root)
        },
        |(p, groups, n, root)| {
            let (p, n, root) = (*p, *n, *root);
            let progs = program::broadcast_hierarchical(p, n, root, groups);
            let finals = sym_run(&progs, init_bufs(CollectiveKind::Broadcast { root }, p, n))?;
            check(CollectiveKind::Broadcast { root }, p, n, &finals)?;
            assert_tier_isolation(&progs, groups)?;
            // n(p−1) down the trees plus one full-buffer relay per level
            // at which the (sub-)root is not a leader.
            expect_eq(
                "hier broadcast total elems",
                total_sent_elems(&progs),
                bcast_hier_volume(p, n, root, groups),
            )
        },
    );
}

"""Per-block absmax int8 gradient quantization Pallas kernels.

This is the compute half of the paper's "Reducing communication volume"
design point: gradients are quantized to int8 (one f32 scale per QBLOCK
elements, 4.06x volume reduction) before hitting the wire, and dequantized
after the allreduce. The Rust collectives layer owns the wire format
(rust/src/collectives/quant.rs mirrors this exact scheme); these kernels
let the quantize/dequantize run inside the AOT-compiled step so the
request path never touches Python.

Lane mapping: QBLOCK = 256 = 2 TPU lanes-width; each grid cell handles a
(rows, QBLOCK) tile so the absmax reduction is a lane reduction.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import QBLOCK

DEF_ROWS = 64  # quantization blocks per grid cell


def _pick_rows(rows: int, nblk: int) -> int:
    r = min(rows, nblk)
    while nblk % r != 0:
        r -= 1
    return r


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (rows, QBLOCK)
    absmax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = q * s_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("rows",))
def quantize_int8(x, rows: int = DEF_ROWS):
    """x: (n,) f32, n % QBLOCK == 0 -> (q:int8 (n,), scales:f32 (n/QBLOCK,))."""
    n = x.shape[0]
    assert n % QBLOCK == 0, n
    nblk = n // QBLOCK
    rb = _pick_rows(rows, nblk)
    xb = x.reshape(nblk, QBLOCK)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nblk // rb,),
        in_specs=[pl.BlockSpec((rb, QBLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rb, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, QBLOCK), jnp.int8),
            jax.ShapeDtypeStruct((nblk,), jnp.float32),
        ],
        interpret=True,
    )(xb)
    return q.reshape(n), s


@functools.partial(jax.jit, static_argnames=("rows",))
def dequantize_int8(q, scale, rows: int = DEF_ROWS):
    """Inverse of quantize_int8 (lossy). q: (n,) int8, scale: (n/QBLOCK,)."""
    n = q.shape[0]
    nblk = n // QBLOCK
    rb = _pick_rows(rows, nblk)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nblk // rb,),
        in_specs=[
            pl.BlockSpec((rb, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rb, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, QBLOCK), jnp.float32),
        interpret=True,
    )(q.reshape(nblk, QBLOCK), scale)
    return out.reshape(n)

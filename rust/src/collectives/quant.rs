//! Low-precision wire formats — the paper's "Reducing communication volume".
//!
//! Three wire dtypes: f32 (4 B/elem), bf16 (2 B/elem, truncation-rounded),
//! and int8 with one f32 absmax scale per [`QBLOCK`]-element block
//! (≈1.016 B/elem). Reduction is ALWAYS performed in f32 after decoding —
//! the paper's correctness requirement ("natively support low precision
//! communication, for guaranteeing correctness"): precision is lost only
//! on the wire, never in the accumulator.
//!
//! The int8 scheme mirrors the L1 Pallas kernel
//! (`python/compile/kernels/quantize.py`) bit-for-bit so a gradient
//! quantized on either side of the stack decodes identically.

use super::ReduceOp;
use crate::util::bf16::{bf16_bits_to_f32, f32_to_bf16_bits};

/// Elements per int8 quantization block (one f32 scale per block).
/// Must match `python/compile/kernels/ref.py::QBLOCK`.
pub const QBLOCK: usize = 256;

/// Fixed per-hop (de)quantize setup cost, ns (buffer walk start-up,
/// scale table touch). Paid once per hop END-POINT pair by the selector
/// and tuner cost models; f32 pays nothing.
pub const BF16_SETUP_NS: u64 = 400;
/// See [`BF16_SETUP_NS`]; int8 also scans each block twice (absmax +
/// quantize), so its fixed term is larger.
pub const INT8_SETUP_NS: u64 = 1_600;
/// Per-element encode+decode cost in 1/4 ns units (bf16: truncate +
/// widen ≈ 0.25 ns/elem on a ~GHz-scalar node model).
const BF16_QUARTER_NS_PER_ELEM: u64 = 1;
/// int8: absmax scan, scale mul, clamp, dequant mul ≈ 0.5 ns/elem.
const INT8_QUARTER_NS_PER_ELEM: u64 = 2;

/// Wire element encoding for collective payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireDtype {
    #[default]
    F32,
    Bf16,
    /// Per-block absmax int8; `QBLOCK` elements share one f32 scale.
    Int8Block,
}

impl WireDtype {
    /// Wire bytes for `n` elements.
    pub fn wire_bytes(&self, n: usize) -> usize {
        match self {
            WireDtype::F32 => 4 * n,
            WireDtype::Bf16 => 2 * n,
            WireDtype::Int8Block => n + 4 * n.div_ceil(QBLOCK),
        }
    }

    /// Volume reduction factor vs f32.
    pub fn compression(&self, n: usize) -> f64 {
        (4 * n) as f64 / self.wire_bytes(n) as f64
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "f32" | "fp32" => Some(WireDtype::F32),
            "bf16" => Some(WireDtype::Bf16),
            "int8" | "i8" => Some(WireDtype::Int8Block),
            _ => None,
        }
    }

    /// Every wire dtype, f32 first — the candidate menu the selector and
    /// tuner enumerate when precision is chosen automatically.
    pub const ALL: [WireDtype; 3] = [WireDtype::F32, WireDtype::Bf16, WireDtype::Int8Block];

    /// Worst-case RELATIVE round-trip error vs the block absmax: the δ
    /// in the error-feedback fixed point r* = δ/(1−δ). bf16 keeps 8
    /// mantissa bits (δ = 2⁻⁸ from truncation); int8 rounds to the
    /// nearest of 127 steps of absmax (δ = 0.5/127 of absmax — relative
    /// to the LARGEST element of a block, not each element).
    pub fn rel_error(&self) -> f64 {
        match self {
            WireDtype::F32 => 0.0,
            WireDtype::Bf16 => 1.0 / 256.0,
            WireDtype::Int8Block => 0.5 / 127.0,
        }
    }
}

/// Modeled cost of encoding at the sender PLUS decoding at the receiver
/// for one hop carrying `elems` elements: a fixed setup term and a
/// per-element term, scaled by the endpoint's chaos compute-slowdown
/// multiplier (`slowdown_milli` = 1000 → healthy). f32 is a memcpy the
/// executor never separates from the send and costs nothing here.
///
/// This is an arithmetic charge in the selector/tuner cost models — it
/// never touches `fabric::sim` (the wire itself only sees fewer bytes).
pub fn quant_hop_ns(elems: usize, dtype: WireDtype, slowdown_milli: u64) -> u64 {
    let base = match dtype {
        WireDtype::F32 => return 0,
        WireDtype::Bf16 => {
            BF16_SETUP_NS + (elems as u64 * BF16_QUARTER_NS_PER_ELEM).div_ceil(4)
        }
        WireDtype::Int8Block => {
            INT8_SETUP_NS + (elems as u64 * INT8_QUARTER_NS_PER_ELEM).div_ceil(4)
        }
    };
    (base * slowdown_milli).div_ceil(1000)
}

/// Per-rank error-feedback accumulator (1-bit-SGD / EF-SGD style): the
/// part of the gradient the wire format dropped is carried into the NEXT
/// iteration's gradient before encoding, so quantization error cannot
/// accumulate across iterations — the residual converges to the fixed
/// point r* ≤ δ·‖g‖/(1−δ) instead of growing linearly.
#[derive(Debug, Clone, PartialEq)]
pub struct EfState {
    residual: Vec<f32>,
}

impl EfState {
    pub fn new(n: usize) -> Self {
        EfState { residual: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.residual.len()
    }

    pub fn is_empty(&self) -> bool {
        self.residual.is_empty()
    }

    /// The error carried toward the next iteration.
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// L∞ norm of the carried residual.
    pub fn residual_linf(&self) -> f32 {
        self.residual.iter().fold(0f32, |a, v| a.max(v.abs()))
    }

    /// Encode `grad + residual` for the wire and bank what the format
    /// dropped. Returns the wire bytes; the CONTRIBUTED value (what the
    /// peers will decode) is `decode(bytes) = grad + residual − new
    /// residual`.
    pub fn encode_with_feedback(&mut self, grad: &[f32], dtype: WireDtype) -> Vec<u8> {
        assert_eq!(grad.len(), self.residual.len(), "error-feedback state size mismatch");
        let compensated: Vec<f32> =
            grad.iter().zip(&self.residual).map(|(g, r)| g + r).collect();
        let wire = encode(&compensated, dtype);
        let sent = decode(&wire, compensated.len(), dtype);
        for (r, (c, s)) in self.residual.iter_mut().zip(compensated.iter().zip(&sent)) {
            *r = c - s;
        }
        wire
    }
}

impl std::fmt::Display for WireDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            WireDtype::F32 => "f32",
            WireDtype::Bf16 => "bf16",
            WireDtype::Int8Block => "int8",
        })
    }
}

/// Encode `src` into wire bytes.
pub fn encode(src: &[f32], dtype: WireDtype) -> Vec<u8> {
    match dtype {
        WireDtype::F32 => {
            // Hot path (§Perf): one memcpy. f32 is IEEE-754 and the wire
            // format is little-endian; on the LE targets we support this
            // is a byte-identical reinterpretation.
            let mut out = vec![0u8; 4 * src.len()];
            // SAFETY: u8 has no alignment requirements; lengths match.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    src.as_ptr() as *const u8,
                    out.as_mut_ptr(),
                    4 * src.len(),
                );
            }
            out
        }
        WireDtype::Bf16 => {
            let mut out = Vec::with_capacity(2 * src.len());
            for v in src {
                out.extend_from_slice(&f32_to_bf16_bits(*v).to_le_bytes());
            }
            out
        }
        WireDtype::Int8Block => {
            let nblk = src.len().div_ceil(QBLOCK);
            let mut out = vec![0u8; 4 * nblk + src.len()];
            let (scale_bytes, payload) = out.split_at_mut(4 * nblk);
            for (bi, blk) in src.chunks(QBLOCK).enumerate() {
                let absmax = blk.iter().fold(0f32, |a, v| a.max(v.abs()));
                let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
                scale_bytes[4 * bi..4 * bi + 4].copy_from_slice(&scale.to_le_bytes());
                let inv = 1.0 / scale; // mul beats div in the inner loop
                let base = bi * QBLOCK;
                for (j, v) in blk.iter().enumerate() {
                    let q = (v * inv).round().clamp(-127.0, 127.0) as i8;
                    payload[base + j] = q as u8;
                }
            }
            out
        }
    }
}

/// Decode wire bytes to f32 (allocating).
pub fn decode(bytes: &[u8], n: usize, dtype: WireDtype) -> Vec<f32> {
    let mut out = vec![0f32; n];
    decode_into(bytes, &mut out, dtype, None);
    out
}

/// Decode wire bytes into `dst`, optionally reducing with `op` (None →
/// overwrite). This is the single hot decode path the executor uses.
pub fn decode_into(bytes: &[u8], dst: &mut [f32], dtype: WireDtype, op: Option<ReduceOp>) {
    let n = dst.len();
    assert_eq!(bytes.len(), dtype.wire_bytes(n), "wire size mismatch");
    match dtype {
        WireDtype::F32 => match op {
            // Overwrite: single memcpy (see encode).
            None => unsafe {
                std::ptr::copy_nonoverlapping(
                    bytes.as_ptr(),
                    dst.as_mut_ptr() as *mut u8,
                    4 * n,
                );
            },
            Some(ReduceOp::Sum) => {
                // Autovectorizable sum-reduce over exact 4-byte chunks.
                for (d, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
                    *d += f32::from_le_bytes(c.try_into().unwrap());
                }
            }
            Some(o) => {
                for (d, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
                    *d = o.apply(*d, f32::from_le_bytes(c.try_into().unwrap()));
                }
            }
        },
        WireDtype::Bf16 => {
            for (i, d) in dst.iter_mut().enumerate() {
                let v = bf16_bits_to_f32(u16::from_le_bytes(
                    bytes[2 * i..2 * i + 2].try_into().unwrap(),
                ));
                *d = match op {
                    Some(o) => o.apply(*d, v),
                    None => v,
                };
            }
        }
        WireDtype::Int8Block => {
            let nblk = n.div_ceil(QBLOCK);
            let (scale_bytes, q) = bytes.split_at(4 * nblk);
            // Block-wise: hoist the scale load out of the inner loop.
            for (blk, (dblk, qblk)) in dst.chunks_mut(QBLOCK).zip(q.chunks(QBLOCK)).enumerate() {
                let s = f32::from_le_bytes(
                    scale_bytes[4 * blk..4 * blk + 4].try_into().unwrap(),
                );
                match op {
                    None => {
                        for (d, qi) in dblk.iter_mut().zip(qblk) {
                            *d = (*qi as i8) as f32 * s;
                        }
                    }
                    Some(ReduceOp::Sum) => {
                        for (d, qi) in dblk.iter_mut().zip(qblk) {
                            *d += (*qi as i8) as f32 * s;
                        }
                    }
                    Some(o) => {
                        for (d, qi) in dblk.iter_mut().zip(qblk) {
                            *d = o.apply(*d, (*qi as i8) as f32 * s);
                        }
                    }
                }
            }
        }
    }
}

/// Worst-case absolute round-trip error for a slice under a wire dtype
/// (used by tests and by the trainer's quantization guard).
pub fn max_roundtrip_error(src: &[f32], dtype: WireDtype) -> f32 {
    match dtype {
        WireDtype::F32 => 0.0,
        WireDtype::Bf16 => src
            .iter()
            .map(|v| (crate::util::bf16::bf16_roundtrip(*v) - v).abs())
            .fold(0.0, f32::max),
        WireDtype::Int8Block => src
            .chunks(QBLOCK)
            .map(|blk| {
                let absmax = blk.iter().fold(0f32, |a, v| a.max(v.abs()));
                absmax / 127.0 * 0.5 + f32::EPSILON * absmax
            })
            .fold(0.0, f32::max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 2654435761) % 1000) as f32 / 250.0 - 2.0).collect()
    }

    #[test]
    fn f32_roundtrip_exact() {
        let x = data(1000);
        let deq = decode(&encode(&x, WireDtype::F32), 1000, WireDtype::F32);
        assert_eq!(x, deq);
    }

    #[test]
    fn bf16_roundtrip_error_bounded() {
        let x = data(1000);
        let deq = decode(&encode(&x, WireDtype::Bf16), 1000, WireDtype::Bf16);
        for (a, b) in x.iter().zip(&deq) {
            // bf16 has 8 mantissa bits -> rel err <= 2^-8.
            assert!((a - b).abs() <= a.abs() / 128.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn int8_roundtrip_error_bounded() {
        let x = data(QBLOCK * 3 + 17); // non-multiple tail block
        let deq = decode(&encode(&x, WireDtype::Int8Block), x.len(), WireDtype::Int8Block);
        let bound = max_roundtrip_error(&x, WireDtype::Int8Block);
        for (i, (a, b)) in x.iter().zip(&deq).enumerate() {
            assert!((a - b).abs() <= bound + 1e-6, "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn int8_wire_size_and_compression() {
        let n = 4096;
        assert_eq!(WireDtype::Int8Block.wire_bytes(n), n + 4 * (n / QBLOCK));
        assert!(WireDtype::Int8Block.compression(n) > 3.9);
        assert_eq!(WireDtype::Bf16.compression(n), 2.0);
        assert_eq!(WireDtype::F32.compression(n), 1.0);
    }

    #[test]
    fn decode_with_sum_reduces() {
        let x = data(512);
        let wire = encode(&x, WireDtype::F32);
        let mut acc = x.clone();
        decode_into(&wire, &mut acc, WireDtype::F32, Some(ReduceOp::Sum));
        for (a, b) in acc.iter().zip(&x) {
            assert_eq!(*a, 2.0 * b);
        }
    }

    #[test]
    fn zero_block_is_stable() {
        let x = vec![0f32; QBLOCK * 2];
        let deq = decode(&encode(&x, WireDtype::Int8Block), x.len(), WireDtype::Int8Block);
        assert_eq!(x, deq);
    }

    #[test]
    fn max_and_min_ops() {
        assert_eq!(ReduceOp::Max.apply(1.0, 2.0), 2.0);
        assert_eq!(ReduceOp::Min.apply(1.0, 2.0), 1.0);
        assert_eq!(ReduceOp::Sum.apply(1.0, 2.0), 3.0);
    }

    #[test]
    fn quant_cost_is_zero_for_f32_and_scales_with_slowdown() {
        assert_eq!(quant_hop_ns(1 << 20, WireDtype::F32, 1000), 0);
        let b = quant_hop_ns(1 << 20, WireDtype::Bf16, 1000);
        let i = quant_hop_ns(1 << 20, WireDtype::Int8Block, 1000);
        assert!(i > b, "int8 quantize costs more than bf16: {i} vs {b}");
        // Fixed setup dominates tiny payloads; per-element term dominates
        // big ones (the shape that creates the precision crossover).
        assert_eq!(quant_hop_ns(0, WireDtype::Bf16, 1000), BF16_SETUP_NS);
        assert_eq!(quant_hop_ns(0, WireDtype::Int8Block, 1000), INT8_SETUP_NS);
        // A chaos-slowed endpoint pays proportionally more.
        assert_eq!(quant_hop_ns(1 << 20, WireDtype::Bf16, 2000), 2 * b);
    }

    #[test]
    fn error_feedback_converges_below_one_shot_error() {
        // Repeatedly allreducing the SAME gradient with error feedback
        // must leave the long-run residual at the fixed point r* ≈
        // δ/(1−δ)·g — and the per-iteration CONTRIBUTED error (grad +
        // old residual − new residual − grad) oscillates around zero
        // mean: summed over k iterations the total contributed mass is
        // k·g ± r*, i.e. the ACCUMULATED error stays below the one-shot
        // quantization error instead of growing like k·δ.
        let g = data(QBLOCK * 2 + 13);
        for dtype in [WireDtype::Bf16, WireDtype::Int8Block] {
            let one_shot = max_roundtrip_error(&g, dtype);
            let mut ef = EfState::new(g.len());
            let mut contributed = vec![0f32; g.len()];
            let iters = 50;
            for _ in 0..iters {
                let wire = ef.encode_with_feedback(&g, dtype);
                let sent = decode(&wire, g.len(), dtype);
                for (c, s) in contributed.iter_mut().zip(&sent) {
                    *c += s;
                }
                // Residual stays bounded by the fixed point (with slack
                // for absmax growth of the compensated buffer).
                assert!(
                    ef.residual_linf() <= 2.0 * one_shot + 1e-6,
                    "{dtype}: residual {} vs one-shot {one_shot}",
                    ef.residual_linf()
                );
            }
            // Accumulated error after `iters` rounds ≤ one residual's
            // worth — NOT iters × one-shot error.
            for (i, (c, gi)) in contributed.iter().zip(&g).enumerate() {
                let err = (c - iters as f32 * gi).abs();
                assert!(
                    err <= 2.0 * one_shot + 1e-4,
                    "{dtype} elem {i}: accumulated err {err} vs one-shot {one_shot}"
                );
            }
        }
    }

    #[test]
    fn error_feedback_without_compression_is_exact() {
        let g = data(300);
        let mut ef = EfState::new(g.len());
        let wire = ef.encode_with_feedback(&g, WireDtype::F32);
        assert_eq!(decode(&wire, g.len(), WireDtype::F32), g);
        assert_eq!(ef.residual_linf(), 0.0);
    }
}

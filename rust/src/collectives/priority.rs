//! Message prioritization policy — the paper's headline runtime feature.
//!
//! With data parallelism the FIRST layer's weight-gradient allreduce is
//! issued LAST (backprop runs output→input) but needed FIRST (the next
//! forward pass starts at layer 0). MPI completes operations roughly in
//! issue order; MLSL instead assigns each gradient op a priority equal to
//! its layer's forward position and lets urgent ops preempt bulk ones
//! (fabric-level preemption in the simulator, step-level preemption in the
//! real progress engine).

use crate::Priority;

/// How gradient-allreduce priorities are assigned.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PriorityPolicy {
    /// Everything at the same priority — MPI/Horovod issue-order behaviour.
    #[default]
    None,
    /// Priority = forward position of the layer (0 = first = most urgent).
    ByLayer,
    /// Priority = reverse forward position (an intentionally-pessimal
    /// ablation: the LAST layer wins the wire; used in tests/benches to
    /// show ordering matters, not just "any ordering").
    ReverseLayer,
}

impl PriorityPolicy {
    /// Priority class for a parameter at `fwd_order` out of `n_layers`.
    pub fn assign(&self, fwd_order: usize, n_layers: usize) -> Priority {
        match self {
            PriorityPolicy::None => 128,
            PriorityPolicy::ByLayer => fwd_order.min(254) as Priority,
            PriorityPolicy::ReverseLayer => {
                n_layers.saturating_sub(1 + fwd_order).min(254) as Priority
            }
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "none" | "fifo" => Some(PriorityPolicy::None),
            "bylayer" | "layer" => Some(PriorityPolicy::ByLayer),
            "reverse" => Some(PriorityPolicy::ReverseLayer),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_layer_makes_first_layer_most_urgent() {
        let p = PriorityPolicy::ByLayer;
        assert!(p.assign(0, 50) < p.assign(1, 50));
        assert!(p.assign(1, 50) < p.assign(49, 50));
    }

    #[test]
    fn none_is_flat() {
        let p = PriorityPolicy::None;
        assert_eq!(p.assign(0, 50), p.assign(49, 50));
    }

    #[test]
    fn reverse_inverts() {
        let p = PriorityPolicy::ReverseLayer;
        assert!(p.assign(49, 50) < p.assign(0, 50));
    }

    #[test]
    fn clamps_to_u8() {
        let p = PriorityPolicy::ByLayer;
        assert_eq!(p.assign(1000, 2000), 254);
    }
}

//! Transformer LM layer table matching the AOT presets in
//! `python/compile/presets.py` — so the simulated experiments and the
//! REAL trainer agree on gradient sizes and priorities.

use super::{LayerDesc, LayerKind, ModelDesc};

/// Build the layer table for a decoder-only transformer.
pub fn transformer(
    name: &str,
    vocab: usize,
    d_model: usize,
    n_layers: usize,
    seq_len: usize,
    batch: usize,
) -> ModelDesc {
    let d_ff = 4 * d_model;
    let mut l = Vec::new();
    let s = seq_len as f64;

    l.push(LayerDesc {
        name: "tok_emb".into(),
        kind: LayerKind::Embed,
        weight_elems: vocab * d_model,
        fwd_flops: 0.0, // lookup
        out_act_elems: seq_len * d_model,
    });
    l.push(LayerDesc {
        name: "pos_emb".into(),
        kind: LayerKind::Embed,
        weight_elems: seq_len * d_model,
        fwd_flops: (seq_len * d_model) as f64,
        out_act_elems: seq_len * d_model,
    });
    for i in 0..n_layers {
        // QKVO projections: 4 × d², per token.
        l.push(LayerDesc {
            name: format!("blk{i}.attn"),
            kind: LayerKind::Attn,
            weight_elems: 4 * d_model * d_model,
            fwd_flops: s * 2.0 * (4 * d_model * d_model) as f64
                + 2.0 * s * s * d_model as f64 * 2.0, // + QK^T and PV
            out_act_elems: seq_len * d_model,
        });
        // MLP: d→4d→d.
        l.push(LayerDesc {
            name: format!("blk{i}.mlp"),
            kind: LayerKind::Fc,
            weight_elems: d_model * d_ff + d_ff + d_ff * d_model + d_model,
            fwd_flops: s * 2.0 * (2 * d_model * d_ff) as f64,
            out_act_elems: seq_len * d_model,
        });
        // The two LayerNorms.
        l.push(LayerDesc {
            name: format!("blk{i}.ln"),
            kind: LayerKind::Norm,
            weight_elems: 4 * d_model,
            fwd_flops: s * (8 * d_model) as f64,
            out_act_elems: seq_len * d_model,
        });
    }
    l.push(LayerDesc {
        name: "lnf".into(),
        kind: LayerKind::Norm,
        weight_elems: 2 * d_model,
        fwd_flops: s * (4 * d_model) as f64,
        out_act_elems: seq_len * d_model,
    });
    l.push(LayerDesc {
        name: "w_out".into(),
        kind: LayerKind::Fc,
        weight_elems: d_model * vocab,
        fwd_flops: s * 2.0 * (d_model * vocab) as f64,
        out_act_elems: seq_len * vocab,
    });
    ModelDesc { name: name.into(), layers: l, default_batch: batch }
}

/// The `small` AOT preset (what `train_e2e` actually trains).
pub fn transformer_small() -> ModelDesc {
    transformer("transformer", 4096, 256, 4, 128, 8)
}

/// The paper-scale `base100m` preset (compile-path validated).
pub fn transformer_100m() -> ModelDesc {
    transformer("transformer100m", 32768, 768, 12, 256, 8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_matches_python_preset_param_count() {
        // python/compile/presets.py::n_params for `small`:
        // v*d + s*d + L*(12d² + 4d + d_ff + d) + 2d + d*v
        let m = transformer_small();
        let (v, d, lyr, s) = (4096usize, 256usize, 4usize, 128usize);
        let d_ff = 4 * d;
        let per_block = 4 * d * d + d * d_ff + d_ff + d_ff * d + d + 4 * d;
        let want = v * d + s * d + lyr * per_block + 2 * d + d * v;
        assert_eq!(m.total_weight_elems(), want);
    }

    #[test]
    fn hundred_m_is_actually_100m() {
        let m = transformer_100m();
        let p = m.total_weight_elems() as f64;
        assert!((90e6..140e6).contains(&p), "{p}");
    }

    #[test]
    fn first_gradient_is_the_embedding() {
        let m = transformer_small();
        let (idx, first) = m.weighted_layers().next().unwrap();
        assert_eq!(idx, 0);
        assert_eq!(first.name, "tok_emb");
    }
}

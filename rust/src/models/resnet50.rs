//! ResNet-50 layer table (He et al. 2015), ImageNet 224×224 input.
//! Built programmatically from the bottleneck architecture: conv1 →
//! [3,4,6,3] bottleneck stages → global pool → fc1000.

use super::{bn, conv, fc, pool, LayerDesc, ModelDesc};

/// One bottleneck block: 1×1 reduce → 3×3 → 1×1 expand (+ BN each), with
/// an optional 1×1 projection shortcut when the shape changes.
fn bottleneck(
    layers: &mut Vec<LayerDesc>,
    stage: usize,
    block: usize,
    cin: usize,
    cmid: usize,
    cout: usize,
    h: usize,
    w: usize,
    project: bool,
) {
    let tag = |s: &str| format!("res{stage}{}.{s}", (b'a' + block as u8) as char);
    layers.push(conv(&tag("conv1"), 1, cin, cmid, h, w));
    layers.push(bn(&tag("bn1"), cmid, h, w));
    layers.push(conv(&tag("conv2"), 3, cmid, cmid, h, w));
    layers.push(bn(&tag("bn2"), cmid, h, w));
    layers.push(conv(&tag("conv3"), 1, cmid, cout, h, w));
    layers.push(bn(&tag("bn3"), cout, h, w));
    if project {
        layers.push(conv(&tag("proj"), 1, cin, cout, h, w));
        layers.push(bn(&tag("projbn"), cout, h, w));
    }
    layers.push(pool(&tag("relu"), cout * h * w, (cout * h * w) as f64));
}

pub fn resnet50() -> ModelDesc {
    let mut layers = Vec::new();
    // conv1: 7x7/2, 64ch, out 112x112.
    layers.push(conv("conv1", 7, 3, 64, 112, 112));
    layers.push(bn("bn1", 64, 112, 112));
    layers.push(pool("pool1", 64 * 56 * 56, (64 * 56 * 56) as f64));

    // (cmid, cout, blocks, spatial size of the stage's outputs)
    let stages = [(64, 256, 3, 56), (128, 512, 4, 28), (256, 1024, 6, 14), (512, 2048, 3, 7)];
    let mut cin = 64;
    for (si, (cmid, cout, blocks, hw)) in stages.into_iter().enumerate() {
        for b in 0..blocks {
            bottleneck(&mut layers, si + 2, b, cin, cmid, cout, hw, hw, b == 0);
            cin = cout;
        }
    }

    layers.push(pool("avgpool", 2048, 2048.0 * 49.0));
    layers.push(fc("fc1000", 2048, 1000));
    ModelDesc { name: "resnet50".into(), layers, default_batch: 32 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_count_matches_paper() {
        let m = resnet50();
        let p = m.total_weight_elems() as f64;
        assert!((p - 25.5e6).abs() / 25.5e6 < 0.03, "{p}");
    }

    #[test]
    fn layer_count_is_resnet_shaped() {
        let m = resnet50();
        let convs = m
            .layers
            .iter()
            .filter(|l| l.kind == crate::models::LayerKind::Conv)
            .count();
        // 1 + 3*(3+4+6+3) + 4 projections = 53 convs.
        assert_eq!(convs, 53);
    }

    #[test]
    fn first_weighted_layer_is_small() {
        // The prioritization story: conv1's gradient (~37 KB) is tiny vs
        // the 25 MB total — latency-bound on the wire.
        let m = resnet50();
        let first = m.weighted_layers().next().unwrap().1;
        assert!(first.weight_bytes() < 40_000);
        assert!(m.total_weight_bytes() > 100_000_000);
    }
}

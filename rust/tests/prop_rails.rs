//! Property tests for multi-rail striping: rails are a pure fabric-level
//! bandwidth optimization, INVISIBLE to collective semantics.
//!
//! Three invariant families, randomized over every program builder
//! (ring / recursive doubling / halving-doubling / hierarchical, across
//! allreduce, allgather, reduce-scatter and broadcast):
//!
//! * **correctness is rail-independent** — the chunk programs (and so
//!   the symbolic-executor results they produce) never see the rail
//!   count, and executing them on rails ∈ {1, 2, 4} delivers the
//!   byte-identical multiset of logical messages — the stream the
//!   symbolic payloads ride on — moving exactly the programs' bytes;
//! * **striping never slows an idle-fabric collective** — every piece's
//!   egress is no longer than the unstriped transfer's;
//! * **work conservation** — summed per-rail `busy_ns` for a
//!   bandwidth-bound transfer equals the single-rail `busy_ns` within
//!   per-piece rounding, and sub-chunk (latency-bound) traffic produces
//!   byte-identical event streams at any rail count.

use mlsl::collectives::program::{build, CollectiveKind};
use mlsl::collectives::simexec::SimCollectives;
use mlsl::collectives::verify::{init_bufs, run as sym_run};
use mlsl::collectives::{Algorithm as A, WireDtype};
use mlsl::fabric::topology::Topology;
use mlsl::fabric::{MsgDesc, NetSim, SimEvent};
use mlsl::util::proptest::{run as prop_run, Config};

const RAILS: [u32; 3] = [1, 2, 4];

/// Flat test fabric: 8 Gbps = 1 B/ns per rail, alpha 1000 ns, 512-byte
/// chunks (small enough that modest element counts stripe).
fn topo(rails: u32, gamma: u64) -> Topology {
    Topology::flat("railtest", 8.0, 1_000, gamma, 512)
        .with_rails(rails)
        .unwrap()
}

/// Random (p, n, kind, algorithm) over every builder legal at p.
fn gen_case(r: &mut mlsl::util::prng::Prng) -> (usize, usize, CollectiveKind, A) {
    let p = 2 + r.usize_below(11);
    let n = 1 + r.usize_below(2_000);
    let root = r.usize_below(p);
    let mut cands: Vec<(CollectiveKind, A)> = vec![
        (CollectiveKind::Allreduce, A::Ring),
        (CollectiveKind::Allgather, A::Ring),
        (CollectiveKind::ReduceScatter, A::Ring),
        (CollectiveKind::Broadcast { root }, A::Ring),
    ];
    if p.is_power_of_two() {
        cands.push((CollectiveKind::Allreduce, A::RecursiveDoubling));
        cands.push((CollectiveKind::Allreduce, A::HalvingDoubling));
        cands.push((CollectiveKind::Allgather, A::RecursiveDoubling));
    }
    for d in (2..p).filter(|d| p % d == 0) {
        let hier = A::hier(&[d]);
        cands.push((CollectiveKind::Allreduce, hier));
        cands.push((CollectiveKind::Allgather, hier));
        cands.push((CollectiveKind::ReduceScatter, hier));
        cands.push((CollectiveKind::Broadcast { root }, hier));
    }
    let (kind, alg) = cands[r.usize_below(cands.len())];
    (p, n, kind, alg)
}

#[test]
fn prop_rail_striping_invisible_to_collective_correctness() {
    prop_run(
        Config { cases: 80, seed: 61 },
        gen_case,
        |&(p, n, kind, alg)| {
            // The builders take no topology at all — the SAME programs
            // run on every rail count (striping lives entirely inside
            // the fabric) — and they are symbolically correct.
            let progs = build(kind, alg, p, n).map_err(|e| e.to_string())?;
            sym_run(&progs, init_bufs(kind, p, n))?;
            // Timed execution per rail count: completes, and the full
            // multiset of logically-delivered messages (src, dst, wire
            // bytes) is byte-identical across rails — what the symbolic
            // payloads ride on. Striping only splits EGRESS into rail
            // pieces; the delivery stream a receiver consumes must be
            // indistinguishable, or resume/replay (and reductions fed by
            // the arrivals) would diverge between rail counts.
            let reference_sent: u64 = progs
                .iter()
                .flat_map(|pr| &pr.steps)
                .filter_map(|s| s.send.map(|x| 4 * x.range.len as u64)) // f32 wire
                .sum();
            let mut t_single = 0;
            let mut reference_deliveries: Option<Vec<(usize, usize, u64)>> = None;
            for (i, &rails) in RAILS.iter().enumerate() {
                let mut sim = NetSim::new(topo(rails, 100), p);
                let mut exec = SimCollectives::new();
                let mut completions = exec.post(&mut sim, 1, progs.clone(), WireDtype::F32, 1);
                let mut delivered: Vec<(usize, usize, u64)> = Vec::new();
                while exec.in_flight() > 0 {
                    let ev = sim
                        .next()
                        .ok_or_else(|| format!("{kind:?}/{alg:?} rails={rails}: deadlock"))?;
                    if let SimEvent::MsgDelivered { msg, .. } = &ev {
                        delivered.push((msg.src, msg.dst, msg.bytes));
                    }
                    exec.on_event_into(&mut sim, &ev, &mut completions);
                }
                let t = completions.iter().map(|c| c.at).max().unwrap_or(0);
                delivered.sort_unstable();
                match &reference_deliveries {
                    None => reference_deliveries = Some(delivered),
                    Some(want) => {
                        if &delivered != want {
                            return Err(format!(
                                "{kind:?}/{alg:?} p={p} rails={rails}: delivered-message \
                                 multiset diverged from the single-rail run"
                            ));
                        }
                    }
                }
                if sim.stats.bytes_sent != reference_sent {
                    return Err(format!(
                        "{kind:?}/{alg:?} p={p} rails={rails}: moved {} bytes, \
                         programs carry {reference_sent}",
                        sim.stats.bytes_sent
                    ));
                }
                if i == 0 {
                    t_single = t;
                }
                // Every piece's egress is no longer than the unstriped
                // transfer's, so more rails can only help; the 1% slack
                // absorbs equal-time tie-break reshuffles only.
                if t > t_single + t_single / 100 {
                    return Err(format!(
                        "{kind:?}/{alg:?} p={p} rails={rails}: striping slowed an \
                         idle-fabric collective ({t} > {t_single})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rail_striping_is_work_conserving() {
    prop_run(
        Config { cases: 100, seed: 62 },
        |r| {
            // At least one whole chunk so striping engages; gamma = 0 so
            // busy time is pure wire work.
            let bytes = 512 + r.below(40_000);
            let rails = [2u32, 4][r.usize_below(2)];
            (bytes, rails)
        },
        |&(bytes, rails)| {
            let mut s1 = NetSim::new(topo(1, 0), 2);
            let mut sr = NetSim::new(topo(rails, 0), 2);
            for s in [&mut s1, &mut sr] {
                s.send(MsgDesc { src: 0, dst: 1, bytes, priority: 1, tag: 1 });
                s.drain();
            }
            let single = s1.nic_busy_ns(0);
            let summed: u64 = (0..sr.num_rails()).map(|i| sr.rail_busy_ns(0, i)).sum();
            if summed != sr.nic_busy_ns(0) {
                return Err("nic_busy_ns must be the per-rail sum".into());
            }
            // Each of the <= rails pieces rounds its wire time up at most
            // 1 ns.
            if summed.abs_diff(single) > rails as u64 {
                return Err(format!(
                    "bytes={bytes} rails={rails}: summed per-rail busy {summed} vs \
                     single-rail {single}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sub_chunk_traffic_byte_identical_across_rails() {
    prop_run(
        Config { cases: 80, seed: 63 },
        |r| {
            // A burst of latency-bound messages (all under one 512-byte
            // chunk) from random sources at random priorities.
            let k = 1 + r.usize_below(6);
            let msgs: Vec<MsgDesc> = (0..k)
                .map(|i| {
                    let src = r.usize_below(4);
                    let dst = (src + 1 + r.usize_below(3)) % 4;
                    MsgDesc {
                        src,
                        dst,
                        bytes: 1 + r.below(511),
                        priority: r.below(4) as u8,
                        tag: i as u64,
                    }
                })
                .collect();
            msgs
        },
        |msgs| {
            // Sub-chunk messages ride one rail: the full delivery event
            // stream must be byte-identical at every rail count — the
            // "zero regression for latency-bound sizes" guarantee.
            let mut reference = None;
            for &rails in &RAILS {
                let mut sim = NetSim::new(topo(rails, 100), 4);
                for m in msgs {
                    sim.send(m.clone());
                }
                let events = sim.drain();
                match &reference {
                    None => reference = Some(events),
                    Some(want) => {
                        if &events != want {
                            return Err(format!("rails={rails}: event stream diverged"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

//! Node partitioning for the parallel simulator: shard assignment and
//! cross-partition mail.
//!
//! The partitioned simulator ([`crate::collectives::parexec`]) splits the
//! fabric into `shards` independently-advancing [`super::NetSim`]
//! instances. The split is by **contiguous node blocks** — never through
//! a shared-memory node — so every rank's egress servers (NIC rails and
//! shm channel) live wholly on one shard and a cross-shard hop is always
//! a NIC-tier hop. That is the property conservative lookahead leans on:
//! every cross-shard message spends at least
//! [`Topology::lookahead_ns`](super::Topology::lookahead_ns) in flight,
//! so a shard may execute all local events strictly before
//! `min(shard clocks) + lookahead` without ever receiving mail in its
//! past. See `docs/ARCHITECTURE.md` §"Partitioned mode".

use super::topology::Topology;
use super::MsgDesc;
use crate::{Ns, Rank};

/// A cross-partition message in coordinator custody: it left the wire on
/// the source shard at `egress_at` and must be delivered on the
/// destination shard at `at` (in-flight latency already priced by the
/// source shard, chaos flaps included).
///
/// `egress_at` exists purely for determinism: the coordinator sorts mail
/// by `(at, egress_at, src, dst, tag)` before injection so delivery-time
/// ties resolve identically on every run, independent of shard count and
/// thread scheduling.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mail {
    /// Absolute delivery time at `msg.dst`.
    pub at: Ns,
    /// Absolute time the last egress piece left the source wire.
    pub egress_at: Ns,
    pub msg: MsgDesc,
}

/// Deterministic sort key making mail injection order a pure function of
/// the mail set (never of shard iteration or thread completion order).
pub fn mail_key(m: &Mail) -> (Ns, Ns, Rank, Rank, u64) {
    (m.at, m.egress_at, m.msg.src, m.msg.dst, m.msg.tag)
}

/// Number of shared-memory nodes a `p`-rank fabric on `topo` has (the
/// unit of partitioning: a node is never split across shards).
pub fn num_nodes(topo: &Topology, p: usize) -> usize {
    let rpn = topo.ranks_per_node().max(1);
    p.div_ceil(rpn)
}

/// Which shard of a `shards`-way partition owns `rank`.
///
/// Nodes are split into `shards` contiguous, balanced blocks (block `s`
/// spans nodes `[s·nodes/shards, (s+1)·nodes/shards)`, so block sizes
/// differ by at most one and some blocks are empty when
/// `shards > nodes`). All ranks of one node map to one shard by
/// construction, keeping shm traffic shard-local.
pub fn shard_of(topo: &Topology, p: usize, shards: usize, rank: Rank) -> usize {
    assert!(shards >= 1, "at least one shard");
    assert!(rank < p, "rank {rank} of {p}");
    let nodes = num_nodes(topo, p).max(1);
    let node = topo.node_of(rank);
    // Inverse of the balanced-block boundary b(s) = s·nodes/shards:
    // the unique s with b(s) <= node < b(s+1).
    ((node + 1) * shards - 1) / nodes
}

/// Ranks owned by shard `shard` (ascending). The concatenation over all
/// shards is exactly `0..p`.
pub fn ranks_of(topo: &Topology, p: usize, shards: usize, shard: usize) -> Vec<Rank> {
    (0..p).filter(|&r| shard_of(topo, p, shards, r) == shard).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_ranks_split_into_contiguous_balanced_blocks() {
        let topo = Topology::flat("t", 8.0, 1_000, 100, 1 << 20);
        // 4 ranks, 2 shards: {0,1} and {2,3} (pinned by the sim.rs
        // partition test too).
        let s: Vec<usize> = (0..4).map(|r| shard_of(&topo, 4, 2, r)).collect();
        assert_eq!(s, vec![0, 0, 1, 1]);
        // 5 ranks, 2 shards: sizes differ by at most one.
        let s: Vec<usize> = (0..5).map(|r| shard_of(&topo, 5, 2, r)).collect();
        assert_eq!(s, vec![0, 0, 1, 1, 1]);
        // More shards than nodes: some shards own nothing, all ranks owned.
        let s: Vec<usize> = (0..2).map(|r| shard_of(&topo, 2, 4, r)).collect();
        assert_eq!(s, vec![1, 3]);
        assert!(ranks_of(&topo, 2, 4, 0).is_empty());
        assert_eq!(ranks_of(&topo, 2, 4, 1), vec![0]);
    }

    #[test]
    fn shm_nodes_are_never_split() {
        let topo = Topology::eth_10g_smp(4); // 4 ranks/node
        for p in [4usize, 8, 12, 16, 20] {
            for shards in 1..=5usize {
                for r in 0..p {
                    let peer = (r / 4) * 4; // first rank of r's node
                    assert_eq!(
                        shard_of(&topo, p, shards, r),
                        shard_of(&topo, p, shards, peer),
                        "p={p} shards={shards} rank={r}"
                    );
                }
            }
        }
    }

    #[test]
    fn every_rank_is_owned_exactly_once_and_blocks_are_monotonic() {
        let topo = Topology::eth_10g_smp(2);
        for p in [2usize, 6, 10, 64] {
            for shards in [1usize, 2, 3, 4, 7] {
                let owners: Vec<usize> =
                    (0..p).map(|r| shard_of(&topo, p, shards, r)).collect();
                assert!(owners.iter().all(|&s| s < shards));
                assert!(owners.windows(2).all(|w| w[0] <= w[1]), "{owners:?}");
                let total: usize =
                    (0..shards).map(|s| ranks_of(&topo, p, shards, s).len()).sum();
                assert_eq!(total, p);
            }
        }
    }

    #[test]
    fn mail_sorts_deterministically() {
        let msg = |src, dst, tag| MsgDesc { src, dst, bytes: 8, priority: 1, tag };
        let mut mail = vec![
            Mail { at: 20, egress_at: 10, msg: msg(1, 2, 5) },
            Mail { at: 10, egress_at: 9, msg: msg(3, 0, 1) },
            Mail { at: 10, egress_at: 2, msg: msg(2, 0, 4) },
            Mail { at: 10, egress_at: 9, msg: msg(0, 3, 0) },
        ];
        mail.sort_by_key(mail_key);
        let tags: Vec<u64> = mail.iter().map(|m| m.msg.tag).collect();
        assert_eq!(tags, vec![4, 0, 1, 5]);
    }
}

//! Size-adaptive algorithm selection — the paper's "implements performance
//! critical data path operations in an optimal manner".
//!
//! The choice is driven by an N-LEVEL alpha-beta cost model on the actual
//! fabric. With contiguous grouping at every tier (group = rank /
//! tier.ranks), a hop at XOR-partner distance d provably stays inside a
//! tier of size s only when s is a power of two and d < s; each level has
//! its own alpha (latency + overhead) and beta⁻¹ (bandwidth):
//!
//! * ring allreduce:            2(P−1)·(α + (n/P)/B), gated by its slowest
//!   hops — the innermost tier containing the whole ring, or the top;
//! * recursive doubling:        Σ over rounds d of (α_d + n/B_d);
//! * halving-doubling:          Σ over rounds d of 2·(α_d + (n·d/P)/B_d);
//! * hierarchical (groups g₁ ⊆ g₂ ⊆ …): per level, 2·⌈log₂(gᵢ/gᵢ₋₁)⌉
//!   full-buffer rounds priced at the tier containing a gᵢ-group, plus a
//!   flat allreduce among the P/g_k outermost leaders whose hops all pay
//!   the top tier.
//!
//! Small n → latency term dominates → fewest rounds (recursive doubling).
//! Large n → bandwidth term dominates → ring / halving-doubling. Deep
//! tier stacks → hierarchical (O(P/g_k) slow-tier steps instead of O(P));
//! the selector considers every prefix of the tier stack that divides P,
//! so a rack-oversubscribed fabric can pick a 3-level reduction. On flat
//! fabrics (empty tier stack) every formula collapses to the classic
//! single-tier model.
//!
//! **Multi-rail fabrics**: every bandwidth term above is divided by the
//! rails the message actually occupies ([`Topology::stripe_count`] —
//! the level's rail count capped by whole chunks in flight, exactly the
//! striping `fabric::sim` executes), while the alpha terms are NEVER
//! discounted: a striped transfer still pays one overhead and one
//! latency. Sub-chunk latency-bound messages therefore price (and run)
//! identically to the single-rail fabric.
//!
//! **Wire precision**: the `_wire` variants price the same hop chains
//! with [`WireDtype`]-compressed bytes in every bandwidth term (alpha
//! still never discounted — compression cannot shrink a latency) plus a
//! per-hop endpoint (de)quantize charge ([`quant_hop_ns`]: fixed setup +
//! per-element term, chaos-compute-slowdown-inclusive). The candidate
//! grid becomes (algorithm × wire dtype): f32 keeps winning latency-bound
//! cells where the quantize setup dwarfs the byte saving, bf16/int8 take
//! over once per-hop payloads outgrow their crossover sizes — which
//! [`compression_crossover_bytes`] locates by bisection so the tuning
//! probe can straddle them.

use super::quant::{quant_hop_ns, WireDtype};
use super::Algorithm;
use crate::fabric::gbps_to_bytes_per_ns;
use crate::fabric::topology::Topology;
use crate::Ns;

/// Per-message fixed cost of a level (latency + injection overhead), ns.
fn alpha(topo: &Topology, level: usize) -> f64 {
    (topo.latency_at(level) + topo.overhead_at(level)) as f64
}

/// Bandwidth of a level, bytes/ns.
fn bw(topo: &Topology, level: usize) -> f64 {
    gbps_to_bytes_per_ns(topo.gbps_at(level))
}

/// Rail-aware EFFECTIVE bandwidth of a level for one message of
/// `msg_bytes`: the per-rail line rate times the rails the transfer
/// actually occupies ([`Topology::stripe_count`] — the level's rail
/// count, capped by whole chunks in flight). Striping divides only the
/// bandwidth term: sub-chunk latency-bound messages get factor 1, and
/// alpha is NEVER discounted (the per-message overhead and latency are
/// paid once regardless of rails — see `fabric::sim`).
fn eff_bw(topo: &Topology, level: usize, msg_bytes: f64) -> f64 {
    bw(topo, level) * topo.stripe_count(level, msg_bytes.max(0.0) as u64) as f64
}

/// How a flat algorithm's participants sit on the fabric, for pricing.
#[derive(Clone, Copy)]
enum Layout {
    /// Participant i is rank base + i·spacing for an aligned contiguous
    /// base: a full communicator (spacing 1) or the leaders of a
    /// hierarchical phase (spacing = their group size, itself a tier
    /// size — so it divides every outer tier).
    Spaced(usize),
    /// Strided / unknown placement: every hop pays the top tier.
    AllTop,
}

/// Level of an XOR-distance-`d` exchange between participants spaced
/// `s` ranks apart under contiguous grouping. The partner `i ^ d` (rank
/// distance ≤ (2d−1)·s) provably stays inside a tier of size R ONLY
/// when s divides R, R/s is a power of two (participant group = index
/// >> log2(R/s)) and d < R/s; otherwise be conservative and price the
/// hop at the next level out (ultimately the top).
fn level_at(topo: &Topology, d: usize, layout: Layout) -> usize {
    let Layout::Spaced(s) = layout else { return topo.top_level() };
    topo.tiers
        .iter()
        .position(|t| {
            t.ranks % s == 0 && (t.ranks / s).is_power_of_two() && d < t.ranks / s
        })
        .unwrap_or_else(|| topo.top_level())
}

/// Innermost level whose tier contains the whole `p`-participant span
/// (p·spacing ranks) — what gates a lockstep ring.
fn ring_level(topo: &Topology, p: usize, layout: Layout) -> usize {
    let Layout::Spaced(s) = layout else { return topo.top_level() };
    topo.tiers
        .iter()
        .position(|t| p.saturating_mul(s) <= t.ranks)
        .unwrap_or_else(|| topo.top_level())
}

/// Predicted wall time (ns, unrounded) of a FLAT algorithm over `p`
/// participants placed per `layout`. [`Layout::AllTop`] is the strided-
/// communicator model (member distance says nothing about co-location);
/// [`Layout::Spaced`] gives XOR rounds and contained rings their true
/// tier — on a rack fabric, a leader phase's small-distance rounds stay
/// in-rack exactly like the built program's hops do in the simulator.
fn flat_cost(topo: &Topology, alg: Algorithm, p: usize, n: f64, layout: Layout) -> f64 {
    let pf = p as f64;
    match alg {
        Algorithm::Ring => {
            // Lockstep pipeline: each step is gated by its slowest hop —
            // the deepest tier containing the whole ring. Per-step
            // segments of n/p bytes stripe across the level's rails.
            let l = ring_level(topo, p, layout);
            let m = n / pf;
            2.0 * (pf - 1.0) * (alpha(topo, l) + m / eff_bw(topo, l, m))
        }
        Algorithm::RecursiveDoubling => {
            let mut total = 0.0;
            let mut d = 1;
            while d < p {
                let l = level_at(topo, d, layout);
                total += alpha(topo, l) + n / eff_bw(topo, l, n);
                d <<= 1;
            }
            total
        }
        Algorithm::HalvingDoubling => {
            // Reduce-scatter halving + mirrored allgather doubling: the
            // round at partner distance d moves n·d/p bytes, twice.
            let mut total = 0.0;
            let mut d = p / 2;
            while d >= 1 {
                let l = level_at(topo, d, layout);
                let m = n * d as f64 / pf;
                total += 2.0 * (alpha(topo, l) + m / eff_bw(topo, l, m));
                d /= 2;
            }
            total
        }
        _ => f64::INFINITY,
    }
}

/// Is a hierarchical group stack usable at `p` ranks? (Outermost group
/// divides p; nesting divisibility is a [`super::GroupStack`] invariant.)
fn hier_valid(groups: &super::GroupStack, p: usize) -> bool {
    let g = groups.outermost();
    g >= 1 && p % g == 0
}

/// Cost of the up-reduce + down-broadcast tree pair at every level of a
/// hierarchical stack (everything except the top leader phase): per level
/// i, 2·⌈log₂(gᵢ/gᵢ₋₁)⌉ full-buffer rounds priced at the innermost tier
/// containing a gᵢ-group.
fn hier_tree_cost(topo: &Topology, groups: &super::GroupStack, n: f64) -> f64 {
    let mut total = 0.0;
    let mut prev = 1usize;
    for g in groups.iter() {
        let branch = g / prev.max(1);
        if branch > 1 {
            let rounds = (branch as f64).log2().ceil();
            let l = topo.level_for_group(g);
            total += 2.0 * rounds * (alpha(topo, l) + n / eff_bw(topo, l, n));
        }
        prev = g;
    }
    total
}

/// Predicted wall time of an allreduce of `bytes` over `p` ranks.
pub fn predict_allreduce_ns(topo: &Topology, alg: Algorithm, p: usize, bytes: u64) -> Ns {
    if p <= 1 {
        return 0;
    }
    let n = bytes as f64;
    let t = match alg {
        Algorithm::Ring | Algorithm::RecursiveDoubling | Algorithm::HalvingDoubling => {
            flat_cost(topo, alg, p, n, Layout::Spaced(1))
        }
        Algorithm::Hierarchical { groups } => {
            if !hier_valid(&groups, p) {
                // Invalid grouping: never the cheapest choice.
                return Ns::MAX / 4;
            }
            let leaders = p / groups.outermost();
            // The top algorithm is exactly what program::build will emit;
            // its participants are the outermost leaders, spaced one
            // outermost group apart — XOR rounds between leaders of the
            // same rack (say) still ride the rack tier, exactly as the
            // built program's hops do in the simulator.
            let inner = super::program::hierarchical_inner(leaders);
            let top = if leaders > 1 {
                flat_cost(topo, inner, leaders, n, Layout::Spaced(groups.outermost()))
            } else {
                0.0
            };
            hier_tree_cost(topo, &groups, n) + top
        }
        Algorithm::Auto => {
            let best = choose_algorithm(topo, p, bytes);
            return predict_allreduce_ns(topo, best, p, bytes);
        }
    };
    t.ceil() as Ns
}

/// Flat algorithms legal at this rank count.
fn flat_candidates(p: usize) -> Vec<Algorithm> {
    let mut c = vec![Algorithm::Ring];
    if p.is_power_of_two() {
        c.push(Algorithm::RecursiveDoubling);
        c.push(Algorithm::HalvingDoubling);
    }
    c
}

/// Hierarchical candidates at this (fabric, p): one stack per PREFIX of
/// the topology's tier sizes that divide `p` (a 3-level fabric offers
/// both the node-only and the node+rack stack). Shared by the allreduce
/// and allgather candidate menus so the two can never desynchronize.
fn hier_prefix_candidates(topo: &Topology, p: usize) -> Vec<Algorithm> {
    let stack = topo.hier_group_sizes_for(p);
    (1..=stack.len())
        .filter_map(|depth| Algorithm::try_hier(&stack[..depth]))
        .collect()
}

/// Every allreduce algorithm the selector considers at this (fabric, p):
/// the flat set plus [`hier_prefix_candidates`], over contiguous
/// full-group communicators only. The tuning probe
/// ([`crate::tuner::probe`]) measures EXACTLY this set, so tuned tables
/// and the analytic chooser pick from the same menu.
pub fn candidate_algorithms(topo: &Topology, p: usize) -> Vec<Algorithm> {
    if p <= 1 {
        return vec![Algorithm::Ring];
    }
    let mut candidates = flat_candidates(p);
    candidates.extend(hier_prefix_candidates(topo, p));
    candidates
}

/// Pick the cheapest supported algorithm for this (fabric, p, bytes).
pub fn choose_algorithm(topo: &Topology, p: usize, bytes: u64) -> Algorithm {
    if p <= 1 {
        return Algorithm::Ring;
    }
    *candidate_algorithms(topo, p)
        .iter()
        .min_by_key(|a| predict_allreduce_ns(topo, **a, p, bytes))
        .unwrap()
}

/// Like [`predict_allreduce_ns`] but pricing EVERY hop at the top
/// tier. This is the correct model for communicators that do NOT occupy
/// contiguous ranks of the topology (e.g. the strided data-parallel
/// groups of a hybrid distribution): there, rank distance inside the
/// communicator says nothing about physical co-location, so no tier
/// discount may apply.
pub fn predict_flat_inter_allreduce_ns(
    topo: &Topology,
    alg: Algorithm,
    p: usize,
    bytes: u64,
) -> Ns {
    if p <= 1 {
        return 0;
    }
    match alg {
        Algorithm::Ring | Algorithm::RecursiveDoubling | Algorithm::HalvingDoubling => {
            flat_cost(topo, alg, p, bytes as f64, Layout::AllTop).ceil() as Ns
        }
        other => predict_allreduce_ns(topo, other, p, bytes),
    }
}

/// Like [`choose_algorithm`] but never hierarchical, and priced all
/// top-tier — for communicators whose members do not decompose into
/// whole groups at any level (e.g. the strided data-parallel groups of a
/// hybrid distribution).
pub fn choose_flat_algorithm(topo: &Topology, p: usize, bytes: u64) -> Algorithm {
    if p <= 1 {
        return Algorithm::Ring;
    }
    *flat_candidates(p)
        .iter()
        .min_by_key(|a| predict_flat_inter_allreduce_ns(topo, **a, p, bytes))
        .unwrap()
}

// ---------------------------------------------------------------------------
// Allgather pricing (activation exchanges)
// ---------------------------------------------------------------------------

/// Flat allgather algorithms legal at this rank count: ring always;
/// recursive doubling (block-doubling allgather, same volume in log₂ p
/// rounds) only at power-of-two rank counts.
pub fn flat_allgather_candidates(p: usize) -> Vec<Algorithm> {
    let mut c = vec![Algorithm::Ring];
    if p > 1 && p.is_power_of_two() {
        c.push(Algorithm::RecursiveDoubling);
    }
    c
}

/// Every allgather algorithm the selector considers at this (fabric, p)
/// over a fully-aligned contiguous communicator: the flat set plus the
/// same [`hier_prefix_candidates`] stacks as allreduce (gather up →
/// leaders allgather → broadcast down).
pub fn allgather_candidates(topo: &Topology, p: usize) -> Vec<Algorithm> {
    let mut c = flat_allgather_candidates(p);
    if p > 1 {
        c.extend(hier_prefix_candidates(topo, p));
    }
    c
}

/// N-level cost of a flat allgather of `n` total bytes over `p`
/// participants placed per `layout` (each contributes n/p).
fn allgather_flat_cost(topo: &Topology, alg: Algorithm, p: usize, n: f64, layout: Layout) -> f64 {
    let pf = p as f64;
    match alg {
        Algorithm::Ring => {
            // p−1 lockstep steps of n/p bytes, gated by the slowest hop.
            let l = ring_level(topo, p, layout);
            let m = n / pf;
            (pf - 1.0) * (alpha(topo, l) + m / eff_bw(topo, l, m))
        }
        Algorithm::RecursiveDoubling if p.is_power_of_two() => {
            // The round at partner distance d exchanges the held block of
            // n·d/p bytes; total volume matches the ring in log₂ p rounds.
            let mut total = 0.0;
            let mut d = 1;
            while d < p {
                let l = level_at(topo, d, layout);
                let m = n * d as f64 / pf;
                total += alpha(topo, l) + m / eff_bw(topo, l, m);
                d <<= 1;
            }
            total
        }
        _ => f64::INFINITY,
    }
}

/// Predicted wall time of an allgather of `bytes` (total buffer) over `p`
/// ranks, priced with the same N-level model as allreduce. Hierarchical
/// allgather: per level, the leader serially ingests its members'
/// segments, the leaders run the flat top allgather, and a full-buffer
/// binomial broadcast comes back down.
pub fn predict_allgather_ns(topo: &Topology, alg: Algorithm, p: usize, bytes: u64) -> Ns {
    if p <= 1 {
        return 0;
    }
    if alg == Algorithm::Auto {
        let best = choose_allgather_algorithm(topo, p, bytes);
        return predict_allgather_ns(topo, best, p, bytes);
    }
    let n = bytes as f64;
    let t = match alg {
        Algorithm::Hierarchical { groups } => {
            if !hier_valid(&groups, p) {
                return Ns::MAX / 4;
            }
            let mut total = 0.0;
            let mut prev = 1usize;
            for g in groups.iter() {
                let branch = g / prev.max(1);
                if branch > 1 {
                    let l = topo.level_for_group(g);
                    // Gather: branch−1 serialized messages of the
                    // member share each; broadcast down: ⌈log₂ branch⌉
                    // full-buffer rounds.
                    let share = n * prev as f64 / p as f64;
                    total +=
                        (branch as f64 - 1.0) * (alpha(topo, l) + share / eff_bw(topo, l, share));
                    let rounds = (branch as f64).log2().ceil();
                    total += rounds * (alpha(topo, l) + n / eff_bw(topo, l, n));
                }
                prev = g;
            }
            let leaders = p / groups.outermost();
            if leaders > 1 {
                let inner = super::program::hierarchical_ag_inner(leaders);
                total +=
                    allgather_flat_cost(topo, inner, leaders, n, Layout::Spaced(groups.outermost()));
            }
            total
        }
        other => allgather_flat_cost(topo, other, p, n, Layout::Spaced(1)),
    };
    if t.is_finite() {
        t.ceil() as Ns
    } else {
        Ns::MAX / 4
    }
}

/// Pick the cheapest allgather algorithm for this (fabric, p, bytes) over
/// a fully-aligned (contiguous whole-group) communicator.
pub fn choose_allgather_algorithm(topo: &Topology, p: usize, bytes: u64) -> Algorithm {
    if p <= 1 {
        return Algorithm::Ring;
    }
    *allgather_candidates(topo, p)
        .iter()
        .min_by_key(|a| predict_allgather_ns(topo, **a, p, bytes))
        .unwrap()
}

/// Like [`choose_allgather_algorithm`] but never hierarchical and priced
/// all top-tier — for communicators that do not decompose into whole
/// groups.
pub fn choose_flat_allgather_algorithm(topo: &Topology, p: usize, bytes: u64) -> Algorithm {
    if p <= 1 {
        return Algorithm::Ring;
    }
    *flat_allgather_candidates(p)
        .iter()
        .min_by_key(|a| allgather_flat_cost(topo, **a, p, bytes as f64, Layout::AllTop).ceil() as Ns)
        .unwrap()
}

// ---------------------------------------------------------------------------
// Wire precision: the (algorithm × wire-dtype) candidate grid
// ---------------------------------------------------------------------------

/// Elements carried by a gradient payload of `bytes`. Gradients live in
/// f32 — the WIRE format is what compresses — so `bytes` is always the
/// f32 buffer size and the element count is bytes/4.
fn payload_elems(bytes: u64) -> usize {
    (bytes as usize).div_ceil(4)
}

/// Per-round element count of a halving-doubling/block-doubling exchange
/// at partner distance `d` (n·d/p, overflow-safe).
fn round_elems(elems: usize, d: usize, p: usize) -> usize {
    ((elems as u128 * d as u128) / p as u128) as usize
}

/// Transport-only cost of a FLAT algorithm whose hops carry
/// `wire`-encoded segments: identical hop chain to [`flat_cost`], but
/// every bandwidth term sees [`WireDtype::wire_bytes`] of the segment's
/// ELEMENTS instead of 4 bytes each. Alpha is unchanged per hop. The
/// endpoint (de)quantize charge is priced separately ([`quant_chain_ns`])
/// so the tuner probe can add it to simulator-measured wire time.
fn flat_cost_wire(
    topo: &Topology,
    alg: Algorithm,
    p: usize,
    elems: usize,
    wire: WireDtype,
    layout: Layout,
) -> f64 {
    let pf = p as f64;
    match alg {
        Algorithm::Ring => {
            let l = ring_level(topo, p, layout);
            let m = wire.wire_bytes(elems.div_ceil(p)) as f64;
            2.0 * (pf - 1.0) * (alpha(topo, l) + m / eff_bw(topo, l, m))
        }
        Algorithm::RecursiveDoubling => {
            let m = wire.wire_bytes(elems) as f64;
            let mut total = 0.0;
            let mut d = 1;
            while d < p {
                let l = level_at(topo, d, layout);
                total += alpha(topo, l) + m / eff_bw(topo, l, m);
                d <<= 1;
            }
            total
        }
        Algorithm::HalvingDoubling => {
            let mut total = 0.0;
            let mut d = p / 2;
            while d >= 1 {
                let l = level_at(topo, d, layout);
                let m = wire.wire_bytes(round_elems(elems, d, p)) as f64;
                total += 2.0 * (alpha(topo, l) + m / eff_bw(topo, l, m));
                d /= 2;
            }
            total
        }
        _ => f64::INFINITY,
    }
}

/// Transport-only wire-compressed twin of [`hier_tree_cost`].
fn hier_tree_cost_wire(
    topo: &Topology,
    groups: &super::GroupStack,
    elems: usize,
    wire: WireDtype,
) -> f64 {
    let m = wire.wire_bytes(elems) as f64;
    let mut total = 0.0;
    let mut prev = 1usize;
    for g in groups.iter() {
        let branch = g / prev.max(1);
        if branch > 1 {
            let rounds = (branch as f64).log2().ceil();
            let l = topo.level_for_group(g);
            total += 2.0 * rounds * (alpha(topo, l) + m / eff_bw(topo, l, m));
        }
        prev = g;
    }
    total
}

/// Total modeled (de)quantize charge of one allreduce: the per-hop
/// [`quant_hop_ns`] terms summed over the algorithm's serialized hop
/// chain — exactly the hops the alpha terms count, so the charge lands
/// on the same critical path the transport model prices. Zero for f32
/// and for single ranks. `slowdown_milli` is the endpoint's chaos
/// compute-slowdown multiplier (1000 = healthy); a degraded rank
/// quantizes proportionally slower.
///
/// Public because the tuner probe adds this to simulator-MEASURED wire
/// time: `fabric::sim` moves the compressed bytes but never models
/// endpoint arithmetic.
pub fn quant_chain_ns(
    alg: Algorithm,
    p: usize,
    elems: usize,
    wire: WireDtype,
    slowdown_milli: u64,
) -> Ns {
    if p <= 1 || wire == WireDtype::F32 {
        return 0;
    }
    match alg {
        Algorithm::Ring => {
            2 * (p as u64 - 1) * quant_hop_ns(elems.div_ceil(p), wire, slowdown_milli)
        }
        Algorithm::RecursiveDoubling => {
            let rounds = usize::BITS - (p - 1).leading_zeros();
            rounds as u64 * quant_hop_ns(elems, wire, slowdown_milli)
        }
        Algorithm::HalvingDoubling => {
            let mut total = 0u64;
            let mut d = p / 2;
            while d >= 1 {
                total += 2 * quant_hop_ns(round_elems(elems, d, p), wire, slowdown_milli);
                d /= 2;
            }
            total
        }
        Algorithm::Hierarchical { groups } => {
            let mut total = 0u64;
            let mut prev = 1usize;
            for g in groups.iter() {
                let branch = g / prev.max(1);
                if branch > 1 {
                    let rounds = (branch as f64).log2().ceil() as u64;
                    total += 2 * rounds * quant_hop_ns(elems, wire, slowdown_milli);
                }
                prev = g;
            }
            let outer = groups.outermost().max(1);
            let leaders = p / outer;
            if leaders > 1 {
                let inner = super::program::hierarchical_inner(leaders);
                total += quant_chain_ns(inner, leaders, elems, wire, slowdown_milli);
            }
            total
        }
        Algorithm::Auto => 0,
    }
}

/// Wire-precision-aware [`predict_allreduce_ns`]: transport priced at
/// compressed wire bytes plus the [`quant_chain_ns`] endpoint charge.
/// f32 delegates to the plain model and is bit-identical to it.
pub fn predict_allreduce_ns_wire(
    topo: &Topology,
    alg: Algorithm,
    p: usize,
    bytes: u64,
    wire: WireDtype,
    slowdown_milli: u64,
) -> Ns {
    if wire == WireDtype::F32 {
        return predict_allreduce_ns(topo, alg, p, bytes);
    }
    if p <= 1 {
        return 0;
    }
    let elems = payload_elems(bytes);
    let transport = match alg {
        Algorithm::Ring | Algorithm::RecursiveDoubling | Algorithm::HalvingDoubling => {
            flat_cost_wire(topo, alg, p, elems, wire, Layout::Spaced(1))
        }
        Algorithm::Hierarchical { groups } => {
            if !hier_valid(&groups, p) {
                return Ns::MAX / 4;
            }
            let leaders = p / groups.outermost();
            let top = if leaders > 1 {
                let inner = super::program::hierarchical_inner(leaders);
                let layout = Layout::Spaced(groups.outermost());
                flat_cost_wire(topo, inner, leaders, elems, wire, layout)
            } else {
                0.0
            };
            hier_tree_cost_wire(topo, &groups, elems, wire) + top
        }
        Algorithm::Auto => {
            let (best, _) = choose_algorithm_wire(topo, p, bytes, &[wire], slowdown_milli);
            return predict_allreduce_ns_wire(topo, best, p, bytes, wire, slowdown_milli);
        }
    };
    transport.ceil() as Ns + quant_chain_ns(alg, p, elems, wire, slowdown_milli)
}

/// Wire-precision-aware [`predict_flat_inter_allreduce_ns`] (every hop
/// at the top tier — strided communicators).
pub fn predict_flat_inter_allreduce_ns_wire(
    topo: &Topology,
    alg: Algorithm,
    p: usize,
    bytes: u64,
    wire: WireDtype,
    slowdown_milli: u64,
) -> Ns {
    if wire == WireDtype::F32 {
        return predict_flat_inter_allreduce_ns(topo, alg, p, bytes);
    }
    if p <= 1 {
        return 0;
    }
    let elems = payload_elems(bytes);
    match alg {
        Algorithm::Ring | Algorithm::RecursiveDoubling | Algorithm::HalvingDoubling => {
            flat_cost_wire(topo, alg, p, elems, wire, Layout::AllTop).ceil() as Ns
                + quant_chain_ns(alg, p, elems, wire, slowdown_milli)
        }
        other => predict_allreduce_ns_wire(topo, other, p, bytes, wire, slowdown_milli),
    }
}

/// Pick the cheapest (algorithm, wire dtype) pair over the full
/// [`candidate_algorithms`] menu crossed with `wires`. Pass
/// [`WireDtype::ALL`] for automatic precision, a single-element slice
/// for a pinned `--wire-dtype`. Ties break toward the FIRST wire listed
/// (f32 first in `ALL`, so latency-bound ties stay uncompressed).
pub fn choose_algorithm_wire(
    topo: &Topology,
    p: usize,
    bytes: u64,
    wires: &[WireDtype],
    slowdown_milli: u64,
) -> (Algorithm, WireDtype) {
    let fallback_wire = wires.first().copied().unwrap_or_default();
    if p <= 1 || wires.is_empty() {
        return (Algorithm::Ring, fallback_wire);
    }
    let algs = candidate_algorithms(topo, p);
    let mut best = (algs[0], fallback_wire);
    let mut best_t = Ns::MAX;
    for w in wires {
        for a in &algs {
            let t = predict_allreduce_ns_wire(topo, *a, p, bytes, *w, slowdown_milli);
            if t < best_t {
                best_t = t;
                best = (*a, *w);
            }
        }
    }
    best
}

/// Like [`choose_algorithm_wire`] but never hierarchical and priced
/// all top-tier — strided communicators.
pub fn choose_flat_algorithm_wire(
    topo: &Topology,
    p: usize,
    bytes: u64,
    wires: &[WireDtype],
    slowdown_milli: u64,
) -> (Algorithm, WireDtype) {
    let fallback_wire = wires.first().copied().unwrap_or_default();
    if p <= 1 || wires.is_empty() {
        return (Algorithm::Ring, fallback_wire);
    }
    let algs = flat_candidates(p);
    let mut best = (algs[0], fallback_wire);
    let mut best_t = Ns::MAX;
    for w in wires {
        for a in &algs {
            let t = predict_flat_inter_allreduce_ns_wire(topo, *a, p, bytes, *w, slowdown_milli);
            if t < best_t {
                best_t = t;
                best = (*a, *w);
            }
        }
    }
    best
}

/// Wire-compressed flat allgather cost WITH the per-hop quantize charge
/// inlined (allgather hops relay already-encoded blocks; bf16
/// re-truncation and int8 re-quantization of decoded payloads are
/// idempotent, so one encode+decode per hop is the right charge).
fn allgather_flat_cost_wire(
    topo: &Topology,
    alg: Algorithm,
    p: usize,
    elems: usize,
    wire: WireDtype,
    layout: Layout,
    slowdown_milli: u64,
) -> f64 {
    let pf = p as f64;
    match alg {
        Algorithm::Ring => {
            let l = ring_level(topo, p, layout);
            let e = elems.div_ceil(p);
            let m = wire.wire_bytes(e) as f64;
            let q = quant_hop_ns(e, wire, slowdown_milli) as f64;
            (pf - 1.0) * (alpha(topo, l) + m / eff_bw(topo, l, m) + q)
        }
        Algorithm::RecursiveDoubling if p.is_power_of_two() => {
            let mut total = 0.0;
            let mut d = 1;
            while d < p {
                let l = level_at(topo, d, layout);
                let e = round_elems(elems, d, p);
                let m = wire.wire_bytes(e) as f64;
                total += alpha(topo, l)
                    + m / eff_bw(topo, l, m)
                    + quant_hop_ns(e, wire, slowdown_milli) as f64;
                d <<= 1;
            }
            total
        }
        _ => f64::INFINITY,
    }
}

/// Wire-precision-aware [`predict_allgather_ns`]. f32 delegates to the
/// plain model and is bit-identical to it.
pub fn predict_allgather_ns_wire(
    topo: &Topology,
    alg: Algorithm,
    p: usize,
    bytes: u64,
    wire: WireDtype,
    slowdown_milli: u64,
) -> Ns {
    if wire == WireDtype::F32 {
        return predict_allgather_ns(topo, alg, p, bytes);
    }
    if p <= 1 {
        return 0;
    }
    if alg == Algorithm::Auto {
        let (best, _) = choose_allgather_algorithm_wire(topo, p, bytes, &[wire], slowdown_milli);
        return predict_allgather_ns_wire(topo, best, p, bytes, wire, slowdown_milli);
    }
    let elems = payload_elems(bytes);
    let t = match alg {
        Algorithm::Hierarchical { groups } => {
            if !hier_valid(&groups, p) {
                return Ns::MAX / 4;
            }
            let mut total = 0.0;
            let mut prev = 1usize;
            for g in groups.iter() {
                let branch = g / prev.max(1);
                if branch > 1 {
                    let l = topo.level_for_group(g);
                    let se = round_elems(elems, prev, p);
                    let share = wire.wire_bytes(se) as f64;
                    total += (branch as f64 - 1.0)
                        * (alpha(topo, l)
                            + share / eff_bw(topo, l, share)
                            + quant_hop_ns(se, wire, slowdown_milli) as f64);
                    let rounds = (branch as f64).log2().ceil();
                    let m = wire.wire_bytes(elems) as f64;
                    total += rounds
                        * (alpha(topo, l)
                            + m / eff_bw(topo, l, m)
                            + quant_hop_ns(elems, wire, slowdown_milli) as f64);
                }
                prev = g;
            }
            let leaders = p / groups.outermost();
            if leaders > 1 {
                let inner = super::program::hierarchical_ag_inner(leaders);
                total += allgather_flat_cost_wire(
                    topo,
                    inner,
                    leaders,
                    elems,
                    wire,
                    Layout::Spaced(groups.outermost()),
                    slowdown_milli,
                );
            }
            total
        }
        other => {
            allgather_flat_cost_wire(topo, other, p, elems, wire, Layout::Spaced(1), slowdown_milli)
        }
    };
    if t.is_finite() {
        t.ceil() as Ns
    } else {
        Ns::MAX / 4
    }
}

/// Pick the cheapest (allgather algorithm, wire dtype) pair over
/// [`allgather_candidates`] × `wires`.
pub fn choose_allgather_algorithm_wire(
    topo: &Topology,
    p: usize,
    bytes: u64,
    wires: &[WireDtype],
    slowdown_milli: u64,
) -> (Algorithm, WireDtype) {
    let fallback_wire = wires.first().copied().unwrap_or_default();
    if p <= 1 || wires.is_empty() {
        return (Algorithm::Ring, fallback_wire);
    }
    let algs = allgather_candidates(topo, p);
    let mut best = (algs[0], fallback_wire);
    let mut best_t = Ns::MAX;
    for w in wires {
        for a in &algs {
            let t = predict_allgather_ns_wire(topo, *a, p, bytes, *w, slowdown_milli);
            if t < best_t {
                best_t = t;
                best = (*a, *w);
            }
        }
    }
    best
}

/// Like [`choose_allgather_algorithm_wire`] but never hierarchical and
/// priced all top-tier.
pub fn choose_flat_allgather_algorithm_wire(
    topo: &Topology,
    p: usize,
    bytes: u64,
    wires: &[WireDtype],
    slowdown_milli: u64,
) -> (Algorithm, WireDtype) {
    let fallback_wire = wires.first().copied().unwrap_or_default();
    if p <= 1 || wires.is_empty() {
        return (Algorithm::Ring, fallback_wire);
    }
    let algs = flat_allgather_candidates(p);
    let elems = payload_elems(bytes);
    let mut best = (algs[0], fallback_wire);
    let mut best_t = Ns::MAX;
    for w in wires {
        for a in &algs {
            let t =
                allgather_flat_cost_wire(topo, *a, p, elems, *w, Layout::AllTop, slowdown_milli);
            let t = if t.is_finite() { t.ceil() as Ns } else { Ns::MAX / 4 };
            if t < best_t {
                best_t = t;
                best = (*a, *w);
            }
        }
    }
    best
}

/// Smallest payload (bytes) at which `wire` first beats f32 — comparing
/// best-over-candidates at each precision, i.e. the measured quantity
/// `mlsl tune` reports as the precision's crossover. Located by
/// bisection up to 1 GiB; `None` when the precision never wins below
/// that cap (fast fabrics where the per-element quantize cost outruns
/// the byte saving — compression is not free lunch on 100 Gb links).
pub fn compression_crossover_bytes(topo: &Topology, p: usize, wire: WireDtype) -> Option<u64> {
    if wire == WireDtype::F32 || p <= 1 {
        return None;
    }
    let algs = candidate_algorithms(topo, p);
    let wins = |bytes: u64| {
        let best_w = algs
            .iter()
            .map(|a| predict_allreduce_ns_wire(topo, *a, p, bytes, wire, 1000))
            .min()
            .unwrap();
        let best_f = algs
            .iter()
            .map(|a| predict_allreduce_ns(topo, *a, p, bytes))
            .min()
            .unwrap();
        best_w < best_f
    };
    let cap: u64 = 1 << 30;
    if !wins(cap) {
        return None;
    }
    let mut lo: u64 = 1;
    if wins(lo) {
        return Some(lo);
    }
    let mut hi = cap;
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if wins(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// The compression crossover sizes of every non-f32 wire dtype at this
/// (fabric, p), ascending and deduplicated — the probe size grid adds
/// these so tuned tables bracket each precision handover.
pub fn compression_crossover_sizes(topo: &Topology, p: usize) -> Vec<u64> {
    let mut out: Vec<u64> = WireDtype::ALL
        .iter()
        .filter_map(|w| compression_crossover_bytes(topo, p, *w))
        .collect();
    out.sort_unstable();
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_messages_pick_fewest_rounds() {
        let topo = Topology::eth_10g();
        // 4 KB over 64 ranks: latency-bound -> recursive doubling.
        assert_eq!(choose_algorithm(&topo, 64, 4 * 1024), Algorithm::RecursiveDoubling);
    }

    #[test]
    fn large_messages_pick_bandwidth_optimal() {
        let topo = Topology::eth_10g();
        let alg = choose_algorithm(&topo, 64, 256 << 20);
        assert!(
            matches!(alg, Algorithm::Ring | Algorithm::HalvingDoubling),
            "{alg:?}"
        );
    }

    #[test]
    fn non_pow2_always_ring() {
        let topo = Topology::omnipath_100g();
        assert_eq!(choose_algorithm(&topo, 6, 1024), Algorithm::Ring);
        assert_eq!(choose_algorithm(&topo, 100, 1 << 20), Algorithm::Ring);
    }

    #[test]
    fn non_pow2_never_selects_doubling_even_on_smp_fabrics() {
        // The power-of-two precondition must hold regardless of tiers.
        for topo in [
            Topology::eth_10g(),
            Topology::eth_10g_smp(2),
            Topology::eth_10g_smp(4),
            Topology::omnipath_100g_smp(2),
        ] {
            for p in [3usize, 6, 12, 24, 48, 96, 100] {
                for bytes in [256u64, 64 << 10, 1 << 20, 64 << 20] {
                    let alg = choose_algorithm(&topo, p, bytes);
                    assert!(
                        !matches!(
                            alg,
                            Algorithm::RecursiveDoubling | Algorithm::HalvingDoubling
                        ),
                        "{} p={p} bytes={bytes}: {alg:?}",
                        topo.name
                    );
                }
            }
        }
    }

    #[test]
    fn hierarchical_requires_multirank_nodes() {
        // Flat fabrics must NEVER select hierarchical, at any size.
        for topo in [Topology::eth_10g(), Topology::eth_25g(), Topology::omnipath_100g()] {
            for p in [2usize, 6, 16, 64, 96, 256] {
                for bytes in [256u64, 64 << 10, 16 << 20, 256 << 20] {
                    let alg = choose_algorithm(&topo, p, bytes);
                    assert!(
                        !matches!(alg, Algorithm::Hierarchical { .. }),
                        "{} p={p} bytes={bytes}: {alg:?}",
                        topo.name
                    );
                }
            }
        }
    }

    #[test]
    fn hierarchical_requires_dividing_node_size() {
        let topo = Topology::eth_10g_smp(4);
        // p not a multiple of ranks_per_node: hierarchical is not legal.
        for p in [6usize, 13, 30] {
            for bytes in [1u64 << 10, 16 << 20] {
                let alg = choose_algorithm(&topo, p, bytes);
                assert!(!matches!(alg, Algorithm::Hierarchical { .. }), "p={p}: {alg:?}");
            }
        }
    }

    #[test]
    fn hierarchical_wins_on_smp_fabric_for_nonpow2_worlds() {
        // 96 ranks at 2/node on 10GbE: the only flat option is ring
        // (non-pow2); hierarchical halves the inter-node step count and
        // must win across sizes.
        let topo = Topology::eth_10g_smp(2);
        for bytes in [64u64 << 10, 1 << 20, 16 << 20] {
            let alg = choose_algorithm(&topo, 96, bytes);
            assert_eq!(alg, Algorithm::hier(&[2]), "bytes={bytes}");
            let flat = predict_allreduce_ns(&topo, Algorithm::Ring, 96, bytes);
            let hier = predict_allreduce_ns(&topo, alg, 96, bytes);
            assert!(hier < flat, "bytes={bytes}: hier={hier} flat={flat}");
        }
    }

    #[test]
    fn strided_pricing_never_gets_the_intra_discount() {
        // A strided communicator's hops all cross nodes: the all-top
        // model must agree with the flat fabric (identical NIC params)…
        let smp = Topology::eth_10g_smp(4);
        let flat = Topology::eth_10g();
        for alg in [Algorithm::Ring, Algorithm::RecursiveDoubling, Algorithm::HalvingDoubling] {
            for p in [4usize, 8, 16] {
                for bytes in [1u64 << 10, 1 << 20] {
                    assert_eq!(
                        predict_flat_inter_allreduce_ns(&smp, alg, p, bytes),
                        predict_allreduce_ns(&flat, alg, p, bytes),
                        "{alg:?} p={p} bytes={bytes}"
                    );
                }
            }
        }
        // …while the contiguous model rightly discounts a ring that fits
        // inside one node. The strided model must not inherit that.
        let b = 1u64 << 20;
        assert!(
            predict_flat_inter_allreduce_ns(&smp, Algorithm::Ring, 4, b)
                > predict_allreduce_ns(&smp, Algorithm::Ring, 4, b)
        );
    }

    #[test]
    fn non_pow2_node_sizes_price_doubling_rounds_inter() {
        // With 3 ranks/node the XOR partner at distance 1 or 2 can cross
        // a node boundary, so the contiguous model must fall back to
        // top-tier pricing — identical to the flat fabric.
        let smp = Topology::eth_10g_smp(3);
        let flat = Topology::eth_10g();
        for alg in [Algorithm::RecursiveDoubling, Algorithm::HalvingDoubling] {
            assert_eq!(
                predict_allreduce_ns(&smp, alg, 16, 1 << 20),
                predict_allreduce_ns(&flat, alg, 16, 1 << 20),
                "{alg:?}"
            );
        }
    }

    #[test]
    fn choose_flat_never_returns_hierarchical() {
        let topo = Topology::eth_10g_smp(4);
        for p in [8usize, 64, 96] {
            for bytes in [1u64 << 10, 16 << 20] {
                let alg = choose_flat_algorithm(&topo, p, bytes);
                assert!(!matches!(alg, Algorithm::Hierarchical { .. }), "p={p}: {alg:?}");
            }
        }
    }

    #[test]
    fn hierarchical_prediction_counts_both_tiers() {
        let topo = Topology::eth_10g_smp(2);
        let bytes = 1u64 << 20;
        let hier = predict_allreduce_ns(&topo, Algorithm::hier(&[2]), 64, bytes);
        // Must exceed the leaders-only flat phase (32 inter ranks)...
        let leaders_only = predict_allreduce_ns(&topo, Algorithm::HalvingDoubling, 32, bytes);
        assert!(hier > leaders_only, "hier={hier} leaders={leaders_only}");
        // ...but stay below the same algorithm run flat over all 64 ranks
        // on the inter tier (the whole point of the hierarchy).
        let flat_ring = predict_allreduce_ns(&topo, Algorithm::Ring, 64, bytes);
        assert!(hier < flat_ring, "hier={hier} flat_ring={flat_ring}");
    }

    #[test]
    fn invalid_hierarchical_grouping_is_never_cheapest() {
        let topo = Topology::eth_10g_smp(2);
        let cost = predict_allreduce_ns(&topo, Algorithm::hier(&[5]), 8, 1024);
        assert!(cost > predict_allreduce_ns(&topo, Algorithm::Ring, 8, 1024));
    }

    #[test]
    fn prediction_monotone_in_size() {
        let topo = Topology::omnipath_100g();
        for alg in [Algorithm::Ring, Algorithm::RecursiveDoubling, Algorithm::HalvingDoubling] {
            let a = predict_allreduce_ns(&topo, alg, 16, 1 << 10);
            let b = predict_allreduce_ns(&topo, alg, 16, 1 << 24);
            assert!(b > a, "{alg:?}");
        }
    }

    #[test]
    fn single_rank_is_free() {
        let topo = Topology::eth_10g();
        assert_eq!(predict_allreduce_ns(&topo, Algorithm::Auto, 1, 1 << 20), 0);
    }

    #[test]
    fn crossover_exists() {
        // Sweeping sizes must switch algorithms somewhere (the A4 bench
        // regenerates the full crossover table).
        let topo = Topology::eth_10g();
        let small = choose_algorithm(&topo, 32, 1024);
        let large = choose_algorithm(&topo, 32, 64 << 20);
        assert_ne!(small, large);
    }

    #[test]
    fn allgather_rdoubling_wins_at_pow2_ring_otherwise() {
        let topo = Topology::eth_10g();
        // Same volume, fewer latency rounds: rd must win for p > 2…
        for bytes in [1u64 << 10, 1 << 20, 64 << 20] {
            assert_eq!(
                choose_allgather_algorithm(&topo, 32, bytes),
                Algorithm::RecursiveDoubling,
                "bytes={bytes}"
            );
        }
        // …and non-power-of-two rank counts only have the ring.
        for p in [3usize, 6, 12, 100] {
            assert_eq!(choose_allgather_algorithm(&topo, p, 1 << 20), Algorithm::Ring, "p={p}");
        }
    }

    #[test]
    fn allgather_prediction_monotone_and_tier_aware() {
        let topo = Topology::omnipath_100g();
        for alg in [Algorithm::Ring, Algorithm::RecursiveDoubling] {
            let a = predict_allgather_ns(&topo, alg, 16, 1 << 10);
            let b = predict_allgather_ns(&topo, alg, 16, 1 << 24);
            assert!(b > a, "{alg:?}");
        }
        // A 4-rank ring inside one node rides the intra tier; the flat
        // (all-top) pricing must not inherit that discount.
        let smp = Topology::eth_10g_smp(4);
        let intra = predict_allgather_ns(&smp, Algorithm::Ring, 4, 1 << 20);
        let flat = predict_allgather_ns(&Topology::eth_10g(), Algorithm::Ring, 4, 1 << 20);
        assert!(intra < flat / 10, "intra={intra} flat={flat}");
        assert_eq!(choose_flat_allgather_algorithm(&smp, 6, 1 << 20), Algorithm::Ring);
    }

    #[test]
    fn hierarchical_allgather_prices_and_wins_on_slow_fabrics() {
        // 64 ranks at 2/node on 10GbE, non-pow2 leader count excluded:
        // the hierarchical allgather halves the slow-tier step count and
        // must beat the flat ring at sizeable payloads.
        let topo = Topology::eth_10g_smp(2);
        let alg = Algorithm::hier(&[2]);
        for p in [64usize, 96] {
            let bytes = 8u64 << 20;
            let hier = predict_allgather_ns(&topo, alg, p, bytes);
            let ring = predict_allgather_ns(&topo, Algorithm::Ring, p, bytes);
            assert!(hier < ring, "p={p}: hier={hier} ring={ring}");
        }
        // Invalid grouping is never the cheapest.
        assert!(predict_allgather_ns(&topo, Algorithm::hier(&[5]), 8, 1024) >= Ns::MAX / 4);
    }

    #[test]
    fn candidate_sets_match_chooser_support() {
        let smp = Topology::eth_10g_smp(2);
        assert!(candidate_algorithms(&smp, 8).contains(&Algorithm::hier(&[2])));
        assert!(!candidate_algorithms(&Topology::eth_10g(), 8)
            .iter()
            .any(|a| matches!(a, Algorithm::Hierarchical { .. })));
        assert_eq!(candidate_algorithms(&smp, 1), vec![Algorithm::Ring]);
        assert_eq!(allgather_candidates(&Topology::eth_10g(), 6), vec![Algorithm::Ring]);
        assert_eq!(
            allgather_candidates(&Topology::eth_10g(), 8),
            vec![Algorithm::Ring, Algorithm::RecursiveDoubling]
        );
        assert!(allgather_candidates(&smp, 8).contains(&Algorithm::hier(&[2])));
    }

    #[test]
    fn three_level_candidates_follow_tier_prefixes() {
        let topo = Topology::by_name("eth10g-x2r4").unwrap(); // node=2, rack=8
        // p=16: both the node-only and node+rack stacks are candidates.
        let c = candidate_algorithms(&topo, 16);
        assert!(c.contains(&Algorithm::hier(&[2])), "{c:?}");
        assert!(c.contains(&Algorithm::hier(&[2, 8])), "{c:?}");
        // p=8 (== one rack): the rack stack degenerates (g == p) and is
        // not offered; the node stack is.
        let c8 = candidate_algorithms(&topo, 8);
        assert!(c8.contains(&Algorithm::hier(&[2])));
        assert!(!c8.contains(&Algorithm::hier(&[2, 8])), "{c8:?}");
        // p=12: rack (8) does not divide 12 → node-only.
        let c12 = candidate_algorithms(&topo, 12);
        assert!(c12.contains(&Algorithm::hier(&[2])));
        assert!(!c12.iter().any(
            |a| matches!(a, Algorithm::Hierarchical { groups } if groups.len() > 1)
        ));
    }

    #[test]
    fn rack_oversubscription_makes_three_level_win() {
        // On the rack-oversubscribed preset the cross-rack tier is the
        // bottleneck. Where the 2-level leader count is not a power of
        // two (its top phase degrades to a ring whose every lockstep is
        // gated by a cross-rack hop), the 3-level stack must price below
        // the 2-level one outside the pure-bandwidth regime, and the
        // chooser must pick it. (At power-of-two leader counts
        // halving-doubling's XOR rounds already localize in-rack, so the
        // extra tree level is not free lunch — the selector decides per
        // cell.)
        let topo = Topology::by_name("eth10g-x8r16").unwrap(); // node=8, rack=128
        for p in [384usize, 768] {
            for bytes in [64u64 << 10, 1 << 20] {
                let two = predict_allreduce_ns(&topo, Algorithm::hier(&[8]), p, bytes);
                let three = predict_allreduce_ns(&topo, Algorithm::hier(&[8, 128]), p, bytes);
                assert!(three < two, "p={p} bytes={bytes}: three={three} two={two}");
                let pick = choose_algorithm(&topo, p, bytes);
                assert_eq!(pick, Algorithm::hier(&[8, 128]), "p={p} bytes={bytes}");
            }
        }
    }

    #[test]
    fn leader_phase_pricing_respects_rack_locality() {
        // On eth10g-x8r16, hier:[8]'s 32 node leaders sit 16 per rack:
        // halving-doubling rounds at leader distance < 16 stay in-rack in
        // the built program, and the cost model must price them there —
        // NOT at the oversubscribed spine. Observable consequences:
        let topo = Topology::by_name("eth10g-x8r16").unwrap();
        let bytes = 16u64 << 20;
        let two = predict_allreduce_ns(&topo, Algorithm::hier(&[8]), 256, bytes);
        // (a) 2-level must price well below the same phase all-top: the
        // all-top figure is what hier:[8] would cost if every one of its
        // 10 leader rounds crossed the spine.
        let all_top = predict_flat_inter_allreduce_ns(&topo, Algorithm::HalvingDoubling, 32, bytes);
        assert!(two < all_top, "two={two} all_top={all_top}");
        // (b) in the bandwidth-bound pow2-leader regime the extra rack
        // tree level is NOT free lunch: 3-level must price above 2-level
        // (matching the a8 bench's measurements), so the chooser must not
        // pick the deep stack here.
        let three = predict_allreduce_ns(&topo, Algorithm::hier(&[8, 128]), 256, bytes);
        assert!(two < three, "two={two} three={three}");
        let pick = choose_algorithm(&topo, 256, bytes);
        assert!(
            !matches!(pick, Algorithm::Hierarchical { groups } if groups.len() > 1),
            "{pick:?}"
        );
    }

    #[test]
    fn rail_striping_discounts_bandwidth_not_latency() {
        let flat = Topology::eth_10g();
        let e4 = flat.clone().with_rails(4).unwrap();
        // Latency-bound sizes (every message under one chunk): the rail
        // count is invisible — identical predictions, alpha undivided.
        for alg in [Algorithm::Ring, Algorithm::RecursiveDoubling, Algorithm::HalvingDoubling] {
            assert_eq!(
                predict_allreduce_ns(&flat, alg, 64, 4 << 10),
                predict_allreduce_ns(&e4, alg, 64, 4 << 10),
                "{alg:?}"
            );
            assert_eq!(
                predict_flat_inter_allreduce_ns(&flat, alg, 16, 4 << 10),
                predict_flat_inter_allreduce_ns(&e4, alg, 16, 4 << 10),
                "{alg:?} strided"
            );
        }
        // Bandwidth-bound ring (1 MiB per-step segments = 4 chunks): the
        // 4 rails buy close to 4x, but never more, and never touch alpha.
        let big = 64u64 << 20;
        let t1 = predict_allreduce_ns(&flat, Algorithm::Ring, 64, big);
        let t4 = predict_allreduce_ns(&e4, Algorithm::Ring, 64, big);
        let ratio = t1 as f64 / t4 as f64;
        assert!((3.2..4.0).contains(&ratio), "ratio={ratio} t1={t1} t4={t4}");
        // Allgather pricing stripes the same way.
        let g1 = predict_allgather_ns(&flat, Algorithm::Ring, 64, big);
        let g4 = predict_allgather_ns(&e4, Algorithm::Ring, 64, big);
        assert!((3.2..4.0).contains(&(g1 as f64 / g4 as f64)));
        // A crossover still exists on the striped fabric and the picks
        // stay shape-consistent: fewest rounds small, bandwidth-optimal
        // large.
        assert_eq!(choose_algorithm(&e4, 64, 1024), Algorithm::RecursiveDoubling);
        let large_pick = choose_algorithm(&e4, 64, 256 << 20);
        assert!(
            matches!(large_pick, Algorithm::Ring | Algorithm::HalvingDoubling),
            "{large_pick:?}"
        );
    }

    #[test]
    fn f32_wire_pricing_is_identical_to_the_plain_model() {
        // The f32 column of the (alg × wire) grid must be the EXACT
        // pre-existing model — tuned tables and analytic reproduction
        // tests depend on bit-identical f32 behavior.
        let topo = Topology::eth_10g_smp(2);
        for alg in [
            Algorithm::Ring,
            Algorithm::RecursiveDoubling,
            Algorithm::HalvingDoubling,
            Algorithm::hier(&[2]),
        ] {
            for p in [4usize, 8, 64] {
                for bytes in [256u64, 1 << 20, 16 << 20] {
                    assert_eq!(
                        predict_allreduce_ns_wire(&topo, alg, p, bytes, WireDtype::F32, 1000),
                        predict_allreduce_ns(&topo, alg, p, bytes),
                        "{alg:?} p={p} bytes={bytes}"
                    );
                    assert_eq!(
                        predict_flat_inter_allreduce_ns_wire(
                            &topo, alg, p, bytes, WireDtype::F32, 1000
                        ),
                        predict_flat_inter_allreduce_ns(&topo, alg, p, bytes),
                        "{alg:?} p={p} bytes={bytes} strided"
                    );
                    assert_eq!(
                        predict_allgather_ns_wire(&topo, alg, p, bytes, WireDtype::F32, 1000),
                        predict_allgather_ns(&topo, alg, p, bytes),
                        "{alg:?} p={p} bytes={bytes} allgather"
                    );
                }
            }
        }
        let (a, w) = choose_algorithm_wire(&topo, 8, 1 << 20, &[WireDtype::F32], 1000);
        assert_eq!((a, w), (choose_algorithm(&topo, 8, 1 << 20), WireDtype::F32));
    }

    #[test]
    fn compression_wins_bandwidth_bound_and_loses_latency_bound() {
        let topo = Topology::eth_10g();
        // 256 B over 8 ranks: the per-hop quantize setup dwarfs the byte
        // saving — the auto grid must stay on the f32 wire.
        let (_, w_small) = choose_algorithm_wire(&topo, 8, 256, &WireDtype::ALL, 1000);
        assert_eq!(w_small, WireDtype::F32);
        // 16 MiB: int8 moves ~4x fewer bytes over the 10G wire and must
        // win; the full ring ordering int8 < bf16 < f32 must hold.
        let (_, w_big) = choose_algorithm_wire(&topo, 8, 16 << 20, &WireDtype::ALL, 1000);
        assert_eq!(w_big, WireDtype::Int8Block);
        let big = 16u64 << 20;
        let f = predict_allreduce_ns_wire(&topo, Algorithm::Ring, 8, big, WireDtype::F32, 1000);
        let b = predict_allreduce_ns_wire(&topo, Algorithm::Ring, 8, big, WireDtype::Bf16, 1000);
        let i =
            predict_allreduce_ns_wire(&topo, Algorithm::Ring, 8, big, WireDtype::Int8Block, 1000);
        assert!(i < b && b < f, "int8={i} bf16={b} f32={f}");
        // Even net of quantize cost the modeled win is well past the a13
        // bench gate (~2.4x at this size on the analytic side).
        assert!(f as f64 / i as f64 > 1.8, "ratio {}", f as f64 / i as f64);
    }

    #[test]
    fn compression_crossovers_exist_and_are_ordered_on_slow_fabrics() {
        let topo = Topology::eth_10g();
        let bf = compression_crossover_bytes(&topo, 8, WireDtype::Bf16).unwrap();
        let i8c = compression_crossover_bytes(&topo, 8, WireDtype::Int8Block).unwrap();
        // bf16's cheaper setup crosses over before int8's.
        assert!(bf < i8c, "bf16@{bf} int8@{i8c}");
        // Bisection postcondition: f32 still wins just below, loses at
        // the reported size.
        let algs = candidate_algorithms(&topo, 8);
        let best = |bytes: u64, w: WireDtype| {
            algs.iter()
                .map(|a| predict_allreduce_ns_wire(&topo, *a, 8, bytes, w, 1000))
                .min()
                .unwrap()
        };
        assert!(best(bf, WireDtype::Bf16) < best(bf, WireDtype::F32));
        assert!(best(bf - 1, WireDtype::Bf16) >= best(bf - 1, WireDtype::F32));
        assert_eq!(compression_crossover_sizes(&topo, 8), vec![bf, i8c]);
        // On a 100 Gb fabric the per-element quantize cost outruns the
        // byte saving at EVERY size — compression never wins there and
        // the helper must say so.
        let opa = Topology::omnipath_100g();
        assert_eq!(compression_crossover_bytes(&opa, 8, WireDtype::Bf16), None);
        assert_eq!(compression_crossover_bytes(&opa, 8, WireDtype::Int8Block), None);
        assert!(compression_crossover_sizes(&opa, 8).is_empty());
    }

    #[test]
    fn hier_wire_pricing_and_slowdown_scaling() {
        let topo = Topology::eth_10g_smp(2);
        let alg = Algorithm::hier(&[2]);
        let big = 16u64 << 20;
        // Compressed hierarchical allreduce beats its f32 twin at bulk
        // sizes: fewer bytes on the slow inter tier.
        let f = predict_allreduce_ns_wire(&topo, alg, 64, big, WireDtype::F32, 1000);
        let q = predict_allreduce_ns_wire(&topo, alg, 64, big, WireDtype::Bf16, 1000);
        assert!(q < f, "bf16={q} f32={f}");
        // A chaos-slowed endpoint pays exactly proportionally more
        // quantize time, and ONLY quantize time (transport unchanged).
        let slowed = predict_allreduce_ns_wire(&topo, alg, 64, big, WireDtype::Bf16, 4000);
        let chain = quant_chain_ns(alg, 64, payload_elems(big), WireDtype::Bf16, 1000);
        assert!(chain > 0);
        assert_eq!(slowed - q, 3 * chain);
        // f32 is immune to compute slowdown in this model (no quantize).
        assert_eq!(
            predict_allreduce_ns_wire(&topo, alg, 64, big, WireDtype::F32, 4000),
            f
        );
    }

    #[test]
    fn quant_chain_counts_the_alpha_hops() {
        // Ring: 2(p−1) segment hops; RD: log2(p) full-buffer hops.
        assert_eq!(quant_chain_ns(Algorithm::Ring, 8, 800, WireDtype::F32, 1000), 0);
        assert_eq!(
            quant_chain_ns(Algorithm::Ring, 8, 800, WireDtype::Bf16, 1000),
            14 * quant_hop_ns(100, WireDtype::Bf16, 1000)
        );
        assert_eq!(
            quant_chain_ns(Algorithm::RecursiveDoubling, 8, 800, WireDtype::Int8Block, 1000),
            3 * quant_hop_ns(800, WireDtype::Int8Block, 1000)
        );
        assert_eq!(quant_chain_ns(Algorithm::Ring, 1, 800, WireDtype::Int8Block, 1000), 0);
    }

    #[test]
    fn crossover_point_is_ordered() {
        // Walking up the sizes on one fabric, once the choice leaves
        // RecursiveDoubling it never comes back (the cost curves cross
        // exactly once: rounds·n/B grows strictly faster than the
        // bandwidth-optimal 2(P−1)/P·n/B term).
        let topo = Topology::eth_10g();
        let mut left_rd = false;
        for shift in 6..28 {
            let alg = choose_algorithm(&topo, 32, 1u64 << shift);
            if alg != Algorithm::RecursiveDoubling {
                left_rd = true;
            } else {
                assert!(!left_rd, "RD re-selected at 2^{shift} after crossover");
            }
        }
        assert!(left_rd, "no crossover up to 2^27");
    }
}

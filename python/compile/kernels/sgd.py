"""Fused SGD-with-momentum update Pallas kernel.

m' = mu*m + (g + wd*w);  w' = w - lr*m'  — one pass over the parameter
buffer instead of three, so the update the paper's first-layer
prioritization exists to unblock is itself memory-bandwidth-optimal.

Arbitrary parameter shapes are handled by flattening and padding to the
tile size; the pad lanes are dead weight but never observed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 4096  # elements per grid cell; multiple of the (8,128) VMEM tile


def _sgd_kernel(w_ref, m_ref, g_ref, wo_ref, mo_ref, *, lr, mu, wd):
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32) + wd * w
    m_new = mu * m_ref[...].astype(jnp.float32) + g
    wo_ref[...] = (w - lr * m_new).astype(wo_ref.dtype)
    mo_ref[...] = m_new.astype(mo_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lr", "mu", "wd"))
def sgd_momentum(w, m, g, lr: float, mu: float, wd: float = 0.0):
    """Fused momentum-SGD step on a parameter of any shape.

    Returns (w', m') with the input shape/dtype.
    """
    shape, dtype = w.shape, w.dtype
    n = w.size
    pad = (-n) % TILE
    def flat(a):
        a = a.reshape(-1).astype(jnp.float32)
        return jnp.pad(a, (0, pad)) if pad else a
    wf, mf, gf = flat(w), flat(m), flat(g)
    np_ = wf.shape[0]
    rows = np_ // TILE
    spec = pl.BlockSpec((1, TILE), lambda i: (i, 0))
    wo, mo = pl.pallas_call(
        functools.partial(_sgd_kernel, lr=lr, mu=mu, wd=wd),
        grid=(rows,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((rows, TILE), jnp.float32),
            jax.ShapeDtypeStruct((rows, TILE), jnp.float32),
        ],
        interpret=True,
    )(wf.reshape(rows, TILE), mf.reshape(rows, TILE), gf.reshape(rows, TILE))
    wn = wo.reshape(-1)[:n].reshape(shape).astype(dtype)
    mn = mo.reshape(-1)[:n].reshape(shape).astype(dtype)
    return wn, mn

//! The Collectives API — the MPI-like half of MLSL's interface, bound to
//! a rank's comm core for asynchronous, prioritized execution over the
//! real in-process fabric.

use crate::collectives::program::{build, CollectiveKind};
use crate::collectives::{choose_algorithm, Algorithm, ReduceOp, WireDtype};
use crate::fabric::shm::{fabric, ShmEndpoint};
use crate::fabric::topology::Topology;
use crate::progress::{CommCore, Handle};
use crate::{Priority, Rank};

/// A rank's communicator: collective entry points, non-blocking handles.
///
/// Collective calls must be made in the same order on every rank (MPI
/// semantics): ids are allocated locally in call order and matched by id
/// on the wire.
pub struct Communicator {
    core: CommCore,
    rank: Rank,
    world: usize,
    /// Fabric model used to resolve `Algorithm::Auto`; defaults to a
    /// shared-memory-ish profile.
    pub topo_hint: Topology,
}

impl Communicator {
    /// Build a fully-connected world of `p` communicators (one per rank
    /// thread).
    pub fn world(p: usize) -> Vec<Communicator> {
        fabric(p).into_iter().map(|ep| Communicator::from_endpoint(ep, p)).collect()
    }

    /// Rebuild a world for the survivors of a membership change: a fresh
    /// fully-connected fabric sized to the survivor count, returned as
    /// `(old fabric rank, communicator)` pairs so callers keep addressing
    /// each participant — and its data — by its ORIGINAL rank id. Only
    /// the wire-level ranks are renumbered (they are positions in the
    /// survivor list, the same convention the simulated path's
    /// `rebuild_for_survivors` uses); nobody's payload moves.
    pub fn elastic_world(survivors: &[Rank]) -> Vec<(Rank, Communicator)> {
        survivors.iter().copied().zip(Communicator::world(survivors.len())).collect()
    }

    pub fn from_endpoint(ep: ShmEndpoint, world: usize) -> Self {
        let rank = ep.rank;
        Self {
            core: CommCore::spawn(ep),
            rank,
            world,
            // In-process fabric: high bandwidth, microsecond-ish costs.
            // All ranks share one address space, so the fabric is a
            // single tier (empty tier stack: no hierarchy to exploit).
            topo_hint: Topology::flat("shm", 400.0, 2_000, 500, 1 << 20),
        }
    }

    pub fn rank(&self) -> Rank {
        self.rank
    }

    pub fn world_size(&self) -> usize {
        self.world
    }

    fn resolve(&self, alg: Algorithm, n: usize) -> Algorithm {
        match alg {
            Algorithm::Auto => choose_algorithm(&self.topo_hint, self.world, 4 * n as u64),
            other => other,
        }
    }

    /// Non-blocking sum-allreduce with priority (0 = most urgent).
    pub fn allreduce_async(
        &self,
        buf: Vec<f32>,
        alg: Algorithm,
        wire: WireDtype,
        priority: Priority,
    ) -> Handle {
        let n = buf.len();
        let alg = self.resolve(alg, n);
        let prog = build(CollectiveKind::Allreduce, alg, self.world, n)
            .expect("resolved algorithm is buildable")
            .swap_remove(self.rank);
        let id = self.core.alloc_id();
        self.core.submit_with_handle(id, prog, buf, ReduceOp::Sum, wire, priority)
    }

    /// Blocking sum-allreduce.
    pub fn allreduce(&self, buf: Vec<f32>) -> Vec<f32> {
        self.allreduce_async(buf, Algorithm::Auto, WireDtype::F32, 128).wait()
    }

    /// Non-blocking broadcast from `root`.
    pub fn broadcast_async(&self, buf: Vec<f32>, root: Rank, priority: Priority) -> Handle {
        let n = buf.len();
        let prog = build(CollectiveKind::Broadcast { root }, Algorithm::Ring, self.world, n)
            .expect("broadcast builds for any rank count")
            .swap_remove(self.rank);
        let id = self.core.alloc_id();
        self.core
            .submit_with_handle(id, prog, buf, ReduceOp::Sum, WireDtype::F32, priority)
    }

    /// Blocking broadcast.
    pub fn broadcast(&self, buf: Vec<f32>, root: Rank) -> Vec<f32> {
        self.broadcast_async(buf, root, 0).wait()
    }

    /// Blocking allgather: each rank contributes its segment (ring layout:
    /// rank r's data must sit in segment r of `buf`).
    pub fn allgather(&self, buf: Vec<f32>) -> Vec<f32> {
        let n = buf.len();
        let prog = build(CollectiveKind::Allgather, Algorithm::Ring, self.world, n)
            .expect("allgather builds for any rank count")
            .swap_remove(self.rank);
        let id = self.core.alloc_id();
        self.core
            .submit_with_handle(id, prog, buf, ReduceOp::Sum, WireDtype::F32, 0)
            .wait()
    }

    /// Blocking reduce to `root`.
    pub fn reduce(&self, buf: Vec<f32>, root: Rank) -> Vec<f32> {
        let n = buf.len();
        let prog = build(CollectiveKind::Reduce { root }, Algorithm::Ring, self.world, n)
            .expect("reduce builds for any rank count")
            .swap_remove(self.rank);
        let id = self.core.alloc_id();
        self.core
            .submit_with_handle(id, prog, buf, ReduceOp::Sum, WireDtype::F32, 64)
            .wait()
    }

    /// Barrier.
    pub fn barrier(&self) {
        let n = if self.world.is_power_of_two() { 1 } else { self.world };
        let prog = crate::collectives::program::barrier(self.world).swap_remove(self.rank);
        let id = self.core.alloc_id();
        self.core
            .submit_with_handle(id, prog, vec![0.0; n], ReduceOp::Sum, WireDtype::F32, 0)
            .wait();
    }

    /// Tear down the comm core, returning its stats.
    pub fn shutdown(self) -> crate::progress::engine::CoreStats {
        self.core.join()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn with_world<F, R>(p: usize, f: F) -> Vec<R>
    where
        F: Fn(Communicator) -> R + Send + Sync + Copy + 'static,
        R: Send + 'static,
    {
        let comms = Communicator::world(p);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| thread::spawn(move || f(c)))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn blocking_allreduce() {
        let outs = with_world(4, |c| {
            let r = c.rank();
            c.allreduce(vec![r as f32; 64])
        });
        for out in outs {
            assert!(out.iter().all(|v| *v == 6.0)); // 0+1+2+3
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        let outs = with_world(3, |c| {
            let mut results = Vec::new();
            for root in 0..3 {
                let buf = if c.rank() == root { vec![root as f32 + 1.0; 16] } else { vec![0.0; 16] };
                results.push(c.broadcast(buf, root));
            }
            results
        });
        for per_rank in outs {
            for (root, out) in per_rank.into_iter().enumerate() {
                assert!(out.iter().all(|v| *v == root as f32 + 1.0));
            }
        }
    }

    #[test]
    fn allgather_assembles_segments() {
        let n = 12;
        let outs = with_world(4, move |c| {
            let seg = crate::collectives::program::segments(n, 4);
            let mut buf = vec![0.0; n];
            for e in seg[c.rank()]..seg[c.rank() + 1] {
                buf[e] = c.rank() as f32 + 1.0;
            }
            c.allgather(buf)
        });
        let want: Vec<f32> = vec![1., 1., 1., 2., 2., 2., 3., 3., 3., 4., 4., 4.];
        for out in outs {
            assert_eq!(out, want);
        }
    }

    #[test]
    fn barrier_and_ordered_ids_interleave_safely() {
        let outs = with_world(4, |c| {
            let mut acc = 0.0;
            for i in 0..5 {
                let out = c.allreduce(vec![i as f32; 8]);
                acc += out[0];
                c.barrier();
            }
            acc
        });
        for v in outs {
            assert_eq!(v, (0..5).map(|i| 4.0 * i as f32).sum());
        }
    }

    #[test]
    fn elastic_world_keeps_survivor_ids_without_renumbering_data() {
        // World of 4 loses rank 2. The rebuilt world spans [0, 1, 3]; each
        // survivor still contributes a value keyed by its ORIGINAL rank id
        // and the allreduce must sum exactly those.
        let survivors = [0usize, 1, 3];
        let pairs = Communicator::elastic_world(&survivors);
        assert_eq!(pairs.len(), 3);
        let handles: Vec<_> = pairs
            .into_iter()
            .map(|(old_rank, c)| {
                thread::spawn(move || {
                    assert_eq!(c.world_size(), 3);
                    (old_rank, c.allreduce(vec![old_rank as f32; 16]))
                })
            })
            .collect();
        for h in handles {
            let (old_rank, out) = h.join().unwrap();
            assert!(out.iter().all(|v| *v == 4.0), "rank {old_rank}: {out:?}"); // 0+1+3
        }
    }

    #[test]
    fn single_rank_world_works() {
        let outs = with_world(1, |c| {
            c.barrier();
            c.allreduce(vec![3.0; 4])
        });
        assert_eq!(outs[0], vec![3.0; 4]);
    }
}

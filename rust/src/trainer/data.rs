//! Synthetic token corpus for the end-to-end training run
//! (DESIGN.md §Substitutions: ImageNet → synthetic tokens).
//!
//! A seeded order-1 Markov chain with Zipf-ish marginals: enough structure
//! that a language model's loss drops well below the uniform log(V)
//! baseline, while remaining fully deterministic and dependency-free.

use crate::util::prng::Prng;

/// Deterministic corpus sampler.
pub struct TokenGen {
    vocab: usize,
    rng: Prng,
    /// Per-state offset making transitions non-uniform but cheap: the
    /// chain is t_{i+1} = perm(t_i) with probability q, else Zipf sample.
    q: f64,
}

impl TokenGen {
    pub fn new(vocab: usize, seed: u64) -> Self {
        Self { vocab, rng: Prng::seed(seed), q: 0.7 }
    }

    fn next_token(&mut self, prev: usize) -> usize {
        if self.rng.f64() < self.q {
            // Deterministic successor: an affine permutation of the vocab.
            (prev.wrapping_mul(31).wrapping_add(17)) % self.vocab
        } else {
            self.rng.zipf(self.vocab, 1.1)
        }
    }

    /// One (batch, seq+1) token matrix, flattened row-major.
    pub fn batch(&mut self, batch: usize, seq_plus_1: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq_plus_1);
        for _ in 0..batch {
            let mut t = self.rng.usize_below(self.vocab);
            out.push(t as i32);
            for _ in 1..seq_plus_1 {
                t = self.next_token(t);
                out.push(t as i32);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range_and_deterministic() {
        let mut g1 = TokenGen::new(512, 42);
        let mut g2 = TokenGen::new(512, 42);
        let b1 = g1.batch(4, 33);
        let b2 = g2.batch(4, 33);
        assert_eq!(b1, b2);
        assert_eq!(b1.len(), 4 * 33);
        assert!(b1.iter().all(|t| (0..512).contains(&(*t as usize))));
    }

    #[test]
    fn different_seeds_differ() {
        let b1 = TokenGen::new(512, 1).batch(2, 16);
        let b2 = TokenGen::new(512, 2).batch(2, 16);
        assert_ne!(b1, b2);
    }

    #[test]
    fn chain_is_predictable_enough_to_learn() {
        // ~q of transitions follow the deterministic permutation: a model
        // CAN beat the uniform baseline. Check empirically.
        let mut g = TokenGen::new(128, 7);
        let b = g.batch(16, 65);
        let mut hits = 0;
        let mut total = 0;
        for row in b.chunks(65) {
            for w in row.windows(2) {
                total += 1;
                if w[1] as usize == (w[0] as usize * 31 + 17) % 128 {
                    hits += 1;
                }
            }
        }
        let frac = hits as f64 / total as f64;
        assert!(frac > 0.6, "{frac}");
    }
}

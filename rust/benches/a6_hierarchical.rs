//! **Ablation A6**: hierarchical (two-tier) vs flat allreduce on
//! multi-rank-per-node fabrics.
//!
//! The paper's testbeds run several ranks per Xeon node; a flat ring pays
//! an inter-node alpha for every one of its 2(P−1) steps, while the
//! hierarchical scheme (intra-node binomial reduce → leader allreduce →
//! intra-node broadcast) only puts P/r ranks on the wire. This bench
//! sweeps total rank count × message size × ranks-per-node on the
//! Xeon/10GbE smp preset, prints simulated times for flat ring vs
//! hierarchical and what `Auto` picks, and ASSERTS the acceptance
//! criterion: hierarchical beats flat ring for >= 64 ranks at
//! ranks_per_node >= 2.
//!
//! Run: `cargo bench --bench a6_hierarchical`

use mlsl::collectives::program::{allreduce_ring, build, CollectiveKind};
use mlsl::collectives::selector::choose_algorithm;
use mlsl::collectives::simexec::time_collective;
use mlsl::collectives::{Algorithm, WireDtype};
use mlsl::fabric::topology::Topology;
use mlsl::fabric::NetSim;
use mlsl::metrics::print_table;
use mlsl::util::stats::fmt_bytes;

fn main() {
    let sizes: [u64; 3] = [64 << 10, 1 << 20, 16 << 20];
    let mut wins = 0usize;
    for rpn in [2usize, 4] {
        let topo = Topology::eth_10g_smp(rpn);
        let mut rows = Vec::new();
        for p in [16usize, 32, 64, 128] {
            for bytes in sizes {
                let n = (bytes / 4) as usize;
                let t_ring = time_collective(
                    &mut NetSim::new(topo.clone(), p),
                    allreduce_ring(p, n),
                    WireDtype::F32,
                    1,
                );
                let hier = Algorithm::hier(&[rpn]);
                let t_hier = time_collective(
                    &mut NetSim::new(topo.clone(), p),
                    build(CollectiveKind::Allreduce, hier, p, n).unwrap(),
                    WireDtype::F32,
                    1,
                );
                let auto = choose_algorithm(&topo, p, bytes);
                if p >= 64 {
                    // Acceptance: the hierarchy must win once enough nodes
                    // are on the slow tier.
                    assert!(
                        t_hier < t_ring,
                        "p={p} rpn={rpn} bytes={bytes}: hier={t_hier} ring={t_ring}"
                    );
                    wins += 1;
                }
                rows.push(vec![
                    p.to_string(),
                    fmt_bytes(bytes),
                    format!("{:.3}", t_ring as f64 / 1e6),
                    format!("{:.3}", t_hier as f64 / 1e6),
                    format!("{:.2}x", t_ring as f64 / t_hier.max(1) as f64),
                    auto.to_string(),
                ]);
            }
        }
        print_table(
            &format!("A6: flat ring vs hierarchical allreduce, 10GbE, {rpn} ranks/node"),
            &["ranks", "size", "ring ms", "hier ms", "speedup", "auto picks"],
            &rows,
        );
    }
    println!("\nexpected shape: hierarchical wins grow with rank count and ranks/node;");
    println!("small sizes win most (inter-node alpha count drops r-fold), large sizes");
    println!("approach the 2n/B wire bound both schemes share.");
    println!("acceptance: hierarchical < flat ring for all {wins} configs with p >= 64. OK");
}

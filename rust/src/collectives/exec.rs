//! Real (data-moving) executor for collective programs over the
//! shared-memory fabric.
//!
//! [`execute`] is the simple blocking single-op path (tests, barriers,
//! broadcast of initial parameters). The multi-op *prioritized* execution
//! the paper is about lives in [`crate::progress`], which drives the same
//! step semantics incrementally.
//!
//! Message tag = collective id: within one collective, messages between a
//! (src, dst) pair are produced and consumed in program order, so FIFO
//! matching per (src, tag) is sufficient (see program.rs header).

use super::program::{Program, Range};
use super::quant::{decode_into, encode, WireDtype};
use super::ReduceOp;
use crate::fabric::shm::ShmEndpoint;

/// Execute one program step's send half: encode `buf[range]` and ship it.
pub fn do_send(
    ep: &ShmEndpoint,
    coll_id: u64,
    buf: &[f32],
    to: crate::Rank,
    range: Range,
    wire: WireDtype,
) {
    let payload = encode(&buf[range.off..range.end()], wire);
    ep.send(to, coll_id, payload);
}

/// Apply a received payload to `buf[range]` (reduce or overwrite).
pub fn apply_recv(
    buf: &mut [f32],
    range: Range,
    payload: &[u8],
    wire: WireDtype,
    reduce: bool,
    op: ReduceOp,
) {
    let dst = &mut buf[range.off..range.off + range.len];
    decode_into(payload, dst, wire, if reduce { Some(op) } else { None });
}

/// Blocking execution of one collective program against the fabric.
pub fn execute(
    ep: &mut ShmEndpoint,
    coll_id: u64,
    prog: &Program,
    buf: &mut [f32],
    op: ReduceOp,
    wire: WireDtype,
) {
    for step in &prog.steps {
        if let Some(sd) = &step.send {
            do_send(ep, coll_id, buf, sd.to, sd.range, wire);
        }
        if let Some(rv) = &step.recv {
            let payload = ep.recv(rv.from, coll_id);
            apply_recv(buf, rv.range, &payload, wire, rv.reduce, op);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::program::CollectiveKind;
    use crate::collectives::{program, Algorithm};
    use crate::fabric::shm;
    use std::thread;

    fn run_collective(
        p: usize,
        n: usize,
        kind: CollectiveKind,
        alg: Algorithm,
        wire: WireDtype,
        init: impl Fn(usize) -> Vec<f32> + Send + Sync + Copy + 'static,
    ) -> Vec<Vec<f32>> {
        let eps = shm::fabric(p);
        let programs = program::build(kind, alg, p, n).unwrap();
        let handles: Vec<_> = eps
            .into_iter()
            .zip(programs)
            .map(|(mut ep, prog)| {
                thread::spawn(move || {
                    let mut buf = init(ep.rank);
                    execute(&mut ep, 1, &prog, &mut buf, ReduceOp::Sum, wire);
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn ring_allreduce_sums_across_threads() {
        let (p, n) = (4, 103);
        let bufs = run_collective(p, n, CollectiveKind::Allreduce, Algorithm::Ring,
                                  WireDtype::F32,
                                  move |r| (0..103).map(|i| (r * 1000 + i) as f32).collect());
        let want: Vec<f32> = (0..n)
            .map(|i| (0..p).map(|r| (r * 1000 + i) as f32).sum())
            .collect();
        for buf in &bufs {
            assert_eq!(buf, &want);
        }
    }

    #[test]
    fn halving_doubling_matches_ring() {
        let (p, n) = (8, 64);
        let init = move |r: usize| (0..64).map(|i| ((r + 1) * (i + 1)) as f32).collect::<Vec<_>>();
        let a = run_collective(p, n, CollectiveKind::Allreduce, Algorithm::Ring,
                               WireDtype::F32, init);
        let b = run_collective(p, n, CollectiveKind::Allreduce,
                               Algorithm::HalvingDoubling, WireDtype::F32, init);
        assert_eq!(a, b);
    }

    #[test]
    fn rdoubling_matches_ring() {
        let (p, n) = (4, 33);
        let init = move |r: usize| (0..33).map(|i| (r as f32) - (i as f32)).collect::<Vec<_>>();
        let a = run_collective(p, n, CollectiveKind::Allreduce, Algorithm::Ring,
                               WireDtype::F32, init);
        let b = run_collective(p, n, CollectiveKind::Allreduce,
                               Algorithm::RecursiveDoubling, WireDtype::F32, init);
        assert_eq!(a, b);
    }

    #[test]
    fn broadcast_delivers_root_buffer() {
        let (p, n) = (6, 41);
        let bufs = run_collective(p, n, CollectiveKind::Broadcast { root: 2 },
                                  Algorithm::Ring, WireDtype::F32,
                                  move |r| if r == 2 {
                                      (0..41).map(|i| i as f32 * 0.5).collect()
                                  } else {
                                      vec![0.0; 41]
                                  });
        let want: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
        for buf in &bufs {
            assert_eq!(buf, &want);
        }
    }

    #[test]
    fn bf16_allreduce_within_tolerance() {
        let (p, n) = (4, 64);
        let bufs = run_collective(p, n, CollectiveKind::Allreduce, Algorithm::Ring,
                                  WireDtype::Bf16,
                                  move |r| (0..64).map(|i| (r + i) as f32 / 7.0).collect());
        for buf in &bufs {
            for (i, v) in buf.iter().enumerate() {
                let want: f32 = (0..p).map(|r| (r + i) as f32 / 7.0).sum();
                assert!((v - want).abs() / want.max(1.0) < 0.05, "{i}: {v} vs {want}");
            }
        }
    }

    #[test]
    fn int8_allreduce_within_tolerance() {
        let (p, n) = (4, 512);
        let bufs = run_collective(p, n, CollectiveKind::Allreduce, Algorithm::Ring,
                                  WireDtype::Int8Block,
                                  move |r| (0..512).map(|i| ((r * i) % 13) as f32).collect());
        for buf in &bufs {
            for (i, v) in buf.iter().enumerate() {
                let want: f32 = (0..p).map(|r| ((r * i) % 13) as f32).sum();
                // int8 quant: generous absolute tolerance scaled by magnitude.
                assert!((v - want).abs() <= 0.05 * want.abs() + 0.8, "{i}: {v} vs {want}");
            }
        }
    }
}

//! The measurement probe: times every candidate algorithm for each
//! tunable collective across a log-spaced (rank count × message size)
//! grid by executing real chunk programs through
//! [`crate::collectives::simexec`] on the live [`Topology`] — the same
//! cycle-accurate instrument the engine times training with, so measured
//! winners transfer directly to engine runs.
//!
//! Cells are independent (one private fabric each), so the grid is
//! embarrassingly parallel: [`tune_threaded`] stripes it across worker
//! threads and produces a byte-identical table (`--sim-threads`).

use crate::collectives::program::{build, CollectiveKind};
use crate::collectives::selector::{allgather_candidates, candidate_algorithms};
use crate::collectives::simexec::time_collective;
use crate::collectives::{Algorithm, WireDtype};
use crate::fabric::topology::Topology;
use crate::fabric::NetSim;
use crate::Ns;

use super::table::{MeasuredCell, TuningTable};

/// The collectives the probe measures.
pub const TUNED_KINDS: [CollectiveKind; 2] =
    [CollectiveKind::Allreduce, CollectiveKind::Allgather];

/// Grid description for a tuning run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProbeSpec {
    /// Largest rank count probed (rows: powers of two plus 3·2^k).
    pub max_ranks: usize,
    pub min_bytes: u64,
    pub max_bytes: u64,
    /// Log-spaced size points between min and max, inclusive.
    pub size_points: usize,
}

impl ProbeSpec {
    /// The full grid the `tune` subcommand measures by default.
    pub fn full() -> Self {
        Self { max_ranks: 64, min_bytes: 1 << 10, max_bytes: 64 << 20, size_points: 9 }
    }

    /// Tiny grid for CI smoke runs and tests.
    pub fn quick() -> Self {
        Self { max_ranks: 16, min_bytes: 1 << 10, max_bytes: 4 << 20, size_points: 4 }
    }

    /// Rank rows: powers of two plus 3·2^k (so ring-only non-power-of-two
    /// cells — and hierarchical cells with non-power-of-two leader counts
    /// — are measured too), clamped to `max_ranks`.
    pub fn rank_grid(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for start in [2usize, 6] {
            let mut p = start;
            while p <= self.max_ranks {
                out.push(p);
                p *= 2;
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// [`ProbeSpec::rank_grid`] extended with rows representative of the
    /// topology's tier shapes: for every tier size s, the multiples s,
    /// 2s, 3s and 4s (clamped to `max_ranks`). On a 3-level fabric this
    /// guarantees cells where the multi-level hierarchical candidates
    /// exist (p a strict multiple of the rack size), so the measured
    /// table actually covers 2- AND 3-level shapes instead of whatever
    /// the generic grid happens to hit.
    pub fn rank_grid_for(&self, topo: &Topology) -> Vec<usize> {
        let mut out = self.rank_grid();
        for s in topo.level_sizes() {
            for m in 1..=4usize {
                let p = s * m;
                if p >= 2 && p <= self.max_ranks {
                    out.push(p);
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// [`ProbeSpec::size_grid`] extended with the topology's RAIL
    /// dimension: on a multi-rail fabric the striping discount switches
    /// on in whole-chunk steps ([`Topology::stripe_count`]), so the grid
    /// adds the stripe-transition sizes `k · chunk_bytes` for
    /// k = 1..=max_rails — the buffer sizes at which a full-buffer round
    /// (recursive doubling's regime) starts occupying its k-th rail.
    /// The measured latency/bandwidth crossovers move exactly across
    /// this region, which the generic log-spaced grid can miss.
    /// Single-rail fabrics keep the generic grid unchanged.
    pub fn size_grid_for(&self, topo: &Topology) -> Vec<u64> {
        let mut out = self.size_grid();
        let rails = topo.max_rails() as u64;
        if rails > 1 {
            for k in 1..=rails {
                let b = k * topo.chunk_bytes;
                if (self.min_bytes..=self.max_bytes).contains(&b) {
                    out.push(b);
                }
            }
            out.sort_unstable();
            out.dedup();
        }
        out
    }

    /// Log-spaced byte sizes from min to max inclusive (ascending).
    pub fn size_grid(&self) -> Vec<u64> {
        let k = self.size_points.max(2);
        let lo = self.min_bytes.max(4) as f64;
        let hi = (self.max_bytes.max(self.min_bytes.max(4))) as f64;
        let mut out: Vec<u64> = (0..k)
            .map(|i| {
                let f = i as f64 / (k - 1) as f64;
                (lo.ln() * (1.0 - f) + hi.ln() * f).exp().round() as u64
            })
            .collect();
        out.dedup();
        out
    }
}

/// Candidates the probe measures for (topo, kind, p) — exactly the sets
/// the analytic selector considers, so the tuned and analytic policies
/// choose from the same menu.
pub fn probe_candidates(topo: &Topology, kind: CollectiveKind, p: usize) -> Vec<Algorithm> {
    match kind {
        CollectiveKind::Allreduce => candidate_algorithms(topo, p),
        CollectiveKind::Allgather => allgather_candidates(topo, p),
        _ => vec![Algorithm::Ring],
    }
}

/// Time one collective on an otherwise idle simulated fabric.
pub fn measure_ns(
    topo: &Topology,
    kind: CollectiveKind,
    alg: Algorithm,
    p: usize,
    bytes: u64,
) -> Ns {
    // Counted here — once per (cell, candidate) measurement — so the
    // serial and threaded grid walks bump `tuner.probes` identically.
    crate::metrics::registry::inc("tuner.probes");
    let n = (bytes / 4).max(1) as usize; // f32 elements
    let programs = build(kind, alg, p, n).expect("probe candidates are buildable");
    let mut sim = NetSim::new(topo.clone(), p);
    time_collective(&mut sim, programs, WireDtype::F32, 1)
}

/// Measure the whole grid, reporting `(done_cells, total_cells)` after
/// every cell.
pub fn tune_with_progress(
    topo: &Topology,
    spec: &ProbeSpec,
    mut progress: impl FnMut(usize, usize),
) -> TuningTable {
    let ranks = spec.rank_grid_for(topo);
    let sizes = spec.size_grid_for(topo);
    let total = TUNED_KINDS.len() * ranks.len() * sizes.len();
    let mut done = 0;
    let mut table = TuningTable::for_topology(topo);
    for kind in TUNED_KINDS {
        for &p in &ranks {
            let cands = probe_candidates(topo, kind, p);
            for &bytes in &sizes {
                let timings: Vec<(Algorithm, Ns)> = cands
                    .iter()
                    .map(|&a| (a, measure_ns(topo, kind, a, p, bytes)))
                    .collect();
                table.insert(kind, MeasuredCell::new(p, bytes, timings));
                done += 1;
                progress(done, total);
            }
        }
    }
    table
}

/// Measure the whole grid silently.
pub fn tune(topo: &Topology, spec: &ProbeSpec) -> TuningTable {
    tune_with_progress(topo, spec, |_, _| {})
}

/// Measure the whole grid with `threads` worker threads
/// (`mlsl tune --sim-threads n`).
///
/// Every grid cell is an independent measurement on its own private
/// [`NetSim`] ([`measure_ns`]), so the grid is striped across scoped
/// threads with no shared state at all. Results are inserted in the
/// serial grid order afterwards, so the produced table — including its
/// JSON serialization — is byte-identical to [`tune`]'s at any thread
/// count. `threads <= 1` takes the serial path unchanged.
pub fn tune_threaded(topo: &Topology, spec: &ProbeSpec, threads: usize) -> TuningTable {
    if threads <= 1 {
        return tune(topo, spec);
    }
    let ranks = spec.rank_grid_for(topo);
    let sizes = spec.size_grid_for(topo);
    let mut cells: Vec<(CollectiveKind, usize, u64)> = Vec::new();
    for kind in TUNED_KINDS {
        for &p in &ranks {
            for &bytes in &sizes {
                cells.push((kind, p, bytes));
            }
        }
    }
    let nthreads = threads.min(cells.len()).max(1);
    let computed: Vec<Vec<(usize, MeasuredCell)>> = std::thread::scope(|scope| {
        let cells = &cells;
        let handles: Vec<_> = (0..nthreads)
            .map(|w| {
                scope.spawn(move || {
                    let mut out = Vec::new();
                    // Stripe, don't chunk: the expensive large-p cells sit
                    // at the end of the grid and would all land on the
                    // last worker otherwise.
                    let mut i = w;
                    while i < cells.len() {
                        let (kind, p, bytes) = cells[i];
                        let cands = probe_candidates(topo, kind, p);
                        let timings: Vec<(Algorithm, Ns)> = cands
                            .iter()
                            .map(|&a| (a, measure_ns(topo, kind, a, p, bytes)))
                            .collect();
                        out.push((i, MeasuredCell::new(p, bytes, timings)));
                        i += nthreads;
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("probe worker panicked")).collect()
    });
    let mut flat: Vec<(usize, MeasuredCell)> = computed.into_iter().flatten().collect();
    flat.sort_by_key(|&(i, _)| i);
    let mut table = TuningTable::for_topology(topo);
    for (i, cell) in flat {
        table.insert(cells[i].0, cell);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_are_log_spaced_and_deduped() {
        let spec =
            ProbeSpec { max_ranks: 24, min_bytes: 1 << 10, max_bytes: 1 << 20, size_points: 3 };
        assert_eq!(spec.rank_grid(), vec![2, 4, 6, 8, 12, 16, 24]);
        assert_eq!(spec.size_grid(), vec![1 << 10, 1 << 15, 1 << 20]);
        // Degenerate range collapses to one point.
        let tiny = ProbeSpec { max_ranks: 2, min_bytes: 1024, max_bytes: 1024, size_points: 5 };
        assert_eq!(tiny.size_grid(), vec![1024]);
        assert_eq!(tiny.rank_grid(), vec![2]);
    }

    #[test]
    fn quick_probe_measures_every_candidate_per_cell() {
        let topo = Topology::eth_10g_smp(2);
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let table = tune(&topo, &spec);
        assert!(!table.is_empty());
        for kind in TUNED_KINDS {
            for cell in table.cells(kind) {
                let want = probe_candidates(&topo, kind, cell.ranks);
                assert_eq!(cell.timings.len(), want.len(), "{kind:?} p={}", cell.ranks);
                for alg in want {
                    let t = cell.time_of(alg).unwrap_or_else(|| {
                        panic!("{kind:?} p={} missing {alg:?}", cell.ranks)
                    });
                    assert!(t > 0, "{kind:?} p={} {alg:?}", cell.ranks);
                }
            }
        }
        assert!(table.matches(&topo));
    }

    #[test]
    fn tier_shaped_rank_rows_cover_multi_level_cells() {
        // On a 3-level fabric the probe grid must include rack-multiple
        // rows, and those cells must measure the 3-level candidate too.
        let topo = Topology::by_name("eth10g-x2r4").unwrap(); // node=2, rack=8
        let spec = ProbeSpec { max_ranks: 32, min_bytes: 1 << 10, max_bytes: 1 << 20, size_points: 2 };
        let grid = spec.rank_grid_for(&topo);
        for p in [8usize, 16, 24, 32] {
            assert!(grid.contains(&p), "{grid:?} missing {p}");
        }
        // Flat topologies keep the generic grid.
        assert_eq!(spec.rank_grid_for(&Topology::eth_10g()), spec.rank_grid());
        let table = tune(&topo, &spec);
        let three = crate::collectives::Algorithm::hier(&[2, 8]);
        let cell16 = table
            .cells(CollectiveKind::Allreduce)
            .iter()
            .find(|c| c.ranks == 16 && c.bytes == 1 << 10)
            .expect("rack-multiple row measured");
        assert!(cell16.time_of(three).is_some(), "{cell16:?}");
        // ...and the allgather grid measures its hierarchical candidate.
        let ag16 = table
            .cells(CollectiveKind::Allgather)
            .iter()
            .find(|c| c.ranks == 16 && c.bytes == 1 << 10)
            .unwrap();
        assert!(ag16.time_of(three).is_some(), "{ag16:?}");
    }

    #[test]
    fn size_grid_gains_a_rail_dimension_on_striped_fabrics() {
        let spec =
            ProbeSpec { max_ranks: 8, min_bytes: 1 << 10, max_bytes: 4 << 20, size_points: 3 };
        // Single-rail fabrics keep the generic grid.
        let flat = Topology::eth_10g(); // chunk 256 KiB
        assert_eq!(spec.size_grid_for(&flat), spec.size_grid());
        // Multi-rail fabrics add the stripe-transition sizes k·chunk.
        let e4 = flat.clone().with_rails(4).unwrap();
        let grid = spec.size_grid_for(&e4);
        for k in 1..=4u64 {
            assert!(grid.contains(&(k * e4.chunk_bytes)), "{grid:?} missing {k}·chunk");
        }
        assert!(grid.windows(2).all(|w| w[0] < w[1]), "sorted+deduped: {grid:?}");
        // Out-of-range transitions are clamped away.
        let tiny =
            ProbeSpec { max_ranks: 8, min_bytes: 1 << 10, max_bytes: 64 << 10, size_points: 3 };
        assert_eq!(tiny.size_grid_for(&e4), tiny.size_grid());
        // The probed table measures those cells like any other.
        let quick = ProbeSpec { max_ranks: 4, min_bytes: 1 << 10, max_bytes: 1 << 20, size_points: 2 };
        let e2 = flat.with_rails(2).unwrap();
        let table = tune(&e2, &quick);
        let cell = table
            .cells(CollectiveKind::Allreduce)
            .iter()
            .find(|c| c.ranks == 4 && c.bytes == 2 * e2.chunk_bytes)
            .expect("rail-transition cell measured");
        assert!(cell.best().is_some());
    }

    #[test]
    fn threaded_tune_matches_serial_byte_for_byte() {
        let topo = Topology::eth_10g_smp(2);
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let serial = tune(&topo, &spec);
        for threads in [2usize, 3] {
            let par = tune_threaded(&topo, &spec, threads);
            assert_eq!(par.to_json_string(), serial.to_json_string(), "threads={threads}");
        }
        // threads=1 is literally the serial path.
        assert_eq!(tune_threaded(&topo, &spec, 1).to_json_string(), serial.to_json_string());
    }

    #[test]
    fn probe_measurements_bump_the_metrics_registry() {
        let before = crate::metrics::registry::get("tuner.probes");
        measure_ns(&Topology::eth_10g(), CollectiveKind::Allreduce, Algorithm::Ring, 4, 4096);
        // >= not ==: sibling tests probing concurrently also bump it.
        assert!(crate::metrics::registry::get("tuner.probes") >= before + 1);
    }

    #[test]
    fn measured_winners_track_latency_bandwidth_shape() {
        // On flat 10GbE the small-message winner must be a logarithmic-
        // round algorithm and the large-message winner bandwidth-optimal:
        // the measured table reproduces the paper's A4 shape.
        let topo = Topology::eth_10g();
        let spec = ProbeSpec { max_ranks: 16, min_bytes: 256, max_bytes: 64 << 20, size_points: 5 };
        let table = tune(&topo, &spec);
        let cells = table.cells(CollectiveKind::Allreduce);
        let small = cells.iter().find(|c| c.ranks == 16 && c.bytes == 256).unwrap();
        assert_eq!(small.best().unwrap().0, Algorithm::RecursiveDoubling);
        let large = cells.iter().find(|c| c.ranks == 16 && c.bytes == 64 << 20).unwrap();
        assert!(matches!(
            large.best().unwrap().0,
            Algorithm::Ring | Algorithm::HalvingDoubling
        ));
    }
}

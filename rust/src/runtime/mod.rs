//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the ONLY place the stack touches XLA at runtime; Python is
//! never on this path. Pattern (see /opt/xla-example/load_hlo):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`. The jax side lowers with `return_tuple=True`, so every
//! executable returns one tuple literal that we decompose.
//!
//! The external `xla` crate is not vendored in this image, so the real
//! implementation is gated behind the `pjrt` cargo feature. The default
//! build ships a stub [`Runtime`] with the identical signatures whose
//! constructor returns a descriptive error — the simulated stack (fabric,
//! collectives, engine, benches) never touches PJRT, and the trainer
//! surfaces the error cleanly when artifacts execution is requested.

pub mod manifest;

pub use manifest::{ArtifactIo, Manifest, ParamSpec};

use anyhow::Result;
#[cfg(not(feature = "pjrt"))]
use anyhow::anyhow;
#[cfg(feature = "pjrt")]
use anyhow::Context;
use std::path::Path;

/// Input tensor for an executable (f32 or i32, row-major).
#[derive(Debug, Clone)]
pub enum Input {
    F32 { data: Vec<f32>, shape: Vec<i64> },
    I32 { data: Vec<i32>, shape: Vec<i64> },
}

impl Input {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        Input::F32 { data, shape: shape.iter().map(|d| *d as i64).collect() }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        Input::I32 { data, shape: shape.iter().map(|d| *d as i64).collect() }
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Input::F32 { data, shape } => xla::Literal::vec1(data).reshape(shape)?,
            Input::I32 { data, shape } => xla::Literal::vec1(data).reshape(shape)?,
        })
    }
}

/// One output tensor, already copied to host f32.
pub type OutputF32 = Vec<f32>;

// ---------------------------------------------------------------------------
// Real implementation (requires the external `xla` crate)
// ---------------------------------------------------------------------------

/// The PJRT client wrapper.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// CPU PJRT client (the only backend on this image).
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu().context("create PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path must be utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled executable.
#[cfg(feature = "pjrt")]
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with the given inputs; returns every tuple element as f32
    /// (scalars come back as 1-element vecs; integer outputs are
    /// converted).
    pub fn run(&self, inputs: &[Input]) -> Result<Vec<OutputF32>> {
        let literals = inputs
            .iter()
            .map(|i| i.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = tuple.to_tuple().context("decompose result tuple")?;
        parts
            .into_iter()
            .enumerate()
            .map(|(i, lit)| {
                lit.to_vec::<f32>()
                    .with_context(|| format!("output {i} of {} to f32", self.name))
            })
            .collect()
    }

    /// Execute keeping outputs on device (hot path: avoids host copies of
    /// parameters between steps). Returns device buffers in tuple order.
    pub fn run_buffers(&self, inputs: &[Input]) -> Result<Vec<xla::PjRtBuffer>> {
        let literals = inputs
            .iter()
            .map(|i| i.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let mut result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute {}", self.name))?;
        Ok(result.remove(0))
    }
}

// ---------------------------------------------------------------------------
// Stub implementation (default build: no `xla` crate available)
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
const NO_PJRT: &str =
    "built without the `pjrt` feature: the PJRT runtime (external `xla` crate) is unavailable";

/// Stub PJRT client: constructor always errors (see module docs).
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    _priv: (),
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        Err(anyhow!("{NO_PJRT}"))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load_hlo<P: AsRef<Path>>(&self, _path: P) -> Result<Executable> {
        Err(anyhow!("{NO_PJRT}"))
    }
}

/// Stub executable (uninhabitable in practice: `Runtime::cpu` errors).
#[cfg(not(feature = "pjrt"))]
pub struct Executable {
    _priv: (),
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    pub fn run(&self, _inputs: &[Input]) -> Result<Vec<OutputF32>> {
        Err(anyhow!("{NO_PJRT}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_errors_descriptively() {
        let err = Runtime::cpu().err().expect("stub must error");
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }

    #[cfg(feature = "pjrt")]
    mod real {
        use super::super::*;
        use std::io::Write;

        /// HLO text for f(x, y) = (x + y,) over f32[4]. Hand-written, minimal.
        const ADD_HLO: &str = r#"
HloModule add4, entry_computation_layout={(f32[4]{0}, f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  y = f32[4]{0} parameter(1)
  s = f32[4]{0} add(x, y)
  ROOT out = (f32[4]{0}) tuple(s)
}
"#;

        fn write_tmp(name: &str, text: &str) -> std::path::PathBuf {
            let dir = std::env::temp_dir().join("mlsl_runtime_tests");
            std::fs::create_dir_all(&dir).unwrap();
            let p = dir.join(name);
            let mut f = std::fs::File::create(&p).unwrap();
            f.write_all(text.as_bytes()).unwrap();
            p
        }

        #[test]
        fn loads_and_runs_hand_written_hlo() {
            let rt = Runtime::cpu().unwrap();
            assert!(!rt.platform().is_empty());
            let path = write_tmp("add4.hlo.txt", ADD_HLO);
            let exe = rt.load_hlo(&path).unwrap();
            let out = exe
                .run(&[
                    Input::f32(vec![1.0, 2.0, 3.0, 4.0], &[4]),
                    Input::f32(vec![10.0, 20.0, 30.0, 40.0], &[4]),
                ])
                .unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0], vec![11.0, 22.0, 33.0, 44.0]);
        }

        #[test]
        fn missing_artifact_is_an_error() {
            let rt = Runtime::cpu().unwrap();
            assert!(rt.load_hlo("/nonexistent/nope.hlo.txt").is_err());
        }
    }
}

//! The compute-to-communication ratio model of Das et al.
//! (arXiv:1602.06709), which the paper says its design choices derive
//! from ("Based on this analysis, we derived the compute to communication
//! ratio...").
//!
//! Key observations encoded here (paper §Design choices):
//!
//! * **Data parallelism**: comm per layer = one weight-gradient allreduce
//!   ≈ 2·W bytes (ring factor 2(P−1)/P → 2); compute ∝ batch. The ratio is
//!   therefore ∝ mini-batch and ∝ output-featuremap work but INDEPENDENT
//!   of kernel size / channel counts (both scale compute and weights the
//!   same way only through W; the out-featuremap term scales compute
//!   only).
//! * **Model parallelism**: comm per layer = activation exchange ∝ batch —
//!   the ratio is batch-independent; attractive only when weights ≫
//!   activations (fc layers).
//! * **Hybrid**: groups of g nodes do model parallelism inside a group,
//!   data parallelism across P/g groups; both terms shrink.
//!
//! All network-time predictions go through a
//! [`crate::tuner::SelectionPolicy`] (the `*_with_policy` variants; the
//! plain functions use the analytic default), whose analytic path is
//! [`crate::collectives::selector::predict_allreduce_ns`] — pricing each
//! hop with the N-LEVEL alpha–beta model of
//! [`crate::fabric::topology::Topology`]: every hop at its deepest
//! common tier (socket / node / rack / top fabric). With a measured
//! tuning table loaded, allreduce terms come from (log-interpolated)
//! measurements instead of the closed forms. On tiered topologies this
//! also makes model-parallel groups that fit inside one tier dramatically
//! cheaper — a node-sized group's activation exchanges never touch the
//! NIC, a rack-sized group's never cross the spine.

use crate::fabric::topology::{NodeSpec, Topology};
use crate::models::{LayerDesc, ModelDesc};

/// How a layer's work is partitioned (the paper's three choices).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parallelism {
    Data,
    Model,
    /// Node-group hybrid: model parallel inside groups of `group` nodes,
    /// data parallel across the `p / group` groups.
    Hybrid { group: usize },
}

/// Communication bytes ONE node must move for `layer` in one iteration.
pub fn comm_bytes(layer: &LayerDesc, par: Parallelism, p: usize, batch: usize) -> u64 {
    if p <= 1 {
        return 0;
    }
    let w = layer.weight_bytes() as f64;
    // One node's slice of activations (its `batch` samples).
    let act = (4 * layer.out_act_elems * batch) as f64;
    let bytes = match par {
        // Ring allreduce of the weight gradient: 2(P−1)/P ≈ 2 × W.
        Parallelism::Data => 2.0 * (p as f64 - 1.0) / p as f64 * w,
        // The group (= world) jointly holds P·batch samples: ring
        // allgather forward + the mirror exchange backward move
        // (P−1)·act per node per direction.
        Parallelism::Model => 2.0 * (p as f64 - 1.0) * act,
        Parallelism::Hybrid { group } => {
            let g = group.max(1).min(p) as f64;
            let groups = (p as f64 / g).max(1.0);
            // Weight shard allreduced across groups + activations inside.
            let wterm = 2.0 * (groups - 1.0) / groups * (w / g);
            let aterm = 2.0 * (g - 1.0) * act;
            wterm + aterm
        }
    };
    bytes.ceil() as u64
}

/// Compute FLOPs one node performs for `layer` in one iteration (fwd+bwd).
///
/// Semantics (Das et al.): every node always carries `batch` samples of
/// work. Under model/hybrid parallelism a group of g nodes jointly
/// processes g·batch samples with weights sharded 1/g — per-node compute
/// is unchanged; what changes is WHICH bytes move (weight gradients
/// shrink 1/g, activations must be exchanged within the group).
pub fn compute_flops(layer: &LayerDesc, par: Parallelism, batch: usize) -> f64 {
    let _ = par;
    (layer.fwd_flops + layer.bwd_flops()) * batch as f64
}

/// The paper's compute-to-communication ratio (FLOPs per byte moved).
/// Higher = scales better. `f64::INFINITY` when no communication.
pub fn ratio(layer: &LayerDesc, par: Parallelism, p: usize, batch: usize) -> f64 {
    let c = comm_bytes(layer, par, p, batch);
    if c == 0 {
        return f64::INFINITY;
    }
    compute_flops(layer, par, batch) / c as f64
}

/// Pick the best parallelism for one layer by maximizing the ratio over
/// {data, model, hybrid(2,4,...,p)} — the "choosing the right work
/// partitioning strategy" procedure.
pub fn best_parallelism(layer: &LayerDesc, p: usize, batch: usize) -> Parallelism {
    let mut candidates = vec![Parallelism::Data, Parallelism::Model];
    let mut g = 2;
    while g < p {
        candidates.push(Parallelism::Hybrid { group: g });
        g *= 2;
    }
    *candidates
        .iter()
        .max_by(|a, b| {
            ratio(layer, **a, p, batch)
                .partial_cmp(&ratio(layer, **b, p, batch))
                .unwrap()
        })
        .unwrap()
}

/// Pick the best UNIFORM node-group size for a whole model on a cluster
/// of `p` nodes: evaluates every power-of-two group size with the
/// alpha-beta fabric model and returns (group, predicted exposed comm ns)
/// — the paper's "identify the optimal parallelization strategy",
/// model-level granularity. Used by `Session::auto_group` and the A1
/// bench cross-check.
pub fn best_group_size(
    model: &ModelDesc,
    topo: &Topology,
    node: &NodeSpec,
    p: usize,
    batch: usize,
) -> (usize, u64) {
    best_group_size_with_policy(
        model,
        topo,
        node,
        p,
        batch,
        &crate::tuner::SelectionPolicy::Analytic,
    )
}

/// [`best_group_size`] under an explicit [`crate::tuner::SelectionPolicy`]:
/// with a tuned policy the gradient-allreduce terms come from measured
/// (interpolated) table cells instead of the closed-form model, so the
/// design-space search calibrates to the same measurements the engine
/// selects with.
pub fn best_group_size_with_policy(
    model: &ModelDesc,
    topo: &Topology,
    node: &NodeSpec,
    p: usize,
    batch: usize,
    policy: &crate::tuner::SelectionPolicy,
) -> (usize, u64) {
    let mut best = (1usize, u64::MAX);
    let mut g = 1usize;
    while g <= p {
        if p % g == 0 {
            // Per-node comm cost: weight shards across groups (hideable;
            // count the unhidden fraction vs the bwd window) + blocking
            // activation exchanges (always exposed).
            let mut act_ns = 0u64;
            let mut grad_ns = 0u64;
            let groups = p / g;
            for layer in &model.layers {
                if g > 1 && layer.out_act_elems > 0 {
                    let bytes = (4 * layer.out_act_elems * batch * g) as u64;
                    // Ring allgather within the group, twice (fwd + bwd),
                    // priced at the innermost tier whose groups contain a
                    // contiguous aligned g-rank run (the group straddles
                    // that tier's boundary otherwise — ultimately the
                    // top): socket-sized groups ride the socket tier,
                    // node-sized the node tier, rack-sized the rack.
                    // Rail-aware: each hop's chunks stripe across the
                    // tier's rails (wire term only; alpha is paid once).
                    let hop =
                        topo.striped_msg_ns_at(topo.level_for_group(g), bytes / g as u64);
                    act_ns += 2 * (g as u64 - 1) * hop;
                }
                if groups > 1 && layer.weight_elems > 0 {
                    let bytes = (4 * layer.weight_elems.div_ceil(g)) as u64;
                    // g == 1: the communicator is the contiguous world and
                    // may go hierarchical (Auto). g > 1: cross-group
                    // communicators are strided (one rank per group) —
                    // only flat algorithms apply, priced all inter-tier
                    // since member distance says nothing about
                    // co-location.
                    grad_ns += if g == 1 {
                        policy.predict_allreduce_ns(topo, groups, bytes)
                    } else {
                        let alg = policy.choose_flat_allreduce(topo, groups, bytes);
                        crate::collectives::selector::predict_flat_inter_allreduce_ns(
                            topo, alg, groups, bytes,
                        )
                    };
                }
            }
            let bwd_window =
                node.compute_ns(model.bwd_flops_per_sample() * batch as f64, 2);
            let exposed = act_ns + grad_ns.saturating_sub(bwd_window);
            if exposed < best.1 {
                best = (g, exposed);
            }
        }
        g *= 2;
    }
    best
}

/// Closed-form iteration-time prediction for data-parallel training with
/// perfect overlap except the first layer (the paper's best case), used to
/// cross-check the simulator.
pub fn predict_iteration_ns(
    model: &ModelDesc,
    topo: &Topology,
    node: &NodeSpec,
    p: usize,
    batch: usize,
    comm_cores: usize,
) -> u64 {
    predict_iteration_ns_with_policy(
        model,
        topo,
        node,
        p,
        batch,
        comm_cores,
        &crate::tuner::SelectionPolicy::Analytic,
    )
}

/// [`predict_iteration_ns`] under an explicit selection policy (measured
/// allreduce times when a tuning table is available).
pub fn predict_iteration_ns_with_policy(
    model: &ModelDesc,
    topo: &Topology,
    node: &NodeSpec,
    p: usize,
    batch: usize,
    comm_cores: usize,
    policy: &crate::tuner::SelectionPolicy,
) -> u64 {
    let compute_ns = node.compute_ns(model.step_flops(batch), comm_cores);
    if p <= 1 {
        return compute_ns;
    }
    let mut comm_ns = 0u64;
    for (_, layer) in model.weighted_layers() {
        let bytes = comm_bytes(layer, Parallelism::Data, p, batch);
        comm_ns += policy.predict_allreduce_ns(
            topo,
            p,
            // predict takes total buffer bytes; comm_bytes already has the
            // ring factor, so undo it here.
            (bytes as f64 / (2.0 * (p as f64 - 1.0) / p as f64)) as u64,
        );
    }
    // With overlap, exposed comm = max(0, comm - bwd compute window).
    let bwd_ns = node.compute_ns(model.bwd_flops_per_sample() * batch as f64, comm_cores);
    let exposed = comm_ns.saturating_sub(bwd_ns);
    compute_ns + exposed
}

/// [`predict_iteration_ns_with_policy`] with compressed collectives on
/// the menu: each gradient allreduce is priced at the cheapest
/// (algorithm × wire-precision) candidate in `wires` — wire bytes at the
/// compressed width, per-hop (de)quantize cost on the endpoints at
/// `slowdown_milli` (1000 = healthy). An `&[WireDtype::F32]` menu
/// reproduces [`predict_iteration_ns_with_policy`] exactly.
#[allow(clippy::too_many_arguments)]
pub fn predict_iteration_ns_wire(
    model: &ModelDesc,
    topo: &Topology,
    node: &NodeSpec,
    p: usize,
    batch: usize,
    comm_cores: usize,
    policy: &crate::tuner::SelectionPolicy,
    wires: &[crate::collectives::WireDtype],
    slowdown_milli: u64,
) -> u64 {
    let compute_ns = node.compute_ns(model.step_flops(batch), comm_cores);
    if p <= 1 {
        return compute_ns;
    }
    let mut comm_ns = 0u64;
    for (_, layer) in model.weighted_layers() {
        let bytes = comm_bytes(layer, Parallelism::Data, p, batch);
        comm_ns += policy.predict_allreduce_ns_wire(
            topo,
            p,
            (bytes as f64 / (2.0 * (p as f64 - 1.0) / p as f64)) as u64,
            wires,
            slowdown_milli,
        );
    }
    let bwd_ns = node.compute_ns(model.bwd_flops_per_sample() * batch as f64, comm_cores);
    compute_ns + comm_ns.saturating_sub(bwd_ns)
}

/// Weak-scaling efficiency prediction: T(1) / T(P) with per-node batch
/// fixed.
pub fn predict_efficiency(
    model: &ModelDesc,
    topo: &Topology,
    node: &NodeSpec,
    p: usize,
    batch: usize,
    comm_cores: usize,
) -> f64 {
    let t1 = predict_iteration_ns(model, topo, node, 1, batch, comm_cores);
    let tp = predict_iteration_ns(model, topo, node, p, batch, comm_cores);
    t1 as f64 / tp as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{fc, ModelDesc};

    fn conv_layer() -> LayerDesc {
        crate::models::conv("c", 3, 256, 256, 28, 28)
    }

    fn fc_layer() -> LayerDesc {
        fc("f", 4096, 4096)
    }

    #[test]
    fn data_parallel_ratio_grows_with_batch() {
        let l = conv_layer();
        let r1 = ratio(&l, Parallelism::Data, 16, 1);
        let r32 = ratio(&l, Parallelism::Data, 16, 32);
        assert!((r32 / r1 - 32.0).abs() < 0.5, "{r1} {r32}");
    }

    #[test]
    fn model_parallel_ratio_batch_independent() {
        let l = fc_layer();
        let r1 = ratio(&l, Parallelism::Model, 16, 1);
        let r32 = ratio(&l, Parallelism::Model, 16, 32);
        // Compute scales with batch but so does activation comm.
        assert!((r32 / r1 - 1.0).abs() < 0.05, "{r1} {r32}");
    }

    #[test]
    fn conv_prefers_data_fc_prefers_model_or_hybrid() {
        // The paper's table: conv layers (small weights, big activations)
        // → data parallel; fc layers (big weights, small activations) at
        // small batch → model/hybrid.
        let c = conv_layer();
        assert_eq!(best_parallelism(&c, 64, 32), Parallelism::Data);
        let f = fc_layer();
        let best = best_parallelism(&f, 64, 4);
        assert_ne!(best, Parallelism::Data, "fc at tiny batch must shard the model");
    }

    #[test]
    fn ratio_independent_of_kernel_size_for_data_parallel() {
        // Das et al.: the data-parallel ratio depends on output featuremap
        // size and batch, NOT on k (both compute and weights carry k²).
        let l3 = crate::models::conv("a", 3, 128, 128, 28, 28);
        let l5 = crate::models::conv("b", 5, 128, 128, 28, 28);
        let r3 = ratio(&l3, Parallelism::Data, 16, 8);
        let r5 = ratio(&l5, Parallelism::Data, 16, 8);
        assert!((r3 / r5 - 1.0).abs() < 0.02, "{r3} vs {r5}");
    }

    #[test]
    fn hybrid_interpolates_extremes() {
        let l = fc_layer();
        let (p, b) = (16, 8);
        let d = comm_bytes(&l, Parallelism::Data, p, b);
        let m = comm_bytes(&l, Parallelism::Model, p, b);
        let h1 = comm_bytes(&l, Parallelism::Hybrid { group: 1 }, p, b);
        let hp = comm_bytes(&l, Parallelism::Hybrid { group: p }, p, b);
        // group=1 == pure data parallel; group=p == pure model parallel.
        assert_eq!(h1, d);
        assert_eq!(hp, m);
    }

    #[test]
    fn efficiency_increases_with_batch() {
        let model = ModelDesc::by_name("resnet50").unwrap();
        let topo = crate::fabric::topology::Topology::omnipath_100g();
        let node = crate::fabric::topology::NodeSpec::skylake_6148();
        let e_small = predict_efficiency(&model, &topo, &node, 64, 2, 2);
        let e_big = predict_efficiency(&model, &topo, &node, 64, 64, 2);
        assert!(e_big > e_small, "{e_small} vs {e_big}");
    }

    #[test]
    fn auto_group_matches_model_character() {
        let topo = crate::fabric::topology::Topology::eth_25g();
        let node = crate::fabric::topology::NodeSpec::skylake_6148();
        // fc-heavy AlexNet at tiny batch: grouping must win.
        let alex = ModelDesc::by_name("alexnet").unwrap();
        let (g_alex, _) = best_group_size(&alex, &topo, &node, 64, 4);
        assert!(g_alex > 1, "alexnet wants model sharding, got group {g_alex}");
        // conv-dominated ResNet-50 at healthy batch: pure data parallel.
        let resnet = ModelDesc::by_name("resnet50").unwrap();
        let (g_res, _) = best_group_size(&resnet, &topo, &node, 64, 32);
        assert_eq!(g_res, 1);
    }

    #[test]
    fn smp_nodes_make_node_sized_groups_cheaper() {
        let node = crate::fabric::topology::NodeSpec::skylake_6148();
        let alex = ModelDesc::by_name("alexnet").unwrap();
        let flat = crate::fabric::topology::Topology::eth_10g();
        let smp = crate::fabric::topology::Topology::eth_10g_smp(4);
        let (_, cost_flat) = best_group_size(&alex, &flat, &node, 64, 4);
        let (g_smp, cost_smp) = best_group_size(&alex, &smp, &node, 64, 4);
        // Per-g costs on the smp fabric are <= the flat fabric's (in-node
        // activation exchanges ride shared memory; gradient terms match),
        // so the optimum cannot be worse...
        assert!(cost_smp <= cost_flat, "{cost_smp} vs {cost_flat}");
        // ...and fc-heavy AlexNet at tiny batch shards within the node.
        assert!(g_smp > 1, "expected model sharding on smp nodes, got g={g_smp}");
    }

    #[test]
    fn session_auto_group_applies() {
        let topo = crate::fabric::topology::Topology::eth_25g();
        let node = crate::fabric::topology::NodeSpec::skylake_6148();
        let alex = ModelDesc::by_name("alexnet").unwrap();
        let mut s = crate::mlsl::Session::new(crate::mlsl::Distribution::data_parallel(64));
        s.add_model(&alex);
        let g = s.auto_group(&alex, &topo, &node, 4);
        assert_eq!(s.distribution().group_size(), g);
        assert!(g > 1);
    }

    #[test]
    fn single_node_is_free() {
        let l = conv_layer();
        assert_eq!(comm_bytes(&l, Parallelism::Data, 1, 32), 0);
        assert!(ratio(&l, Parallelism::Data, 1, 32).is_infinite());
    }

    #[test]
    fn policy_threading_defaults_to_analytic_and_accepts_tables() {
        use crate::tuner::{tune, ProbeSpec, SelectionPolicy};
        let model = ModelDesc::by_name("resnet50").unwrap();
        let topo = crate::fabric::topology::Topology::eth_10g();
        let node = crate::fabric::topology::NodeSpec::skylake_6148();
        // The plain entry points are exactly the analytic policy.
        assert_eq!(
            best_group_size(&model, &topo, &node, 16, 16),
            best_group_size_with_policy(&model, &topo, &node, 16, 16, &SelectionPolicy::Analytic)
        );
        assert_eq!(
            predict_iteration_ns(&model, &topo, &node, 16, 16, 2),
            predict_iteration_ns_with_policy(
                &model,
                &topo,
                &node,
                16,
                16,
                2,
                &SelectionPolicy::Analytic
            )
        );
        // A measured table yields a sane prediction of the same magnitude
        // (measured and modeled times agree within the sim-vs-model slack).
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 16;
        let policy = SelectionPolicy::TunedWithFallback(tune(&topo, &spec));
        let analytic = predict_iteration_ns(&model, &topo, &node, 16, 16, 2);
        let tuned =
            predict_iteration_ns_with_policy(&model, &topo, &node, 16, 16, 2, &policy);
        let ratio = tuned as f64 / analytic as f64;
        assert!((0.5..2.0).contains(&ratio), "tuned={tuned} analytic={analytic}");
    }

    #[test]
    fn wire_menu_prediction_brackets_the_plain_model() {
        use crate::collectives::WireDtype;
        use crate::tuner::SelectionPolicy;
        let model = ModelDesc::by_name("vgg16").unwrap();
        let topo = crate::fabric::topology::Topology::eth_10g();
        let node = crate::fabric::topology::NodeSpec::skylake_6148();
        let policy = SelectionPolicy::Analytic;
        let plain = predict_iteration_ns_with_policy(&model, &topo, &node, 8, 16, 2, &policy);
        // The f32-only menu IS the plain model.
        let f32_only = predict_iteration_ns_wire(
            &model, &topo, &node, 8, 16, 2, &policy, &[WireDtype::F32], 1000,
        );
        assert_eq!(f32_only, plain);
        // A full menu can only shave exposed comm, never add to it —
        // and on 10G ethernet under vgg16's fc layers it really does.
        let full = predict_iteration_ns_wire(
            &model, &topo, &node, 8, 16, 2, &policy, &WireDtype::ALL, 1000,
        );
        assert!(full < plain, "full-menu={full} plain={plain}");
        let compute = node.compute_ns(model.step_flops(16), 2);
        assert!(full >= compute);
    }
}

//! Deterministic trace layer: structured spans recorded from the event
//! hot paths of [`crate::fabric::NetSim`], the collective executors and
//! the engine, with analyzers on top.
//!
//! Design contract (docs/TRACING.md):
//!
//! * **Zero behavioral impact when disabled.** The simulator owns an
//!   `Option<Box<TraceBuf>>`; every hook is a single `if let` on that
//!   option, and no hook mutates anything the event loop reads. With
//!   tracing off the delivered-message stream, completion timestamps and
//!   stats are byte-identical to a build without this module
//!   (regression-tested in `tests/prop_trace.rs`, bounded by the
//!   `a12_trace_overhead` bench).
//! * **Content identity, not local ids.** Spans carry only simulation
//!   content (ranks, bytes, priorities, tags, timestamps) — never
//!   per-shard message ids — so the per-shard buffers of a partitioned
//!   run ([`crate::collectives::parexec`]) merge into a trace
//!   byte-identical to the serial run's ([`Trace::normalized`]).
//! * **Causality built in.** Every hop/compute span records the event
//!   that triggered its posting ([`Cause`]), which is what the
//!   critical-path analyzer ([`critical`]) walks backwards.
//!
//! Renderers/analyzers over the span store: Chrome trace-event JSON
//! export ([`chrome`], loads in Perfetto / `chrome://tracing`),
//! critical-path decomposition ([`critical::critical_path`]), windowed
//! utilization time series ([`Utilization`]), and the ASCII Gantt
//! timeline ([`crate::metrics::Timeline::from_trace`]).

pub mod chrome;
pub mod critical;

use std::collections::{BTreeMap, HashMap};

use crate::fabric::MsgDesc;
use crate::{Ns, Priority, Rank};

/// The event that caused a span to be posted: the simulator event the
/// driver was reacting to when it issued the send/compute. Identified by
/// *content* (not ids), so serial and partitioned runs agree exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Cause {
    /// A message delivery (`SimEvent::MsgDelivered`).
    Msg { at: Ns, src: Rank, dst: Rank, bytes: u64, priority: Priority, tag: u64 },
    /// A compute completion (`SimEvent::ComputeDone`).
    Compute { at: Ns, node: Rank, tag: u64 },
}

/// Which egress channel a busy interval was measured on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TrackChan {
    /// One NIC rail (strict-priority, preemptive).
    Rail(u32),
    /// The intra-node shared-memory channel (FIFO, one free class).
    Shm,
}

/// One point-to-point message's full lifecycle, recorded on the source
/// node when its last egress piece leaves the wire.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct HopSpan {
    pub src: Rank,
    pub dst: Rank,
    pub bytes: u64,
    pub priority: Priority,
    /// Collective id (the executor posts messages tagged with it).
    pub tag: u64,
    /// Deepest common tier the hop was priced at.
    pub level: usize,
    /// When the send was posted.
    pub posted_at: Ns,
    /// When the first piece first held a wire (queueing ends here).
    pub first_service_at: Ns,
    /// When the LAST egress piece left the wire.
    pub egress_done_at: Ns,
    /// Delivery at the destination (`egress_done_at` + in-flight latency).
    pub deliver_at: Ns,
    /// Pure wire service of the max-cost piece (overhead + bytes/bw):
    /// the egress time the hop needs with zero contention.
    pub service_ns: Ns,
    /// Rail pieces the transfer was striped into.
    pub pieces: u32,
    /// Chaos latency multiplier applied in flight (1000 = healthy).
    pub lat_mult_milli: u64,
    /// Event the posting driver was reacting to (None: posted up front).
    pub cause: Option<Cause>,
}

impl HopSpan {
    /// Queueing delay: posted until a wire first served it.
    pub fn queue_ns(&self) -> Ns {
        self.first_service_at.saturating_sub(self.posted_at)
    }

    /// Preemption/gating stall: wire-holding interval minus pure service.
    pub fn stall_ns(&self) -> Ns {
        (self.egress_done_at.saturating_sub(self.first_service_at))
            .saturating_sub(self.service_ns)
    }

    /// In-flight (alpha) time after the last piece left the wire.
    pub fn flight_ns(&self) -> Ns {
        self.deliver_at.saturating_sub(self.egress_done_at)
    }

    /// End-to-end posted-to-delivered time.
    pub fn total_ns(&self) -> Ns {
        self.deliver_at.saturating_sub(self.posted_at)
    }
}

/// A contiguous busy interval of one egress channel.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct BusySpan {
    pub node: Rank,
    pub chan: TrackChan,
    /// Urgency class of the transfer that held the wire.
    pub class: Priority,
    pub start: Ns,
    pub end: Ns,
}

/// A compute timer interval (post to expiry, chaos slowdown included).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct ComputeSpan {
    pub node: Rank,
    pub start: Ns,
    pub end: Ns,
    pub tag: u64,
    pub cause: Option<Cause>,
}

/// One structured trace record. The derived `Ord` is the canonical
/// content order [`Trace::normalized`] sorts into.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceEvent {
    /// A message hop (see [`HopSpan`]).
    Hop(HopSpan),
    /// An egress-channel busy interval.
    Busy(BusySpan),
    /// A compute interval.
    Compute(ComputeSpan),
    /// A collective was posted to the executor.
    CollStart { coll_id: u64, at: Ns, priority: Priority, ranks: usize },
    /// One rank finished its chunk program for `coll_id`.
    RankDone { coll_id: u64, rank: Rank, at: Ns },
    /// A zero-bandwidth chaos window opened (`on`) or closed (`!on`).
    ChaosGate { at: Ns, on: bool },
    /// A chaos plan killed one NIC rail.
    RailDie { at: Ns, node: Rank, rail: u32 },
    /// A labeled engine marker (phase transition, collective issue).
    Mark { node: Rank, at: Ns, track: String, label: String },
}

impl TraceEvent {
    /// Start timestamp used for time-ordered rendering.
    pub fn start_ns(&self) -> Ns {
        match self {
            TraceEvent::Hop(h) => h.posted_at,
            TraceEvent::Busy(b) => b.start,
            TraceEvent::Compute(c) => c.start,
            TraceEvent::CollStart { at, .. }
            | TraceEvent::RankDone { at, .. }
            | TraceEvent::ChaosGate { at, .. }
            | TraceEvent::RailDie { at, .. }
            | TraceEvent::Mark { at, .. } => *at,
        }
    }

    /// End timestamp (== start for instants).
    pub fn end_ns(&self) -> Ns {
        match self {
            TraceEvent::Hop(h) => h.deliver_at,
            TraceEvent::Busy(b) => b.end,
            TraceEvent::Compute(c) => c.end,
            other => other.start_ns(),
        }
    }
}

/// An immutable, mergeable span store.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn span_count(&self) -> usize {
        self.events.len()
    }

    /// Latest timestamp any span touches.
    pub fn end_time(&self) -> Ns {
        self.events.iter().map(|e| e.end_ns()).max().unwrap_or(0)
    }

    /// Sort into the canonical content order. Two traces of the same
    /// simulation — serial or merged from partitioned shards — are
    /// byte-identical after normalization.
    pub fn normalized(mut self) -> Trace {
        self.events.sort();
        self
    }

    /// Merge per-shard buffers into one canonical trace. Every record is
    /// recorded by exactly one shard (the owner of its source/node), so
    /// concatenation followed by the canonical sort reproduces the
    /// serial trace exactly.
    pub fn merge(parts: Vec<Trace>) -> Trace {
        let mut events = Vec::with_capacity(parts.iter().map(|t| t.events.len()).sum());
        for mut t in parts {
            events.append(&mut t.events);
        }
        Trace { events }.normalized()
    }

    /// All hop spans, in store order.
    pub fn hops(&self) -> impl Iterator<Item = &HopSpan> {
        self.events.iter().filter_map(|e| match e {
            TraceEvent::Hop(h) => Some(h),
            _ => None,
        })
    }
}

/// The live recording buffer a [`crate::fabric::NetSim`] owns while
/// tracing is enabled. All per-message bookkeeping (pending hops, the
/// current cause) lives HERE, so the disabled simulator carries no
/// trace state at all.
#[derive(Debug, Default)]
pub struct TraceBuf {
    pub events: Vec<TraceEvent>,
    /// Hops posted but not yet fully off the wire, keyed by the
    /// simulator's private message id (never exposed in records).
    pending: HashMap<u64, PendingHop>,
    /// The event the driver is currently reacting to.
    pub current_cause: Option<Cause>,
}

#[derive(Debug)]
struct PendingHop {
    level: usize,
    pieces: u32,
    posted_at: Ns,
    first_service_at: Option<Ns>,
    service_ns: Ns,
    cause: Option<Cause>,
}

impl TraceBuf {
    /// A send was posted: open the hop record.
    pub fn start_hop(
        &mut self,
        msg_id: u64,
        level: usize,
        pieces: u32,
        service_ns: Ns,
        now: Ns,
    ) {
        self.pending.insert(
            msg_id,
            PendingHop {
                level,
                pieces,
                posted_at: now,
                first_service_at: None,
                service_ns,
                cause: self.current_cause,
            },
        );
    }

    /// A wire elected a piece of `msg_id` to run (first election wins).
    pub fn note_service(&mut self, msg_id: u64, now: Ns) {
        if let Some(p) = self.pending.get_mut(&msg_id) {
            if p.first_service_at.is_none() {
                p.first_service_at = Some(now);
            }
        }
    }

    /// The last egress piece left the wire: close and record the hop.
    pub fn finish_hop(
        &mut self,
        msg_id: u64,
        msg: &MsgDesc,
        egress_done_at: Ns,
        deliver_at: Ns,
        lat_mult_milli: u64,
    ) {
        let Some(p) = self.pending.remove(&msg_id) else {
            return; // injected arrival or tracing enabled mid-flight
        };
        self.events.push(TraceEvent::Hop(HopSpan {
            src: msg.src,
            dst: msg.dst,
            bytes: msg.bytes,
            priority: msg.priority,
            tag: msg.tag,
            level: p.level,
            posted_at: p.posted_at,
            first_service_at: p.first_service_at.unwrap_or(p.posted_at),
            egress_done_at,
            deliver_at,
            service_ns: p.service_ns,
            pieces: p.pieces,
            lat_mult_milli,
            cause: p.cause,
        }));
    }

    /// Push a fully-formed record (executor/engine hooks).
    pub fn push(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }

    /// Move the recorded spans out, leaving the buffer recording.
    pub fn take(&mut self) -> Trace {
        Trace { events: std::mem::take(&mut self.events) }
    }
}

// ---------------------------------------------------------------------------
// Windowed utilization
// ---------------------------------------------------------------------------

/// Busy-time aggregates for one time window.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct UtilWindow {
    pub start: Ns,
    pub end: Ns,
    /// Busy ns per rail index, summed over nodes.
    pub rail_busy: Vec<Ns>,
    /// Busy ns of the shared-memory channels, summed over nodes.
    pub shm_busy: Ns,
    /// Busy ns per urgency class (NIC rails only).
    pub by_class: BTreeMap<Priority, Ns>,
    /// Wire-holding ns of hops per tier ([`HopSpan::first_service_at`]
    /// to [`HopSpan::egress_done_at`], attributed to the hop's level).
    pub by_level: BTreeMap<usize, Ns>,
}

/// Windowed per-rail / per-class / per-tier busy-fraction time series
/// computed post-hoc from the recorded [`BusySpan`]s and [`HopSpan`]s —
/// the read path that replaces ad-hoc counter plumbing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Utilization {
    pub window_ns: Ns,
    pub p: usize,
    pub rails: usize,
    pub windows: Vec<UtilWindow>,
}

impl Utilization {
    /// Slice `trace` into `window_ns`-wide windows over `p` nodes with
    /// `rails` NIC rails each.
    pub fn compute(trace: &Trace, p: usize, rails: usize, window_ns: Ns) -> Utilization {
        let window_ns = window_ns.max(1);
        let horizon = trace.end_time();
        let n_windows = (horizon.div_ceil(window_ns)).max(1) as usize;
        let mut windows: Vec<UtilWindow> = (0..n_windows)
            .map(|i| UtilWindow {
                start: i as Ns * window_ns,
                end: (i as Ns + 1) * window_ns,
                rail_busy: vec![0; rails.max(1)],
                ..UtilWindow::default()
            })
            .collect();
        // Distribute [start, end) across the windows it overlaps.
        fn split(
            windows: &mut [UtilWindow],
            window_ns: Ns,
            start: Ns,
            end: Ns,
            add: &mut dyn FnMut(&mut UtilWindow, Ns),
        ) {
            let mut t = start;
            while t < end {
                let w = (t / window_ns) as usize;
                let Some(win) = windows.get_mut(w) else { break };
                let stop = end.min((w as Ns + 1) * window_ns);
                add(win, stop - t);
                t = stop;
            }
        }
        for ev in &trace.events {
            match ev {
                TraceEvent::Busy(b) => {
                    let (chan, class) = (b.chan, b.class);
                    split(&mut windows, window_ns, b.start, b.end, &mut |w, ns| match chan {
                        TrackChan::Rail(r) => {
                            if let Some(cell) = w.rail_busy.get_mut(r as usize) {
                                *cell += ns;
                            }
                            *w.by_class.entry(class).or_insert(0) += ns;
                        }
                        TrackChan::Shm => w.shm_busy += ns,
                    });
                }
                TraceEvent::Hop(h) => {
                    let level = h.level;
                    split(
                        &mut windows,
                        window_ns,
                        h.first_service_at,
                        h.egress_done_at,
                        &mut |w, ns| {
                            *w.by_level.entry(level).or_insert(0) += ns;
                        },
                    );
                }
                _ => {}
            }
        }
        Utilization { window_ns, p, rails: rails.max(1), windows }
    }

    /// Busy fraction of rail `r` in window `w` (capacity = p wires).
    pub fn rail_fraction(&self, w: usize, r: usize) -> f64 {
        let win = &self.windows[w];
        let cap = (win.end - win.start) as f64 * self.p as f64;
        win.rail_busy.get(r).copied().unwrap_or(0) as f64 / cap.max(1.0)
    }

    /// Render the series as an aligned text table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("window_ns      ");
        for r in 0..self.rails {
            out.push_str(&format!("rail{r:<7}"));
        }
        out.push_str("shm        tiers (busy ns)        classes (busy ns)\n");
        for w in &self.windows {
            let cap = ((w.end - w.start) as f64 * self.p as f64).max(1.0);
            out.push_str(&format!("{:<15}", w.start));
            for r in 0..self.rails {
                out.push_str(&format!(
                    "{:<11.3}",
                    w.rail_busy.get(r).copied().unwrap_or(0) as f64 / cap
                ));
            }
            out.push_str(&format!("{:<11.3}", w.shm_busy as f64 / cap));
            let tiers: Vec<String> =
                w.by_level.iter().map(|(l, ns)| format!("L{l}:{ns}")).collect();
            let classes: Vec<String> =
                w.by_class.iter().map(|(c, ns)| format!("p{c}:{ns}")).collect();
            out.push_str(&format!("{:<22}", tiers.join(" ")));
            out.push_str(&classes.join(" "));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hop(src: Rank, posted: Ns, deliver: Ns, tag: u64) -> TraceEvent {
        TraceEvent::Hop(HopSpan {
            src,
            dst: src + 1,
            bytes: 1000,
            priority: 1,
            tag,
            level: 0,
            posted_at: posted,
            first_service_at: posted,
            egress_done_at: deliver.saturating_sub(10),
            deliver_at: deliver,
            service_ns: deliver.saturating_sub(posted + 10),
            pieces: 1,
            lat_mult_milli: 1000,
            cause: None,
        })
    }

    #[test]
    fn merge_equals_sorted_concat_regardless_of_shard_split() {
        let a = hop(0, 0, 100, 1);
        let b = hop(1, 5, 80, 1);
        let c = hop(2, 7, 90, 2);
        let serial = Trace { events: vec![a.clone(), b.clone(), c.clone()] }.normalized();
        let merged = Trace::merge(vec![
            Trace { events: vec![c.clone(), a.clone()] },
            Trace { events: vec![b.clone()] },
            Trace::default(),
        ]);
        assert_eq!(serial, merged);
        // Normalization is idempotent.
        assert_eq!(serial.clone().normalized(), serial);
    }

    #[test]
    fn hop_decomposition_is_non_negative_and_partitions_total() {
        let h = HopSpan {
            src: 0,
            dst: 1,
            bytes: 4096,
            priority: 2,
            tag: 9,
            level: 1,
            posted_at: 100,
            first_service_at: 150,
            egress_done_at: 700,
            deliver_at: 1200,
            service_ns: 400,
            pieces: 2,
            lat_mult_milli: 1000,
            cause: None,
        };
        assert_eq!(h.queue_ns(), 50);
        assert_eq!(h.stall_ns(), 150); // (700-150) - 400
        assert_eq!(h.flight_ns(), 500);
        assert_eq!(
            h.queue_ns() + h.service_ns + h.stall_ns() + h.flight_ns(),
            h.total_ns()
        );
    }

    #[test]
    fn pending_hops_resolve_through_the_buffer() {
        let mut buf = TraceBuf::default();
        buf.current_cause = Some(Cause::Compute { at: 5, node: 0, tag: 3 });
        buf.start_hop(42, 1, 2, 300, 10);
        buf.note_service(42, 25);
        buf.note_service(42, 60); // later elections don't move the mark
        let msg = MsgDesc { src: 0, dst: 3, bytes: 2048, priority: 1, tag: 7 };
        buf.finish_hop(42, &msg, 400, 900, 1000);
        // Unknown ids (injected cross-partition arrivals) are ignored.
        buf.finish_hop(99, &msg, 1, 2, 1000);
        let tr = buf.take();
        assert_eq!(tr.span_count(), 1);
        let h = tr.hops().next().unwrap();
        assert_eq!((h.posted_at, h.first_service_at), (10, 25));
        assert_eq!((h.egress_done_at, h.deliver_at), (400, 900));
        assert_eq!(h.cause, Some(Cause::Compute { at: 5, node: 0, tag: 3 }));
        assert!(buf.take().events.is_empty(), "take drains the buffer");
    }

    #[test]
    fn utilization_windows_clip_spans_and_attribute_classes() {
        let tr = Trace {
            events: vec![
                TraceEvent::Busy(BusySpan {
                    node: 0,
                    chan: TrackChan::Rail(0),
                    class: 1,
                    start: 50,
                    end: 250,
                }),
                TraceEvent::Busy(BusySpan {
                    node: 1,
                    chan: TrackChan::Shm,
                    class: 0,
                    start: 0,
                    end: 100,
                }),
                hop(0, 0, 260, 1),
            ],
        };
        let u = Utilization::compute(&tr, 2, 1, 100);
        assert_eq!(u.windows.len(), 3);
        // Rail busy 50..250 splits 50 / 100 / 50 across the windows.
        assert_eq!(u.windows[0].rail_busy[0], 50);
        assert_eq!(u.windows[1].rail_busy[0], 100);
        assert_eq!(u.windows[2].rail_busy[0], 50);
        assert_eq!(u.windows[0].shm_busy, 100);
        assert_eq!(u.windows[1].by_class.get(&1), Some(&100));
        // Fractions normalize by window × nodes.
        assert!((u.rail_fraction(1, 0) - 0.5).abs() < 1e-12);
        let rendered = u.render();
        assert!(rendered.contains("rail0"));
        assert!(rendered.contains("L0:"));
    }
}

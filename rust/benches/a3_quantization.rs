//! **Ablation A3**: low-precision collectives ("Reducing communication
//! volume"). Wire dtypes f32 / bf16 / int8(+per-block scales) on the same
//! allreduce; volume, time and the end-to-end effect on exposed comm.
//!
//! Run: `cargo bench --bench a3_quantization`

mod common;

use common::{cfg, ms, ratio};
use mlsl::collectives::program::allreduce_ring;
use mlsl::collectives::simexec::time_collective;
use mlsl::collectives::WireDtype;
use mlsl::engine::{simulate, CommMode};
use mlsl::fabric::topology::Topology;
use mlsl::fabric::NetSim;
use mlsl::metrics::print_table;

fn main() {
    // --- collective-level: 25M-element (ResNet-50-sized) allreduce ---
    let n = 25_500_000usize;
    let mut rows = Vec::new();
    for p in [16usize, 64, 256] {
        let mut per_dtype = Vec::new();
        for wire in [WireDtype::F32, WireDtype::Bf16, WireDtype::Int8Block] {
            let mut sim = NetSim::new(Topology::eth_10g(), p);
            let t = time_collective(&mut sim, allreduce_ring(p, n), wire, 1);
            per_dtype.push((wire, t, sim.stats.bytes_sent / p as u64));
        }
        let f32_t = per_dtype[0].1;
        for (wire, t, bytes) in per_dtype {
            rows.push(vec![
                p.to_string(),
                wire.to_string(),
                format!("{:.1}", bytes as f64 / 1e6),
                ms(t),
                ratio(f32_t, t),
            ]);
        }
    }
    print_table(
        "A3a: 25.5M-element gradient allreduce on 10GbE — wire dtype",
        &["nodes", "wire", "MB/node", "time ms", "speedup vs f32"],
        &rows,
    );

    // --- end-to-end: exposed comm in bulk-sync VGG-16 training ---
    let mut rows = Vec::new();
    let mut base = 0u64;
    for wire in [WireDtype::F32, WireDtype::Bf16, WireDtype::Int8Block] {
        let mut c = cfg("vgg16", Topology::eth_10g(), 16, 32, CommMode::BulkSync);
        c.wire = wire;
        c.iterations = 2;
        let r = simulate(c);
        if wire == WireDtype::F32 {
            base = r.exposed_comm_ns;
        }
        rows.push(vec![
            wire.to_string(),
            ms(r.iter_ns),
            ms(r.exposed_comm_ns),
            ratio(base, r.exposed_comm_ns),
        ]);
    }
    print_table(
        "A3b: VGG-16 bulk-sync training, 16 nodes, 10GbE — end-to-end wire dtype",
        &["wire", "iter ms", "exposed ms", "exposure reduction"],
        &rows,
    );
    println!("\nexpected shape: bf16 ~2x and int8 ~4x volume/time reduction for");
    println!("bandwidth-bound sizes; latency floor limits gains at small sizes.");
    println!("(correctness of quantized reduction: see trainer::tests::int8_wire_still_learns)");
}

//! **Fig. 2 reproduction**: ResNet-50 weak scaling on Xeon/Omnipath with
//! Intel-Caffe + MLSL. Paper: ~90% scaling efficiency at 256 nodes
//! (batch 32/node, overlap + prioritization + dedicated comm cores).
//!
//! Run: `cargo bench --bench fig2_resnet50_scaling`

mod common;

use common::{cfg, ms};
use mlsl::collectives::PriorityPolicy;
use mlsl::engine::{simulate, CommMode};
use mlsl::fabric::topology::Topology;
use mlsl::metrics::print_table;

fn main() {
    let nodes = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];
    let mut rows = Vec::new();
    let mut t1 = 0u64;
    for p in nodes {
        let mut c = cfg("resnet50", Topology::omnipath_100g(), p, 32,
                        CommMode::MlslAsync { comm_cores: 2 });
        c.policy = PriorityPolicy::ByLayer;
        c.jitter = 0.03; // straggler model — see engine docs
        c.iterations = 4;
        let r = simulate(c);
        if p == 1 {
            t1 = r.iter_ns;
        }
        let eff = 100.0 * t1 as f64 / r.iter_ns as f64;
        rows.push(vec![
            p.to_string(),
            ms(r.iter_ns),
            ms(r.exposed_comm_ns),
            format!("{eff:.1}%"),
            format!("{:.0}", r.throughput_samples_per_s),
        ]);
    }
    print_table(
        "Fig.2: ResNet-50 weak scaling, Xeon(SKX-6148)+Omnipath, batch 32/node, MLSL mode",
        &["nodes", "iter ms", "exposed comm ms", "efficiency", "samples/s"],
        &rows,
    );
    println!("\npaper: ~90% efficiency at 256 nodes (Intel Caffe + MLSL).");
    println!("expected shape: efficiency decays gently from 100% to ~90% at 256.");
}

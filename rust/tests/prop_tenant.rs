//! Property tests for the multi-tenant fabric: tenants share WIRES,
//! never payloads — and the single-tenant path is bitwise unchanged.
//!
//! Three invariant families (the PR's determinism contract):
//!
//! * **tenant isolation** — two jobs on disjoint rank blocks never
//!   exchange a message: every delivered training message stays inside
//!   its tenant's block and carries that tenant's tag bits, and each
//!   disjoint tenant's report is bitwise equal to the single-job run
//!   (sharing a fabric with an idle-NIC neighbor changes nothing);
//! * **single-tenant equivalence** — `simulate_tenants` with one
//!   colocated tenant and a quiet fabric reproduces `simulate`'s report
//!   field for field across randomized configs;
//! * **background bends timing only** — a seeded [`BgPlan`] may delay
//!   training messages but never changes their multiset (sources,
//!   destinations, byte counts) nor the training byte volume, and the
//!   same seed replays a byte-identical event stream.

use mlsl::collectives::program::{build, CollectiveKind};
use mlsl::collectives::simexec::SimCollectives;
use mlsl::collectives::{Algorithm as A, WireDtype};
use mlsl::engine::{simulate, simulate_tenants, CommMode, EngineConfig, TenantSpec};
use mlsl::fabric::topology::Topology;
use mlsl::fabric::{tenant_of_tag, BgPlan, NetSim, SimEvent, StragglerPlan, BG_TAG, TENANT_TAG_SHIFT};
use mlsl::models::ModelDesc;
use mlsl::util::proptest::{run as prop_run, Config};

fn engine_cfg(model: &str, p: usize, mode: CommMode, iters: usize) -> EngineConfig {
    let mut cfg = EngineConfig::new(
        ModelDesc::by_name(model).expect("test model exists"),
        Topology::eth_10g(),
        p,
    );
    cfg.mode = mode;
    cfg.iterations = iters;
    cfg
}

#[test]
fn prop_disjoint_tenants_never_exchange_payloads() {
    prop_run(
        Config { cases: 50, seed: 91 },
        |r| {
            let p = 2 + r.usize_below(5); // per-tenant ranks, 2..7
            let n = 1 + r.usize_below(4_000);
            let alg = if p.is_power_of_two() && r.below(2) == 0 {
                A::RecursiveDoubling
            } else {
                A::Ring
            };
            let kind = if r.below(2) == 0 {
                CollectiveKind::Allreduce
            } else {
                CollectiveKind::Allgather
            };
            (p, n, kind, alg)
        },
        |&(p, n, kind, alg)| {
            // Two tenants, disjoint blocks [0,p) and [p,2p), one fabric.
            let topo = Topology::flat("tenanttest", 8.0, 1_000, 100, 512);
            let mut sim = NetSim::new(topo, 2 * p);
            sim.set_tenants(2);
            let progs = build(kind, alg, p, n).map_err(|e| e.to_string())?;
            let mut exec = SimCollectives::new();
            let mut completions = Vec::new();
            for t in 0..2u64 {
                let map: Vec<usize> = (0..p).map(|r| r + (t as usize) * p).collect();
                let done = exec.post_mapped(
                    &mut sim,
                    1 + (t << TENANT_TAG_SHIFT),
                    progs.clone(),
                    map,
                    WireDtype::F32,
                    1,
                );
                completions.extend(done);
            }
            while exec.in_flight() > 0 {
                let ev = sim
                    .next()
                    .ok_or_else(|| format!("{kind:?}/{alg} p={p}: deadlock"))?;
                if let SimEvent::MsgDelivered { msg, .. } = &ev {
                    let t = tenant_of_tag(msg.tag, 2);
                    let block = t * p..(t + 1) * p;
                    if !block.contains(&msg.src) || !block.contains(&msg.dst) {
                        return Err(format!(
                            "{kind:?}/{alg} p={p}: tenant {t} message {}→{} escaped \
                             its rank block {block:?}",
                            msg.src, msg.dst
                        ));
                    }
                }
                exec.on_event_into(&mut sim, &ev, &mut completions);
            }
            if completions.len() != 2 * p {
                return Err(format!(
                    "{kind:?}/{alg} p={p}: {} of {} ranks completed",
                    completions.len(),
                    2 * p
                ));
            }
            // Symmetric tenants on symmetric blocks: identical accounting.
            if sim.stats.tenant_bytes[0] != sim.stats.tenant_bytes[1]
                || sim.stats.tenant_msgs[0] != sim.stats.tenant_msgs[1]
            {
                return Err(format!(
                    "{kind:?}/{alg} p={p}: symmetric tenants accounted differently \
                     ({:?} bytes, {:?} msgs)",
                    sim.stats.tenant_bytes, sim.stats.tenant_msgs
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_disjoint_tenant_reports_match_the_single_job_bitwise() {
    prop_run(
        Config { cases: 8, seed: 92 },
        |r| {
            let p = [2, 4][r.usize_below(2)];
            let model = ["resnet50", "vgg16"][r.usize_below(2)];
            let jobs = 2 + r.usize_below(2); // 2..4 tenants
            (p, model, jobs)
        },
        |&(p, model, jobs)| {
            let cfg = engine_cfg(model, p, CommMode::BulkSync, 2);
            let single = simulate(cfg.clone());
            let multi = simulate_tenants(&cfg, &TenantSpec { jobs, disjoint: true }, false);
            for (t, r) in multi.reports.iter().enumerate() {
                if r.iter_ns != single.iter_ns
                    || r.per_iter_ns != single.per_iter_ns
                    || r.bytes_per_node != single.bytes_per_node
                    || r.exposed_comm_ns != single.exposed_comm_ns
                {
                    return Err(format!(
                        "{model} p={p} jobs={jobs}: disjoint tenant {t} diverged from \
                         the single job (iter {} vs {})",
                        r.iter_ns, single.iter_ns
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_single_tenant_is_bitwise_the_plain_engine() {
    prop_run(
        Config { cases: 10, seed: 93 },
        |r| {
            let p = [2, 4, 8][r.usize_below(3)];
            let model = ["resnet50", "googlenet"][r.usize_below(2)];
            let mode =
                [CommMode::BulkSync, CommMode::MlslAsync { comm_cores: 2 }][r.usize_below(2)];
            (p, model, mode)
        },
        |&(p, model, mode)| {
            let cfg = engine_cfg(model, p, mode, 2);
            let single = simulate(cfg.clone());
            let multi = simulate_tenants(&cfg, &TenantSpec { jobs: 1, disjoint: false }, false);
            let r = &multi.reports[0];
            if r.iter_ns != single.iter_ns
                || r.per_iter_ns != single.per_iter_ns
                || r.bytes_per_node != single.bytes_per_node
                || r.exposed_comm_ns != single.exposed_comm_ns
                || r.preemptions != single.preemptions
            {
                return Err(format!(
                    "{model} p={p} {mode:?}: --tenants 1 diverged from the plain \
                     engine (iter {} vs {}, bytes {} vs {})",
                    r.iter_ns, single.iter_ns, r.bytes_per_node, single.bytes_per_node
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_background_bends_timing_never_payloads() {
    prop_run(
        Config { cases: 40, seed: 94 },
        |r| {
            let p = 2 + r.usize_below(7); // 2..9
            let n = 1 + r.usize_below(3_000);
            let seed = r.below(u64::MAX);
            (p, n, seed)
        },
        |&(p, n, seed)| {
            type Delivered = Vec<(usize, usize, u64)>;
            let topo = Topology::flat("bgtest", 8.0, 1_000, 100, 512);
            let progs =
                build(CollectiveKind::Allreduce, A::Ring, p, n).map_err(|e| e.to_string())?;
            let run = |bg: Option<BgPlan>| -> Result<(Delivered, Vec<SimEvent>), String> {
                let mut sim = NetSim::new(topo.clone(), p);
                sim.set_tenants(1);
                if let Some(plan) = bg {
                    sim.set_background(plan);
                }
                let mut exec = SimCollectives::new();
                let mut completions =
                    exec.post(&mut sim, 1, progs.clone(), WireDtype::F32, 1);
                let mut training = Vec::new();
                let mut events = Vec::new();
                while exec.in_flight() > 0 {
                    let ev =
                        sim.next().ok_or_else(|| format!("p={p}: deadlock under bg"))?;
                    if let SimEvent::MsgDelivered { msg, .. } = &ev {
                        if msg.tag & BG_TAG == 0 {
                            training.push((msg.src, msg.dst, msg.bytes));
                        }
                    }
                    events.push(ev.clone());
                    exec.on_event_into(&mut sim, &ev, &mut completions);
                }
                training.sort_unstable();
                Ok((training, events))
            };
            let (quiet, _) = run(None)?;
            // Horizon spanning the collective so flows actually overlap it.
            let plan = BgPlan::generate(seed, &topo, p, 500_000);
            let (noisy, ev_a) = run(Some(plan.clone()))?;
            if noisy != quiet {
                return Err(format!(
                    "p={p} seed={seed}: background changed the delivered \
                     training-message multiset"
                ));
            }
            // Same seed ⇒ byte-identical event stream (bg messages included).
            let (_, ev_b) = run(Some(plan))?;
            if ev_a != ev_b {
                return Err(format!("p={p} seed={seed}: bg event streams diverged"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_stragglers_bend_timing_never_traffic() {
    prop_run(
        Config { cases: 6, seed: 95 },
        |r| {
            let p = [2, 4][r.usize_below(2)];
            let node = r.usize_below(p);
            let factor = 1.5 + (r.below(30) as f64) / 10.0; // 1.5x..4.5x
            (p, node, factor)
        },
        |&(p, node, factor)| {
            let healthy = simulate(engine_cfg("resnet50", p, CommMode::BulkSync, 2));
            let mut cfg = engine_cfg("resnet50", p, CommMode::BulkSync, 2);
            cfg.straggler =
                Some(StragglerPlan::parse(&format!("{node}:{factor}"), p).unwrap());
            let slow = simulate(cfg);
            if slow.bytes_per_node != healthy.bytes_per_node {
                return Err(format!(
                    "p={p} straggler {node}:{factor}: traffic changed ({} vs {})",
                    slow.bytes_per_node, healthy.bytes_per_node
                ));
            }
            if slow.iter_ns < healthy.iter_ns {
                return Err(format!(
                    "p={p} straggler {node}:{factor}: run got FASTER ({} vs {})",
                    slow.iter_ns, healthy.iter_ns
                ));
            }
            // Lockstep sync bounds the damage at the straggler's own
            // factor: compute scales by it, communication does not.
            let bound = (healthy.iter_ns as f64 * (factor + 0.05)) as u64;
            if slow.iter_ns > bound {
                return Err(format!(
                    "p={p} straggler {node}:{factor}: slowdown cascaded \
                     ({} vs healthy {}, bound {})",
                    slow.iter_ns, healthy.iter_ns, bound
                ));
            }
            Ok(())
        },
    );
}

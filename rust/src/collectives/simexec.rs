//! Timed execution of collective programs on the discrete-event fabric.
//!
//! This is how the engine *times* communication: the same chunk programs
//! the real executor moves bytes with are walked step-by-step against
//! [`NetSim`], which models egress serialization, strict-priority
//! preemption and latency. Reduction FLOPs are not charged (beta-model;
//! negligible vs wire time for the sizes involved — noted in DESIGN.md).

use std::collections::{HashMap, VecDeque};

use super::program::Program;
use super::quant::WireDtype;
use crate::fabric::{MsgDesc, NetSim, SimEvent};
use crate::trace::TraceEvent;
use crate::{Ns, Priority, Rank};

/// Per-rank execution state of one in-flight collective.
struct RankState {
    pc: usize,
    sent_current: bool,
    /// Arrived-but-unconsumed message counts per source rank.
    arrivals: HashMap<Rank, VecDeque<()>>,
    done_at: Option<Ns>,
}

struct SimOp {
    programs: Vec<Program>,
    ranks: Vec<RankState>,
    wire: WireDtype,
    priority: Priority,
    posted_at: Ns,
    /// Program (local) rank → fabric node id. Identity for world-spanning
    /// collectives; sub-communicators (hybrid node groups) map here.
    map: Vec<Rank>,
    /// Inverse of `map`.
    inv: HashMap<Rank, usize>,
}

/// Completion record: (collective id, rank, completion time).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    pub coll_id: u64,
    pub rank: Rank,
    pub at: Ns,
}

/// Multi-collective executor over the simulator.
#[derive(Default)]
pub struct SimCollectives {
    ops: HashMap<u64, SimOp>,
}

impl SimCollectives {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of still-incomplete collectives.
    pub fn in_flight(&self) -> usize {
        self.ops.len()
    }

    /// Post a world-spanning collective (identity rank map).
    pub fn post(
        &mut self,
        sim: &mut NetSim,
        coll_id: u64,
        programs: Vec<Program>,
        wire: WireDtype,
        priority: Priority,
    ) -> Vec<Completion> {
        let p = programs.len();
        self.post_mapped(sim, coll_id, programs, (0..p).collect(), wire, priority)
    }

    /// Post a collective over a sub-communicator: program rank i runs on
    /// fabric node `map[i]`. Issues whatever first steps can go
    /// immediately; returns instant completions (p = 1 / empty programs).
    pub fn post_mapped(
        &mut self,
        sim: &mut NetSim,
        coll_id: u64,
        programs: Vec<Program>,
        map: Vec<Rank>,
        wire: WireDtype,
        priority: Priority,
    ) -> Vec<Completion> {
        let p = programs.len();
        assert_eq!(map.len(), p, "rank map must cover every program");
        let inv: HashMap<Rank, usize> = map.iter().enumerate().map(|(l, g)| (*g, l)).collect();
        assert_eq!(inv.len(), p, "rank map must be injective");
        let mut op = SimOp {
            ranks: (0..p)
                .map(|_| RankState {
                    pc: 0,
                    sent_current: false,
                    arrivals: HashMap::new(),
                    done_at: None,
                })
                .collect(),
            programs,
            wire,
            priority,
            posted_at: sim.now(),
            map,
            inv,
        };
        // One start record per collective: the shard owning program rank 0
        // emits it, so merged partitioned traces match the serial trace.
        if sim.trace_enabled() && sim.owns(op.map[0]) {
            sim.trace_push(TraceEvent::CollStart {
                coll_id,
                at: op.posted_at,
                priority,
                ranks: p,
            });
        }
        let mut done = Vec::new();
        for r in 0..p {
            Self::advance(&mut op, sim, coll_id, r, &mut done);
        }
        if done.len() == p {
            // Entire collective finished instantly (single rank).
            return done;
        }
        self.ops.insert(coll_id, op);
        done
    }

    /// Feed a fabric event, APPENDING any rank completions it triggered
    /// to `done`. Takes a caller-owned buffer instead of returning a
    /// fresh `Vec` because this runs once per delivered message — the
    /// simulator event loop's L3 hot path (see the `Nic::order` min-heap
    /// note in `fabric/sim.rs`); callers clear and reuse one scratch
    /// buffer across the whole run.
    pub fn on_event_into(
        &mut self,
        sim: &mut NetSim,
        ev: &SimEvent,
        done: &mut Vec<Completion>,
    ) {
        if let SimEvent::MsgDelivered { msg, .. } = ev {
            let coll_id = msg.tag;
            let finished = {
                let Some(op) = self.ops.get_mut(&coll_id) else {
                    return;
                };
                let dst = op.inv[&msg.dst];
                let src = op.inv[&msg.src];
                op.ranks[dst].arrivals.entry(src).or_default().push_back(());
                Self::advance(op, sim, coll_id, dst, done);
                op.ranks.iter().all(|r| r.done_at.is_some())
            };
            if finished {
                self.ops.remove(&coll_id);
            }
        }
    }

    /// Walk rank `r`'s program as far as possible.
    fn advance(
        op: &mut SimOp,
        sim: &mut NetSim,
        coll_id: u64,
        r: Rank,
        done: &mut Vec<Completion>,
    ) {
        let prog = &op.programs[r];
        let st = &mut op.ranks[r];
        while st.pc < prog.steps.len() {
            let step = &prog.steps[st.pc];
            if let (Some(sd), false) = (&step.send, st.sent_current) {
                let bytes = op.wire.wire_bytes(sd.range.len) as u64;
                if op.wire != WireDtype::F32 {
                    // Wire-format win vs the 4 B/elem f32 payload; the f32
                    // path stays registry-free (hot loop).
                    crate::metrics::registry::add(
                        "quant.bytes_saved",
                        (4 * sd.range.len as u64).saturating_sub(bytes),
                    );
                }
                sim.send(MsgDesc {
                    src: op.map[r],
                    dst: op.map[sd.to],
                    bytes,
                    priority: op.priority,
                    tag: coll_id,
                });
                st.sent_current = true;
            }
            if let Some(rv) = &step.recv {
                let q = st.arrivals.entry(rv.from).or_default();
                if q.pop_front().is_none() {
                    return; // blocked on this recv
                }
            }
            st.pc += 1;
            st.sent_current = false;
        }
        if st.done_at.is_none() {
            st.done_at = Some(sim.now());
            // Completions report FABRIC node ids, not program ranks.
            done.push(Completion { coll_id, rank: op.map[r], at: sim.now() });
            // Owner-gated like CollStart: non-owner shards reach here only
            // for phantom completions (recv-free ranks), which parexec
            // filters — the trace must skip them the same way.
            if sim.trace_enabled() && sim.owns(op.map[r]) {
                sim.trace_push(TraceEvent::RankDone {
                    coll_id,
                    rank: op.map[r],
                    at: sim.now(),
                });
            }
        }
    }

    /// Elapsed time of a completed op for reporting (None if in flight).
    pub fn op_age(&self, coll_id: u64, now: Ns) -> Option<Ns> {
        self.ops.get(&coll_id).map(|op| now - op.posted_at)
    }

    /// Fabric node ids an in-flight collective spans (program rank i runs
    /// on `members[i]`). None once completed or aborted.
    pub fn members_of(&self, coll_id: u64) -> Option<&[Rank]> {
        self.ops.get(&coll_id).map(|op| op.map.as_slice())
    }

    /// Shrink the in-flight set: drop a collective whose membership just
    /// changed under it. Messages it already put on the wire still drain
    /// through the simulator (the fabric does not unsend bytes) but their
    /// deliveries hit [`Self::on_event_into`]'s unknown-id path and are
    /// ignored — no completion is ever reported for an aborted op. The
    /// elastic engine quiesces at iteration boundaries and rebuilds via
    /// [`crate::collectives::program::rebuild_for_survivors`]; this is
    /// the escape hatch for plans that cannot wait out the iteration.
    /// Returns false if the id was not in flight.
    pub fn abort(&mut self, coll_id: u64) -> bool {
        self.ops.remove(&coll_id).is_some()
    }
}

/// Convenience: run a single collective to completion on an otherwise idle
/// fabric; returns the finish time (max over ranks). Used by tests, the A4
/// bench and the selector calibration.
pub fn time_collective(
    sim: &mut NetSim,
    programs: Vec<Program>,
    wire: WireDtype,
    priority: Priority,
) -> Ns {
    let mut exec = SimCollectives::new();
    let mut completions = exec.post(sim, 1, programs, wire, priority);
    while exec.in_flight() > 0 {
        let ev = sim.next().expect("fabric drained with op in flight: deadlock");
        exec.on_event_into(sim, &ev, &mut completions);
    }
    completions.iter().map(|c| c.at).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::program::{allreduce_hierarchical, allreduce_ring, allreduce_rdoubling};
    use crate::collectives::selector::predict_allreduce_ns;
    use crate::collectives::Algorithm;
    use crate::fabric::topology::Topology;

    fn sim(p: usize) -> NetSim {
        NetSim::new(Topology::eth_10g(), p)
    }

    #[test]
    fn single_rank_completes_instantly() {
        let mut s = sim(1);
        let t = time_collective(&mut s, allreduce_ring(1, 100), WireDtype::F32, 1);
        assert_eq!(t, 0);
    }

    #[test]
    fn ring_allreduce_time_matches_analytic_model() {
        let p = 8;
        let n_bytes: u64 = 8 << 20; // 8 MiB
        let mut s = sim(p);
        let measured = time_collective(
            &mut s,
            allreduce_ring(p, (n_bytes / 4) as usize),
            WireDtype::F32,
            1,
        );
        let predicted = predict_allreduce_ns(s.topology(), Algorithm::Ring, p, n_bytes);
        // The analytic alpha-beta model ignores pipelining imperfections;
        // agreement within 20% validates the simulator against the model.
        let ratio = measured as f64 / predicted as f64;
        assert!((0.8..1.25).contains(&ratio), "measured={measured} predicted={predicted}");
    }

    #[test]
    fn rdoubling_beats_ring_for_small_messages() {
        let p = 16;
        let small = 256usize; // 1 KiB
        let t_ring = time_collective(&mut sim(p), allreduce_ring(p, small), WireDtype::F32, 1);
        let t_rd =
            time_collective(&mut sim(p), allreduce_rdoubling(p, small), WireDtype::F32, 1);
        assert!(t_rd < t_ring, "rd={t_rd} ring={t_ring}");
    }

    #[test]
    fn ring_beats_rdoubling_for_large_messages() {
        let p = 16;
        let large = 8 << 20; // elements
        let t_ring = time_collective(&mut sim(p), allreduce_ring(p, large), WireDtype::F32, 1);
        let t_rd =
            time_collective(&mut sim(p), allreduce_rdoubling(p, large), WireDtype::F32, 1);
        assert!(t_ring < t_rd, "ring={t_ring} rd={t_rd}");
    }

    #[test]
    fn hierarchical_beats_flat_ring_on_two_tier_fabric() {
        // 64 ranks at 2/node on 10GbE: the hierarchy halves the number of
        // slow inter-node steps (intra reduce/broadcast ride shared
        // memory), so the simulated allreduce must finish sooner — across
        // latency-bound AND bandwidth-bound sizes.
        let (p, rpn) = (64usize, 2usize);
        for n in [16usize << 10, 1 << 20] {
            let topo = Topology::eth_10g_smp(rpn);
            let t_ring = time_collective(
                &mut NetSim::new(topo.clone(), p),
                allreduce_ring(p, n),
                WireDtype::F32,
                1,
            );
            let t_hier = time_collective(
                &mut NetSim::new(topo, p),
                allreduce_hierarchical(p, n, rpn, Algorithm::Ring),
                WireDtype::F32,
                1,
            );
            assert!(t_hier < t_ring, "n={n}: hier={t_hier} ring={t_ring}");
        }
    }

    #[test]
    fn hierarchical_sim_time_tracks_two_tier_prediction() {
        let (p, rpn) = (16usize, 4usize);
        let n = 1usize << 20; // elements
        let topo = Topology::eth_10g_smp(rpn);
        let alg = Algorithm::hier(&[rpn]);
        let programs = crate::collectives::program::build(
            crate::collectives::CollectiveKind::Allreduce,
            alg,
            p,
            n,
        )
        .unwrap();
        let mut s = NetSim::new(topo.clone(), p);
        let measured = time_collective(&mut s, programs, WireDtype::F32, 1);
        let predicted = predict_allreduce_ns(&topo, alg, p, (4 * n) as u64);
        let ratio = measured as f64 / predicted as f64;
        assert!(
            (0.5..2.0).contains(&ratio),
            "measured={measured} predicted={predicted}"
        );
    }

    #[test]
    fn int8_wire_is_faster_than_f32() {
        let p = 8;
        let n = 4 << 20;
        let saved_before = crate::metrics::registry::get("quant.bytes_saved");
        let t32 = time_collective(&mut sim(p), allreduce_ring(p, n), WireDtype::F32, 1);
        let t8 =
            time_collective(&mut sim(p), allreduce_ring(p, n), WireDtype::Int8Block, 1);
        assert!(
            (t32 as f64 / t8 as f64) > 3.0,
            "expected ~4x volume win: f32={t32} int8={t8}"
        );
        // A compressed run banks its wire-format win: ~3 B/elem × the
        // ring's 2(p−1) segment sends.
        let saved = crate::metrics::registry::get("quant.bytes_saved") - saved_before;
        assert!(saved > 0, "quant.bytes_saved not bumped");
    }

    #[test]
    fn rail_striping_speeds_up_bandwidth_bound_ring_only() {
        let p = 8;
        let time_on = |topo: Topology, n: usize| {
            time_collective(&mut NetSim::new(topo, p), allreduce_ring(p, n), WireDtype::F32, 1)
        };
        let base = Topology::eth_10g();
        let e2 = base.clone().with_rails(2).unwrap();
        // Bandwidth-bound (4 MiB per-step segments, 16 chunks): the
        // second rail nearly halves the wall time.
        let big = 8usize << 20; // elements
        let t1 = time_on(base.clone(), big);
        let t2 = time_on(e2.clone(), big);
        assert!(
            t1 as f64 / t2 as f64 >= 1.8,
            "2-rail bandwidth-bound speedup: t1={t1} t2={t2}"
        );
        // Latency-bound (sub-chunk steps): byte-identical timing.
        let small = 256usize;
        assert_eq!(time_on(base, small), time_on(e2, small));
    }

    #[test]
    fn abort_drops_op_and_in_flight_messages_drain_harmlessly() {
        let p = 4;
        let mut s = sim(p);
        let mut exec = SimCollectives::new();
        let mut completions = Vec::new();
        completions.extend(exec.post(&mut s, 7, allreduce_ring(p, 1 << 20), WireDtype::F32, 1));
        assert_eq!(exec.in_flight(), 1);
        assert_eq!(exec.members_of(7), Some(&[0usize, 1, 2, 3][..]));
        assert!(exec.abort(7));
        assert!(!exec.abort(7), "second abort of same id must be a no-op");
        assert_eq!(exec.in_flight(), 0);
        assert_eq!(exec.members_of(7), None);
        // First-step sends are already on the wire; draining them must not
        // panic, resurrect the op, or produce completions.
        while let Some(ev) = s.next() {
            exec.on_event_into(&mut s, &ev, &mut completions);
        }
        assert!(completions.is_empty(), "{completions:?}");
        assert_eq!(exec.in_flight(), 0);
    }

    #[test]
    fn concurrent_ops_with_priorities_order_completions() {
        // Bulk op posted first at low priority; urgent posted right after.
        // Urgent must complete first on the shared wires.
        let p = 4;
        let mut s = sim(p);
        let mut exec = SimCollectives::new();
        let mut completions = Vec::new();
        completions.extend(exec.post(&mut s, 10, allreduce_ring(p, 4 << 20), WireDtype::F32, 9));
        completions.extend(exec.post(&mut s, 20, allreduce_ring(p, 1024), WireDtype::F32, 0));
        while exec.in_flight() > 0 {
            let ev = s.next().unwrap();
            exec.on_event_into(&mut s, &ev, &mut completions);
        }
        let urgent_done = completions.iter().filter(|c| c.coll_id == 20).map(|c| c.at).max().unwrap();
        let bulk_done = completions.iter().filter(|c| c.coll_id == 10).map(|c| c.at).max().unwrap();
        assert!(urgent_done < bulk_done / 10, "urgent={urgent_done} bulk={bulk_done}");
    }
}

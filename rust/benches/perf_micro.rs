//! Hot-path micro-benchmarks (§Perf): wall-clock timings of the pieces on
//! the critical paths of both the simulated and the REAL stack.
//!
//! * simulator event throughput (events/s) — L3 sim hot loop
//! * wire encode/decode throughput per dtype (GB/s) — real collectives
//! * in-process ring allreduce throughput (GB/s reduced) — comm cores
//! * PJRT executable invocation latency — runtime layer
//!
//! Run: `cargo bench --bench perf_micro`

use std::time::Instant;

use mlsl::collectives::{quant, ReduceOp, WireDtype};
use mlsl::fabric::topology::Topology;
use mlsl::fabric::{MsgDesc, NetSim};
use mlsl::metrics::print_table;
use mlsl::mlsl::Communicator;

fn time<F: FnMut()>(mut f: F, iters: usize) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let mut rows = Vec::new();

    // --- 1. simulator event throughput -----------------------------------
    {
        let events = 200_000usize;
        let t = time(
            || {
                let mut sim = NetSim::new(Topology::eth_10g(), 16);
                for i in 0..events {
                    sim.send(MsgDesc {
                        src: i % 16,
                        dst: (i + 1) % 16,
                        bytes: 1024,
                        priority: (i % 4) as u8,
                        tag: i as u64,
                    });
                }
                let mut n = 0;
                while sim.next().is_some() {
                    n += 1;
                }
                assert_eq!(n, events);
            },
            3,
        );
        rows.push(vec![
            "sim: send+deliver".into(),
            format!("{:.2} M events/s", events as f64 / t / 1e6),
        ]);
    }

    // --- 2. wire encode/decode throughput ---------------------------------
    {
        let n = 4 << 20; // 16 MB of f32
        let src: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        for wire in [WireDtype::F32, WireDtype::Bf16, WireDtype::Int8Block] {
            let enc = time(|| { std::hint::black_box(quant::encode(&src, wire)); }, 5);
            let encoded = quant::encode(&src, wire);
            let mut dst = vec![0f32; n];
            let dec = time(
                || quant::decode_into(&encoded, &mut dst, wire, Some(ReduceOp::Sum)),
                5,
            );
            let gb = (4 * n) as f64 / 1e9;
            rows.push(vec![
                format!("wire encode {wire}"),
                format!("{:.2} GB/s", gb / enc),
            ]);
            rows.push(vec![
                format!("wire decode+reduce {wire}"),
                format!("{:.2} GB/s", gb / dec),
            ]);
        }
    }

    // --- 3. in-process ring allreduce (steady-state: world reused) -------
    {
        let n = 1 << 22; // 16 MB per rank
        let reps = 8usize;
        for p in [2usize, 4] {
            let comms = Communicator::world(p);
            let t0 = Instant::now();
            let handles: Vec<_> = comms
                .into_iter()
                .map(|c| {
                    std::thread::spawn(move || {
                        for _ in 0..reps {
                            let _ = c.allreduce(vec![1.0f32; n]);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            let t = t0.elapsed().as_secs_f64() / reps as f64;
            rows.push(vec![
                format!("shm ring allreduce p={p} 16MB"),
                format!("{:.2} GB/s reduced", (4 * n) as f64 / 1e9 / t),
            ]);
        }
    }

    // --- 4. PJRT invocation latency ----------------------------------------
    {
        let micro = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/micro");
        if micro.join("matmul.hlo.txt").exists() {
            let rt = mlsl::runtime::Runtime::cpu().expect("pjrt");
            let exe = rt.load_hlo(micro.join("matmul.hlo.txt")).expect("compile");
            let x = mlsl::runtime::Input::f32(vec![0.5; 256 * 256], &[256, 256]);
            let w = mlsl::runtime::Input::f32(vec![0.25; 256 * 256], &[256, 256]);
            let b = mlsl::runtime::Input::f32(vec![0.0; 256], &[256]);
            let t = time(|| { exe.run(&[x.clone(), w.clone(), b.clone()]).unwrap(); }, 20);
            let flops = 2.0 * 256.0 * 256.0 * 256.0;
            rows.push(vec![
                "pjrt matmul 256^3 (pallas-lowered)".into(),
                format!("{:.1} µs/call, {:.2} GFLOP/s", t * 1e6, flops / t / 1e9),
            ]);
        } else {
            rows.push(vec!["pjrt matmul".into(), "SKIPPED (run `make artifacts`)".into()]);
        }
    }

    print_table("perf_micro: hot-path throughputs", &["path", "rate"], &rows);
}

//! **Ablation A5**: dedicated communication cores ("dedicating one or
//! more cores for driving the network in an optimal manner").
//!
//! Stealing c of 40 cores costs c/40 of compute throughput but buys
//! asynchronous progress (overlap). comm-cores = 0 means no async
//! progress at all — communication only advances at blocking waits (the
//! plain-MPI behaviour).
//!
//! Run: `cargo bench --bench a5_comm_cores`

mod common;

use common::{cfg, ms};
use mlsl::engine::{simulate, CommMode};
use mlsl::fabric::topology::Topology;
use mlsl::metrics::print_table;

fn main() {
    for (topo, batch) in [(Topology::eth_10g(), 16usize), (Topology::omnipath_100g(), 32)] {
        let mut rows = Vec::new();
        // 0 comm cores -> MpiNonBlocking (no async progress).
        let c0 = cfg("resnet50", topo.clone(), 64, batch, CommMode::MpiNonBlocking);
        let r0 = simulate(c0);
        rows.push(vec![
            "0 (no async progress)".into(),
            ms(r0.iter_ns),
            ms(r0.compute_ns),
            ms(r0.exposed_comm_ns),
        ]);
        for cores in [1usize, 2, 4, 8] {
            let c = cfg("resnet50", topo.clone(), 64, batch,
                        CommMode::MlslAsync { comm_cores: cores });
            let r = simulate(c);
            rows.push(vec![
                cores.to_string(),
                ms(r.iter_ns),
                ms(r.compute_ns),
                ms(r.exposed_comm_ns),
            ]);
        }
        print_table(
            &format!("A5: ResNet-50, 64 nodes, {}, batch {batch}/node — comm cores", topo.name),
            &["comm cores", "iter ms", "compute ms", "exposed ms"],
            &rows,
        );
    }
    println!("\nexpected shape: 1-2 comm cores beat 0 (overlap wins despite the compute");
    println!("tax); returns diminish and eventually reverse as more cores are stolen.");
}

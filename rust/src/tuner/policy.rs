//! Selection policy: who decides which algorithm a collective runs —
//! the closed-form model ("model says") or a measured tuning table
//! ("measurement says").
//!
//! Every call site that previously hardcoded
//! [`selector::choose_algorithm`] / [`selector::choose_flat_algorithm`]
//! (the engine, the analytic design-space model, the CLI) now consults a
//! [`SelectionPolicy`]. The analytic policy reproduces the old behaviour
//! exactly; the tuned policies answer from a [`TuningTable`] and are
//! guaranteed to only ever return algorithms that
//! [`crate::collectives::program::build`] accepts at the queried rank
//! count (a legality filter runs before every table pick, because the
//! nearest measured row may prefer an algorithm that does not exist at
//! the actual p).

use crate::collectives::program::CollectiveKind;
use crate::collectives::selector;
use crate::collectives::{Algorithm, WireDtype};
use crate::fabric::topology::Topology;
use crate::Ns;

use super::table::{Cand, TuningTable};

/// Is `alg` buildable as an allreduce over `p` ranks? Deliberately the
/// BUILDER'S precondition, not the analytic candidate menu: a tuned
/// table may apply a measurement to any rank count the program compiles
/// at (e.g. hierarchical at p == ranks_per_node). Constant-time — this
/// runs per candidate on every tuned choose/predict — and kept in
/// lockstep with [`crate::collectives::program::build`] by the
/// `legality_matches_builder` test.
pub fn allreduce_legal(alg: Algorithm, p: usize) -> bool {
    match alg {
        Algorithm::Ring => true,
        Algorithm::RecursiveDoubling | Algorithm::HalvingDoubling => p.is_power_of_two(),
        // Nesting divisibility is a GroupStack construction invariant;
        // only the outermost group vs p remains to check.
        Algorithm::Hierarchical { groups } => p % groups.outermost() == 0,
        Algorithm::Auto => false,
    }
}

/// Is `alg` a real allgather program over `p` ranks? Ring, recursive
/// doubling and hierarchical have allgather builders; every other
/// algorithm would silently compile to a ring, which a tuned table must
/// not be credited for. Lockstep with `build`: `legality_matches_builder`.
pub fn allgather_legal(alg: Algorithm, p: usize) -> bool {
    match alg {
        Algorithm::Ring => true,
        Algorithm::RecursiveDoubling => p.is_power_of_two(),
        Algorithm::Hierarchical { groups } => p % groups.outermost() == 0,
        _ => false,
    }
}

/// A hierarchical pick from a table must also FIT the live topology's
/// tier stack: every group size has to be one of its tier sizes. The
/// engine hands partially-aligned communicators a topology view
/// truncated to the tiers their members actually tile or fit inside
/// ([`Topology::chooser_tier_depth`]); a table row measured on the full
/// fabric may still prefer a deeper stack (divisibility alone cannot
/// tell), and applying it would run a "rack" phase across a rack
/// boundary the members straddle. Non-hierarchical picks fit anywhere.
fn fits_tiers(alg: Algorithm, topo: &Topology) -> bool {
    match alg {
        Algorithm::Hierarchical { groups } => {
            let sizes = topo.level_sizes();
            groups.iter().all(|g| sizes.contains(&g))
        }
        _ => true,
    }
}

/// How call sites choose collective algorithms.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum SelectionPolicy {
    /// Closed-form two-tier alpha-beta model (the default: no table
    /// supplied).
    #[default]
    Analytic,
    /// Measured table, trusted unconditionally (nearest-cell semantics
    /// even when its fingerprint does not match the live topology);
    /// analytic only when the table has no legal candidate for a query.
    Tuned(TuningTable),
    /// Measured table, consulted ONLY while its fingerprint matches the
    /// live topology; any mismatch falls back to the analytic model
    /// wholesale. This is what `--tuning-table` installs. Note the
    /// engine's partially-aligned communicators query through a
    /// TRUNCATED topology view ([`Topology::restrict_tiers`]) whose
    /// fingerprint never matches a table measured on the full fabric —
    /// they deliberately get the analytic model (the table's cells were
    /// measured on fully-aligned communicators and do not transfer).
    TunedWithFallback(TuningTable),
}

impl SelectionPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SelectionPolicy::Analytic => "analytic",
            SelectionPolicy::Tuned(_) => "tuned",
            SelectionPolicy::TunedWithFallback(_) => "tuned+fallback",
        }
    }

    /// The table to consult for `topo`, if this policy trusts one.
    fn table_for(&self, topo: &Topology) -> Option<&TuningTable> {
        match self {
            SelectionPolicy::Analytic => None,
            SelectionPolicy::Tuned(t) => Some(t),
            SelectionPolicy::TunedWithFallback(t) => {
                if t.matches(topo) {
                    Some(t)
                } else {
                    None
                }
            }
        }
    }

    /// Allreduce over a node-aligned (contiguous whole-node) communicator.
    pub fn choose_allreduce(&self, topo: &Topology, p: usize, bytes: u64) -> Algorithm {
        if p <= 1 {
            return Algorithm::Ring;
        }
        if let Some(t) = self.table_for(topo) {
            let legal = |a: Algorithm| fits_tiers(a, topo) && allreduce_legal(a, p);
            if let Some(alg) = t.lookup(CollectiveKind::Allreduce, p, bytes, &legal) {
                return alg;
            }
        }
        selector::choose_algorithm(topo, p, bytes)
    }

    /// Allreduce over a strided / non-aligned communicator. Tables are
    /// measured on contiguous communicators, where in-tier hops get tier
    /// discounts; a strided group gets none, so the table only applies on
    /// flat fabrics (empty tier stack, where contiguity is irrelevant).
    /// Otherwise the all-top analytic model decides — exactly what a
    /// mis-applied table would mispredict.
    pub fn choose_flat_allreduce(&self, topo: &Topology, p: usize, bytes: u64) -> Algorithm {
        if p <= 1 {
            return Algorithm::Ring;
        }
        if !topo.is_hierarchical() {
            if let Some(t) = self.table_for(topo) {
                let legal = |a: Algorithm| {
                    !matches!(a, Algorithm::Hierarchical { .. }) && allreduce_legal(a, p)
                };
                if let Some(alg) = t.lookup(CollectiveKind::Allreduce, p, bytes, &legal) {
                    return alg;
                }
            }
        }
        selector::choose_flat_algorithm(topo, p, bytes)
    }

    /// Allgather over a node-aligned communicator (the engine's
    /// activation exchanges).
    pub fn choose_allgather(&self, topo: &Topology, p: usize, bytes: u64) -> Algorithm {
        if p <= 1 {
            return Algorithm::Ring;
        }
        if let Some(t) = self.table_for(topo) {
            let legal = |a: Algorithm| fits_tiers(a, topo) && allgather_legal(a, p);
            if let Some(alg) = t.lookup(CollectiveKind::Allgather, p, bytes, &legal) {
                return alg;
            }
        }
        selector::choose_allgather_algorithm(topo, p, bytes)
    }

    /// Allgather over a non-aligned communicator (see
    /// [`Self::choose_flat_allreduce`] for the gating rationale).
    pub fn choose_flat_allgather(&self, topo: &Topology, p: usize, bytes: u64) -> Algorithm {
        if p <= 1 {
            return Algorithm::Ring;
        }
        if !topo.is_hierarchical() {
            if let Some(t) = self.table_for(topo) {
                let legal = |a: Algorithm| {
                    !matches!(a, Algorithm::Hierarchical { .. }) && allgather_legal(a, p)
                };
                if let Some(alg) = t.lookup(CollectiveKind::Allgather, p, bytes, &legal) {
                    return alg;
                }
            }
        }
        selector::choose_flat_allgather_algorithm(topo, p, bytes)
    }

    /// One-stop choice for an arbitrary member list (the engine's path,
    /// including post-churn survivor sets): node-aligned contiguous
    /// groups get the hierarchical chooser over a topology view
    /// truncated to the tiers the members actually tile
    /// ([`Topology::chooser_tier_depth`]); anything strided or
    /// non-contiguous — which elastic departures routinely produce —
    /// gets the flat chooser. Centralising this gate here means churned
    /// communicators and healthy ones choose through the same code.
    pub fn choose_for_members(
        &self,
        topo: &Topology,
        members: &[crate::Rank],
        kind: CollectiveKind,
        bytes: u64,
    ) -> Algorithm {
        let p = members.len();
        let depth = topo.aligned_tier_depth(members);
        let usable = topo.chooser_tier_depth(members);
        let restricted;
        let view = if usable >= topo.tiers.len() {
            topo
        } else {
            restricted = topo.restrict_tiers(usable);
            &restricted
        };
        match (kind, depth > 0) {
            (CollectiveKind::Allreduce, true) => self.choose_allreduce(view, p, bytes),
            (CollectiveKind::Allreduce, false) => self.choose_flat_allreduce(topo, p, bytes),
            (_, true) => self.choose_allgather(view, p, bytes),
            (_, false) => self.choose_flat_allgather(topo, p, bytes),
        }
    }

    /// Predicted allreduce time under this policy: tuned policies answer
    /// from measured (log-interpolated) cells when they can, the analytic
    /// policy from the closed-form model — so design-space analyses built
    /// on this prediction calibrate to measurements once a table exists.
    pub fn predict_allreduce_ns(&self, topo: &Topology, p: usize, bytes: u64) -> Ns {
        if p <= 1 {
            return 0;
        }
        // One interpolation pass serves both the pick and its time (this
        // sits in the analytic design-space loops, per layer × group).
        if let Some(t) = self.table_for(topo) {
            let cheapest_legal = t
                .interpolated(CollectiveKind::Allreduce, p, bytes)
                .unwrap_or_default()
                .into_iter()
                .filter(|(a, _)| fits_tiers(*a, topo) && allreduce_legal(*a, p))
                .min_by(|x, y| x.1.partial_cmp(&y.1).expect("measured times are finite"));
            if let Some((_, ns)) = cheapest_legal {
                return ns.ceil() as Ns;
            }
        }
        let alg = selector::choose_algorithm(topo, p, bytes);
        selector::predict_allreduce_ns(topo, alg, p, bytes)
    }

    // -----------------------------------------------------------------
    // Wire precision: (algorithm × wire dtype) choices
    // -----------------------------------------------------------------

    /// Allreduce over a node-aligned communicator, choosing from the
    /// (algorithm × wire dtype) grid. `wires` is the precision menu
    /// ([`WireDtype::ALL`] for `--wire-dtype auto`, a single element for
    /// a pinned precision); `slowdown_milli` is the worst endpoint chaos
    /// compute-slowdown the quantize charge must assume (1000 = healthy).
    /// Tuned policies answer from measured candidate columns; the
    /// analytic model decides otherwise. A `[F32]` menu reproduces
    /// [`Self::choose_allreduce`] exactly.
    pub fn choose_allreduce_wire(
        &self,
        topo: &Topology,
        p: usize,
        bytes: u64,
        wires: &[WireDtype],
        slowdown_milli: u64,
    ) -> (Algorithm, WireDtype) {
        if p <= 1 {
            return (Algorithm::Ring, wires.first().copied().unwrap_or_default());
        }
        if let Some(t) = self.table_for(topo) {
            let legal = |(a, w): Cand| {
                wires.contains(&w) && fits_tiers(a, topo) && allreduce_legal(a, p)
            };
            if let Some(cand) = t.lookup_cand(CollectiveKind::Allreduce, p, bytes, &legal) {
                return cand;
            }
        }
        selector::choose_algorithm_wire(topo, p, bytes, wires, slowdown_milli)
    }

    /// Allreduce over a strided / non-aligned communicator with the
    /// precision menu (table on flat fabrics only — see
    /// [`Self::choose_flat_allreduce`]).
    pub fn choose_flat_allreduce_wire(
        &self,
        topo: &Topology,
        p: usize,
        bytes: u64,
        wires: &[WireDtype],
        slowdown_milli: u64,
    ) -> (Algorithm, WireDtype) {
        if p <= 1 {
            return (Algorithm::Ring, wires.first().copied().unwrap_or_default());
        }
        if !topo.is_hierarchical() {
            if let Some(t) = self.table_for(topo) {
                let legal = |(a, w): Cand| {
                    wires.contains(&w)
                        && !matches!(a, Algorithm::Hierarchical { .. })
                        && allreduce_legal(a, p)
                };
                if let Some(cand) = t.lookup_cand(CollectiveKind::Allreduce, p, bytes, &legal) {
                    return cand;
                }
            }
        }
        selector::choose_flat_algorithm_wire(topo, p, bytes, wires, slowdown_milli)
    }

    /// [`Self::choose_for_members`] over the (algorithm × wire dtype)
    /// grid. Only reductions are error-feedback-protected, so only
    /// allreduce consults the precision menu; every other kind keeps its
    /// algorithm choice and the f32 wire.
    pub fn choose_for_members_wire(
        &self,
        topo: &Topology,
        members: &[crate::Rank],
        kind: CollectiveKind,
        bytes: u64,
        wires: &[WireDtype],
        slowdown_milli: u64,
    ) -> (Algorithm, WireDtype) {
        if kind != CollectiveKind::Allreduce {
            return (self.choose_for_members(topo, members, kind, bytes), WireDtype::F32);
        }
        let p = members.len();
        let depth = topo.aligned_tier_depth(members);
        let usable = topo.chooser_tier_depth(members);
        let restricted;
        let view = if usable >= topo.tiers.len() {
            topo
        } else {
            restricted = topo.restrict_tiers(usable);
            &restricted
        };
        if depth > 0 {
            self.choose_allreduce_wire(view, p, bytes, wires, slowdown_milli)
        } else {
            self.choose_flat_allreduce_wire(topo, p, bytes, wires, slowdown_milli)
        }
    }

    /// Wire-precision-aware [`Self::predict_allreduce_ns`]: the predicted
    /// time of the best (algorithm, wire) pick offered by `wires`.
    pub fn predict_allreduce_ns_wire(
        &self,
        topo: &Topology,
        p: usize,
        bytes: u64,
        wires: &[WireDtype],
        slowdown_milli: u64,
    ) -> Ns {
        if p <= 1 {
            return 0;
        }
        if let Some(t) = self.table_for(topo) {
            let cheapest_legal = t
                .interpolated_cand(CollectiveKind::Allreduce, p, bytes)
                .unwrap_or_default()
                .into_iter()
                .filter(|((a, w), _)| {
                    wires.contains(w) && fits_tiers(*a, topo) && allreduce_legal(*a, p)
                })
                .min_by(|x, y| x.1.partial_cmp(&y.1).expect("measured times are finite"));
            if let Some((_, ns)) = cheapest_legal {
                return ns.ceil() as Ns;
            }
        }
        let (alg, wire) = selector::choose_algorithm_wire(topo, p, bytes, wires, slowdown_milli);
        selector::predict_allreduce_ns_wire(topo, alg, p, bytes, wire, slowdown_milli)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::probe::{tune, ProbeSpec};

    #[test]
    fn legality_matches_builder() {
        // The constant-time legality checks must agree with the builder's
        // own validation everywhere the policy can query them (p >= 1;
        // the policy short-circuits p <= 1 before filtering). For
        // allgather only ring/rdoubling count: `build` compiles anything
        // else to a ring fallback, which legality deliberately rejects.
        use crate::collectives::program::build;
        let stacks: [&[usize]; 10] =
            [&[1], &[2], &[3], &[4], &[5], &[8], &[2, 4], &[2, 8], &[3, 6], &[2, 4, 8]];
        for p in 1..=64usize {
            let mut algs = vec![
                Algorithm::Ring,
                Algorithm::RecursiveDoubling,
                Algorithm::HalvingDoubling,
                Algorithm::Auto,
            ];
            for stack in stacks {
                algs.push(Algorithm::hier(stack));
            }
            for alg in &algs {
                assert_eq!(
                    allreduce_legal(*alg, p),
                    build(CollectiveKind::Allreduce, *alg, p, 1).is_ok(),
                    "allreduce {alg:?} p={p}"
                );
            }
            for alg in algs.iter().filter(|a| **a != Algorithm::Auto) {
                // Auto compiles to a ring for allgather (not an error), so
                // the legality check deliberately excludes it.
                if *alg == Algorithm::HalvingDoubling {
                    continue; // same: silently compiles to a ring
                }
                assert_eq!(
                    allgather_legal(*alg, p),
                    build(CollectiveKind::Allgather, *alg, p, 1).is_ok(),
                    "allgather {alg:?} p={p}"
                );
            }
        }
    }

    #[test]
    fn analytic_policy_reproduces_selector_choices() {
        let policy = SelectionPolicy::default();
        assert_eq!(policy.name(), "analytic");
        for topo in [Topology::eth_10g(), Topology::eth_10g_smp(2)] {
            for p in [2usize, 6, 16, 64] {
                for bytes in [1u64 << 10, 1 << 20, 64 << 20] {
                    assert_eq!(
                        policy.choose_allreduce(&topo, p, bytes),
                        selector::choose_algorithm(&topo, p, bytes)
                    );
                    assert_eq!(
                        policy.choose_flat_allreduce(&topo, p, bytes),
                        selector::choose_flat_algorithm(&topo, p, bytes)
                    );
                    assert_eq!(
                        policy.choose_allgather(&topo, p, bytes),
                        selector::choose_allgather_algorithm(&topo, p, bytes)
                    );
                }
            }
        }
    }

    #[test]
    fn tuned_policy_answers_from_the_table_on_grid_cells() {
        let topo = Topology::eth_10g();
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let table = tune(&topo, &spec);
        let policy = SelectionPolicy::TunedWithFallback(table.clone());
        for kind in crate::tuner::probe::TUNED_KINDS {
            for cell in table.cells(kind) {
                let pick = match kind {
                    CollectiveKind::Allreduce => {
                        policy.choose_allreduce(&topo, cell.ranks, cell.bytes)
                    }
                    _ => policy.choose_allgather(&topo, cell.ranks, cell.bytes),
                };
                assert_eq!(pick, cell.best().unwrap().0, "{kind:?} p={}", cell.ranks);
            }
        }
    }

    #[test]
    fn table_picks_never_exceed_the_live_tier_stack() {
        use crate::tuner::table::MeasuredCell;
        // A strict Tuned table (trusted regardless of fingerprint) claims
        // the 3-level stack wins a cell. Queried through a topology view
        // that lacks the rack tier — what the engine hands rack-straddling
        // communicators — the pick must be filtered out, not applied.
        let full = Topology::by_name("eth10g-x2r4").unwrap();
        let three = Algorithm::hier(&[2, 8]);
        let mut table = crate::tuner::TuningTable::for_topology(&full);
        table.insert(
            CollectiveKind::Allreduce,
            MeasuredCell::new(16, 1 << 20, vec![(Algorithm::Ring, 99_999), (three, 10)]),
        );
        let policy = SelectionPolicy::Tuned(table);
        // On the full fabric the measured 3-level winner applies…
        assert_eq!(policy.choose_allreduce(&full, 16, 1 << 20), three);
        // …but on the node-only restricted view it must not: the members
        // behind that view straddle a rack boundary.
        let restricted = full.restrict_tiers(1);
        let pick = policy.choose_allreduce(&restricted, 16, 1 << 20);
        assert_ne!(pick, three, "{pick:?}");
    }

    #[test]
    fn strided_groups_on_smp_fabrics_stay_analytic() {
        let topo = Topology::eth_10g_smp(2);
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let policy = SelectionPolicy::Tuned(tune(&topo, &spec));
        for p in [4usize, 6, 8] {
            for bytes in [1u64 << 10, 1 << 20] {
                assert_eq!(
                    policy.choose_flat_allreduce(&topo, p, bytes),
                    selector::choose_flat_algorithm(&topo, p, bytes),
                    "p={p} bytes={bytes}"
                );
            }
        }
    }

    #[test]
    fn choose_for_members_gates_on_alignment() {
        let topo = Topology::by_name("eth10g-x2e2").unwrap();
        let policy = SelectionPolicy::default();
        let bytes = 1u64 << 20;
        // Whole-node contiguous members: hierarchical chooser on the
        // (here untruncated) tier view.
        let aligned: Vec<usize> = (0..8).collect();
        assert_eq!(
            policy.choose_for_members(&topo, &aligned, CollectiveKind::Allreduce, bytes),
            policy.choose_allreduce(&topo, 8, bytes)
        );
        assert_eq!(
            policy.choose_for_members(&topo, &aligned, CollectiveKind::Allgather, bytes),
            policy.choose_allgather(&topo, 8, bytes)
        );
        // A post-churn survivor set with a hole is non-contiguous: the
        // flat chooser decides (no tier discounts apply to it).
        let holed: Vec<usize> = vec![0, 1, 2, 4, 5, 6, 7];
        assert_eq!(topo.aligned_tier_depth(&holed), 0);
        assert_eq!(
            policy.choose_for_members(&topo, &holed, CollectiveKind::Allreduce, bytes),
            policy.choose_flat_allreduce(&topo, 7, bytes)
        );
    }

    #[test]
    fn wire_choices_reduce_to_plain_choices_on_an_f32_menu() {
        let topo = Topology::eth_10g_smp(2);
        let f32_only = [WireDtype::F32];
        let policy = SelectionPolicy::default();
        for p in [2usize, 6, 8, 16] {
            for bytes in [1u64 << 10, 1 << 20, 16 << 20] {
                assert_eq!(
                    policy.choose_allreduce_wire(&topo, p, bytes, &f32_only, 1000),
                    (policy.choose_allreduce(&topo, p, bytes), WireDtype::F32)
                );
                assert_eq!(
                    policy.choose_flat_allreduce_wire(&topo, p, bytes, &f32_only, 1000),
                    (policy.choose_flat_allreduce(&topo, p, bytes), WireDtype::F32)
                );
                assert_eq!(
                    policy.predict_allreduce_ns_wire(&topo, p, bytes, &f32_only, 1000),
                    policy.predict_allreduce_ns(&topo, p, bytes)
                );
            }
        }
    }

    #[test]
    fn tuned_wire_policy_answers_candidates_from_the_table() {
        let topo = Topology::eth_10g();
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let table = tune(&topo, &spec);
        let policy = SelectionPolicy::TunedWithFallback(table.clone());
        for cell in table.cells(CollectiveKind::Allreduce) {
            // Full menu: the pick is the cell's measured best candidate.
            let pick =
                policy.choose_allreduce_wire(&topo, cell.ranks, cell.bytes, &WireDtype::ALL, 1000);
            assert_eq!(pick, cell.best_cand().unwrap().0, "p={}", cell.ranks);
            // f32-pinned menu: the pick is the f32-restricted best — the
            // same answer the algorithm-only tuned policy gives.
            let f32_menu = [WireDtype::F32];
            let (alg, wire) =
                policy.choose_allreduce_wire(&topo, cell.ranks, cell.bytes, &f32_menu, 1000);
            assert_eq!(wire, WireDtype::F32);
            assert_eq!(alg, cell.best().unwrap().0, "p={}", cell.ranks);
        }
        // The bulk cells' tuned winner is compressed on 10GbE.
        let bulk = table
            .cells(CollectiveKind::Allreduce)
            .iter()
            .map(|c| policy.choose_allreduce_wire(&topo, c.ranks, c.bytes, &WireDtype::ALL, 1000))
            .any(|(_, w)| w != WireDtype::F32);
        assert!(bulk, "no compressed winner anywhere on the quick grid");
        // choose_for_members_wire keeps non-reductions on the f32 wire.
        let members: Vec<usize> = (0..8).collect();
        let (_, w) = policy.choose_for_members_wire(
            &topo,
            &members,
            CollectiveKind::Allgather,
            1 << 20,
            &WireDtype::ALL,
            1000,
        );
        assert_eq!(w, WireDtype::F32);
    }

    #[test]
    fn tuned_prediction_matches_measurement_on_grid_cells() {
        let topo = Topology::eth_10g();
        let mut spec = ProbeSpec::quick();
        spec.max_ranks = 8;
        let table = tune(&topo, &spec);
        let policy = SelectionPolicy::Tuned(table.clone());
        for cell in table.cells(CollectiveKind::Allreduce) {
            let (_, best_ns) = cell.best().unwrap();
            assert_eq!(
                policy.predict_allreduce_ns(&topo, cell.ranks, cell.bytes),
                best_ns,
                "p={} bytes={}",
                cell.ranks,
                cell.bytes
            );
        }
    }
}

//! GoogLeNet (Inception v1, Szegedy et al. 2014) layer table, 224×224.
//! 9 inception modules; ~7M parameters — many SMALL gradients, the
//! opposite end of the spectrum from VGG (latency- rather than
//! bandwidth-dominated communication).

use super::{conv, fc, pool, LayerDesc, ModelDesc};

/// Inception module: 1×1 + (1×1→3×3) + (1×1→5×5) + (pool→1×1 proj).
#[allow(clippy::too_many_arguments)]
fn inception(
    l: &mut Vec<LayerDesc>,
    name: &str,
    cin: usize,
    c1: usize,
    c3r: usize,
    c3: usize,
    c5r: usize,
    c5: usize,
    cp: usize,
    hw: usize,
) -> usize {
    l.push(conv(&format!("{name}.1x1"), 1, cin, c1, hw, hw));
    l.push(conv(&format!("{name}.3x3r"), 1, cin, c3r, hw, hw));
    l.push(conv(&format!("{name}.3x3"), 3, c3r, c3, hw, hw));
    l.push(conv(&format!("{name}.5x5r"), 1, cin, c5r, hw, hw));
    l.push(conv(&format!("{name}.5x5"), 5, c5r, c5, hw, hw));
    l.push(conv(&format!("{name}.pproj"), 1, cin, cp, hw, hw));
    c1 + c3 + c5 + cp // concatenated output channels
}

pub fn googlenet() -> ModelDesc {
    let mut l = Vec::new();
    l.push(conv("conv1", 7, 3, 64, 112, 112));
    l.push(pool("pool1", 64 * 56 * 56, (64 * 56 * 56) as f64));
    l.push(conv("conv2r", 1, 64, 64, 56, 56));
    l.push(conv("conv2", 3, 64, 192, 56, 56));
    l.push(pool("pool2", 192 * 28 * 28, (192 * 28 * 28) as f64));

    // (c1, c3r, c3, c5r, c5, cp) per module — the published table.
    let mut cin = 192;
    cin = inception(&mut l, "inc3a", cin, 64, 96, 128, 16, 32, 32, 28);
    cin = inception(&mut l, "inc3b", cin, 128, 128, 192, 32, 96, 64, 28);
    l.push(pool("pool3", cin * 14 * 14, (cin * 14 * 14) as f64));
    cin = inception(&mut l, "inc4a", cin, 192, 96, 208, 16, 48, 64, 14);
    cin = inception(&mut l, "inc4b", cin, 160, 112, 224, 24, 64, 64, 14);
    cin = inception(&mut l, "inc4c", cin, 128, 128, 256, 24, 64, 64, 14);
    cin = inception(&mut l, "inc4d", cin, 112, 144, 288, 32, 64, 64, 14);
    cin = inception(&mut l, "inc4e", cin, 256, 160, 320, 32, 128, 128, 14);
    l.push(pool("pool4", cin * 7 * 7, (cin * 7 * 7) as f64));
    cin = inception(&mut l, "inc5a", cin, 256, 160, 320, 32, 128, 128, 7);
    cin = inception(&mut l, "inc5b", cin, 384, 192, 384, 48, 128, 128, 7);
    l.push(pool("avgpool", cin, (cin * 49) as f64));
    l.push(fc("fc1000", cin, 1000));
    ModelDesc { name: "googlenet".into(), layers: l, default_batch: 32 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_count_matches_paper() {
        let m = googlenet();
        let p = m.total_weight_elems() as f64;
        assert!((p - 7.0e6).abs() / 7.0e6 < 0.03, "{p}");
    }

    #[test]
    fn gradients_are_many_and_small() {
        let m = googlenet();
        let weighted = m.weighted_layers().count();
        assert!(weighted > 55, "{weighted}");
        // Median gradient well under 1 MB.
        let mut sizes: Vec<u64> = m.weighted_layers().map(|(_, l)| l.weight_bytes()).collect();
        sizes.sort();
        assert!(sizes[sizes.len() / 2] < 1_000_000);
    }
}

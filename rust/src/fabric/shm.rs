//! Real in-process fabric: ranks are threads, wires are lock-free channels.
//!
//! This is what the *real* training path runs on (DESIGN.md §Substitutions:
//! multi-node MPI ranks → in-process worker threads). The collectives and
//! progress-engine code above it is identical to what the simulated path
//! schedules — this fabric just actually moves the bytes.
//!
//! Message matching follows MPI semantics: `(src, tag)` envelopes, with an
//! unexpected-message queue so arrival order never deadlocks a program.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use crate::Rank;

/// A message on the in-process wire.
#[derive(Debug)]
pub struct WireMsg {
    pub src: Rank,
    pub tag: u64,
    pub payload: Vec<u8>,
}

/// One rank's endpoint: senders to every peer + its own inbox.
pub struct ShmEndpoint {
    pub rank: Rank,
    pub p: usize,
    txs: Vec<Sender<WireMsg>>,
    rx: Receiver<WireMsg>,
    /// Arrived-but-not-yet-requested messages, keyed by (src, tag).
    unexpected: HashMap<(Rank, u64), VecDeque<Vec<u8>>>,
}

/// Build a fully-connected `p`-rank fabric; hand one endpoint to each
/// rank thread.
pub fn fabric(p: usize) -> Vec<ShmEndpoint> {
    let mut txs = Vec::with_capacity(p);
    let mut rxs = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    rxs.into_iter()
        .enumerate()
        .map(|(rank, rx)| ShmEndpoint {
            rank,
            p,
            txs: txs.clone(),
            rx,
            unexpected: HashMap::new(),
        })
        .collect()
}

impl ShmEndpoint {
    /// Non-blocking send (channels are unbounded; collective schedules are
    /// therefore deadlock-free by construction).
    pub fn send(&self, dst: Rank, tag: u64, payload: Vec<u8>) {
        self.txs[dst]
            .send(WireMsg { src: self.rank, tag, payload })
            .expect("peer endpoint dropped");
    }

    /// Drain everything currently in the inbox into the unexpected queue.
    pub fn poll(&mut self) {
        while let Ok(m) = self.rx.try_recv() {
            self.unexpected
                .entry((m.src, m.tag))
                .or_default()
                .push_back(m.payload);
        }
    }

    /// Non-blocking matched receive.
    pub fn take(&mut self, from: Rank, tag: u64) -> Option<Vec<u8>> {
        self.poll();
        let q = self.unexpected.get_mut(&(from, tag))?;
        let m = q.pop_front();
        if q.is_empty() {
            self.unexpected.remove(&(from, tag));
        }
        m
    }

    /// Blocking matched receive.
    pub fn recv(&mut self, from: Rank, tag: u64) -> Vec<u8> {
        loop {
            if let Some(m) = self.take(from, tag) {
                return m;
            }
            match self.rx.recv_timeout(Duration::from_millis(50)) {
                Ok(m) => self
                    .unexpected
                    .entry((m.src, m.tag))
                    .or_default()
                    .push_back(m.payload),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(e) => panic!("fabric torn down while receiving: {e}"),
            }
        }
    }

    /// Is a matched message already available?
    pub fn has(&mut self, from: Rank, tag: u64) -> bool {
        self.poll();
        self.unexpected
            .get(&(from, tag))
            .map_or(false, |q| !q.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn pairwise_send_recv() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.send(1, 42, vec![1, 2, 3]);
        assert_eq!(e1.recv(0, 42), vec![1, 2, 3]);
    }

    #[test]
    fn out_of_order_tags_match() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.send(1, 7, vec![7]);
        e0.send(1, 8, vec![8]);
        // Request the later tag first: earlier lands in unexpected queue.
        assert_eq!(e1.recv(0, 8), vec![8]);
        assert_eq!(e1.recv(0, 7), vec![7]);
    }

    #[test]
    fn fifo_within_same_tag() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        for i in 0..10u8 {
            e0.send(1, 5, vec![i]);
        }
        for i in 0..10u8 {
            assert_eq!(e1.recv(0, 5), vec![i]);
        }
    }

    #[test]
    fn cross_thread_ring() {
        let eps = fabric(4);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || {
                    let next = (ep.rank + 1) % ep.p;
                    let prev = (ep.rank + ep.p - 1) % ep.p;
                    ep.send(next, 1, vec![ep.rank as u8]);
                    let got = ep.recv(prev, 1);
                    assert_eq!(got, vec![prev as u8]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}

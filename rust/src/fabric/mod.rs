//! Cluster substrate: the paper's testbeds, rebuilt.
//!
//! The paper evaluates MLSL on Xeon/Omnipath (Fig. 2, up to 256 nodes) and
//! Xeon/10GbE (the 1.8–2.2× prioritization claim). We do not have those
//! clusters; per DESIGN.md §Substitutions this module provides:
//!
//! * [`sim`] — a discrete-event network simulator whose NICs are
//!   strict-priority, *preemptive* servers: a higher-priority message takes
//!   the wire from an in-flight bulk transfer, which is exactly the
//!   mechanism MLSL's message prioritization needs and MPI lacks.
//! * [`shm`] — a real in-process fabric (ranks = threads, wires = lock-free
//!   channels) used by the *real* training path, so the identical
//!   collectives/progress code runs with actual gradient bytes.
//! * [`topology`] — parameter presets for the two fabrics the paper uses
//!   plus the node compute model (Skylake-class FLOPs).
//!
//! # N-level tier hierarchy
//!
//! Real clusters are hierarchical: sockets inside nodes, nodes inside
//! racks, racks behind an oversubscribed spine. A [`Topology`] carries an
//! ordered stack of [`topology::TierSpec`]s (innermost first, each with
//! its own group size, line rate, latency, per-message overhead) plus the
//! top-level fabric parameters. The simulator prices every hop at its
//! **deepest common tier** — the innermost level whose contiguous group
//! contains both endpoints; hops confined to a shared-memory tier ride a
//! separate per-rank shm channel and never contend with NIC traffic. The
//! `-x<r>[r<k>][e<l>]` preset suffixes (`eth10g-x2`, `opa-x4`,
//! `eth10g-x8r16e2`) select the paper's testbeds at r ranks/node,
//! optionally k nodes/rack and optionally l NIC egress rails per node;
//! an empty tier stack collapses to the old flat model, bit-for-bit.
//! Hierarchical collectives
//! ([`crate::collectives::Algorithm::Hierarchical`]) exploit the fast
//! tiers by reducing onto one leader per group at every level before
//! touching the slowest wire; multi-rail nodes additionally stripe each
//! bandwidth-bound transfer's chunks across their rails ([`sim`]),
//! multiplying injection bandwidth without discounting latency.

pub mod event;
pub mod par;
pub mod shm;
pub mod sim;
pub mod topology;

pub use sim::{
    tenant_of_tag, BgFlow, BgPlan, ChaosPlan, ChaosStats, FlapWindow, NetSim, RailDeath,
    SimEvent, StragglerPlan, BG_TAG, TENANT_TAG_SHIFT,
};
pub use topology::{NodeSpec, Topology};

use crate::{Ns, Priority, Rank};

/// A point-to-point message descriptor (what traverses the simulated wire).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MsgDesc {
    pub src: Rank,
    pub dst: Rank,
    pub bytes: u64,
    pub priority: Priority,
    /// Opaque tag the layer above uses to route completions
    /// (collective id << 32 | step index, by convention).
    pub tag: u64,
}

/// Gigabytes-per-second → bytes-per-nanosecond.
pub fn gbps_to_bytes_per_ns(gbps: f64) -> f64 {
    // 1 Gbit/s = 1e9 bit/s = 0.125e9 byte/s = 0.125 byte/ns.
    gbps * 0.125
}

/// Transfer duration in ns for `bytes` at `gbps` line rate.
pub fn wire_ns(bytes: u64, gbps: f64) -> Ns {
    let bpns = gbps_to_bytes_per_ns(gbps);
    (bytes as f64 / bpns).ceil() as Ns
}

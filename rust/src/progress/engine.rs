//! The comm-core thread: prioritized, preemptive multi-op progress.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use crate::collectives::exec::{apply_recv, do_send};
use crate::collectives::program::Program;
use crate::collectives::{ReduceOp, WireDtype};
use crate::fabric::shm::ShmEndpoint;
use crate::{Priority, Rank};

use super::handle::Handle;

/// A collective operation submitted to a comm core.
pub struct OpSubmit {
    pub coll_id: u64,
    pub program: Program,
    pub buf: Vec<f32>,
    pub op: ReduceOp,
    pub wire: WireDtype,
    pub priority: Priority,
    pub done: Sender<Vec<f32>>,
}

struct ActiveOp {
    sub: OpSubmit,
    pc: usize,
    sent_current: bool,
    seq: u64, // FIFO tiebreak within a priority class
}

impl ActiveOp {
    fn complete(&self) -> bool {
        self.pc >= self.sub.program.steps.len()
    }
}

enum Command {
    Submit(OpSubmit),
    Shutdown,
}

/// Statistics a comm core reports at shutdown (read via [`CommCore::join`]).
#[derive(Debug, Default, Clone)]
pub struct CoreStats {
    pub ops_completed: u64,
    pub steps_executed: u64,
    /// Times a ready lower-priority op was bypassed in favour of a more
    /// urgent one — the preemption count.
    pub bypasses: u64,
}

/// A dedicated communication core (thread) for one rank.
pub struct CommCore {
    rank: Rank,
    tx: Sender<Command>,
    join: Option<JoinHandle<CoreStats>>,
    next_coll_id: std::cell::Cell<u64>,
}

impl CommCore {
    /// Spawn the comm core for `ep`'s rank.
    pub fn spawn(ep: ShmEndpoint) -> Self {
        let rank = ep.rank;
        let (tx, rx) = channel();
        let join = std::thread::Builder::new()
            .name(format!("mlsl-comm-{rank}"))
            .spawn(move || core_loop(ep, rx))
            .expect("spawn comm core");
        Self { rank, tx, join: Some(join), next_coll_id: std::cell::Cell::new(1) }
    }

    pub fn rank(&self) -> Rank {
        self.rank
    }

    /// Next collective id. Ids must be allocated in the SAME order on all
    /// ranks (collectives are matched by id); submitting ops in a
    /// deterministic order per iteration satisfies this, as MPI requires.
    pub fn alloc_id(&self) -> u64 {
        let id = self.next_coll_id.get();
        self.next_coll_id.set(id + 1);
        id
    }

    /// Submit a prepared op (see [`crate::mlsl::Communicator`] for the
    /// user-facing API that builds programs).
    pub fn submit(&self, sub: OpSubmit) {
        self.tx.send(Command::Submit(sub)).expect("comm core alive");
    }

    /// Convenience: submit and return a handle.
    pub fn submit_with_handle(
        &self,
        coll_id: u64,
        program: Program,
        buf: Vec<f32>,
        op: ReduceOp,
        wire: WireDtype,
        priority: Priority,
    ) -> Handle {
        let (dtx, drx) = channel();
        self.submit(OpSubmit { coll_id, program, buf, op, wire, priority, done: dtx });
        Handle { rx: drx, coll_id }
    }

    /// Stop the core and collect its stats.
    pub fn join(mut self) -> CoreStats {
        let _ = self.tx.send(Command::Shutdown);
        self.join.take().expect("not yet joined").join().expect("comm core panicked")
    }
}

impl Drop for CommCore {
    fn drop(&mut self) {
        let _ = self.tx.send(Command::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// The comm-core event loop.
fn core_loop(mut ep: ShmEndpoint, rx: Receiver<Command>) -> CoreStats {
    let mut stats = CoreStats::default();
    let mut active: HashMap<u64, ActiveOp> = HashMap::new();
    let mut seq = 0u64;
    let mut shutdown = false;
    let mut idle_spins = 0u32;

    loop {
        // 1. Ingest new submissions.
        loop {
            match rx.try_recv() {
                Ok(Command::Submit(sub)) => {
                    if sub.program.steps.is_empty() {
                        // Single-rank world: complete immediately.
                        stats.ops_completed += 1;
                        let _ = sub.done.send(sub.buf);
                    } else {
                        active.insert(sub.coll_id, ActiveOp { sub, pc: 0, sent_current: false, seq });
                        seq += 1;
                    }
                }
                Ok(Command::Shutdown) => shutdown = true,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => shutdown = true,
            }
            if shutdown {
                break;
            }
        }
        if shutdown && active.is_empty() {
            return stats;
        }

        // 2. Pull arrivals into the endpoint's unexpected queues.
        ep.poll();

        // 3. Advance the most urgent op that can make progress RIGHT NOW.
        //    Ops are scanned in (priority, seq) order; executing only the
        //    first ready one per pass gives step-granular preemption.
        let mut order: Vec<(Priority, u64, u64)> = active
            .values()
            .map(|a| (a.sub.priority, a.seq, a.sub.coll_id))
            .collect();
        order.sort_unstable();

        let mut progressed = false;
        let mut bypassed_ready = 0u64;
        for (_, _, coll_id) in &order {
            let a = active.get_mut(coll_id).expect("active op");
            let step = a.sub.program.steps[a.pc];
            let mut did = false;
            if let (Some(sd), false) = (&step.send, a.sent_current) {
                do_send(&ep, a.sub.coll_id, &a.sub.buf, sd.to, sd.range, a.sub.wire);
                a.sent_current = true;
                did = true;
            }
            let recv_done = match &step.recv {
                None => true,
                Some(rv) => {
                    if let Some(payload) = ep.take(rv.from, a.sub.coll_id) {
                        apply_recv(&mut a.sub.buf, rv.range, &payload, a.sub.wire, rv.reduce, a.sub.op);
                        did = true;
                        true
                    } else {
                        false
                    }
                }
            };
            if recv_done {
                a.pc += 1;
                a.sent_current = false;
            }
            if did {
                stats.steps_executed += 1;
                stats.bypasses += bypassed_ready;
                progressed = true;
                if a.complete() {
                    let a = active.remove(coll_id).expect("present");
                    stats.ops_completed += 1;
                    // Receiver may have been dropped (fire-and-forget).
                    let _ = a.sub.done.send(a.sub.buf);
                }
                break; // re-evaluate priorities from scratch
            } else {
                // This op had nothing to do; if it *would* have been ready
                // later it's not a bypass. A bypass is counted when a
                // LOWER-priority op progresses after this one stalls —
                // approximated by counting stalled higher-priority ops.
                bypassed_ready += 1;
            }
        }

        // 4. Idle strategy: spin briefly, then yield, then nap.
        if !progressed {
            idle_spins += 1;
            if idle_spins < 64 {
                std::hint::spin_loop();
            } else if idle_spins < 256 {
                std::thread::yield_now();
            } else {
                std::thread::sleep(std::time::Duration::from_micros(50));
            }
        } else {
            idle_spins = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::program::{allreduce_ring, CollectiveKind};
    use crate::collectives::Algorithm;
    use crate::fabric::shm;

    fn spawn_world(p: usize) -> Vec<CommCore> {
        shm::fabric(p).into_iter().map(CommCore::spawn).collect()
    }

    #[test]
    fn single_allreduce_roundtrip() {
        let p = 4;
        let n = 1000;
        let cores = spawn_world(p);
        let mut handles = Vec::new();
        for (r, core) in cores.iter().enumerate() {
            let progs = allreduce_ring(p, n);
            let buf: Vec<f32> = (0..n).map(|i| (r * n + i) as f32).collect();
            handles.push(core.submit_with_handle(
                1,
                progs[r].clone(),
                buf,
                ReduceOp::Sum,
                WireDtype::F32,
                1,
            ));
        }
        for h in handles {
            let out = h.wait();
            for (i, v) in out.iter().enumerate() {
                let want: f32 = (0..p).map(|r| (r * n + i) as f32).sum();
                assert_eq!(*v, want);
            }
        }
    }

    #[test]
    fn many_concurrent_ops_all_complete() {
        let p = 4;
        let n = 257;
        let cores = spawn_world(p);
        let mut handles: Vec<Vec<Handle>> = (0..p).map(|_| Vec::new()).collect();
        for id in 1..=20u64 {
            for (r, core) in cores.iter().enumerate() {
                let progs = allreduce_ring(p, n);
                let buf = vec![id as f32; n];
                handles[r].push(core.submit_with_handle(
                    id,
                    progs[r].clone(),
                    buf,
                    ReduceOp::Sum,
                    WireDtype::F32,
                    (id % 5) as Priority,
                ));
            }
        }
        for per_rank in handles {
            for h in per_rank {
                let id = h.id();
                let out = h.wait();
                assert!(out.iter().all(|v| *v == (p as f32) * id as f32));
            }
        }
    }

    #[test]
    fn mixed_algorithms_and_wires() {
        let p = 4;
        let n = 512;
        let cores = spawn_world(p);
        let cases = [
            (1u64, Algorithm::Ring, WireDtype::F32),
            (2, Algorithm::HalvingDoubling, WireDtype::Bf16),
            (3, Algorithm::RecursiveDoubling, WireDtype::F32),
        ];
        let mut handles: Vec<Handle> = Vec::new();
        for (id, alg, wire) in cases {
            for (r, core) in cores.iter().enumerate() {
                let progs =
                    crate::collectives::program::build(CollectiveKind::Allreduce, alg, p, n)
                        .unwrap();
                handles.push(core.submit_with_handle(
                    id,
                    progs[r].clone(),
                    vec![1.0; n],
                    ReduceOp::Sum,
                    wire,
                    0,
                ));
            }
        }
        for h in handles {
            let out = h.wait();
            for v in out {
                assert!((v - p as f32).abs() < 0.05, "{v}");
            }
        }
    }

    #[test]
    fn stats_reported_on_join() {
        let p = 2;
        let cores = spawn_world(p);
        let progs = allreduce_ring(p, 16);
        let mut handles = Vec::new();
        for (r, core) in cores.iter().enumerate() {
            handles.push(core.submit_with_handle(
                1,
                progs[r].clone(),
                vec![1.0; 16],
                ReduceOp::Sum,
                WireDtype::F32,
                0,
            ));
        }
        for h in handles {
            h.wait();
        }
        for core in cores {
            let stats = core.join();
            assert_eq!(stats.ops_completed, 1);
            assert!(stats.steps_executed >= 1);
        }
    }
}

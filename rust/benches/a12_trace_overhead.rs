//! **Ablation A12**: the deterministic trace layer (`mlsl::trace`) —
//! what observation costs, and what the critical-path analyzer says
//! about the a6-style hierarchical workload.
//!
//! The observable contract this bench ASSERTS:
//!
//! * **zero behavioral impact** — a traced p = 256 ring allreduce
//!   produces byte-identical completions, delivered messages, finish
//!   time and traffic stats to the untraced run (checked before any
//!   timing is taken);
//! * **disabled-path stability** — two interleaved min-of-N batches of
//!   the *untraced* run agree within 2% wall-clock: the trace hooks
//!   (one branch on an `Option` that is `None`) leave no measurable
//!   residue on the hot path (re-measured up to 3 times to ride out
//!   scheduler noise before failing);
//! * **bounded recording cost** — the traced run is at most 2.5x the
//!   untraced wall-clock on the same workload (it records one span per
//!   message plus busy intervals);
//! * **attribution** — on the hierarchical (a6-style) allreduce at
//!   16 MiB, the critical path's per-tier decomposition puts the
//!   majority of hop time on the inter-node tier: the leader phase is
//!   the bottleneck the paper's hierarchical analysis predicts.
//!
//! Emits `BENCH_trace_overhead.json` (repo root).
//!
//! Run: `cargo bench --bench a12_trace_overhead`

use std::time::Instant;

use mlsl::collectives::parexec::{run_collective_serial, ParOutcome};
use mlsl::collectives::program::{allreduce_hierarchical, allreduce_ring};
use mlsl::collectives::{Algorithm, WireDtype};
use mlsl::fabric::topology::Topology;
use mlsl::trace::critical::critical_path;

const REPS: usize = 7;
const RETRIES: usize = 3;

fn run_ring(topo: &Topology, p: usize, n: usize, record: bool, trace: bool) -> ParOutcome {
    run_collective_serial(
        topo,
        p,
        allreduce_ring(p, n),
        WireDtype::F32,
        1,
        None,
        record,
        trace,
    )
}

/// Min-of-`REPS` wall-clock milliseconds for one arm.
fn min_ms(topo: &Topology, p: usize, n: usize, trace: bool) -> f64 {
    let mut best = f64::MAX;
    for _ in 0..REPS {
        let t0 = Instant::now();
        let out = run_ring(topo, p, n, false, trace);
        assert!(out.finish_ns > 0);
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    best
}

fn main() {
    let topo = Topology::eth_10g();
    let (p, n) = (256usize, 64 << 10);

    // -- behavioral identity first: nothing below matters if this fails --
    let off = run_ring(&topo, p, n, true, false);
    let on = run_ring(&topo, p, n, true, true);
    assert!(off.trace.is_none(), "untraced run must not allocate a trace");
    let trace = on.trace.as_ref().expect("traced run records spans");
    assert!(trace.span_count() > 0);
    assert_eq!(on.completions, off.completions, "tracing changed completions");
    assert_eq!(on.delivered, off.delivered, "tracing changed the delivered multiset");
    assert_eq!(on.finish_ns, off.finish_ns, "tracing changed the finish time");
    assert_eq!(on.final_clock, off.final_clock);
    assert_eq!(on.stats.msgs_sent, off.stats.msgs_sent);
    assert_eq!(on.stats.bytes_sent, off.stats.bytes_sent);
    assert_eq!(on.stats.preemptions, off.stats.preemptions);
    println!(
        "identity: traced == untraced at p={p} ring ({} spans recorded, finish {} ns)",
        trace.span_count(),
        on.finish_ns
    );

    // -- disabled-path stability: interleaved A/B, min-of-{REPS} --------
    let (mut base_a, mut base_b, mut drift) = (0.0f64, 0.0f64, f64::MAX);
    for attempt in 0..RETRIES {
        base_a = min_ms(&topo, p, n, false);
        base_b = min_ms(&topo, p, n, false);
        drift = (base_a - base_b).abs() / base_a.min(base_b).max(1e-9);
        if drift <= 0.02 {
            break;
        }
        println!("  drift {:.1}% on attempt {} — re-measuring", drift * 100.0, attempt + 1);
    }
    assert!(
        drift <= 0.02,
        "disabled-path A/B drift {:.2}% > 2% after {RETRIES} attempts \
         ({base_a:.2} ms vs {base_b:.2} ms)",
        drift * 100.0
    );
    println!(
        "disabled path: {base_a:.2} ms vs {base_b:.2} ms interleaved ({:.2}% drift)",
        drift * 100.0
    );

    // -- recording cost: traced vs untraced wall-clock ------------------
    let untraced_ms = base_a.min(base_b);
    let traced_ms = min_ms(&topo, p, n, true);
    let ratio = traced_ms / untraced_ms.max(1e-9);
    println!("recording cost: {untraced_ms:.2} ms untraced, {traced_ms:.2} ms traced ({ratio:.2}x)");
    assert!(
        ratio <= 2.5,
        "traced run is {ratio:.2}x the untraced wall-clock (> 2.5x bound)"
    );

    // -- critical-path attribution on the hierarchical workload ---------
    // a6 shape: 4 ranks/node over eth10g shm tiers, 16 ranks total,
    // 16 MiB of f32 gradient — large enough that the leaders' inter-node
    // ring dominates the intra-node reduce/broadcast phases.
    let smp = Topology::by_name("eth10g-x4").expect("preset");
    let (hp, rpn) = (16usize, 4usize);
    let big_n = (16usize << 20) / 4; // 16 MiB of f32
    let hier = run_collective_serial(
        &smp,
        hp,
        allreduce_hierarchical(hp, big_n, rpn, Algorithm::Ring),
        WireDtype::F32,
        1,
        None,
        false,
        true,
    );
    let htrace = hier.trace.as_ref().expect("traced");
    let cp = critical_path(htrace, 1).expect("collective 1 leaves hops");
    print!("{}", cp.render(3));
    let inter = cp.level_share(1);
    assert!(
        inter > 0.5,
        "hierarchical 16 MiB: inter-node tier carries {:.0}% of the critical path \
         (expected the leader phase to dominate)",
        inter * 100.0
    );
    println!(
        "attribution: inter-node tier = {:.0}% of the 16 MiB hierarchical critical path",
        inter * 100.0
    );

    // -- emit BENCH_trace_overhead.json at the repo root ----------------
    let json = format!(
        "{{\n  \"bench\": \"a12_trace_overhead\",\n  \"p\": {p},\n  \"elems\": {n},\n  \
         \"spans\": {},\n  \"untraced_ms\": {untraced_ms:.3},\n  \
         \"disabled_drift_pct\": {:.3},\n  \"traced_ms\": {traced_ms:.3},\n  \
         \"traced_ratio\": {ratio:.3},\n  \"hier_inter_tier_share\": {:.3}\n}}\n",
        trace.span_count(),
        drift * 100.0,
        inter,
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_trace_overhead.json");
    std::fs::write(out, &json).expect("write BENCH_trace_overhead.json");
    println!("wrote {out}");

    println!("\nexpected shape: the disabled path is one never-taken branch per event, so");
    println!("the A/B batches are statistically identical; recording appends fixed-size");
    println!("span records (no per-event allocation beyond the buffer growth), keeping the");
    println!("traced run within a small constant of untraced; and at 16 MiB the hierarchy's");
    println!("leader ring owns the critical path, matching the selector's cost model. OK");
}

//! AlexNet (Krizhevsky 2012) layer table, 227×227 input.
//! The Das-et-al analysis paper's running example; 61M parameters,
//! fc-dominated like VGG but with far less conv compute.

use super::{conv, fc, pool, LayerDesc, ModelDesc};

/// Grouped convolution (AlexNet's two-GPU legacy): each of `groups`
/// filter groups sees only cin/groups input channels.
fn conv_grouped(name: &str, k: usize, cin: usize, cout: usize, h: usize, w: usize, groups: usize) -> LayerDesc {
    let mut l = conv(name, k, cin / groups, cout, h, w);
    l.name = name.into();
    l
}

pub fn alexnet() -> ModelDesc {
    let mut l = Vec::new();
    l.push(conv("conv1", 11, 3, 96, 55, 55));
    l.push(pool("pool1", 96 * 27 * 27, (96 * 27 * 27) as f64));
    l.push(conv_grouped("conv2", 5, 96, 256, 27, 27, 2));
    l.push(pool("pool2", 256 * 13 * 13, (256 * 13 * 13) as f64));
    l.push(conv("conv3", 3, 256, 384, 13, 13));
    l.push(conv_grouped("conv4", 3, 384, 384, 13, 13, 2));
    l.push(conv_grouped("conv5", 3, 384, 256, 13, 13, 2));
    l.push(pool("pool5", 256 * 6 * 6, (256 * 6 * 6) as f64));
    l.push(fc("fc6", 256 * 6 * 6, 4096));
    l.push(fc("fc7", 4096, 4096));
    l.push(fc("fc8", 4096, 1000));
    ModelDesc { name: "alexnet".into(), layers: l, default_batch: 64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_count_matches_paper() {
        let m = alexnet();
        let p = m.total_weight_elems() as f64;
        assert!((p - 61.0e6).abs() / 61.0e6 < 0.03, "{p}");
    }
}

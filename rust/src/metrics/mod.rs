//! Counters, timelines and CSV emission for experiments.
//!
//! The [`registry`] submodule holds the process-global named counters
//! ([`util::warn`](crate::util::warn) occurrences, tuner out-of-grid
//! clamps, probed cells, `quant.bytes_saved` — wire bytes a compressed
//! collective avoided sending vs the f32 payload …) so drills and
//! benches can assert on them without grepping stderr; [`Timeline::from_trace`] renders the
//! engine's ASCII Gantt from the trace layer's span store
//! (`docs/TRACING.md`).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::trace::{Trace, TraceEvent};
use crate::{Ns, Rank};

/// Jain's fairness index over per-entity allocations:
/// `J = (Σx)² / (n · Σx²)`. 1.0 = perfectly fair, `1/n` = one entity
/// holds everything; 1.0 by convention for empty or all-zero inputs
/// (nothing was allocated, so nothing was unfair).
pub fn jain(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n == 0 {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        return 1.0;
    }
    sum * sum / (n as f64 * sq)
}

/// Process-global named counters. Monotonic u64s behind a mutex: cheap
/// enough for warning paths and per-probe bumps, and assertable from
/// tests and the `mlsl trace` CLI without scraping stderr. Tests that
/// assert on counts should [`registry::snapshot`] before and after the
/// exercised call and compare deltas — the registry is shared across
/// the whole process.
pub mod registry {
    use std::collections::BTreeMap;
    use std::sync::{Mutex, OnceLock};

    fn cell() -> &'static Mutex<BTreeMap<String, u64>> {
        static REGISTRY: OnceLock<Mutex<BTreeMap<String, u64>>> = OnceLock::new();
        REGISTRY.get_or_init(|| Mutex::new(BTreeMap::new()))
    }

    /// Add `v` to counter `key` (created at 0).
    pub fn add(key: &str, v: u64) {
        let mut map = cell().lock().expect("metrics registry poisoned");
        *map.entry(key.to_string()).or_insert(0) += v;
    }

    /// Increment counter `key` by one.
    pub fn inc(key: &str) {
        add(key, 1);
    }

    /// Current value of `key` (0 if never touched).
    pub fn get(key: &str) -> u64 {
        cell().lock().expect("metrics registry poisoned").get(key).copied().unwrap_or(0)
    }

    /// Sorted copy of every counter.
    pub fn snapshot() -> Vec<(String, u64)> {
        cell()
            .lock()
            .expect("metrics registry poisoned")
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    #[cfg(test)]
    mod tests {
        #[test]
        fn registry_counts_and_snapshots() {
            // Delta-based: other tests in the process share the registry.
            let key = "metrics.registry.selftest";
            let before = super::get(key);
            super::inc(key);
            super::add(key, 2);
            assert_eq!(super::get(key), before + 3);
            assert!(super::snapshot().iter().any(|(k, v)| k == key && *v >= 3));
        }
    }
}

/// Named floating counters.
#[derive(Debug, Default, Clone)]
pub struct Counters {
    map: BTreeMap<String, f64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, key: &str, v: f64) {
        *self.map.entry(key.to_string()).or_insert(0.0) += v;
    }

    pub fn inc(&mut self, key: &str) {
        self.add(key, 1.0);
    }

    pub fn get(&self, key: &str) -> f64 {
        self.map.get(key).copied().unwrap_or(0.0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.map.iter().map(|(k, v)| (k.as_str(), *v))
    }
}

/// A recorded interval on some node's timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub node: Rank,
    pub start: Ns,
    pub end: Ns,
    pub track: String, // "compute" | "comm" | custom
    pub label: String,
}

/// Event-interval recorder with an ASCII Gantt renderer (used by the
/// priority_timeline example to *show* preemption happening).
#[derive(Debug, Default, Clone)]
pub struct Timeline {
    pub spans: Vec<Span>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, node: Rank, start: Ns, end: Ns, track: &str, label: &str) {
        self.spans.push(Span {
            node,
            start,
            end: end.max(start),
            track: track.to_string(),
            label: label.to_string(),
        });
    }

    pub fn end_time(&self) -> Ns {
        self.spans.iter().map(|s| s.end).max().unwrap_or(0)
    }

    /// Build a renderable timeline from a recorded [`Trace`]: compute
    /// spans whose `(node, tag)` the `labeler` names go on the
    /// `"compute"` track, and every [`TraceEvent::Mark`] becomes an
    /// instant span on its own track. This is how the engine's ASCII
    /// Gantt is derived from the span store instead of a parallel
    /// recording path.
    pub fn from_trace(
        trace: &Trace,
        labeler: impl Fn(Rank, u64) -> Option<String>,
    ) -> Timeline {
        let mut tl = Timeline::new();
        for ev in &trace.events {
            match ev {
                TraceEvent::Compute(c) => {
                    if let Some(label) = labeler(c.node, c.tag) {
                        tl.record(c.node, c.start, c.end, "compute", &label);
                    }
                }
                TraceEvent::Mark { node, at, track, label } => {
                    tl.record(*node, *at, *at, track, label);
                }
                _ => {}
            }
        }
        tl
    }

    /// Render one row per (node, track) with `width` columns.
    pub fn ascii_gantt(&self, width: usize) -> String {
        let t_end = self.end_time().max(1);
        let mut rows: BTreeMap<(Rank, String), Vec<char>> = BTreeMap::new();
        for s in &self.spans {
            let row = rows
                .entry((s.node, s.track.clone()))
                .or_insert_with(|| vec!['.'; width]);
            let a = (s.start as u128 * width as u128 / t_end as u128) as usize;
            let b = ((s.end as u128 * width as u128).div_ceil(t_end as u128) as usize).min(width);
            let c = s.label.chars().next().unwrap_or('#');
            for cell in row.iter_mut().take(b).skip(a) {
                *cell = c;
            }
        }
        let mut out = String::new();
        for ((node, track), row) in rows {
            out.push_str(&format!("node{node:<3} {track:<8} |"));
            out.extend(row);
            out.push_str("|\n");
        }
        out.push_str(&format!(
            "scale: full width = {}\n",
            crate::util::stats::fmt_ns(t_end)
        ));
        out
    }

    /// Write spans as CSV (node,start_ns,end_ns,track,label).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "node,start_ns,end_ns,track,label")?;
        for s in &self.spans {
            writeln!(f, "{},{},{},{},{}", s.node, s.start, s.end, s.track, s.label)?;
        }
        Ok(())
    }
}

/// Write a generic CSV table.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for r in rows {
        writeln!(f, "{}", r.join(","))?;
    }
    Ok(())
}

/// Markdown-ish table printer shared by the bench harnesses.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    println!("| {} |", header.join(" | "));
    println!("|{}|", header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for r in rows {
        println!("| {} |", r.join(" | "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut c = Counters::new();
        c.inc("x");
        c.add("x", 2.0);
        c.add("y", 0.5);
        assert_eq!(c.get("x"), 3.0);
        assert_eq!(c.get("y"), 0.5);
        assert_eq!(c.get("absent"), 0.0);
    }

    #[test]
    fn gantt_renders_spans() {
        let mut t = Timeline::new();
        t.record(0, 0, 50, "compute", "fwd");
        t.record(0, 50, 100, "comm", "grad");
        let g = t.ascii_gantt(20);
        assert!(g.contains("node0"));
        assert!(g.contains("compute"));
        assert!(g.contains("ffffffffff"));
        assert!(g.contains("gggggggggg"));
    }

    #[test]
    fn csv_output() {
        // Unique per-process dir so concurrent test runs never collide;
        // removed on success (left behind on assert failure for triage).
        let dir =
            std::env::temp_dir().join(format!("mlsl_test_csv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let mut t = Timeline::new();
        t.record(1, 10, 20, "comm", "x");
        t.write_csv(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("1,10,20,comm,x"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn timeline_derives_from_trace_spans() {
        use crate::trace::ComputeSpan;
        let tr = Trace {
            events: vec![
                TraceEvent::Compute(ComputeSpan {
                    node: 0,
                    start: 0,
                    end: 50,
                    tag: (1 << 32) | 3,
                    cause: None,
                }),
                TraceEvent::Compute(ComputeSpan {
                    node: 1,
                    start: 0,
                    end: 50,
                    tag: (1 << 32) | 3,
                    cause: None,
                }),
                TraceEvent::Mark {
                    node: 0,
                    at: 60,
                    track: "issue".into(),
                    label: "g3".into(),
                },
            ],
        };
        let tl = Timeline::from_trace(&tr, |node, tag| {
            (node == 0 && tag >> 32 == 1).then(|| format!("f{}", tag & 0xffff_ffff))
        });
        assert_eq!(tl.spans.len(), 2, "unlabeled nodes are skipped");
        assert_eq!(tl.spans[0].label, "f3");
        assert_eq!(tl.spans[0].track, "compute");
        assert_eq!((tl.spans[1].start, tl.spans[1].end), (60, 60));
        assert_eq!(tl.spans[1].track, "issue");
    }

    #[test]
    fn jain_index_brackets_fairness() {
        assert_eq!(jain(&[]), 1.0);
        assert_eq!(jain(&[0.0, 0.0]), 1.0);
        assert!((jain(&[5.0, 5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12, "equal shares");
        assert!((jain(&[1.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12, "one hog → 1/n");
        let mid = jain(&[3.0, 1.0]);
        assert!(mid > 0.5 && mid < 1.0, "{mid}");
    }
}

"""Model presets for the mlsl-rs Transformer LM.

`small` is the end-to-end default (fits a few hundred CPU training steps
in minutes); `base100m` is the paper-scale configuration (compile-path
validated; training it on this CPU-only image is impractical and the
substitution is recorded in DESIGN.md / EXPERIMENTS.md).
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    batch: int  # per-rank micro-batch

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


PRESETS = {
    "tiny": ModelConfig("tiny", vocab=512, d_model=64, n_layers=2, n_heads=2,
                        seq_len=32, batch=4),
    "small": ModelConfig("small", vocab=4096, d_model=256, n_layers=4, n_heads=4,
                         seq_len=128, batch=8),
    "medium": ModelConfig("medium", vocab=16384, d_model=512, n_layers=6, n_heads=8,
                          seq_len=128, batch=8),
    "base100m": ModelConfig("base100m", vocab=32768, d_model=768, n_layers=12,
                            n_heads=12, seq_len=256, batch=8),
}


def n_params(cfg: ModelConfig) -> int:
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    per_block = 4 * d * d + d * f + f + f * d + d + 4 * d  # attn + mlp + 2 LN
    return v * d + s * d + cfg.n_layers * per_block + 2 * d + d * v

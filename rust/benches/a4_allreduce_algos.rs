//! **Ablation A4**: allreduce algorithm selection ("implements
//! performance critical data path operations in an optimal manner").
//!
//! Sweeps message size × rank count × fabric for ring / recursive
//! doubling / halving-doubling, prints the measured (simulated) times,
//! what `Auto` picks, and where the crossovers fall.
//!
//! Run: `cargo bench --bench a4_allreduce_algos`

use mlsl::collectives::program::build;
use mlsl::collectives::simexec::time_collective;
use mlsl::collectives::{choose_algorithm, Algorithm, CollectiveKind, WireDtype};
use mlsl::fabric::topology::Topology;
use mlsl::fabric::NetSim;
use mlsl::metrics::print_table;
use mlsl::util::stats::fmt_bytes;

fn main() {
    let sizes: [u64; 7] = [1 << 10, 16 << 10, 256 << 10, 1 << 20, 16 << 20, 64 << 20, 256 << 20];
    for topo in [Topology::eth_10g(), Topology::omnipath_100g()] {
        for p in [16usize, 64] {
            let mut rows = Vec::new();
            for bytes in sizes {
                let n = (bytes / 4) as usize;
                let mut times = Vec::new();
                for alg in [Algorithm::Ring, Algorithm::RecursiveDoubling, Algorithm::HalvingDoubling] {
                    let mut sim = NetSim::new(topo.clone(), p);
                    let t = time_collective(
                        &mut sim,
                        build(CollectiveKind::Allreduce, alg, p, n).unwrap(),
                        WireDtype::F32,
                        1,
                    );
                    times.push(t);
                }
                let auto = choose_algorithm(&topo, p, bytes);
                let best = [Algorithm::Ring, Algorithm::RecursiveDoubling, Algorithm::HalvingDoubling]
                    [times.iter().enumerate().min_by_key(|(_, t)| **t).unwrap().0];
                rows.push(vec![
                    fmt_bytes(bytes),
                    format!("{:.3}", times[0] as f64 / 1e6),
                    format!("{:.3}", times[1] as f64 / 1e6),
                    format!("{:.3}", times[2] as f64 / 1e6),
                    auto.to_string(),
                    best.to_string(),
                ]);
            }
            print_table(
                &format!("A4: allreduce algorithms, {} nodes, {}", p, topo.name),
                &["size", "ring ms", "rdoubling ms", "halving ms", "auto picks", "measured best"],
                &rows,
            );
        }
    }
    println!("\nexpected shape: rdoubling wins small sizes (latency, log2(p) rounds),");
    println!("ring/halving win large sizes (bandwidth-optimal); `auto` should track");
    println!("the measured best across the crossover.");
}

//! Quickstart: the two MLSL interfaces in ~60 lines.
//!
//! 1. The **Collectives API** — spin up 4 in-process ranks, allreduce a
//!    gradient buffer with priorities through each rank's dedicated comm
//!    core (the paper's async-progress design).
//! 2. The **DL Layer API** — register ResNet-50 with a `Session` and let
//!    the library derive which communication every layer needs under data
//!    / hybrid parallelism.
//!
//! Run: `cargo run --release --example quickstart`

use std::thread;

use mlsl::collectives::{Algorithm, WireDtype};
use mlsl::mlsl::{Communicator, Distribution, Session};
use mlsl::models::ModelDesc;

fn main() {
    // ------------------------------------------------------------------
    // 1. Collectives API
    // ------------------------------------------------------------------
    let p = 4;
    let comms = Communicator::world(p);
    let handles: Vec<_> = comms
        .into_iter()
        .map(|comm| {
            thread::spawn(move || {
                let rank = comm.rank();
                // A "bulk" low-priority op and an "urgent" first-layer op
                // in flight together; the comm core serves the urgent one
                // first (message prioritization).
                let bulk = comm.allreduce_async(
                    vec![rank as f32; 1 << 20],
                    Algorithm::Auto,
                    WireDtype::F32,
                    200, // low priority
                );
                let urgent = comm.allreduce_async(
                    vec![1.0; 1024],
                    Algorithm::Auto,
                    WireDtype::F32,
                    0, // most urgent
                );
                let u = urgent.wait();
                assert_eq!(u[0], p as f32);
                let b = bulk.wait();
                assert_eq!(b[0], (0..p).map(|r| r as f32).sum::<f32>());
                if rank == 0 {
                    println!("[collectives] urgent + bulk allreduce complete on {p} ranks");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // ------------------------------------------------------------------
    // 2. DL Layer API
    // ------------------------------------------------------------------
    let model = ModelDesc::by_name("resnet50").unwrap();
    for group in [1usize, 4] {
        let mut session = Session::new(Distribution::new(64, group));
        session.add_model(&model);
        let reqs = session.iteration_comms(32);
        let grad_ops = reqs
            .iter()
            .filter(|r| matches!(r.kind, mlsl::collectives::CollectiveKind::Allreduce))
            .count();
        let act_ops = reqs.len() - grad_ops;
        println!(
            "[dl-layer]   64 nodes, group={group}: {grad_ops} gradient allreduces + \
             {act_ops} activation exchanges per iteration"
        );
        // First layer's gradient is the most urgent class — the paper's
        // prioritization rule, derived automatically.
        if let Some(first) = reqs.iter().min_by_key(|r| session.op(r.op_id).fwd_order) {
            println!(
                "[dl-layer]   most urgent gradient: {} (priority {})",
                session.op(first.op_id).name,
                first.priority
            );
        }
    }
}

//! Small numeric helpers: summary statistics and human formatting used by
//! the benches and metrics reporting.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (linear interpolation), p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = (p / 100.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (idx - lo as f64)
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Format a byte count human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let bf = b as f64;
    if bf < KIB {
        format!("{b} B")
    } else if bf < KIB * KIB {
        format!("{:.1} KiB", bf / KIB)
    } else if bf < KIB * KIB * KIB {
        format!("{:.1} MiB", bf / KIB / KIB)
    } else {
        format!("{:.2} GiB", bf / KIB / KIB / KIB)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138).abs() < 0.01);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500), "500 ns");
        assert_eq!(fmt_ns(1_500), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000), "2.50 ms");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.0 MiB");
    }
}

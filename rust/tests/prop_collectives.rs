//! Randomized property tests over the collectives layer (the in-tree
//! `util::proptest` harness replaces the proptest crate: offline image).
//!
//! Invariants:
//! * every algorithm × (p, n) is algebraically correct (symbolic executor);
//! * ring/halving programs are bandwidth-optimal;
//! * real threaded execution matches the f64 reference reduction;
//! * wire round-trips respect the dtype error bounds.

use mlsl::collectives::program::{self, CollectiveKind};
use mlsl::collectives::{quant, verify, Algorithm, ReduceOp, WireDtype};
use mlsl::util::prng::Prng;
use mlsl::util::proptest::{run, Config};

#[test]
fn prop_ring_allreduce_correct_any_p_n() {
    run(
        Config { cases: 120, seed: 11 },
        |r| (1 + r.usize_below(12), 1 + r.usize_below(200)),
        |&(p, n)| verify::verify(CollectiveKind::Allreduce, Algorithm::Ring, p, n),
    );
}

#[test]
fn prop_pow2_algorithms_correct() {
    run(
        Config { cases: 80, seed: 12 },
        |r| (1usize << r.usize_below(6), 1 + r.usize_below(300), r.below(2)),
        |&(p, n, which)| {
            let alg = if which == 0 { Algorithm::RecursiveDoubling } else { Algorithm::HalvingDoubling };
            verify::verify(CollectiveKind::Allreduce, alg, p, n)
        },
    );
}

#[test]
fn prop_all_collective_kinds_correct() {
    run(
        Config { cases: 100, seed: 13 },
        |r| {
            let p = 1 + r.usize_below(9);
            let n = 1 + r.usize_below(64);
            let root = r.usize_below(p);
            let kind = match r.below(4) {
                0 => CollectiveKind::ReduceScatter,
                1 => CollectiveKind::Allgather,
                2 => CollectiveKind::Broadcast { root },
                _ => CollectiveKind::Reduce { root },
            };
            (kind, p, n)
        },
        |&(kind, p, n)| verify::verify(kind, Algorithm::Ring, p, n),
    );
}

#[test]
fn prop_ring_is_bandwidth_optimal() {
    run(
        Config { cases: 60, seed: 14 },
        |r| (2 + r.usize_below(14), 16 + r.usize_below(4000)),
        |&(p, n)| {
            for prog in program::allreduce_ring(p, n) {
                let sent: usize = prog
                    .steps
                    .iter()
                    .filter_map(|s| s.send.map(|x| x.range.len))
                    .sum();
                // Ring sends sum_over_steps seg sizes; with exact integer
                // segments this is within one segment of 2(p-1)n/p.
                let ideal = 2 * (p - 1) * n / p;
                let seg_max = n.div_ceil(p);
                if sent > ideal + 2 * seg_max {
                    return Err(format!("p={p} n={n}: sent {sent} vs ideal {ideal}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_threaded_execution_matches_reference() {
    run(
        Config { cases: 25, seed: 15 },
        |r| {
            let p = 2 + r.usize_below(5);
            let n = 1 + r.usize_below(500);
            let alg = if p.is_power_of_two() && r.below(2) == 0 {
                Algorithm::HalvingDoubling
            } else {
                Algorithm::Ring
            };
            let seed = r.next_u64();
            (p, n, alg, seed)
        },
        |&(p, n, alg, seed)| {
            let inputs: Vec<Vec<f32>> = (0..p)
                .map(|rank| {
                    let mut rng = Prng::seed(seed ^ rank as u64);
                    (0..n).map(|_| rng.range_f32(-2.0, 2.0)).collect()
                })
                .collect();
            let want: Vec<f32> = (0..n)
                .map(|i| inputs.iter().map(|b| b[i] as f64).sum::<f64>() as f32)
                .collect();

            let eps = mlsl::fabric::shm::fabric(p);
            let programs = program::build(CollectiveKind::Allreduce, alg, p, n).unwrap();
            let handles: Vec<_> = eps
                .into_iter()
                .zip(programs)
                .zip(inputs)
                .map(|((mut ep, prog), mut buf)| {
                    std::thread::spawn(move || {
                        mlsl::collectives::exec::execute(
                            &mut ep, 7, &prog, &mut buf, ReduceOp::Sum, WireDtype::F32,
                        );
                        buf
                    })
                })
                .collect();
            for h in handles {
                let got = h.join().unwrap();
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    if (g - w).abs() > 1e-3 * w.abs().max(1.0) {
                        return Err(format!("elem {i}: {g} vs {w}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_wire_roundtrip_error_bounds() {
    run(
        Config { cases: 120, seed: 16 },
        |r| {
            let n = 1 + r.usize_below(2000);
            let scale = (10.0f64).powf(r.f64() * 6.0 - 3.0) as f32;
            let seed = r.next_u64();
            (n, scale, seed)
        },
        |&(n, scale, seed)| {
            let mut rng = Prng::seed(seed);
            let src: Vec<f32> = (0..n).map(|_| rng.range_f32(-scale, scale)).collect();
            for wire in [WireDtype::F32, WireDtype::Bf16, WireDtype::Int8Block] {
                let bytes = quant::encode(&src, wire);
                if bytes.len() != wire.wire_bytes(n) {
                    return Err(format!("{wire}: wire size"));
                }
                let back = quant::decode(&bytes, n, wire);
                let bound = quant::max_roundtrip_error(&src, wire);
                for (i, (a, b)) in src.iter().zip(&back).enumerate() {
                    if (a - b).abs() > bound + scale * 1e-6 {
                        return Err(format!("{wire} elem {i}: {a} vs {b} (bound {bound})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_segments_partition_exactly() {
    run(
        Config { cases: 200, seed: 17 },
        |r| (1 + r.usize_below(64), r.usize_below(100_000)),
        |&(p, n)| {
            let seg = program::segments(n, p);
            if seg.len() != p + 1 || seg[0] != 0 || seg[p] != n {
                return Err(format!("bad bounds {seg:?}"));
            }
            for w in seg.windows(2) {
                if w[1] < w[0] {
                    return Err("non-monotone".into());
                }
                // Balance: every segment within 1 of n/p.
                if (w[1] - w[0]) as i64 - (n / p) as i64 > 1 {
                    return Err(format!("unbalanced: {}", w[1] - w[0]));
                }
            }
            Ok(())
        },
    );
}

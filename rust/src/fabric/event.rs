//! Deterministic min-heap event queue for the discrete-event simulator.
//!
//! Ties in time are broken by insertion sequence, so a simulation is a
//! pure function of its inputs — the property the proptest suite leans on.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::Ns;

/// An event queue entry: fires at `at`, FIFO among equal times.
struct Entry<E> {
    at: Ns,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// Monotonic event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: Ns,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0, now: 0 }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> Ns {
        self.now
    }

    /// Schedule `ev` at absolute time `at` (clamped to now: the simulator
    /// never schedules into the past).
    pub fn push_at(&mut self, at: Ns, ev: E) -> u64 {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { at: at.max(self.now), seq, ev });
        seq
    }

    /// Schedule `ev` `delay` ns from now.
    pub fn push_in(&mut self, delay: Ns, ev: E) -> u64 {
        self.push_at(self.now.saturating_add(delay), ev)
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Ns, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.at >= self.now, "time went backwards");
        self.now = e.at;
        Some((e.at, e.ev))
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<Ns> {
        self.heap.peek().map(|e| e.at)
    }

    /// Fast-forward the clock of an idle queue to `at` (no-op when the
    /// clock is already past it). Used by batched drivers that post work
    /// at absolute times: events pushed afterwards with `push_in` are
    /// relative to the new clock. Callers must not skip over pending
    /// events — the `NetSim` wrapper asserts that.
    pub fn advance_to(&mut self, at: Ns) {
        self.now = self.now.max(at);
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(30, "c");
        q.push_at(10, "a");
        q.push_at(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn equal_times_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push_at(5, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((5, i)));
        }
    }

    #[test]
    fn clock_is_monotonic_and_clamps() {
        let mut q = EventQueue::new();
        q.push_at(100, "late");
        assert_eq!(q.pop(), Some((100, "late")));
        // Scheduling "at 50" after the clock reached 100 clamps to 100.
        q.push_at(50, "early");
        assert_eq!(q.pop(), Some((100, "early")));
        assert_eq!(q.now(), 100);
    }

    #[test]
    fn advance_to_fast_forwards_but_never_rewinds() {
        let mut q: EventQueue<&str> = EventQueue::new();
        q.advance_to(500);
        assert_eq!(q.now(), 500);
        q.advance_to(100); // no rewind
        assert_eq!(q.now(), 500);
        q.push_in(5, "z");
        assert_eq!(q.pop(), Some((505, "z")));
    }

    #[test]
    fn push_in_is_relative() {
        let mut q = EventQueue::new();
        q.push_at(40, "x");
        q.pop();
        q.push_in(5, "y");
        assert_eq!(q.pop(), Some((45, "y")));
    }
}

//! **Ablation A7**: tuned (measured) collective selection.
//!
//! Builds a tuning table per fabric preset with the probe, then asserts
//! two bounds:
//!
//! 1. **grid replay** (the acceptance criterion): the tuned policy's
//!    pick matches the measured-best algorithm in ≥ 90% of grid cells
//!    and is never > 5% slower than the measured best in any cell;
//! 2. **holdout replay** (the bound that can actually fail): at the
//!    geometric MIDPOINT of every adjacent size pair — sizes the table
//!    never measured — the pick's freshly simulated time stays within
//!    30% of the freshly simulated best, exercising rank-row snapping,
//!    log interpolation and the legality fallback off-grid.
//!
//! Prints, per preset, how often the analytic model would have agreed
//! with the measurements — the gap is exactly what the tuner buys.
//!
//! Run: `cargo bench --bench a7_tuned_selection`

use mlsl::collectives::program::CollectiveKind;
use mlsl::fabric::topology::Topology;
use mlsl::metrics::print_table;
use mlsl::tuner::{probe, ProbeSpec, SelectionPolicy};
use mlsl::util::stats::fmt_bytes;

fn main() {
    let mut spec = ProbeSpec::quick();
    spec.max_ranks = 32;
    spec.max_bytes = 16 << 20;
    spec.size_points = 6;
    let mut rows = Vec::new();
    for topo in [
        Topology::eth_10g(),
        Topology::eth_10g_smp(2),
        Topology::omnipath_100g(),
        Topology::omnipath_100g_smp(4),
    ] {
        let table = probe::tune(&topo, &spec);
        let policy = SelectionPolicy::TunedWithFallback(table.clone());
        let (mut total, mut matched, mut analytic_matched) = (0usize, 0usize, 0usize);
        let mut worst = 1.0f64;
        for kind in probe::TUNED_KINDS {
            for cell in table.cells(kind) {
                let (best, best_ns) = cell.best().expect("probed cells are non-empty");
                let pick = match kind {
                    CollectiveKind::Allreduce => {
                        policy.choose_allreduce(&topo, cell.ranks, cell.bytes)
                    }
                    _ => policy.choose_allgather(&topo, cell.ranks, cell.bytes),
                };
                let pick_ns = cell.time_of(pick).expect("picks come from measured candidates");
                let slow = pick_ns as f64 / best_ns.max(1) as f64;
                assert!(
                    slow <= 1.05,
                    "{} {kind:?} p={} {}: tuned pick {pick} is {slow:.3}x the measured best {best}",
                    topo.name,
                    cell.ranks,
                    fmt_bytes(cell.bytes),
                );
                total += 1;
                if pick == best {
                    matched += 1;
                }
                worst = worst.max(slow);
                let analytic = match kind {
                    CollectiveKind::Allreduce => {
                        SelectionPolicy::Analytic.choose_allreduce(&topo, cell.ranks, cell.bytes)
                    }
                    _ => SelectionPolicy::Analytic.choose_allgather(&topo, cell.ranks, cell.bytes),
                };
                if analytic == best {
                    analytic_matched += 1;
                }
            }
        }
        let pct = 100.0 * matched as f64 / total.max(1) as f64;
        assert!(
            pct >= 90.0,
            "{}: tuned pick matched the measured best in only {pct:.1}% of {total} cells",
            topo.name
        );

        // Holdout replay: interpolated picks at never-measured sizes.
        let mut holdout_worst = 1.0f64;
        let mut holdouts = 0usize;
        for kind in probe::TUNED_KINDS {
            for p in table.rank_rows(kind) {
                let sizes: Vec<u64> = table
                    .cells(kind)
                    .iter()
                    .filter(|c| c.ranks == p)
                    .map(|c| c.bytes)
                    .collect();
                for w in sizes.windows(2) {
                    let mid = ((w[0] as f64 * w[1] as f64).sqrt()).round() as u64;
                    let pick = match kind {
                        CollectiveKind::Allreduce => policy.choose_allreduce(&topo, p, mid),
                        _ => policy.choose_allgather(&topo, p, mid),
                    };
                    let fresh: Vec<(mlsl::collectives::Algorithm, u64)> =
                        probe::probe_candidates(&topo, kind, p)
                            .into_iter()
                            .map(|a| (a, probe::measure_ns(&topo, kind, a, p, mid)))
                            .collect();
                    let best = fresh.iter().map(|(_, t)| *t).min().expect("non-empty");
                    let pick_ns = fresh
                        .iter()
                        .find(|(a, _)| *a == pick)
                        .map(|(_, t)| *t)
                        .expect("pick comes from the candidate set");
                    let slow = pick_ns as f64 / best.max(1) as f64;
                    assert!(
                        slow <= 1.30,
                        "{} {kind:?} p={p} holdout {}: pick {pick} is {slow:.3}x fresh best",
                        topo.name,
                        fmt_bytes(mid),
                    );
                    holdout_worst = holdout_worst.max(slow);
                    holdouts += 1;
                }
            }
        }

        rows.push(vec![
            topo.name.clone(),
            total.to_string(),
            format!("{pct:.1}%"),
            format!("{:.1}%", 100.0 * analytic_matched as f64 / total.max(1) as f64),
            format!("{worst:.3}x"),
            format!("{holdout_worst:.3}x ({holdouts})"),
        ]);
    }
    print_table(
        "A7: tuned selection vs measured best (grid + holdout replay)",
        &[
            "fabric",
            "cells",
            "tuned match",
            "analytic match",
            "grid worst-case",
            "holdout worst-case",
        ],
        &rows,
    );
    println!("\nacceptance: tuned match >= 90% per fabric, grid worst-case <= 1.05x, and");
    println!("interpolated holdout (midpoint) picks <= 1.30x the fresh best (all asserted).");
    println!("the analytic column is the closed-form model scored against the same");
    println!("measurements — the shortfall is what measurement-driven selection buys.");
}

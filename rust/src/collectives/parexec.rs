//! Partitioned parallel execution of simulated workloads: conservative
//! PDES over a fleet of [`NetSim`] shards.
//!
//! # How it works
//!
//! The fabric is split into `shards` node-contiguous sub-simulators
//! ([`NetSim::new_partition`] / [`crate::fabric::par::shard_of`]), each
//! with its own event heap. The coordinator repeatedly:
//!
//! 1. takes the fleet minimum of [`NetSim::next_event_time`] (`w_min`),
//! 2. lets every shard execute all local events strictly before
//!    `w_min + lookahead` ([`NetSim::next_before`]) — in parallel on
//!    scoped worker threads when `threads > 1`,
//! 3. drains each shard's cross-partition outbox ([`NetSim::take_mail`]),
//!    sorts the mail deterministically
//!    ([`crate::fabric::par::mail_key`]) and injects every message into
//!    its destination shard ([`NetSim::inject_delivery`]).
//!
//! `lookahead` is [`Topology::lookahead_ns`]: a cross-shard hop always
//! rides a NIC tier (nodes are never split), so a message produced by an
//! event at time `t ≥ w_min` is delivered at
//! `t + latency ≥ w_min + lookahead` — never inside any shard's past.
//! That makes the windowed run *exact*, not approximate: for a
//! single-collective (uniform-priority) workload the fleet produces the
//! byte-identical delivered-message multiset, identical completion
//! timestamps, identical final clocks and identical chaos fault counters
//! as the serial simulator — `tests/prop_parallel.rs` proves it shape by
//! shape, and the `a11_parallel_sim` bench demonstrates the speedup.
//!
//! # Why the engine's driver loop is NOT partitioned
//!
//! The engine ([`crate::engine`]) posts a collective at the instant its
//! *last* member reaches the issue point and releases churn holds the
//! same way: a zero-latency coupling from one rank's event to sends on
//! *every* rank. Conservative PDES requires strictly positive lookahead
//! on every cross-partition dependency, so those barriers cannot be
//! windowed without rollback (optimistic PDES), which is out of scope.
//! The engine therefore keeps its exact serial loop at any
//! `--sim-threads` setting, while everything underneath it that is
//! barrier-free parallelizes: standalone collective timing (this
//! module) and tuning-grid probing ([`crate::tuner::probe`]). Mixed-
//! priority multi-collective workloads have the same caveat — FIFO
//! order *within* one priority class on one NIC is only reproduced
//! exactly for uniform-priority workloads, which is exactly what the
//! tuner and the benches time. See `docs/ARCHITECTURE.md` §"Partitioned
//! mode" for the full argument.

use super::program::Program;
use super::simexec::{Completion, SimCollectives};
use super::WireDtype;
use crate::fabric::par::{mail_key, shard_of, Mail};
use crate::fabric::sim::{ChaosPlan, ChaosStats, SimStats};
use crate::fabric::topology::Topology;
use crate::fabric::{MsgDesc, NetSim, SimEvent};
use crate::trace::Trace;
use crate::{Ns, Priority, Rank};

/// Collective id `run_collective` posts under (single-workload runs).
const COLL_ID: u64 = 1;

/// Fleet shape for a partitioned run.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Sub-simulators the fabric is split into (1 = a single shard,
    /// which reproduces the serial pop sequence trivially).
    pub shards: usize,
    /// Worker threads driving the shards inside each window (1 = step
    /// the shards sequentially; results are identical either way).
    pub threads: usize,
    /// Fault schedule installed on every shard (each shard applies the
    /// owned-rank subset of the plan; see [`NetSim::set_chaos`]).
    pub chaos: Option<ChaosPlan>,
    /// Record every `MsgDelivered` into [`ParOutcome::delivered`]
    /// (memory ∝ message count — equivalence tests only).
    pub record_deliveries: bool,
    /// Enable span tracing on every shard; per-shard buffers are merged
    /// into [`ParOutcome::trace`], byte-identical to the serial run's
    /// normalized trace (see `docs/TRACING.md`).
    pub trace: bool,
}

impl FleetConfig {
    /// `threads` workers over `threads` shards, nothing recorded.
    pub fn threaded(threads: usize) -> Self {
        let t = threads.max(1);
        Self { shards: t, threads: t, chaos: None, record_deliveries: false, trace: false }
    }
}

/// Everything a partitioned run produces, aggregated over the fleet.
#[derive(Debug, Clone)]
pub struct ParOutcome {
    /// Time the workload finished: max completion / recorded-delivery
    /// timestamp (0 for an empty workload).
    pub finish_ns: Ns,
    /// Max shard clock after the full drain — includes trailing chaos
    /// windows, so it is comparable with a drained serial run.
    pub final_clock: Ns,
    /// Per-rank completions, sorted by `(at, rank)`; one per rank for a
    /// collective run, empty for pattern runs.
    pub completions: Vec<Completion>,
    /// Delivered-message multiset, sorted; only filled when
    /// [`FleetConfig::record_deliveries`] is set.
    pub delivered: Vec<(MsgDesc, Ns)>,
    /// Fleet-summed traffic stats (equal to the serial run's).
    pub stats: SimStats,
    /// Fleet-aggregated fault counters (equal to the serial run's).
    pub chaos: ChaosStats,
    /// Merged, normalized trace; only filled when [`FleetConfig::trace`]
    /// is set.
    pub trace: Option<Trace>,
}

/// One shard's reactive workload: posts initial work, then reacts to
/// the events its shard surfaces.
pub trait ShardDriver: Send {
    fn start(&mut self, sim: &mut NetSim);
    fn on_event(&mut self, sim: &mut NetSim, ev: SimEvent);
}

/// Lookahead actually safe under `chaos`: [`Topology::lookahead_ns`]
/// scaled down by any sub-healthy latency multiplier a hand-built plan
/// might carry ([`ChaosPlan::generate`] never shrinks latency, so the
/// scale is 1 for generated plans). Never below 1 ns — the window must
/// make progress.
pub fn effective_lookahead(topo: &Topology, chaos: Option<&ChaosPlan>) -> Ns {
    let mut scale_milli = 1000u64;
    if let Some(plan) = chaos {
        for f in plan.flaps.iter().filter(|f| !f.zero_bw && f.latency_mult_milli < 1000) {
            scale_milli = scale_milli * f.latency_mult_milli / 1000;
        }
    }
    (topo.lookahead_ns().saturating_mul(scale_milli) / 1000).max(1)
}

/// The coordinator: run every shard to quiescence under conservative-
/// lookahead windows, routing cross-partition mail at window boundaries.
pub fn run_fleet<D: ShardDriver>(
    shards: &mut [NetSim],
    drivers: &mut [D],
    lookahead: Ns,
    threads: usize,
) {
    assert_eq!(shards.len(), drivers.len());
    let topo = shards[0].topology().clone();
    let p = shards[0].num_nodes();
    let nshards = shards.len();
    for (sim, drv) in shards.iter_mut().zip(drivers.iter_mut()) {
        drv.start(sim);
    }
    loop {
        // Window base: the earliest pending event fleet-wide. All
        // outboxes are empty here (mail is routed before re-entering the
        // loop), so an empty fleet queue means the run is complete.
        let Some(w_min) = shards.iter().filter_map(|s| s.next_event_time()).min() else {
            break;
        };
        let horizon = w_min.saturating_add(lookahead.max(1));
        let mut mail: Vec<Mail> = Vec::new();
        if threads > 1 && nshards > 1 {
            // One scoped worker per shard: each owns a disjoint
            // (&mut NetSim, &mut D) pair, so the shards advance truly
            // concurrently; the join is the window barrier.
            let batches: Vec<Vec<Mail>> = std::thread::scope(|scope| {
                let handles: Vec<_> = shards
                    .iter_mut()
                    .zip(drivers.iter_mut())
                    .map(|(sim, drv)| {
                        scope.spawn(move || {
                            while let Some(ev) = sim.next_before(horizon) {
                                drv.on_event(sim, ev);
                            }
                            sim.take_mail()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
            });
            for b in batches {
                mail.extend(b);
            }
        } else {
            for (sim, drv) in shards.iter_mut().zip(drivers.iter_mut()) {
                while let Some(ev) = sim.next_before(horizon) {
                    drv.on_event(sim, ev);
                }
                mail.extend(sim.take_mail());
            }
        }
        // Deterministic routing: the injection order is a pure function
        // of the mail set, never of shard iteration or thread timing.
        // Lookahead guarantees m.at >= horizon > every shard clock, so
        // injection never lands in a shard's past.
        mail.sort_by_key(mail_key);
        for m in mail {
            let dst = shard_of(&topo, p, nshards, m.msg.dst);
            shards[dst].inject_delivery(m.at, m.msg);
        }
    }
}

/// Fleet-sum / fleet-aggregate the per-shard counters. All counters are
/// owner-counted on exactly one shard and sum to the serial value —
/// except `zero_bw_windows`, which every shard counts identically
/// (gate events are replicated fleet-wide), so the aggregate takes the
/// max instead of the sum.
fn aggregate_stats(shards: &[NetSim]) -> (SimStats, ChaosStats) {
    let mut stats = SimStats::default();
    let mut chaos = ChaosStats::default();
    for s in shards {
        stats.msgs_sent += s.stats.msgs_sent;
        stats.bytes_sent += s.stats.bytes_sent;
        stats.preemptions += s.stats.preemptions;
        for (acc, b) in stats.bytes_by_priority.iter_mut().zip(s.stats.bytes_by_priority.iter())
        {
            *acc += b;
        }
        // Per-tenant accounting (egress bytes/msgs owner-counted on the
        // source shard, busy ns on the rail's shard): elementwise sums,
        // resized up so a partitioned multi-tenant run loses nothing.
        for (dst, src) in [
            (&mut stats.tenant_bytes, &s.stats.tenant_bytes),
            (&mut stats.tenant_msgs, &s.stats.tenant_msgs),
            (&mut stats.tenant_busy_ns, &s.stats.tenant_busy_ns),
        ] {
            if dst.len() < src.len() {
                dst.resize(src.len(), 0);
            }
            for (acc, v) in dst.iter_mut().zip(src.iter()) {
                *acc += v;
            }
        }
        chaos.zero_bw_windows = chaos.zero_bw_windows.max(s.chaos_stats.zero_bw_windows);
        chaos.latency_spikes += s.chaos_stats.latency_spikes;
        chaos.rails_killed += s.chaos_stats.rails_killed;
        chaos.transfers_rerouted += s.chaos_stats.transfers_rerouted;
        chaos.slowdowns_applied += s.chaos_stats.slowdowns_applied;
    }
    (stats, chaos)
}

/// Drain and merge the fleet's per-shard trace buffers. Every span is
/// recorded on exactly one shard (hops on the source, compute/busy on
/// the owner, collective marks owner-gated — see `fabric/sim.rs`), so
/// the merge is a plain sorted union equal to the serial trace.
fn collect_trace(shards: &mut [NetSim], on: bool) -> Option<Trace> {
    if !on {
        return None;
    }
    Some(Trace::merge(
        shards.iter_mut().filter_map(NetSim::take_trace).collect(),
    ))
}

// ---------------------------------------------------------------------------
// Program-driven runs (real collective builders)
// ---------------------------------------------------------------------------

/// Per-shard driver walking one collective's chunk programs through a
/// replicated [`SimCollectives`]: the shard holds real programs for its
/// owned ranks and empty stand-ins for foreign ones (their sends are the
/// owner's job; their instant phantom completions are filtered out).
struct CollDriver {
    shard: usize,
    shards: usize,
    exec: SimCollectives,
    programs: Option<Vec<Program>>,
    wire: WireDtype,
    priority: Priority,
    completions: Vec<Completion>,
    delivered: Option<Vec<(MsgDesc, Ns)>>,
}

impl ShardDriver for CollDriver {
    fn start(&mut self, sim: &mut NetSim) {
        let programs = self.programs.take().expect("started once");
        let done =
            self.exec.post(sim, COLL_ID, programs, self.wire, self.priority);
        self.completions.extend(done);
    }

    fn on_event(&mut self, sim: &mut NetSim, ev: SimEvent) {
        if let Some(log) = &mut self.delivered {
            if let SimEvent::MsgDelivered { msg, at } = &ev {
                log.push((msg.clone(), *at));
            }
        }
        self.exec.on_event_into(sim, &ev, &mut self.completions);
    }
}

/// Run one collective (all `p` ranks, identity map) over a partitioned
/// fleet and return the aggregated outcome. With `cfg.shards == 1` this
/// is the serial pop sequence, windowed.
///
/// Panics if the fleet quiesces with unfinished ranks (a deadlocked
/// program — same contract as [`super::simexec::time_collective`]).
pub fn run_collective(
    topo: &Topology,
    p: usize,
    programs: Vec<Program>,
    wire: WireDtype,
    priority: Priority,
    cfg: &FleetConfig,
) -> ParOutcome {
    assert_eq!(programs.len(), p, "one program per rank");
    let shards_n = cfg.shards.max(1);
    let mut shards: Vec<NetSim> = (0..shards_n)
        .map(|s| {
            let mut sim = if shards_n == 1 {
                NetSim::new(topo.clone(), p)
            } else {
                NetSim::new_partition(topo.clone(), p, s, shards_n)
            };
            if let Some(plan) = &cfg.chaos {
                sim.set_chaos(plan.clone());
            }
            sim.set_trace(cfg.trace);
            sim
        })
        .collect();
    let mut drivers: Vec<CollDriver> = (0..shards_n)
        .map(|s| CollDriver {
            shard: s,
            shards: shards_n,
            exec: SimCollectives::new(),
            programs: Some(
                programs
                    .iter()
                    .map(|pr| {
                        if shards_n == 1 || shard_of(topo, p, shards_n, pr.rank) == s {
                            pr.clone()
                        } else {
                            Program { rank: pr.rank, steps: Vec::new() }
                        }
                    })
                    .collect(),
            ),
            wire,
            priority,
            completions: Vec::new(),
            delivered: cfg.record_deliveries.then(Vec::new),
        })
        .collect();
    let lookahead = effective_lookahead(topo, cfg.chaos.as_ref());
    run_fleet(&mut shards, &mut drivers, lookahead, cfg.threads);

    let mut completions: Vec<Completion> = Vec::with_capacity(p);
    let mut delivered = Vec::new();
    for d in &mut drivers {
        // Phantom completions (foreign empty programs) report the post
        // time; only the owner's are real.
        completions.extend(d.completions.iter().filter(|c| {
            d.shards == 1 || shard_of(topo, p, d.shards, c.rank) == d.shard
        }));
        if let Some(log) = &mut d.delivered {
            delivered.append(log);
        }
        assert_eq!(d.exec.in_flight(), 0, "fleet drained with op in flight: deadlock");
    }
    assert_eq!(completions.len(), p, "every rank must complete exactly once");
    completions.sort_by_key(|c| (c.at, c.rank));
    delivered.sort_by_key(delivery_key);
    let (stats, chaos) = aggregate_stats(&shards);
    let trace = collect_trace(&mut shards, cfg.trace);
    ParOutcome {
        finish_ns: completions.iter().map(|c| c.at).max().unwrap_or(0),
        final_clock: shards.iter().map(|s| s.now()).max().unwrap_or(0),
        completions,
        delivered,
        stats,
        chaos,
        trace,
    }
}

/// Reference serial run of the same workload on the classic simulator
/// (plain [`NetSim::next`] loop, fully drained): what the partitioned
/// fleet must byte-identically reproduce.
#[allow(clippy::too_many_arguments)]
pub fn run_collective_serial(
    topo: &Topology,
    p: usize,
    programs: Vec<Program>,
    wire: WireDtype,
    priority: Priority,
    chaos: Option<&ChaosPlan>,
    record_deliveries: bool,
    trace: bool,
) -> ParOutcome {
    let mut sim = NetSim::new(topo.clone(), p);
    if let Some(plan) = chaos {
        sim.set_chaos(plan.clone());
    }
    sim.set_trace(trace);
    let mut exec = SimCollectives::new();
    let mut completions = exec.post(&mut sim, COLL_ID, programs, wire, priority);
    let mut delivered = Vec::new();
    while let Some(ev) = sim.next() {
        if record_deliveries {
            if let SimEvent::MsgDelivered { msg, at } = &ev {
                delivered.push((msg.clone(), *at));
            }
        }
        exec.on_event_into(&mut sim, &ev, &mut completions);
    }
    assert_eq!(exec.in_flight(), 0, "fabric drained with op in flight: deadlock");
    assert_eq!(completions.len(), p);
    completions.sort_by_key(|c| (c.at, c.rank));
    delivered.sort_by_key(delivery_key);
    let mut shards = [sim];
    let (stats, chaos) = aggregate_stats(&shards);
    let tr = collect_trace(&mut shards, trace);
    ParOutcome {
        finish_ns: completions.iter().map(|c| c.at).max().unwrap_or(0),
        final_clock: shards[0].now(),
        completions,
        delivered,
        stats,
        chaos,
        trace: tr,
    }
}

fn delivery_key(d: &(MsgDesc, Ns)) -> (Ns, Rank, Rank, u64, u64, Priority) {
    (d.1, d.0.src, d.0.dst, d.0.tag, d.0.bytes, d.0.priority)
}

/// Time one collective over a `threads`-way partitioned fleet — the
/// parallel counterpart of [`super::simexec::time_collective`], exact
/// for its single-collective workload at any thread count.
pub fn time_collective_partitioned(
    topo: &Topology,
    p: usize,
    programs: Vec<Program>,
    wire: WireDtype,
    priority: Priority,
    threads: usize,
) -> Ns {
    run_collective(topo, p, programs, wire, priority, &FleetConfig::threaded(threads)).finish_ns
}

// ---------------------------------------------------------------------------
// Pattern-driven runs (datacenter-scale benches)
// ---------------------------------------------------------------------------

/// Synthetic collective dataflows with O(p) driver state: at p = 65,536 a
/// ring allreduce's explicit chunk programs would hold billions of steps,
/// so the scale benches drive the fabric with the *pattern* instead —
/// round k's send is gated on round k-1's receive, exactly the chunk
/// programs' dependency structure, with per-round partners below.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// Rank r sends to (r+1) mod p every round (2(p−1) rounds = the full
    /// ring allreduce: reduce-scatter then allgather).
    Ring,
    /// Round k pairs rank r with r XOR 2^k (p must be a power of two;
    /// log2(p) rounds = the full recursive-doubling allreduce).
    RecursiveDoubling,
}

/// A pattern workload: `rounds` rounds of `msg_bytes` messages per rank.
#[derive(Debug, Clone)]
pub struct PatternSpec {
    pub pattern: Pattern,
    pub p: usize,
    pub msg_bytes: u64,
    pub rounds: usize,
    pub priority: Priority,
}

impl PatternSpec {
    /// The full ring allreduce at `p` with `seg_bytes` per-step segments.
    pub fn ring_allreduce(p: usize, seg_bytes: u64) -> Self {
        Self { pattern: Pattern::Ring, p, msg_bytes: seg_bytes, rounds: 2 * (p - 1), priority: 1 }
    }

    /// The full recursive-doubling allreduce at `p` (power of two) with
    /// `msg_bytes` full-buffer messages.
    pub fn rdoubling_allreduce(p: usize, msg_bytes: u64) -> Self {
        assert!(p.is_power_of_two(), "recursive doubling needs a power-of-two p");
        Self {
            pattern: Pattern::RecursiveDoubling,
            p,
            msg_bytes,
            rounds: p.trailing_zeros() as usize,
            priority: 1,
        }
    }

    fn partner(&self, r: Rank, round: usize) -> Rank {
        match self.pattern {
            Pattern::Ring => (r + 1) % self.p,
            Pattern::RecursiveDoubling => r ^ (1usize << round),
        }
    }

    /// Total messages the whole fabric moves.
    pub fn total_msgs(&self) -> u64 {
        self.p as u64 * self.rounds as u64
    }
}

struct PatternDriver {
    spec: PatternSpec,
    /// Rounds sent / received per rank (only owned ranks ever advance
    /// past the initial post — foreign sends are dropped by the shard).
    sent: Vec<u32>,
    recvd: Vec<u32>,
    last_at: Ns,
}

impl PatternDriver {
    fn try_send(&mut self, sim: &mut NetSim, r: Rank) {
        // Round k's send is gated on k receives (rounds 0..k-1 consumed).
        while (self.sent[r] as usize) < self.spec.rounds && self.recvd[r] >= self.sent[r] {
            let k = self.sent[r] as usize;
            sim.send(MsgDesc {
                src: r,
                dst: self.spec.partner(r, k),
                bytes: self.spec.msg_bytes,
                priority: self.spec.priority,
                tag: k as u64,
            });
            self.sent[r] += 1;
        }
    }
}

impl ShardDriver for PatternDriver {
    fn start(&mut self, sim: &mut NetSim) {
        for r in 0..self.spec.p {
            self.try_send(sim, r); // the shard drops foreign sends itself
        }
    }

    fn on_event(&mut self, sim: &mut NetSim, ev: SimEvent) {
        if let SimEvent::MsgDelivered { msg, at } = ev {
            self.last_at = self.last_at.max(at);
            self.recvd[msg.dst] += 1;
            self.try_send(sim, msg.dst);
        }
    }
}

/// Run a [`PatternSpec`] over a partitioned fleet; `finish_ns` is the
/// last delivery. `cfg.shards == 1` with [`NetSim::new`] semantics is
/// the serial reference.
pub fn run_pattern(topo: &Topology, spec: &PatternSpec, cfg: &FleetConfig) -> ParOutcome {
    assert!(spec.p >= 2, "patterns need at least two ranks");
    if spec.pattern == Pattern::RecursiveDoubling {
        assert!(spec.p.is_power_of_two() && spec.rounds <= spec.p.trailing_zeros() as usize);
    }
    let shards_n = cfg.shards.max(1);
    let mut shards: Vec<NetSim> = (0..shards_n)
        .map(|s| {
            let mut sim = if shards_n == 1 {
                NetSim::new(topo.clone(), spec.p)
            } else {
                NetSim::new_partition(topo.clone(), spec.p, s, shards_n)
            };
            if let Some(plan) = &cfg.chaos {
                sim.set_chaos(plan.clone());
            }
            sim.set_trace(cfg.trace);
            sim
        })
        .collect();
    let mut drivers: Vec<PatternDriver> = (0..shards_n)
        .map(|_| PatternDriver {
            spec: spec.clone(),
            sent: vec![0; spec.p],
            recvd: vec![0; spec.p],
            last_at: 0,
        })
        .collect();
    let lookahead = effective_lookahead(topo, cfg.chaos.as_ref());
    run_fleet(&mut shards, &mut drivers, lookahead, cfg.threads);
    // Every owned rank must have received all its rounds.
    for (s, d) in drivers.iter().enumerate() {
        for r in 0..spec.p {
            if shards_n == 1 || shard_of(topo, spec.p, shards_n, r) == s {
                assert_eq!(
                    d.recvd[r] as usize, spec.rounds,
                    "rank {r} on shard {s} starved: pattern deadlock"
                );
            }
        }
    }
    let (stats, chaos) = aggregate_stats(&shards);
    let trace = collect_trace(&mut shards, cfg.trace);
    ParOutcome {
        finish_ns: drivers.iter().map(|d| d.last_at).max().unwrap_or(0),
        final_clock: shards.iter().map(|s| s.now()).max().unwrap_or(0),
        completions: Vec::new(),
        delivered: Vec::new(),
        stats,
        chaos,
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::program::{allreduce_rdoubling, allreduce_ring};

    fn flat() -> Topology {
        // 8 Gbps = 1 B/ns, alpha 1000, gamma 100.
        Topology::flat("t", 8.0, 1_000, 100, 1 << 20)
    }

    #[test]
    fn partitioned_ring_matches_serial_exactly() {
        let topo = flat();
        let p = 8;
        let n = 4 << 10;
        let serial = run_collective_serial(
            &topo,
            p,
            allreduce_ring(p, n),
            WireDtype::F32,
            1,
            None,
            true,
            false,
        );
        for shards in [1usize, 2, 3, 4] {
            for threads in [1usize, 2, 4] {
                let cfg = FleetConfig {
                    shards,
                    threads,
                    chaos: None,
                    record_deliveries: true,
                    trace: false,
                };
                let par =
                    run_collective(&topo, p, allreduce_ring(p, n), WireDtype::F32, 1, &cfg);
                assert_eq!(par.completions, serial.completions, "shards={shards}");
                assert_eq!(par.delivered, serial.delivered, "shards={shards}");
                assert_eq!(par.finish_ns, serial.finish_ns);
                assert_eq!(par.final_clock, serial.final_clock);
                assert_eq!(par.stats.msgs_sent, serial.stats.msgs_sent);
                assert_eq!(par.stats.bytes_sent, serial.stats.bytes_sent);
            }
        }
    }

    #[test]
    fn chaos_counters_survive_partitioning() {
        let topo = flat();
        let p = 8;
        let n = 64 << 10;
        let plan = ChaosPlan::generate(41, &topo, p, 2_000_000);
        let serial = run_collective_serial(
            &topo,
            p,
            allreduce_ring(p, n),
            WireDtype::F32,
            1,
            Some(&plan),
            true,
            false,
        );
        let cfg = FleetConfig {
            shards: 4,
            threads: 2,
            chaos: Some(plan),
            record_deliveries: true,
            trace: false,
        };
        let par = run_collective(&topo, p, allreduce_ring(p, n), WireDtype::F32, 1, &cfg);
        assert_eq!(par.delivered, serial.delivered);
        assert_eq!(par.chaos, serial.chaos);
        assert_eq!(par.final_clock, serial.final_clock);
    }

    #[test]
    fn pattern_runs_match_their_program_counterparts_shape() {
        // The ring pattern's finish time must equal the real ring
        // program's at matched segment size (same dependency structure).
        let topo = flat();
        let p = 8;
        let n = 8 * 1024; // elements; seg = n/p elems = 4096 bytes
        let t_prog = run_collective_serial(
            &topo,
            p,
            allreduce_ring(p, n),
            WireDtype::F32,
            1,
            None,
            false,
            false,
        )
        .finish_ns;
        let spec = PatternSpec::ring_allreduce(p, (n / p * 4) as u64);
        let serial_cfg = FleetConfig {
            shards: 1,
            threads: 1,
            chaos: None,
            record_deliveries: false,
            trace: false,
        };
        let t_pat = run_pattern(&topo, &spec, &serial_cfg).finish_ns;
        assert_eq!(t_pat, t_prog);
    }

    #[test]
    fn pattern_partitioning_is_exact_at_any_shard_count() {
        let topo = flat();
        for spec in [
            PatternSpec::ring_allreduce(12, 2_000),
            PatternSpec::rdoubling_allreduce(16, 8_000),
        ] {
            let serial = run_pattern(
                &topo,
                &spec,
                &FleetConfig {
                    shards: 1,
                    threads: 1,
                    chaos: None,
                    record_deliveries: false,
                    trace: false,
                },
            );
            for threads in [2usize, 4] {
                let par = run_pattern(&topo, &spec, &FleetConfig::threaded(threads));
                assert_eq!(par.finish_ns, serial.finish_ns, "{spec:?} threads={threads}");
                assert_eq!(par.stats.msgs_sent, serial.stats.msgs_sent);
                assert_eq!(par.stats.msgs_sent, spec.total_msgs());
                assert_eq!(par.stats.bytes_sent, serial.stats.bytes_sent);
            }
        }
    }

    #[test]
    fn traces_merge_byte_identically_across_shards_and_threads() {
        let topo = flat();
        let p = 8;
        let n = 4 << 10;
        let serial = run_collective_serial(
            &topo,
            p,
            allreduce_ring(p, n),
            WireDtype::F32,
            1,
            None,
            false,
            true,
        );
        let st = serial.trace.expect("serial trace recorded");
        assert!(st.span_count() > 0);
        // Every rank's completion made it into the trace exactly once.
        let dones = st
            .events
            .iter()
            .filter(|e| matches!(e, crate::trace::TraceEvent::RankDone { .. }))
            .count();
        assert_eq!(dones, p);
        for (shards, threads) in [(2usize, 1usize), (3, 2), (4, 4)] {
            let cfg = FleetConfig {
                shards,
                threads,
                chaos: None,
                record_deliveries: false,
                trace: true,
            };
            let par = run_collective(&topo, p, allreduce_ring(p, n), WireDtype::F32, 1, &cfg);
            assert_eq!(
                par.trace.as_ref(),
                Some(&st),
                "merged trace must equal serial (shards={shards} threads={threads})"
            );
        }
        // And tracing itself never moves the clock.
        let untraced = run_collective_serial(
            &topo,
            p,
            allreduce_ring(p, n),
            WireDtype::F32,
            1,
            None,
            false,
            false,
        );
        assert_eq!(untraced.finish_ns, serial.finish_ns);
        assert!(untraced.trace.is_none());
    }

    #[test]
    fn effective_lookahead_shrinks_only_for_hand_built_sub_healthy_flaps() {
        let topo = flat();
        assert_eq!(effective_lookahead(&topo, None), 1_000);
        let gen = ChaosPlan::generate(7, &topo, 4, 1_000_000);
        assert_eq!(effective_lookahead(&topo, Some(&gen)), 1_000, "generated plans never shrink");
        let mut plan = ChaosPlan::quiet(0, 4);
        plan.flaps.push(crate::fabric::FlapWindow {
            level: 0,
            from: 0,
            until: 1_000,
            zero_bw: false,
            latency_mult_milli: 500, // half latency: lookahead must halve
        });
        assert_eq!(effective_lookahead(&topo, Some(&plan)), 500);
    }
}

//! **Ablation A8**: 3-level (node + rack) vs 2-level (node only)
//! hierarchical allreduce on a rack-oversubscribed 10GbE fabric.
//!
//! The `eth10g-x8r16` preset groups 8 ranks per shared-memory node and 16
//! nodes per rack behind a 4:1-oversubscribed spine (in-rack hops keep
//! the 10G NIC rate at half the latency; cross-rack hops see 2.5G and 2×
//! latency). A 2-level hierarchy still runs its whole leader phase over
//! every node leader; the 3-level stack adds a rack reduction so only one
//! leader per rack crosses the spine.
//!
//! Where the 2-level leader count is a power of two, halving-doubling's
//! XOR rounds already localize the small-distance rounds in-rack — the
//! extra tree level buys little. Where it is NOT (the top phase degrades
//! to a ring whose every lockstep includes a cross-rack hop), the rack
//! level wins outside the pure-bandwidth regime. This bench sweeps both
//! shapes, prints simulated times, and ASSERTS the acceptance criterion:
//! 3-level beats 2-level for p >= 256 at non-power-of-two leader counts
//! across the latency-to-mid size range, and tuned selection (a table
//! built from these same measurements) picks the 3-level stack there.
//!
//! Run: `cargo bench --bench a8_three_level`

use mlsl::collectives::program::{build, CollectiveKind};
use mlsl::collectives::selector::choose_algorithm;
use mlsl::collectives::simexec::time_collective;
use mlsl::collectives::{Algorithm, WireDtype};
use mlsl::fabric::topology::Topology;
use mlsl::fabric::NetSim;
use mlsl::metrics::print_table;
use mlsl::tuner::table::{MeasuredCell, TuningTable};
use mlsl::tuner::SelectionPolicy;
use mlsl::util::stats::fmt_bytes;

fn simulate(topo: &Topology, alg: Algorithm, p: usize, n: usize) -> u64 {
    let programs =
        build(CollectiveKind::Allreduce, alg, p, n).expect("bench algorithms are buildable");
    time_collective(&mut NetSim::new(topo.clone(), p), programs, WireDtype::F32, 1)
}

fn main() {
    let topo = Topology::by_name("eth10g-x8r16").expect("rack preset resolves");
    let two = Algorithm::hier(&[8]);
    let three = Algorithm::hier(&[8, 128]);
    let sizes: [u64; 3] = [64 << 10, 1 << 20, 16 << 20];
    // Assertion scope: non-pow2 leader counts, latency-to-mid sizes.
    let asserted_sizes = 1u64 << 20;
    let mut table = TuningTable::for_topology(&topo);
    let mut rows = Vec::new();
    let mut wins = 0usize;
    for p in [256usize, 384, 768] {
        let leaders = p / 8;
        for bytes in sizes {
            let n = (bytes / 4) as usize;
            let t_two = simulate(&topo, two, p, n);
            let t_three = simulate(&topo, three, p, n);
            let auto = choose_algorithm(&topo, p, bytes);
            table.insert(
                CollectiveKind::Allreduce,
                MeasuredCell::new(p, bytes, vec![(two, t_two), (three, t_three)]),
            );
            if !leaders.is_power_of_two() && bytes <= asserted_sizes {
                assert!(
                    t_three < t_two,
                    "p={p} bytes={bytes}: three={t_three} two={t_two}"
                );
                wins += 1;
            }
            rows.push(vec![
                p.to_string(),
                leaders.to_string(),
                fmt_bytes(bytes),
                format!("{:.3}", t_two as f64 / 1e6),
                format!("{:.3}", t_three as f64 / 1e6),
                format!("{:.2}x", t_two as f64 / t_three.max(1) as f64),
                auto.to_string(),
            ]);
        }
    }
    print_table(
        "A8: 2-level vs 3-level hierarchical allreduce, eth10g-x8r16 (4:1 spine)",
        &["ranks", "leaders", "size", "2-level ms", "3-level ms", "speedup", "auto picks"],
        &rows,
    );

    // Tuned selection must pick the 3-level stack exactly where it
    // measured fastest (the table above was built from these runs).
    let policy = SelectionPolicy::TunedWithFallback(table);
    for (p, bytes) in [(384usize, 64u64 << 10), (384, 1 << 20), (768, 64 << 10), (768, 1 << 20)] {
        let pick = policy.choose_allreduce(&topo, p, bytes);
        assert_eq!(pick, three, "tuned pick at p={p} bytes={bytes}");
    }
    // And the analytic chooser agrees in the same regime.
    for (p, bytes) in [(384usize, 64u64 << 10), (384, 1 << 20)] {
        assert_eq!(choose_algorithm(&topo, p, bytes), three, "analytic pick p={p}");
    }

    println!("\nexpected shape: the rack level pays 2*ceil(log2 16) full-buffer rounds on");
    println!("the in-rack tier to take all but one leader per rack off the oversubscribed");
    println!("spine — a clear win while rounds dominate (small/mid sizes, ring-shaped");
    println!("leader phases), converging to the spine wire bound at huge sizes where");
    println!("halving-doubling's XOR locality already kept its big rounds in-rack.");
    println!("acceptance: 3-level < 2-level in all {wins} asserted cells; tuned + analytic");
    println!("selection pick the 3-level stack there. OK");
}

//! # mlsl-rs — Machine Learning Scaling Library, reproduced in Rust
//!
//! A from-scratch reproduction of *On Scale-out Deep Learning Training for
//! Cloud and HPC* (Sridharan et al., SysML 2018): Intel's MLSL — a
//! DL-specific communication/scaling library. See `DESIGN.md` for the full
//! system inventory and the per-experiment index.
//!
//! Two guided tours live under `docs/`:
//!
//! * `docs/ARCHITECTURE.md` — the whole stack top to bottom (topology →
//!   chunk programs → selection → tuner → event-driven fabric, including
//!   the partitioned parallel-simulation mode behind `--sim-threads` →
//!   engine churn/chaos), with the data-flow diagram, the warning
//!   contract and measured simulator performance;
//! * `docs/PRESETS.md` — every topology preset, the
//!   `<base>[-x<r>[r<k>][e<l>]]` suffix grammar and worked examples.
//!
//! ## Layout
//!
//! * [`fabric`] — the cluster substrate: a discrete-event network simulator
//!   with strict-priority preemptive NICs (the paper's Xeon/Omnipath and
//!   10GbE testbeds, rebuilt), plus a real in-process shared-memory fabric
//!   where ranks are threads.
//! * [`collectives`] — allreduce / reduce-scatter / allgather / broadcast as
//!   per-rank *chunk programs* (ring, recursive halving-doubling, binomial
//!   tree), size-adaptive algorithm selection, and low-precision wire
//!   formats (fp32 / bf16 / int8 with per-block scales); includes the
//!   partitioned parallel executor ([`collectives::parexec`]) that runs
//!   timing workloads over sharded simulators with byte-identical
//!   results.
//! * [`progress`] — the asynchronous progress engine: dedicated "comm
//!   cores" (threads) drive chunk programs off the compute path, with
//!   message prioritization and chunk-granular preemption.
//! * [`mlsl`] — the paper's two public interfaces: the MPI-like
//!   collectives API and the DL Layer API (`Session` / `Operation` /
//!   `Distribution`), including hybrid (node-group) parallelism.
//! * [`models`] — layer tables for ResNet-50, VGG-16, GoogLeNet, AlexNet
//!   and a Transformer LM (per-layer FLOPs / weight / activation bytes).
//! * [`analytic`] — the compute-to-communication ratio model of Das et al.
//!   (arXiv:1602.06709), used for design-space analysis and to cross-check
//!   the simulator.
//! * [`engine`] — the framework role: per-layer fwd/bwd iteration timeline
//!   driving MLSL ops over the simulated fabric; includes the out-of-box
//!   MPI/Horovod baseline modes the paper compares against.
//! * [`tuner`] — measurement-driven collective selection: a probe that
//!   times every candidate algorithm on the live topology, persisted
//!   tuning tables (fingerprint-keyed, JSON), and the `SelectionPolicy`
//!   (analytic / tuned / tuned-with-fallback) every algorithm choice goes
//!   through.
//! * [`runtime`] — PJRT wrapper (via the `xla` crate) that loads the
//!   AOT-compiled JAX+Pallas artifacts (`artifacts/*.hlo.txt`).
//! * [`trainer`] — the *real* data-parallel trainer: rank threads execute
//!   `grad_step` via PJRT, gradients are allreduced by this library (with
//!   per-layer priorities), then `apply_update` runs — Python never on the
//!   training path.
//! * [`trace`] — the deterministic observability layer: structured spans
//!   recorded off the simulator's event hot paths (zero impact when
//!   disabled), Chrome trace-event export, critical-path analysis and
//!   windowed utilization; `docs/TRACING.md` is the guided tour.
//! * [`config`] / [`metrics`] — TOML run configs, manifest loading,
//!   counters, timelines and CSV emission; counters live in a global
//!   registry the trace CLI dumps.

pub mod analytic;
pub mod collectives;
pub mod config;
pub mod engine;
pub mod fabric;
pub mod metrics;
pub mod mlsl;
pub mod models;
pub mod progress;
pub mod runtime;
pub mod trace;
pub mod trainer;
pub mod tuner;
pub mod util;

/// Rank of a node (or thread standing in for a node) inside a communicator.
pub type Rank = usize;

/// Nanosecond simulation timestamps (integer: keeps the event queue totally
/// ordered and property-test friendly).
pub type Ns = u64;

/// Message/op priority class: **0 is most urgent**. The DL Layer API maps
/// a parameter's forward order to its gradient-allreduce priority so the
/// first layer's (needed first in the next forward pass) wins the wire.
pub type Priority = u8;

pub use collectives::{Algorithm, ReduceOp, WireDtype};
pub use mlsl::{Distribution, Session};

"""AOT lowering: JAX (L2 + L1 Pallas) -> HLO text artifacts for the Rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published `xla` 0.1.6 crate binds) rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Per preset we emit:
    artifacts/<preset>/grad_step.hlo.txt     (params..., tokens) -> (loss, grads...)
    artifacts/<preset>/apply_update.hlo.txt  (params..., moms..., grads...) -> (params'..., moms'...)
    artifacts/<preset>/train_step.hlo.txt    (params..., moms..., tokens) -> (params'..., moms'..., loss)
    artifacts/<preset>/eval_loss.hlo.txt     (params..., tokens) -> (loss,)
    artifacts/<preset>/manifest.json         parameter order/shapes/layers, io specs, hparams
plus shared micro artifacts:
    artifacts/micro/quant_roundtrip.hlo.txt  (x,) -> (q, scales, deq)
    artifacts/micro/matmul.hlo.txt           (x, w, b) -> (y,)

Python runs ONCE at `make artifacts`; the Rust binary is self-contained
afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import kernels, model
from .presets import PRESETS, n_params


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)/1e6:.2f} MB)")


def emit_preset(preset: str, out_dir: str, lr: float, mu: float, wd: float,
                skip_heavy: bool = False) -> None:
    cfg = PRESETS[preset]
    specs = model.param_specs(cfg)
    n = len(specs)
    pdir = os.path.join(out_dir, preset)
    print(f"[{preset}] {n} params, {n_params(cfg)/1e6:.1f}M elements")

    f32 = jnp.float32
    p_specs = [jax.ShapeDtypeStruct(tuple(s["shape"]), f32) for s in specs]
    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)

    def lower(fn, *args):
        return to_hlo_text(jax.jit(fn).lower(*args))

    _write(os.path.join(pdir, "grad_step.hlo.txt"),
           lower(lambda *a: model.grad_step(cfg, *a), *p_specs, tok_spec))
    _write(os.path.join(pdir, "apply_update.hlo.txt"),
           lower(lambda *a: model.apply_update(cfg, lr, mu, wd, *a),
                 *p_specs, *p_specs, *p_specs))
    if not skip_heavy:
        _write(os.path.join(pdir, "train_step.hlo.txt"),
               lower(lambda *a: model.train_step(cfg, lr, mu, wd, *a),
                     *p_specs, *p_specs, tok_spec))
    _write(os.path.join(pdir, "eval_loss.hlo.txt"),
           lower(lambda *a: model.eval_loss(cfg, *a), *p_specs, tok_spec))

    manifest = {
        "preset": preset,
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "seq_len": cfg.seq_len, "batch": cfg.batch,
            "d_ff": cfg.d_ff, "n_param_tensors": n,
            "n_param_elements": int(sum(s["size"] for s in specs)),
        },
        "hparams": {"lr": lr, "momentum": mu, "weight_decay": wd},
        "params": specs,
        "artifacts": {
            "grad_step": {
                "file": "grad_step.hlo.txt",
                "inputs": [s["name"] for s in specs] + ["tokens"],
                "outputs": ["loss"] + [f"grad.{s['name']}" for s in specs],
            },
            "apply_update": {
                "file": "apply_update.hlo.txt",
                "inputs": [s["name"] for s in specs]
                          + [f"mom.{s['name']}" for s in specs]
                          + [f"grad.{s['name']}" for s in specs],
                "outputs": [s["name"] for s in specs]
                           + [f"mom.{s['name']}" for s in specs],
            },
            "train_step": None if skip_heavy else {
                "file": "train_step.hlo.txt",
                "inputs": [s["name"] for s in specs]
                          + [f"mom.{s['name']}" for s in specs] + ["tokens"],
                "outputs": [s["name"] for s in specs]
                           + [f"mom.{s['name']}" for s in specs] + ["loss"],
            },
            "eval_loss": {
                "file": "eval_loss.hlo.txt",
                "inputs": [s["name"] for s in specs] + ["tokens"],
                "outputs": ["loss"],
            },
        },
        "tokens_shape": [cfg.batch, cfg.seq_len + 1],
    }
    _write(os.path.join(pdir, "manifest.json"), json.dumps(manifest, indent=1))


def emit_micro(out_dir: str) -> None:
    mdir = os.path.join(out_dir, "micro")
    n = 64 * kernels.QBLOCK
    x_spec = jax.ShapeDtypeStruct((n,), jnp.float32)

    def quant_roundtrip(x):
        q, s = kernels.quantize_int8(x)
        return q, s, kernels.dequantize_int8(q, s)

    _write(os.path.join(mdir, "quant_roundtrip.hlo.txt"),
           to_hlo_text(jax.jit(quant_roundtrip).lower(x_spec)))

    m, k, nn = 256, 256, 256
    _write(os.path.join(mdir, "matmul.hlo.txt"),
           to_hlo_text(jax.jit(
               lambda x, w, b: (kernels.matmul_bias_act(x, w, b, "gelu"),)
           ).lower(
               jax.ShapeDtypeStruct((m, k), jnp.float32),
               jax.ShapeDtypeStruct((k, nn), jnp.float32),
               jax.ShapeDtypeStruct((nn,), jnp.float32),
           )))
    _write(os.path.join(mdir, "manifest.json"), json.dumps({
        "quant_roundtrip": {"file": "quant_roundtrip.hlo.txt", "n": n,
                            "qblock": kernels.QBLOCK},
        "matmul": {"file": "matmul.hlo.txt", "m": m, "k": k, "n": nn},
    }, indent=1))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small",
                    help="comma-separated; 'base100m' is compile-only scale")
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=1e-4)
    ap.add_argument("--skip-heavy", action="store_true",
                    help="skip the fused train_step artifact (largest lowering)")
    args = ap.parse_args()

    for preset in args.presets.split(","):
        preset = preset.strip()
        if preset:
            emit_preset(preset, args.out_dir, args.lr, args.momentum,
                        args.weight_decay, skip_heavy=args.skip_heavy)
    emit_micro(args.out_dir)
    # Stamp for make's incremental check.
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("ok\n")
    print("artifacts complete")


if __name__ == "__main__":
    main()
